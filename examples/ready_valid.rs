//! The hybrid ready-valid interconnect (§3.3 + §4.1).
//!
//! Builds the RV NoC backend, reports the Fig. 8 area trade (full FIFO vs
//! split FIFO), and demonstrates the behavioural side: elastic channels
//! absorb bursty backpressure that stalls a static fabric, while the
//! split FIFO trades a little combinational delay for most of the area
//! saving.
//!
//! Run: `cargo run --release --example ready_valid`

use std::collections::HashMap;

use canal::apps;
use canal::area::{area_of, AreaModel, FabricMode};
use canal::coordinator;
use canal::dsl::{create_uniform_interconnect, InterconnectConfig};
use canal::hw::{emit, lower_ready_valid, verify_rtl, RvOptions};
use canal::sim::{FabricKind, RvSim, StallPattern};

fn main() {
    let cfg =
        InterconnectConfig { width: 6, height: 6, mem_column_period: 0, ..Default::default() };
    let ic = create_uniform_interconnect(&cfg);

    // Generate the ready-valid hardware (valid mirrors + ready joins +
    // split FIFOs) and verify its data path against the IR.
    let lowered = lower_ready_valid(&ic, &RvOptions { fifo_depth: 2, split: true });
    let rtl = emit(&lowered.netlist);
    assert!(verify_rtl(&ic, &rtl).is_empty());
    let h = lowered.netlist.histogram();
    println!(
        "rv fabric: {} data muxes, {} valid muxes, {} ready joins, {} fifos",
        h["mux"], h["valid_mux"], h["ready_join"], h["fifo"]
    );

    // Fig. 8: the area trade.
    println!("\n{}", coordinator::fig08_fifo_area().render());

    // Behaviour: bursty sink backpressure on the camera pipeline.
    println!("elastic behaviour under bursty backpressure (camera, 96 tokens):");
    let app = apps::camera();
    let model = AreaModel::default();
    for fabric in
        [FabricKind::Static, FabricKind::RvFullFifo { depth: 2 }, FabricKind::RvSplitFifo]
    {
        let caps: HashMap<_, _> = app
            .edges()
            .iter()
            .map(|e| ((e.src, e.src_port, e.dst, e.dst_port), fabric.capacity(1)))
            .collect();
        let input: Vec<i64> = (0..512).map(|i| (i * 31 + 7) % 255).collect();
        let mut sim = RvSim::new(&app, &caps, input);
        let run = sim.run(96, 10_000_000, StallPattern::Bursty { accept: 3, stall: 2 });
        let mode = match fabric {
            FabricKind::Static => FabricMode::Static,
            FabricKind::RvFullFifo { depth } => {
                FabricMode::ReadyValidFullFifo { fifo_depth: depth as usize }
            }
            FabricKind::RvSplitFifo => FabricMode::ReadyValidSplitFifo,
        };
        let area = area_of(&ic, &model, mode).interior_tile(&ic).sb_um2;
        println!(
            "  {:<28} {} cycles for {} tokens, period penalty {:+.0} ps, sb area {:.0} um^2",
            format!("{fabric:?}"),
            run.cycles,
            run.tokens,
            fabric.period_penalty_ps(2),
            area,
        );
    }
    println!("\nsplit FIFO: full-FIFO elasticity at a fraction of the area (Fig. 6/8).");
}
