//! End-to-end driver: the full system on a real small workload.
//!
//! This is the repo's headline validation (EXPERIMENTS.md §E2E). For the
//! whole application suite on the paper's baseline interconnect it runs
//! every layer of the stack and proves they compose:
//!
//!   eDSL → IR → RTL generation + structural verification
//!       → pack → analytic global placement (**AOT JAX/Pallas artifact
//!         executed through PJRT from Rust**) → SA detailed placement
//!       → negotiated A* routing → STA → bitstream
//!       → functional check of every routed net on the configured fabric
//!       → cycle-accurate elastic simulation of gaussian 3x3 on a real
//!         16x16 image, checked against a direct 2-D convolution.
//!
//! Run: `make artifacts && cargo run --release --example e2e_paper_eval`

use std::collections::HashMap;
use std::time::Instant;

use canal::apps;
use canal::area::{area_of, AreaModel, FabricMode};
use canal::bitstream::{encode, Configuration};
use canal::coordinator;
use canal::dsl::{create_uniform_interconnect, InterconnectConfig};
use canal::hw::{allocate, emit, lower_static, verify_rtl};
use canal::pnr::{run_flow_with, FlowParams, SaParams};
use canal::sim::{check_routing, FabricKind, RvSim, StallPattern};
use canal::util::table::{fmt, Table};

const IMG: usize = 16;

/// Direct 2-D binomial 3x3 convolution (zero padded), >> 4 — the golden
/// reference for the gaussian DFG.
fn gaussian_ref(img: &[i64]) -> Vec<i64> {
    let k = [1i64, 2, 1, 2, 4, 2, 1, 2, 1];
    let mut out = vec![0i64; IMG * IMG];
    for y in 0..IMG {
        for x in 0..IMG {
            let mut acc = 0;
            for dy in 0..3usize {
                for dx in 0..3usize {
                    let (sy, sx) = (y as i64 - dy as i64, x as i64 - dx as i64);
                    if sy >= 0 && sx >= 0 {
                        acc += k[dy * 3 + dx] * img[sy as usize * IMG + sx as usize];
                    }
                }
            }
            out[y * IMG + x] = acc >> 4;
        }
    }
    out
}

fn main() {
    let t0 = Instant::now();
    println!("=== Canal end-to-end evaluation (paper baseline fabric) ===\n");

    // --- 1. Fabric: the paper's §4 baseline, 8x8 ------------------------
    let cfg = InterconnectConfig::paper_baseline(8, 8);
    let ic = create_uniform_interconnect(&cfg);
    let lowered = lower_static(&ic);
    let rtl = emit(&lowered.netlist);
    assert!(verify_rtl(&ic, &rtl).is_empty(), "RTL/IR structural mismatch");
    let cs = allocate(&ic);
    let model = AreaModel::default();
    let area = area_of(&ic, &model, FabricMode::Static);
    println!(
        "fabric `{}`:\n  {} IR nodes, {} edges; RTL {} KiB, structural verification PASS",
        ic.descriptor,
        ic.node_count(),
        ic.edge_count(),
        rtl.len() / 1024
    );
    println!(
        "  interconnect area {:.0} um^2 (SB {:.0}, CB {:.0}, config {:.0})\n",
        area.total_um2(),
        area.total_sb_um2(),
        area.total_cb_um2(),
        area.total_config_um2()
    );

    // --- 2. PnR the whole suite with the PJRT (JAX/Pallas) placer ------
    let placer = coordinator::default_placer();
    println!("global placement backend: {}\n", placer.name());
    let params = FlowParams {
        sa: SaParams { moves_per_node: 20, ..Default::default() },
        alpha_sweep: vec![1.0, 2.0, 4.0],
        ..Default::default()
    };

    let mut t = Table::new(
        "per-application results (8x8 wilton, 5 tracks, 4096-item stream)",
        &["app", "verts", "nets", "route_iters", "crit_ps", "runtime_us", "bitstream_words"],
    );
    let mut total_runtime_us = 0.0;
    for app in apps::suite() {
        let r = run_flow_with(&ic, &app, &params, placer.as_ref())
            .unwrap_or_else(|e| panic!("{} failed to route: {e}", app.name));
        let config = Configuration::from_routing(&ic, 16, &r.routing).unwrap();
        check_routing(&ic, 16, &config, &r.routing)
            .unwrap_or_else(|e| panic!("{}: functional check failed: {e}", app.name));
        let bits = encode(&config, &cs);
        total_runtime_us += r.timing.runtime_ns / 1000.0;
        t.row(vec![
            app.name.clone(),
            r.packed.app.len().to_string(),
            r.routing.trees.len().to_string(),
            r.routing.iterations.to_string(),
            fmt(r.timing.critical_path_ps),
            fmt(r.timing.runtime_ns / 1000.0),
            bits.len().to_string(),
        ]);
    }
    t.note("every row: routed + bitstream generated + every net functionally verified");
    println!("{}", t.render());

    // --- 3. Real workload: gaussian 3x3 on a 16x16 image ----------------
    println!("gaussian 3x3 on a real {IMG}x{IMG} image (elastic simulation):");
    let img: Vec<i64> = (0..IMG * IMG).map(|i| ((i * 37 + 11) % 256) as i64).collect();
    let app = apps::gaussian();
    let caps: HashMap<_, _> = app
        .edges()
        .iter()
        .map(|e| ((e.src, e.src_port, e.dst, e.dst_port), FabricKind::RvSplitFifo.capacity(1)))
        .collect();
    let mut sim = RvSim::new(&app, &caps, img.clone());
    sim.linebuffer_delay = IMG;
    let run = sim.run(IMG * IMG, 10_000_000, StallPattern::Bursty { accept: 7, stall: 2 });
    let got = &run.outputs["out"];
    let want = gaussian_ref(&img);
    assert_eq!(got.len(), IMG * IMG, "incomplete output");

    // Interior pixels must match the direct convolution exactly (the
    // streaming boundary handling differs only at x<2 / y<2 edges).
    let mut checked = 0;
    for y in 2..IMG {
        for x in 2..IMG {
            let i = y * IMG + x;
            assert_eq!(
                got[i], want[i],
                "pixel ({x},{y}): stream {} vs conv {}",
                got[i], want[i]
            );
            checked += 1;
        }
    }
    println!(
        "  {} interior pixels match direct 2-D convolution exactly; {} cycles under backpressure",
        checked, run.cycles
    );

    println!("\ntotal modeled suite run time: {:.1} us", total_runtime_us);
    println!("e2e driver wall clock: {:.1} s — ALL CHECKS PASS", t0.elapsed().as_secs_f64());
}
