//! Design-space exploration (§4.2): sweep the three axes the paper
//! explores — switch-box topology, routing tracks, and core connection
//! sides — and print the paper-style tables.
//!
//! Run: `cargo run --release --example design_space_exploration`

use canal::coordinator::{self, ExpOptions};

fn main() {
    let o = ExpOptions { sa_moves: 10, ..Default::default() };
    let placer = coordinator::default_placer();

    println!("{}", coordinator::fig09_topology(&o).render());
    println!("{}", coordinator::fig10_area_tracks().render());
    println!("{}", coordinator::fig11_runtime_tracks(&o, placer.as_ref()).render());
    println!("{}", coordinator::fig13_port_area().render());
    println!("{}", coordinator::fig14_sb_ports_runtime(&o, placer.as_ref()).render());
    println!("{}", coordinator::fig15_cb_ports_runtime(&o, placer.as_ref()).render());
    println!("{}", coordinator::alpha_sweep(&o).render());
}
