//! Design-space exploration (§4.2 + §3.3): sweep the axes the paper
//! explores — fabric (static vs ready-valid), switch-box topology,
//! routing tracks, and core connection sides — and print the
//! paper-style tables.
//!
//! The sweeps run through the sharded `canal::dse` engine: one engine
//! instance is shared across the seven engine-backed figures, so
//! overlapping points are PnR'd once, and results persist in
//! `dse_cache.json` — on a warm re-run the engine performs zero PnR
//! calls and zero elastic simulations (the fig13 area table and the
//! alpha ablation at the end run outside the engine and recompute
//! every time).
//!
//! Run: `cargo run --release --example design_space_exploration`

use canal::coordinator::{self, ExpOptions};
use canal::dse::{DseEngine, EngineOptions};

fn main() {
    let o = ExpOptions { sa_moves: 10, ..Default::default() };
    let placer = coordinator::default_placer();
    let mut engine = DseEngine::new(EngineOptions {
        workers: 0, // one per core
        cache_path: Some("dse_cache.json".into()),
        warm_start: false,
    })
    .expect("dse engine");

    println!(
        "{}",
        coordinator::fig07_hybrid_throughput_with(&o, placer.as_ref(), &mut engine).render()
    );
    println!("{}", coordinator::fig08_fifo_area_with(&mut engine).render());
    println!("{}", coordinator::fig09_topology_with(&o, &mut engine).render());
    println!("{}", coordinator::fig10_area_tracks_with(&mut engine).render());
    println!(
        "{}",
        coordinator::fig11_runtime_tracks_with(&o, placer.as_ref(), &mut engine).render()
    );
    println!("{}", coordinator::fig13_port_area().render());
    println!(
        "{}",
        coordinator::fig14_sb_ports_runtime_with(&o, placer.as_ref(), &mut engine).render()
    );
    println!(
        "{}",
        coordinator::fig15_cb_ports_runtime_with(&o, placer.as_ref(), &mut engine).render()
    );
    println!("{}", coordinator::alpha_sweep(&o).render());

    let s = engine.lifetime_stats();
    println!(
        "dse engine: {} jobs, {} cache hits, {} PnR runs, {} sims, {} configs built, {} steals",
        s.jobs, s.cache_hits, s.pnr_runs, s.sims, s.configs_built, s.steals
    );
    println!("cache: {} entries in dse_cache.json", engine.cache().len());
}
