//! Quickstart: the whole Canal flow in ~60 lines.
//!
//! Build an interconnect with the eDSL, generate + structurally verify
//! its RTL, place and route an application, generate the bitstream, and
//! functionally check every routed net on the configured fabric.
//!
//! Run: `cargo run --release --example quickstart`

use canal::apps;
use canal::bitstream::{encode, Configuration};
use canal::dsl::{create_uniform_interconnect, InterconnectConfig, SbTopology};
use canal::hw::{allocate, emit, lower_static, verify_rtl};
use canal::pnr::{run_flow, FlowParams};
use canal::sim::check_routing;

fn main() {
    // 1. Describe the interconnect (the paper's Fig. 4 helper).
    let cfg = InterconnectConfig {
        width: 8,
        height: 8,
        num_tracks: 5,
        sb_topology: SbTopology::Wilton,
        mem_column_period: 4,
        ..Default::default()
    };
    let ic = create_uniform_interconnect(&cfg);
    println!("built `{}`: {} nodes, {} edges", ic.descriptor, ic.node_count(), ic.edge_count());

    // 2. Generate hardware and verify RTL connectivity against the IR.
    let lowered = lower_static(&ic);
    let rtl = emit(&lowered.netlist);
    let mismatches = verify_rtl(&ic, &rtl);
    assert!(mismatches.is_empty(), "structural verification failed: {mismatches:?}");
    println!("RTL: {} bytes, structural verification PASS", rtl.len());

    // 3. Place and route a 3x3 gaussian blur.
    let app = apps::gaussian();
    let result = run_flow(&ic, &app, &FlowParams::default()).expect("gaussian must route");
    println!(
        "PnR: {} nets in {} router iterations; critical path {:.0} ps; run time {:.1} us",
        result.routing.trees.len(),
        result.routing.iterations,
        result.timing.critical_path_ps,
        result.timing.runtime_ns / 1000.0,
    );

    // 4. Generate the configuration bitstream.
    let config = Configuration::from_routing(&ic, 16, &result.routing).unwrap();
    let bits = encode(&config, &allocate(&ic));
    println!("bitstream: {} configuration words", bits.len());

    // 5. Check every routed net delivers on the configured fabric.
    check_routing(&ic, 16, &config, &result.routing).expect("functional check");
    println!("functional check: every net delivers PASS");
}
