//! Fig. 9 bench: Wilton vs Disjoint routability across track counts.
use std::time::Duration;

use canal::coordinator::{fig09_topology, ExpOptions};
use canal::util::bench::{bench, black_box};

fn main() {
    let o = ExpOptions { sa_moves: 8, ..Default::default() };
    let t = fig09_topology(&o);
    println!("{}", t.render());
    let quick = ExpOptions { sa_moves: 2, seeds: 1, ..Default::default() };
    let s = bench("fig09 full topology sweep", 3, Duration::from_secs(60), || {
        black_box(fig09_topology(&quick));
    });
    println!("{s}");
}
