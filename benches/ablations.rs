//! Ablation benches beyond the paper's numbered figures:
//! split-FIFO chain depth (§3.3), pipeline-register density (Fig. 4's
//! `reg_density` parameter), and the dynamic-NoC extension (§3.3 last
//! paragraph). DESIGN.md §5 lists these as the design-choice ablations.
use std::time::Duration;

use canal::coordinator::{
    dynamic_noc_comparison, fifo_chain_depth, reg_density_sweep, ExpOptions,
};
use canal::util::bench::{bench, black_box};

fn main() {
    let o = ExpOptions::default();

    let t = fifo_chain_depth();
    println!("{}", t.render());
    let t = reg_density_sweep(&o);
    println!("{}", t.render());
    let t = dynamic_noc_comparison(&o);
    println!("{}", t.render());

    let s = bench("ablation suite (chain+density+noc)", 3, Duration::from_secs(30), || {
        black_box(fifo_chain_depth());
        black_box(dynamic_noc_comparison(&ExpOptions { sa_moves: 4, ..Default::default() }));
    });
    println!("{s}");
}
