//! Hot-path micro/meso benchmarks — the §Perf measurement harness.
//!
//! Covers every layer on the request path:
//!   L3  router (A* + negotiation), SA detailed placement, elastic sim,
//!       configuration sweep, bitstream encode;
//!   L2/L1  global placement: native Rust vs the AOT JAX/Pallas artifact
//!       through PJRT (dispatch amortization = INNER_STEPS per call).
//!
//! Run: `cargo bench --bench hot_paths` (results land in bench_output.txt
//! via the Makefile; EXPERIMENTS.md §Perf records before/after).

use std::time::Duration;

use canal::apps;
use canal::bitstream::{encode, Configuration};
use canal::dsl::{create_uniform_interconnect, InterconnectConfig};
use canal::hw::allocate;
use canal::pnr::{
    build_global_problem, detailed_place, initial_positions, legalize, pack, route,
    BatchedNativePlacer, GlobalPlacer, NativePlacer, PlacementInstance, RouterParams, SaParams,
};
use canal::sim::{sweep_connections, FabricKind, RvSim, StallPattern};
use canal::util::bench::{bench, black_box};

fn main() {
    let budget = Duration::from_secs(8);
    let ic = create_uniform_interconnect(&InterconnectConfig::paper_baseline(8, 8));
    let ic16 = create_uniform_interconnect(&InterconnectConfig::paper_baseline(16, 16));

    // --- L3: router ------------------------------------------------------
    let packed = pack(&apps::harris());
    let problem = build_global_problem(&packed.app, &ic);
    let (xs0, ys0) = initial_positions(&packed.app, &ic, 1);
    let (xs, ys) = NativePlacer::default().optimize(&problem, &xs0, &ys0);
    let placement = legalize(&packed.app, &ic, &xs, &ys).unwrap();
    let nets = packed.app.nets();
    let n_nets = nets.len() as f64;
    let s = bench("route harris (8x8x5)", 200, budget, || {
        black_box(route(&ic, &packed.app, &placement, 16, &RouterParams::default()).unwrap());
    });
    println!("{s}   [{:.0} net-routes/s]", n_nets * s.throughput_per_sec());

    // --- L3: router search cores + Steiner sharing -------------------------
    {
        use canal::pnr::SearchCore;
        let baseline =
            route(&ic, &packed.app, &placement, 16, &RouterParams::default()).unwrap();
        for core in SearchCore::ALL {
            let params = RouterParams { search_core: core, ..Default::default() };
            let r = route(&ic, &packed.app, &placement, 16, &params).unwrap();
            if !core.changes_results() {
                assert_eq!(
                    r.route_expansions, baseline.route_expansions,
                    "core {} must pop exactly like the binary heap",
                    core.name()
                );
                for (a, b) in r.trees.iter().zip(&baseline.trees) {
                    assert_eq!(
                        a.sink_paths,
                        b.sink_paths,
                        "core {} must be bit-identical to the binary heap",
                        core.name()
                    );
                }
            }
            let s = bench(&format!("route harris core={} (8x8x5)", core.name()), 200, budget, || {
                black_box(route(&ic, &packed.app, &placement, 16, &params).unwrap());
            });
            println!(
                "{s}   [route_expansions={} wirelength={}]",
                r.route_expansions,
                r.wirelength()
            );
        }

        // Steiner sharing vs independent per-sink routing, on every
        // multi-fanout app in the suite: shared subtrees must cost less
        // wire AND less search work on each of them, strictly less in
        // aggregate. An app whose independent-sink routing cannot even
        // converge is the strongest win and scores as 2x the shared cost.
        let indep = RouterParams { steiner: false, ..Default::default() };
        let (mut shared_wl, mut indep_wl) = (0usize, 0usize);
        let (mut shared_ex, mut indep_ex) = (0u64, 0u64);
        for app in apps::suite() {
            let p = pack(&app);
            if !p.app.nets().iter().any(|n| n.sinks.len() > 1) {
                continue;
            }
            let problem = build_global_problem(&p.app, &ic);
            let (xs0, ys0) = initial_positions(&p.app, &ic, 1);
            let (xs, ys) = NativePlacer::default().optimize(&problem, &xs0, &ys0);
            let pl = match legalize(&p.app, &ic, &xs, &ys) {
                Ok(pl) => pl,
                Err(_) => continue,
            };
            let shared = route(&ic, &p.app, &pl, 16, &RouterParams::default()).unwrap();
            shared_wl += shared.wirelength();
            shared_ex += shared.route_expansions;
            match route(&ic, &p.app, &pl, 16, &indep) {
                Ok(ind) => {
                    println!(
                        "steiner {}: wirelength {} vs {} independent, \
                         route_expansions {} vs {}",
                        app.name,
                        shared.wirelength(),
                        ind.wirelength(),
                        shared.route_expansions,
                        ind.route_expansions
                    );
                    assert!(
                        shared.wirelength() <= ind.wirelength(),
                        "{}: Steiner sharing must not cost more wire",
                        app.name
                    );
                    assert!(
                        shared.route_expansions < ind.route_expansions,
                        "{}: Steiner sharing must reduce search work",
                        app.name
                    );
                    indep_wl += ind.wirelength();
                    indep_ex += ind.route_expansions;
                }
                Err(e) => {
                    println!("steiner {}: independent-sink routing FAILED ({e})", app.name);
                    indep_wl += 2 * shared.wirelength();
                    indep_ex += 2 * shared.route_expansions;
                }
            }
        }
        assert!(
            shared_wl < indep_wl && shared_ex < indep_ex,
            "Steiner sharing must win in aggregate: \
             wirelength {shared_wl} vs {indep_wl}, expansions {shared_ex} vs {indep_ex}"
        );
        println!(
            "steiner aggregate: wirelength {shared_wl} vs {indep_wl} independent, \
             route_expansions {shared_ex} vs {indep_ex}"
        );
    }

    // --- L3: STA ----------------------------------------------------------
    let routed = route(&ic, &packed.app, &placement, 16, &RouterParams::default()).unwrap();
    let s = bench("STA harris (8x8x5)", 2000, budget, || {
        black_box(canal::pnr::analyze(&ic, &packed, &routed, 16, 4096));
    });
    println!("{s}");

    // --- L3: SA detailed placement ---------------------------------------
    let sa = SaParams { moves_per_node: 20, ..Default::default() };
    let s = bench("SA detailed place harris (20 mpn)", 100, budget, || {
        black_box(detailed_place(&packed.app, &ic, &nets, placement.clone(), &sa));
    });
    println!("{s}");

    // --- L3: elastic simulation ------------------------------------------
    let app = apps::gaussian();
    let caps: std::collections::HashMap<_, _> = app
        .edges()
        .iter()
        .map(|e| ((e.src, e.src_port, e.dst, e.dst_port), 2usize))
        .collect();
    let input: Vec<i64> = (0..4096).map(|i| (i * 7) % 255).collect();
    let s = bench("rv-sim gaussian 1024 tokens", 100, budget, || {
        let mut sim = RvSim::new(&app, &caps, input.clone());
        black_box(sim.run(1024, 10_000_000, StallPattern::None));
    });
    println!("{s}");

    // Flattened-arena sim on *routed* capacities (what every DSE fabric
    // point runs): harris, per-edge capacities from the registers its
    // routed nets cross, split-FIFO model, bursty backpressure.
    let harris = apps::harris();
    let caps_routed = canal::sim::routed_capacities(
        &harris,
        &packed,
        &ic,
        16,
        &routed,
        FabricKind::RvSplitFifo,
    );
    let s = bench("rv-sim harris routed split-fifo 512 tokens", 100, budget, || {
        let mut sim = RvSim::new(&harris, &caps_routed, input.clone());
        black_box(sim.run(512, 10_000_000, StallPattern::Bursty { accept: 3, stall: 2 }));
    });
    println!("{s}");

    // --- L3: exhaustive configuration sweep -------------------------------
    let cs = allocate(&ic);
    let conns = ic.edge_count() as f64;
    let s = bench("config sweep 8x8", 50, budget, || {
        black_box(sweep_connections(&ic, Some(&cs)));
    });
    println!("{s}   [{:.2}M conn/s]", conns * s.throughput_per_sec() / 1e6);

    // --- L3: bitstream encode ---------------------------------------------
    let flow = canal::pnr::run_flow(
        &ic,
        &apps::gaussian(),
        &canal::pnr::FlowParams {
            sa: SaParams { moves_per_node: 6, ..Default::default() },
            ..Default::default()
        },
    )
    .unwrap();
    let config = Configuration::from_routing(&ic, 16, &flow.routing).unwrap();
    let s = bench("bitstream encode (gaussian)", 2000, budget, || {
        black_box(encode(&config, &cs));
    });
    println!("{s}");

    // --- L3: static functional sim ----------------------------------------
    let s = bench("static-sim check gaussian", 1000, budget, || {
        canal::sim::check_routing(&ic, 16, &config, &flow.routing).unwrap();
    });
    println!("{s}");

    // --- L3: DSE sweep throughput (cold vs warm cache) --------------------
    {
        use canal::dse::{DseEngine, SweepSpec};
        let spec = SweepSpec {
            name: "bench_sweep".into(),
            base: InterconnectConfig { mem_column_period: 3, ..Default::default() },
            tracks: vec![4, 5],
            apps: vec!["pointwise".into(), "gaussian".into()],
            seeds: vec![1, 2],
            flow: canal::pnr::FlowParams {
                sa: SaParams { moves_per_node: 6, ..Default::default() },
                ..Default::default()
            },
            ..Default::default()
        };
        // Cold-cache batched-vs-scalar: same spec, one engine per
        // backend. NativePlacer takes the trait's sequential place_batch
        // loop; BatchedNativePlacer solves each per-config job group in
        // one struct-of-arrays pass. Results are bit-identical — only
        // the solve pattern differs.
        let mut engine = DseEngine::in_memory();
        let t0 = std::time::Instant::now();
        let cold = engine.run(&spec, &NativePlacer::default()).unwrap();
        let cold_s = t0.elapsed().as_secs_f64();
        let n = cold.points.len() as f64;
        println!(
            "dse sweep cold scalar-place ({} points, {} pnr runs)   {:.3}s   [{:.1} points/s]",
            cold.points.len(),
            cold.stats.pnr_runs,
            cold_s,
            n / cold_s
        );
        let mut engine_b = DseEngine::in_memory();
        let t0 = std::time::Instant::now();
        let cold_b = engine_b.run(&spec, &BatchedNativePlacer::default()).unwrap();
        let cold_b_s = t0.elapsed().as_secs_f64();
        println!(
            "dse sweep cold batched-place ({} points, {} group solves) {:.3}s   [{:.1} points/s]",
            cold_b.points.len(),
            cold_b.stats.batched_solves,
            cold_b_s,
            n / cold_b_s
        );
        assert_eq!(
            cold.points.iter().map(|(_, r)| r).collect::<Vec<_>>(),
            cold_b.points.iter().map(|(_, r)| r).collect::<Vec<_>>(),
            "batched and scalar cold sweeps must be bit-identical"
        );
        let s = bench("dse sweep warm (cache-hit path)", 500, budget, || {
            black_box(engine.run(&spec, &NativePlacer::default()).unwrap());
        });
        println!("{s}   [{:.0} points/s warm]", n * s.throughput_per_sec());

        // Fabric-axis sweep: 3 fabrics per (config, app, seed); every
        // routed point adds one elastic simulation on its own routing.
        let fabric_spec = SweepSpec {
            name: "bench_fabric_sweep".into(),
            fabrics: vec![
                FabricKind::Static,
                FabricKind::RvFullFifo { depth: 2 },
                FabricKind::RvSplitFifo,
            ],
            ..spec.clone()
        };
        let mut engine_f = DseEngine::in_memory();
        let t0 = std::time::Instant::now();
        let cold_f = engine_f.run(&fabric_spec, &NativePlacer::default()).unwrap();
        let cold_f_s = t0.elapsed().as_secs_f64();
        println!(
            "dse fabric sweep cold ({} points, {} sims)          {:.3}s   [{:.1} points/s]",
            cold_f.points.len(),
            cold_f.stats.sims,
            cold_f_s,
            cold_f.points.len() as f64 / cold_f_s
        );

        // Incremental PnR: the same tracks × fabric neighborhood sweep,
        // cold-scratch vs warm-started (`EngineOptions::warm_start`) —
        // warm points skip global placement and replay donor route
        // trees, so this pair is the feature's headline perf line.
        use canal::dse::EngineOptions;
        let neighbor_spec = SweepSpec {
            name: "bench_warm_neighbors".into(),
            fabrics: vec![FabricKind::Static, FabricKind::RvFullFifo { depth: 2 }],
            seeds: vec![1],
            ..spec.clone()
        };
        let mut engine_scratch = DseEngine::in_memory();
        let t0 = std::time::Instant::now();
        let scratch_out = engine_scratch.run(&neighbor_spec, &NativePlacer::default()).unwrap();
        let scratch_s = t0.elapsed().as_secs_f64();
        let np = scratch_out.points.len() as f64;
        println!(
            "dse neighbor sweep cold-scratch ({} points)         {:.3}s   [{:.1} points/s]",
            scratch_out.points.len(),
            scratch_s,
            np / scratch_s
        );
        let mut engine_warm = DseEngine::new(EngineOptions {
            workers: 0,
            cache_path: None,
            warm_start: true,
        })
        .unwrap();
        let t0 = std::time::Instant::now();
        let warm_out = engine_warm.run(&neighbor_spec, &NativePlacer::default()).unwrap();
        let warm_s = t0.elapsed().as_secs_f64();
        println!(
            "dse neighbor sweep warm-start ({} warm starts, {} nets reused, {} rerouted) \
             {:.3}s   [{:.1} points/s]",
            warm_out.stats.warm_starts,
            warm_out.stats.nets_reused,
            warm_out.stats.nets_rerouted,
            warm_s,
            np / warm_s
        );

        // Observability overhead: the same cold sweep untraced vs with
        // the full gate open (spans + metrics). Best-of-3 each to damp
        // scheduler noise; the design budget is < 5% overhead.
        use canal::obs::ObsOptions;
        let cold_run = |label: &str| -> f64 {
            (0..3)
                .map(|i| {
                    let mut e = DseEngine::in_memory();
                    let gated_spec =
                        SweepSpec { name: format!("bench_obs_{label}_{i}"), ..spec.clone() };
                    let t0 = std::time::Instant::now();
                    black_box(e.run(&gated_spec, &NativePlacer::default()).unwrap());
                    t0.elapsed().as_secs_f64()
                })
                .fold(f64::INFINITY, f64::min)
        };
        ObsOptions::disabled().apply();
        let untraced_s = cold_run("off");
        ObsOptions::full().apply();
        let traced_s = cold_run("on");
        ObsOptions::disabled().apply();
        let overhead_pct = (traced_s / untraced_s - 1.0) * 100.0;
        println!(
            "dse cold sweep untraced {untraced_s:.3}s vs traced {traced_s:.3}s   \
             [obs overhead {overhead_pct:+.1}%]"
        );
        assert!(
            overhead_pct < 5.0,
            "observability overhead {overhead_pct:.1}% blows the 5% budget"
        );

        // History-sampler overhead: the daemon records the metrics
        // registry into the history ring while sweeps run, and the
        // sampler thread must be invisible to the flow. Same best-of-3
        // cold sweep with the metrics gate open, without vs with a live
        // sampler at 100 ms (10x the daemon's default rate); the design
        // budget is < 2%.
        {
            use canal::obs::{HistorySampler, MetricsHistory};
            ObsOptions { metrics: true, trace: false }.apply();
            let plain_s = cold_run("hist_off");
            let sampler = HistorySampler::spawn(
                std::sync::Arc::new(MetricsHistory::new(512, Duration::from_millis(100))),
                || None,
            );
            let sampled_s = cold_run("hist_on");
            drop(sampler);
            ObsOptions::disabled().apply();
            let hist_pct = (sampled_s / plain_s - 1.0) * 100.0;
            println!(
                "dse cold sweep history-off {plain_s:.3}s vs history-on {sampled_s:.3}s   \
                 [sampler overhead {hist_pct:+.1}%]"
            );
            assert!(
                hist_pct < 2.0,
                "history sampler overhead {hist_pct:.1}% blows the 2% budget"
            );
        }

        // Tuned search vs full enumeration: `canal tune` walks the same
        // space as the exhaustive sweep but prunes on a cheap area/delay
        // model and drops dominated candidates between seed rounds, so
        // it must recover the exact Pareto frontier with strictly fewer
        // cold evaluations than the cross-product.
        {
            use canal::area::{area_of, AreaModel};
            use canal::dse::{
                objectives_of, pareto_frontier, run_tune, BuildFresh, ParetoArchive,
                ParetoEntry, TuneOptions,
            };
            let tune_spec = SweepSpec {
                name: "bench_tune".into(),
                base: InterconnectConfig {
                    width: 4,
                    height: 4,
                    mem_column_period: 3,
                    ..Default::default()
                },
                tracks: vec![2, 3, 4],
                apps: vec!["pointwise4".into()],
                seeds: vec![1, 2],
                flow: canal::pnr::FlowParams {
                    sa: SaParams { moves_per_node: 4, ..Default::default() },
                    ..Default::default()
                },
                ..Default::default()
            };
            let placer = NativePlacer::default();
            let mut archive = ParetoArchive::in_memory();
            let mut engine_t = DseEngine::in_memory();
            let t0 = std::time::Instant::now();
            let tuned = run_tune(
                &tune_spec,
                placer.name(),
                &BuildFresh,
                &mut archive,
                &TuneOptions::default(),
                &mut |s| engine_t.run(s, &placer),
            )
            .unwrap();
            let tuned_s = t0.elapsed().as_secs_f64();
            let mut engine_full = DseEngine::in_memory();
            let t0 = std::time::Instant::now();
            let full = engine_full.run(&tune_spec, &placer).unwrap();
            let full_s = t0.elapsed().as_secs_f64();
            println!(
                "dse tuned search ({} evals, {} pnr runs) {:.3}s vs \
                 full sweep ({} points, {} pnr runs) {:.3}s",
                tuned.evaluated,
                tuned.stats.pnr_runs,
                tuned_s,
                full.points.len(),
                full.stats.pnr_runs,
                full_s
            );
            assert!(
                tuned.evaluated < tuned.cross_product,
                "tuned search must beat enumeration: {} evals vs {} cross-product",
                tuned.evaluated,
                tuned.cross_product
            );

            // Fold the full sweep into the exhaustive reference frontier
            // with the same area model and objective extraction the
            // tuner uses, then demand exact agreement.
            let model = AreaModel::default();
            let mut areas: std::collections::HashMap<String, f64> = Default::default();
            let mut agg: std::collections::BTreeMap<(String, String), ParetoEntry> =
                Default::default();
            for (job, r) in &full.points {
                // Keyed by the FULL descriptor: area depends on the
                // fabric mode too, and the descriptor is the only
                // string that carries both.
                let area = *areas.entry(job.key.config.0.clone()).or_insert_with(|| {
                    let ic = create_uniform_interconnect(&job.cfg);
                    area_of(&ic, &model, job.fabric.area_mode()).interior_tile(&ic).total()
                });
                let o = objectives_of(r, area);
                let key = (job.key.config.0.clone(), job.key.app.clone());
                match agg.get_mut(&key) {
                    Some(e) => {
                        e.objectives.fold(&o);
                        if let Err(at) = e.seeds.binary_search(&job.key.seed) {
                            e.seeds.insert(at, job.key.seed);
                        }
                    }
                    None => {
                        agg.insert(
                            key,
                            ParetoEntry {
                                config: job.key.config.0.clone(),
                                app: job.key.app.clone(),
                                fabric: job.fabric.label(),
                                objectives: o,
                                seeds: vec![job.key.seed],
                            },
                        );
                    }
                }
            }
            let entries: Vec<ParetoEntry> =
                agg.into_values().filter(|e| e.objectives.is_finite()).collect();
            let reference = pareto_frontier(&entries);
            assert_eq!(
                tuned.frontier, reference,
                "tuned frontier must equal the exhaustive sweep's frontier"
            );
            println!(
                "dse tune frontier: {} entries, searched {} of {} cross-product",
                tuned.frontier.len(),
                tuned.evaluated,
                tuned.cross_product
            );
        }
    }

    // --- L2/L1: global placement backends ---------------------------------
    let packed16 = pack(&apps::harris());
    let problem16 = build_global_problem(&packed16.app, &ic16);
    let (x160, y160) = initial_positions(&packed16.app, &ic16, 1);
    let native = NativePlacer::default();
    let s = bench("global place native (150 iters)", 100, budget, || {
        black_box(native.optimize(&problem16, &x160, &y160));
    });
    println!("{s}");

    // Batched-vs-scalar at the solver level: the whole suite's problems
    // as one group (a per-config DSE job group), scalar loop vs one
    // struct-of-arrays pass.
    {
        let suite: Vec<_> = apps::suite().iter().map(|a| pack(a).app).collect();
        let problems: Vec<_> = suite.iter().map(|a| build_global_problem(a, &ic16)).collect();
        let inits: Vec<_> = suite
            .iter()
            .enumerate()
            .map(|(i, a)| initial_positions(a, &ic16, i as u64))
            .collect();
        let batch: Vec<PlacementInstance> = problems
            .iter()
            .zip(&inits)
            .map(|(p, (xs0, ys0))| PlacementInstance { problem: p, xs0, ys0 })
            .collect();
        let k = batch.len() as f64;
        let s = bench("global place scalar loop (suite group)", 50, budget, || {
            for b in &batch {
                black_box(native.optimize(b.problem, b.xs0, b.ys0));
            }
        });
        println!("{s}   [{:.1} problems/s]", k * s.throughput_per_sec());
        let batched = BatchedNativePlacer::default();
        let s = bench("global place batched SoA (suite group)", 50, budget, || {
            black_box(batched.place_batch(&batch));
        });
        println!("{s}   [{:.1} problems/s]", k * s.throughput_per_sec());
    }

    match canal::runtime::PjrtPlacer::load_default() {
        Ok(pjrt) => {
            let s = bench("global place pjrt jax/pallas (150 iters)", 50, budget, || {
                black_box(pjrt.optimize(&problem16, &x160, &y160));
            });
            println!("{s}");
        }
        Err(e) => println!("pjrt placer unavailable: {e} (run `make artifacts`)"),
    }

    // --- service: warm-request throughput ---------------------------------
    // Load generator for the daemon: N clients × M identical warm `dse`
    // requests against one shared SessionState (every request is zero
    // PnR / zero sims — this measures protocol + coalescing + cache
    // overhead, i.e. the daemon's serving floor).
    {
        use canal::pnr::BatchedNativePlacer as ServicePlacer;
        use canal::service::{
            Client, DseParams, Request, ServeOptions, Server, SessionState, StateOptions,
        };
        use std::sync::Arc;
        let state = Arc::new(
            SessionState::with_placer(
                StateOptions { workers: 2, cache_path: None, ic_capacity: 8 },
                Box::new(ServicePlacer::default()),
            )
            .unwrap(),
        );
        let server = Server::bind_with_state(
            ServeOptions {
                addr: "127.0.0.1:0".into(),
                conn_threads: 8,
                ..Default::default()
            },
            Arc::clone(&state),
        )
        .unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let handle = std::thread::spawn(move || server.run());
        let params = DseParams {
            width: 4,
            height: 4,
            tracks: vec![2, 3],
            apps: vec!["pointwise4".into()],
            sa_moves: 6,
            ..Default::default()
        };
        // One cold pass warms the shared cache.
        Client::connect(&addr).unwrap().call(&Request::Dse(params.clone())).unwrap();

        let (n_clients, m_requests) = (4usize, 50usize);
        let t0 = std::time::Instant::now();
        std::thread::scope(|scope| {
            for _ in 0..n_clients {
                let (addr, params) = (&addr, &params);
                scope.spawn(move || {
                    let mut c = Client::connect(addr).unwrap();
                    for _ in 0..m_requests {
                        black_box(c.call(&Request::Dse(params.clone())).unwrap());
                    }
                });
            }
        });
        let secs = t0.elapsed().as_secs_f64();
        let total = (n_clients * m_requests) as f64;
        println!(
            "service warm dse requests ({n_clients} clients x {m_requests})   {secs:.3}s   \
             [{:.0} requests/s]",
            total / secs
        );

        let mut c = Client::connect(&addr).unwrap();
        let pings = 200usize;
        let t0 = std::time::Instant::now();
        for _ in 0..pings {
            black_box(c.call(&Request::Ping).unwrap());
        }
        let secs = t0.elapsed().as_secs_f64();
        println!(
            "service ping round-trips (1 conn x {pings})   {secs:.3}s   [{:.0} rt/s]",
            pings as f64 / secs
        );
        c.call(&Request::Shutdown).unwrap();
        handle.join().unwrap().unwrap();
    }
}
