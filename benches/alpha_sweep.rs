//! Ablation bench: detailed-placement alpha sweep (paper §3.4: sweep
//! alpha 1..20, keep the best post-route critical path).
use std::time::Duration;

use canal::coordinator::{alpha_sweep, ExpOptions};
use canal::util::bench::{bench, black_box};

fn main() {
    let o = ExpOptions { sa_moves: 10, ..Default::default() };
    println!("{}", alpha_sweep(&o).render());
    let quick = ExpOptions { sa_moves: 2, ..Default::default() };
    let s = bench("alpha sweep (6 values x 3 apps)", 3, Duration::from_secs(60), || {
        black_box(alpha_sweep(&quick));
    });
    println!("{s}");
}
