//! Fig. 13 bench: SB / CB area vs core connection sides (4/3/2).
use std::time::Duration;

use canal::coordinator::fig13_port_area;
use canal::util::bench::{bench, black_box};

fn main() {
    let t = fig13_port_area();
    println!("{}", t.render());
    let s = bench("fig13 port-area sweep", 20, Duration::from_secs(5), || {
        black_box(fig13_port_area());
    });
    println!("{s}");
}
