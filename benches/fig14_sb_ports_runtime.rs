//! Fig. 14 bench: run time vs SB core-output connection sides.
use std::time::Duration;

use canal::coordinator::{default_placer, fig14_sb_ports_runtime, ExpOptions};
use canal::util::bench::{bench, black_box};

fn main() {
    let o = ExpOptions { sa_moves: 10, ..Default::default() };
    let placer = default_placer();
    let t = fig14_sb_ports_runtime(&o, placer.as_ref());
    println!("{}", t.render());
    let quick = ExpOptions { sa_moves: 2, ..Default::default() };
    let s = bench("fig14 sb-ports sweep", 3, Duration::from_secs(60), || {
        black_box(fig14_sb_ports_runtime(&quick, placer.as_ref()));
    });
    println!("{s}");
}
