//! Fig. 11 bench: application run time vs routing tracks (full PnR per
//! cell; uses the PJRT JAX/Pallas placer when artifacts are present).
use std::time::Duration;

use canal::coordinator::{default_placer, fig11_runtime_tracks, ExpOptions};
use canal::util::bench::{bench, black_box};

fn main() {
    let o = ExpOptions { sa_moves: 10, ..Default::default() };
    let placer = default_placer();
    let t = fig11_runtime_tracks(&o, placer.as_ref());
    println!("{}", t.render());
    let quick = ExpOptions { sa_moves: 2, ..Default::default() };
    let s = bench("fig11 runtime-vs-tracks sweep", 3, Duration::from_secs(90), || {
        black_box(fig11_runtime_tracks(&quick, placer.as_ref()));
    });
    println!("{s}");
}
