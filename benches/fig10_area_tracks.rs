//! Fig. 10 bench: SB / CB area vs number of routing tracks.
use std::time::Duration;

use canal::coordinator::fig10_area_tracks;
use canal::util::bench::{bench, black_box};

fn main() {
    let t = fig10_area_tracks();
    println!("{}", t.render());
    let s = bench("fig10 area-vs-tracks sweep", 20, Duration::from_secs(5), || {
        black_box(fig10_area_tracks());
    });
    println!("{s}");
}
