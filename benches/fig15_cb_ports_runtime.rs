//! Fig. 15 bench: run time vs CB core-input connection sides.
use std::time::Duration;

use canal::coordinator::{default_placer, fig15_cb_ports_runtime, ExpOptions};
use canal::util::bench::{bench, black_box};

fn main() {
    let o = ExpOptions { sa_moves: 10, ..Default::default() };
    let placer = default_placer();
    let t = fig15_cb_ports_runtime(&o, placer.as_ref());
    println!("{}", t.render());
    let quick = ExpOptions { sa_moves: 2, ..Default::default() };
    let s = bench("fig15 cb-ports sweep", 3, Duration::from_secs(60), || {
        black_box(fig15_cb_ports_runtime(&quick, placer.as_ref()));
    });
    println!("{s}");
}
