//! Fig. 8 bench: SB area — static vs full-FIFO vs split-FIFO ready-valid.
//! Regenerates the paper's bar chart data and times the area pipeline.
use std::time::Duration;

use canal::coordinator::fig08_fifo_area;
use canal::util::bench::{bench, black_box};

fn main() {
    let t = fig08_fifo_area();
    println!("{}", t.render());
    let s = bench("fig08 area pipeline", 50, Duration::from_secs(5), || {
        black_box(fig08_fifo_area());
    });
    println!("{s}");
}
