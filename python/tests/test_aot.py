"""AOT artifact contract: what `canal::runtime` relies on.

These tests pin the build-path guarantees: HLO text is produced (not
protos — xla_extension 0.5.1 rejects jax>=0.5 ids), shapes in the meta
file match the model constants, lowering is deterministic, and the golden
test vector in artifacts/ (when present) reproduces under re-execution.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_hlo_text_is_parseable_hlo():
    lowered = jax.jit(model.placement_cost).lower(
        *(
            model.example_args()[i]
            for i in (0, 1, 4, 5, 6, 8)
        )
    )
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule"), text[:80]
    # The rust loader needs an ENTRY computation with tuple output.
    assert "ENTRY" in text
    assert "tuple(" in text or "ROOT" in text


def test_lowering_is_deterministic():
    ex = model.example_args()
    a = aot.to_hlo_text(jax.jit(model.placement_steps).lower(*ex))
    b = aot.to_hlo_text(jax.jit(model.placement_steps).lower(*ex))
    assert a == b


def test_testvec_inputs_are_deterministic():
    a = aot._testvec_inputs()
    b = aot._testvec_inputs()
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "placer_meta.txt")),
    reason="artifacts not built",
)
def test_meta_matches_model_constants():
    meta = {}
    with open(os.path.join(ARTIFACTS, "placer_meta.txt")) as f:
        for line in f:
            k, v = line.split("=")
            meta[k.strip()] = int(v)
    assert meta["pad_n"] == model.PAD_N
    assert meta["pad_m"] == model.PAD_M
    assert meta["pad_k"] == model.PAD_K
    assert meta["inner_steps"] == model.INNER_STEPS
    # pad_b is absent from pre-batching artifact sets (rust defaults to 1).
    assert meta.get("pad_b", 1) in (1, model.PAD_B)


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "placer_testvec.txt")),
    reason="artifacts not built",
)
def test_golden_testvec_reproduces():
    vecs = {}
    with open(os.path.join(ARTIFACTS, "placer_testvec.txt")) as f:
        for line in f:
            name, *vals = line.split()
            vecs[name] = np.array([float(v) for v in vals], np.float32)
    inputs = aot._testvec_inputs()
    # The dumped inputs must match the generator (same seed).
    names = ["xs", "ys", "vx", "vy", "pins", "col", "colm", "bounds", "hyper"]
    for name, arr in zip(names, inputs):
        np.testing.assert_allclose(
            vecs[f"in_{name}"], np.asarray(arr, np.float32).reshape(-1), rtol=0, atol=0
        )
    # Re-running the jitted step function reproduces the dumped outputs.
    outs = jax.jit(model.placement_steps)(*[jnp.asarray(a) for a in inputs])
    for name, arr in zip(["xs", "ys", "vx", "vy"], outs):
        np.testing.assert_allclose(
            vecs[f"out_{name}"], np.asarray(arr).reshape(-1), rtol=1e-6, atol=1e-6
        )


def test_cost_artifact_signature_is_scalar():
    xs, ys, _, _, pins, col, colm, _, hyper = [
        jnp.asarray(a) for a in aot._testvec_inputs()
    ]
    cost = model.placement_cost(xs, ys, pins, col, colm, hyper)
    assert np.asarray(cost).shape == ()
    assert float(cost) > 0.0


def test_pallas_and_ref_agree_across_steps():
    # Multi-step trajectories with the Pallas kernel on vs off stay equal.
    xs, ys, vx, vy, pins, col, colm, bounds, hyper = [
        jnp.asarray(a) for a in aot._testvec_inputs()
    ]

    def run(use_pallas, steps=8):
        state = (xs, ys, vx, vy)
        for _ in range(steps):
            state = model.one_step(
                state, pins, col, colm, bounds, hyper, use_pallas=use_pallas
            )
        return state

    for x, y in zip(run(True), run(False)):
        np.testing.assert_allclose(x, y, rtol=1e-5, atol=1e-5)
