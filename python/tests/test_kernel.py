"""L1 correctness: Pallas kernel vs pure-jnp oracle.

The CORE correctness signal: `hpwl.net_cost_grad` must match
`ref.net_cost_grad` bit-for-bit-ish (fp32 tolerance) across shapes,
paddings and degenerate nets. Hypothesis sweeps the shape/content space.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import hpwl, ref


def random_problem(rng, n, m, k, pad_m):
    pins = -np.ones((pad_m, k), np.int32)
    for i in range(m):
        deg = int(rng.integers(1, k + 1))  # deg 1 nets are degenerate
        pins[i, :deg] = rng.choice(n, size=deg, replace=False if deg <= n else True)
    pos = rng.uniform(0, 16, size=(n, 2)).astype(np.float32)
    return pos, pins


def test_kernel_matches_ref_basic():
    rng = np.random.default_rng(0)
    pos, pins = random_problem(rng, n=32, m=40, k=6, pad_m=hpwl.BLOCK_M)
    coords = ref.gather_pins(jnp.asarray(pos), jnp.asarray(pins))
    mask = ref.pin_mask(jnp.asarray(pins))
    ck, gk = hpwl.net_cost_grad(coords, mask)
    cr, gr = ref.net_cost_grad(coords, mask)
    np.testing.assert_allclose(ck, cr, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(gk, gr, rtol=1e-6, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(4, 64),
    m=st.integers(1, 96),
    k=st.integers(2, 12),
    blocks=st.integers(1, 2),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_hypothesis(n, m, k, blocks, seed):
    rng = np.random.default_rng(seed)
    pad_m = hpwl.BLOCK_M * blocks
    m = min(m, pad_m)
    pos, pins = random_problem(rng, n, m, k, pad_m)
    coords = ref.gather_pins(jnp.asarray(pos), jnp.asarray(pins))
    mask = ref.pin_mask(jnp.asarray(pins))
    ck, gk = hpwl.net_cost_grad(coords, mask)
    cr, gr = ref.net_cost_grad(coords, mask)
    np.testing.assert_allclose(ck, cr, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(gk, gr, rtol=1e-5, atol=1e-5)


def test_degenerate_nets_contribute_nothing():
    # All-padding and single-pin nets must yield zero cost and gradient.
    pins = -np.ones((hpwl.BLOCK_M, 4), np.int32)
    pins[0, 0] = 1  # single-pin net
    pos = jnp.ones((8, 2), jnp.float32)
    coords = ref.gather_pins(pos, jnp.asarray(pins))
    mask = ref.pin_mask(jnp.asarray(pins))
    c, g = hpwl.net_cost_grad(coords, mask)
    assert float(jnp.abs(c).max()) == 0.0
    assert float(jnp.abs(g).max()) == 0.0


def test_kernel_requires_block_padding():
    coords = jnp.zeros((7, 4, 2), jnp.float32)
    mask = jnp.zeros((7, 4), jnp.float32)
    with pytest.raises(AssertionError):
        hpwl.net_cost_grad(coords, mask)


def test_gradient_matches_autodiff():
    # The hand-written gradient equals jax.grad of the cost.
    rng = np.random.default_rng(3)
    pos, pins = random_problem(rng, n=24, m=30, k=5, pad_m=hpwl.BLOCK_M)
    pins_j = jnp.asarray(pins)

    def cost_of(p):
        coords = ref.gather_pins(p, pins_j)
        mask = ref.pin_mask(pins_j)
        c, _ = ref.net_cost_grad(coords, mask)
        return c.sum()

    auto = jax.grad(cost_of)(jnp.asarray(pos))
    coords = ref.gather_pins(jnp.asarray(pos), pins_j)
    mask = ref.pin_mask(pins_j)
    _, pin_grad = hpwl.net_cost_grad(coords, mask)
    manual = jnp.zeros((24, 2)).at[jnp.maximum(pins_j, 0).reshape(-1)].add(
        (pin_grad * mask[..., None]).reshape(-1, 2)
    )
    np.testing.assert_allclose(manual, auto, rtol=1e-5, atol=1e-5)
