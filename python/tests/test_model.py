"""L2 correctness: the placement model and its AOT contract."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def small_problem(seed=0, n=20, m=24, k=4):
    rng = np.random.default_rng(seed)
    pad_m = 128
    pins = -np.ones((pad_m, k), np.int32)
    for i in range(m):
        deg = int(rng.integers(2, k + 1))
        pins[i, :deg] = rng.choice(n, size=deg, replace=False)
    xs = rng.uniform(1, 7, n).astype(np.float32)
    ys = rng.uniform(1, 7, n).astype(np.float32)
    col = np.zeros(n, np.float32)
    colm = np.zeros(n, np.float32)
    col[:3] = 4.0
    colm[:3] = 1.0
    return xs, ys, pins, col, colm


def test_cost_grad_matches_ref_path():
    xs, ys, pins, col, colm = small_problem()
    a = model.cost_grad(jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(pins),
                        jnp.asarray(col), jnp.asarray(colm), 0.4, use_pallas=True)
    b = model.cost_grad(jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(pins),
                        jnp.asarray(col), jnp.asarray(colm), 0.4, use_pallas=False)
    for x, y in zip(a, b):
        np.testing.assert_allclose(x, y, rtol=1e-5, atol=1e-5)


def test_steps_reduce_cost():
    xs, ys, pins, col, colm = small_problem(seed=2)
    bounds = jnp.array([7.0, 7.0], jnp.float32)
    hyper = jnp.array([0.05, 0.9, 0.4], jnp.float32)
    state = (jnp.asarray(xs), jnp.asarray(ys),
             jnp.zeros_like(jnp.asarray(xs)), jnp.zeros_like(jnp.asarray(ys)))
    c0 = model.placement_cost(state[0], state[1], jnp.asarray(pins),
                              jnp.asarray(col), jnp.asarray(colm), hyper)
    out = model.placement_steps(state[0], state[1], state[2], state[3],
                                jnp.asarray(pins), jnp.asarray(col),
                                jnp.asarray(colm), bounds, hyper)
    c1 = model.placement_cost(out[0], out[1], jnp.asarray(pins),
                              jnp.asarray(col), jnp.asarray(colm), hyper)
    assert float(c1) < float(c0)


def test_positions_stay_in_bounds():
    xs, ys, pins, col, colm = small_problem(seed=5)
    bounds = jnp.array([7.0, 7.0], jnp.float32)
    hyper = jnp.array([0.5, 0.95, 0.4], jnp.float32)  # aggressive lr
    out = model.placement_steps(jnp.asarray(xs), jnp.asarray(ys),
                                jnp.zeros(len(xs)), jnp.zeros(len(ys)),
                                jnp.asarray(pins), jnp.asarray(col),
                                jnp.asarray(colm), bounds, hyper)
    assert float(out[0].min()) >= 0.0 and float(out[0].max()) <= 7.0
    assert float(out[1].min()) >= 0.0 and float(out[1].max()) <= 7.0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), lam=st.floats(0.0, 2.0))
def test_grad_is_descent_direction(seed, lam):
    xs, ys, pins, col, colm = small_problem(seed=seed)
    xs, ys = jnp.asarray(xs), jnp.asarray(ys)
    pins, col, colm = jnp.asarray(pins), jnp.asarray(col), jnp.asarray(colm)
    c0, gx, gy = model.cost_grad(xs, ys, pins, col, colm, lam)
    eps = 1e-3
    c1, _, _ = model.cost_grad(xs - eps * gx, ys - eps * gy, pins, col, colm, lam)
    assert float(c1) <= float(c0) + 1e-4


def test_example_args_cover_padded_shapes():
    args = model.example_args()
    assert args[0].shape == (model.PAD_N,)
    assert args[4].shape == (model.PAD_M, model.PAD_K)
    assert model.PAD_M % 128 == 0  # kernel block constraint


def test_batch_example_args_add_leading_lane_axis():
    scalar = model.example_args()
    batch = model.example_args_batch()
    assert len(batch) == len(scalar)
    for s, b in zip(scalar, batch):
        assert b.shape == (model.PAD_B,) + s.shape
        assert b.dtype == s.dtype


def test_batch_steps_match_scalar_steps_per_lane():
    """placement_steps_batch lane l == placement_steps on problem l."""
    bounds = jnp.array([7.0, 7.0], jnp.float32)
    hyper = jnp.array([0.12, 0.9, 0.4], jnp.float32)
    lanes = []
    for seed in range(3):
        xs, ys, pins, col, colm = small_problem(seed=seed)
        n = len(xs)
        pad = model.PAD_N - n
        lanes.append(
            (
                np.pad(xs, (0, pad)),
                np.pad(ys, (0, pad)),
                np.zeros(model.PAD_N, np.float32),
                np.zeros(model.PAD_N, np.float32),
                np.pad(pins, ((0, model.PAD_M - pins.shape[0]), (0, model.PAD_K - pins.shape[1])), constant_values=-1),
                np.pad(col, (0, pad)),
                np.pad(colm, (0, pad)),
                np.asarray(bounds),
                np.asarray(hyper),
            )
        )
    stacked = [jnp.asarray(np.stack([lane[i] for lane in lanes])) for i in range(9)]
    batched = model.placement_steps_batch(*stacked)
    for l, lane in enumerate(lanes):
        scalar = model.placement_steps(*[jnp.asarray(a) for a in lane])
        for b, s in zip(batched, scalar):
            np.testing.assert_allclose(np.asarray(b)[l], np.asarray(s), rtol=1e-6, atol=1e-6)
