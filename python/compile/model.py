"""L2 JAX model: the analytic global-placement optimizer (Eq. 1).

Builds the full differentiable objective on top of the L1 Pallas kernel
(`kernels.hpwl`): gather vertex positions into pin space, run the per-net
kernel, scatter pin gradients back, add the MEM-column legalization term,
and advance a momentum-gradient-descent step (the conjugate-gradient
stand-in; same fixed-iteration contract as the Rust-native fallback in
`canal::pnr::place::NativePlacer`).

The AOT artifact exports `placement_steps`: INNER_STEPS optimizer steps
per call (lax.scan), so the Rust hot loop pays one PJRT dispatch per
INNER_STEPS iterations.

Shape contract (fixed at AOT time, padded by the Rust runtime):
  xs, ys, vx, vy : f32[N]
  pins           : i32[M, K]   (-1 padded)
  col, colm      : f32[N]
  bounds         : f32[2]      (width-1, height-1) clamp box
  hyper          : f32[3]      (lr, momentum, lambda_mem)
"""

import jax
import jax.numpy as jnp

from .kernels import hpwl, ref

INNER_STEPS = 75

# Padded problem sizes for the exported artifact. Generous for the whole
# application suite (largest packed app is ~70 vertices / ~90 nets).
PAD_N = 256
PAD_M = 512
PAD_K = 16

# Batch lanes of the vmapped `placement_steps_batch` artifact: one DSE
# job group (all (app, seed) points of one interconnect config) solves in
# a single PJRT dispatch. Sized to the common group shape — suite apps x
# a couple of seeds; canal::runtime chunks larger groups.
PAD_B = 8


def cost_grad(xs, ys, pins, col, colm, lambda_mem, *, use_pallas=True):
    """Objective + gradient, kernel-accelerated. Returns (cost, gx, gy)."""
    pos = jnp.stack([xs, ys], axis=1)
    coords = ref.gather_pins(pos, pins)
    mask = ref.pin_mask(pins)
    kern = hpwl.net_cost_grad if use_pallas else ref.net_cost_grad
    net_cost, pin_grad = kern(coords, mask)

    n = pos.shape[0]
    safe = jnp.maximum(pins, 0).reshape(-1)
    flat = (pin_grad * mask[..., None]).reshape(-1, 2)
    grad = jnp.zeros((n, 2), jnp.float32).at[safe].add(flat)

    dx = (xs - col) * colm
    cost = net_cost.sum() + lambda_mem * (dx * dx).sum()
    gx = grad[:, 0] + lambda_mem * 2.0 * dx
    gy = grad[:, 1]
    return cost, gx, gy


def one_step(state, pins, col, colm, bounds, hyper, *, use_pallas=True):
    """One momentum-GD step; mirrors NativePlacer::optimize's inner loop."""
    xs, ys, vx, vy = state
    lr, momentum, lambda_mem = hyper[0], hyper[1], hyper[2]
    _, gx, gy = cost_grad(xs, ys, pins, col, colm, lambda_mem, use_pallas=use_pallas)
    vx = momentum * vx - lr * gx
    vy = momentum * vy - lr * gy
    xs = jnp.clip(xs + vx, 0.0, bounds[0])
    ys = jnp.clip(ys + vy, 0.0, bounds[1])
    return (xs, ys, vx, vy)


def placement_steps(xs, ys, vx, vy, pins, col, colm, bounds, hyper):
    """INNER_STEPS optimizer steps (the AOT-exported entry point)."""

    def body(state, _):
        return one_step(state, pins, col, colm, bounds, hyper), ()

    (xs, ys, vx, vy), _ = jax.lax.scan(body, (xs, ys, vx, vy), None, length=INNER_STEPS)
    return xs, ys, vx, vy


def placement_steps_batch(xs, ys, vx, vy, pins, col, colm, bounds, hyper):
    """INNER_STEPS optimizer steps on PAD_B independent problems at once.

    A straight vmap of `placement_steps` over a leading batch axis on
    every argument (each lane carries its own pins/bounds/hyper), so one
    HLO execution advances a whole DSE job group. vmap adds the batch
    dimension without reassociating the per-lane arithmetic — each lane
    computes exactly what the scalar artifact computes.
    """
    return jax.vmap(placement_steps)(xs, ys, vx, vy, pins, col, colm, bounds, hyper)


def placement_cost(xs, ys, pins, col, colm, hyper):
    """Objective value only (exported for convergence monitoring)."""
    cost, _, _ = cost_grad(xs, ys, pins, col, colm, hyper[2])
    return cost


def example_args():
    """ShapeDtypeStructs for AOT lowering at the padded sizes."""
    f = jnp.float32
    return (
        jax.ShapeDtypeStruct((PAD_N,), f),  # xs
        jax.ShapeDtypeStruct((PAD_N,), f),  # ys
        jax.ShapeDtypeStruct((PAD_N,), f),  # vx
        jax.ShapeDtypeStruct((PAD_N,), f),  # vy
        jax.ShapeDtypeStruct((PAD_M, PAD_K), jnp.int32),  # pins
        jax.ShapeDtypeStruct((PAD_N,), f),  # col
        jax.ShapeDtypeStruct((PAD_N,), f),  # colm
        jax.ShapeDtypeStruct((2,), f),  # bounds
        jax.ShapeDtypeStruct((3,), f),  # hyper
    )


def example_args_batch():
    """ShapeDtypeStructs of `placement_steps_batch` (leading PAD_B axis)."""
    return tuple(
        jax.ShapeDtypeStruct((PAD_B,) + a.shape, a.dtype) for a in example_args()
    )
