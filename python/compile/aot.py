"""AOT export: lower the L2 placement model to HLO text artifacts.

HLO *text* (not serialized HloModuleProto) is the interchange format: the
`xla` crate's xla_extension 0.5.1 rejects jax>=0.5 protos with 64-bit
instruction ids; the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Artifacts:
  placer_step.hlo.txt        — INNER_STEPS momentum-GD steps per call
  placer_batch_step.hlo.txt  — the same steps on PAD_B problems per call
                               (vmapped; one dispatch per DSE job group)
  placer_cost.hlo.txt        — objective value (convergence monitoring)
  placer_meta.txt            — shape contract consumed by canal::runtime
  placer_testvec.txt         — input/output vectors for Rust cross-checks

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _testvec_inputs(seed=7):
    """Small deterministic problem embedded in the padded shapes."""
    rng = np.random.default_rng(seed)
    n_real, m_real, k_real = 40, 60, 5
    xs = np.zeros(model.PAD_N, np.float32)
    ys = np.zeros(model.PAD_N, np.float32)
    xs[:n_real] = rng.uniform(2, 6, n_real).astype(np.float32)
    ys[:n_real] = rng.uniform(2, 6, n_real).astype(np.float32)
    pins = -np.ones((model.PAD_M, model.PAD_K), np.int32)
    for m in range(m_real):
        deg = int(rng.integers(2, k_real + 1))
        pins[m, :deg] = rng.choice(n_real, size=deg, replace=False)
    col = np.zeros(model.PAD_N, np.float32)
    colm = np.zeros(model.PAD_N, np.float32)
    mem = rng.choice(n_real, size=6, replace=False)
    col[mem] = 4.0
    colm[mem] = 1.0
    bounds = np.array([7.0, 7.0], np.float32)
    hyper = np.array([0.12, 0.9, 0.4], np.float32)
    vx = np.zeros(model.PAD_N, np.float32)
    vy = np.zeros(model.PAD_N, np.float32)
    return xs, ys, vx, vy, pins, col, colm, bounds, hyper


def _dump_vec(f, name, arr):
    flat = np.asarray(arr).reshape(-1)
    f.write(f"{name} {' '.join(repr(float(v)) for v in flat)}\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    example = model.example_args()

    step_hlo = to_hlo_text(jax.jit(model.placement_steps).lower(*example))
    with open(os.path.join(args.out_dir, "placer_step.hlo.txt"), "w") as f:
        f.write(step_hlo)
    print(f"placer_step.hlo.txt: {len(step_hlo)} chars")

    batch_hlo = to_hlo_text(
        jax.jit(model.placement_steps_batch).lower(*model.example_args_batch())
    )
    with open(os.path.join(args.out_dir, "placer_batch_step.hlo.txt"), "w") as f:
        f.write(batch_hlo)
    print(f"placer_batch_step.hlo.txt: {len(batch_hlo)} chars")

    cost_example = (example[0], example[1], example[4], example[5], example[6], example[8])
    cost_hlo = to_hlo_text(jax.jit(model.placement_cost).lower(*cost_example))
    with open(os.path.join(args.out_dir, "placer_cost.hlo.txt"), "w") as f:
        f.write(cost_hlo)
    print(f"placer_cost.hlo.txt: {len(cost_hlo)} chars")

    with open(os.path.join(args.out_dir, "placer_meta.txt"), "w") as f:
        f.write(
            f"pad_n = {model.PAD_N}\npad_m = {model.PAD_M}\npad_k = {model.PAD_K}\n"
            f"inner_steps = {model.INNER_STEPS}\npad_b = {model.PAD_B}\n"
        )

    # Golden test vector: run one artifact call worth of steps in python
    # and dump inputs + outputs for the Rust runtime's numeric cross-check.
    inputs = _testvec_inputs()
    outs = jax.jit(model.placement_steps)(*[jnp.asarray(a) for a in inputs])
    with open(os.path.join(args.out_dir, "placer_testvec.txt"), "w") as f:
        names = ["xs", "ys", "vx", "vy", "pins", "col", "colm", "bounds", "hyper"]
        for name, arr in zip(names, inputs):
            _dump_vec(f, f"in_{name}", arr)
        for name, arr in zip(["xs", "ys", "vx", "vy"], outs):
            _dump_vec(f, f"out_{name}", np.asarray(arr))
    print("placer_testvec.txt written")


if __name__ == "__main__":
    main()
