"""Build-time compile path: L2 JAX model + L1 Pallas kernels + AOT export.

Never imported at runtime — the Rust binary loads the HLO-text artifacts
this package produces (see aot.py and `canal::runtime`).
"""
