"""Pure-jnp oracle for the global-placement wirelength kernel.

This is the correctness reference the Pallas kernel (`hpwl.py`) is tested
against, and it mirrors `canal::pnr::place::global_cost_grad` on the Rust
side exactly: a quadratic star-model wirelength (the L2 approximation of
HPWL the paper's global placer uses, Eq. 1) plus a quadratic MEM-column
legalization term.

Conventions (shared by ref, kernel, model and the Rust runtime):
- ``pos``:   f32[N, 2]   continuous positions (x, y) per vertex;
- ``pins``:  i32[M, K]   vertex indices per net, ``-1`` padding;
- ``col``:   f32[N]      target MEM column per vertex (0 where unused);
- ``colm``:  f32[N]      1.0 where the column pull applies, else 0.0.
"""

import jax.numpy as jnp


def gather_pins(pos, pins):
    """Gather pin coordinates: f32[M, K, 2]; padded pins gather index 0."""
    safe = jnp.maximum(pins, 0)
    return pos[safe]


def pin_mask(pins):
    """f32[M, K] validity mask."""
    return (pins >= 0).astype(jnp.float32)


def net_cost_grad(coords, mask):
    """Per-net star-model cost and per-pin gradient.

    coords: f32[M, K, 2] gathered pin positions, mask: f32[M, K].
    Returns (cost f32[M], grad f32[M, K, 2]) where
    ``cost_m = sum_k mask * |p_k - c_m|^2`` with ``c_m`` the masked
    centroid, and ``grad = 2 * mask * (p_k - c_m)`` (centroid terms cancel
    in the total derivative, matching the Rust implementation).
    """
    mask3 = mask[..., None]
    count = jnp.maximum(mask.sum(axis=1), 1.0)[:, None]
    centroid = (coords * mask3).sum(axis=1) / count  # [M, 2]
    dev = (coords - centroid[:, None, :]) * mask3  # [M, K, 2]
    cost = (dev * dev).sum(axis=(1, 2))  # [M]
    # Degenerate nets (fewer than 2 real pins) contribute nothing.
    live = (mask.sum(axis=1) >= 2.0).astype(jnp.float32)
    return cost * live, 2.0 * dev * live[:, None, None]


def placement_cost_grad(pos, pins, col, colm, lambda_mem):
    """Full objective: wirelength + MEM legalization. Returns (cost, grad).

    cost: f32[]; grad: f32[N, 2].
    """
    coords = gather_pins(pos, pins)
    mask = pin_mask(pins)
    net_cost, pin_grad = net_cost_grad(coords, mask)

    n = pos.shape[0]
    safe = jnp.maximum(pins, 0).reshape(-1)
    flat = (pin_grad * mask[..., None]).reshape(-1, 2)
    grad = jnp.zeros((n, 2), jnp.float32).at[safe].add(flat)

    dx = (pos[:, 0] - col) * colm
    cost = net_cost.sum() + lambda_mem * (dx * dx).sum()
    grad = grad.at[:, 0].add(lambda_mem * 2.0 * dx)
    return cost, grad
