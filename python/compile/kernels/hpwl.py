"""L1 Pallas kernel: per-net star-model wirelength cost + pin gradients.

The global placer's hot spot is the per-net reduction over gathered pin
coordinates: masked centroid, squared deviations, and the 2*(p - c)
gradient. This kernel blocks the *net* dimension so each program instance
reduces a (BLOCK_M, K, 2) slab held in VMEM; the VPU handles the masked
reductions (no data-dependent control flow). The gather/scatter between
vertex space and pin space stays in the L2 jax model where XLA fuses it
with the optimizer update.

TPU adaptation notes (DESIGN.md §Hardware-Adaptation): the paper's CAD
lineage runs this on CPUs; on TPU the slab layout is chosen so K*2 lands
on the lane dimension and BLOCK_M on sublanes. VMEM footprint per program
instance: BLOCK_M * K * 2 * 4B (coords) + BLOCK_M * K * 4B (mask) +
outputs — ~20 KiB at BLOCK_M=128, K=16, far under the ~16 MiB budget, so
the kernel is memory-bandwidth-bound and the roofline argument is made on
bytes, not FLOPs.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_M = 128


def _net_kernel(coords_ref, mask_ref, cost_ref, grad_ref):
    """One block of nets: coords (BM, K, 2), mask (BM, K)."""
    coords = coords_ref[...]
    mask = mask_ref[...]
    mask3 = mask[..., None]
    count = jnp.maximum(mask.sum(axis=1), 1.0)[:, None]
    centroid = (coords * mask3).sum(axis=1) / count
    dev = (coords - centroid[:, None, :]) * mask3
    live = (mask.sum(axis=1) >= 2.0).astype(jnp.float32)
    cost_ref[...] = (dev * dev).sum(axis=(1, 2)) * live
    grad_ref[...] = 2.0 * dev * live[:, None, None]


@functools.partial(jax.jit, static_argnames=())
def net_cost_grad(coords, mask):
    """Pallas-blocked per-net cost/gradient.

    coords: f32[M, K, 2]; mask: f32[M, K]; M must be a multiple of
    BLOCK_M (the model pads). Returns (f32[M], f32[M, K, 2]).
    """
    m, k, _ = coords.shape
    assert m % BLOCK_M == 0, f"net count {m} not padded to {BLOCK_M}"
    grid = (m // BLOCK_M,)
    return pl.pallas_call(
        _net_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_M, k, 2), lambda i: (i, 0, 0)),
            pl.BlockSpec((BLOCK_M, k), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_M,), lambda i: (i,)),
            pl.BlockSpec((BLOCK_M, k, 2), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m,), jnp.float32),
            jax.ShapeDtypeStruct((m, k, 2), jnp.float32),
        ],
        interpret=True,
    )(coords, mask)
