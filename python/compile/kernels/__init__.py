"""L1 Pallas kernels and their pure-jnp oracles."""

from . import hpwl, ref  # noqa: F401
