//! Textual interconnect specifications.
//!
//! A flat `key = value` format (one setting per line, `#` comments) that
//! maps 1:1 onto [`InterconnectConfig`]. This gives the `canal` CLI a
//! file-based front-end next to the programmatic eDSL:
//!
//! ```text
//! # amber-like array
//! width = 16
//! height = 16
//! num_tracks = 5
//! track_widths = 16
//! sb_topology = wilton
//! reg_density = 1
//! sb_core_sides = 4
//! cb_core_sides = 4
//! mem_column_period = 4
//! ```

use super::config::{ConnectedSides, InterconnectConfig, OutputTrackMode};
use super::sb::SbTopology;

/// Parse a spec document into a config, starting from defaults.
pub fn parse_spec(text: &str) -> Result<InterconnectConfig, String> {
    let mut cfg = InterconnectConfig::default();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected `key = value`, got `{raw}`", lineno + 1))?;
        let (key, value) = (key.trim(), value.trim());
        let err = |what: &str| format!("line {}: invalid {what}: `{value}`", lineno + 1);
        match key {
            "width" => cfg.width = value.parse().map_err(|_| err("width"))?,
            "height" => cfg.height = value.parse().map_err(|_| err("height"))?,
            "num_tracks" => cfg.num_tracks = value.parse().map_err(|_| err("num_tracks"))?,
            "track_widths" => {
                cfg.track_widths = value
                    .split(',')
                    .map(|v| v.trim().parse().map_err(|_| err("track_widths")))
                    .collect::<Result<_, _>>()?;
            }
            "sb_topology" => {
                cfg.sb_topology = SbTopology::parse(value).ok_or_else(|| err("sb_topology"))?;
            }
            "reg_density" => cfg.reg_density = value.parse().map_err(|_| err("reg_density"))?,
            "sb_core_sides" => {
                cfg.sb_core_sides = ConnectedSides(value.parse().map_err(|_| err("sb_core_sides"))?);
            }
            "cb_core_sides" => {
                cfg.cb_core_sides = ConnectedSides(value.parse().map_err(|_| err("cb_core_sides"))?);
            }
            "mem_column_period" => {
                cfg.mem_column_period = value.parse().map_err(|_| err("mem_column_period"))?;
            }
            "output_tracks" => {
                cfg.output_tracks =
                    OutputTrackMode::parse(value).ok_or_else(|| err("output_tracks"))?;
            }
            other => return Err(format!("line {}: unknown key `{other}`", lineno + 1)),
        }
    }
    cfg.validate()?;
    Ok(cfg)
}

/// Emit a spec document for a config (round-trips through [`parse_spec`]).
pub fn emit_spec(cfg: &InterconnectConfig) -> String {
    let widths: Vec<String> = cfg.track_widths.iter().map(|w| w.to_string()).collect();
    format!(
        "# canal interconnect spec\n\
         width = {}\nheight = {}\nnum_tracks = {}\ntrack_widths = {}\n\
         sb_topology = {}\nreg_density = {}\nsb_core_sides = {}\ncb_core_sides = {}\n\
         mem_column_period = {}\noutput_tracks = {}\n",
        cfg.width,
        cfg.height,
        cfg.num_tracks,
        widths.join(", "),
        cfg.sb_topology.name(),
        cfg.reg_density,
        cfg.sb_core_sides.0,
        cfg.cb_core_sides.0,
        cfg.mem_column_period,
        cfg.output_tracks.name(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_spec() {
        let cfg = parse_spec(
            "width = 16\nheight = 8\nnum_tracks = 7\ntrack_widths = 1, 16\n\
             sb_topology = disjoint\nreg_density = 2\nsb_core_sides = 3\n\
             cb_core_sides = 2\nmem_column_period = 4\n",
        )
        .unwrap();
        assert_eq!((cfg.width, cfg.height), (16, 8));
        assert_eq!(cfg.num_tracks, 7);
        assert_eq!(cfg.track_widths, vec![1, 16]);
        assert_eq!(cfg.sb_topology, SbTopology::Disjoint);
        assert_eq!(cfg.sb_core_sides, ConnectedSides(3));
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let cfg = parse_spec("# hello\n\nwidth = 4 # inline\n").unwrap();
        assert_eq!(cfg.width, 4);
    }

    #[test]
    fn unknown_keys_rejected_with_line_number() {
        let e = parse_spec("widht = 4\n").unwrap_err();
        assert!(e.contains("line 1"), "{e}");
    }

    #[test]
    fn invalid_values_rejected() {
        assert!(parse_spec("width = banana\n").is_err());
        assert!(parse_spec("sb_topology = torus\n").is_err());
        assert!(parse_spec("num_tracks = 0\n").is_err()); // fails validate()
    }

    #[test]
    fn output_tracks_key_parses() {
        let cfg = parse_spec("output_tracks = pinned\n").unwrap();
        assert_eq!(cfg.output_tracks, OutputTrackMode::Pinned);
        assert!(parse_spec("output_tracks = some\n").is_err());
    }

    #[test]
    fn emit_parse_roundtrip() {
        let mut cfg = InterconnectConfig::default();
        cfg.width = 12;
        cfg.track_widths = vec![1, 16];
        cfg.sb_topology = SbTopology::Imran;
        cfg.output_tracks = OutputTrackMode::Pinned;
        let parsed = parse_spec(&emit_spec(&cfg)).unwrap();
        assert_eq!(parsed.width, cfg.width);
        assert_eq!(parsed.track_widths, cfg.track_widths);
        assert_eq!(parsed.sb_topology, cfg.sb_topology);
        assert_eq!(parsed.output_tracks, cfg.output_tracks);
    }
}
