//! Low-level Canal eDSL: explicit node creation and wiring.
//!
//! This is the Rust analogue of the paper's low-level API (Fig. 4):
//!
//! ```python
//! node = Node(x=1, y=1, side="south", track=1)
//! for port_node in tile.pe.inputs():
//!     node.add_edge(port_node)
//! ```
//!
//! A designer can build an entire custom interconnect from these
//! primitives; the high-level helpers in [`super::uniform`] are built on
//! top of exactly this API.

use crate::ir::{Interconnect, Node, NodeId, NodeKind, RoutingGraph, SbIo, Side};

use super::config::DelayModel;

/// Builder over one routing-graph layer of an [`Interconnect`].
pub struct GraphBuilder<'a> {
    graph: &'a mut RoutingGraph,
    delays: DelayModel,
}

impl<'a> GraphBuilder<'a> {
    pub fn new(graph: &'a mut RoutingGraph, delays: DelayModel) -> Self {
        GraphBuilder { graph, delays }
    }

    pub fn width(&self) -> u8 {
        self.graph.width
    }

    /// Create a switch-box track endpoint. SB *outputs* carry the SB mux
    /// delay; inputs are plain wires.
    pub fn sb(&mut self, x: u16, y: u16, side: Side, io: SbIo, track: u16) -> NodeId {
        let delay = if io == SbIo::Out { self.delays.sb_mux_ps } else { 0 };
        self.graph.add_node(Node::new(
            NodeKind::SwitchBox { side, io, track },
            x,
            y,
            self.graph.width,
            delay,
        ))
    }

    /// Create a core port node. Input ports lower to connection boxes and
    /// carry the CB mux delay.
    pub fn port(&mut self, x: u16, y: u16, name: &str, input: bool) -> NodeId {
        let delay = if input { self.delays.cb_mux_ps } else { 0 };
        self.graph.add_node(Node::new(
            NodeKind::Port { name: name.to_string(), input },
            x,
            y,
            self.graph.width,
            delay,
        ))
    }

    /// Create a pipeline register and its bypass mux on an SB output, wire
    /// `sb_out -> reg -> rmux` and `sb_out -> rmux`, and return the
    /// `rmux` node (the tile-boundary driver).
    pub fn register(&mut self, sb_out: NodeId, side: Side, track: u16) -> NodeId {
        let (x, y) = {
            let n = self.graph.node(sb_out);
            (n.x, n.y)
        };
        let reg = self.graph.add_node(Node::new(
            NodeKind::Register { side, track },
            x,
            y,
            self.graph.width,
            self.delays.reg_clk_q_ps,
        ));
        let rmux = self.graph.add_node(Node::new(
            NodeKind::RegMux { side, track },
            x,
            y,
            self.graph.width,
            self.delays.reg_mux_ps,
        ));
        self.graph.connect(sb_out, reg);
        self.graph.connect(sb_out, rmux);
        self.graph.connect(reg, rmux);
        rmux
    }

    /// Wire two nodes with zero (intra-tile) delay.
    pub fn wire(&mut self, from: NodeId, to: NodeId) {
        self.graph.connect(from, to);
    }

    /// Wire an inter-tile track hop with the model's wire delay.
    pub fn track_wire(&mut self, from: NodeId, to: NodeId) {
        self.graph.connect_with_delay(from, to, self.delays.wire_ps);
    }

    pub fn graph(&self) -> &RoutingGraph {
        self.graph
    }
}

/// Convenience for examples and tests: look up the node a route must enter
/// a tile on. Mirrors the paper's `Node(x=.., y=.., side=.., track=..)`.
pub fn sb_node(ic: &Interconnect, bit_width: u8, x: u16, y: u16, side: Side, io: SbIo, track: u16) -> Option<NodeId> {
    ic.graph(bit_width).find_sb(x, y, side, io, track)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::NodeKind;

    #[test]
    fn low_level_api_mirrors_paper_fig4() {
        // Build the Fig. 4 snippet: one SB node wired to all PE inputs.
        let mut g = RoutingGraph::new(16);
        let mut b = GraphBuilder::new(&mut g, DelayModel::default());
        let node = b.sb(1, 1, Side::South, SbIo::In, 1);
        let inputs: Vec<NodeId> =
            (0..4).map(|i| b.port(1, 1, &format!("data_in_{i}"), true)).collect();
        for port_node in &inputs {
            b.wire(node, *port_node);
        }
        assert_eq!(g.fan_out(node).len(), 4);
        for p in inputs {
            assert_eq!(g.fan_in(p), &[node]);
        }
    }

    #[test]
    fn register_builds_bypassable_pipeline_stage() {
        let mut g = RoutingGraph::new(16);
        let mut b = GraphBuilder::new(&mut g, DelayModel::default());
        let out = b.sb(0, 0, Side::East, SbIo::Out, 2);
        let rmux = b.register(out, Side::East, 2);
        // rmux has exactly two drivers: the register and the raw SB out.
        assert_eq!(g.fan_in(rmux).len(), 2);
        let reg = g
            .fan_in(rmux)
            .iter()
            .copied()
            .find(|&n| g.node(n).kind.is_register())
            .expect("register driver");
        assert_eq!(g.fan_in(reg), &[out]);
        assert!(matches!(g.node(rmux).kind, NodeKind::RegMux { side: Side::East, track: 2 }));
    }

    #[test]
    fn delays_follow_model() {
        let delays = DelayModel { sb_mux_ps: 11, cb_mux_ps: 22, wire_ps: 33, reg_clk_q_ps: 44, reg_mux_ps: 55 };
        let mut g = RoutingGraph::new(16);
        let mut b = GraphBuilder::new(&mut g, delays);
        let sbo = b.sb(0, 0, Side::East, SbIo::Out, 0);
        let sbi = b.sb(0, 0, Side::West, SbIo::In, 0);
        let pin = b.port(0, 0, "data_in_0", true);
        let pout = b.port(0, 0, "data_out_0", false);
        b.track_wire(sbo, sbi);
        assert_eq!(g.node(sbo).delay_ps, 11);
        assert_eq!(g.node(sbi).delay_ps, 0);
        assert_eq!(g.node(pin).delay_ps, 22);
        assert_eq!(g.node(pout).delay_ps, 0);
        assert_eq!(g.wire_delay(sbo, sbi), 33);
    }
}
