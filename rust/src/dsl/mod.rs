//! The Canal eDSL (§3.2): programmatic construction of interconnect IR.
//!
//! Two levels, mirroring the paper:
//! - [`builder`] — low-level node creation and wiring (Fig. 4, top);
//! - [`uniform::create_uniform_interconnect`] — high-level helper that
//!   builds a full uniform array from an [`config::InterconnectConfig`]
//!   (Fig. 4, bottom).
//!
//! [`spec`] adds a textual front-end so the CLI can load interconnect
//! specifications from files.

pub mod builder;
pub mod config;
pub mod sb;
pub mod spec;
pub mod uniform;

pub use builder::GraphBuilder;
pub use config::{ConnectedSides, DelayModel, InterconnectConfig, OutputTrackMode};
pub use sb::SbTopology;
pub use uniform::create_uniform_interconnect;
