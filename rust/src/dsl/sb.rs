//! Switch-box topology policies (§4.2.1, Fig. 9).
//!
//! A switch-box topology defines, for every *incoming* track on one side,
//! which *outgoing* track it connects to on each of the other three sides
//! (no U-turns). Both topologies evaluated in the paper connect each input
//! to each other side exactly once, so they have identical area; they
//! differ only in which track the turn lands on:
//!
//! - **Disjoint** [Weste & Eshraghian]: track `i` connects to track `i` on
//!   every other side. A route that starts on track `i` is confined to
//!   track `i` for its whole life — the restriction the paper blames for
//!   Disjoint failing to route.
//! - **Wilton** [Wilton '97]: straight-through connections keep the track
//!   number, but turns *permute* it, so the router can change tracks at
//!   every corner. The specific turn permutations below follow the
//!   classic Wilton construction (a cyclic shift on one diagonal and the
//!   reflection `W - t mod W` on the other); the property the paper's
//!   routability result rests on is that every turn is a non-identity
//!   bijection.

use crate::ir::Side;

/// Supported switch-box topologies.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum SbTopology {
    Wilton,
    Disjoint,
    /// Imran/universal-style variant (extension beyond the paper's two):
    /// reflection on every turn. Kept for DSE breadth.
    Imran,
}

impl SbTopology {
    pub fn name(self) -> &'static str {
        match self {
            SbTopology::Wilton => "wilton",
            SbTopology::Disjoint => "disjoint",
            SbTopology::Imran => "imran",
        }
    }

    pub fn parse(s: &str) -> Option<SbTopology> {
        match s.to_ascii_lowercase().as_str() {
            "wilton" => Some(SbTopology::Wilton),
            "disjoint" => Some(SbTopology::Disjoint),
            "imran" => Some(SbTopology::Imran),
            _ => None,
        }
    }

    /// Outgoing track on `to` for a signal entering on `from` at `track`,
    /// with `num_tracks` tracks per side. `from == to` (U-turn) is not a
    /// connection and returns `None`.
    pub fn map_track(self, from: Side, to: Side, track: u16, num_tracks: u16) -> Option<u16> {
        if from == to {
            return None;
        }
        let nt = num_tracks;
        let t = track;
        debug_assert!(t < nt);
        let straight = from.opposite() == to;
        let mapped = match self {
            SbTopology::Disjoint => t,
            SbTopology::Imran => {
                if straight {
                    t
                } else {
                    (nt - t) % nt
                }
            }
            SbTopology::Wilton => {
                if straight {
                    t
                } else {
                    use Side::*;
                    match (from, to) {
                        // Reflection diagonal (self-inverse pairs).
                        (West, North) | (North, West) => (nt - t) % nt,
                        (South, West) | (West, South) => (nt - t) % nt,
                        // Cyclic-shift diagonal.
                        (North, East) | (East, South) => (t + 1) % nt,
                        (East, North) | (South, East) => (t + nt - 1) % nt,
                        _ => unreachable!("straight handled above"),
                    }
                }
            }
        };
        Some(mapped)
    }

    /// Enumerate every internal SB connection as
    /// `(from_side, from_track, to_side, to_track)`.
    pub fn connections(self, num_tracks: u16) -> Vec<(Side, u16, Side, u16)> {
        let mut out = Vec::new();
        for from in Side::ALL {
            for to in Side::ALL {
                if from == to {
                    continue;
                }
                for t in 0..num_tracks {
                    if let Some(t2) = self.map_track(from, to, t, num_tracks) {
                        out.push((from, t, to, t2));
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    const TOPOS: [SbTopology; 3] = [SbTopology::Wilton, SbTopology::Disjoint, SbTopology::Imran];

    #[test]
    fn no_u_turns() {
        for topo in TOPOS {
            for s in Side::ALL {
                assert_eq!(topo.map_track(s, s, 0, 5), None);
            }
        }
    }

    #[test]
    fn every_side_pair_is_a_bijection() {
        // Each (from, to) pair must map the track set one-to-one, so every
        // SB output mux sees exactly one input per other side — the
        // equal-area property the paper relies on when comparing
        // topologies.
        for topo in TOPOS {
            for nt in 1..9u16 {
                for from in Side::ALL {
                    for to in Side::ALL {
                        if from == to {
                            continue;
                        }
                        let image: HashSet<u16> = (0..nt)
                            .map(|t| topo.map_track(from, to, t, nt).unwrap())
                            .collect();
                        assert_eq!(image.len(), nt as usize, "{topo:?} {from}->{to} nt={nt}");
                        assert!(image.iter().all(|&t| t < nt));
                    }
                }
            }
        }
    }

    #[test]
    fn disjoint_is_identity_everywhere() {
        for from in Side::ALL {
            for to in Side::ALL {
                if from == to {
                    continue;
                }
                for t in 0..8 {
                    assert_eq!(SbTopology::Disjoint.map_track(from, to, t, 8), Some(t));
                }
            }
        }
    }

    #[test]
    fn wilton_turns_change_tracks() {
        // The defining difference from Disjoint: at least one track number
        // changes on every turn (for nt > 2).
        let nt = 5;
        for from in Side::ALL {
            for to in Side::ALL {
                if from == to || from.opposite() == to {
                    continue;
                }
                let moved = (0..nt)
                    .filter(|&t| SbTopology::Wilton.map_track(from, to, t, nt) != Some(t))
                    .count();
                assert!(moved >= nt as usize - 1, "turn {from}->{to} barely permutes");
            }
        }
    }

    #[test]
    fn straight_connections_preserve_track() {
        for topo in TOPOS {
            for (a, b) in [(Side::North, Side::South), (Side::East, Side::West)] {
                for t in 0..6 {
                    assert_eq!(topo.map_track(a, b, t, 6), Some(t));
                    assert_eq!(topo.map_track(b, a, t, 6), Some(t));
                }
            }
        }
    }

    #[test]
    fn connection_counts_match_equal_area_claim() {
        // Both paper topologies: 4 sides x 3 other sides x nt tracks.
        for topo in TOPOS {
            assert_eq!(topo.connections(5).len(), 4 * 3 * 5, "{topo:?}");
        }
    }

    #[test]
    fn parse_roundtrip() {
        for topo in TOPOS {
            assert_eq!(SbTopology::parse(topo.name()), Some(topo));
        }
        assert_eq!(SbTopology::parse("nope"), None);
    }
}
