//! Interconnect configuration: the parameter surface of the Canal eDSL's
//! high-level helpers (the paper's Fig. 4:
//! `create_uniform_interconnect(width=32, height=32, sb_type="wilton",
//! num_tracks=5, track_width=16, reg_density=1)`), extended with the
//! design-space axes of §4.2 (SB/CB core-connection sides, Fig. 12/13).

use super::sb::SbTopology;

/// Delay model attached to generated IR nodes/edges (Fig. 7: "timing
/// information as weights"). Values are representative of a 12 nm CGRA
/// fabric; only *relative* timing matters for the paper's experiments.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DelayModel {
    /// Switch-box output mux propagation delay.
    pub sb_mux_ps: u32,
    /// Connection-box mux propagation delay.
    pub cb_mux_ps: u32,
    /// Inter-tile track wire delay per hop.
    pub wire_ps: u32,
    /// Pipeline-register clk-to-q (counts on the downstream segment).
    pub reg_clk_q_ps: u32,
    /// Register-bypass mux delay.
    pub reg_mux_ps: u32,
}

impl Default for DelayModel {
    fn default() -> Self {
        DelayModel { sb_mux_ps: 45, cb_mux_ps: 38, wire_ps: 90, reg_clk_q_ps: 55, reg_mux_ps: 25 }
    }
}

/// How many of a tile's four sides carry core↔fabric connections
/// (§4.2.2). The paper reduces 4 → 3 by dropping the east-facing
/// connections, then 3 → 2 by also dropping south.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct ConnectedSides(pub u8);

impl ConnectedSides {
    pub const FOUR: ConnectedSides = ConnectedSides(4);
    pub const THREE: ConnectedSides = ConnectedSides(3);
    pub const TWO: ConnectedSides = ConnectedSides(2);

    /// The sides kept, in the paper's reduction order: always N and W;
    /// 3 sides adds S; 4 sides adds E.
    pub fn sides(self) -> Vec<crate::ir::Side> {
        use crate::ir::Side::*;
        match self.0 {
            4 => vec![North, South, East, West],
            3 => vec![North, South, West],
            2 => vec![North, West],
            n => panic!("connected sides must be 2..=4, got {n}"),
        }
    }
}

/// How core *outputs* attach to switch-box tracks.
///
/// `AllTracks` (the default) lets every output drive every track of each
/// connected side — maximal endpoint flexibility. `Pinned` models the
/// depopulated style (output `j` drives only tracks `t ≡ j mod
/// n_outputs`): a net's starting track is then fixed by its driver, which
/// is exactly the restriction §4.2.1 blames for Disjoint's unroutability
/// ("if you want to route a wire ... starting from a certain track
/// number, you must only use that track number").
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum OutputTrackMode {
    AllTracks,
    Pinned,
}

impl OutputTrackMode {
    pub fn name(self) -> &'static str {
        match self {
            OutputTrackMode::AllTracks => "all",
            OutputTrackMode::Pinned => "pinned",
        }
    }

    pub fn parse(s: &str) -> Option<OutputTrackMode> {
        match s.to_ascii_lowercase().as_str() {
            "all" => Some(OutputTrackMode::AllTracks),
            "pinned" => Some(OutputTrackMode::Pinned),
            _ => None,
        }
    }
}

/// Full parameterization of a uniform interconnect.
#[derive(Clone, Debug)]
pub struct InterconnectConfig {
    /// Array width/height in tiles.
    pub width: u16,
    pub height: u16,
    /// Routing tracks per side, per bit-width layer.
    pub num_tracks: u16,
    /// Bit widths of the routing layers (e.g. `[16]`, or `[1, 16]` for a
    /// control layer plus a data layer).
    pub track_widths: Vec<u8>,
    /// Switch-box topology.
    pub sb_topology: SbTopology,
    /// Pipeline-register density: a register on every SB output of every
    /// tile whose `(x + y) % reg_density == 0`. `0` disables pipeline
    /// registers entirely. `1` ⇒ registers in every tile (the paper's
    /// `reg_density=1`).
    pub reg_density: u16,
    /// Sides on which core *outputs* drive the switch box (Fig. 12/14).
    pub sb_core_sides: ConnectedSides,
    /// Which tracks each core output drives on those sides.
    pub output_tracks: OutputTrackMode,
    /// Sides whose incoming tracks feed the connection box (Fig. 13/15).
    pub cb_core_sides: ConnectedSides,
    /// Every `mem_column_period`-th column is a MEM column (0 = no MEM
    /// tiles). CGRAs "typically have fewer rows or columns of memory
    /// tiles" (§3.4); Amber-style arrays use every 4th column.
    pub mem_column_period: u16,
    /// Delay model for STA / timing-driven routing.
    pub delays: DelayModel,
}

impl Default for InterconnectConfig {
    fn default() -> Self {
        InterconnectConfig {
            width: 8,
            height: 8,
            num_tracks: 5,
            track_widths: vec![16],
            sb_topology: SbTopology::Wilton,
            reg_density: 1,
            sb_core_sides: ConnectedSides::FOUR,
            output_tracks: OutputTrackMode::AllTracks,
            cb_core_sides: ConnectedSides::FOUR,
            mem_column_period: 4,
            delays: DelayModel::default(),
        }
    }
}

impl InterconnectConfig {
    /// The paper's §4 baseline: five 16-bit tracks, Wilton, PEs with four
    /// inputs and two outputs, MEM every 4th column.
    pub fn paper_baseline(width: u16, height: u16) -> Self {
        InterconnectConfig { width, height, ..Default::default() }
    }

    /// One-line descriptor recorded in generated collateral.
    pub fn descriptor(&self) -> String {
        format!(
            "uniform {}x{} sb={} tracks={} widths={:?} reg_density={} sb_sides={} cb_sides={} mem_period={} out_tracks={}",
            self.width,
            self.height,
            self.sb_topology.name(),
            self.num_tracks,
            self.track_widths,
            self.reg_density,
            self.sb_core_sides.0,
            self.cb_core_sides.0,
            self.mem_column_period,
            self.output_tracks.name(),
        )
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.width == 0 || self.height == 0 {
            return Err("array dimensions must be nonzero".into());
        }
        if self.num_tracks == 0 {
            return Err("need at least one routing track".into());
        }
        if self.track_widths.is_empty() {
            return Err("need at least one track width".into());
        }
        let mut w = self.track_widths.clone();
        w.dedup();
        if w.len() != self.track_widths.len() {
            return Err("duplicate track widths".into());
        }
        if !(2..=4).contains(&self.sb_core_sides.0) || !(2..=4).contains(&self.cb_core_sides.0) {
            return Err("connected sides must be in 2..=4".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Side;

    #[test]
    fn side_reduction_follows_paper_order() {
        // 4 -> 3 removes east; 3 -> 2 removes south.
        let four = ConnectedSides::FOUR.sides();
        let three = ConnectedSides::THREE.sides();
        let two = ConnectedSides::TWO.sides();
        assert!(four.contains(&Side::East) && !three.contains(&Side::East));
        assert!(three.contains(&Side::South) && !two.contains(&Side::South));
        assert_eq!(two, vec![Side::North, Side::West]);
    }

    #[test]
    fn default_config_is_valid_paper_baseline() {
        let c = InterconnectConfig::paper_baseline(16, 16);
        c.validate().unwrap();
        assert_eq!(c.num_tracks, 5);
        assert_eq!(c.track_widths, vec![16]);
        assert_eq!(c.sb_topology, SbTopology::Wilton);
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = InterconnectConfig::default();
        c.num_tracks = 0;
        assert!(c.validate().is_err());
        let mut c = InterconnectConfig::default();
        c.width = 0;
        assert!(c.validate().is_err());
        let mut c = InterconnectConfig::default();
        c.track_widths = vec![16, 16];
        assert!(c.validate().is_err());
    }

    #[test]
    fn descriptor_mentions_key_axes() {
        let d = InterconnectConfig::default().descriptor();
        assert!(d.contains("wilton"));
        assert!(d.contains("tracks=5"));
    }
}
