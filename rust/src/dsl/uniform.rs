//! `create_uniform_interconnect` — the high-level Canal helper (§3.2,
//! Fig. 4): build a full array where every switch box shares one topology,
//! parameterized by array size, topology, track count/width, register
//! density, and core-connection sides.

use crate::ir::{
    assert_valid, CoreKind, CoreSpec, Interconnect, NodeId, PortSpec, RoutingGraph, SbIo, Side,
    Tile,
};

use super::builder::GraphBuilder;
use super::config::InterconnectConfig;

/// Core specs per tile position: PEs everywhere, MEM columns on the
/// configured period. Ports are created for every configured track width
/// (data ports on wide layers, one predicate/valid pair on the 1-bit
/// layer).
fn make_core(kind: CoreKind, widths: &[u8]) -> CoreSpec {
    let mut inputs = Vec::new();
    let mut outputs = Vec::new();
    for &w in widths {
        let base = match kind {
            CoreKind::Pe => CoreSpec::pe(w),
            CoreKind::Mem => CoreSpec::mem(w),
            CoreKind::Io => CoreSpec::io(w),
        };
        if w == 1 {
            // Control layer: a single predicate in / valid out pair.
            inputs.push(PortSpec::new("bit_in_0", 1));
            outputs.push(PortSpec::new("bit_out_0", 1));
        } else {
            inputs.extend(base.inputs);
            outputs.extend(base.outputs);
        }
    }
    let delay_ps = match kind {
        CoreKind::Pe => 640,
        CoreKind::Mem => 800,
        CoreKind::Io => 0,
    };
    CoreSpec { kind, inputs, outputs, delay_ps }
}

/// Whether tile `(x, y)` carries pipeline registers under `reg_density`.
/// Density 1 ⇒ every tile; density N ⇒ every N-th diagonal; 0 ⇒ none.
fn is_registered(cfg: &InterconnectConfig, x: u16, y: u16) -> bool {
    cfg.reg_density != 0 && (x + y) % cfg.reg_density == 0
}

/// Build one routing-graph layer.
fn build_layer(cfg: &InterconnectConfig, tiles: &[Tile], bit_width: u8) -> RoutingGraph {
    let mut graph = RoutingGraph::new(bit_width);
    let nt = cfg.num_tracks;
    let mut b = GraphBuilder::new(&mut graph, cfg.delays);

    // --- Per-tile nodes -------------------------------------------------
    // `boundary[(x, y, side, track)]` is the node that drives the
    // neighbouring tile: the register bypass mux if this tile is
    // registered, otherwise the raw SB output.
    let mut boundary: std::collections::HashMap<(u16, u16, Side, u16), NodeId> =
        std::collections::HashMap::new();

    for tile in tiles {
        let (x, y) = (tile.x, tile.y);
        // SB endpoints on all four sides.
        let mut sb_in = [[NodeId(0); 8]; 4];
        let mut sb_out = [[NodeId(0); 8]; 4];
        assert!(nt as usize <= 8, "track count > 8 unsupported by builder scratch arrays");
        for side in Side::ALL {
            for t in 0..nt {
                sb_in[side.index()][t as usize] = b.sb(x, y, side, SbIo::In, t);
                sb_out[side.index()][t as usize] = b.sb(x, y, side, SbIo::Out, t);
            }
        }

        // Internal SB topology connections.
        for (from, t, to, t2) in cfg.sb_topology.connections(nt) {
            b.wire(sb_in[from.index()][t as usize], sb_out[to.index()][t2 as usize]);
        }

        // Core ports of this layer.
        let in_ports: Vec<(String, NodeId)> = tile
            .core
            .inputs
            .iter()
            .filter(|p| p.width == bit_width)
            .map(|p| (p.name.clone(), b.port(x, y, &p.name, true)))
            .collect();
        let out_ports: Vec<NodeId> = tile
            .core
            .outputs
            .iter()
            .filter(|p| p.width == bit_width)
            .map(|p| b.port(x, y, &p.name, false))
            .collect();

        // Core outputs -> SB outputs on the configured sides (Fig. 12).
        // `AllTracks`: every output reaches every track of each connected
        // side. `Pinned`: output j reaches only tracks t ≡ j (mod
        // n_outputs) — the depopulated style whose interaction with the
        // Disjoint topology §4.2.1 describes.
        for &side in &cfg.sb_core_sides.sides() {
            for t in 0..nt {
                for (j, &op) in out_ports.iter().enumerate() {
                    let drives = match cfg.output_tracks {
                        super::config::OutputTrackMode::AllTracks => true,
                        super::config::OutputTrackMode::Pinned => {
                            !out_ports.is_empty()
                                && t as usize % out_ports.len() == j
                        }
                    };
                    if drives {
                        b.wire(op, sb_out[side.index()][t as usize]);
                    }
                }
            }
        }

        // Connection box: incoming tracks on the configured sides feed
        // every core input port (Fig. 13).
        for &side in &cfg.cb_core_sides.sides() {
            for t in 0..nt {
                for (_, ip) in &in_ports {
                    b.wire(sb_in[side.index()][t as usize], *ip);
                }
            }
        }

        // Pipeline registers on SB outputs.
        let registered = is_registered(cfg, x, y);
        for side in Side::ALL {
            for t in 0..nt {
                let out = sb_out[side.index()][t as usize];
                let driver = if registered { b.register(out, side, t) } else { out };
                boundary.insert((x, y, side, t), driver);
            }
        }
    }

    // --- Inter-tile track wires -----------------------------------------
    let (w, h) = (cfg.width as i32, cfg.height as i32);
    for tile in tiles {
        let (x, y) = (tile.x, tile.y);
        for side in Side::ALL {
            let (dx, dy) = side.offset();
            let (nx, ny) = (x as i32 + dx, y as i32 + dy);
            if nx < 0 || ny < 0 || nx >= w || ny >= h {
                continue; // array margin
            }
            for t in 0..nt {
                let from = boundary[&(x, y, side, t)];
                let to = graph_find_sb(b.graph(), nx as u16, ny as u16, side.opposite(), t);
                b.track_wire(from, to);
            }
        }
    }

    graph
}

fn graph_find_sb(g: &RoutingGraph, x: u16, y: u16, side: Side, track: u16) -> NodeId {
    g.find_sb(x, y, side, SbIo::In, track)
        .unwrap_or_else(|| panic!("missing sb in node at ({x},{y}) {side} t{track}"))
}

/// Build a uniform interconnect from a configuration. This is the
/// reproduction of the paper's `create_uniform_interconnect` helper.
pub fn create_uniform_interconnect(cfg: &InterconnectConfig) -> Interconnect {
    cfg.validate().unwrap_or_else(|e| panic!("invalid interconnect config: {e}"));

    // Tile grid: MEM columns every `mem_column_period` (never column 0).
    let mut tiles = Vec::with_capacity(cfg.width as usize * cfg.height as usize);
    for y in 0..cfg.height {
        for x in 0..cfg.width {
            let kind = if cfg.mem_column_period != 0 && x != 0 && x % cfg.mem_column_period == 0 {
                CoreKind::Mem
            } else {
                CoreKind::Pe
            };
            tiles.push(Tile { x, y, core: make_core(kind, &cfg.track_widths) });
        }
    }

    let mut ic = Interconnect::new(cfg.width, cfg.height, tiles, cfg.descriptor());
    for &bw in &cfg.track_widths {
        let layer = build_layer(cfg, &ic.tiles, bw);
        ic.graphs.insert(bw, layer);
    }
    assert_valid(&ic);
    // Freeze once, here: every consumer (PnR, STA, bitstream, simulation)
    // reads the immutable CSR view, and DSE sweeps share it across
    // threads without re-deriving anything per run.
    ic.freeze();
    ic
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::config::ConnectedSides;
    use crate::dsl::sb::SbTopology;
    use crate::ir::{validate, NodeKind};

    fn small(cfg_mod: impl FnOnce(&mut InterconnectConfig)) -> Interconnect {
        let mut cfg = InterconnectConfig {
            width: 4,
            height: 4,
            num_tracks: 3,
            mem_column_period: 2,
            ..Default::default()
        };
        cfg_mod(&mut cfg);
        create_uniform_interconnect(&cfg)
    }

    #[test]
    fn builds_valid_ir() {
        let ic = small(|_| {});
        assert!(validate(&ic).is_empty());
        assert_eq!(ic.tiles.len(), 16);
    }

    #[test]
    fn built_interconnect_is_frozen() {
        let ic = small(|c| c.track_widths = vec![1, 16]);
        assert!(ic.is_frozen());
        for bw in ic.bit_widths() {
            assert_eq!(ic.compiled(bw).len(), ic.graph(bw).len());
            assert_eq!(ic.compiled(bw).edge_count(), ic.graph(bw).edge_count());
        }
    }

    #[test]
    fn mem_columns_on_period() {
        let ic = small(|_| {});
        assert_eq!(ic.tile(2, 1).core.kind, CoreKind::Mem);
        assert_eq!(ic.tile(1, 1).core.kind, CoreKind::Pe);
        assert_eq!(ic.tile(0, 0).core.kind, CoreKind::Pe); // column 0 never MEM
    }

    #[test]
    fn sb_out_mux_inputs_match_topology_plus_core() {
        // Interior tile, 4-side core connections: each SB out mux sees one
        // input per other side (3) + each of the PE's 2 outputs.
        let ic = small(|c| c.reg_density = 0);
        let g = ic.graph(16);
        let out = g.find_sb(1, 1, Side::East, SbIo::Out, 0).unwrap();
        assert_eq!(g.fan_in(out).len(), 3 + 2);
    }

    #[test]
    fn cb_fan_in_scales_with_sides_and_tracks() {
        let ic4 = small(|c| c.reg_density = 0);
        let g = ic4.graph(16);
        let p = g.find_port(1, 1, "data_in_0", true).unwrap();
        assert_eq!(g.fan_in(p).len(), 4 * 3); // 4 sides x 3 tracks

        let ic2 = small(|c| {
            c.reg_density = 0;
            c.cb_core_sides = ConnectedSides::TWO;
        });
        let g2 = ic2.graph(16);
        let p2 = g2.find_port(1, 1, "data_in_0", true).unwrap();
        assert_eq!(g2.fan_in(p2).len(), 2 * 3);
    }

    #[test]
    fn reducing_sb_sides_shrinks_mux_fan_in() {
        let ic = small(|c| {
            c.reg_density = 0;
            c.sb_core_sides = ConnectedSides::TWO; // keeps N and W
        });
        let g = ic.graph(16);
        // East side no longer fed by core outputs: 3 topology inputs only.
        let east = g.find_sb(1, 1, Side::East, SbIo::Out, 0).unwrap();
        assert_eq!(g.fan_in(east).len(), 3);
        // North still fed by both PE outputs.
        let north = g.find_sb(1, 1, Side::North, SbIo::Out, 0).unwrap();
        assert_eq!(g.fan_in(north).len(), 3 + 2);
    }

    #[test]
    fn tiles_stitched_to_neighbours() {
        let ic = small(|c| c.reg_density = 0);
        let g = ic.graph(16);
        let out = g.find_sb(1, 1, Side::East, SbIo::Out, 2).unwrap();
        let nin = g.find_sb(2, 1, Side::West, SbIo::In, 2).unwrap();
        assert_eq!(g.fan_out(out), &[nin]);
        assert_eq!(g.wire_delay(out, nin), crate::dsl::config::DelayModel::default().wire_ps);
    }

    #[test]
    fn registered_tiles_interpose_regmux_at_boundary() {
        let ic = small(|c| c.reg_density = 1);
        let g = ic.graph(16);
        let out = g.find_sb(1, 1, Side::East, SbIo::Out, 0).unwrap();
        // SB out drives register + bypass mux, not the neighbour directly.
        let sinks = g.fan_out(out);
        assert_eq!(sinks.len(), 2);
        let rmux = sinks
            .iter()
            .copied()
            .find(|&n| matches!(g.node(n).kind, NodeKind::RegMux { .. }))
            .unwrap();
        // The bypass mux drives the neighbour's SB input.
        let nin = g.find_sb(2, 1, Side::West, SbIo::In, 0).unwrap();
        assert_eq!(g.fan_out(rmux), &[nin]);
    }

    #[test]
    fn margins_have_no_dangling_wires() {
        let ic = small(|c| c.reg_density = 0);
        let g = ic.graph(16);
        // West side of column-0 tile has no incoming neighbour.
        let win = g.find_sb(0, 1, Side::West, SbIo::In, 0).unwrap();
        assert!(g.fan_in(win).is_empty());
        // And its west out drives nothing.
        let wout = g.find_sb(0, 1, Side::West, SbIo::Out, 0).unwrap();
        assert!(g.fan_out(wout).is_empty());
    }

    #[test]
    fn control_layer_built_when_requested() {
        let ic = small(|c| c.track_widths = vec![1, 16]);
        assert_eq!(ic.bit_widths(), vec![1, 16]);
        let g1 = ic.graph(1);
        assert!(g1.find_port(1, 1, "bit_in_0", true).is_some());
        assert!(g1.find_port(1, 1, "data_in_0", true).is_none());
    }

    #[test]
    fn disjoint_and_wilton_have_equal_node_and_edge_counts() {
        // The equal-area premise of Fig. 9's comparison.
        let w = small(|c| c.sb_topology = SbTopology::Wilton);
        let d = small(|c| c.sb_topology = SbTopology::Disjoint);
        assert_eq!(w.node_count(), d.node_count());
        assert_eq!(w.edge_count(), d.edge_count());
    }
}
