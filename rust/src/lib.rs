//! # Canal — a flexible interconnect generator for CGRAs
//!
//! Rust + JAX + Pallas reproduction of *"Canal: A Flexible Interconnect
//! Generator for Coarse-Grained Reconfigurable Arrays"* (Melchert, Zhang,
//! et al., 2022).
//!
//! The crate is organized around the paper's system diagram (Fig. 2):
//!
//! - [`ir`] — the graph-based intermediate representation (§3.1);
//! - [`dsl`] — the Canal eDSL that constructs the IR (§3.2);
//! - [`hw`] — hardware generation: static mesh and statically-configured
//!   ready-valid NoC backends, Verilog emission, structural verification,
//!   configuration-space allocation (§3.3);
//! - [`bitstream`] — bitstream generation from PnR results;
//! - [`pnr`] — packing, placement (analytic global — scalar and batched
//!   solvers — + simulated-annealing detailed) and iterative A* routing
//!   over the IR graph (§3.4);
//! - [`sim`] — functional simulation of configured fabrics, including a
//!   cycle-accurate ready-valid mode with FIFO backpressure;
//! - [`apps`] — the application benchmark suite (dataflow graphs);
//! - [`area`] — the GF12-calibrated area model (evaluation substrate);
//! - [`runtime`] — PJRT loader executing the AOT-compiled JAX/Pallas
//!   global-placement artifacts from the Rust hot path;
//! - [`coordinator`] — design-space-exploration driver reproducing every
//!   figure in the paper's evaluation;
//! - [`dse`] — the sharded, cached design-space-exploration engine:
//!   declarative sweep specs over the frozen `CompiledGraph`, a
//!   work-stealing worker pool that drains each per-config job group
//!   through one batched placement solve, and a
//!   `(config, app, seed)`-keyed result cache with JSON persistence;
//! - [`service`] — the persistent daemon (`canal serve`): a TCP server
//!   with a newline-delimited JSON protocol, concurrent sessions over
//!   one shared warm state (LRU of frozen interconnects, one result
//!   cache, one placer backend), and coalescing of overlapping in-flight
//!   `dse` requests;
//! - [`obs`] — observability: span tracing into per-worker ring
//!   buffers, a process-wide metrics registry (counters / gauges /
//!   log-bucketed histograms), and Chrome-trace + NDJSON export —
//!   zero-cost behind an atomic gate when disabled;
//! - [`util`] — self-contained support code (deterministic RNG, JSON,
//!   benchmarking, property-test harness).
//!
//! # Documentation map
//!
//! Narrative documentation lives in the repository's `docs/` directory:
//!
//! - `README.md` — pipeline overview, module map, quickstart;
//! - `docs/architecture.md` — the two-representation IR, the CSR layout,
//!   the fan-in-order = mux-select invariant, and the freeze lifecycle;
//! - `docs/dse.md` — sweep specs, `ConfigDescriptor` keying, the batched
//!   placement contract, and the `dse_cache.json` format;
//! - `docs/cli.md` — the `canal` CLI reference (`canal help` prints the
//!   same usage block);
//! - `docs/service.md` — the daemon: protocol frames, state-sharing and
//!   coalescing rules, shutdown semantics;
//! - `docs/observability.md` — span taxonomy, metric names, trace file
//!   format, and how to open a trace in Perfetto.
//!
//! The per-module rustdoc (start at the list above) is the normative
//! reference for invariants; the `docs/` pages are the narrative tour.

pub mod apps;
pub mod area;
pub mod bitstream;
pub mod coordinator;
pub mod dse;
pub mod dsl;
pub mod hw;
pub mod ir;
pub mod obs;
pub mod pnr;
pub mod runtime;
pub mod service;
pub mod sim;
pub mod util;
