//! Minimal lossless JSON reader/writer (serde is unavailable offline).
//!
//! Numbers are kept as their literal text on both paths: a `u64` seed or
//! a shortest-round-trip `f64` survives a parse → emit cycle bit-exactly,
//! which is what lets the DSE result cache re-render warm tables
//! byte-identically to cold ones.

/// A JSON value. Object member order is preserved (no hashing), so the
/// emitted text of a parsed document is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// A number, stored as its literal text.
    Num(String),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    pub fn num_u64(v: u64) -> Json {
        Json::Num(v.to_string())
    }

    /// `{:?}` is Rust's shortest representation that parses back to the
    /// same bits; JSON has no encoding for the non-finite values.
    pub fn num_f64(v: f64) -> Json {
        if v.is_finite() {
            Json::Num(format!("{v:?}"))
        } else {
            Json::Null
        }
    }

    /// Object member lookup (`None` on non-objects and missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(members) => members.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(s) => s.parse().ok(),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(s) => s.parse().ok(),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// Compact rendering.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    /// Compact rendering with a *framing guarantee*: the returned string
    /// contains no `\n` or `\r` byte, so it is always exactly one line.
    /// This is what the service protocol's newline-delimited framing
    /// (`service::proto`) builds on.
    ///
    /// The guarantee holds by construction — the string escaper emits
    /// `\n`/`\r` (and every other control character) escaped, the
    /// renderer emits no whitespace between tokens, and number literals
    /// cannot contain whitespace: a parsed [`Json::Num`] keeps only
    /// bytes matched by the number scanner (digits, sign, `.`, `e`), and
    /// the `num_*` constructors format from numeric types. The
    /// debug-build assertion below audits that reasoning; release builds
    /// pay nothing.
    pub fn render_line(&self) -> String {
        let out = self.render();
        debug_assert!(
            !out.bytes().any(|b| b == b'\n' || b == b'\r'),
            "render_line produced an embedded newline: {out:?}"
        );
        out
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(s) => out.push_str(s),
            Json::Str(s) => escape_into(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.render_into(out);
                }
                out.push(']');
            }
            Json::Obj(members) => {
                out.push('{');
                for (i, (k, v)) in members.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape_into(k, out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }

    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }
}

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn err(&self, what: &str) -> String {
        format!("byte {}: {what}", self.i)
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.i < self.b.len() && self.b[self.i] == c {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.b.get(self.i) {
            None => Err(self.err("unexpected end of input")),
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => {
                self.i += 1;
                let mut items = Vec::new();
                self.skip_ws();
                if self.b.get(self.i) == Some(&b']') {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                loop {
                    self.skip_ws();
                    items.push(self.value()?);
                    self.skip_ws();
                    match self.b.get(self.i) {
                        Some(b',') => self.i += 1,
                        Some(b']') => {
                            self.i += 1;
                            return Ok(Json::Arr(items));
                        }
                        _ => return Err(self.err("expected `,` or `]`")),
                    }
                }
            }
            Some(b'{') => {
                self.i += 1;
                let mut members = Vec::new();
                self.skip_ws();
                if self.b.get(self.i) == Some(&b'}') {
                    self.i += 1;
                    return Ok(Json::Obj(members));
                }
                loop {
                    self.skip_ws();
                    let k = self.string()?;
                    self.skip_ws();
                    self.eat(b':')?;
                    self.skip_ws();
                    let v = self.value()?;
                    members.push((k, v));
                    self.skip_ws();
                    match self.b.get(self.i) {
                        Some(b',') => self.i += 1,
                        Some(b'}') => {
                            self.i += 1;
                            return Ok(Json::Obj(members));
                        }
                        _ => return Err(self.err("expected `,` or `}`")),
                    }
                }
            }
            Some(c) if *c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected `{}`", *c as char))),
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).expect("ascii");
        // Validate the literal; keep the exact text for lossless re-emit.
        text.parse::<f64>().map_err(|_| self.err(&format!("bad number `{text}`")))?;
        Ok(Json::Num(text.to_string()))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = *self.b.get(self.i).ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = *self.b.get(self.i).ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let code = if (0xD800..0xDC00).contains(&hi) {
                                // Surrogate pair: require the low half.
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else {
                                hi
                            };
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| self.err("bad unicode escape"))?,
                            );
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                _ => {
                    // Multi-byte UTF-8: copy the whole sequence through.
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.i - 1;
                    let end = start + len;
                    let chunk = self
                        .b
                        .get(start..end)
                        .and_then(|raw| std::str::from_utf8(raw).ok())
                        .ok_or_else(|| self.err("bad utf-8"))?;
                    s.push_str(chunk);
                    self.i = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        let raw = self
            .b
            .get(self.i..self.i + 4)
            .and_then(|h| std::str::from_utf8(h).ok())
            .ok_or_else(|| self.err("bad \\u escape"))?;
        let v = u32::from_str_radix(raw, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.i += 4;
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrips_structure() {
        let v = Json::Obj(vec![
            ("name".into(), Json::str("harris")),
            ("seed".into(), Json::num_u64(u64::MAX)),
            ("ok".into(), Json::Bool(true)),
            ("none".into(), Json::Null),
            ("xs".into(), Json::Arr(vec![Json::num_f64(1.5), Json::num_f64(-0.25)])),
        ]);
        let text = v.render();
        let back = Json::parse(&text).unwrap();
        assert_eq!(v, back);
        // Emission of a parsed doc is stable.
        assert_eq!(back.render(), text);
    }

    #[test]
    fn floats_roundtrip_bit_exactly() {
        for v in [0.1, 1.0 / 3.0, 9378.234_567_89, f64::MIN_POSITIVE, 1e300, -0.0] {
            let j = Json::num_f64(v);
            let back = Json::parse(&j.render()).unwrap().as_f64().unwrap();
            assert_eq!(v.to_bits(), back.to_bits(), "{v}");
        }
    }

    #[test]
    fn u64_roundtrips_without_f64_truncation() {
        let j = Json::num_u64(u64::MAX - 1);
        assert_eq!(Json::parse(&j.render()).unwrap().as_u64(), Some(u64::MAX - 1));
    }

    #[test]
    fn escapes_survive() {
        let v = Json::str("a\"b\\c\nd\te — µ");
        let back = Json::parse(&v.render()).unwrap();
        assert_eq!(back.as_str(), Some("a\"b\\c\nd\te — µ"));
        let surrogate = r#""😀""#;
        assert_eq!(Json::parse(surrogate).unwrap().as_str(), Some("😀"));
    }

    #[test]
    fn render_line_never_embeds_newlines() {
        // Escape-path audit: every place a raw `\n`/`\r` could sneak
        // into the output — string values, object keys, nested
        // structures, parsed-and-re-emitted documents — must come out
        // escaped. The framed service protocol depends on this.
        let hostile = "a\nb\rc\r\nd\u{85}e\u{2028}f\u{2029}g\th\u{0}i";
        let v = Json::Obj(vec![
            ("k\ney".into(), Json::str(hostile)),
            ("arr".into(), Json::Arr(vec![Json::str("\n"), Json::str("\r\n")])),
            (
                "nested".into(),
                Json::Obj(vec![("inner\r".into(), Json::Arr(vec![Json::str(hostile)]))]),
            ),
            ("n".into(), Json::num_f64(1.5e-300)),
        ]);
        let line = v.render_line();
        assert!(!line.bytes().any(|b| b == b'\n' || b == b'\r'), "{line:?}");
        // Still a faithful encoding: parsing the line restores the
        // hostile content exactly.
        let back = Json::parse(&line).unwrap();
        assert_eq!(back.get("k\ney").and_then(Json::as_str), Some(hostile));
        assert_eq!(back, v);
        // NEL / LS / PS are not ASCII newline bytes in UTF-8, so they
        // pass through raw — and contain no 0x0A/0x0D byte.
        assert!(line.contains('\u{2028}'));
        // A parsed document re-renders to one line too (numbers keep
        // their literal text; the scanner admits no whitespace bytes).
        let reparsed = Json::parse("{ \"a\" : [ 1.5e3 ,\n -2 ] }").unwrap();
        let line2 = reparsed.render_line();
        assert_eq!(line2, r#"{"a":[1.5e3,-2]}"#);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#""\ud800x""#).is_err());
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"a": [1, 2], "b": "x", "c": false}"#).unwrap();
        assert_eq!(v.get("a").and_then(Json::as_arr).map(<[Json]>::len), Some(2));
        assert_eq!(v.get("b").and_then(Json::as_str), Some("x"));
        assert_eq!(v.get("c").and_then(Json::as_bool), Some(false));
        assert_eq!(v.get("missing"), None);
        assert_eq!(v.get("b").and_then(Json::as_u64), None);
    }
}
