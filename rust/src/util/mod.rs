//! Small self-contained utilities: deterministic RNG, table rendering,
//! lossless JSON, and a benchmarking harness (offline substitutes for
//! rand/serde_json/criterion).
pub mod bench;
pub mod json;
pub mod rng;
pub mod table;
