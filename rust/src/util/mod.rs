//! Small self-contained utilities: deterministic RNG, table rendering,
//! and a benchmarking harness (offline substitutes for rand/criterion).
pub mod bench;
pub mod rng;
pub mod table;
