/// Derive an independent child seed from `(seed, label)`: FNV-1a over the
/// label, mixed with the parent seed through a splitmix64 finalizer.
///
/// Sharded sweeps use this to give every `(config, app, seed)` job its own
/// reproducible stream: the stream depends only on the label and the
/// logical seed, never on which worker ran the job or in what order.
pub fn derive_seed(seed: u64, label: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in label.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    let mut z = (seed ^ h.rotate_left(31)).wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Deterministic xoshiro256** RNG (no external deps; reproducible runs).
#[derive(Clone, Debug)]
pub struct Rng { s: [u64; 4] }

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Labeled substream of `seed` (see [`derive_seed`]).
    pub fn derive(seed: u64, label: &str) -> Rng {
        Rng::new(derive_seed(seed, label))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derive_is_deterministic_and_label_sensitive() {
        assert_eq!(derive_seed(7, "cfg/app"), derive_seed(7, "cfg/app"));
        assert_ne!(derive_seed(7, "cfg/app"), derive_seed(7, "cfg/apq"));
        assert_ne!(derive_seed(7, "cfg/app"), derive_seed(8, "cfg/app"));
        // A label prefix is not a collision.
        assert_ne!(derive_seed(7, "cfg"), derive_seed(7, "cfg/"));
    }

    #[test]
    fn derived_streams_diverge_from_parent_and_siblings() {
        let mut parent = Rng::new(1);
        let mut a = Rng::derive(1, "a");
        let mut b = Rng::derive(1, "b");
        let pa: Vec<u64> = (0..8).map(|_| parent.next_u64()).collect();
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(pa, va);
        assert_ne!(va, vb);
    }

    #[test]
    fn below_and_f64_stay_in_range() {
        let mut r = Rng::derive(42, "range");
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
            let f = r.f64();
            assert!((0.0..1.0).contains(&f));
        }
    }
}
