/// Deterministic xoshiro256** RNG (no external deps; reproducible runs).
#[derive(Clone, Debug)]
pub struct Rng { s: [u64; 4] }

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }
}
