//! Minimal benchmarking harness (criterion is unavailable offline; this
//! provides the same core loop: warmup, timed iterations, robust stats).

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchStats {
    pub fn throughput_per_sec(&self) -> f64 {
        1.0 / self.median.as_secs_f64()
    }
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<40} median {:>12?}  mean {:>12?}  min {:>12?}  ({} iters)",
            self.name, self.median, self.mean, self.min, self.iters
        )
    }
}

/// Run `f` repeatedly: a warmup pass, then up to `max_iters` timed passes
/// or until `budget` elapses, whichever first. Returns robust stats.
pub fn bench<F: FnMut()>(name: &str, max_iters: usize, budget: Duration, mut f: F) -> BenchStats {
    f(); // warmup
    let mut samples = Vec::new();
    let start = Instant::now();
    for _ in 0..max_iters.max(1) {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
        if start.elapsed() > budget {
            break;
        }
    }
    samples.sort();
    let n = samples.len();
    let mean = samples.iter().sum::<Duration>() / n as u32;
    BenchStats {
        name: name.to_string(),
        iters: n,
        median: samples[n / 2],
        mean,
        min: samples[0],
        max: samples[n - 1],
    }
}

/// Guard against the optimizer deleting benched work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_stats() {
        let mut acc = 0u64;
        let s = bench("spin", 16, Duration::from_millis(200), || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
        });
        assert!(s.iters >= 1);
        assert!(s.min <= s.median && s.median <= s.max);
        assert!(s.throughput_per_sec() > 0.0);
    }
}
