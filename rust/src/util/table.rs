//! Aligned text tables + CSV output for experiment reports.

/// A simple column-aligned table with a title and footnotes.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub notes: Vec<String>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            ..Default::default()
        }
    }

    pub fn row<S: ToString>(&mut self, cells: Vec<S>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.into_iter().map(|c| c.to_string()).collect());
    }

    pub fn note(&mut self, s: &str) {
        self.notes.push(s.to_string());
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut s = format!("## {}\n", self.title);
        s.push_str(&line(&self.headers));
        s.push('\n');
        s.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        s.push('\n');
        for r in &self.rows {
            s.push_str(&line(r));
            s.push('\n');
        }
        for n in &self.notes {
            s.push_str(&format!("  note: {n}\n"));
        }
        s
    }

    /// CSV form (for plotting).
    pub fn to_csv(&self) -> String {
        let mut s = self.headers.join(",");
        s.push('\n');
        for r in &self.rows {
            s.push_str(&r.join(","));
            s.push('\n');
        }
        s
    }
}

/// Format a float compactly.
pub fn fmt(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 1000.0 {
        format!("{v:.0}")
    } else if v.abs() >= 10.0 {
        format!("{v:.1}")
    } else {
        format!("{v:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(vec!["a", "1"]);
        t.row(vec!["long-name", "22"]);
        t.note("hello");
        let r = t.render();
        assert!(r.contains("## demo"));
        assert!(r.contains("note: hello"));
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines[1].len(), lines[3].len());
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn wrong_width_rejected() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = Table::new("d", &["x", "y"]);
        t.row(vec!["1", "2"]);
        assert_eq!(t.to_csv(), "x,y\n1,2\n");
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt(0.0), "0");
        assert_eq!(fmt(1234.6), "1235");
        assert_eq!(fmt(12.34), "12.3");
        assert_eq!(fmt(1.234), "1.234");
    }
}
