//! Bitstream generation (Fig. 2: "configuration bitstream").
//!
//! A routed application determines, for every mux the route trees pass
//! through, which input the mux must select; the select values are packed
//! into per-tile 32-bit configuration words using the address map from
//! [`crate::hw::config`]. The bitstream is the sorted list of
//! `(tile_x, tile_y, word) -> value` writes, serializable to the classic
//! `ADDR DATA` hex format.

use std::collections::{BTreeMap, HashMap};

use crate::hw::config::ConfigSpace;
use crate::ir::{Interconnect, NodeId};
use crate::pnr::RoutingResult;

/// Abstract configuration: chosen select per mux node (per bit-width
/// layer), and mode per register node. This is what the simulator
/// executes; the bitstream is its packed encoding.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Configuration {
    /// `(bit_width, node) -> mux select`.
    pub selects: HashMap<(u8, NodeId), u32>,
    /// `(bit_width, node) -> register mode` (0 pipeline / 1 head / 2 tail).
    pub reg_modes: HashMap<(u8, NodeId), u32>,
}

impl Configuration {
    /// Derive the configuration implied by a routing result on one layer.
    ///
    /// Every consecutive pair `(a, b)` on a sink path with `fan_in(b) > 1`
    /// pins `b`'s mux to select `a`. Conflicting requirements (two nets
    /// demanding different selects on one mux) are impossible for
    /// node-disjoint routings and are reported as errors.
    ///
    /// Runs on the frozen CSR graph; its fan-in CSR preserves the builder
    /// graph's insertion order, so selects (and thus bitstreams) are
    /// bit-identical to ones derived from the builder graph.
    pub fn from_routing(
        ic: &Interconnect,
        bit_width: u8,
        routing: &RoutingResult,
    ) -> Result<Configuration, String> {
        let g = ic.compiled(bit_width);
        let names = ic.graph(bit_width);
        let mut cfg = Configuration::default();
        for tree in &routing.trees {
            for path in &tree.sink_paths {
                for w in path.windows(2) {
                    let (a, b) = (w[0], w[1]);
                    if g.fan_in(b).len() > 1 {
                        let sel = g
                            .select_of(b, a)
                            .ok_or_else(|| {
                                format!(
                                    "route uses non-edge {} -> {}",
                                    names.node(a).qualified_name(),
                                    names.node(b).qualified_name()
                                )
                            })? as u32;
                        match cfg.selects.get(&(bit_width, b)) {
                            Some(&prev) if prev != sel => {
                                return Err(format!(
                                    "conflicting selects on {}: {prev} vs {sel}",
                                    names.node(b).qualified_name()
                                ));
                            }
                            _ => {
                                cfg.selects.insert((bit_width, b), sel);
                            }
                        }
                    }
                    // Routes through a register node pin its mode to
                    // pipeline (static flow) — RV flows override later.
                    if g.is_register(b) {
                        cfg.reg_modes.insert((bit_width, b), 0);
                    }
                }
            }
        }
        Ok(cfg)
    }
}

/// A packed bitstream: per-(tile, word) 32-bit values.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Bitstream {
    /// `(x, y, word) -> value`, sorted for deterministic output.
    pub words: BTreeMap<(u16, u16, u32), u32>,
}

impl Bitstream {
    /// Number of configuration writes.
    pub fn len(&self) -> usize {
        self.words.len()
    }

    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    /// Serialize as `XX YY WW VVVVVVVV` hex lines (one write per line).
    pub fn to_text(&self) -> String {
        let mut s = String::new();
        for (&(x, y, w), &v) in &self.words {
            s.push_str(&format!("{x:02x} {y:02x} {w:02x} {v:08x}\n"));
        }
        s
    }

    /// Parse the textual format.
    pub fn from_text(text: &str) -> Result<Bitstream, String> {
        let mut b = Bitstream::default();
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let f: Vec<&str> = line.split_whitespace().collect();
            if f.len() != 4 {
                return Err(format!("line {}: expected 4 fields", i + 1));
            }
            let x = u16::from_str_radix(f[0], 16).map_err(|e| format!("line {}: {e}", i + 1))?;
            let y = u16::from_str_radix(f[1], 16).map_err(|e| format!("line {}: {e}", i + 1))?;
            let w = u32::from_str_radix(f[2], 16).map_err(|e| format!("line {}: {e}", i + 1))?;
            let v = u32::from_str_radix(f[3], 16).map_err(|e| format!("line {}: {e}", i + 1))?;
            b.words.insert((x, y, w), v);
        }
        Ok(b)
    }
}

/// Pack a configuration into a bitstream using the config-space address
/// map. Unset fields default to 0.
pub fn encode(cfg: &Configuration, cs: &ConfigSpace) -> Bitstream {
    let mut b = Bitstream::default();
    for (&(bw, node), &sel) in &cfg.selects {
        let f = cs
            .mux_field(bw, node)
            .unwrap_or_else(|| panic!("no field for mux {node} (width {bw})"));
        let entry = b.words.entry((f.x, f.y, f.word)).or_insert(0);
        *entry = (*entry & !f.mask()) | f.encode(sel);
    }
    for (&(bw, node), &mode) in &cfg.reg_modes {
        let f = cs
            .reg_field(bw, node)
            .unwrap_or_else(|| panic!("no field for register {node}"));
        let entry = b.words.entry((f.x, f.y, f.word)).or_insert(0);
        *entry = (*entry & !f.mask()) | f.encode(mode);
    }
    b
}

/// Decode a bitstream back into an abstract configuration (the inverse of
/// [`encode`] for every allocated field).
pub fn decode(b: &Bitstream, cs: &ConfigSpace) -> Configuration {
    use crate::hw::config::FieldRole;
    let mut cfg = Configuration::default();
    for (role, f) in cs.fields() {
        let word = b.words.get(&(f.x, f.y, f.word)).copied().unwrap_or(0);
        let val = (word & f.mask()) >> f.offset;
        match role {
            FieldRole::MuxSelect { bit_width, node } => {
                if val != 0 || b.words.contains_key(&(f.x, f.y, f.word)) {
                    cfg.selects.insert((*bit_width, *node), val);
                }
            }
            FieldRole::RegisterMode { bit_width, node } => {
                if b.words.contains_key(&(f.x, f.y, f.word)) {
                    cfg.reg_modes.insert((*bit_width, *node), val);
                }
            }
        }
    }
    cfg
}

/// Disassemble a bitstream into a human-readable per-tile listing:
/// every configured mux shows which driver it selects, every register its
/// mode. The inverse direction of Fig. 2's bitstream arrow — used for
/// debugging configurations and in the sweep tests' failure reports.
///
/// Writes are word-granular, so every field of a written word decodes —
/// fields the router never touched read back as select 0 (their reset
/// value); the listing is therefore a superset of the explicit config.
pub fn disassemble(b: &Bitstream, cs: &ConfigSpace, ic: &Interconnect) -> String {
    let cfg = decode(b, cs);
    let mut lines: Vec<String> = Vec::new();
    for (&(bw, node), &sel) in &cfg.selects {
        let g = ic.graph(bw);
        let n = g.node(node);
        let driver = g
            .fan_in(node)
            .get(sel as usize)
            .map(|&d| g.node(d).qualified_name())
            .unwrap_or_else(|| format!("<invalid select {sel}>"));
        lines.push(format!(
            "({:>2},{:>2}) w{bw} {} <= {}",
            n.x,
            n.y,
            n.kind.label(),
            driver
        ));
    }
    for (&(bw, node), &mode) in &cfg.reg_modes {
        let g = ic.graph(bw);
        let n = g.node(node);
        let mode_name = match mode {
            0 => "pipeline",
            1 => "fifo-head",
            2 => "fifo-tail",
            _ => "unknown",
        };
        lines.push(format!("({:>2},{:>2}) w{bw} {} mode={mode_name}", n.x, n.y, n.kind.label()));
    }
    lines.sort();
    lines.join("\n")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::dsl::{create_uniform_interconnect, InterconnectConfig};
    use crate::hw::config::allocate;
    use crate::pnr::{run_flow, FlowParams, SaParams};

    fn flow() -> (Interconnect, RoutingResult) {
        let ic = create_uniform_interconnect(&InterconnectConfig {
            width: 8,
            height: 8,
            num_tracks: 4,
            mem_column_period: 3,
            ..Default::default()
        });
        let params = FlowParams {
            sa: SaParams { moves_per_node: 8, ..Default::default() },
            ..Default::default()
        };
        let r = run_flow(&ic, &apps::gaussian(), &params).unwrap();
        (ic, r.routing)
    }

    #[test]
    fn routing_to_configuration_no_conflicts() {
        let (ic, routing) = flow();
        let cfg = Configuration::from_routing(&ic, 16, &routing).unwrap();
        assert!(!cfg.selects.is_empty());
        // Every select is within its mux's fan-in range.
        let g = ic.graph(16);
        for (&(_, node), &sel) in &cfg.selects {
            assert!((sel as usize) < g.fan_in(node).len());
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let (ic, routing) = flow();
        let cs = allocate(&ic);
        let cfg = Configuration::from_routing(&ic, 16, &routing).unwrap();
        let bits = encode(&cfg, &cs);
        let back = decode(&bits, &cs);
        // Every select survives the round trip.
        for (k, v) in &cfg.selects {
            assert_eq!(back.selects.get(k), Some(v), "select lost for {k:?}");
        }
    }

    #[test]
    fn text_roundtrip() {
        let (ic, routing) = flow();
        let cs = allocate(&ic);
        let cfg = Configuration::from_routing(&ic, 16, &routing).unwrap();
        let bits = encode(&cfg, &cs);
        let text = bits.to_text();
        let parsed = Bitstream::from_text(&text).unwrap();
        assert_eq!(bits, parsed);
        assert!(Bitstream::from_text("zz yy").is_err());
    }

    #[test]
    fn disassembly_names_selected_drivers() {
        let (ic, routing) = flow();
        let cs = allocate(&ic);
        let cfg = Configuration::from_routing(&ic, 16, &routing).unwrap();
        let bits = encode(&cfg, &cs);
        let dis = disassemble(&bits, &cs, &ic);
        // Word-granular decode: at least every configured field appears.
        assert!(dis.lines().count() >= cfg.selects.len() + cfg.reg_modes.len());
        // Every configured mux line names a real driver (never the
        // invalid-select marker), and the route's CB selects appear.
        assert!(!dis.contains("<invalid"), "{dis}");
        assert!(dis.contains("port_in_"), "{dis}");
        assert!(dis.contains(" <= "));
    }

    #[test]
    fn bitstream_is_deterministic_and_sorted() {
        let (ic, routing) = flow();
        let cs = allocate(&ic);
        let cfg = Configuration::from_routing(&ic, 16, &routing).unwrap();
        let a = encode(&cfg, &cs).to_text();
        let b = encode(&cfg, &cs).to_text();
        assert_eq!(a, b);
        let lines: Vec<&str> = a.lines().collect();
        let mut sorted = lines.clone();
        sorted.sort();
        assert_eq!(lines, sorted);
    }
}
