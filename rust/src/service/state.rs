//! Process-wide session state of the Canal daemon: the assets every
//! connection shares, and the coalescing rules that keep concurrent
//! sessions from duplicating work.
//!
//! One [`SessionState`] owns, for the whole process:
//!
//! - an **LRU of frozen interconnects** ([`IcLru`]) keyed by
//!   `InterconnectConfig::descriptor()` — the expensive
//!   build-and-freeze (CSR [`crate::ir::CompiledGraph`]s) is paid once
//!   per configuration across *all* connections, not once per request;
//! - one **result cache** ([`ResultCache`]) with its backing file —
//!   every request partitions against it and feeds new results back,
//!   persisted after each request that computed anything and once more
//!   on shutdown;
//! - one **placement backend** — constructed once (the PJRT service
//!   thread, when available, is a process-wide singleton exactly like
//!   the one-shot CLI's);
//! - the **in-flight table**: `JobKey → cell` for every job some
//!   request is currently computing.
//!
//! ## Coalescing
//!
//! A `dse` request resolves each of its (deduplicated, canonically
//! ordered) jobs to one of three sources under a single lock:
//! *hit* (already cached), *join* (another request is computing it —
//! wait on its cell), or *mine* (claim it). Claimed jobs run through
//! [`crate::dse::execute_jobs`] — grouped per configuration and drained
//! through one batched placement solve per group, exactly like the
//! one-shot engine — then fill their cells and enter the cache. The
//! result: however many concurrent sessions ask for overlapping sweeps,
//! each `(config, app, seed)` point is placed-and-routed **at most
//! once** per daemon lifetime, and every session still receives points
//! bit-identical to a sequential `canal dse` run (same job keys, same
//! deterministic executor).
//!
//! If a computing request unwinds, its claims are released and the
//! cells are failed (never left pending), so joiners error out instead
//! of hanging.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

use crate::coordinator;
use crate::dse::{
    archive_path_for, area_points, execute_jobs_obs, publish_engine_stats, run_tune, DseEngine,
    EngineOptions, EngineStats, InterconnectSource, JobKey, ParetoArchive, PointResult,
    ResultCache, SweepOutcome, SweepProgress, SweepSpec, TuneOptions, TuneOutcome,
};
use crate::dsl::{create_uniform_interconnect, InterconnectConfig};
use crate::ir::Interconnect;
use crate::obs;
use crate::obs::span::names as spans;
use crate::obs::{MetricsHistory, ProgressSample};
use crate::pnr::GlobalPlacer;
use crate::util::json::Json;
use crate::util::table::Table;

/// Session-state tuning.
#[derive(Clone, Debug)]
pub struct StateOptions {
    /// Worker threads per request's cold execution; `0` ⇒ one per core.
    pub workers: usize,
    /// Result-cache backing file; `None` ⇒ in-memory only.
    pub cache_path: Option<PathBuf>,
    /// Frozen interconnects kept warm (LRU; at least 1).
    pub ic_capacity: usize,
}

impl Default for StateOptions {
    fn default() -> Self {
        StateOptions { workers: 0, cache_path: None, ic_capacity: 32 }
    }
}

/// LRU cache of frozen interconnects keyed by
/// `InterconnectConfig::descriptor()`. The build is a pure function of
/// the config, so serving a warm `Arc` is behaviorally identical to
/// rebuilding — only the freeze cost disappears. Doubles as the
/// executor's [`InterconnectSource`].
pub struct IcLru {
    inner: Mutex<IcLruInner>,
    hits: AtomicU64,
    builds: AtomicU64,
    evictions: AtomicU64,
}

struct IcLruInner {
    map: HashMap<String, (Arc<Interconnect>, u64)>,
    /// Monotonic access clock (recency stamp).
    tick: u64,
    capacity: usize,
}

impl IcLru {
    pub fn new(capacity: usize) -> IcLru {
        IcLru {
            inner: Mutex::new(IcLruInner {
                map: HashMap::new(),
                tick: 0,
                capacity: capacity.max(1),
            }),
            hits: AtomicU64::new(0),
            builds: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    pub fn len(&self) -> usize {
        lock_ignore_poison(&self.inner).map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn builds(&self) -> u64 {
        self.builds.load(Ordering::Relaxed)
    }

    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }
}

impl InterconnectSource for IcLru {
    fn interconnect(&self, cfg: &InterconnectConfig) -> (Arc<Interconnect>, bool) {
        let key = cfg.descriptor();
        {
            let mut inner = lock_ignore_poison(&self.inner);
            inner.tick += 1;
            let tick = inner.tick;
            if let Some((ic, last)) = inner.map.get_mut(&key) {
                *last = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                return (Arc::clone(ic), false);
            }
        }
        // Build outside the lock: freezing is the expensive part, and a
        // miss on config A must not serialize behind a build of config
        // B. Two requests racing on the same cold config may both
        // build; the loser's copy is dropped on insert (the builds are
        // identical — pure function of the config) and the executor's
        // per-run `OnceLock` makes the race rare in practice.
        let built = Arc::new(create_uniform_interconnect(cfg));
        self.builds.fetch_add(1, Ordering::Relaxed);
        let mut inner = lock_ignore_poison(&self.inner);
        inner.tick += 1;
        let tick = inner.tick;
        let ic = match inner.map.get_mut(&key) {
            Some((winner, last)) => {
                *last = tick;
                Arc::clone(winner)
            }
            None => {
                inner.map.insert(key, (Arc::clone(&built), tick));
                built
            }
        };
        while inner.map.len() > inner.capacity {
            // O(n) recency scan — capacities are tens, not thousands.
            let oldest =
                inner.map.iter().min_by_key(|(_, (_, t))| *t).map(|(k, _)| k.clone());
            match oldest {
                Some(k) => {
                    inner.map.remove(&k);
                    self.evictions.fetch_add(1, Ordering::Relaxed);
                }
                None => break,
            }
        }
        (ic, true)
    }
}

/// Cumulative daemon counters, exposed through the `stats` request.
/// Engine-shaped fields aggregate over every request served.
#[derive(Default)]
pub struct ServiceStats {
    pub connections: AtomicU64,
    pub requests: AtomicU64,
    pub errors: AtomicU64,
    pub dse_requests: AtomicU64,
    pub figure_requests: AtomicU64,
    pub tune_requests: AtomicU64,
    pub jobs: AtomicU64,
    pub cache_hits: AtomicU64,
    pub coalesced: AtomicU64,
    pub pnr_runs: AtomicU64,
    pub sims: AtomicU64,
    pub configs_built: AtomicU64,
    pub batched_solves: AtomicU64,
    pub steals: AtomicU64,
    pub warm_starts: AtomicU64,
    pub nets_reused: AtomicU64,
    pub nets_rerouted: AtomicU64,
    pub route_expansions: AtomicU64,
    pub flushes: AtomicU64,
}

impl ServiceStats {
    fn absorb_engine(&self, s: &EngineStats) {
        self.jobs.fetch_add(s.jobs, Ordering::Relaxed);
        self.cache_hits.fetch_add(s.cache_hits, Ordering::Relaxed);
        self.coalesced.fetch_add(s.coalesced, Ordering::Relaxed);
        self.pnr_runs.fetch_add(s.pnr_runs, Ordering::Relaxed);
        self.sims.fetch_add(s.sims, Ordering::Relaxed);
        self.configs_built.fetch_add(s.configs_built, Ordering::Relaxed);
        self.batched_solves.fetch_add(s.batched_solves, Ordering::Relaxed);
        self.steals.fetch_add(s.steals, Ordering::Relaxed);
        self.warm_starts.fetch_add(s.warm_starts, Ordering::Relaxed);
        self.nets_reused.fetch_add(s.nets_reused, Ordering::Relaxed);
        self.nets_rerouted.fetch_add(s.nets_rerouted, Ordering::Relaxed);
        self.route_expansions.fetch_add(s.route_expansions, Ordering::Relaxed);
    }
}

/// A coalescing cell: one in-flight job's eventual result, waited on by
/// every request that joined it.
struct JobCell {
    state: Mutex<CellState>,
    cv: Condvar,
}

enum CellState {
    Pending,
    Done(PointResult),
    Failed(String),
}

impl JobCell {
    fn new() -> JobCell {
        JobCell { state: Mutex::new(CellState::Pending), cv: Condvar::new() }
    }

    fn fill(&self, outcome: Result<PointResult, String>) {
        let mut s = lock_ignore_poison(&self.state);
        *s = match outcome {
            Ok(r) => CellState::Done(r),
            Err(e) => CellState::Failed(e),
        };
        self.cv.notify_all();
    }

    fn wait(&self) -> Result<PointResult, String> {
        let mut s = lock_ignore_poison(&self.state);
        loop {
            match &*s {
                CellState::Pending => {
                    s = self
                        .cv
                        .wait(s)
                        .unwrap_or_else(std::sync::PoisonError::into_inner);
                }
                CellState::Done(r) => return Ok(r.clone()),
                CellState::Failed(e) => return Err(e.clone()),
            }
        }
    }
}

/// The cache and the in-flight table live under ONE lock: a request's
/// hit/join/claim partition must be atomic, or two requests could both
/// claim (or both miss) the same job.
struct SharedDse {
    cache: ResultCache,
    inflight: HashMap<JobKey, Arc<JobCell>>,
}

/// A mutex whose poison flag we deliberately ignore: every critical
/// section here leaves the data consistent at each statement (maps and
/// counters), and a daemon must keep serving other sessions after one
/// request thread panics.
fn lock_ignore_poison<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Releases a request's claims if its cold execution unwinds: removes
/// them from the in-flight table and fails their cells so joiners
/// error out instead of waiting forever.
struct ClaimGuard<'a> {
    shared: &'a Mutex<SharedDse>,
    claims: Vec<(JobKey, Arc<JobCell>)>,
    armed: bool,
}

impl ClaimGuard<'_> {
    fn defuse(mut self) {
        self.armed = false;
    }
}

impl Drop for ClaimGuard<'_> {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        let mut shared = lock_ignore_poison(self.shared);
        for (key, cell) in &self.claims {
            shared.inflight.remove(key);
            cell.fill(Err("in-flight computation aborted".into()));
        }
    }
}

/// The daemon's shared session state. See the module docs for what it
/// owns and the coalescing contract.
pub struct SessionState {
    opts: StateOptions,
    placer: Box<dyn GlobalPlacer + Sync + Send>,
    shared: Mutex<SharedDse>,
    ics: IcLru,
    stats: ServiceStats,
    /// Serializes cache-file writers among themselves (never held
    /// together with `shared` during I/O — see [`Self::flush`]).
    flush_lock: Mutex<()>,
    /// Serializes `tune` requests among themselves: each tune is a
    /// read-merge-write transaction on the Pareto archive file, and two
    /// interleaved transactions could silently drop each other's
    /// incumbents. Held across the whole search — the underlying
    /// single-point evaluations still coalesce with concurrent `dse`
    /// requests through [`Self::run_dse`]'s shared path, so this costs
    /// nothing but archive consistency.
    tune_lock: Mutex<()>,
    /// The dashboard's data source: a fixed-capacity ring of
    /// timestamped metrics-registry samples, fed by the server's
    /// background sampler thread and drained by `history`/`watch`
    /// requests and `GET /dash`.
    history: Arc<MetricsHistory>,
    /// The sweep the sampler snapshots alongside each sample, if one is
    /// live. Requests register their [`SweepProgress`] here for the
    /// duration of a sweep (last writer wins when requests overlap —
    /// the dashboard shows *a* live sweep, the trace files show all).
    live_progress: Mutex<Option<Arc<SweepProgress>>>,
}

/// Clears a request's [`SweepProgress`] out of the live slot when the
/// request finishes — but only if the slot still holds *this* request's
/// tracker, so a concurrent request that registered later keeps its
/// registration when an earlier one unwinds.
pub struct LiveProgressGuard<'a> {
    state: &'a SessionState,
    progress: Arc<SweepProgress>,
}

impl Drop for LiveProgressGuard<'_> {
    fn drop(&mut self) {
        let mut slot = lock_ignore_poison(&self.state.live_progress);
        if slot.as_ref().is_some_and(|p| Arc::ptr_eq(p, &self.progress)) {
            *slot = None;
        }
    }
}

impl SessionState {
    /// State with the best available placement backend (same selection
    /// as the one-shot CLI: PJRT artifact when present, batched native
    /// otherwise).
    pub fn new(opts: StateOptions) -> Result<SessionState, String> {
        let placer = coordinator::default_placer();
        SessionState::with_placer(opts, placer)
    }

    /// State over an explicit backend (tests pin the native solver so
    /// daemon results compare against in-process references).
    pub fn with_placer(
        opts: StateOptions,
        placer: Box<dyn GlobalPlacer + Sync + Send>,
    ) -> Result<SessionState, String> {
        let cache = match &opts.cache_path {
            Some(path) => ResultCache::at(path)?,
            None => ResultCache::in_memory(),
        };
        let ic_capacity = opts.ic_capacity;
        Ok(SessionState {
            opts,
            placer,
            shared: Mutex::new(SharedDse { cache, inflight: HashMap::new() }),
            ics: IcLru::new(ic_capacity),
            stats: ServiceStats::default(),
            flush_lock: Mutex::new(()),
            tune_lock: Mutex::new(()),
            history: Arc::new(MetricsHistory::with_defaults()),
            live_progress: Mutex::new(None),
        })
    }

    /// Cache identity of the placement backend every request solves on.
    pub fn placer_name(&self) -> &'static str {
        self.placer.name()
    }

    pub fn stats(&self) -> &ServiceStats {
        &self.stats
    }

    pub fn ic_lru(&self) -> &IcLru {
        &self.ics
    }

    /// The dashboard's time-series ring (shared with the sampler thread).
    pub fn history(&self) -> &Arc<MetricsHistory> {
        &self.history
    }

    /// Register `progress` as the live sweep the sampler snapshots.
    /// The returned guard clears the slot on drop — but only if this
    /// registration is still the current one (`Arc::ptr_eq`), so
    /// overlapping requests never clear each other's trackers.
    pub fn track_progress(&self, progress: Arc<SweepProgress>) -> LiveProgressGuard<'_> {
        *lock_ignore_poison(&self.live_progress) = Some(Arc::clone(&progress));
        LiveProgressGuard { state: self, progress }
    }

    /// One point-in-time view of the live sweep, shaped for the history
    /// ring (`None` when no sweep is running). Utilization is rendered
    /// down to whole percent per worker — the ring stores thousands of
    /// samples, so a `u8` per worker keeps it cheap.
    pub fn progress_sample(&self) -> Option<ProgressSample> {
        let progress = lock_ignore_poison(&self.live_progress).clone()?;
        let snap = progress.snapshot();
        let elapsed = snap.elapsed_ns.max(1);
        let worker_util_pct = snap
            .worker_busy_ns
            .iter()
            .map(|&busy| (busy.saturating_mul(100) / elapsed).min(100) as u8)
            .collect();
        Some(ProgressSample {
            jobs_total: snap.jobs_total,
            jobs_done: snap.jobs_done,
            cache_hits: snap.cache_hits,
            coalesced: snap.coalesced,
            cold_total: snap.cold_total,
            cold_done: snap.cold_done,
            warm_starts: snap.warm_starts,
            worker_util_pct,
        })
    }

    /// The Pareto-archive document served at `GET /archive.json`: the
    /// archive file next to the result cache, read as-is. Deliberately
    /// *not* [`ParetoArchive::at`] — that constructor creates the file
    /// as a side effect, and a read-only endpoint must not write. An
    /// in-memory daemon (or a daemon that has never tuned) serves an
    /// empty document of the same shape.
    pub fn archive_json(&self) -> Json {
        let empty = || {
            Json::Obj(vec![
                ("version".into(), Json::num_u64(1)),
                ("entries".into(), Json::Arr(vec![])),
            ])
        };
        let Some(cache) = &self.opts.cache_path else {
            return empty();
        };
        match std::fs::read_to_string(archive_path_for(cache)) {
            Ok(text) => Json::parse(&text).unwrap_or_else(|_| empty()),
            Err(_) => empty(),
        }
    }

    pub fn cache_len(&self) -> usize {
        lock_ignore_poison(&self.shared).cache.len()
    }

    /// Persist the shared cache (no-op when in-memory). The request
    /// lock is held only for a cheap map snapshot; serialization and
    /// file I/O happen outside it (writers serialize among themselves
    /// on `flush_lock`, so concurrent flushes cannot interleave on the
    /// temp file), keeping concurrent sessions off the disk's latency.
    pub fn flush(&self) -> Result<(), String> {
        let _writer = lock_ignore_poison(&self.flush_lock);
        let (snapshot, path) = {
            let shared = lock_ignore_poison(&self.shared);
            (shared.cache.snapshot(), shared.cache.path().map(std::path::Path::to_path_buf))
        };
        if let Some(path) = &path {
            snapshot.save_to(path)?;
        }
        self.stats.flushes.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Run one sweep through the shared state. Jobs resolve to cache
    /// hits, joins on other requests' in-flight cells, or claims this
    /// request computes; the outcome is indistinguishable from
    /// [`DseEngine::run`] on a same-temperature cache — canonical
    /// order, bit-identical points — with `stats.coalesced` counting
    /// the joins.
    pub fn run_dse(&self, spec: &SweepSpec) -> Result<SweepOutcome, String> {
        self.run_dse_with_progress(spec, None)
    }

    /// [`Self::run_dse`], optionally ticking a live [`SweepProgress`]
    /// the server's heartbeat thread renders into progress frames.
    /// `progress` is written, never read — passing `None` computes the
    /// same bits.
    pub fn run_dse_with_progress(
        &self,
        spec: &SweepSpec,
        progress: Option<&SweepProgress>,
    ) -> Result<SweepOutcome, String> {
        self.stats.dse_requests.fetch_add(1, Ordering::Relaxed);
        self.run_dse_inner(spec, progress)
    }

    /// The sweep body without the `dse_requests` bump: `tune` issues
    /// many one-candidate sweeps through this same hit/join/claim path
    /// (so its points warm, and are warmed by, every other session),
    /// but the daemon's request counters must say "one tune", not
    /// "N dse requests".
    fn run_dse_inner(
        &self,
        spec: &SweepSpec,
        progress: Option<&SweepProgress>,
    ) -> Result<SweepOutcome, String> {
        let jobs = spec.jobs(self.placer.name())?;
        let mut stats = EngineStats { jobs: jobs.len() as u64, ..Default::default() };

        enum Source {
            Hit(PointResult),
            Join(Arc<JobCell>),
            Mine(usize),
        }

        let mut sources: Vec<Source> = Vec::with_capacity(jobs.len());
        let mut claimed: Vec<&crate::dse::Job> = Vec::new();
        let mut claimed_cells: Vec<Arc<JobCell>> = Vec::new();
        {
            let mut shared = lock_ignore_poison(&self.shared);
            for (idx, job) in jobs.iter().enumerate() {
                if let Some(r) = shared.cache.get(&job.key) {
                    stats.cache_hits += 1;
                    obs::event(spans::DSE_HIT, idx as u64, 0);
                    sources.push(Source::Hit(r.clone()));
                } else if let Some(cell) = shared.inflight.get(&job.key) {
                    stats.coalesced += 1;
                    obs::event(spans::DSE_JOIN, idx as u64, 0);
                    sources.push(Source::Join(Arc::clone(cell)));
                } else {
                    let cell = Arc::new(JobCell::new());
                    shared.inflight.insert(job.key.clone(), Arc::clone(&cell));
                    obs::event(spans::DSE_CLAIM, idx as u64, 0);
                    sources.push(Source::Mine(claimed.len()));
                    claimed.push(job);
                    claimed_cells.push(cell);
                }
            }
        }
        if let Some(p) = progress {
            p.begin(jobs.len() as u64, stats.cache_hits, stats.coalesced);
        }

        let guard = ClaimGuard {
            shared: &self.shared,
            claims: claimed
                .iter()
                .map(|j| j.key.clone())
                .zip(claimed_cells.iter().map(Arc::clone))
                .collect(),
            armed: true,
        };

        let cold = execute_jobs_obs(
            &claimed,
            self.opts.workers,
            self.placer.as_ref(),
            &self.ics,
            None,
            progress,
        );
        stats.absorb(&cold.stats);

        {
            let mut shared = lock_ignore_poison(&self.shared);
            for ((job, cell), result) in
                claimed.iter().zip(&claimed_cells).zip(&cold.results)
            {
                shared.cache.insert(job.key.clone(), result.clone());
                shared.inflight.remove(&job.key);
                cell.fill(Ok(result.clone()));
            }
        }
        guard.defuse();
        if cold.stats.pnr_runs > 0 {
            self.flush()?;
        }

        let areas =
            if spec.area { area_points(spec, &cold.interconnects, &self.ics)? } else { vec![] };

        // Assemble in canonical order. Joins block here — outside every
        // lock — until the computing request fills their cells.
        drop(claimed);
        let mut points = Vec::with_capacity(jobs.len());
        for (job, src) in jobs.into_iter().zip(sources) {
            let r = match src {
                Source::Hit(r) => r,
                Source::Mine(i) => cold.results[i].clone(),
                Source::Join(cell) => cell
                    .wait()
                    .map_err(|e| format!("coalesced job failed in another session: {e}"))?,
            };
            points.push((job, r));
        }

        self.stats.absorb_engine(&stats);
        if obs::metrics_on() {
            publish_engine_stats(&stats);
        }
        Ok(SweepOutcome { name: spec.name.clone(), points, areas, stats })
    }

    /// Run one Pareto-autotuner search ([`crate::dse::run_tune`])
    /// through the shared state. Every real evaluation is a
    /// one-candidate spec routed through [`Self::run_dse`]'s
    /// hit/join/claim partition, so tune points coalesce with (and
    /// warm) concurrent `dse` sweeps of overlapping specs. The archive
    /// lives next to the shared result cache
    /// ([`crate::dse::archive_path_for`]) when the daemon is
    /// file-backed, and is per-request in-memory otherwise; tune
    /// requests serialize among themselves on [`Self::tune_lock`].
    pub fn run_tune(
        &self,
        spec: &SweepSpec,
        opts: &TuneOptions,
    ) -> Result<TuneOutcome, String> {
        self.run_tune_with_progress(spec, opts, None)
    }

    /// [`Self::run_tune`] with a live [`SweepProgress`]: each
    /// single-point evaluation re-`begin`s the tracker, so the
    /// heartbeat renders per-evaluation progress rather than a global
    /// fraction (the search's total is unknowable up front — that is
    /// the point of searching).
    pub fn run_tune_with_progress(
        &self,
        spec: &SweepSpec,
        opts: &TuneOptions,
        progress: Option<&SweepProgress>,
    ) -> Result<TuneOutcome, String> {
        self.stats.tune_requests.fetch_add(1, Ordering::Relaxed);
        let _tune = lock_ignore_poison(&self.tune_lock);
        let mut archive = match &self.opts.cache_path {
            Some(path) => ParetoArchive::at(&archive_path_for(path))?,
            None => ParetoArchive::in_memory(),
        };
        run_tune(
            spec,
            self.placer.name(),
            &self.ics,
            &mut archive,
            opts,
            &mut |s| self.run_dse_inner(s, progress),
        )
    }

    /// Regenerate one engine-backed paper figure against the shared
    /// cache: the figure drivers take a `&mut DseEngine`, so the run
    /// happens on a snapshot-backed engine and new entries merge back
    /// afterwards. Figure requests coalesce with concurrent work only
    /// through the warm cache (no in-flight joining) — a deliberate
    /// simplification documented in `docs/service.md`.
    pub fn run_figure(
        &self,
        which: &str,
        sa_moves: usize,
    ) -> Result<(Table, EngineStats), String> {
        self.stats.figure_requests.fetch_add(1, Ordering::Relaxed);
        let o = coordinator::ExpOptions { sa_moves, ..Default::default() };
        let snapshot = lock_ignore_poison(&self.shared).cache.snapshot();
        let mut engine = DseEngine::with_cache(
            EngineOptions { workers: self.opts.workers, cache_path: None, warm_start: false },
            snapshot,
        );
        let placer: &(dyn GlobalPlacer + Sync) = self.placer.as_ref();
        let table = match which {
            "fig7" | "fig07" => coordinator::fig07_hybrid_throughput_with(&o, placer, &mut engine),
            "fig8" | "fig08" => coordinator::fig08_fifo_area_with(&mut engine),
            "fig9" | "fig09" => coordinator::fig09_topology_with(&o, &mut engine),
            "fig10" => coordinator::fig10_area_tracks_with(&mut engine),
            "fig11" => coordinator::fig11_runtime_tracks_with(&o, placer, &mut engine),
            "fig14" => coordinator::fig14_sb_ports_runtime_with(&o, placer, &mut engine),
            "fig15" => coordinator::fig15_cb_ports_runtime_with(&o, placer, &mut engine),
            other => {
                return Err(format!(
                    "unknown figure `{other}` (fig7|fig8|fig9|fig10|fig11|fig14|fig15)"
                ))
            }
        };
        let stats = engine.lifetime_stats().clone();
        {
            let mut shared = lock_ignore_poison(&self.shared);
            for (k, r) in engine.cache().iter() {
                if !shared.cache.contains(k) {
                    shared.cache.insert(k.clone(), r.clone());
                }
            }
        }
        if stats.pnr_runs > 0 {
            self.flush()?;
        }
        self.stats.absorb_engine(&stats);
        Ok((table, stats))
    }

    /// The `stats` response body: cumulative counters plus current
    /// occupancy of both shared caches.
    pub fn stats_json(&self) -> Json {
        let s = &self.stats;
        let get = |a: &AtomicU64| Json::num_u64(a.load(Ordering::Relaxed));
        Json::Obj(vec![
            ("connections".into(), get(&s.connections)),
            ("requests".into(), get(&s.requests)),
            ("errors".into(), get(&s.errors)),
            ("dse_requests".into(), get(&s.dse_requests)),
            ("figure_requests".into(), get(&s.figure_requests)),
            ("tune_requests".into(), get(&s.tune_requests)),
            ("jobs".into(), get(&s.jobs)),
            ("cache_hits".into(), get(&s.cache_hits)),
            ("coalesced".into(), get(&s.coalesced)),
            ("pnr_runs".into(), get(&s.pnr_runs)),
            ("sims".into(), get(&s.sims)),
            ("configs_built".into(), get(&s.configs_built)),
            ("batched_solves".into(), get(&s.batched_solves)),
            ("steals".into(), get(&s.steals)),
            ("warm_starts".into(), get(&s.warm_starts)),
            ("nets_reused".into(), get(&s.nets_reused)),
            ("nets_rerouted".into(), get(&s.nets_rerouted)),
            ("route_expansions".into(), get(&s.route_expansions)),
            ("flushes".into(), get(&s.flushes)),
            ("cache_entries".into(), Json::num_u64(self.cache_len() as u64)),
            ("interconnects_cached".into(), Json::num_u64(self.ics.len() as u64)),
            ("ic_hits".into(), Json::num_u64(self.ics.hits())),
            ("ic_builds".into(), Json::num_u64(self.ics.builds())),
            ("ic_evictions".into(), Json::num_u64(self.ics.evictions())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pnr::{BatchedNativePlacer, FlowParams, NativePlacer, SaParams};

    fn tiny_spec(name: &str) -> SweepSpec {
        SweepSpec {
            name: name.into(),
            base: InterconnectConfig {
                width: 4,
                height: 4,
                mem_column_period: 3,
                ..Default::default()
            },
            tracks: vec![2, 3],
            apps: vec!["pointwise4".into()],
            seeds: vec![1],
            flow: FlowParams {
                sa: SaParams { moves_per_node: 4, ..Default::default() },
                ..Default::default()
            },
            ..Default::default()
        }
    }

    fn state() -> SessionState {
        SessionState::with_placer(
            StateOptions { workers: 2, ..Default::default() },
            Box::new(BatchedNativePlacer::default()),
        )
        .unwrap()
    }

    #[test]
    fn ic_lru_shares_warm_graphs_and_evicts_least_recent() {
        let lru = IcLru::new(2);
        let cfg = |tracks| InterconnectConfig {
            width: 4,
            height: 4,
            num_tracks: tracks,
            mem_column_period: 0,
            ..Default::default()
        };
        let (a1, built) = lru.interconnect(&cfg(2));
        assert!(built);
        let (a2, built) = lru.interconnect(&cfg(2));
        assert!(!built, "second request must be a warm serve");
        assert!(Arc::ptr_eq(&a1, &a2), "warm serves share the frozen Arc");
        lru.interconnect(&cfg(3));
        // Touch tracks=2 so tracks=3 is the eviction victim.
        lru.interconnect(&cfg(2));
        lru.interconnect(&cfg(4));
        assert_eq!(lru.len(), 2);
        assert_eq!(lru.evictions(), 1);
        let (_, built) = lru.interconnect(&cfg(2));
        assert!(!built, "recently-used entry must have survived");
        let (_, built) = lru.interconnect(&cfg(3));
        assert!(built, "least-recently-used entry was evicted");
        assert_eq!(lru.builds(), 4);
        assert!(lru.hits() >= 3);
    }

    #[test]
    fn run_dse_matches_engine_and_second_pass_is_all_hits() {
        let st = state();
        let spec = tiny_spec("state-test");
        let cold = st.run_dse(&spec).unwrap();
        assert_eq!(cold.stats.pnr_runs, 2);
        assert_eq!(cold.stats.coalesced, 0);
        let warm = st.run_dse(&spec).unwrap();
        assert_eq!(warm.stats.pnr_runs, 0);
        assert_eq!(warm.stats.sims, 0);
        assert_eq!(warm.stats.cache_hits, 2);
        // Reference: the one-shot engine on the same spec and backend.
        let mut engine = DseEngine::in_memory();
        let reference = engine.run(&spec, &BatchedNativePlacer::default()).unwrap();
        for ((ja, ra), (jb, rb)) in reference.points.iter().zip(&warm.points) {
            assert_eq!(ja.key, jb.key);
            assert_eq!(ra, rb);
        }
        assert_eq!(st.stats.pnr_runs.load(Ordering::Relaxed), 2);
        assert_eq!(st.stats.dse_requests.load(Ordering::Relaxed), 2);
        // The frozen interconnects stayed warm in the LRU.
        assert_eq!(st.ic_lru().len(), 2);
    }

    #[test]
    fn concurrent_overlapping_requests_never_duplicate_pnr() {
        let st = state();
        let spec = tiny_spec("coalesce-test");
        let barrier = std::sync::Barrier::new(4);
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let (st, spec, barrier) = (&st, &spec, &barrier);
                scope.spawn(move || {
                    barrier.wait();
                    let out = st.run_dse(spec).unwrap();
                    assert_eq!(out.points.len(), 2);
                    for (_, r) in &out.points {
                        assert!(r.routed);
                    }
                    out
                });
            }
        });
        // However the four requests interleaved — all coalesced, all
        // raced to claim, or fully serialized — each unique job was
        // computed exactly once.
        assert_eq!(st.stats.pnr_runs.load(Ordering::Relaxed), 2);
        assert_eq!(st.stats.sims.load(Ordering::Relaxed), 2);
        assert_eq!(
            st.stats.cache_hits.load(Ordering::Relaxed)
                + st.stats.coalesced.load(Ordering::Relaxed),
            4 * 2 - 2
        );
        // And a straggler sees plain cache hits.
        let warm = st.run_dse(&spec).unwrap();
        assert_eq!(warm.stats.cache_hits, 2);
        let mut engine = DseEngine::in_memory();
        let reference = engine.run(&spec, &NativePlacer::default()).unwrap();
        for ((ja, ra), (jb, rb)) in reference.points.iter().zip(&warm.points) {
            assert_eq!(ja.key, jb.key);
            assert_eq!(ra, rb, "coalesced daemon results must match the sequential engine");
        }
    }

    #[test]
    fn area_requests_reuse_the_lru_and_run_no_pnr() {
        let st = state();
        let spec = SweepSpec { area: true, apps: vec![], ..tiny_spec("area") };
        let out = st.run_dse(&spec).unwrap();
        assert_eq!(out.stats.pnr_runs, 0);
        assert_eq!(out.areas.len(), 2);
        assert_eq!(st.ic_lru().builds(), 2);
        let again = st.run_dse(&spec).unwrap();
        assert_eq!(again.areas, out.areas);
        assert_eq!(st.ic_lru().builds(), 2, "area re-run must serve warm interconnects");
    }

    #[test]
    fn tune_requests_coalesce_through_the_shared_cache() {
        let st = state();
        let spec = SweepSpec { seeds: vec![1, 2], ..tiny_spec("state-tune") };
        let cold = st.run_tune(&spec, &TuneOptions::default()).unwrap();
        assert!(cold.evaluated >= 1);
        assert!(cold.evaluated <= cold.cross_product);
        assert!(!cold.frontier.is_empty());
        assert!(cold.stats.pnr_runs > 0, "cold tune must run real PnR");
        // A warm re-tune revisits only cached points: zero PnR, zero
        // sims, same frontier.
        let warm = st.run_tune(&spec, &TuneOptions::default()).unwrap();
        assert_eq!(warm.stats.pnr_runs, 0);
        assert_eq!(warm.stats.sims, 0);
        assert_eq!(warm.frontier.len(), cold.frontier.len());
        // Tune evaluations are not dse requests: the daemon counters
        // say "two tunes", zero sweeps.
        assert_eq!(st.stats.tune_requests.load(Ordering::Relaxed), 2);
        assert_eq!(st.stats.dse_requests.load(Ordering::Relaxed), 0);
        // Descriptor alignment: a plain dse of the same spec finds
        // every tuner-evaluated point already cached — the tuner's
        // one-candidate specs produced identical ConfigDescriptor keys.
        let dse = st.run_dse(&spec).unwrap();
        assert_eq!(dse.stats.cache_hits, cold.evaluated);
    }

    #[test]
    fn live_progress_slot_tracks_and_clears_by_identity() {
        let st = state();
        assert!(st.progress_sample().is_none(), "no sweep, no sample");
        let p = Arc::new(SweepProgress::new());
        p.begin(4, 1, 1);
        {
            let _guard = st.track_progress(Arc::clone(&p));
            let sample = st.progress_sample().expect("live sweep must sample");
            assert_eq!(sample.jobs_total, 4);
            assert_eq!(sample.jobs_done, 2, "hits + coalesced count as done");
            assert_eq!(sample.cache_hits, 1);
            assert_eq!(sample.cold_total, 2);
        }
        assert!(st.progress_sample().is_none(), "guard clears the slot on drop");
        // A superseded guard must not clear the newer registration.
        let newer = Arc::new(SweepProgress::new());
        newer.begin(8, 0, 0);
        let old_guard = st.track_progress(Arc::clone(&p));
        let _new_guard = st.track_progress(Arc::clone(&newer));
        drop(old_guard);
        let sample = st.progress_sample().expect("newer registration survives");
        assert_eq!(sample.jobs_total, 8);
    }

    #[test]
    fn archive_json_reads_the_file_without_creating_it() {
        // In-memory daemon: empty document, correct shape.
        let st = state();
        let doc = st.archive_json();
        assert_eq!(doc.get("entries").and_then(Json::as_arr).map(Vec::len), Some(0));
        // File-backed daemon: the archive file is served as-is, and a
        // read must not create it.
        let cache = std::env::temp_dir()
            .join(format!("canal_state_archive_{}.json", std::process::id()));
        let archive = archive_path_for(&cache);
        std::fs::remove_file(&archive).ok();
        let st = SessionState::with_placer(
            StateOptions { workers: 2, cache_path: Some(cache.clone()), ic_capacity: 32 },
            Box::new(BatchedNativePlacer::default()),
        )
        .unwrap();
        assert_eq!(
            st.archive_json().get("entries").and_then(Json::as_arr).map(Vec::len),
            Some(0)
        );
        assert!(!archive.exists(), "serving the archive must not create the file");
        std::fs::write(&archive, "{\"version\":1,\"entries\":[{\"config\":\"t2\"}]}")
            .unwrap();
        let doc = st.archive_json();
        assert_eq!(doc.get("entries").and_then(Json::as_arr).map(Vec::len), Some(1));
        std::fs::remove_file(&archive).ok();
        std::fs::remove_file(&cache).ok();
    }

    #[test]
    fn figure_requests_share_the_cache_both_ways() {
        let st = state();
        // fig10 is area-only (zero PnR) — a cheap end-to-end check that
        // the snapshot engine runs and its stats flow back.
        let (table, stats) = st.run_figure("fig10", 4).unwrap();
        assert!(table.render().contains("Fig. 10"), "{}", table.title);
        assert_eq!(stats.pnr_runs, 0);
        assert!(st.run_figure("fig99", 4).is_err());
    }
}
