//! Thin blocking client for the Canal daemon: one TCP connection,
//! strictly sequential request/response exchanges in the NDJSON framing
//! of [`super::proto`]. Powers `canal client` and the loopback tests;
//! scripted callers can equally speak the protocol with `nc`.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use crate::util::json::Json;

use super::proto::{self, Frame, Request};

/// How long a client waits for the next frame before giving up — long,
/// because a cold `dse` request legitimately computes for a while, but
/// finite, so a dead server cannot hang a script forever.
const DEFAULT_READ_TIMEOUT: Duration = Duration::from_secs(600);

/// One connection to a running daemon.
pub struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
    next_id: u64,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client, String> {
        let stream = TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
        stream
            .set_read_timeout(Some(DEFAULT_READ_TIMEOUT))
            .map_err(|e| e.to_string())?;
        let reader = BufReader::new(stream.try_clone().map_err(|e| e.to_string())?);
        Ok(Client { writer: stream, reader, next_id: 0 })
    }

    /// Send one request and block for its terminal frame, discarding
    /// progress.
    pub fn call(&mut self, req: &Request) -> Result<Json, String> {
        self.call_with(req, |_| {})
    }

    /// Send one request; stream progress messages into `on_progress`;
    /// return the result data, or the server's error as `Err`.
    pub fn call_with<F: FnMut(&str)>(
        &mut self,
        req: &Request,
        mut on_progress: F,
    ) -> Result<Json, String> {
        self.next_id += 1;
        let id = self.next_id;
        let mut line = proto::request_line(id, req);
        line.push('\n');
        self.writer.write_all(line.as_bytes()).map_err(|e| format!("send: {e}"))?;
        loop {
            match self.read_frame()? {
                Frame::Progress { message, .. } => on_progress(&message),
                // History frames only flow on `watch` connections (use
                // `call_frames` for those); tolerate one anywhere.
                Frame::History { .. } => {}
                Frame::Result { data, .. } => return Ok(data),
                Frame::Error { error, .. } => return Err(error),
            }
        }
    }

    /// Send one request and stream **every** frame — progress, history,
    /// result, error — into `on_frame` until it returns `false`, the
    /// request reaches a terminal frame, or the connection drops.
    ///
    /// This is the `watch` entry point: a `watch` request never sends a
    /// terminal frame, so the callback's return value (or disconnect)
    /// is what ends the stream. `Ok` carries the terminal frame when
    /// one arrived, `None` when the callback stopped the stream first.
    pub fn call_frames<F: FnMut(&Frame) -> bool>(
        &mut self,
        req: &Request,
        mut on_frame: F,
    ) -> Result<Option<Frame>, String> {
        self.next_id += 1;
        let id = self.next_id;
        let mut line = proto::request_line(id, req);
        line.push('\n');
        self.writer.write_all(line.as_bytes()).map_err(|e| format!("send: {e}"))?;
        loop {
            let frame = self.read_frame()?;
            let keep_going = on_frame(&frame);
            if frame.is_terminal() {
                return Ok(Some(frame));
            }
            if !keep_going {
                return Ok(None);
            }
        }
    }

    fn read_frame(&mut self) -> Result<Frame, String> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).map_err(|e| format!("recv: {e}"))?;
        if n == 0 {
            return Err("connection closed by server".into());
        }
        Frame::parse(line.trim_end_matches(['\n', '\r']))
    }
}
