//! The persistent Canal daemon (`canal serve`): concurrent sessions,
//! shared warm caches, and request coalescing over the DSE engine.
//!
//! Every other entry point in this crate is a one-shot process: it
//! rebuilds interconnects, pays the CSR freeze cost, loads the result
//! cache from disk, and throws all of it away on exit. Automated CGRA
//! design-space exploration is the opposite workload — many small
//! queries against one model — so this module keeps the model resident:
//!
//! - [`state`] — the process-wide [`SessionState`]: an LRU of frozen
//!   interconnects, ONE result cache with periodic persistence, ONE
//!   placement backend, and the in-flight table that coalesces
//!   overlapping `dse` requests (each `(config, app, seed)` point is
//!   computed at most once per daemon lifetime, whatever the
//!   concurrency);
//! - [`proto`] — the newline-delimited JSON protocol: typed requests
//!   (`generate`, `pnr`, `simulate`, `dse`, `area`, `figure`, plus
//!   `ping`/`info`/`stats`/`metrics`/`history`/`watch`/`shutdown`) and
//!   streamed response frames (timestamped progress and history events,
//!   then one terminal result or error);
//! - [`server`] — `std::net::TcpListener` + a connection worker pool,
//!   with graceful drain on `shutdown` requests and SIGTERM/SIGINT
//!   (in-flight jobs finish, the cache is flushed, exit is clean), plus
//!   a minimal HTTP responder on the same port (`GET /dash`,
//!   `/metrics.json`, `/history.json`, `/archive.json`);
//! - [`dash`] — the self-contained HTML+SVG dashboard page behind
//!   `GET /dash`;
//! - [`client`] — the thin blocking client behind `canal client`.
//!
//! Everything is `std`-only, consistent with the crate's offline
//! dependency set.
//!
//! Contract (asserted by `tests/service_e2e.rs`): results served by the
//! daemon are **bit-identical** to the sequential `canal dse` path for
//! the same parameters — [`proto::DseParams::to_spec`] is the shared
//! spec construction, and the shared-state executor is the same
//! deterministic [`crate::dse`] machinery — and a repeated identical
//! request performs zero PnR calls and zero simulations, observable
//! through the `stats` frames.
//!
//! The narrative protocol reference lives in `docs/service.md`.

pub mod client;
pub mod dash;
pub mod proto;
pub mod server;
pub mod state;

pub use client::Client;
pub use proto::{DseParams, Frame, GenParams, Request, SimParams, PROTO_VERSION};
pub use server::{signaled, ServeOptions, Server};
pub use state::{IcLru, ServiceStats, SessionState, StateOptions};
