//! The Canal daemon: a TCP listener and a fixed pool of connection
//! worker threads serving the NDJSON protocol ([`super::proto`]) over
//! one process-wide [`SessionState`].
//!
//! ## Lifecycle
//!
//! [`Server::bind`] binds the listener (an `--addr 127.0.0.1:0` bind
//! picks an ephemeral port; the resolved address is written to
//! `port_file` when configured, which is how scripted callers find it),
//! then [`Server::run`] blocks: an accept loop hands connections to the
//! worker pool, each worker serving one connection at a time, requests
//! on a connection strictly in order.
//!
//! ## Shutdown semantics (graceful drain)
//!
//! A `shutdown` request — or SIGTERM/SIGINT on unix — flips one flag:
//!
//! 1. the accept loop stops accepting and exits;
//! 2. workers finish the request they are currently serving (in-flight
//!    jobs complete and enter the shared cache), then close their
//!    connection instead of reading further requests;
//! 3. queued-but-unserved connections are closed without service;
//! 4. the shared result cache is flushed to its backing file;
//! 5. [`Server::run`] returns `Ok` — `canal serve` exits 0.
//!
//! Nothing is ever aborted mid-PnR: drain means "stop taking work",
//! not "stop working".
//!
//! ## Error containment
//!
//! A request-level failure (unknown app, invalid spec…) produces an
//! error frame and the connection keeps serving. A *framing* failure —
//! a line that does not parse as a request — produces an error frame
//! with `id: 0` and closes the connection, since byte-stream alignment
//! can no longer be trusted. A client that disconnects mid-request
//! costs nothing but the wasted write: the computation still completes
//! and its results stay in the shared cache for the next session.
//!
//! ## The HTTP surface
//!
//! The same listener doubles as a minimal HTTP responder: a first line
//! starting with `GET ` is treated as an HTTP request (browsers and
//! `curl` need no special port), served one response
//! (`Connection: close`), and the connection closes — everything else
//! is NDJSON, byte-identical to a daemon without the sniff. Routes:
//! `/dash` (self-contained HTML dashboard, inline SVG),
//! `/metrics.json`, `/history.json`, and `/archive.json`. HTTP hits
//! count under `service.http.*` metrics, never under the NDJSON
//! request counters.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::Duration;

use crate::dse::{
    app_by_name, areas_table, frontier_table, outcome_json, points_table, stats_json, tune_json,
    SweepProgress, TuneOptions,
};
use crate::dse::InterconnectSource;
use crate::hw::{allocate, lower_ready_valid, lower_static, RvOptions};
use crate::obs;
use crate::obs::metrics::{counter, gauge, histogram, Counter};
use crate::obs::span::names as spans;
use crate::obs::HistorySampler;
use crate::sim::{RvSim, StallPattern};
use crate::util::json::Json;

use super::dash;
use super::proto::{self, DseParams, Frame, GenParams, Request, SimParams, PROTO_VERSION};
use super::state::{SessionState, StateOptions};

/// Upper bound on one request line; a client exceeding it is cut off
/// (protects the daemon from unframed garbage).
const MAX_FRAME_BYTES: usize = 16 * 1024 * 1024;

/// Default for [`ServeOptions::read_poll`].
const READ_POLL: Duration = Duration::from_millis(500);

/// Idle connections back their poll timeout off exponentially up to
/// this multiple of [`ServeOptions::read_poll`] (resets on data), so a
/// parked client costs a fraction of the wakeups while drain latency
/// stays bounded.
const READ_POLL_BACKOFF_MAX: u32 = 4;

/// Default for [`ServeOptions::heartbeat`].
const HEARTBEAT_EVERY: Duration = Duration::from_secs(15);

/// Cadence of `watch` delta frames. Fixed (not configurable over the
/// wire): fast enough that a terminal dashboard feels live, slow enough
/// that an idle watcher costs a few empty frames per second at most.
const WATCH_EVERY: Duration = Duration::from_millis(250);

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Bind address; port `0` picks an ephemeral port.
    pub addr: String,
    /// Connection worker threads; `0` ⇒ 8.
    pub conn_threads: usize,
    /// Shared-state tuning (engine workers, cache file, LRU capacity).
    pub state: StateOptions,
    /// When set, the resolved `host:port` is written here after bind —
    /// the handshake scripted callers use with ephemeral ports.
    pub port_file: Option<PathBuf>,
    /// How long a blocked read waits before re-checking the shutdown
    /// flag (the *base* of the idle backoff). Bounds drain latency for
    /// idle connections at `read_poll * READ_POLL_BACKOFF_MAX`.
    pub read_poll: Duration,
    /// Heartbeat period during long computations: well under the
    /// client's read timeout, so a silent stretch only ever means a
    /// dead server. Tests shrink it to observe mid-sweep progress.
    pub heartbeat: Duration,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            addr: "127.0.0.1:9000".into(),
            conn_threads: 0,
            state: StateOptions::default(),
            port_file: None,
            read_poll: READ_POLL,
            heartbeat: HEARTBEAT_EVERY,
        }
    }
}

/// A bound (not yet running) daemon.
pub struct Server {
    listener: TcpListener,
    state: Arc<SessionState>,
    shutdown: Arc<AtomicBool>,
    conn_threads: usize,
    read_poll: Duration,
    heartbeat: Duration,
    /// The history sampler thread, running from bind until the server
    /// drops (its `Drop` stops and joins the thread).
    _sampler: HistorySampler,
}

impl Server {
    /// Bind with a fresh [`SessionState`] (default placement backend).
    pub fn bind(opts: ServeOptions) -> Result<Server, String> {
        let state = Arc::new(SessionState::new(opts.state.clone())?);
        Server::bind_with_state(opts, state)
    }

    /// Bind over an existing state — tests pin the placement backend,
    /// and embedders can share the state with in-process work.
    pub fn bind_with_state(
        opts: ServeOptions,
        state: Arc<SessionState>,
    ) -> Result<Server, String> {
        let listener =
            TcpListener::bind(&opts.addr).map_err(|e| format!("bind {}: {e}", opts.addr))?;
        let local = listener.local_addr().map_err(|e| e.to_string())?;
        if let Some(path) = &opts.port_file {
            std::fs::write(path, format!("{local}\n"))
                .map_err(|e| format!("{}: {e}", path.display()))?;
        }
        let conn_threads = if opts.conn_threads == 0 { 8 } else { opts.conn_threads };
        // The daemon always collects metrics (the `metrics` request
        // serves them); span tracing stays off unless a caller enabled
        // it before binding.
        obs::ObsOptions { metrics: true, trace: obs::trace_on() }.apply();
        // The history sampler runs for the server's whole lifetime,
        // snapshotting the registry (and the live sweep, when one is
        // running) into the ring that `history`/`watch`/`GET /dash`
        // serve.
        let sampler = {
            let history = Arc::clone(state.history());
            let sampler_state = Arc::clone(&state);
            HistorySampler::spawn(history, move || sampler_state.progress_sample())
        };
        Ok(Server {
            listener,
            state,
            shutdown: Arc::new(AtomicBool::new(false)),
            conn_threads,
            read_poll: opts.read_poll.max(Duration::from_millis(1)),
            heartbeat: opts.heartbeat.max(Duration::from_millis(1)),
            _sampler: sampler,
        })
    }

    /// The resolved bind address (meaningful after an ephemeral bind).
    pub fn local_addr(&self) -> Result<SocketAddr, String> {
        self.listener.local_addr().map_err(|e| e.to_string())
    }

    pub fn state(&self) -> &Arc<SessionState> {
        &self.state
    }

    /// The drain flag; storing `true` stops the accept loop (same
    /// effect as a `shutdown` request, minus the response frame).
    pub fn shutdown_flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.shutdown)
    }

    /// Serve until shutdown, then drain and flush. See the module docs
    /// for the exact drain semantics.
    pub fn run(self) -> Result<(), String> {
        install_signal_handlers();
        self.listener
            .set_nonblocking(true)
            .map_err(|e| format!("listener nonblocking: {e}"))?;

        let (tx, rx) = mpsc::channel::<TcpStream>();
        let rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::with_capacity(self.conn_threads);
        let (read_poll, heartbeat) = (self.read_poll, self.heartbeat);
        let queue_depth = obs::metrics_on().then(|| gauge("service.queue.depth"));
        for _ in 0..self.conn_threads {
            let rx = Arc::clone(&rx);
            let state = Arc::clone(&self.state);
            let shutdown = Arc::clone(&self.shutdown);
            let queue_depth = queue_depth.clone();
            workers.push(std::thread::spawn(move || loop {
                // Classic handoff queue: one worker at a time parks in
                // `recv`; the channel closing (accept loop gone) ends
                // the pool.
                let next = {
                    let rx = rx.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
                    rx.recv()
                };
                match next {
                    Ok(stream) => {
                        if let Some(g) = &queue_depth {
                            g.add(-1);
                        }
                        if shutdown.load(Ordering::SeqCst) {
                            // Drain mode: queued connections are closed
                            // without service.
                            continue;
                        }
                        // A panicking handler must cost one connection,
                        // not one pool thread: a worker that died on a
                        // panic would silently shrink the pool until
                        // accepted connections are never served.
                        let outcome = catch_unwind(AssertUnwindSafe(|| {
                            handle_conn(stream, &state, &shutdown, read_poll, heartbeat)
                        }));
                        if outcome.is_err() {
                            state.stats().errors.fetch_add(1, Ordering::Relaxed);
                            eprintln!(
                                "canal serve: connection handler panicked; worker recovered"
                            );
                        }
                    }
                    Err(_) => break,
                }
            }));
        }

        loop {
            if self.shutdown.load(Ordering::SeqCst) || signaled() {
                self.shutdown.store(true, Ordering::SeqCst);
                break;
            }
            match self.listener.accept() {
                Ok((stream, _)) => {
                    let _ = stream.set_nonblocking(false);
                    self.state.stats().connections.fetch_add(1, Ordering::Relaxed);
                    if let Some(g) = &queue_depth {
                        g.add(1);
                    }
                    if tx.send(stream).is_err() {
                        break;
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => {
                    // Transient accept failures (e.g. EMFILE) must not
                    // kill the daemon; back off and keep serving.
                    eprintln!("canal serve: accept error: {e}");
                    std::thread::sleep(Duration::from_millis(100));
                }
            }
        }

        drop(tx);
        for w in workers {
            let _ = w.join();
        }
        self.state.flush()?;
        Ok(())
    }
}

/// Serve one connection: requests strictly in order until EOF, a
/// framing error, or drain.
fn handle_conn(
    stream: TcpStream,
    state: &Arc<SessionState>,
    shutdown: &Arc<AtomicBool>,
    read_poll: Duration,
    heartbeat: Duration,
) {
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = LineReader::new(read_half, read_poll);
    let mut writer = stream;
    loop {
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        let line = match reader.read_line(shutdown) {
            Ok(Some(line)) => line,
            Ok(None) | Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        // Protocol sniff: browsers and `curl` speak HTTP to the same
        // port. HTTP requests never touch the NDJSON counters, and the
        // connection closes after one response — everything below this
        // line is byte-identical to a daemon without the sniff.
        if line.starts_with("GET ") {
            serve_http(&line, &mut reader, &mut writer, state, shutdown);
            break;
        }
        state.stats().requests.fetch_add(1, Ordering::Relaxed);
        let (id, req) = match proto::parse_request(&line) {
            Ok(parsed) => parsed,
            Err(e) => {
                state.stats().errors.fetch_add(1, Ordering::Relaxed);
                let _ = write_frame(
                    &mut writer,
                    &Frame::Error { id: 0, error: format!("malformed request: {e}") },
                );
                // Framing can no longer be trusted on this stream.
                break;
            }
        };
        let is_shutdown = matches!(req, Request::Shutdown);
        let cmd = cmd_name(&req);
        let t0 = obs::metrics_on().then(obs::now_ns);
        let outcome = {
            let mut _req = obs::span(spans::REQUEST);
            _req.args(id, 0);
            handle_request(id, req, state, &mut writer, shutdown, heartbeat)
        };
        if let Some(t0) = t0 {
            let dur = obs::now_ns().saturating_sub(t0);
            counter(&format!("service.request.{cmd}")).inc();
            histogram("service.request.latency_us").record(dur / 1_000);
        }
        if let Err(e) = outcome {
            state.stats().errors.fetch_add(1, Ordering::Relaxed);
            let _ = write_frame(&mut writer, &Frame::Error { id, error: e });
        }
        if is_shutdown {
            break;
        }
    }
}

/// Metric label for one request kind (`service.request.<cmd>`).
fn cmd_name(req: &Request) -> &'static str {
    match req {
        Request::Ping => "ping",
        Request::Info => "info",
        Request::Stats => "stats",
        Request::Metrics => "metrics",
        Request::History => "history",
        Request::Watch => "watch",
        Request::Generate(_) => "generate",
        Request::Simulate(_) => "simulate",
        Request::Pnr(_) => "pnr",
        Request::Dse(_) => "dse",
        Request::Tune(_) => "tune",
        Request::Area(_) => "area",
        Request::Figure { .. } => "figure",
        Request::Shutdown => "shutdown",
    }
}

/// Serve one request. `Ok` means the terminal result frame was emitted
/// (write failures are deliberately ignored — see the module docs on
/// disconnects); `Err` asks the caller to emit the error frame.
fn handle_request(
    id: u64,
    req: Request,
    state: &Arc<SessionState>,
    w: &mut TcpStream,
    shutdown: &Arc<AtomicBool>,
    heartbeat: Duration,
) -> Result<(), String> {
    match req {
        Request::Ping => respond(
            w,
            id,
            Json::Obj(vec![
                ("pong".into(), Json::Bool(true)),
                ("proto".into(), Json::num_u64(PROTO_VERSION)),
            ]),
        ),
        Request::Info => respond(w, id, info_json(state)),
        Request::Stats => respond(w, id, state.stats_json()),
        Request::Metrics => respond(w, id, obs::export::metrics_json()),
        Request::History => respond(w, id, state.history().to_json()),
        Request::Watch => watch_request(id, state, w, shutdown),
        Request::Shutdown => {
            shutdown.store(true, Ordering::SeqCst);
            let flushed = state.flush().is_ok();
            respond(
                w,
                id,
                Json::Obj(vec![
                    ("ok".into(), Json::Bool(true)),
                    ("flushed".into(), Json::Bool(flushed)),
                ]),
            )
        }
        Request::Generate(g) => generate_request(id, &g, state, w),
        Request::Simulate(s) => simulate_request(id, &s, w),
        Request::Dse(p) => dse_request(id, &p, state, w, heartbeat),
        Request::Tune(p) => tune_request(id, &p, state, w, heartbeat),
        Request::Area(p) => {
            let p = DseParams { area: true, apps: vec![], ..p };
            dse_request(id, &p, state, w, heartbeat)
        }
        Request::Pnr(p) => {
            if p.apps.len() != 1 {
                return Err(format!(
                    "pnr: exactly one app required, got {}",
                    p.apps.len()
                ));
            }
            dse_request(id, &p, state, w, heartbeat)
        }
        Request::Figure { which, sa_moves } => {
            let _ = write_frame(
                w,
                &Frame::progress(id, format!("regenerating {which} through the shared cache")),
            );
            let (table, stats) =
                with_heartbeat(w, id, heartbeat, None, || state.run_figure(&which, sa_moves))?;
            respond(
                w,
                id,
                Json::Obj(vec![
                    ("which".into(), Json::str(&which)),
                    ("table".into(), Json::str(&table.render())),
                    ("csv".into(), Json::str(&table.to_csv())),
                    ("stats".into(), stats_json(&stats)),
                ]),
            )
        }
    }
}

/// `watch`: stream the history ring as delta frames until the client
/// disconnects (or the daemon drains). The first frame carries the
/// whole ring (the backlog a fresh dashboard renders immediately);
/// every [`WATCH_EVERY`] after that, a frame with the samples recorded
/// since — empty frames included, so a silent daemon still proves it is
/// alive and `mono_ns` stays strictly monotone frame over frame. A
/// watch connection is dedicated: no terminal frame is ever sent, and
/// the stream ends only with the connection.
fn watch_request(
    id: u64,
    state: &Arc<SessionState>,
    w: &mut TcpStream,
    shutdown: &Arc<AtomicBool>,
) -> Result<(), String> {
    let history = state.history();
    let mut from = 0u64;
    loop {
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        let (next, samples) = history.since(from);
        from = next;
        let data = Json::Obj(vec![(
            "samples".into(),
            Json::Arr(samples.iter().map(|s| s.to_json()).collect()),
        )]);
        if write_frame(w, &Frame::history(id, data)).is_err() {
            // Client disconnected — the stream is the session.
            break;
        }
        std::thread::sleep(WATCH_EVERY);
    }
    Ok(())
}

/// Serve one HTTP request on a sniffed connection and close it.
///
/// Deliberately minimal: the request line names the route, the header
/// block is drained and ignored, and the response is a complete
/// `Content-Length`-framed document with `Connection: close`. That is
/// every bit of HTTP a dashboard tab or a `curl` one-liner needs.
fn serve_http(
    request_line: &str,
    reader: &mut LineReader,
    w: &mut TcpStream,
    state: &Arc<SessionState>,
    shutdown: &Arc<AtomicBool>,
) {
    while let Ok(Some(line)) = reader.read_line(shutdown) {
        if line.trim().is_empty() {
            break;
        }
    }
    let path = request_line.split_whitespace().nth(1).unwrap_or("/");
    let path = path.split(['?', '#']).next().unwrap_or("/");
    let (status, ctype, body) = match path {
        "/" | "/dash" => {
            let samples = state.history().samples();
            let metrics = obs::metrics::snapshot();
            let archive = state.archive_json();
            (
                "200 OK",
                "text/html; charset=utf-8",
                dash::dash_page(&samples, &metrics, &archive),
            )
        }
        "/metrics.json" => {
            ("200 OK", "application/json", obs::export::metrics_json().render())
        }
        "/history.json" => {
            ("200 OK", "application/json", state.history().to_json().render())
        }
        "/archive.json" => ("200 OK", "application/json", state.archive_json().render()),
        _ => (
            "404 Not Found",
            "text/plain; charset=utf-8",
            format!("no route for {path}\n"),
        ),
    };
    if obs::metrics_on() {
        counter("service.http.requests").inc();
        if status == "200 OK" {
            counter("service.http.ok").inc();
        } else {
            counter("service.http.not_found").inc();
        }
    }
    let head = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    let _ = w.write_all(head.as_bytes());
    let _ = w.write_all(body.as_bytes());
}

/// Run `f` while a sibling thread emits a heartbeat progress frame
/// every `every`, so the client's read timeout only ever catches a dead
/// server — never a legitimately long computation. With a
/// [`SweepProgress`], each heartbeat carries the live sweep state
/// (jobs done/total, warm/cold split, per-worker utilization — what
/// `canal client --watch` renders) instead of a bare "still working".
/// The heartbeat thread is the sole writer while `f` runs and is
/// stopped (condvar, so zero added latency on fast requests) and joined
/// before the caller writes its next frame.
fn with_heartbeat<T: Send>(
    w: &TcpStream,
    id: u64,
    every: Duration,
    progress: Option<&SweepProgress>,
    f: impl FnOnce() -> T + Send,
) -> T {
    let hb_stream = w.try_clone();
    let stop = Mutex::new(false);
    let cv = Condvar::new();
    std::thread::scope(|scope| {
        if let Ok(mut hb) = hb_stream {
            let (stop, cv) = (&stop, &cv);
            scope.spawn(move || {
                let mut stopped =
                    stop.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
                loop {
                    let (guard, timeout) = cv
                        .wait_timeout(stopped, every)
                        .unwrap_or_else(std::sync::PoisonError::into_inner);
                    stopped = guard;
                    if *stopped {
                        break;
                    }
                    if timeout.timed_out() {
                        if obs::metrics_on() {
                            counter("service.heartbeats").inc();
                        }
                        let message = match progress {
                            Some(p) => p.snapshot().message(),
                            None => "still working".into(),
                        };
                        let _ = write_frame(&mut hb, &Frame::progress(id, message));
                    }
                }
            });
        }
        // Stop via a drop guard: if `f` panics, `thread::scope` joins
        // the heartbeat thread before propagating — without the guard
        // the flag would never be set and the join would hang forever.
        struct StopGuard<'a> {
            stop: &'a Mutex<bool>,
            cv: &'a Condvar,
        }
        impl Drop for StopGuard<'_> {
            fn drop(&mut self) {
                *self.stop.lock().unwrap_or_else(std::sync::PoisonError::into_inner) = true;
                self.cv.notify_all();
            }
        }
        let _stop_on_exit = StopGuard { stop: &stop, cv: &cv };
        f()
    })
}

fn dse_request(
    id: u64,
    p: &DseParams,
    state: &Arc<SessionState>,
    w: &mut TcpStream,
    heartbeat: Duration,
) -> Result<(), String> {
    let spec = p.to_spec();
    if spec.apps.is_empty() && !spec.area {
        return Err("nothing to do: pass apps and/or area".into());
    }
    let _ = write_frame(
        w,
        &Frame::progress(id, format!("sweep `{}`: resolving jobs", spec.name)),
    );
    let progress = Arc::new(SweepProgress::new());
    let live = state.track_progress(Arc::clone(&progress));
    let out = with_heartbeat(w, id, heartbeat, Some(&progress), || {
        state.run_dse_with_progress(&spec, Some(&progress))
    });
    drop(live);
    let out = out?;
    let s = &out.stats;
    let _ = write_frame(
        w,
        &Frame::progress(
            id,
            format!(
                "{} jobs: {} cached, {} coalesced, {} PnR runs, {} sims",
                s.jobs, s.cache_hits, s.coalesced, s.pnr_runs, s.sims
            ),
        ),
    );
    // The machine-readable record plus rendered tables, so thin clients
    // print without reimplementing the formatting.
    let Json::Obj(mut members) = outcome_json(&out) else {
        unreachable!("outcome_json returns an object")
    };
    members.push(("table".into(), Json::str(&points_table(&out).render())));
    if spec.area {
        members.push(("areas_table".into(), Json::str(&areas_table(&out).render())));
    }
    respond(w, id, Json::Obj(members))
}

/// `tune`: the Pareto autotuner over the daemon's shared cache. Takes
/// the same params as `dse` (the spec IS the search space); pruning
/// stays at its default (on) over the wire — the `--no-prune` escape
/// hatch is a CLI debugging aid, not a protocol feature.
fn tune_request(
    id: u64,
    p: &DseParams,
    state: &Arc<SessionState>,
    w: &mut TcpStream,
    heartbeat: Duration,
) -> Result<(), String> {
    let spec = p.to_spec();
    if spec.apps.is_empty() {
        return Err("tune: need at least one app".into());
    }
    let _ = write_frame(
        w,
        &Frame::progress(id, format!("tune `{}`: searching the design space", spec.name)),
    );
    let progress = Arc::new(SweepProgress::new());
    let live = state.track_progress(Arc::clone(&progress));
    let out = with_heartbeat(w, id, heartbeat, Some(&progress), || {
        state.run_tune_with_progress(&spec, &TuneOptions::default(), Some(&progress))
    });
    drop(live);
    let out = out?;
    let _ = write_frame(
        w,
        &Frame::progress(
            id,
            format!(
                "{} evaluations ({} cross-product): {} pruned, {} dropped, {} rounds",
                out.evaluated, out.cross_product, out.pruned, out.dropped, out.rounds
            ),
        ),
    );
    let Json::Obj(mut members) = tune_json(&out) else {
        unreachable!("tune_json returns an object")
    };
    members.push(("table".into(), Json::str(&frontier_table(&out).render())));
    respond(w, id, Json::Obj(members))
}

fn generate_request(
    id: u64,
    g: &GenParams,
    state: &Arc<SessionState>,
    w: &mut TcpStream,
) -> Result<(), String> {
    let cfg = g.config();
    cfg.validate()?;
    let (ic, _) = state.ic_lru().interconnect(&cfg);
    let lowered = match g.backend.as_str() {
        "static" => lower_static(&ic),
        "rv" => lower_ready_valid(&ic, &RvOptions::default()),
        other => return Err(format!("unknown backend `{other}`")),
    };
    let mut kinds: Vec<(&'static str, usize)> =
        lowered.netlist.histogram().into_iter().collect();
    kinds.sort();
    let modules = Json::Obj(
        kinds.into_iter().map(|(k, v)| (k.to_string(), Json::num_u64(v as u64))).collect(),
    );
    let cs = allocate(&ic);
    let total_bits: u32 = cs.bits_per_tile().values().sum();
    respond(
        w,
        id,
        Json::Obj(vec![
            ("descriptor".into(), Json::str(&ic.descriptor)),
            ("backend".into(), Json::str(&g.backend)),
            ("nodes".into(), Json::num_u64(ic.node_count() as u64)),
            ("edges".into(), Json::num_u64(ic.edge_count() as u64)),
            ("config_bits".into(), Json::num_u64(total_bits as u64)),
            ("modules".into(), modules),
        ]),
    )
}

fn simulate_request(id: u64, s: &SimParams, w: &mut TcpStream) -> Result<(), String> {
    let app =
        app_by_name(&s.app).ok_or_else(|| format!("unknown app `{}` (see `info`)", s.app))?;
    let caps: std::collections::HashMap<_, _> = app
        .edges()
        .iter()
        .map(|e| ((e.src, e.src_port, e.dst, e.dst_port), s.fabric.capacity(1)))
        .collect();
    let input: Vec<i64> =
        (0..(s.tokens as i64 * 4)).map(|i| (i * 13 + 5) % 199).collect();
    let stall = StallPattern::Bursty { accept: 3, stall: 2 };
    let mut sim = RvSim::new(&app, &caps, input);
    let run = sim.run(s.tokens, 10_000_000, stall);
    let mut names: Vec<_> = run.outputs.keys().collect();
    names.sort();
    let outputs = Json::Obj(
        names
            .into_iter()
            .map(|name| {
                let seq = &run.outputs[name];
                (
                    name.clone(),
                    Json::Obj(vec![
                        (
                            "head".into(),
                            Json::Arr(
                                seq.iter().take(8).map(|&v| Json::Num(v.to_string())).collect(),
                            ),
                        ),
                        ("tokens".into(), Json::num_u64(seq.len() as u64)),
                    ]),
                )
            })
            .collect(),
    );
    respond(
        w,
        id,
        Json::Obj(vec![
            ("app".into(), Json::str(&app.name)),
            ("fabric".into(), Json::str(&s.fabric.label())),
            ("cycles".into(), Json::num_u64(run.cycles as u64)),
            ("tokens".into(), Json::num_u64(run.tokens as u64)),
            ("outputs".into(), outputs),
        ]),
    )
}

fn info_json(state: &Arc<SessionState>) -> Json {
    Json::Obj(vec![
        ("version".into(), Json::str(env!("CARGO_PKG_VERSION"))),
        ("proto".into(), Json::num_u64(PROTO_VERSION)),
        ("pjrt_feature".into(), Json::Bool(cfg!(feature = "pjrt"))),
        ("placer".into(), Json::str(state.placer_name())),
        (
            "apps".into(),
            Json::Arr(crate::dse::registry_keys().iter().map(|k| Json::str(k)).collect()),
        ),
    ])
}

/// Emit the terminal result frame. Write failures are swallowed: the
/// work is done and cached; only this session lost its answer.
fn respond(w: &mut TcpStream, id: u64, data: Json) -> Result<(), String> {
    let _ = write_frame(w, &Frame::Result { id, data });
    Ok(())
}

fn write_frame(w: &mut TcpStream, frame: &Frame) -> std::io::Result<()> {
    let mut line = frame.to_line();
    line.push('\n');
    w.write_all(line.as_bytes())
}

/// Newline framing over a read-timeout socket: partial reads accumulate
/// in `pending` (a `BufReader` would lose its buffer on `WouldBlock`
/// mid-line), and every timeout re-checks the drain flag.
///
/// The poll timeout starts at the configured base and doubles on each
/// consecutive timeout up to [`READ_POLL_BACKOFF_MAX`]× the base,
/// resetting the moment bytes arrive — an idle connection burns a
/// fraction of the wakeups (observable via
/// `service.conn.poll_wakeups`) while an active one keeps the snappy
/// base poll.
struct LineReader {
    stream: TcpStream,
    pending: Vec<u8>,
    base_poll: Duration,
    /// Current backoff multiplier (power of two, ≤ READ_POLL_BACKOFF_MAX).
    poll_mult: u32,
    /// Metric handles, resolved once per connection (`None` when
    /// metrics are disabled — the hot loop then touches no registry).
    poll_wakeups: Option<Arc<Counter>>,
    bytes_read: Option<Arc<Counter>>,
}

impl LineReader {
    fn new(stream: TcpStream, base_poll: Duration) -> LineReader {
        let _ = stream.set_read_timeout(Some(base_poll));
        let (poll_wakeups, bytes_read) = if obs::metrics_on() {
            (Some(counter("service.conn.poll_wakeups")), Some(counter("service.conn.bytes_read")))
        } else {
            (None, None)
        };
        LineReader {
            stream,
            pending: Vec::new(),
            base_poll,
            poll_mult: 1,
            poll_wakeups,
            bytes_read,
        }
    }

    fn set_poll_mult(&mut self, mult: u32) {
        if mult != self.poll_mult {
            self.poll_mult = mult;
            let _ = self.stream.set_read_timeout(Some(self.base_poll * mult));
        }
    }

    /// `Ok(None)` = clean end (EOF, or drain while idle).
    fn read_line(&mut self, shutdown: &AtomicBool) -> std::io::Result<Option<String>> {
        loop {
            if let Some(pos) = self.pending.iter().position(|&b| b == b'\n') {
                let rest = self.pending.split_off(pos + 1);
                let mut line = std::mem::replace(&mut self.pending, rest);
                line.pop();
                if line.last() == Some(&b'\r') {
                    line.pop();
                }
                return match String::from_utf8(line) {
                    Ok(s) => Ok(Some(s)),
                    Err(_) => Err(std::io::Error::new(
                        std::io::ErrorKind::InvalidData,
                        "non-utf8 frame",
                    )),
                };
            }
            if self.pending.len() > MAX_FRAME_BYTES {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    "frame exceeds 16 MiB",
                ));
            }
            let mut buf = [0u8; 4096];
            match self.stream.read(&mut buf) {
                Ok(0) => return Ok(None),
                Ok(n) => {
                    if let Some(c) = &self.bytes_read {
                        c.add(n as u64);
                    }
                    self.set_poll_mult(1);
                    self.pending.extend_from_slice(&buf[..n]);
                }
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    if let Some(c) = &self.poll_wakeups {
                        c.inc();
                    }
                    if shutdown.load(Ordering::SeqCst) {
                        return Ok(None);
                    }
                    let next = (self.poll_mult * 2).min(READ_POLL_BACKOFF_MAX);
                    self.set_poll_mult(next);
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
    }
}

static SIGNALED: AtomicBool = AtomicBool::new(false);

/// `true` once SIGTERM or SIGINT arrived (always `false` off unix).
pub fn signaled() -> bool {
    SIGNALED.load(Ordering::SeqCst)
}

/// Route SIGTERM/SIGINT into the drain flag. No external crates: the
/// raw libc `signal` entry point every Rust binary on unix already
/// links against.
#[cfg(unix)]
fn install_signal_handlers() {
    extern "C" fn on_signal(_sig: i32) {
        SIGNALED.store(true, Ordering::SeqCst);
    }
    type Handler = extern "C" fn(i32);
    extern "C" {
        fn signal(signum: i32, handler: Handler) -> usize;
    }
    // SAFETY: the handler performs exactly one atomic store
    // (async-signal-safe); registration itself has no preconditions.
    unsafe {
        let _ = signal(15, on_signal); // SIGTERM: orchestrated stop
        let _ = signal(2, on_signal); // SIGINT: interactive ^C
    }
}

#[cfg(not(unix))]
fn install_signal_handlers() {}
