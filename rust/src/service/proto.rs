//! The wire protocol of the Canal daemon: newline-delimited JSON
//! ("NDJSON") frames over a TCP stream.
//!
//! ## Framing
//!
//! Every frame — request or response — is exactly one line: a JSON
//! object rendered by [`Json::render_line`] (which guarantees no
//! embedded `\n`/`\r` byte) followed by one `\n`. A reader therefore
//! splits on `\n` and parses each line independently; no length
//! prefixes, no continuation state.
//!
//! ## Requests
//!
//! `{"id": <u64>, "cmd": "<name>", ...params}` — the `id` is chosen by
//! the client and echoed on every response frame, so a client can match
//! responses even though the server handles one request per connection
//! at a time. Commands: `ping`, `info`, `stats`, `metrics`, `history`,
//! `watch`, `generate`, `pnr`, `simulate`, `dse`, `tune`, `area`,
//! `figure`, `shutdown` (see [`Request`]).
//!
//! ## Responses
//!
//! A request produces zero or more *progress* frames followed by
//! exactly one terminal frame — *result* or *error*:
//!
//! ```json
//! {"id":7,"frame":"progress","message":"12 jobs: 8 cached, 4 cold","ts_ms":1754640000123,"mono_ns":98765}
//! {"id":7,"frame":"result","data":{...}}
//! {"id":7,"frame":"error","error":"unknown app `nope`"}
//! ```
//!
//! Progress frames carry a `ts_ms` wall-clock / `mono_ns` monotonic
//! timestamp pair stamped at emit time (absent on pre-dash servers;
//! parsed as 0). The one exception to "exactly one terminal frame" is
//! `watch`: it streams *history* frames (`"frame":"history"`, same
//! timestamp pair plus a `data` payload of [`crate::obs::history`]
//! samples) until the client disconnects — it never terminates on its
//! own, so a watch connection is dedicated to watching.
//!
//! A line the server cannot parse at all is answered with an error
//! frame carrying `id: 0`, after which the server closes the
//! connection (framing state is no longer trustworthy).
//!
//! ## Sweep parameters
//!
//! [`DseParams`] is the wire form of a sweep request. Its fields mirror
//! the `canal dse` CLI flags one-for-one and `to_spec` is the single
//! construction path shared by the CLI and the daemon — which is what
//! makes daemon responses bit-identical to the one-shot `canal dse`
//! path for the same parameters.

use crate::dse::{PointResult, SeedMode, Sizing, SweepSpec};
use crate::dsl::{InterconnectConfig, OutputTrackMode, SbTopology};
use crate::pnr::{FlowParams, RouterParams, SaParams, SearchCore};
use crate::sim::FabricKind;
use crate::util::json::Json;

/// Protocol schema version, reported by `ping` and `info`.
pub const PROTO_VERSION: u64 = 1;

/// One parsed client request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Liveness check; returns `{"pong":true,"proto":1}`.
    Ping,
    /// Server build/configuration report (version, features, placer
    /// backend, app registry).
    Info,
    /// Cumulative [`service-wide counters`](super::state::ServiceStats)
    /// plus cache occupancy.
    Stats,
    /// Snapshot of the process-wide observability registry
    /// ([`crate::obs::metrics`]): every counter/gauge/histogram the
    /// daemon has recorded, as `{"metrics":[...]}`.
    Metrics,
    /// One-shot dump of the daemon's [`crate::obs::MetricsHistory`]
    /// ring: every retained timestamped sample, as
    /// `{"period_ms","capacity","next_seq","samples":[...]}`.
    History,
    /// Streaming follow of the same history: periodic `history` frames
    /// carrying the samples recorded since the previous frame, until
    /// the client disconnects (never a terminal frame).
    Watch,
    /// Build an interconnect and report its shape.
    Generate(GenParams),
    /// Place-and-route a single application: a one-job sweep through
    /// the shared cache (`params.apps` must name exactly one app).
    Pnr(DseParams),
    /// Cycle-accurate elastic simulation of one application graph.
    Simulate(SimParams),
    /// A full design-space sweep.
    Dse(DseParams),
    /// Pareto autotune over the same parameter space: search instead of
    /// enumeration ([`crate::dse::run_tune`]). Shares [`DseParams`]
    /// wholesale — the axes define the candidate space, the seeds the
    /// successive-halving rounds — so a `tune` request warms exactly
    /// the cache entries a `dse` of the same params would.
    Tune(DseParams),
    /// Area-only sweep (`params.area` is implied; `apps` ignored).
    Area(DseParams),
    /// Regenerate one engine-backed paper figure through the shared
    /// cache.
    Figure { which: String, sa_moves: usize },
    /// Graceful drain: finish in-flight work, flush the cache, exit.
    Shutdown,
}

/// `generate` request parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct GenParams {
    pub width: u16,
    pub height: u16,
    pub mem_period: u16,
    pub tracks: Option<u16>,
    pub topology: Option<SbTopology>,
    /// `static` or `rv` (the two hardware lowering backends).
    pub backend: String,
}

impl Default for GenParams {
    fn default() -> Self {
        GenParams {
            width: 8,
            height: 8,
            mem_period: 3,
            tracks: None,
            topology: None,
            backend: "static".into(),
        }
    }
}

impl GenParams {
    pub fn config(&self) -> InterconnectConfig {
        let mut cfg = InterconnectConfig {
            width: self.width,
            height: self.height,
            mem_column_period: self.mem_period,
            ..Default::default()
        };
        if let Some(t) = self.tracks {
            cfg.num_tracks = t;
        }
        if let Some(topo) = self.topology {
            cfg.sb_topology = topo;
        }
        cfg
    }
}

/// `simulate` request parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct SimParams {
    pub app: String,
    pub fabric: FabricKind,
    pub tokens: usize,
}

impl Default for SimParams {
    fn default() -> Self {
        SimParams { app: String::new(), fabric: FabricKind::RvSplitFifo, tokens: 64 }
    }
}

/// Wire form of one sweep request. Field-for-field the `canal dse` CLI
/// flags; [`DseParams::to_spec`] is the shared construction path, so a
/// daemon request and a CLI invocation with the same values produce the
/// same [`SweepSpec`] — and therefore the same job keys and results.
#[derive(Clone, Debug, PartialEq)]
pub struct DseParams {
    pub name: String,
    pub width: u16,
    pub height: u16,
    pub mem_period: u16,
    pub tracks: Vec<u16>,
    pub topologies: Vec<SbTopology>,
    pub out_tracks: Vec<OutputTrackMode>,
    pub sb_sides: Vec<u8>,
    pub cb_sides: Vec<u8>,
    pub fabrics: Vec<FabricKind>,
    pub apps: Vec<String>,
    /// First logical seed; the axis is `seed .. seed + seeds`.
    pub seed: u64,
    pub seeds: u64,
    pub derived_seeds: bool,
    pub tight: Option<f64>,
    pub sa_moves: usize,
    /// Router search core, by [`SearchCore::parse`] name
    /// (`binary-heap` default).
    pub search_core: String,
    /// Slack-driven net ordering between PathFinder iterations.
    pub slack_order: bool,
    pub area: bool,
}

impl Default for DseParams {
    fn default() -> Self {
        DseParams {
            name: "cli".into(),
            width: 8,
            height: 8,
            mem_period: 3,
            tracks: vec![],
            topologies: vec![],
            out_tracks: vec![],
            sb_sides: vec![],
            cb_sides: vec![],
            fabrics: vec![],
            apps: vec![],
            seed: 1,
            seeds: 1,
            derived_seeds: false,
            tight: None,
            sa_moves: 12,
            search_core: SearchCore::BinaryHeap.name().into(),
            slack_order: false,
            area: false,
        }
    }
}

impl DseParams {
    /// The resolved sweep spec — identical construction to `canal dse`.
    pub fn to_spec(&self) -> SweepSpec {
        SweepSpec {
            name: self.name.clone(),
            base: InterconnectConfig {
                width: self.width,
                height: self.height,
                mem_column_period: self.mem_period,
                ..Default::default()
            },
            tracks: self.tracks.clone(),
            topologies: self.topologies.clone(),
            output_tracks: self.out_tracks.clone(),
            sb_sides: self.sb_sides.clone(),
            cb_sides: self.cb_sides.clone(),
            fabrics: self.fabrics.clone(),
            sizing: match self.tight {
                Some(slack) => Sizing::TightArray { slack },
                None => Sizing::Fixed,
            },
            apps: self.apps.clone(),
            seeds: (0..self.seeds).map(|i| self.seed + i).collect(),
            seed_mode: if self.derived_seeds { SeedMode::Derived } else { SeedMode::Raw },
            flow: FlowParams {
                sa: SaParams { moves_per_node: self.sa_moves, ..Default::default() },
                router: RouterParams {
                    // Validated on parse ([`DseParams::from_json`]) and
                    // by the CLI, so a miss here can only come from a
                    // hand-built struct; fall back to the default core.
                    search_core: SearchCore::parse(&self.search_core).unwrap_or_default(),
                    slack_order: self.slack_order,
                    ..Default::default()
                },
                ..Default::default()
            },
            area: self.area,
        }
    }

    fn to_members(&self) -> Vec<(String, Json)> {
        vec![
            ("name".into(), Json::str(&self.name)),
            ("width".into(), Json::num_u64(self.width as u64)),
            ("height".into(), Json::num_u64(self.height as u64)),
            ("mem_period".into(), Json::num_u64(self.mem_period as u64)),
            ("tracks".into(), num_list(self.tracks.iter().map(|&t| t as u64))),
            (
                "topologies".into(),
                str_list(self.topologies.iter().map(|t| t.name().to_string())),
            ),
            (
                "out_tracks".into(),
                str_list(self.out_tracks.iter().map(|m| m.name().to_string())),
            ),
            ("sb_sides".into(), num_list(self.sb_sides.iter().map(|&s| s as u64))),
            ("cb_sides".into(), num_list(self.cb_sides.iter().map(|&s| s as u64))),
            ("fabrics".into(), str_list(self.fabrics.iter().map(|f| f.label()))),
            ("apps".into(), str_list(self.apps.iter().cloned())),
            ("seed".into(), Json::num_u64(self.seed)),
            ("seeds".into(), Json::num_u64(self.seeds)),
            ("derived_seeds".into(), Json::Bool(self.derived_seeds)),
            (
                "tight".into(),
                match self.tight {
                    Some(s) => Json::num_f64(s),
                    None => Json::Null,
                },
            ),
            ("sa_moves".into(), Json::num_u64(self.sa_moves as u64)),
            ("search_core".into(), Json::str(&self.search_core)),
            ("slack_order".into(), Json::Bool(self.slack_order)),
            ("area".into(), Json::Bool(self.area)),
        ]
    }

    /// Read the params out of a request object; absent fields take the
    /// CLI defaults, present-but-malformed fields are loud.
    pub fn from_json(v: &Json) -> Result<DseParams, String> {
        let d = DseParams::default();
        Ok(DseParams {
            name: opt_str(v, "name")?.unwrap_or(d.name),
            width: opt_u16(v, "width")?.unwrap_or(d.width),
            height: opt_u16(v, "height")?.unwrap_or(d.height),
            mem_period: opt_u16(v, "mem_period")?.unwrap_or(d.mem_period),
            tracks: opt_num_list(v, "tracks", |n| u16::try_from(n).ok())?,
            topologies: opt_parsed_list(v, "topologies", SbTopology::parse)?,
            out_tracks: opt_parsed_list(v, "out_tracks", OutputTrackMode::parse)?,
            sb_sides: opt_num_list(v, "sb_sides", |n| u8::try_from(n).ok())?,
            cb_sides: opt_num_list(v, "cb_sides", |n| u8::try_from(n).ok())?,
            fabrics: opt_parsed_list(v, "fabrics", FabricKind::parse)?,
            apps: opt_parsed_list(v, "apps", |s| Some(s.to_string()))?,
            seed: opt_u64(v, "seed")?.unwrap_or(d.seed),
            seeds: opt_u64(v, "seeds")?.unwrap_or(d.seeds),
            derived_seeds: opt_bool(v, "derived_seeds")?.unwrap_or(d.derived_seeds),
            tight: opt_f64(v, "tight")?,
            sa_moves: opt_u64(v, "sa_moves")?.map(|n| n as usize).unwrap_or(d.sa_moves),
            search_core: match opt_str(v, "search_core")? {
                None => d.search_core,
                Some(s) => {
                    let core = SearchCore::parse(&s)
                        .ok_or_else(|| format!("bad `search_core` value `{s}`"))?;
                    // Canonicalize so aliases ("heap", "a-star") share
                    // the wire form with their canonical spelling.
                    core.name().into()
                }
            },
            slack_order: opt_bool(v, "slack_order")?.unwrap_or(d.slack_order),
            area: opt_bool(v, "area")?.unwrap_or(d.area),
        })
    }
}

/// Serialize one request as a single frame line (no trailing newline).
pub fn request_line(id: u64, req: &Request) -> String {
    let mut members = vec![("id".to_string(), Json::num_u64(id))];
    let cmd = |members: &mut Vec<(String, Json)>, name: &str| {
        members.push(("cmd".into(), Json::str(name)));
    };
    match req {
        Request::Ping => cmd(&mut members, "ping"),
        Request::Info => cmd(&mut members, "info"),
        Request::Stats => cmd(&mut members, "stats"),
        Request::Metrics => cmd(&mut members, "metrics"),
        Request::History => cmd(&mut members, "history"),
        Request::Watch => cmd(&mut members, "watch"),
        Request::Shutdown => cmd(&mut members, "shutdown"),
        Request::Generate(g) => {
            cmd(&mut members, "generate");
            members.push(("width".into(), Json::num_u64(g.width as u64)));
            members.push(("height".into(), Json::num_u64(g.height as u64)));
            members.push(("mem_period".into(), Json::num_u64(g.mem_period as u64)));
            if let Some(t) = g.tracks {
                members.push(("tracks".into(), Json::num_u64(t as u64)));
            }
            if let Some(topo) = g.topology {
                members.push(("topology".into(), Json::str(topo.name())));
            }
            members.push(("backend".into(), Json::str(&g.backend)));
        }
        Request::Simulate(s) => {
            cmd(&mut members, "simulate");
            members.push(("app".into(), Json::str(&s.app)));
            members.push(("fabric".into(), Json::str(&s.fabric.label())));
            members.push(("tokens".into(), Json::num_u64(s.tokens as u64)));
        }
        Request::Pnr(p) => {
            cmd(&mut members, "pnr");
            members.extend(p.to_members());
        }
        Request::Dse(p) => {
            cmd(&mut members, "dse");
            members.extend(p.to_members());
        }
        Request::Tune(p) => {
            cmd(&mut members, "tune");
            members.extend(p.to_members());
        }
        Request::Area(p) => {
            cmd(&mut members, "area");
            members.extend(p.to_members());
        }
        Request::Figure { which, sa_moves } => {
            cmd(&mut members, "figure");
            members.push(("which".into(), Json::str(which)));
            members.push(("sa_moves".into(), Json::num_u64(*sa_moves as u64)));
        }
    }
    Json::Obj(members).render_line()
}

/// Parse one request line into `(id, request)`.
pub fn parse_request(line: &str) -> Result<(u64, Request), String> {
    let v = Json::parse(line)?;
    let id = v.get("id").and_then(Json::as_u64).ok_or("missing `id`")?;
    let cmd = v.get("cmd").and_then(Json::as_str).ok_or("missing `cmd`")?;
    let req = match cmd {
        "ping" => Request::Ping,
        "info" => Request::Info,
        "stats" => Request::Stats,
        "metrics" => Request::Metrics,
        "history" => Request::History,
        "watch" => Request::Watch,
        "shutdown" => Request::Shutdown,
        "generate" => {
            let d = GenParams::default();
            Request::Generate(GenParams {
                width: opt_u16(&v, "width")?.unwrap_or(d.width),
                height: opt_u16(&v, "height")?.unwrap_or(d.height),
                mem_period: opt_u16(&v, "mem_period")?.unwrap_or(d.mem_period),
                tracks: opt_u16(&v, "tracks")?,
                topology: match opt_str(&v, "topology")? {
                    None => None,
                    Some(s) => {
                        Some(SbTopology::parse(&s).ok_or_else(|| format!("bad topology `{s}`"))?)
                    }
                },
                backend: opt_str(&v, "backend")?.unwrap_or(d.backend),
            })
        }
        "simulate" => {
            let d = SimParams::default();
            Request::Simulate(SimParams {
                app: opt_str(&v, "app")?.ok_or("simulate: missing `app`")?,
                fabric: match opt_str(&v, "fabric")? {
                    None => d.fabric,
                    Some(s) => {
                        FabricKind::parse(&s).ok_or_else(|| format!("bad fabric `{s}`"))?
                    }
                },
                tokens: opt_u64(&v, "tokens")?.map(|n| n as usize).unwrap_or(d.tokens),
            })
        }
        "pnr" => Request::Pnr(DseParams::from_json(&v)?),
        "dse" => Request::Dse(DseParams::from_json(&v)?),
        "tune" => Request::Tune(DseParams::from_json(&v)?),
        "area" => Request::Area(DseParams::from_json(&v)?),
        "figure" => Request::Figure {
            which: opt_str(&v, "which")?.ok_or("figure: missing `which`")?,
            sa_moves: opt_u64(&v, "sa_moves")?.map(|n| n as usize).unwrap_or(12),
        },
        other => return Err(format!("unknown cmd `{other}`")),
    };
    Ok((id, req))
}

/// One server→client frame.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// A human-readable status line (heartbeats, sweep stages), stamped
    /// with the emit time. `ts_ms`/`mono_ns` parse as 0 from pre-dash
    /// servers that didn't send them.
    Progress { id: u64, message: String, ts_ms: u64, mono_ns: u64 },
    /// A batch of [`crate::obs::history`] samples (the `watch` stream),
    /// stamped with the emit time.
    History { id: u64, ts_ms: u64, mono_ns: u64, data: Json },
    Result { id: u64, data: Json },
    Error { id: u64, error: String },
}

impl Frame {
    /// A progress frame stamped with the current wall/monotonic time.
    pub fn progress(id: u64, message: impl Into<String>) -> Frame {
        Frame::Progress {
            id,
            message: message.into(),
            ts_ms: crate::obs::now_ms(),
            mono_ns: crate::obs::now_ns(),
        }
    }

    /// A history frame stamped with the current wall/monotonic time.
    pub fn history(id: u64, data: Json) -> Frame {
        Frame::History { id, data, ts_ms: crate::obs::now_ms(), mono_ns: crate::obs::now_ns() }
    }

    pub fn id(&self) -> u64 {
        match self {
            Frame::Progress { id, .. }
            | Frame::History { id, .. }
            | Frame::Result { id, .. }
            | Frame::Error { id, .. } => *id,
        }
    }

    /// `true` for the frame that ends a request (result or error).
    pub fn is_terminal(&self) -> bool {
        !matches!(self, Frame::Progress { .. } | Frame::History { .. })
    }

    /// Serialize as a single line (no trailing newline). The
    /// [`Json::render_line`] guarantee is what keeps arbitrary error
    /// text and table content from breaking the framing.
    pub fn to_line(&self) -> String {
        let v = match self {
            Frame::Progress { id, message, ts_ms, mono_ns } => Json::Obj(vec![
                ("id".into(), Json::num_u64(*id)),
                ("frame".into(), Json::str("progress")),
                ("message".into(), Json::str(message)),
                ("ts_ms".into(), Json::num_u64(*ts_ms)),
                ("mono_ns".into(), Json::num_u64(*mono_ns)),
            ]),
            Frame::History { id, ts_ms, mono_ns, data } => Json::Obj(vec![
                ("id".into(), Json::num_u64(*id)),
                ("frame".into(), Json::str("history")),
                ("ts_ms".into(), Json::num_u64(*ts_ms)),
                ("mono_ns".into(), Json::num_u64(*mono_ns)),
                ("data".into(), data.clone()),
            ]),
            Frame::Result { id, data } => Json::Obj(vec![
                ("id".into(), Json::num_u64(*id)),
                ("frame".into(), Json::str("result")),
                ("data".into(), data.clone()),
            ]),
            Frame::Error { id, error } => Json::Obj(vec![
                ("id".into(), Json::num_u64(*id)),
                ("frame".into(), Json::str("error")),
                ("error".into(), Json::str(error)),
            ]),
        };
        v.render_line()
    }

    pub fn parse(line: &str) -> Result<Frame, String> {
        let v = Json::parse(line)?;
        let id = v.get("id").and_then(Json::as_u64).ok_or("frame: missing `id`")?;
        let stamp = |k: &str| v.get(k).and_then(Json::as_u64).unwrap_or(0);
        match v.get("frame").and_then(Json::as_str) {
            Some("progress") => Ok(Frame::Progress {
                id,
                message: v
                    .get("message")
                    .and_then(Json::as_str)
                    .unwrap_or_default()
                    .to_string(),
                ts_ms: stamp("ts_ms"),
                mono_ns: stamp("mono_ns"),
            }),
            Some("history") => Ok(Frame::History {
                id,
                ts_ms: stamp("ts_ms"),
                mono_ns: stamp("mono_ns"),
                data: v.get("data").cloned().unwrap_or(Json::Null),
            }),
            Some("result") => {
                Ok(Frame::Result { id, data: v.get("data").cloned().unwrap_or(Json::Null) })
            }
            Some("error") => Ok(Frame::Error {
                id,
                error: v
                    .get("error")
                    .and_then(Json::as_str)
                    .unwrap_or("unspecified error")
                    .to_string(),
            }),
            _ => Err("frame: missing or unknown `frame` kind".into()),
        }
    }
}

/// Parse one `points[]` element of a `dse`/`pnr` result (the
/// [`crate::dse::outcome_json`] point shape) back into the exact
/// [`PointResult`] — floats bit-exact, which is what lets the loopback
/// tests assert daemon results are bit-identical to the in-process
/// engine.
pub fn point_result_from_json(v: &Json) -> Result<PointResult, String> {
    let u64_field = |k: &str| -> Result<u64, String> {
        v.get(k).and_then(Json::as_u64).ok_or_else(|| format!("point: missing `{k}`"))
    };
    // `num_f64` writes non-finite values as null; accept them back as
    // NaN (mirrors the cache reader).
    let f64_field = |k: &str| -> Result<f64, String> {
        match v.get(k) {
            Some(Json::Null) => Ok(f64::NAN),
            Some(j) => j.as_f64().ok_or_else(|| format!("point: bad `{k}`")),
            None => Err(format!("point: missing `{k}`")),
        }
    };
    Ok(PointResult {
        routed: v.get("routed").and_then(Json::as_bool).ok_or("point: missing `routed`")?,
        critical_path_ps: f64_field("critical_path_ps")?,
        period_ps: f64_field("period_ps")?,
        latency_cycles: u64_field("latency_cycles")?,
        runtime_ns: f64_field("runtime_ns")?,
        iterations: u64_field("iterations")?,
        nodes_used: u64_field("nodes_used")?,
        alpha: f64_field("alpha")?,
        sim_cycles: u64_field("sim_cycles")?,
        sim_tokens: u64_field("sim_tokens")?,
        stall_cycles: u64_field("stall_cycles")?,
    })
}

fn num_list<I: Iterator<Item = u64>>(items: I) -> Json {
    Json::Arr(items.map(Json::num_u64).collect())
}

fn str_list<I: Iterator<Item = String>>(items: I) -> Json {
    Json::Arr(items.map(Json::Str).collect())
}

fn opt_str(v: &Json, k: &str) -> Result<Option<String>, String> {
    match v.get(k) {
        None | Some(Json::Null) => Ok(None),
        Some(j) => j
            .as_str()
            .map(|s| Some(s.to_string()))
            .ok_or_else(|| format!("bad `{k}`: expected string")),
    }
}

fn opt_bool(v: &Json, k: &str) -> Result<Option<bool>, String> {
    match v.get(k) {
        None | Some(Json::Null) => Ok(None),
        Some(j) => j.as_bool().map(Some).ok_or_else(|| format!("bad `{k}`: expected bool")),
    }
}

fn opt_u64(v: &Json, k: &str) -> Result<Option<u64>, String> {
    match v.get(k) {
        None | Some(Json::Null) => Ok(None),
        Some(j) => j.as_u64().map(Some).ok_or_else(|| format!("bad `{k}`: expected integer")),
    }
}

fn opt_f64(v: &Json, k: &str) -> Result<Option<f64>, String> {
    match v.get(k) {
        None | Some(Json::Null) => Ok(None),
        Some(j) => j.as_f64().map(Some).ok_or_else(|| format!("bad `{k}`: expected number")),
    }
}

fn opt_u16(v: &Json, k: &str) -> Result<Option<u16>, String> {
    match opt_u64(v, k)? {
        None => Ok(None),
        Some(n) => u16::try_from(n)
            .map(Some)
            .map_err(|_| format!("bad `{k}`: {n} out of range")),
    }
}

fn opt_num_list<T, F: Fn(u64) -> Option<T>>(
    v: &Json,
    k: &str,
    convert: F,
) -> Result<Vec<T>, String> {
    match v.get(k) {
        None | Some(Json::Null) => Ok(vec![]),
        Some(j) => j
            .as_arr()
            .ok_or_else(|| format!("bad `{k}`: expected array"))?
            .iter()
            .map(|item| {
                item.as_u64()
                    .and_then(&convert)
                    .ok_or_else(|| format!("bad `{k}` element"))
            })
            .collect(),
    }
}

fn opt_parsed_list<T, F: Fn(&str) -> Option<T>>(
    v: &Json,
    k: &str,
    parse: F,
) -> Result<Vec<T>, String> {
    match v.get(k) {
        None | Some(Json::Null) => Ok(vec![]),
        Some(j) => j
            .as_arr()
            .ok_or_else(|| format!("bad `{k}`: expected array"))?
            .iter()
            .map(|item| {
                let s = item.as_str().ok_or_else(|| format!("bad `{k}` element"))?;
                parse(s).ok_or_else(|| format!("bad `{k}` value `{s}`"))
            })
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrips_through_the_wire_form() {
        let reqs = vec![
            Request::Ping,
            Request::Info,
            Request::Stats,
            Request::Metrics,
            Request::History,
            Request::Watch,
            Request::Shutdown,
            Request::Generate(GenParams {
                tracks: Some(4),
                topology: Some(SbTopology::Disjoint),
                backend: "rv".into(),
                ..Default::default()
            }),
            Request::Simulate(SimParams {
                app: "gaussian".into(),
                fabric: FabricKind::RvFullFifo { depth: 4 },
                tokens: 128,
            }),
            Request::Dse(DseParams {
                tracks: vec![3, 4],
                topologies: vec![SbTopology::Wilton, SbTopology::Disjoint],
                fabrics: vec![FabricKind::Static, FabricKind::RvSplitFifo],
                apps: vec!["pointwise4".into()],
                seeds: 2,
                derived_seeds: true,
                tight: Some(1.25),
                search_core: "astar".into(),
                slack_order: true,
                area: true,
                ..Default::default()
            }),
            Request::Pnr(DseParams { apps: vec!["harris".into()], ..Default::default() }),
            Request::Tune(DseParams {
                tracks: vec![2, 3, 4],
                apps: vec!["pointwise4".into()],
                seeds: 2,
                ..Default::default()
            }),
            Request::Area(DseParams { tracks: vec![2, 3], area: true, ..Default::default() }),
            Request::Figure { which: "fig10".into(), sa_moves: 6 },
        ];
        for (i, req) in reqs.into_iter().enumerate() {
            let line = request_line(i as u64 + 1, &req);
            assert!(!line.contains('\n'), "{line}");
            let (id, back) = parse_request(&line).unwrap();
            assert_eq!(id, i as u64 + 1);
            assert_eq!(back, req, "roundtrip of {line}");
        }
    }

    #[test]
    fn absent_fields_take_cli_defaults_and_bad_fields_are_loud() {
        let (_, req) = parse_request(r#"{"id":1,"cmd":"dse"}"#).unwrap();
        assert_eq!(req, Request::Dse(DseParams::default()));
        assert!(parse_request(r#"{"cmd":"ping"}"#).is_err(), "id is required");
        assert!(parse_request(r#"{"id":1}"#).is_err(), "cmd is required");
        assert!(parse_request(r#"{"id":1,"cmd":"warp"}"#).is_err(), "unknown cmd");
        assert!(parse_request(r#"{"id":1,"cmd":"dse","tracks":"3"}"#).is_err());
        assert!(parse_request(r#"{"id":1,"cmd":"dse","fabrics":["warp"]}"#).is_err());
        assert!(parse_request(r#"{"id":1,"cmd":"dse","search_core":"warp"}"#).is_err());
        // Aliases canonicalize on parse, so wire forms never fork keys.
        let (_, req) =
            parse_request(r#"{"id":1,"cmd":"dse","search_core":"a-star"}"#).unwrap();
        match req {
            Request::Dse(p) => assert_eq!(p.search_core, "astar"),
            other => panic!("expected dse, got {other:?}"),
        }
        assert!(parse_request(r#"{"id":1,"cmd":"simulate"}"#).is_err(), "app required");
        assert!(parse_request("not json").is_err());
    }

    #[test]
    fn to_spec_matches_the_cli_construction() {
        let p = DseParams {
            tracks: vec![3, 4],
            apps: vec!["gaussian".into()],
            seed: 5,
            seeds: 2,
            sa_moves: 4,
            ..Default::default()
        };
        let spec = p.to_spec();
        assert_eq!(spec.name, "cli");
        assert_eq!(spec.base.width, 8);
        assert_eq!(spec.base.mem_column_period, 3);
        assert_eq!(spec.seeds, vec![5, 6]);
        assert_eq!(spec.flow.sa.moves_per_node, 4);
        assert!(matches!(spec.sizing, Sizing::Fixed));
        assert_eq!(spec.seed_mode, SeedMode::Raw);
        assert_eq!(spec.flow.router.search_core, SearchCore::BinaryHeap);
        assert!(!spec.flow.router.slack_order);
        let variant = DseParams {
            search_core: "bidir".into(),
            slack_order: true,
            ..DseParams::default()
        }
        .to_spec();
        assert_eq!(variant.flow.router.search_core, SearchCore::Bidir);
        assert!(variant.flow.router.slack_order);
        // Same job keys as a spec built by hand the way cmd_dse does.
        let jobs = spec.jobs("native-gd").unwrap();
        assert_eq!(jobs.len(), 4);
        let tight = DseParams { tight: Some(1.5), ..p }.to_spec();
        assert!(matches!(tight.sizing, Sizing::TightArray { slack } if slack == 1.5));
    }

    #[test]
    fn frames_roundtrip_and_stay_single_line() {
        let frames = vec![
            Frame::Progress {
                id: 3,
                message: "multi\nline\rmessage".into(),
                ts_ms: 1_754_640_000_123,
                mono_ns: 42_000,
            },
            Frame::History {
                id: 6,
                ts_ms: 1_754_640_000_456,
                mono_ns: 43_000,
                data: Json::Obj(vec![("samples".into(), Json::Arr(vec![]))]),
            },
            Frame::Result {
                id: 4,
                data: Json::Obj(vec![("table".into(), Json::str("a | b\nc | d\n"))]),
            },
            Frame::Error { id: 5, error: "bad\nthing".into() },
        ];
        for f in frames {
            let line = f.to_line();
            assert!(!line.bytes().any(|b| b == b'\n' || b == b'\r'), "{line:?}");
            assert_eq!(Frame::parse(&line).unwrap(), f);
        }
        assert!(Frame::parse(r#"{"id":1}"#).is_err());
        assert!(Frame::parse(r#"{"id":1,"frame":"warp"}"#).is_err());
        assert!(Frame::Error { id: 1, error: "x".into() }.is_terminal());
        assert!(!Frame::progress(1, "x").is_terminal());
        assert!(!Frame::history(1, Json::Null).is_terminal());
    }

    #[test]
    fn frame_constructors_stamp_both_clocks() {
        let a = Frame::progress(1, "tick");
        let b = Frame::progress(1, "tock");
        match (&a, &b) {
            (
                Frame::Progress { ts_ms, mono_ns, .. },
                Frame::Progress { mono_ns: later, .. },
            ) => {
                assert!(*ts_ms > 0, "wall clock must be stamped");
                assert!(later >= mono_ns, "monotonic stamps never go backwards");
            }
            other => panic!("expected progress frames, got {other:?}"),
        }
        // A pre-dash frame without stamps still parses (as zero).
        let old = Frame::parse(r#"{"id":9,"frame":"progress","message":"hi"}"#).unwrap();
        assert_eq!(
            old,
            Frame::Progress { id: 9, message: "hi".into(), ts_ms: 0, mono_ns: 0 }
        );
    }

    #[test]
    fn point_results_roundtrip_bit_exactly_through_outcome_json() {
        use crate::dse::{outcome_json, DseEngine, SweepSpec};
        use crate::pnr::NativePlacer;
        let spec = SweepSpec {
            base: InterconnectConfig { mem_column_period: 3, ..Default::default() },
            apps: vec!["pointwise".into()],
            flow: FlowParams {
                sa: SaParams { moves_per_node: 4, ..Default::default() },
                ..Default::default()
            },
            ..Default::default()
        };
        let mut engine = DseEngine::in_memory();
        let out = engine.run(&spec, &NativePlacer::default()).unwrap();
        let doc = Json::parse(&outcome_json(&out).render_line()).unwrap();
        let points = doc.get("points").and_then(Json::as_arr).unwrap();
        assert_eq!(points.len(), out.points.len());
        for (wire, (_, direct)) in points.iter().zip(&out.points) {
            let back = point_result_from_json(wire).unwrap();
            assert_eq!(&back, direct);
            assert_eq!(back.runtime_ns.to_bits(), direct.runtime_ns.to_bits());
            assert_eq!(back.critical_path_ps.to_bits(), direct.critical_path_ps.to_bits());
        }
    }
}
