//! The `/dash` page: one self-contained HTML document with inline SVG
//! charts over the daemon's [`MetricsHistory`] ring, the current
//! metrics snapshot, and the persisted Pareto archive.
//!
//! Self-contained is the contract: no external JS, CSS, fonts, or
//! images — the page is a single `String` a browser renders offline,
//! so `curl http://daemon/dash > dash.html` is a complete artifact of
//! a run. Charts are plain SVG polylines/rects/circles computed here;
//! there is no client-side code at all (reload for fresh data — the
//! live view is `canal client --watch`/`--dash`).
//!
//! [`MetricsHistory`]: crate::obs::MetricsHistory

use crate::obs;
use crate::obs::metrics::MetricValue;
use crate::obs::HistorySample;
use crate::util::json::Json;

/// Chart canvas size (one size fits every panel; the page scales them
/// with CSS width).
const CHART_W: f64 = 560.0;
const CHART_H: f64 = 120.0;
/// Inset so strokes at the extremes stay visible.
const PAD: f64 = 4.0;

/// Escape a string for HTML text/attribute context.
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '&' => out.push_str("&amp;"),
            '<' => out.push_str("&lt;"),
            '>' => out.push_str("&gt;"),
            '"' => out.push_str("&quot;"),
            _ => out.push(c),
        }
    }
    out
}

/// Compact human number (charts and table cells).
fn fmt_num(v: f64) -> String {
    if !v.is_finite() {
        return "-".into();
    }
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v:.2}")
    }
}

/// Map `values` onto an SVG polyline `points` attribute, y-flipped
/// (SVG grows downward) and scaled to `[vmin, vmax]`. Non-finite
/// values clamp to `vmin` rather than poisoning the path.
fn polyline_points(values: &[f64], vmin: f64, vmax: f64) -> String {
    let n = values.len();
    if n == 0 {
        return String::new();
    }
    let span = (vmax - vmin).max(1e-9);
    let dx = if n > 1 { (CHART_W - 2.0 * PAD) / (n - 1) as f64 } else { 0.0 };
    let mut out = String::new();
    for (i, &v) in values.iter().enumerate() {
        let v = if v.is_finite() { v.clamp(vmin, vmax) } else { vmin };
        let x = PAD + dx * i as f64;
        let y = CHART_H - PAD - (v - vmin) / span * (CHART_H - 2.0 * PAD);
        if i > 0 {
            out.push(' ');
        }
        out.push_str(&format!("{x:.1},{y:.1}"));
    }
    out
}

/// One `<svg>` line chart with any number of named series on a shared
/// y-scale (computed from the data, floored at zero).
fn line_chart(title: &str, series: &[(&str, &str, Vec<f64>)]) -> String {
    let mut vmax = 0.0f64;
    for (_, _, values) in series {
        for &v in values {
            if v.is_finite() {
                vmax = vmax.max(v);
            }
        }
    }
    let vmax = if vmax > 0.0 { vmax } else { 1.0 };
    let mut svg = format!(
        "<svg viewBox=\"0 0 {CHART_W} {CHART_H}\" role=\"img\" aria-label=\"{}\">\
         <rect x=\"0\" y=\"0\" width=\"{CHART_W}\" height=\"{CHART_H}\" class=\"bg\"/>",
        esc(title)
    );
    for (_, color, values) in series {
        let pts = polyline_points(values, 0.0, vmax);
        if !pts.is_empty() {
            svg.push_str(&format!(
                "<polyline fill=\"none\" stroke=\"{color}\" stroke-width=\"1.5\" points=\"{pts}\"/>"
            ));
        }
    }
    svg.push_str("</svg>");
    let legend: Vec<String> = series
        .iter()
        .map(|(name, color, values)| {
            let last = values.iter().rev().find(|v| v.is_finite()).copied().unwrap_or(0.0);
            format!(
                "<span class=\"key\"><span class=\"swatch\" style=\"background:{color}\"></span>{} {}</span>",
                esc(name),
                fmt_num(last)
            )
        })
        .collect();
    format!(
        "<section><h2>{}</h2>{svg}<p class=\"legend\">peak {} · {}</p></section>",
        esc(title),
        fmt_num(vmax),
        legend.join(" ")
    )
}

/// Per-sample deltas summed over every counter whose name starts with
/// `prefix`.
fn counter_delta_series(samples: &[HistorySample], prefix: &str) -> Vec<f64> {
    samples
        .iter()
        .map(|s| {
            s.counters
                .iter()
                .filter(|(n, _)| n.starts_with(prefix))
                .map(|(_, d)| *d as f64)
                .sum()
        })
        .collect()
}

/// One quantile field of a named histogram across the samples
/// (`f64::NAN` where the histogram is absent — the polyline clamps).
fn quantile_series(
    samples: &[HistorySample],
    name: &str,
    pick: impl Fn(&crate::obs::history::QuantilePoint) -> f64,
) -> Vec<f64> {
    samples
        .iter()
        .map(|s| {
            s.quantiles
                .iter()
                .find(|(n, _)| n == name)
                .map(|(_, q)| pick(q))
                .unwrap_or(f64::NAN)
        })
        .collect()
}

/// The per-worker utilization timeline: one polyline per worker over
/// every sample that carried live-sweep progress (gaps between sweeps
/// read as 0%).
fn utilization_chart(samples: &[HistorySample]) -> String {
    let workers = samples
        .iter()
        .filter_map(|s| s.progress.as_ref())
        .map(|p| p.worker_util_pct.len())
        .max()
        .unwrap_or(0);
    if workers == 0 {
        return "<section><h2>worker utilization</h2><p class=\"empty\">no sweep has run \
                yet — utilization appears while a sweep is live</p></section>"
            .into();
    }
    const PALETTE: [&str; 6] =
        ["#2f6fde", "#d9822b", "#2e9e44", "#c43d56", "#7b51c9", "#1d9e9e"];
    let mut series: Vec<(String, &str, Vec<f64>)> = Vec::with_capacity(workers);
    for w in 0..workers {
        let values: Vec<f64> = samples
            .iter()
            .map(|s| {
                s.progress
                    .as_ref()
                    .and_then(|p| p.worker_util_pct.get(w))
                    .map(|&pct| f64::from(pct))
                    .unwrap_or(0.0)
            })
            .collect();
        series.push((format!("w{w}"), PALETTE[w % PALETTE.len()], values));
    }
    let named: Vec<(&str, &str, Vec<f64>)> =
        series.iter().map(|(n, c, v)| (n.as_str(), *c, v.clone())).collect();
    line_chart("worker utilization (%)", &named)
}

/// The live (or most recent) sweep as progress bars.
fn progress_section(samples: &[HistorySample]) -> String {
    let Some(p) = samples.iter().rev().find_map(|s| s.progress.as_ref()) else {
        return String::new();
    };
    let bar = |label: &str, done: u64, total: u64| {
        let frac = if total > 0 { done as f64 / total as f64 } else { 0.0 };
        let w = (CHART_W - 2.0 * PAD) * frac.clamp(0.0, 1.0);
        format!(
            "<p class=\"barlabel\">{label}: {done}/{total}</p>\
             <svg viewBox=\"0 0 {CHART_W} 14\"><rect x=\"{PAD}\" y=\"2\" \
             width=\"{:.1}\" height=\"10\" class=\"bg\"/><rect x=\"{PAD}\" y=\"2\" \
             width=\"{w:.1}\" height=\"10\" fill=\"#2e9e44\"/></svg>",
            CHART_W - 2.0 * PAD
        )
    };
    format!(
        "<section><h2>sweep progress</h2>{}{}<p class=\"legend\">{} cached · {} \
         coalesced · {} warm-started</p></section>",
        bar("jobs", p.jobs_done, p.jobs_total),
        bar("cold points", p.cold_done, p.cold_total),
        p.cache_hits,
        p.coalesced,
        p.warm_starts
    )
}

/// The Pareto frontier as an area×period scatter (one circle per
/// archive entry).
fn frontier_chart(archive: &Json) -> String {
    let entries = archive.get("entries").and_then(Json::as_arr);
    let points: Vec<(f64, f64, String)> = entries
        .map(|es| {
            es.iter()
                .filter_map(|e| {
                    let area = e.get("area_um2").and_then(Json::as_f64)?;
                    let period = e.get("period_ps").and_then(Json::as_f64)?;
                    if !area.is_finite() || !period.is_finite() {
                        return None;
                    }
                    let label = e.get("config").and_then(Json::as_str).unwrap_or("?");
                    Some((area, period, label.to_string()))
                })
                .collect()
        })
        .unwrap_or_default();
    if points.is_empty() {
        return "<section><h2>pareto frontier</h2><p class=\"empty\">archive is empty — \
                run <code>canal client tune</code> against a file-backed daemon</p>\
                </section>"
            .into();
    }
    let (mut amin, mut amax, mut pmin, mut pmax) =
        (f64::INFINITY, f64::NEG_INFINITY, f64::INFINITY, f64::NEG_INFINITY);
    for (a, p, _) in &points {
        amin = amin.min(*a);
        amax = amax.max(*a);
        pmin = pmin.min(*p);
        pmax = pmax.max(*p);
    }
    let aspan = (amax - amin).max(1e-9);
    let pspan = (pmax - pmin).max(1e-9);
    let mut svg = format!(
        "<svg viewBox=\"0 0 {CHART_W} {CHART_H}\" role=\"img\" aria-label=\"pareto \
         frontier\"><rect x=\"0\" y=\"0\" width=\"{CHART_W}\" height=\"{CHART_H}\" \
         class=\"bg\"/>"
    );
    for (a, p, label) in &points {
        let x = PAD + (a - amin) / aspan * (CHART_W - 2.0 * PAD);
        let y = CHART_H - PAD - (p - pmin) / pspan * (CHART_H - 2.0 * PAD);
        svg.push_str(&format!(
            "<circle cx=\"{x:.1}\" cy=\"{y:.1}\" r=\"3.5\" fill=\"#2f6fde\">\
             <title>{}: {} µm² × {} ps</title></circle>",
            esc(label),
            fmt_num(*a),
            fmt_num(*p)
        ));
    }
    svg.push_str("</svg>");
    format!(
        "<section><h2>pareto frontier</h2>{svg}<p class=\"legend\">{} entries · area \
         {}–{} µm² · period {}–{} ps</p></section>",
        points.len(),
        fmt_num(amin),
        fmt_num(amax),
        fmt_num(pmin),
        fmt_num(pmax)
    )
}

/// The current registry snapshot as a table (the page's "live counter
/// values" — what smoke tests grep for).
fn metrics_table(metrics: &[(String, MetricValue)]) -> String {
    let mut rows = String::new();
    for (name, value) in metrics {
        let (kind, rendered) = match value {
            MetricValue::Counter(v) => ("counter", fmt_num(*v as f64)),
            MetricValue::Gauge(v) => ("gauge", v.to_string()),
            MetricValue::Histogram(h) => (
                "histogram",
                format!(
                    "n={} p50={} p90={} p99={}",
                    h.count,
                    fmt_num(h.p50),
                    fmt_num(h.p90),
                    fmt_num(h.p99)
                ),
            ),
        };
        rows.push_str(&format!(
            "<tr><td>{}</td><td>{kind}</td><td>{rendered}</td></tr>",
            esc(name)
        ));
    }
    format!(
        "<section><h2>metrics</h2><table><thead><tr><th>metric</th><th>type</th>\
         <th>value</th></tr></thead><tbody>{rows}</tbody></table></section>"
    )
}

/// Render the whole dashboard page.
///
/// Pure function of its inputs (plus the "generated at" stamp), so unit
/// tests drive it without a socket; the server calls it with the live
/// ring, the live registry snapshot, and the archive file's contents.
pub fn dash_page(
    samples: &[HistorySample],
    metrics: &[(String, MetricValue)],
    archive: &Json,
) -> String {
    let requests = counter_delta_series(samples, "service.request.");
    let latency = vec![
        (
            "p50",
            "#2e9e44",
            quantile_series(samples, "service.request.latency_us", |q| q.p50),
        ),
        (
            "p90",
            "#d9822b",
            quantile_series(samples, "service.request.latency_us", |q| q.p90),
        ),
        (
            "p99",
            "#c43d56",
            quantile_series(samples, "service.request.latency_us", |q| q.p99),
        ),
    ];
    let hits = counter_delta_series(samples, "engine.cache_hits");
    let jobs = counter_delta_series(samples, "engine.jobs");
    let hit_rate: Vec<f64> = hits
        .iter()
        .zip(&jobs)
        .map(|(&h, &j)| if j > 0.0 { h / j * 100.0 } else { 0.0 })
        .collect();
    let (total_hits, total_jobs) = metrics.iter().fold((0u64, 0u64), |acc, (n, v)| {
        match (n.as_str(), v) {
            ("engine.cache_hits", MetricValue::Counter(c)) => (acc.0 + c, acc.1),
            ("engine.jobs", MetricValue::Counter(c)) => (acc.0, acc.1 + c),
            _ => acc,
        }
    });
    let lifetime_rate = if total_jobs > 0 {
        format!("{:.1}% lifetime ({total_hits}/{total_jobs})", total_hits as f64
            / total_jobs as f64
            * 100.0)
    } else {
        "no jobs yet".into()
    };

    let mut body = String::new();
    body.push_str(&line_chart(
        "requests per sample",
        &[("requests", "#2f6fde", requests)],
    ));
    body.push_str(&line_chart("request latency (µs)", &latency));
    body.push_str(&line_chart(
        "dse cache hit rate (%)",
        &[("hit rate", "#7b51c9", hit_rate)],
    ));
    body.push_str(&format!("<p class=\"legend\">{}</p>", esc(&lifetime_rate)));
    body.push_str(&progress_section(samples));
    body.push_str(&utilization_chart(samples));
    body.push_str(&frontier_chart(archive));
    body.push_str(&metrics_table(metrics));

    let style = "body{font-family:ui-monospace,monospace;margin:1.5rem auto;max-width:620px;\
                 color:#222;background:#fdfdfc}h1{font-size:1.3rem}h2{font-size:0.95rem;\
                 margin:1.2rem 0 0.3rem}svg{width:100%;height:auto;display:block}\
                 .bg{fill:#f0f0ee}.legend{font-size:0.75rem;color:#666;margin:0.2rem 0}\
                 .key{margin-right:0.8rem}.swatch{display:inline-block;width:0.7em;\
                 height:0.7em;margin-right:0.25em}.barlabel{font-size:0.75rem;margin:0.4rem 0 0.1rem}\
                 .empty{font-size:0.8rem;color:#888}table{border-collapse:collapse;\
                 font-size:0.75rem;width:100%}th,td{text-align:left;padding:0.15rem 0.5rem;\
                 border-bottom:1px solid #eee}";
    format!(
        "<!DOCTYPE html><html lang=\"en\"><head><meta charset=\"utf-8\">\
         <title>canal dash</title><style>{style}</style></head><body>\
         <h1>canal dash</h1><p class=\"legend\">generated at ts_ms {} · mono_ns {} · \
         {} history samples · reload for fresh data</p>{body}</body></html>",
        obs::now_ms(),
        obs::now_ns(),
        samples.len()
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::history::{ProgressSample, QuantilePoint};

    fn sample(seq: u64, with_progress: bool) -> HistorySample {
        HistorySample {
            seq,
            ts_ms: 1_754_640_000_000 + seq,
            mono_ns: seq * 1_000_000,
            counters: vec![
                ("engine.cache_hits".into(), 3),
                ("engine.jobs".into(), 4),
                ("service.request.dse".into(), 2),
            ],
            gauges: vec![("service.queue.depth".into(), 1)],
            quantiles: vec![(
                "service.request.latency_us".into(),
                QuantilePoint { count_delta: 2, p50: 120.0, p90: 300.0, p99: 900.0 },
            )],
            progress: with_progress.then(|| ProgressSample {
                jobs_total: 8,
                jobs_done: 4,
                cache_hits: 2,
                cold_total: 6,
                cold_done: 2,
                worker_util_pct: vec![93, 88],
                ..Default::default()
            }),
        }
    }

    fn archive() -> Json {
        Json::parse(
            "{\"version\":1,\"entries\":[{\"config\":\"t2\",\"area_um2\":1200.5,\
             \"period_ps\":850.0},{\"config\":\"t4\",\"area_um2\":2400.0,\
             \"period_ps\":610.0}]}",
        )
        .unwrap()
    }

    #[test]
    fn page_is_self_contained_html_with_charts() {
        let samples = vec![sample(0, false), sample(1, true), sample(2, true)];
        let metrics = vec![
            ("engine.cache_hits".into(), MetricValue::Counter(9)),
            ("engine.jobs".into(), MetricValue::Counter(12)),
            ("service.request.dse".into(), MetricValue::Counter(6)),
        ];
        let page = dash_page(&samples, &metrics, &archive());
        assert!(page.starts_with("<!DOCTYPE html>"));
        assert!(page.contains("<svg"), "charts must be inline SVG");
        assert!(page.contains("polyline"), "line charts present");
        assert!(page.contains("service.request.dse"), "live counters in the table");
        assert!(page.contains("worker utilization"), "util timeline present");
        assert!(page.contains("pareto frontier"));
        assert!(page.contains("<circle"), "frontier scatter has points");
        assert!(page.contains("75.0% lifetime (9/12)"));
        // Self-contained: no external fetches of any kind.
        assert!(!page.contains("<script"));
        assert!(!page.contains("<link"));
        assert!(!page.contains("http://") && !page.contains("https://"));
    }

    #[test]
    fn empty_inputs_render_a_valid_page() {
        let page = dash_page(&[], &[], &Json::Obj(vec![]));
        assert!(page.contains("<svg"), "charts render even with no data");
        assert!(page.contains("archive is empty"));
        assert!(page.contains("no sweep has run yet"));
        assert!(page.contains("0 history samples"));
    }

    #[test]
    fn non_finite_values_never_reach_the_svg() {
        let mut s = sample(0, false);
        s.quantiles = vec![(
            "service.request.latency_us".into(),
            QuantilePoint {
                count_delta: 1,
                p50: f64::NAN,
                p90: f64::INFINITY,
                p99: 1.0,
            },
        )];
        let page = dash_page(&[s], &[], &Json::Obj(vec![]));
        assert!(!page.contains("NaN") && !page.contains("inf"), "values are clamped");
    }
}
