//! The frozen routing graph: an immutable, cache-friendly CSR view.
//!
//! [`super::graph::RoutingGraph`] is the *builder-facing* IR: adjacency as
//! `Vec<Vec<NodeId>>`, wire delays in a `HashMap` — convenient to grow one
//! edge at a time from the eDSL, hostile to the PnR/timing/simulation hot
//! loops that traverse it millions of times per design-space sweep.
//! [`CompiledGraph`] is the same graph *frozen*: compressed-sparse-row
//! adjacency in both directions, wire delays in flat arrays parallel to
//! the CSR edge arrays (no hashing on an edge relaxation), and dense
//! per-node attribute arrays (coordinates, intrinsic delay, kind flags).
//!
//! # The freeze contract
//!
//! Lowering is purely structural — `compile` never reorders anything:
//!
//! - **Fan-in order is mux-select order.** `fan_in(n)[k]` is the driver
//!   that select value `k` routes onto `n`, exactly as in the builder
//!   graph, where the position of an incoming edge in insertion order *is*
//!   the select encoding the bitstream generator emits. A routing result
//!   therefore produces a bit-identical bitstream whether its selects are
//!   derived from the builder graph or the compiled one.
//! - **Fan-out order is insertion order** too, so edge iteration (and
//!   with it A* tie-breaking, hence routing determinism) is unchanged.
//! - **Node ids are shared.** `NodeId` indexes both representations; a
//!   path computed on one is valid on the other.
//!
//! The compiled view is immutable by construction (no `&mut` API) and all
//! of its storage is plain `Vec`s of POD, so it is `Send + Sync`: one
//! frozen interconnect can be shared by reference across every PnR thread
//! of a design-space sweep — the foundation for parallel/sharded DSE.
//! Mutating the builder graph after a freeze does *not* update the
//! compiled view; [`super::interconnect::Interconnect::graph_mut`] drops
//! stale compiled graphs and the owner must re-freeze.

use super::graph::RoutingGraph;
use super::node::NodeId;

/// Per-node kind flags (dense `u8` instead of the fat `NodeKind` enum).
const FLAG_PORT: u8 = 1 << 0;
const FLAG_REGISTER: u8 = 1 << 1;

/// An immutable CSR-packed routing graph of one bit width.
#[derive(Clone, Debug)]
pub struct CompiledGraph {
    /// Bit width carried by every node in this graph.
    pub width: u8,
    n: usize,
    // --- CSR fan-out ---------------------------------------------------
    /// `out_offsets[i]..out_offsets[i+1]` slices the fan-out of node `i`.
    out_offsets: Vec<u32>,
    out_targets: Vec<NodeId>,
    /// Wire delay (ps) of the edge at the same CSR position.
    out_delays: Vec<u32>,
    // --- CSR fan-in (position = mux-select encoding) -------------------
    in_offsets: Vec<u32>,
    in_sources: Vec<NodeId>,
    in_delays: Vec<u32>,
    // --- Dense per-node attributes -------------------------------------
    xs: Vec<u16>,
    ys: Vec<u16>,
    node_delays: Vec<u32>,
    flags: Vec<u8>,
    /// Largest outgoing wire delay per node (precomputed for the router's
    /// base-cost model; 0 for sink nodes).
    max_out_wire: Vec<u32>,
}

impl CompiledGraph {
    /// Freeze a builder graph. Insertion order of both adjacency
    /// directions is preserved exactly (see the module docs).
    pub fn compile(g: &RoutingGraph) -> CompiledGraph {
        let n = g.len();
        let edges = g.edge_count();

        let mut out_offsets = Vec::with_capacity(n + 1);
        let mut out_targets = Vec::with_capacity(edges);
        let mut out_delays = Vec::with_capacity(edges);
        let mut in_offsets = Vec::with_capacity(n + 1);
        let mut in_sources = Vec::with_capacity(edges);
        let mut in_delays = Vec::with_capacity(edges);
        let mut xs = Vec::with_capacity(n);
        let mut ys = Vec::with_capacity(n);
        let mut node_delays = Vec::with_capacity(n);
        let mut flags = Vec::with_capacity(n);
        let mut max_out_wire = Vec::with_capacity(n);

        for (id, node) in g.iter() {
            out_offsets.push(out_targets.len() as u32);
            let mut max_wire = 0u32;
            for &to in g.fan_out(id) {
                let w = g.wire_delay(id, to);
                max_wire = max_wire.max(w);
                out_targets.push(to);
                out_delays.push(w);
            }
            in_offsets.push(in_sources.len() as u32);
            for &from in g.fan_in(id) {
                in_sources.push(from);
                in_delays.push(g.wire_delay(from, id));
            }
            xs.push(node.x);
            ys.push(node.y);
            node_delays.push(node.delay_ps);
            let mut f = 0u8;
            if node.kind.is_port() {
                f |= FLAG_PORT;
            }
            if node.kind.is_register() {
                f |= FLAG_REGISTER;
            }
            flags.push(f);
            max_out_wire.push(max_wire);
        }
        out_offsets.push(out_targets.len() as u32);
        in_offsets.push(in_sources.len() as u32);

        CompiledGraph {
            width: g.width,
            n,
            out_offsets,
            out_targets,
            out_delays,
            in_offsets,
            in_sources,
            in_delays,
            xs,
            ys,
            node_delays,
            flags,
            max_out_wire,
        }
    }

    /// Number of nodes.
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Total number of directed edges.
    pub fn edge_count(&self) -> usize {
        self.out_targets.len()
    }

    /// All node ids.
    pub fn ids(&self) -> impl Iterator<Item = NodeId> + 'static {
        (0..self.n as u32).map(NodeId)
    }

    /// Nodes driven by `id`, in the builder graph's insertion order.
    #[inline]
    pub fn fan_out(&self, id: NodeId) -> &[NodeId] {
        let i = id.index();
        &self.out_targets[self.out_offsets[i] as usize..self.out_offsets[i + 1] as usize]
    }

    /// Wire delays (ps) parallel to [`Self::fan_out`].
    #[inline]
    pub fn out_wire_delays(&self, id: NodeId) -> &[u32] {
        let i = id.index();
        &self.out_delays[self.out_offsets[i] as usize..self.out_offsets[i + 1] as usize]
    }

    /// Drivers of `id` in mux-select order.
    #[inline]
    pub fn fan_in(&self, id: NodeId) -> &[NodeId] {
        let i = id.index();
        &self.in_sources[self.in_offsets[i] as usize..self.in_offsets[i + 1] as usize]
    }

    /// Wire delays (ps) parallel to [`Self::fan_in`].
    #[inline]
    pub fn in_wire_delays(&self, id: NodeId) -> &[u32] {
        let i = id.index();
        &self.in_delays[self.in_offsets[i] as usize..self.in_offsets[i + 1] as usize]
    }

    /// Wire delay of edge `(from, to)`; panics if the edge does not exist
    /// (the same contract as `RoutingGraph::wire_delay`). Fan-outs are
    /// small (a handful of sinks), so the scan beats any hash.
    #[inline]
    pub fn wire_delay(&self, from: NodeId, to: NodeId) -> u32 {
        let outs = self.fan_out(from);
        let k = outs
            .iter()
            .position(|&t| t == to)
            .unwrap_or_else(|| panic!("no edge {from} -> {to}"));
        self.out_wire_delays(from)[k]
    }

    /// Mux-select value that routes `driver` onto `id`, if connected.
    #[inline]
    pub fn select_of(&self, id: NodeId, driver: NodeId) -> Option<usize> {
        self.fan_in(id).iter().position(|&d| d == driver)
    }

    /// Tile x coordinate of a node.
    #[inline]
    pub fn x(&self, id: NodeId) -> u16 {
        self.xs[id.index()]
    }

    /// Tile y coordinate of a node.
    #[inline]
    pub fn y(&self, id: NodeId) -> u16 {
        self.ys[id.index()]
    }

    /// Intrinsic node delay in ps (mux delay, register clk-q, ...).
    #[inline]
    pub fn node_delay_ps(&self, id: NodeId) -> u32 {
        self.node_delays[id.index()]
    }

    /// Is this a core-port node?
    #[inline]
    pub fn is_port(&self, id: NodeId) -> bool {
        self.flags[id.index()] & FLAG_PORT != 0
    }

    /// Is this a pipeline-register node?
    #[inline]
    pub fn is_register(&self, id: NodeId) -> bool {
        self.flags[id.index()] & FLAG_REGISTER != 0
    }

    /// Largest outgoing wire delay of a node (0 for sinks). Precomputed
    /// so the router's base-cost pass is hash-free.
    #[inline]
    pub fn max_out_wire_delay(&self, id: NodeId) -> u32 {
        self.max_out_wire[id.index()]
    }

    /// Delay along one path (node delays + wire delays), ps.
    pub fn path_delay(&self, path: &[NodeId]) -> f64 {
        let mut d = 0.0;
        for (i, &n) in path.iter().enumerate() {
            d += self.node_delays[n.index()] as f64;
            if i + 1 < path.len() {
                d += self.wire_delay(n, path[i + 1]) as f64;
            }
        }
        d
    }
}

impl RoutingGraph {
    /// Freeze this builder graph into an immutable CSR view.
    pub fn compile(&self) -> CompiledGraph {
        CompiledGraph::compile(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::node::{Node, NodeKind, SbIo, Side};

    fn sb(x: u16, y: u16, side: Side, io: SbIo, track: u16) -> Node {
        Node::new(NodeKind::SwitchBox { side, io, track }, x, y, 16, 40)
    }

    fn diamond() -> (RoutingGraph, [NodeId; 4]) {
        // a -> c, b -> c (mux), c -> d, a -> d (mux on d too)
        let mut g = RoutingGraph::new(16);
        let a = g.add_node(sb(0, 0, Side::North, SbIo::In, 0));
        let b = g.add_node(sb(0, 0, Side::South, SbIo::In, 0));
        let c = g.add_node(sb(0, 0, Side::East, SbIo::Out, 0));
        let d = g.add_node(sb(1, 0, Side::West, SbIo::In, 0));
        g.connect_with_delay(a, c, 10);
        g.connect_with_delay(b, c, 20);
        g.connect_with_delay(c, d, 90);
        g.connect_with_delay(a, d, 5);
        (g, [a, b, c, d])
    }

    #[test]
    fn csr_preserves_adjacency_and_order() {
        let (g, [a, b, c, d]) = diamond();
        let cg = g.compile();
        assert_eq!(cg.len(), 4);
        assert_eq!(cg.edge_count(), 4);
        assert_eq!(cg.fan_out(a), &[c, d]);
        assert_eq!(cg.fan_in(c), &[a, b]);
        assert_eq!(cg.fan_in(d), &[c, a]);
        assert_eq!(cg.select_of(c, a), Some(0));
        assert_eq!(cg.select_of(c, b), Some(1));
        assert_eq!(cg.select_of(d, a), Some(1));
        assert_eq!(cg.select_of(c, c), None);
    }

    #[test]
    fn delays_align_with_csr_positions() {
        let (g, [a, b, c, d]) = diamond();
        let cg = g.compile();
        assert_eq!(cg.wire_delay(a, c), 10);
        assert_eq!(cg.wire_delay(b, c), 20);
        assert_eq!(cg.wire_delay(c, d), 90);
        assert_eq!(cg.wire_delay(a, d), 5);
        assert_eq!(cg.out_wire_delays(a), &[10, 5]);
        assert_eq!(cg.in_wire_delays(c), &[10, 20]);
        assert_eq!(cg.max_out_wire_delay(a), 10);
        assert_eq!(cg.max_out_wire_delay(c), 90);
        assert_eq!(cg.max_out_wire_delay(d), 0);
    }

    #[test]
    fn node_attributes_are_dense_copies() {
        let (g, [a, _, c, d]) = diamond();
        let cg = g.compile();
        assert_eq!((cg.x(d), cg.y(d)), (1, 0));
        assert_eq!(cg.node_delay_ps(a), 40);
        assert!(!cg.is_port(c));
        assert!(!cg.is_register(c));
    }

    #[test]
    fn path_delay_matches_builder_graph() {
        let (g, [a, _, c, d]) = diamond();
        let cg = g.compile();
        let path = [a, c, d];
        let manual: f64 = path.iter().map(|&n| g.node(n).delay_ps as f64).sum::<f64>()
            + path.windows(2).map(|w| g.wire_delay(w[0], w[1]) as f64).sum::<f64>();
        assert_eq!(cg.path_delay(&path), manual);
    }

    #[test]
    fn compiled_graph_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<CompiledGraph>();
    }

    #[test]
    #[should_panic(expected = "no edge")]
    fn missing_edge_delay_panics_like_builder() {
        let (g, [a, b, ..]) = diamond();
        g.compile().wire_delay(b, a);
    }
}
