//! The routing graph: Canal's graph-based IR (§3.1).
//!
//! One `RoutingGraph` holds all nodes and wires of a single bit width
//! (real interconnects instantiate one graph per track width, e.g. a
//! 16-bit data layer and a 1-bit control layer). Nodes live in an arena
//! indexed by [`NodeId`]; edges are adjacency lists kept in *insertion
//! order* — the position of an incoming edge is the mux-select value the
//! bitstream generator emits, so order is part of the architecture.
//!
//! This is the *builder-facing* representation. Once construction is
//! done it is frozen into the immutable CSR
//! [`super::compiled::CompiledGraph`] (via [`RoutingGraph::compile`] /
//! `Interconnect::freeze`), which every PnR, timing and simulation hot
//! path consumes.

use std::collections::HashMap;

use super::node::{Node, NodeId, NodeKind, SbIo, Side};

/// Key used to find a node by (tile, kind) — the IR analogue of the
/// `Node(x=1, y=1, side="south", track=1)` lookup in the paper's Fig. 4.
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
pub struct NodeKey {
    pub x: u16,
    pub y: u16,
    pub kind: NodeKind,
}

/// Directed routing graph for one bit width.
#[derive(Clone, Debug, Default)]
pub struct RoutingGraph {
    /// Bit width carried by every node in this graph.
    pub width: u8,
    nodes: Vec<Node>,
    /// `edges_out[n]` = nodes driven by `n`, in insertion order.
    edges_out: Vec<Vec<NodeId>>,
    /// `edges_in[n]` = drivers of `n`, in insertion order. The index of a
    /// driver in this list is its mux-select encoding.
    edges_in: Vec<Vec<NodeId>>,
    /// Per-edge wire delay in ps, keyed by (from, to).
    wire_delay: HashMap<(NodeId, NodeId), u32>,
    /// Edges whose delay was given explicitly (via `connect_with_delay`).
    /// `connect` defaults to 0 ps, which is right for intra-tile wiring
    /// but a silent lie on a tile crossing — validation flags cross-tile
    /// edges that were never given an explicit delay, while an explicit
    /// 0 (an idealized delay model) stays legal.
    explicit_delay: std::collections::HashSet<(NodeId, NodeId)>,
    /// Reverse lookup from (x, y, kind).
    index: HashMap<NodeKey, NodeId>,
}

impl RoutingGraph {
    pub fn new(width: u8) -> Self {
        RoutingGraph { width, ..Default::default() }
    }

    /// Number of nodes.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Add a node; panics if an identical (x, y, kind) node already exists
    /// or if the node's width disagrees with the graph's width.
    pub fn add_node(&mut self, node: Node) -> NodeId {
        assert_eq!(
            node.width, self.width,
            "node width {} does not match graph width {}",
            node.width, self.width
        );
        let key = NodeKey { x: node.x, y: node.y, kind: node.kind.clone() };
        assert!(
            !self.index.contains_key(&key),
            "duplicate node {} at ({}, {})",
            node.kind.label(),
            node.x,
            node.y
        );
        let id = NodeId(self.nodes.len() as u32);
        self.index.insert(key, id);
        self.nodes.push(node);
        self.edges_out.push(Vec::new());
        self.edges_in.push(Vec::new());
        id
    }

    /// Connect `from -> to` with an explicit wire delay. Duplicate edges
    /// are rejected (they would create ambiguous mux selects).
    pub fn connect_with_delay(&mut self, from: NodeId, to: NodeId, delay_ps: u32) {
        self.connect_inner(from, to, delay_ps);
        self.explicit_delay.insert((from, to));
    }

    /// Connect with zero wire delay (intra-tile wiring).
    pub fn connect(&mut self, from: NodeId, to: NodeId) {
        self.connect_inner(from, to, 0);
    }

    fn connect_inner(&mut self, from: NodeId, to: NodeId, delay_ps: u32) {
        assert_ne!(from, to, "self-loop on {}", self.node(from).qualified_name());
        assert!(
            !self.edges_out[from.index()].contains(&to),
            "duplicate edge {} -> {}",
            self.node(from).qualified_name(),
            self.node(to).qualified_name()
        );
        self.edges_out[from.index()].push(to);
        self.edges_in[to.index()].push(from);
        self.wire_delay.insert((from, to), delay_ps);
    }

    /// Was this edge's delay given explicitly (rather than defaulted to 0
    /// by [`Self::connect`])? Consumed by validation to catch tile
    /// crossings whose delay was never modeled.
    pub fn has_explicit_delay(&self, from: NodeId, to: NodeId) -> bool {
        self.explicit_delay.contains(&(from, to))
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.index()]
    }

    pub fn node_mut(&mut self, id: NodeId) -> &mut Node {
        &mut self.nodes[id.index()]
    }

    /// Drivers of `id` in mux-select order.
    pub fn fan_in(&self, id: NodeId) -> &[NodeId] {
        &self.edges_in[id.index()]
    }

    /// Nodes driven by `id`.
    pub fn fan_out(&self, id: NodeId) -> &[NodeId] {
        &self.edges_out[id.index()]
    }

    /// Wire delay of edge `(from, to)`; panics if absent.
    pub fn wire_delay(&self, from: NodeId, to: NodeId) -> u32 {
        self.wire_delay[&(from, to)]
    }

    /// Mux-select value that routes `driver` onto `id`, if connected.
    pub fn select_of(&self, id: NodeId, driver: NodeId) -> Option<usize> {
        self.fan_in(id).iter().position(|&d| d == driver)
    }

    /// Find a node by (x, y, kind).
    pub fn find(&self, x: u16, y: u16, kind: &NodeKind) -> Option<NodeId> {
        self.index.get(&NodeKey { x, y, kind: kind.clone() }).copied()
    }

    /// Convenience: find a switch-box endpoint.
    pub fn find_sb(&self, x: u16, y: u16, side: Side, io: SbIo, track: u16) -> Option<NodeId> {
        self.find(x, y, &NodeKind::SwitchBox { side, io, track })
    }

    /// Convenience: find a core port.
    pub fn find_port(&self, x: u16, y: u16, name: &str, input: bool) -> Option<NodeId> {
        self.find(x, y, &NodeKind::Port { name: name.to_string(), input })
    }

    /// Iterate `(NodeId, &Node)`.
    pub fn iter(&self) -> impl Iterator<Item = (NodeId, &Node)> {
        self.nodes.iter().enumerate().map(|(i, n)| (NodeId(i as u32), n))
    }

    /// All node ids.
    pub fn ids(&self) -> impl Iterator<Item = NodeId> + 'static {
        (0..self.nodes.len() as u32).map(NodeId)
    }

    /// Nodes that lower to multiplexers (fan-in > 1). The heart of the
    /// lowering rule "nodes with multiple incoming edges generate
    /// multiplexers" (§3.3).
    pub fn mux_nodes(&self) -> impl Iterator<Item = NodeId> + '_ {
        self.ids().filter(|id| self.fan_in(*id).len() > 1)
    }

    /// Total number of directed edges.
    pub fn edge_count(&self) -> usize {
        self.edges_out.iter().map(Vec::len).sum()
    }

    /// All edges as (from, to) pairs.
    pub fn edges(&self) -> impl Iterator<Item = (NodeId, NodeId)> + '_ {
        self.ids().flat_map(move |from| {
            self.fan_out(from).iter().map(move |&to| (from, to))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::node::{Node, NodeKind, SbIo, Side};

    fn sb(x: u16, y: u16, side: Side, io: SbIo, track: u16) -> Node {
        Node::new(NodeKind::SwitchBox { side, io, track }, x, y, 16, 40)
    }

    #[test]
    fn add_and_find_roundtrip() {
        let mut g = RoutingGraph::new(16);
        let a = g.add_node(sb(0, 0, Side::North, SbIo::In, 0));
        assert_eq!(g.find_sb(0, 0, Side::North, SbIo::In, 0), Some(a));
        assert_eq!(g.find_sb(0, 0, Side::North, SbIo::Out, 0), None);
        assert_eq!(g.len(), 1);
    }

    #[test]
    fn fan_in_order_is_mux_select_order() {
        let mut g = RoutingGraph::new(16);
        let a = g.add_node(sb(0, 0, Side::North, SbIo::In, 0));
        let b = g.add_node(sb(0, 0, Side::South, SbIo::In, 0));
        let c = g.add_node(sb(0, 0, Side::East, SbIo::Out, 0));
        g.connect(a, c);
        g.connect(b, c);
        assert_eq!(g.fan_in(c), &[a, b]);
        assert_eq!(g.select_of(c, a), Some(0));
        assert_eq!(g.select_of(c, b), Some(1));
        assert_eq!(g.select_of(c, c), None);
    }

    #[test]
    fn mux_nodes_require_multiple_drivers() {
        let mut g = RoutingGraph::new(16);
        let a = g.add_node(sb(0, 0, Side::North, SbIo::In, 0));
        let b = g.add_node(sb(0, 0, Side::South, SbIo::In, 0));
        let c = g.add_node(sb(0, 0, Side::East, SbIo::Out, 0));
        g.connect(a, c);
        assert_eq!(g.mux_nodes().count(), 0);
        g.connect(b, c);
        assert_eq!(g.mux_nodes().collect::<Vec<_>>(), vec![c]);
    }

    #[test]
    #[should_panic(expected = "duplicate node")]
    fn duplicate_nodes_rejected() {
        let mut g = RoutingGraph::new(16);
        g.add_node(sb(1, 1, Side::North, SbIo::In, 0));
        g.add_node(sb(1, 1, Side::North, SbIo::In, 0));
    }

    #[test]
    #[should_panic(expected = "duplicate edge")]
    fn duplicate_edges_rejected() {
        let mut g = RoutingGraph::new(16);
        let a = g.add_node(sb(0, 0, Side::North, SbIo::In, 0));
        let b = g.add_node(sb(0, 0, Side::East, SbIo::Out, 0));
        g.connect(a, b);
        g.connect(a, b);
    }

    #[test]
    #[should_panic(expected = "width")]
    fn width_mismatch_rejected() {
        let mut g = RoutingGraph::new(16);
        g.add_node(Node::new(NodeKind::Port { name: "p".into(), input: true }, 0, 0, 1, 0));
    }

    #[test]
    fn wire_delay_stored_per_edge() {
        let mut g = RoutingGraph::new(16);
        let a = g.add_node(sb(0, 0, Side::East, SbIo::Out, 0));
        let b = g.add_node(sb(1, 0, Side::West, SbIo::In, 0));
        g.connect_with_delay(a, b, 85);
        assert_eq!(g.wire_delay(a, b), 85);
        assert_eq!(g.edge_count(), 1);
        assert_eq!(g.edges().collect::<Vec<_>>(), vec![(a, b)]);
    }
}
