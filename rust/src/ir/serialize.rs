//! IR serialization: the PnR-collateral graph format.
//!
//! Canal's generator emits place-and-route collateral alongside RTL
//! (Fig. 2). This module serializes a routing graph to a line-based text
//! format (one node or edge per line) and parses it back — the analogue
//! of the `.graph` files the Stanford flow hands to its PnR tools. The
//! format round-trips exactly, including fan-in order (= mux select
//! encoding).
//!
//! ```text
//! canal-graph v1 width=16
//! N 0 x=1 y=2 d=45 sb north out t=3
//! N 1 x=1 y=2 d=0  port in data_in_0
//! N 2 x=1 y=2 d=55 reg east t=0
//! N 3 x=1 y=2 d=25 rmux east t=0
//! E 0 1 w=90
//! ```

use crate::ir::{Node, NodeId, NodeKind, RoutingGraph, SbIo, Side};

fn side_of(tok: &str) -> Result<Side, String> {
    match tok {
        "north" => Ok(Side::North),
        "south" => Ok(Side::South),
        "east" => Ok(Side::East),
        "west" => Ok(Side::West),
        other => Err(format!("bad side `{other}`")),
    }
}

/// Serialize one routing graph.
pub fn emit_graph(g: &RoutingGraph) -> String {
    let mut s = format!("canal-graph v1 width={}\n", g.width);
    for (id, n) in g.iter() {
        let kind = match &n.kind {
            NodeKind::SwitchBox { side, io, track } => {
                format!("sb {} {} t={}", side.name(), io.name(), track)
            }
            NodeKind::Port { name, input } => {
                format!("port {} {}", if *input { "in" } else { "out" }, name)
            }
            NodeKind::Register { side, track } => format!("reg {} t={}", side.name(), track),
            NodeKind::RegMux { side, track } => format!("rmux {} t={}", side.name(), track),
        };
        s.push_str(&format!("N {} x={} y={} d={} {}\n", id.0, n.x, n.y, n.delay_ps, kind));
    }
    // Edges in fan-in order per sink so select encodings survive. An
    // edge whose delay was never given explicitly (plain `connect`) is
    // emitted without a `w=` token, so delay-missingness — which the
    // validator flags on tile crossings — survives a round-trip.
    for (id, _) in g.iter() {
        for &src in g.fan_in(id) {
            if g.has_explicit_delay(src, id) {
                s.push_str(&format!("E {} {} w={}\n", src.0, id.0, g.wire_delay(src, id)));
            } else {
                s.push_str(&format!("E {} {}\n", src.0, id.0));
            }
        }
    }
    s
}

fn kv(tok: &str, key: &str) -> Result<u32, String> {
    tok.strip_prefix(key)
        .and_then(|v| v.strip_prefix('='))
        .and_then(|v| v.parse().ok())
        .ok_or_else(|| format!("expected `{key}=<int>`, got `{tok}`"))
}

/// Parse a serialized routing graph.
pub fn parse_graph(text: &str) -> Result<RoutingGraph, String> {
    let mut lines = text.lines().enumerate();
    let (_, header) = lines.next().ok_or("empty graph file")?;
    let width: u8 = header
        .split_whitespace()
        .find_map(|t| t.strip_prefix("width="))
        .and_then(|v| v.parse().ok())
        .ok_or("missing width in header")?;
    if !header.starts_with("canal-graph v1") {
        return Err("unsupported graph format".into());
    }

    let mut g = RoutingGraph::new(width);
    let mut pending_edges: Vec<(NodeId, NodeId, Option<u32>)> = Vec::new();
    let mut max_seen_id: i64 = -1;

    for (lineno, line) in lines {
        let err = |m: String| format!("line {}: {m}", lineno + 1);
        let toks: Vec<&str> = line.split_whitespace().collect();
        match toks.first() {
            Some(&"N") => {
                let id: u32 = toks[1].parse().map_err(|_| err("bad node id".into()))?;
                if id as i64 != max_seen_id + 1 {
                    return Err(err(format!("non-sequential node id {id}")));
                }
                max_seen_id = id as i64;
                let x = kv(toks[2], "x")? as u16;
                let y = kv(toks[3], "y")? as u16;
                let d = kv(toks[4], "d")?;
                let kind = match toks[5] {
                    "sb" => NodeKind::SwitchBox {
                        side: side_of(toks[6])?,
                        io: match toks[7] {
                            "in" => SbIo::In,
                            "out" => SbIo::Out,
                            o => return Err(err(format!("bad io `{o}`"))),
                        },
                        track: kv(toks[8], "t")? as u16,
                    },
                    "port" => NodeKind::Port {
                        input: toks[6] == "in",
                        name: toks[7].to_string(),
                    },
                    "reg" => NodeKind::Register {
                        side: side_of(toks[6])?,
                        track: kv(toks[7], "t")? as u16,
                    },
                    "rmux" => NodeKind::RegMux {
                        side: side_of(toks[6])?,
                        track: kv(toks[7], "t")? as u16,
                    },
                    o => return Err(err(format!("bad node kind `{o}`"))),
                };
                g.add_node(Node::new(kind, x, y, width, d));
            }
            Some(&"E") => {
                let a: u32 = toks[1].parse().map_err(|_| err("bad edge src".into()))?;
                let b: u32 = toks[2].parse().map_err(|_| err("bad edge dst".into()))?;
                // `w=` absent ⇒ an implicit (defaulted) delay, re-created
                // with plain `connect` so validation still sees it as
                // never-explicitly-modeled.
                let w = match toks.get(3) {
                    Some(tok) => Some(kv(tok, "w")?),
                    None => None,
                };
                pending_edges.push((NodeId(a), NodeId(b), w));
            }
            Some(_) | None => continue,
        }
    }
    for (a, b, w) in pending_edges {
        match w {
            Some(w) => g.connect_with_delay(a, b, w),
            None => g.connect(a, b),
        }
    }
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::{create_uniform_interconnect, InterconnectConfig};

    fn graph() -> RoutingGraph {
        let ic = create_uniform_interconnect(&InterconnectConfig {
            width: 3,
            height: 3,
            num_tracks: 2,
            reg_density: 1,
            mem_column_period: 2,
            ..Default::default()
        });
        ic.graphs[&16].clone()
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let g = graph();
        let text = emit_graph(&g);
        let g2 = parse_graph(&text).unwrap();
        assert_eq!(g.len(), g2.len());
        assert_eq!(g.edge_count(), g2.edge_count());
        for (id, n) in g.iter() {
            let n2 = g2.node(id);
            assert_eq!(n.kind, n2.kind);
            assert_eq!((n.x, n.y, n.delay_ps), (n2.x, n2.y, n2.delay_ps));
            // Fan-in order (select encoding) must survive exactly.
            assert_eq!(g.fan_in(id), g2.fan_in(id), "{}", n.qualified_name());
            for &src in g.fan_in(id) {
                assert_eq!(g.wire_delay(src, id), g2.wire_delay(src, id));
                // Delay explicitness (the validator's missing-delay
                // signal) must survive too.
                assert_eq!(
                    g.has_explicit_delay(src, id),
                    g2.has_explicit_delay(src, id),
                    "{}",
                    n.qualified_name()
                );
            }
        }
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_graph("").is_err());
        assert!(parse_graph("not-a-graph v9\n").is_err());
        assert!(parse_graph("canal-graph v1 width=16\nN 5 x=0 y=0 d=0 sb north in t=0\n").is_err());
        assert!(parse_graph("canal-graph v1 width=16\nN 0 x=0 y=0 d=0 frob\n").is_err());
    }

    #[test]
    fn emitted_text_is_stable() {
        let g = graph();
        assert_eq!(emit_graph(&g), emit_graph(&g));
    }
}
