//! Structural validation of the interconnect IR.
//!
//! Canal performs type checking on node attributes (§3.1) and verifies the
//! structural correctness of generated hardware against the IR (§3.3).
//! This module is the first half of that story: invariants the IR itself
//! must satisfy before any lowering happens. The second half (RTL vs IR)
//! lives in `hw::verify`.

use super::interconnect::Interconnect;
use super::node::{NodeKind, SbIo};

/// A violated invariant, with enough context to locate it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Violation {
    pub rule: &'static str,
    pub detail: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{}] {}", self.rule, self.detail)
    }
}

/// Validate every graph of an interconnect. Returns all violations found
/// (empty ⇒ valid).
pub fn validate(ic: &Interconnect) -> Vec<Violation> {
    let mut out = Vec::new();
    for (&bw, g) in &ic.graphs {
        let ctx = |detail: String| format!("width-{bw} graph: {detail}");

        for (id, node) in g.iter() {
            // Coordinates must be inside the array.
            if node.x >= ic.width || node.y >= ic.height {
                out.push(Violation {
                    rule: "node-in-bounds",
                    detail: ctx(format!("{} outside {}x{} array", node.qualified_name(), ic.width, ic.height)),
                });
            }

            let fan_in = g.fan_in(id).len();
            let fan_out = g.fan_out(id).len();

            match &node.kind {
                // SB input endpoints are driven by at most one neighbour
                // tile output (plus nothing else): they are wires, not
                // muxes. Fan-in 0 is legal on array margins.
                NodeKind::SwitchBox { io: SbIo::In, .. } => {
                    if fan_in > 1 {
                        out.push(Violation {
                            rule: "sb-in-single-driver",
                            detail: ctx(format!("{} has fan-in {fan_in}", node.qualified_name())),
                        });
                    }
                }
                // SB outputs must drive exactly one neighbour SB input
                // (or nothing on the margin) and must have at least one
                // driver, otherwise the mux has no inputs. Intra-tile
                // sinks (pipeline register + bypass mux) are exempt from
                // the single-sink rule.
                NodeKind::SwitchBox { io: SbIo::Out, .. } => {
                    if fan_in == 0 {
                        out.push(Violation {
                            rule: "sb-out-has-drivers",
                            detail: ctx(format!("{} has no drivers", node.qualified_name())),
                        });
                    }
                    let sb_sinks = g
                        .fan_out(id)
                        .iter()
                        .filter(|&&s| matches!(g.node(s).kind, NodeKind::SwitchBox { .. }))
                        .count();
                    if sb_sinks > 1 {
                        out.push(Violation {
                            rule: "sb-out-single-sink",
                            detail: ctx(format!(
                                "{} drives {sb_sinks} switch-box nodes",
                                node.qualified_name()
                            )),
                        });
                    }
                    let _ = fan_out;
                }
                // A register has exactly one driver (the SB mux feeding
                // it) and drives exactly one node (its bypass mux).
                NodeKind::Register { .. } => {
                    if fan_in != 1 || fan_out != 1 {
                        out.push(Violation {
                            rule: "register-1-in-1-out",
                            detail: ctx(format!(
                                "{} fan-in {fan_in} fan-out {fan_out}",
                                node.qualified_name()
                            )),
                        });
                    }
                }
                // A register-bypass mux has exactly two drivers: the
                // register and the register's own driver.
                NodeKind::RegMux { .. } => {
                    if fan_in != 2 {
                        out.push(Violation {
                            rule: "regmux-2-drivers",
                            detail: ctx(format!("{} fan-in {fan_in}", node.qualified_name())),
                        });
                    }
                }
                // Output ports are sources; input ports are sinks of the
                // routing fabric.
                NodeKind::Port { input, .. } => {
                    if *input && fan_out != 0 {
                        out.push(Violation {
                            rule: "in-port-is-sink",
                            detail: ctx(format!("{} drives fabric nodes", node.qualified_name())),
                        });
                    }
                    if !*input && fan_in != 0 {
                        out.push(Violation {
                            rule: "out-port-is-source",
                            detail: ctx(format!("{} driven by fabric", node.qualified_name())),
                        });
                    }
                }
            }

            // Inter-tile edges must connect geometric neighbours, and a
            // tile-crossing wire must carry an *explicitly given* delay:
            // `connect` defaults to 0 ps (correct for intra-tile wiring),
            // and a cross-tile hop silently left at the default would
            // make every downstream timing number quietly wrong. An
            // explicit 0 via `connect_with_delay` (idealized delay
            // model) remains legal.
            for &succ in g.fan_out(id) {
                let s = g.node(succ);
                let dx = (s.x as i32 - node.x as i32).abs();
                let dy = (s.y as i32 - node.y as i32).abs();
                if dx + dy > 1 {
                    out.push(Violation {
                        rule: "edges-are-local",
                        detail: ctx(format!(
                            "{} -> {} spans non-adjacent tiles",
                            node.qualified_name(),
                            s.qualified_name()
                        )),
                    });
                }
                if dx + dy > 0 && !g.has_explicit_delay(id, succ) {
                    out.push(Violation {
                        rule: "wire-delay-missing",
                        detail: ctx(format!(
                            "{} -> {} crosses tiles with no explicit wire delay",
                            node.qualified_name(),
                            s.qualified_name()
                        )),
                    });
                }
            }
        }
    }
    out
}

/// Panic with a readable report if the interconnect is invalid. Builders
/// call this after construction.
pub fn assert_valid(ic: &Interconnect) {
    let violations = validate(ic);
    if !violations.is_empty() {
        let mut msg = format!("interconnect IR invalid ({} violations):\n", violations.len());
        for v in violations.iter().take(20) {
            msg.push_str(&format!("  {v}\n"));
        }
        panic!("{msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::graph::RoutingGraph;
    use crate::ir::interconnect::{CoreSpec, Interconnect, Tile};
    use crate::ir::node::{Node, NodeKind, SbIo, Side};

    fn ic_1x1() -> Interconnect {
        let tiles = vec![Tile { x: 0, y: 0, core: CoreSpec::pe(16) }];
        let mut ic = Interconnect::new(1, 1, tiles, "test".into());
        ic.graphs.insert(16, RoutingGraph::new(16));
        ic
    }

    #[test]
    fn empty_graph_is_valid() {
        assert!(validate(&ic_1x1()).is_empty());
    }

    #[test]
    fn detects_out_of_bounds_node() {
        let mut ic = ic_1x1();
        ic.graph_mut(16).add_node(Node::new(
            NodeKind::SwitchBox { side: Side::North, io: SbIo::In, track: 0 },
            5,
            5,
            16,
            0,
        ));
        let v = validate(&ic);
        assert!(v.iter().any(|v| v.rule == "node-in-bounds"), "{v:?}");
    }

    #[test]
    fn detects_multi_driven_sb_input() {
        let mut ic = ic_1x1();
        let g = ic.graph_mut(16);
        let i = g.add_node(Node::new(
            NodeKind::SwitchBox { side: Side::North, io: SbIo::In, track: 0 },
            0, 0, 16, 0,
        ));
        let a = g.add_node(Node::new(NodeKind::Port { name: "data_out_0".into(), input: false }, 0, 0, 16, 0));
        let b = g.add_node(Node::new(NodeKind::Port { name: "data_out_1".into(), input: false }, 0, 0, 16, 0));
        g.connect(a, i);
        g.connect(b, i);
        let v = validate(&ic);
        assert!(v.iter().any(|v| v.rule == "sb-in-single-driver"), "{v:?}");
    }

    #[test]
    fn detects_driverless_sb_output() {
        let mut ic = ic_1x1();
        ic.graph_mut(16).add_node(Node::new(
            NodeKind::SwitchBox { side: Side::North, io: SbIo::Out, track: 0 },
            0, 0, 16, 0,
        ));
        let v = validate(&ic);
        assert!(v.iter().any(|v| v.rule == "sb-out-has-drivers"), "{v:?}");
    }

    #[test]
    fn detects_missing_wire_delay_on_tile_crossing() {
        // Build a 2x1 array with one cross-tile hop, wired by `wire`.
        let crossing = |wire: fn(&mut RoutingGraph, crate::ir::NodeId, crate::ir::NodeId)| {
            let tiles = vec![
                Tile { x: 0, y: 0, core: CoreSpec::pe(16) },
                Tile { x: 1, y: 0, core: CoreSpec::pe(16) },
            ];
            let mut ic = Interconnect::new(2, 1, tiles, "test".into());
            ic.graphs.insert(16, RoutingGraph::new(16));
            let g = ic.graph_mut(16);
            let out = g.add_node(Node::new(
                NodeKind::SwitchBox { side: Side::East, io: SbIo::Out, track: 0 },
                0, 0, 16, 0,
            ));
            let inn = g.add_node(Node::new(
                NodeKind::SwitchBox { side: Side::West, io: SbIo::In, track: 0 },
                1, 0, 16, 0,
            ));
            wire(g, out, inn);
            validate(&ic)
        };
        let missing = |v: &[Violation]| v.iter().any(|v| v.rule == "wire-delay-missing");

        // Defaulted delay on a tile crossing: silent STA poison, flagged.
        let v = crossing(|g, a, b| g.connect(a, b));
        assert!(missing(&v), "{v:?}");
        // The same hop with an explicit delay is clean.
        let v = crossing(|g, a, b| g.connect_with_delay(a, b, 90));
        assert!(!missing(&v), "{v:?}");
        // An explicit zero (idealized delay model) is also clean.
        let v = crossing(|g, a, b| g.connect_with_delay(a, b, 0));
        assert!(!missing(&v), "{v:?}");
    }

    #[test]
    fn detects_fabric_driving_output_port() {
        let mut ic = ic_1x1();
        let g = ic.graph_mut(16);
        let p = g.add_node(Node::new(NodeKind::Port { name: "data_out_0".into(), input: false }, 0, 0, 16, 0));
        let q = g.add_node(Node::new(NodeKind::Port { name: "data_out_1".into(), input: false }, 0, 0, 16, 0));
        g.connect(q, p);
        let v = validate(&ic);
        assert!(v.iter().any(|v| v.rule == "out-port-is-source"), "{v:?}");
    }
}
