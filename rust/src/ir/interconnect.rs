//! The full interconnect: a grid of tiles plus one routing graph per bit
//! width. This is what the Canal eDSL builds and every downstream tool
//! (hardware lowering, PnR, bitstream generation, simulation) consumes.

use std::collections::BTreeMap;
use std::sync::Arc;

use super::compiled::CompiledGraph;
use super::graph::RoutingGraph;
use super::node::{NodeId, NodeKind};

/// A port on a core (PE or MEM).
#[derive(Clone, PartialEq, Eq, Debug)]
pub struct PortSpec {
    pub name: String,
    pub width: u8,
}

impl PortSpec {
    pub fn new(name: &str, width: u8) -> Self {
        PortSpec { name: name.to_string(), width }
    }
}

/// Kind of core occupying a tile. The paper's arrays interleave PE tiles
/// and MEM tiles (fewer MEM columns than PE columns).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum CoreKind {
    Pe,
    Mem,
    /// I/O pad tiles on the array margin: entry/exit points for
    /// application streams.
    Io,
}

impl CoreKind {
    pub fn name(self) -> &'static str {
        match self {
            CoreKind::Pe => "PE",
            CoreKind::Mem => "MEM",
            CoreKind::Io => "IO",
        }
    }
}

/// What sits inside a tile. Canal treats cores as opaque: only their
/// ports (and a delay attribute for STA) are visible to the interconnect.
#[derive(Clone, PartialEq, Debug)]
pub struct CoreSpec {
    pub kind: CoreKind,
    pub inputs: Vec<PortSpec>,
    pub outputs: Vec<PortSpec>,
    /// Combinational delay through the core in ps (used by timing-driven
    /// routing and STA; Fig. 7's "timing information as weights").
    pub delay_ps: u32,
}

impl CoreSpec {
    /// The paper's reference PE: 4 data inputs, 2 data outputs
    /// (§4.1: "PEs with two outputs and four inputs").
    pub fn pe(width: u8) -> Self {
        CoreSpec {
            kind: CoreKind::Pe,
            inputs: (0..4).map(|i| PortSpec::new(&format!("data_in_{i}"), width)).collect(),
            outputs: (0..2).map(|i| PortSpec::new(&format!("data_out_{i}"), width)).collect(),
            delay_ps: 640,
        }
    }

    /// Memory tile: 2 inputs (wdata, addr-ish) and 2 outputs.
    pub fn mem(width: u8) -> Self {
        CoreSpec {
            kind: CoreKind::Mem,
            inputs: (0..2).map(|i| PortSpec::new(&format!("wdata_{i}"), width)).collect(),
            outputs: (0..2).map(|i| PortSpec::new(&format!("rdata_{i}"), width)).collect(),
            delay_ps: 800,
        }
    }

    /// Margin I/O tile: one input (to pad) and one output (from pad).
    pub fn io(width: u8) -> Self {
        CoreSpec {
            kind: CoreKind::Io,
            inputs: vec![PortSpec::new("io_in", width)],
            outputs: vec![PortSpec::new("io_out", width)],
            delay_ps: 0,
        }
    }

    pub fn port_width(&self, name: &str) -> Option<u8> {
        self.inputs
            .iter()
            .chain(self.outputs.iter())
            .find(|p| p.name == name)
            .map(|p| p.width)
    }
}

/// One tile of the array.
#[derive(Clone, Debug)]
pub struct Tile {
    pub x: u16,
    pub y: u16,
    pub core: CoreSpec,
}

/// The complete interconnect IR.
#[derive(Clone, Debug)]
pub struct Interconnect {
    pub width: u16,
    pub height: u16,
    /// Row-major tiles (`y * width + x`).
    pub tiles: Vec<Tile>,
    /// One routing graph per bit width, e.g. {16: data, 1: control}.
    pub graphs: BTreeMap<u8, RoutingGraph>,
    /// Human-readable description of how this interconnect was built
    /// (topology name, tracks, ...), embedded into generated collateral.
    pub descriptor: String,
    /// Frozen CSR view per bit width (see [`CompiledGraph`]): built by
    /// [`Self::freeze`], dropped by [`Self::graph_mut`] so a stale view
    /// can never be read after mutation. `Arc` so sweeps can share one
    /// compiled graph across threads without cloning it.
    compiled: BTreeMap<u8, Arc<CompiledGraph>>,
    /// Dense core kind per tile (row-major) — hot-loop alternative to
    /// dereferencing the fat `Tile`/`CoreSpec` structs.
    kind_grid: Vec<CoreKind>,
    /// Tile coordinates per core kind, in row-major scan order (the
    /// legalizer depends on this order for deterministic tie-breaking).
    sites: [Vec<(u16, u16)>; 3],
}

fn kind_slot(kind: CoreKind) -> usize {
    match kind {
        CoreKind::Pe => 0,
        CoreKind::Mem => 1,
        CoreKind::Io => 2,
    }
}

impl Interconnect {
    pub fn new(width: u16, height: u16, tiles: Vec<Tile>, descriptor: String) -> Self {
        assert_eq!(tiles.len(), width as usize * height as usize);
        for (i, t) in tiles.iter().enumerate() {
            assert_eq!(
                (t.x as usize, t.y as usize),
                (i % width as usize, i / width as usize),
                "tiles must be row-major"
            );
        }
        let mut ic = Interconnect {
            width,
            height,
            tiles,
            graphs: BTreeMap::new(),
            descriptor,
            compiled: BTreeMap::new(),
            kind_grid: Vec::new(),
            sites: Default::default(),
        };
        ic.rebuild_tile_index();
        ic
    }

    fn rebuild_tile_index(&mut self) {
        self.kind_grid = self.tiles.iter().map(|t| t.core.kind).collect();
        self.sites = Default::default();
        for t in &self.tiles {
            self.sites[kind_slot(t.core.kind)].push((t.x, t.y));
        }
    }

    /// Freeze every routing graph into its immutable CSR form and refresh
    /// the dense tile index. Builders call this once, after the last edge
    /// (or tile customization) is applied; every PnR / timing / simulation
    /// hot path reads the compiled view. Idempotent — and because `tiles`
    /// and `graphs` are public, any direct mutation of either after a
    /// freeze must be followed by another `freeze()` call.
    pub fn freeze(&mut self) {
        self.rebuild_tile_index();
        self.compiled =
            self.graphs.iter().map(|(&bw, g)| (bw, Arc::new(g.compile()))).collect();
    }

    /// Has [`Self::freeze`] been called (and no graph mutated since)?
    pub fn is_frozen(&self) -> bool {
        self.compiled.len() == self.graphs.len() && !self.graphs.is_empty()
    }

    /// The frozen CSR view of one layer. Panics if the interconnect was
    /// never frozen or was mutated (via [`Self::graph_mut`]) after the
    /// last freeze. `graphs` is a public field, so a direct mutation
    /// bypasses that invalidation — debug builds catch the common cases
    /// (added nodes/edges) here; release builds trust the contract.
    pub fn compiled(&self, bit_width: u8) -> &CompiledGraph {
        match self.compiled.get(&bit_width) {
            Some(c) => {
                debug_assert!(
                    self.graphs
                        .get(&bit_width)
                        .map(|g| (g.len(), g.edge_count()))
                        == Some((c.len(), c.edge_count())),
                    "compiled view of width {bit_width} is stale: re-freeze() after \
                     mutating `graphs` directly"
                );
                c
            }
            None => panic!(
                "no compiled graph of width {bit_width}: call freeze() after building \
                 or mutating the interconnect"
            ),
        }
    }

    /// Shared handle to one frozen layer (for cross-thread DSE sharding).
    pub fn compiled_arc(&self, bit_width: u8) -> Arc<CompiledGraph> {
        Arc::clone(self.compiled.get(&bit_width).unwrap_or_else(|| {
            panic!("no compiled graph of width {bit_width}: call freeze() first")
        }))
    }

    /// Core kind at a tile — dense-array lookup for placer hot loops.
    /// Reflects `tiles` as of construction or the last [`Self::freeze`];
    /// re-freeze after mutating `tiles` directly.
    #[inline]
    pub fn core_kind_at(&self, x: u16, y: u16) -> CoreKind {
        self.kind_grid[y as usize * self.width as usize + x as usize]
    }

    /// All tile coordinates hosting `kind`, in row-major order (the
    /// legalizer's tie-break order). Same freshness contract as
    /// [`Self::core_kind_at`].
    pub fn sites_of(&self, kind: CoreKind) -> &[(u16, u16)] {
        &self.sites[kind_slot(kind)]
    }

    pub fn tile(&self, x: u16, y: u16) -> &Tile {
        &self.tiles[y as usize * self.width as usize + x as usize]
    }

    pub fn in_bounds(&self, x: i32, y: i32) -> bool {
        x >= 0 && y >= 0 && (x as u16) < self.width && (y as u16) < self.height
    }

    pub fn graph(&self, bit_width: u8) -> &RoutingGraph {
        self.graphs
            .get(&bit_width)
            .unwrap_or_else(|| panic!("no routing graph of width {bit_width}"))
    }

    /// Mutable access to a builder graph. Drops every frozen view first:
    /// a compiled graph must never outlive a mutation of its source.
    pub fn graph_mut(&mut self, bit_width: u8) -> &mut RoutingGraph {
        self.compiled.clear();
        self.graphs
            .get_mut(&bit_width)
            .unwrap_or_else(|| panic!("no routing graph of width {bit_width}"))
    }

    /// Bit widths present, ascending.
    pub fn bit_widths(&self) -> Vec<u8> {
        self.graphs.keys().copied().collect()
    }

    /// Iterate tiles of a given core kind.
    pub fn tiles_of(&self, kind: CoreKind) -> impl Iterator<Item = &Tile> {
        self.tiles.iter().filter(move |t| t.core.kind == kind)
    }

    /// All core-port nodes of a graph at a tile.
    pub fn port_nodes(&self, bit_width: u8, x: u16, y: u16) -> Vec<NodeId> {
        let g = self.graph(bit_width);
        let tile = self.tile(x, y);
        let mut out = Vec::new();
        for p in tile.core.inputs.iter().filter(|p| p.width == bit_width) {
            if let Some(id) = g.find(x, y, &NodeKind::Port { name: p.name.clone(), input: true }) {
                out.push(id);
            }
        }
        for p in tile.core.outputs.iter().filter(|p| p.width == bit_width) {
            if let Some(id) = g.find(x, y, &NodeKind::Port { name: p.name.clone(), input: false }) {
                out.push(id);
            }
        }
        out
    }

    /// Total nodes across all graphs.
    pub fn node_count(&self) -> usize {
        self.graphs.values().map(RoutingGraph::len).sum()
    }

    /// Total edges across all graphs.
    pub fn edge_count(&self) -> usize {
        self.graphs.values().map(RoutingGraph::edge_count).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiles(w: u16, h: u16) -> Vec<Tile> {
        let mut ts = Vec::new();
        for y in 0..h {
            for x in 0..w {
                ts.push(Tile { x, y, core: CoreSpec::pe(16) });
            }
        }
        ts
    }

    #[test]
    fn row_major_layout_enforced() {
        let ic = Interconnect::new(3, 2, tiles(3, 2), "t".into());
        assert_eq!(ic.tile(2, 1).x, 2);
        assert_eq!(ic.tile(2, 1).y, 1);
        assert!(ic.in_bounds(0, 0));
        assert!(!ic.in_bounds(3, 0));
        assert!(!ic.in_bounds(-1, 0));
    }

    #[test]
    #[should_panic(expected = "row-major")]
    fn shuffled_tiles_rejected() {
        let mut ts = tiles(2, 2);
        ts.swap(0, 1);
        Interconnect::new(2, 2, ts, "t".into());
    }

    #[test]
    fn reference_pe_matches_paper() {
        let pe = CoreSpec::pe(16);
        assert_eq!(pe.inputs.len(), 4);
        assert_eq!(pe.outputs.len(), 2);
        assert_eq!(pe.port_width("data_in_0"), Some(16));
        assert_eq!(pe.port_width("nope"), None);
    }

    #[test]
    fn graphs_indexed_by_width() {
        let mut ic = Interconnect::new(2, 2, tiles(2, 2), "t".into());
        ic.graphs.insert(16, RoutingGraph::new(16));
        ic.graphs.insert(1, RoutingGraph::new(1));
        assert_eq!(ic.bit_widths(), vec![1, 16]);
        assert_eq!(ic.graph(16).width, 16);
    }

    #[test]
    fn freeze_builds_compiled_views_and_mutation_drops_them() {
        let mut ic = Interconnect::new(2, 2, tiles(2, 2), "t".into());
        ic.graphs.insert(16, RoutingGraph::new(16));
        assert!(!ic.is_frozen());
        ic.freeze();
        assert!(ic.is_frozen());
        assert_eq!(ic.compiled(16).width, 16);
        assert_eq!(ic.compiled_arc(16).len(), 0);
        // Any mutable graph access invalidates the frozen views.
        let _ = ic.graph_mut(16);
        assert!(!ic.is_frozen());
    }

    #[test]
    #[should_panic(expected = "freeze()")]
    fn compiled_access_without_freeze_panics() {
        let mut ic = Interconnect::new(2, 2, tiles(2, 2), "t".into());
        ic.graphs.insert(16, RoutingGraph::new(16));
        ic.compiled(16);
    }

    #[test]
    fn freeze_refreshes_tile_index_after_tile_mutation() {
        let mut ic = Interconnect::new(2, 2, tiles(2, 2), "t".into());
        ic.graphs.insert(16, RoutingGraph::new(16));
        ic.freeze();
        assert_eq!(ic.core_kind_at(1, 0), CoreKind::Pe);
        ic.tiles[1].core = CoreSpec::mem(16); // customize post-construction
        ic.freeze();
        assert_eq!(ic.core_kind_at(1, 0), CoreKind::Mem);
        assert_eq!(ic.sites_of(CoreKind::Mem), &[(1, 0)]);
    }

    #[test]
    fn dense_tile_lookups_match_tiles() {
        let mut ts = tiles(3, 2);
        ts[4].core = CoreSpec::mem(16); // (1, 1)
        let ic = Interconnect::new(3, 2, ts, "t".into());
        assert_eq!(ic.core_kind_at(1, 1), CoreKind::Mem);
        assert_eq!(ic.core_kind_at(0, 1), CoreKind::Pe);
        assert_eq!(ic.sites_of(CoreKind::Mem), &[(1, 1)]);
        assert_eq!(ic.sites_of(CoreKind::Pe).len(), 5);
        assert!(ic.sites_of(CoreKind::Io).is_empty());
        // Row-major order (the legalizer's tie-break contract).
        assert_eq!(ic.sites_of(CoreKind::Pe)[0], (0, 0));
        assert_eq!(ic.sites_of(CoreKind::Pe)[1], (1, 0));
    }
}
