//! Graph-based intermediate representation for CGRA interconnects (§3.1).
//!
//! Nodes represent anything connectable in hardware (switch-box track
//! endpoints, core ports, pipeline registers, bypass muxes); directed edges
//! are wires. Fan-in > 1 lowers to a configurable multiplexer. The same IR
//! drives hardware generation (`crate::hw`), PnR (`crate::pnr`), bitstream
//! generation (`crate::bitstream`) and simulation (`crate::sim`).

pub mod compiled;
pub mod graph;
pub mod interconnect;
pub mod node;
pub mod serialize;
pub mod validate;

pub use compiled::CompiledGraph;
pub use graph::{NodeKey, RoutingGraph};
pub use interconnect::{CoreKind, CoreSpec, Interconnect, PortSpec, Tile};
pub use node::{Node, NodeId, NodeKind, SbIo, Side};
pub use serialize::{emit_graph, parse_graph};
pub use validate::{assert_valid, validate, Violation};
