//! IR node definitions.
//!
//! Canal's intermediate representation is a directed graph whose nodes are
//! "anything that can be connected in the underlying hardware" (§3.1 of the
//! paper) and whose edges are wires. A node with multiple incoming edges
//! lowers to a configurable multiplexer; node attributes drive both hardware
//! generation and place-and-route.

use std::fmt;

/// A side of a switch box / tile. The ordering (N, S, E, W) is significant:
/// it is the configuration-space ordering used by the bitstream generator
/// and the mux-select encoding.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub enum Side {
    North = 0,
    South = 1,
    East = 2,
    West = 3,
}

impl Side {
    pub const ALL: [Side; 4] = [Side::North, Side::South, Side::East, Side::West];

    /// The opposite side (used to stitch adjacent tiles together: this
    /// tile's `East` output drives the neighbour's `West` input).
    pub fn opposite(self) -> Side {
        match self {
            Side::North => Side::South,
            Side::South => Side::North,
            Side::East => Side::West,
            Side::West => Side::East,
        }
    }

    /// Grid offset of the neighbouring tile on this side. `North` is
    /// -y (row 0 is the top row, matching the paper's figures).
    pub fn offset(self) -> (i32, i32) {
        match self {
            Side::North => (0, -1),
            Side::South => (0, 1),
            Side::East => (1, 0),
            Side::West => (-1, 0),
        }
    }

    pub fn index(self) -> usize {
        self as usize
    }

    pub fn from_index(i: usize) -> Side {
        Side::ALL[i]
    }

    pub fn name(self) -> &'static str {
        match self {
            Side::North => "north",
            Side::South => "south",
            Side::East => "east",
            Side::West => "west",
        }
    }
}

impl fmt::Display for Side {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Direction of a switch-box track endpoint relative to the tile.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub enum SbIo {
    /// Track entering the tile from a neighbour.
    In = 0,
    /// Track leaving the tile toward a neighbour.
    Out = 1,
}

impl SbIo {
    pub fn name(self) -> &'static str {
        match self {
            SbIo::In => "in",
            SbIo::Out => "out",
        }
    }
}

/// What a node *is* — drives hardware lowering (§3.3):
/// - `SwitchBox` out endpoints with fan-in > 1 lower to SB multiplexers,
/// - `Port { input: true }` lowers to a connection box (CB) multiplexer,
/// - `Register` lowers to a pipeline register (or a FIFO entry in the
///   ready-valid backend),
/// - `RegMux` lowers to the register-bypass multiplexer that makes
///   pipeline registers optional per route.
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
pub enum NodeKind {
    /// A track endpoint on one side of a switch box.
    SwitchBox { side: Side, io: SbIo, track: u16 },
    /// A core port. `input` ports get a CB; `output` ports feed SBs.
    Port { name: String, input: bool },
    /// A pipeline register sitting on a track (before the SB output).
    Register { side: Side, track: u16 },
    /// The bypass mux choosing between a register and its input wire.
    RegMux { side: Side, track: u16 },
}

impl NodeKind {
    /// Stable, human-readable node-kind label used in netlists, PnR dumps
    /// and bitstream metadata.
    pub fn label(&self) -> String {
        match self {
            NodeKind::SwitchBox { side, io, track } => {
                format!("sb_{}_{}_t{}", side.name(), io.name(), track)
            }
            NodeKind::Port { name, input } => {
                format!("port_{}_{}", if *input { "in" } else { "out" }, name)
            }
            NodeKind::Register { side, track } => format!("reg_{}_t{}", side.name(), track),
            NodeKind::RegMux { side, track } => format!("rmux_{}_t{}", side.name(), track),
        }
    }

    pub fn is_port(&self) -> bool {
        matches!(self, NodeKind::Port { .. })
    }

    pub fn is_register(&self) -> bool {
        matches!(self, NodeKind::Register { .. })
    }
}

/// Index of a node within one [`super::graph::RoutingGraph`].
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct NodeId(pub u32);

impl NodeId {
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

impl fmt::Display for NodeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "n{}", self.0)
    }
}

/// A node in the routing graph, with the attributes hardware generation and
/// PnR need (§3.1: "each node also has attributes that provide additional
/// information for type checking and hardware generation").
#[derive(Clone, Debug)]
pub struct Node {
    pub kind: NodeKind,
    /// Tile coordinates within the array.
    pub x: u16,
    pub y: u16,
    /// Bit width of the value this node carries (e.g. 16-bit data, 1-bit
    /// control). All edges between nodes must connect equal widths.
    pub width: u8,
    /// Intrinsic delay in picoseconds contributed when a route passes
    /// through this node (mux delay, register clk-q, ...). Edge weights in
    /// Fig. 7 of the paper; consumed by the router and by STA.
    pub delay_ps: u32,
}

impl Node {
    pub fn new(kind: NodeKind, x: u16, y: u16, width: u8, delay_ps: u32) -> Self {
        Node { kind, x, y, width, delay_ps }
    }

    /// Fully qualified name: unique within an interconnect of one width.
    pub fn qualified_name(&self) -> String {
        format!("x{:02}_y{:02}_{}", self.x, self.y, self.kind.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn side_opposites_are_involutive() {
        for s in Side::ALL {
            assert_eq!(s.opposite().opposite(), s);
        }
    }

    #[test]
    fn side_offsets_are_antisymmetric() {
        for s in Side::ALL {
            let (dx, dy) = s.offset();
            let (ox, oy) = s.opposite().offset();
            assert_eq!((dx, dy), (-ox, -oy));
        }
    }

    #[test]
    fn labels_are_distinct_per_kind() {
        let kinds = [
            NodeKind::SwitchBox { side: Side::North, io: SbIo::In, track: 0 },
            NodeKind::SwitchBox { side: Side::North, io: SbIo::Out, track: 0 },
            NodeKind::SwitchBox { side: Side::South, io: SbIo::In, track: 0 },
            NodeKind::Port { name: "data0".into(), input: true },
            NodeKind::Port { name: "data0".into(), input: false },
            NodeKind::Register { side: Side::East, track: 1 },
            NodeKind::RegMux { side: Side::East, track: 1 },
        ];
        let labels: std::collections::HashSet<_> = kinds.iter().map(|k| k.label()).collect();
        assert_eq!(labels.len(), kinds.len());
    }

    #[test]
    fn qualified_names_embed_position() {
        let n = Node::new(NodeKind::Register { side: Side::West, track: 3 }, 4, 7, 16, 50);
        assert_eq!(n.qualified_name(), "x04_y07_reg_west_t3");
    }
}
