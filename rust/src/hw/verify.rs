//! Structural verification: RTL vs IR (§3.3).
//!
//! "After the graph is translated into RTL, Canal verifies structural
//! correctness by comparing the connectivity of the hardware with that of
//! the IR by parsing the generated RTL." This module parses the emitted
//! Verilog back into (out ← ordered inputs) connectivity and checks it
//! against the routing graph: every fan-in-N node must appear as an N-way
//! mux with the IR's driver order (the order *is* the select encoding),
//! every single-driver node as a buffer/DFF, and every port as a module
//! port of the right direction.

use std::collections::HashMap;

use crate::ir::{Interconnect, NodeKind};

/// Connectivity recovered from RTL text.
#[derive(Clone, Debug, Default)]
pub struct ParsedRtl {
    /// mux: out wire -> ordered input wires.
    pub muxes: HashMap<String, Vec<String>>,
    /// buf: out -> in.
    pub bufs: HashMap<String, String>,
    /// dff: q -> d.
    pub dffs: HashMap<String, String>,
    /// fifo instance name -> (d, q).
    pub fifos: HashMap<String, (String, String)>,
    /// module ports: name -> is_output.
    pub ports: HashMap<String, bool>,
}

/// Parse the canonical Verilog produced by [`super::verilog::emit`].
pub fn parse_rtl(text: &str) -> ParsedRtl {
    let mut out = ParsedRtl::default();
    for raw in text.lines() {
        let line = raw.trim();
        if let Some(rest) = line.strip_prefix("input  wire ") {
            let name = rest.trim_end_matches(',').split_whitespace().last().unwrap_or("");
            if !name.is_empty() && name != "clk" && name != "rst" {
                out.ports.insert(name.to_string(), false);
            }
        } else if let Some(rest) = line.strip_prefix("output wire ") {
            let name = rest.trim_end_matches(',').split_whitespace().last().unwrap_or("");
            out.ports.insert(name.to_string(), true);
        } else if line.starts_with("assign ") && line.contains(" ? ") {
            // assign OUT = cfg == B'dK ? IN0 : cfg == B'dK ? IN1 : ... : W'd0; // name
            let body = line.trim_start_matches("assign ");
            let (lhs, rhs) = match body.split_once('=') {
                Some(p) => p,
                None => continue,
            };
            let lhs = lhs.trim().to_string();
            let mut inputs = Vec::new();
            for seg in rhs.split('?').skip(1) {
                let inp = seg.split(':').next().unwrap_or("").trim();
                if !inp.is_empty() {
                    inputs.push(inp.to_string());
                }
            }
            out.muxes.insert(lhs, inputs);
        } else if line.starts_with("assign ") && !line.contains('?') && !line.contains('&') {
            // assign OUT = IN; // name
            let body = line.trim_start_matches("assign ");
            if let Some((lhs, rhs)) = body.split_once('=') {
                let rhs = rhs.split(';').next().unwrap_or("").trim();
                // Ready joins with a single ungated term also match this
                // shape; they never collide with data wires (r-prefix).
                out.bufs.insert(lhs.trim().to_string(), rhs.to_string());
            }
        } else if line.starts_with("always @(posedge clk) ") {
            // always @(posedge clk) Q <= D; // name
            let body = line.trim_start_matches("always @(posedge clk) ");
            if let Some((q, d)) = body.split_once("<=") {
                let d = d.split(';').next().unwrap_or("").trim();
                out.dffs.insert(q.trim().to_string(), d.to_string());
            }
        } else if line.starts_with("canal_rv_fifo #(") {
            // grab .d(WIRE) / .q(WIRE) + instance name
            let name = line
                .split(')')
                .find_map(|s| {
                    let s = s.trim_start();
                    s.strip_prefix(") ").map(|x| x.to_string())
                })
                .unwrap_or_default();
            let grab = |key: &str| {
                line.split(key)
                    .nth(1)
                    .and_then(|s| s.split(')').next())
                    .unwrap_or("")
                    .to_string()
            };
            let inst = if name.is_empty() {
                // fallback: token before "(.clk"
                line.split("(.clk").next().unwrap_or("").split_whitespace().last().unwrap_or("").to_string()
            } else {
                name
            };
            out.fifos.insert(inst, (grab(".d("), grab(".q(")));
        }
    }
    out
}

/// A structural mismatch between RTL and IR.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Mismatch {
    pub wire: String,
    pub reason: String,
}

/// Verify RTL text against the interconnect IR. Empty result ⇒ pass.
pub fn verify_rtl(ic: &Interconnect, rtl: &str) -> Vec<Mismatch> {
    let parsed = parse_rtl(rtl);
    let mut mismatches = Vec::new();

    for (&bw, g) in &ic.graphs {
        let wname = |id| format!("w{bw}_{}", g.node(id).qualified_name());
        for (id, node) in g.iter() {
            let wire = wname(id);
            let fan_in = g.fan_in(id);
            match (&node.kind, fan_in.len()) {
                (NodeKind::Port { input: false, .. }, _) => {
                    match parsed.ports.get(&wire) {
                        Some(false) => {}
                        Some(true) => mismatches.push(Mismatch {
                            wire,
                            reason: "output port emitted as module output".into(),
                        }),
                        None => mismatches.push(Mismatch {
                            wire,
                            reason: "core output port missing from module ports".into(),
                        }),
                    }
                }
                (NodeKind::Register { .. }, 1) => {
                    let d_expected = wname(fan_in[0]);
                    let dff_ok =
                        parsed.dffs.get(&wire).map(|d| *d == d_expected).unwrap_or(false);
                    let fifo_ok = parsed
                        .fifos
                        .values()
                        .any(|(d, q)| *q == wire && *d == d_expected);
                    if !dff_ok && !fifo_ok {
                        mismatches.push(Mismatch {
                            wire,
                            reason: format!("register not driven by {d_expected}"),
                        });
                    }
                }
                (_, n) if n > 1 => match parsed.muxes.get(&wire) {
                    None => mismatches.push(Mismatch {
                        wire,
                        reason: format!("expected {n}-input mux, none found"),
                    }),
                    Some(inputs) => {
                        let expected: Vec<String> =
                            fan_in.iter().map(|&f| wname(f)).collect();
                        if *inputs != expected {
                            mismatches.push(Mismatch {
                                wire,
                                reason: format!(
                                    "mux inputs {inputs:?} != IR drivers {expected:?}"
                                ),
                            });
                        }
                    }
                },
                (_, 1) => {
                    let expected = wname(fan_in[0]);
                    let ok = parsed.bufs.get(&wire).map(|i| *i == expected).unwrap_or(false);
                    if !ok {
                        mismatches.push(Mismatch {
                            wire,
                            reason: format!("buffer from {expected} missing"),
                        });
                    }
                }
                (_, _) => {} // margin stubs have no hardware
            }
        }
    }
    mismatches
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::{create_uniform_interconnect, InterconnectConfig};
    use crate::hw::lower::{lower_ready_valid, lower_static, RvOptions};
    use crate::hw::verilog::emit;

    fn ic() -> Interconnect {
        create_uniform_interconnect(&InterconnectConfig {
            width: 3,
            height: 2,
            num_tracks: 2,
            mem_column_period: 2,
            reg_density: 1,
            ..Default::default()
        })
    }

    #[test]
    fn static_rtl_verifies_against_ir() {
        let ic = ic();
        let rtl = emit(&lower_static(&ic).netlist);
        let m = verify_rtl(&ic, &rtl);
        assert!(m.is_empty(), "{:?}", &m[..m.len().min(5)]);
    }

    #[test]
    fn rv_rtl_verifies_against_ir() {
        let ic = ic();
        let rtl = emit(&lower_ready_valid(&ic, &RvOptions::default()).netlist);
        let m = verify_rtl(&ic, &rtl);
        assert!(m.is_empty(), "{:?}", &m[..m.len().min(5)]);
    }

    #[test]
    fn tampered_mux_input_detected() {
        let ic = ic();
        let rtl = emit(&lower_static(&ic).netlist);
        // Swap the first two mux alternatives on some mux line: select
        // encodings no longer match the IR driver order.
        let line = rtl
            .lines()
            .find(|l| l.contains(" ? ") && l.contains("sb_north_out_t0"))
            .expect("a mux line");
        let mut parts: Vec<&str> = line.split(" ? ").collect();
        assert!(parts.len() >= 3);
        // swap input wires between first two arms
        let a = parts[1].split(" : ").next().unwrap().to_string();
        let b = parts[2].split(" : ").next().unwrap().to_string();
        let swapped = line
            .replacen(&a, "__TMP__", 1)
            .replacen(&b, &a, 1)
            .replacen("__TMP__", &b, 1);
        let tampered = rtl.replace(line, &swapped);
        let _ = parts.pop();
        let m = verify_rtl(&ic, &tampered);
        assert!(!m.is_empty(), "tampering must be detected");
    }

    #[test]
    fn dropped_buffer_detected() {
        let ic = ic();
        let rtl = emit(&lower_static(&ic).netlist);
        let line = rtl
            .lines()
            .find(|l| {
                l.trim_start().starts_with("assign") && !l.contains('?') && l.contains("// buf_")
            })
            .expect("a buf line");
        let tampered = rtl.replace(line, "");
        let m = verify_rtl(&ic, &tampered);
        assert!(m.iter().any(|x| x.reason.contains("buffer")));
    }

    #[test]
    fn parse_recovers_port_directions() {
        let ic = ic();
        let rtl = emit(&lower_static(&ic).netlist);
        let parsed = parse_rtl(&rtl);
        assert!(parsed.ports.values().any(|&o| o));
        assert!(parsed.ports.values().any(|&o| !o));
        assert!(!parsed.ports.contains_key("clk"));
    }
}
