//! Structural netlist: the hardware-side IR the graph IR lowers into.
//!
//! The paper uses magma; we use an equivalent in-memory structural
//! representation from which Verilog is emitted ([`super::verilog`]) and
//! against which structural verification runs ([`super::verify`]). Only
//! *connectivity* semantics matter for Canal's checks, so primitives are
//! kept at mux/register/FIFO granularity — exactly the components the
//! lowering rules of §3.3 produce.

use std::collections::HashMap;

use super::config::ConfigField;

/// Index of a wire in a [`Netlist`].
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct WireId(pub u32);

/// A named wire (bus) of `width` bits.
#[derive(Clone, Debug)]
pub struct Wire {
    pub name: String,
    pub width: u8,
}

/// Hardware primitive instances — the lowering targets of §3.3:
/// edges → wires, multi-fan-in nodes → muxes, register nodes → registers
/// (or FIFOs in the ready-valid backend).
#[derive(Clone, Debug)]
pub enum Prim {
    /// Configurable mux: `out = inputs[config]`.
    Mux { name: String, inputs: Vec<WireId>, out: WireId, config: ConfigField },
    /// Plain wire alias (fan-in of exactly one): `out = input`.
    Buf { name: String, input: WireId, out: WireId },
    /// Pipeline register.
    Dff { name: String, d: WireId, q: WireId },
    /// Ready-valid FIFO stage replacing a Dff in the RV backend.
    /// `split` marks the Fig. 6 optimization (second entry borrowed from
    /// the adjacent tile's register; control chained across the border).
    Fifo {
        name: String,
        d: WireId,
        q: WireId,
        depth: u8,
        split: bool,
        mode: ConfigField,
        valid_in: WireId,
        valid_out: WireId,
        ready_in: WireId,
        ready_out: WireId,
    },
    /// 1-bit valid mux mirroring a data mux (shares its config field).
    ValidMux { name: String, inputs: Vec<WireId>, out: WireId, config: ConfigField },
    /// Ready-join (Fig. 5): combines downstream readies of a fan-out
    /// point using the one-hot decode of the listed mux selects.
    /// `readies[i]` is gated by "mux `muxes[i]` currently selects us".
    ReadyJoin {
        name: String,
        readies: Vec<WireId>,
        sel_of: Vec<(ConfigField, u32)>,
        out: WireId,
    },
    /// Top-level port of the fabric (core-side or pad-side boundary).
    Io { name: String, wire: WireId, output: bool },
}

impl Prim {
    pub fn name(&self) -> &str {
        match self {
            Prim::Mux { name, .. }
            | Prim::Buf { name, .. }
            | Prim::Dff { name, .. }
            | Prim::Fifo { name, .. }
            | Prim::ValidMux { name, .. }
            | Prim::ReadyJoin { name, .. }
            | Prim::Io { name, .. } => name,
        }
    }
}

/// A flat structural netlist.
#[derive(Clone, Debug, Default)]
pub struct Netlist {
    pub name: String,
    wires: Vec<Wire>,
    by_name: HashMap<String, WireId>,
    pub prims: Vec<Prim>,
}

impl Netlist {
    pub fn new(name: &str) -> Self {
        Netlist { name: name.to_string(), ..Default::default() }
    }

    /// Declare (or fetch) a wire.
    pub fn wire(&mut self, name: &str, width: u8) -> WireId {
        if let Some(&id) = self.by_name.get(name) {
            assert_eq!(self.wires[id.0 as usize].width, width, "width clash on `{name}`");
            return id;
        }
        let id = WireId(self.wires.len() as u32);
        self.wires.push(Wire { name: name.to_string(), width });
        self.by_name.insert(name.to_string(), id);
        id
    }

    pub fn wire_name(&self, id: WireId) -> &str {
        &self.wires[id.0 as usize].name
    }

    pub fn wire_width(&self, id: WireId) -> u8 {
        self.wires[id.0 as usize].width
    }

    pub fn find_wire(&self, name: &str) -> Option<WireId> {
        self.by_name.get(name).copied()
    }

    pub fn wires(&self) -> &[Wire] {
        &self.wires
    }

    pub fn add(&mut self, prim: Prim) {
        self.prims.push(prim);
    }

    /// Drivers per wire (for structural checks): wire -> primitive index.
    pub fn drivers(&self) -> HashMap<WireId, Vec<usize>> {
        let mut m: HashMap<WireId, Vec<usize>> = HashMap::new();
        for (i, p) in self.prims.iter().enumerate() {
            let outs: Vec<WireId> = match p {
                Prim::Mux { out, .. }
                | Prim::Buf { out, .. }
                | Prim::ValidMux { out, .. }
                | Prim::ReadyJoin { out, .. } => vec![*out],
                Prim::Dff { q, .. } => vec![*q],
                Prim::Fifo { q, valid_out, ready_out, .. } => vec![*q, *valid_out, *ready_out],
                Prim::Io { wire, output, .. } => {
                    if *output {
                        vec![]
                    } else {
                        vec![*wire]
                    }
                }
            };
            for o in outs {
                m.entry(o).or_default().push(i);
            }
        }
        m
    }

    /// Structural sanity: every wire has at most one driver; mux inputs
    /// have matching widths.
    pub fn check(&self) -> Result<(), String> {
        for (w, drv) in self.drivers() {
            if drv.len() > 1 {
                return Err(format!(
                    "wire `{}` multiply driven by {:?}",
                    self.wire_name(w),
                    drv.iter().map(|&i| self.prims[i].name()).collect::<Vec<_>>()
                ));
            }
        }
        for p in &self.prims {
            if let Prim::Mux { name, inputs, out, .. } = p {
                let w = self.wire_width(*out);
                for i in inputs {
                    if self.wire_width(*i) != w {
                        return Err(format!("mux `{name}` mixes widths"));
                    }
                }
                if inputs.len() < 2 {
                    return Err(format!("mux `{name}` has {} inputs", inputs.len()));
                }
            }
        }
        Ok(())
    }

    /// Count primitives by family.
    pub fn histogram(&self) -> HashMap<&'static str, usize> {
        let mut h = HashMap::new();
        for p in &self.prims {
            let k = match p {
                Prim::Mux { .. } => "mux",
                Prim::Buf { .. } => "buf",
                Prim::Dff { .. } => "dff",
                Prim::Fifo { .. } => "fifo",
                Prim::ValidMux { .. } => "valid_mux",
                Prim::ReadyJoin { .. } => "ready_join",
                Prim::Io { .. } => "io",
            };
            *h.entry(k).or_insert(0) += 1;
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn field() -> ConfigField {
        ConfigField { x: 0, y: 0, word: 0, offset: 0, bits: 2 }
    }

    #[test]
    fn wire_dedup_by_name() {
        let mut n = Netlist::new("t");
        let a = n.wire("a", 16);
        let a2 = n.wire("a", 16);
        assert_eq!(a, a2);
        assert_eq!(n.wires().len(), 1);
    }

    #[test]
    #[should_panic(expected = "width clash")]
    fn width_clash_detected() {
        let mut n = Netlist::new("t");
        n.wire("a", 16);
        n.wire("a", 8);
    }

    #[test]
    fn multiple_drivers_rejected() {
        let mut n = Netlist::new("t");
        let a = n.wire("a", 16);
        let b = n.wire("b", 16);
        let c = n.wire("c", 16);
        n.add(Prim::Buf { name: "b0".into(), input: a, out: c });
        n.add(Prim::Buf { name: "b1".into(), input: b, out: c });
        assert!(n.check().is_err());
    }

    #[test]
    fn mux_width_mismatch_rejected() {
        let mut n = Netlist::new("t");
        let a = n.wire("a", 16);
        let b = n.wire("b", 8);
        let c = n.wire("c", 16);
        n.add(Prim::Mux { name: "m".into(), inputs: vec![a, b], out: c, config: field() });
        assert!(n.check().is_err());
    }

    #[test]
    fn histogram_counts() {
        let mut n = Netlist::new("t");
        let a = n.wire("a", 16);
        let b = n.wire("b", 16);
        n.add(Prim::Dff { name: "r".into(), d: a, q: b });
        n.add(Prim::Io { name: "ia".into(), wire: a, output: false });
        assert_eq!(n.histogram()["dff"], 1);
        assert_eq!(n.histogram()["io"], 1);
        n.check().unwrap();
    }
}
