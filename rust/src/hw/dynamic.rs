//! Dynamic NoC backend (§3.3, final paragraph).
//!
//! "The methodology described here also applies to generating dynamic
//! NoCs. Instead of lowering a node into a configurable multiplexer to
//! select among incoming data tracks, we can generate a router whose
//! routing table is computed based on the same connectivity information."
//!
//! This backend lowers the same graph IR into one *router* per tile:
//! - every side of the tile whose SB endpoints have inter-tile edges in
//!   the IR becomes a router port (the IR's connectivity decides which
//!   ports exist — a margin tile has no port on its array-boundary side);
//! - the routing table is computed from the IR connectivity by BFS over
//!   the tile-adjacency graph *induced by the IR edges*, with X-first
//!   (dimension-order) tie-breaking so the table is deadlock-free on a
//!   mesh;
//! - the area of a router is priced from the same gate-level model as the
//!   static muxes: a crossbar per output port, an input FIFO per input
//!   port, and the routing-table storage.

use std::collections::VecDeque;

use crate::area::AreaModel;
use crate::ir::{Interconnect, SbIo, Side};

/// Options for the dynamic NoC backend.
#[derive(Clone, Copy, Debug)]
pub struct DynOptions {
    /// Input-buffer depth per router port (flits).
    pub buf_depth: usize,
    /// Router pipeline latency (cycles from head-of-queue to neighbour).
    pub hop_latency: u32,
}

impl Default for DynOptions {
    fn default() -> Self {
        DynOptions { buf_depth: 2, hop_latency: 1 }
    }
}

/// One generated router.
#[derive(Clone, Debug)]
pub struct DynRouter {
    pub x: u16,
    pub y: u16,
    /// Sides with an inter-tile link (derived from the IR edges).
    pub ports: Vec<Side>,
    /// `table[dest_tile_index]` = side to forward on (None = local
    /// delivery, i.e. dest == this tile).
    pub table: Vec<Option<Side>>,
}

impl DynRouter {
    /// Look up the output side for a destination tile.
    pub fn route_to(&self, dest: usize) -> Option<Side> {
        self.table[dest]
    }

    /// Number of routing-table entries that are reachable.
    pub fn reachable(&self) -> usize {
        self.table.iter().filter(|e| e.is_some()).count()
    }
}

/// The lowered dynamic NoC.
#[derive(Clone, Debug)]
pub struct DynNoc {
    pub width: u16,
    pub height: u16,
    /// Routers in row-major order.
    pub routers: Vec<DynRouter>,
    /// Data width (bits) carried per flit.
    pub flit_width: u8,
    pub opts: DynOptions,
}

impl DynNoc {
    pub fn router(&self, x: u16, y: u16) -> &DynRouter {
        &self.routers[y as usize * self.width as usize + x as usize]
    }

    pub fn tile_index(&self, x: u16, y: u16) -> usize {
        y as usize * self.width as usize + x as usize
    }
}

/// Which sides of tile (x, y) have inter-tile IR edges (outgoing track
/// endpoints wired to a neighbour). This is "the same connectivity
/// information" the static backend lowers to muxes.
fn linked_sides(ic: &Interconnect, bit_width: u8, x: u16, y: u16) -> Vec<Side> {
    let g = ic.graph(bit_width);
    // Does the transitive fan-out of `id`, walked through *same-tile*
    // nodes (register / bypass-mux chains), ever cross the tile edge?
    let crosses_tile = |start: crate::ir::NodeId| {
        let mut stack = vec![start];
        let mut seen = std::collections::HashSet::new();
        while let Some(id) = stack.pop() {
            if !seen.insert(id) {
                continue;
            }
            for &s in g.fan_out(id) {
                let n = g.node(s);
                if (n.x, n.y) != (x, y) {
                    return true;
                }
                stack.push(s);
            }
        }
        false
    };
    let mut sides = Vec::new();
    for side in Side::ALL {
        // An out endpoint on `side` whose (possibly registered) output
        // reaches a neighbouring tile makes this a NoC link.
        let linked = (0..64u16)
            .map_while(|t| g.find_sb(x, y, side, SbIo::Out, t))
            .any(crosses_tile);
        if linked {
            sides.push(side);
        }
    }
    sides
}

/// Lower the interconnect IR into a dynamic NoC: one router per tile,
/// with routing tables computed from IR connectivity.
pub fn lower_dynamic(ic: &Interconnect, bit_width: u8, opts: &DynOptions) -> DynNoc {
    let (w, h) = (ic.width as usize, ic.height as usize);

    // Tile-adjacency induced by the IR (usually the full mesh, but a
    // custom IR with missing links produces tables that avoid them).
    let mut adj: Vec<Vec<Side>> = Vec::with_capacity(w * h);
    for y in 0..ic.height {
        for x in 0..ic.width {
            adj.push(linked_sides(ic, bit_width, x, y));
        }
    }

    // BFS per destination, walking *backwards* from the destination so
    // each tile learns its forwarding side. X-first preference: sides are
    // visited E/W before N/S so ties resolve to dimension-ordered routes
    // (deadlock-free on a mesh).
    const SIDE_PREF: [Side; 4] = [Side::East, Side::West, Side::North, Side::South];
    let mut tables: Vec<Vec<Option<Side>>> = vec![vec![None; w * h]; w * h];
    for dest in 0..w * h {
        let (dx, dy) = ((dest % w) as i32, (dest / w) as i32);
        // dist[t] = hops from t to dest; fwd[t] = side to forward on.
        let mut dist: Vec<u32> = vec![u32::MAX; w * h];
        dist[dest] = 0;
        let mut queue = VecDeque::from([dest]);
        while let Some(t) = queue.pop_front() {
            let (tx, ty) = ((t % w) as i32, (t / w) as i32);
            for &side in &SIDE_PREF {
                // Neighbour that would forward *onto* t via `side`:
                // neighbour + offset(side) == t, i.e. neighbour = t -
                // offset. The neighbour needs an IR link on `side`.
                let (ox, oy) = side.offset();
                let (nx, ny) = (tx - ox, ty - oy);
                if nx < 0 || ny < 0 || nx >= w as i32 || ny >= h as i32 {
                    continue;
                }
                let n = ny as usize * w + nx as usize;
                if !adj[n].contains(&side) {
                    continue;
                }
                if dist[n] == u32::MAX {
                    dist[n] = dist[t] + 1;
                    tables[n][dest] = Some(side);
                    queue.push_back(n);
                } else if dist[n] == dist[t] + 1 {
                    // Prefer X-dimension moves among equal-length choices
                    // (dimension order): replace a N/S entry with an E/W
                    // one when the destination differs in X.
                    let cur = tables[n][dest];
                    let cur_is_y =
                        matches!(cur, Some(Side::North) | Some(Side::South));
                    let new_is_x = matches!(side, Side::East | Side::West);
                    if cur_is_y && new_is_x && nx != dx && ny != dy {
                        tables[n][dest] = Some(side);
                    }
                }
            }
        }
        let _ = (dx, dy);
    }

    let mut routers = Vec::with_capacity(w * h);
    for y in 0..ic.height {
        for x in 0..ic.width {
            let i = y as usize * w + x as usize;
            routers.push(DynRouter { x, y, ports: adj[i].clone(), table: tables[i].clone() });
        }
    }

    DynNoc { width: ic.width, height: ic.height, routers, flit_width: bit_width, opts: *opts }
}

/// Area of one router in µm² under the shared gate-level model:
/// crossbar (one `ports+1`:1 mux per output, +1 for local injection),
/// input FIFOs, and routing-table storage (2 bits per reachable dest:
/// the side encoding).
pub fn router_area_um2(model: &AreaModel, r: &DynRouter, flit_width: u8, opts: &DynOptions) -> f64 {
    let p = r.ports.len();
    if p == 0 {
        return 0.0;
    }
    // Crossbar: each output port (p sides + 1 ejection) selects among
    // (p inputs + 1 injection).
    let crossbar: f64 = (0..=p).map(|_| model.mux_ge(p + 1, flit_width)).sum();
    // Input buffering: depth x (width + valid) flops + FIFO control.
    let fifos: f64 = (p + 1) as f64
        * (opts.buf_depth as f64 * model.register_ge(flit_width + 1)
            + model.fifo_extra_ge(opts.buf_depth, 0));
    // Routing table: 2 bits per reachable destination (side encoding),
    // stored in flops (a statically-configured NoC writes it at config
    // time, exactly like the mux config bits of the static fabric).
    let table = 2.0 * r.reachable() as f64 * model.flop_ge / 8.0; // amortized SRAM-ish
    model.to_um2(crossbar + fifos + table)
}

/// Total and per-interior-tile router area for the NoC.
pub fn noc_area(model: &AreaModel, noc: &DynNoc) -> (f64, f64) {
    let total: f64 =
        noc.routers.iter().map(|r| router_area_um2(model, r, noc.flit_width, &noc.opts)).sum();
    let interior = noc.router(noc.width / 2, noc.height / 2);
    (total, router_area_um2(model, interior, noc.flit_width, &noc.opts))
}

/// Verify the routing tables: every (src, dest) pair where dest is
/// reachable must converge to dest within `w*h` hops, without loops.
pub fn verify_tables(noc: &DynNoc) -> Result<(), String> {
    let (w, h) = (noc.width as usize, noc.height as usize);
    for src in 0..w * h {
        for dest in 0..w * h {
            if src == dest {
                continue;
            }
            let mut cur = src;
            let mut hops = 0;
            let mut seen = vec![false; w * h];
            while cur != dest {
                if seen[cur] {
                    return Err(format!("routing loop: src {src} dest {dest} at {cur}"));
                }
                seen[cur] = true;
                let r = &noc.routers[cur];
                let side = match r.table[dest] {
                    Some(s) => s,
                    None => {
                        // Unreachable is only legal if no router reaches it.
                        if noc.routers[dest].ports.is_empty() {
                            break;
                        }
                        return Err(format!("no route: src {src} dest {dest} at {cur}"));
                    }
                };
                let (ox, oy) = side.offset();
                let (nx, ny) = (r.x as i32 + ox, r.y as i32 + oy);
                if nx < 0 || ny < 0 || nx >= w as i32 || ny >= h as i32 {
                    return Err(format!("route walks off-array: src {src} dest {dest}"));
                }
                cur = ny as usize * w + nx as usize;
                hops += 1;
                if hops > w * h {
                    return Err(format!("route too long: src {src} dest {dest}"));
                }
            }
        }
    }
    Ok(())
}

/// Hop count between two tiles under the generated tables.
pub fn hop_count(noc: &DynNoc, src: (u16, u16), dest: (u16, u16)) -> Option<u32> {
    let d = noc.tile_index(dest.0, dest.1);
    let mut cur = noc.tile_index(src.0, src.1);
    let mut hops = 0;
    while cur != d {
        let side = noc.routers[cur].table[d]?;
        let (ox, oy) = side.offset();
        let r = &noc.routers[cur];
        cur = (r.y as i32 + oy) as usize * noc.width as usize + (r.x as i32 + ox) as usize;
        hops += 1;
        if hops > noc.routers.len() as u32 {
            return None;
        }
    }
    Some(hops)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::{create_uniform_interconnect, InterconnectConfig};

    fn noc(w: u16, h: u16) -> DynNoc {
        let ic = create_uniform_interconnect(&InterconnectConfig {
            width: w,
            height: h,
            num_tracks: 3,
            mem_column_period: 0,
            ..Default::default()
        });
        lower_dynamic(&ic, 16, &DynOptions::default())
    }

    #[test]
    fn routers_have_mesh_ports() {
        let n = noc(4, 4);
        // Interior tile: 4 ports; corner: 2; edge: 3.
        assert_eq!(n.router(1, 1).ports.len(), 4);
        assert_eq!(n.router(0, 0).ports.len(), 2);
        assert_eq!(n.router(1, 0).ports.len(), 3);
    }

    #[test]
    fn tables_verify_on_meshes() {
        for (w, h) in [(2u16, 2u16), (4, 4), (5, 3)] {
            verify_tables(&noc(w, h)).unwrap();
        }
    }

    #[test]
    fn routes_are_minimal_on_full_mesh() {
        let n = noc(6, 6);
        for (src, dest) in [((0u16, 0u16), (5u16, 5u16)), ((2, 3), (4, 1)), ((5, 0), (0, 5))] {
            let hops = hop_count(&n, src, dest).unwrap();
            let manhattan = (src.0 as i32 - dest.0 as i32).unsigned_abs()
                + (src.1 as i32 - dest.1 as i32).unsigned_abs();
            assert_eq!(hops, manhattan, "{src:?} -> {dest:?}");
        }
    }

    #[test]
    fn x_first_dimension_order() {
        // From (0,0) to (3,3) the first hop must be East (X before Y).
        let n = noc(4, 4);
        let dest = n.tile_index(3, 3);
        assert_eq!(n.router(0, 0).table[dest], Some(Side::East));
        // And once X is aligned, hops go South.
        assert_eq!(n.router(3, 0).table[dest], Some(Side::South));
    }

    #[test]
    fn router_area_scales_with_ports_and_buffers() {
        let n = noc(4, 4);
        let m = AreaModel::default();
        let corner = router_area_um2(&m, n.router(0, 0), 16, &n.opts);
        let interior = router_area_um2(&m, n.router(1, 1), 16, &n.opts);
        assert!(interior > corner);
        let deep = DynOptions { buf_depth: 8, hop_latency: 1 };
        assert!(router_area_um2(&m, n.router(1, 1), 16, &deep) > interior);
    }

    #[test]
    fn local_delivery_is_none() {
        let n = noc(3, 3);
        for (i, r) in n.routers.iter().enumerate() {
            assert_eq!(r.table[i], None, "router {i} must deliver locally");
        }
    }
}
