//! Hardware generation (§3.3): lowering the graph IR to RTL.
//!
//! Two backends — a fully static mesh and a statically-configured
//! ready-valid NoC (valid layer mirroring data, ready joining via the AOI
//! one-hot reuse of Fig. 5, and full or split FIFOs per Fig. 6) — plus
//! Verilog emission, RTL-vs-IR structural verification, and the
//! configuration-space allocator shared with the bitstream generator.

pub mod config;
pub mod dynamic;
pub mod lower;
pub mod netlist;
pub mod verify;
pub mod verilog;

pub use config::{allocate, ConfigField, ConfigSpace, FieldRole, CONFIG_WORD_BITS};
pub use dynamic::{hop_count, lower_dynamic, noc_area, router_area_um2, verify_tables, DynNoc, DynOptions, DynRouter};
pub use lower::{lower_ready_valid, lower_static, Lowered, RvOptions};
pub use netlist::{Netlist, Prim, Wire, WireId};
pub use verify::{parse_rtl, verify_rtl, Mismatch, ParsedRtl};
pub use verilog::{cfg_reg_name, emit};
