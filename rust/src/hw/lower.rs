//! Lowering the graph IR to hardware (§3.3).
//!
//! Two backends share the mechanical core rules:
//! 1. nodes with hardware attributes generate the specified hardware,
//! 2. directed edges are translated into wires,
//! 3. nodes with multiple incoming edges generate multiplexers;
//!
//! [`lower_static`] produces the fully static mesh. [`lower_ready_valid`]
//! additionally threads a valid layer (same direction as data, muxes
//! mirror the data muxes and share their config) and a ready layer
//! (opposite direction, merged at fan-in points by reusing the AOI mux
//! one-hot decode — Fig. 5), and lowers register nodes to FIFOs (full,
//! or split across adjacent tiles — Fig. 6).

use crate::ir::{Interconnect, NodeId, NodeKind, RoutingGraph};
#[cfg(test)]
use crate::ir::SbIo;

use super::config::{allocate, ConfigSpace};
use super::netlist::{Netlist, Prim, WireId};

/// Ready-valid backend options.
#[derive(Clone, Copy, Debug)]
pub struct RvOptions {
    /// FIFO depth at each register node.
    pub fifo_depth: u8,
    /// Use the split-FIFO optimization (Fig. 6): the second entry is the
    /// adjacent tile's register, chained by cross-tile control.
    pub split: bool,
}

impl Default for RvOptions {
    fn default() -> Self {
        RvOptions { fifo_depth: 2, split: true }
    }
}

/// Output of lowering: the netlist plus the config space it references.
pub struct Lowered {
    pub netlist: Netlist,
    pub config: ConfigSpace,
}

fn data_wire(n: &Netlist, bw: u8) -> impl Fn(&mut Netlist, &RoutingGraph, NodeId) -> WireId {
    let _ = n;
    move |nl: &mut Netlist, g: &RoutingGraph, id: NodeId| {
        let name = format!("w{bw}_{}", g.node(id).qualified_name());
        nl.wire(&name, bw)
    }
}

/// Lower to the fully static mesh backend.
pub fn lower_static(ic: &Interconnect) -> Lowered {
    let config = allocate(ic);
    let mut nl = Netlist::new("canal_fabric");

    for (&bw, g) in &ic.graphs {
        let wire_of = data_wire(&nl, bw);
        for (id, node) in g.iter() {
            let out = wire_of(&mut nl, g, id);
            let fan_in = g.fan_in(id);
            let qname = node.qualified_name();
            match (&node.kind, fan_in.len()) {
                // Core output ports are fabric inputs.
                (NodeKind::Port { input: false, .. }, _) => {
                    nl.add(Prim::Io { name: format!("io_{bw}_{qname}"), wire: out, output: false });
                }
                // Registers are DFFs.
                (NodeKind::Register { .. }, 1) => {
                    let d = wire_of(&mut nl, g, fan_in[0]);
                    nl.add(Prim::Dff { name: format!("dff_{bw}_{qname}"), d, q: out });
                }
                // Multi-fan-in ⇒ mux (CB muxes for input ports also get
                // a fabric output Io toward the core).
                (_, n) if n > 1 => {
                    let inputs: Vec<WireId> =
                        fan_in.iter().map(|&f| wire_of(&mut nl, g, f)).collect();
                    let field = config.mux_field(bw, id).expect("mux field allocated");
                    nl.add(Prim::Mux {
                        name: format!("mux_{bw}_{qname}"),
                        inputs,
                        out,
                        config: field,
                    });
                    if matches!(node.kind, NodeKind::Port { input: true, .. }) {
                        nl.add(Prim::Io { name: format!("io_{bw}_{qname}"), wire: out, output: true });
                    }
                }
                // Single fan-in ⇒ buffer (plain wire).
                (_, 1) => {
                    let input = wire_of(&mut nl, g, fan_in[0]);
                    nl.add(Prim::Buf { name: format!("buf_{bw}_{qname}"), input, out });
                    if matches!(node.kind, NodeKind::Port { input: true, .. }) {
                        nl.add(Prim::Io { name: format!("io_{bw}_{qname}"), wire: out, output: true });
                    }
                }
                // Fan-in 0 non-port nodes: margin SB inputs — undriven
                // stubs (tied off at the array boundary).
                (_, _) => {}
            }
        }
    }

    nl.check().expect("static lowering must produce a sane netlist");
    Lowered { netlist: nl, config }
}

/// Lower to the statically-configured ready-valid NoC backend.
pub fn lower_ready_valid(ic: &Interconnect, opts: &RvOptions) -> Lowered {
    // Start from the static lowering: data path is identical (§3.3:
    // "generating hardware for valid channels follows the same strategy
    // as the data channels").
    let Lowered { mut netlist, config } = lower_static(ic);

    for (&bw, g) in &ic.graphs {
        let vname = |g: &RoutingGraph, id: NodeId| format!("v{bw}_{}", g.node(id).qualified_name());
        let rname = |g: &RoutingGraph, id: NodeId| format!("r{bw}_{}", g.node(id).qualified_name());

        for (id, node) in g.iter() {
            let qname = node.qualified_name();
            let fan_in = g.fan_in(id);
            let v_out = netlist.wire(&vname(g, id), 1);
            let r_out = netlist.wire(&rname(g, id), 1);

            // --- Valid layer: mirrors the data path --------------------
            match (&node.kind, fan_in.len()) {
                (NodeKind::Port { input: false, .. }, _) => {
                    netlist.add(Prim::Io { name: format!("iov_{bw}_{qname}"), wire: v_out, output: false });
                }
                (NodeKind::Register { .. }, 1) => {
                    // Handled by the FIFO below (valid threads through it).
                }
                (_, n) if n > 1 => {
                    let inputs: Vec<WireId> =
                        fan_in.iter().map(|&f| netlist.wire(&vname(g, f), 1)).collect();
                    let field = config.mux_field(bw, id).expect("shared config");
                    netlist.add(Prim::ValidMux {
                        name: format!("vmux_{bw}_{qname}"),
                        inputs,
                        out: v_out,
                        config: field,
                    });
                    if matches!(node.kind, NodeKind::Port { input: true, .. }) {
                        netlist.add(Prim::Io { name: format!("iov_{bw}_{qname}"), wire: v_out, output: true });
                    }
                }
                (_, 1) => {
                    let input = netlist.wire(&vname(g, fan_in[0]), 1);
                    netlist.add(Prim::Buf { name: format!("vbuf_{bw}_{qname}"), input, out: v_out });
                }
                (_, _) => {}
            }

            // --- Ready layer: flows opposite to data -------------------
            // The ready seen by node `id` joins the readies of all its
            // consumers; consumers that are muxes gate their contribution
            // with "that mux currently selects `id`" (Fig. 5, one-hot
            // reuse).
            let consumers = g.fan_out(id);
            match (&node.kind, consumers.len()) {
                (NodeKind::Port { input: true, .. }, _) => {
                    // Core input port: ready comes from the core.
                    netlist.add(Prim::Io { name: format!("ior_{bw}_{qname}"), wire: r_out, output: false });
                }
                (_, 0) => {
                    // Margin stub: never back-pressured (stays undriven in
                    // the netlist; simulation ties it high).
                }
                _ => {
                    // For register nodes the join lands on a dedicated
                    // "downstream" wire: the FIFO sits between its
                    // consumers' join and the ready its *upstream* sees.
                    let join_out = if node.kind.is_register() {
                        netlist.wire(&format!("rdn{bw}_{qname}"), 1)
                    } else {
                        r_out
                    };
                    let mut readies = Vec::new();
                    let mut sel_of = Vec::new();
                    for &c in consumers {
                        readies.push(netlist.wire(&rname(g, c), 1));
                        if g.fan_in(c).len() > 1 {
                            let field = config.mux_field(bw, c).expect("consumer mux config");
                            let sel = g.select_of(c, id).expect("consumer edge") as u32;
                            sel_of.push((field, sel));
                        } else {
                            // Single-input consumer: always listening
                            // (sentinel zero-bit field, never gated).
                            sel_of.push((
                                super::config::ConfigField { x: node.x, y: node.y, word: u32::MAX, offset: 0, bits: 0 },
                                0,
                            ));
                        }
                    }
                    netlist.add(Prim::ReadyJoin {
                        name: format!("rjoin_{bw}_{qname}"),
                        readies,
                        sel_of,
                        out: join_out,
                    });
                }
            }

            // --- FIFO at register nodes -------------------------------
            // Ready topology: upstream sees the FIFO's `ready_out`
            // (not-full); the FIFO drains toward its consumers' join.
            if node.kind.is_register() && fan_in.len() == 1 {
                let src = fan_in[0];
                let d = netlist.find_wire(&format!("w{bw}_{}", g.node(src).qualified_name())).unwrap();
                let q = netlist.find_wire(&format!("w{bw}_{}", g.node(id).qualified_name())).unwrap();
                let mode = config.reg_field(bw, id).expect("register mode field");
                let valid_in = netlist.wire(&vname(g, src), 1);
                let ready_in = netlist.wire(&format!("rdn{bw}_{qname}"), 1);
                let fifo = Prim::Fifo {
                    name: format!("fifo_{bw}_{qname}"),
                    d,
                    q,
                    depth: opts.fifo_depth,
                    split: opts.split,
                    mode,
                    valid_in,
                    valid_out: v_out,
                    ready_in,
                    ready_out: r_out,
                };
                netlist.add(fifo);
            }
        }
    }

    // Note: the RV netlist intentionally leaves the Dff primitives from
    // the static pass in place for register nodes — the FIFO *wraps* the
    // existing register (depth-1 storage) per §3.3; the Dff's q/FIFO's q
    // are the same wire, so we drop the redundant Dffs here.
    let mut nl = Netlist::new(&netlist.name);
    for w in netlist.wires().to_vec() {
        nl.wire(&w.name, w.width);
    }
    let fifo_qs: std::collections::HashSet<String> = netlist
        .prims
        .iter()
        .filter_map(|p| match p {
            Prim::Fifo { q, .. } => Some(netlist.wire_name(*q).to_string()),
            _ => None,
        })
        .collect();
    for p in netlist.prims.clone() {
        if let Prim::Dff { q, .. } = &p {
            if fifo_qs.contains(netlist.wire_name(*q)) {
                continue;
            }
        }
        nl.add(p);
    }
    nl.check().expect("ready-valid lowering must produce a sane netlist");
    Lowered { netlist: nl, config }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::{create_uniform_interconnect, InterconnectConfig};

    fn ic(reg_density: u16) -> Interconnect {
        create_uniform_interconnect(&InterconnectConfig {
            width: 3,
            height: 3,
            num_tracks: 2,
            mem_column_period: 0,
            reg_density,
            ..Default::default()
        })
    }

    #[test]
    fn static_lowering_mux_count_matches_ir() {
        let ic = ic(0);
        let lowered = lower_static(&ic);
        let g = ic.graph(16);
        let ir_muxes = g.mux_nodes().count();
        assert_eq!(lowered.netlist.histogram()["mux"], ir_muxes);
    }

    #[test]
    fn registers_lower_to_dffs() {
        let ic = ic(1);
        let lowered = lower_static(&ic);
        let g = ic.graph(16);
        let regs = g.iter().filter(|(_, n)| n.kind.is_register()).count();
        assert_eq!(lowered.netlist.histogram()["dff"], regs);
    }

    #[test]
    fn rv_lowering_adds_valid_and_ready_layers() {
        let ic = ic(1);
        let lowered = lower_ready_valid(&ic, &RvOptions::default());
        let h = lowered.netlist.histogram();
        assert!(h["valid_mux"] > 0);
        assert!(h["ready_join"] > 0);
        assert!(h["fifo"] > 0);
        assert!(!h.contains_key("dff"), "FIFOs must absorb the registers");
    }

    #[test]
    fn valid_muxes_share_data_mux_config() {
        let ic = ic(0);
        let lowered = lower_ready_valid(&ic, &RvOptions::default());
        let nl = &lowered.netlist;
        for p in &nl.prims {
            if let Prim::ValidMux { name, config, .. } = p {
                let data_name = name.replacen("vmux_", "mux_", 1);
                let data = nl
                    .prims
                    .iter()
                    .find_map(|q| match q {
                        Prim::Mux { name, config, .. } if *name == data_name => Some(*config),
                        _ => None,
                    })
                    .unwrap_or_else(|| panic!("no data mux for {name}"));
                assert_eq!(*config, data, "{name} must share its data mux's config");
            }
        }
    }

    #[test]
    fn ready_join_gates_match_mux_selects() {
        let ic = ic(0);
        let lowered = lower_ready_valid(&ic, &RvOptions::default());
        let g = ic.graph(16);
        // Pick an SB input with multiple mux consumers and check its
        // ready join lists one gate per consumer.
        let (id, _) = g
            .iter()
            .find(|(id, n)| {
                matches!(n.kind, NodeKind::SwitchBox { io: SbIo::In, .. })
                    && g.fan_out(*id).len() > 1
            })
            .expect("an SB input with fanout");
        let jn = format!("rjoin_16_{}", g.node(id).qualified_name());
        let join = lowered
            .netlist
            .prims
            .iter()
            .find(|p| p.name() == jn)
            .unwrap_or_else(|| panic!("missing {jn}"));
        if let Prim::ReadyJoin { readies, sel_of, .. } = join {
            assert_eq!(readies.len(), g.fan_out(id).len());
            assert_eq!(sel_of.len(), readies.len());
        } else {
            panic!("not a ReadyJoin");
        }
    }

    #[test]
    fn split_flag_propagates_to_fifos() {
        let ic = ic(1);
        let split = lower_ready_valid(&ic, &RvOptions { fifo_depth: 2, split: true });
        let full = lower_ready_valid(&ic, &RvOptions { fifo_depth: 2, split: false });
        let is_split = |nl: &Netlist| {
            nl.prims.iter().any(|p| matches!(p, Prim::Fifo { split: true, .. }))
        };
        assert!(is_split(&split.netlist));
        assert!(!is_split(&full.netlist));
    }
}
