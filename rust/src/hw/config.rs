//! Configuration-space allocation.
//!
//! Every configurable element (SB mux, CB mux, register-bypass mux, FIFO
//! mode) owns a field in its tile's configuration registers. The
//! allocator packs fields into 32-bit words per tile; a bitstream is a
//! sequence of `(tile, word) -> value` writes (the addressing scheme used
//! by Amber-class CGRAs: tile-row/column + register offset).

use std::collections::{BTreeMap, HashMap};

use crate::ir::{Interconnect, NodeId, NodeKind};

pub const CONFIG_WORD_BITS: u32 = 32;

/// A configuration field: `bits` wide, at `offset` within `word` of tile
/// `(x, y)`.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct ConfigField {
    pub x: u16,
    pub y: u16,
    pub word: u32,
    pub offset: u32,
    pub bits: u32,
}

impl ConfigField {
    /// Mask of this field within its word.
    pub fn mask(&self) -> u32 {
        if self.bits >= 32 {
            u32::MAX
        } else {
            ((1u32 << self.bits) - 1) << self.offset
        }
    }

    /// Encode a value into (word, shifted-bits) form.
    pub fn encode(&self, value: u32) -> u32 {
        assert!(self.bits >= 32 || value < (1 << self.bits), "value {value} overflows field");
        value << self.offset
    }
}

/// What a field controls (for reports and the bitstream debugger).
#[derive(Clone, PartialEq, Eq, Debug)]
pub enum FieldRole {
    /// Select of the mux lowered from this IR node (bit width graph key +
    /// node id).
    MuxSelect { bit_width: u8, node: NodeId },
    /// FIFO/register mode of a register node: 0 = pipeline register,
    /// 1 = FIFO head, 2 = FIFO tail (split mode).
    RegisterMode { bit_width: u8, node: NodeId },
}

/// The allocated configuration space of one interconnect.
#[derive(Clone, Debug, Default)]
pub struct ConfigSpace {
    fields: Vec<(FieldRole, ConfigField)>,
    by_role: HashMap<(u8, u32, bool), usize>,
    /// Next free (word, offset) per tile.
    cursor: HashMap<(u16, u16), (u32, u32)>,
}

impl ConfigSpace {
    /// Allocate `bits` for `role` in tile `(x, y)`. Fields never straddle
    /// word boundaries.
    pub fn alloc(&mut self, x: u16, y: u16, bits: u32, role: FieldRole) -> ConfigField {
        assert!(bits >= 1 && bits <= CONFIG_WORD_BITS);
        let (mut word, mut offset) = *self.cursor.get(&(x, y)).unwrap_or(&(0, 0));
        if offset + bits > CONFIG_WORD_BITS {
            word += 1;
            offset = 0;
        }
        let field = ConfigField { x, y, word, offset, bits };
        self.cursor.insert((x, y), (word, offset + bits));
        let key = match &role {
            FieldRole::MuxSelect { bit_width, node } => (*bit_width, node.0, false),
            FieldRole::RegisterMode { bit_width, node } => (*bit_width, node.0, true),
        };
        self.by_role.insert(key, self.fields.len());
        self.fields.push((role, field));
        field
    }

    /// Find the field of a mux select.
    pub fn mux_field(&self, bit_width: u8, node: NodeId) -> Option<ConfigField> {
        self.by_role.get(&(bit_width, node.0, false)).map(|&i| self.fields[i].1)
    }

    /// Find the field of a register mode.
    pub fn reg_field(&self, bit_width: u8, node: NodeId) -> Option<ConfigField> {
        self.by_role.get(&(bit_width, node.0, true)).map(|&i| self.fields[i].1)
    }

    pub fn fields(&self) -> &[(FieldRole, ConfigField)] {
        &self.fields
    }

    /// Total config bits per tile.
    pub fn bits_per_tile(&self) -> BTreeMap<(u16, u16), u32> {
        let mut m = BTreeMap::new();
        for (_, f) in &self.fields {
            *m.entry((f.x, f.y)).or_insert(0) += f.bits;
        }
        m
    }

    /// Number of config words a tile uses.
    pub fn words_of_tile(&self, x: u16, y: u16) -> u32 {
        self.cursor.get(&(x, y)).map(|&(w, o)| w + (o > 0) as u32).unwrap_or(0)
    }
}

/// Allocate the configuration space of an interconnect: one select field
/// per mux node (fan-in > 1), one mode field per register node.
pub fn allocate(ic: &Interconnect) -> ConfigSpace {
    let mut cs = ConfigSpace::default();
    for (&bw, g) in &ic.graphs {
        for (id, node) in g.iter() {
            let fan_in = g.fan_in(id).len();
            match node.kind {
                NodeKind::SwitchBox { .. } | NodeKind::Port { .. } | NodeKind::RegMux { .. } => {
                    if fan_in > 1 {
                        let bits = (usize::BITS - (fan_in - 1).leading_zeros()).max(1);
                        cs.alloc(node.x, node.y, bits, FieldRole::MuxSelect { bit_width: bw, node: id });
                    }
                }
                NodeKind::Register { .. } => {
                    // 2 bits: pipeline / fifo-head / fifo-tail.
                    cs.alloc(node.x, node.y, 2, FieldRole::RegisterMode { bit_width: bw, node: id });
                }
            }
        }
    }
    cs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::{create_uniform_interconnect, InterconnectConfig};

    #[test]
    fn fields_never_straddle_words() {
        let mut cs = ConfigSpace::default();
        // 10 x 3 bits + one 31-bit field forces straddle handling.
        for i in 0..10 {
            cs.alloc(0, 0, 3, FieldRole::MuxSelect { bit_width: 16, node: NodeId(i) });
        }
        let f = cs.alloc(0, 0, 31, FieldRole::MuxSelect { bit_width: 16, node: NodeId(99) });
        assert_eq!(f.offset, 0);
        assert_eq!(f.word, 1);
        for (_, f) in cs.fields() {
            assert!(f.offset + f.bits <= CONFIG_WORD_BITS);
        }
    }

    #[test]
    fn fields_within_a_tile_do_not_overlap() {
        let ic = create_uniform_interconnect(&InterconnectConfig {
            width: 3,
            height: 3,
            num_tracks: 3,
            ..Default::default()
        });
        let cs = allocate(&ic);
        let mut seen: HashMap<(u16, u16, u32), u32> = HashMap::new();
        for (_, f) in cs.fields() {
            let used = seen.entry((f.x, f.y, f.word)).or_insert(0);
            assert_eq!(*used & f.mask(), 0, "overlap in tile ({},{}) word {}", f.x, f.y, f.word);
            *used |= f.mask();
        }
    }

    #[test]
    fn every_mux_gets_a_field() {
        let ic = create_uniform_interconnect(&InterconnectConfig {
            width: 3,
            height: 3,
            num_tracks: 2,
            ..Default::default()
        });
        let cs = allocate(&ic);
        let g = ic.graph(16);
        for id in g.mux_nodes() {
            assert!(cs.mux_field(16, id).is_some(), "{}", g.node(id).qualified_name());
        }
    }

    #[test]
    fn encode_respects_field_width() {
        let f = ConfigField { x: 0, y: 0, word: 0, offset: 4, bits: 3 };
        assert_eq!(f.encode(5), 5 << 4);
        assert_eq!(f.mask(), 0b111 << 4);
        let r = std::panic::catch_unwind(|| f.encode(8));
        assert!(r.is_err());
    }

    #[test]
    fn config_bits_scale_with_tracks() {
        let bits = |tracks| {
            let ic = create_uniform_interconnect(&InterconnectConfig {
                width: 3,
                height: 3,
                num_tracks: tracks,
                ..Default::default()
            });
            allocate(&ic).bits_per_tile()[&(1, 1)]
        };
        assert!(bits(4) > bits(2));
    }
}
