//! `canal tune`: a multi-objective Pareto autotuner over the cached DSE
//! engine — search, not enumeration (see `docs/tune.md`).
//!
//! The tuner finds the (area × critical-path period × simulated
//! throughput) Pareto frontier of a [`SweepSpec`]'s design space without
//! visiting the full cross-product:
//!
//! 1. **Cheap-model pre-pruning.** Every candidate is scored before any
//!    PnR with the *exact* interior-tile area (the area model never needs
//!    a placement) and a wire-delay lower bound on the achievable period
//!    read off the frozen [`crate::ir::CompiledGraph`]
//!    ([`period_lower_bound_ps`]). A candidate is discarded only when a
//!    same-app rival is strictly better on *both* cheap scores — a
//!    conservative heuristic: under a shared delay model the delay bound
//!    is constant across axis values, so pruning engages only where the
//!    candidate space actually varies the delay landscape.
//! 2. **Successive halving across seeds.** Seeds are spent one round at
//!    a time; after each round a candidate is dropped when another
//!    survivor's aggregate (or an archive incumbent) strictly dominates
//!    its own. Every real evaluation is a one-candidate [`SweepSpec`]
//!    routed through the caller's evaluator — the engine's
//!    `ResultCache`/coalescing/warm-start machinery — and reproduces the
//!    candidate's exact [`ConfigDescriptor`], so revisited points are
//!    free and pre-tuner caches stay warm.
//! 3. **A persisted Pareto archive.** Routed aggregates merge into a
//!    versioned, atomically-written [`ParetoArchive`]
//!    (`pareto_archive.json`); the archive is pruned to its own frontier
//!    and its incumbents join the next search's dominance checks, so the
//!    tuner gets monotonically cheaper per session.
//!
//! Determinism: candidates, rounds, and dominance checks all iterate
//! BTree-ordered state and consume results in the spec's canonical
//! order, so for a fixed cache temperature the archive bytes are
//! identical across worker counts (asserted in `tests/tune.rs`).
//!
//! NaN discipline: unroutable points — including routed points whose
//! metrics round-tripped through JSON `null` as NaN (see
//! [`PointResult::has_finite_metrics`]) — never dominate anything and
//! never enter the archive; any finite same-app rival dominates them.

use std::collections::BTreeMap;
use std::io;
use std::path::{Path, PathBuf};

use crate::area::{area_of, AreaModel};
use crate::ir::Interconnect;
use crate::obs;
use crate::obs::span::names as spans;
use crate::sim::FabricKind;
use crate::util::json::Json;

use super::exec::{EngineStats, InterconnectSource, SweepOutcome};
use super::spec::{ConfigDescriptor, PointResult, Sizing, SweepSpec};

/// Archive file schema version.
pub const TUNE_VERSION: u64 = 1;

/// One point in objective space: minimize `area_um2` and `period_ps`,
/// maximize `throughput`. Non-finite values mean "unroutable" (or
/// metrics lost to a JSON `null` round trip) — see [`dominates`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Objectives {
    /// Interior-tile interconnect area (µm²) under the entry's fabric
    /// mode — exact, placement-independent.
    pub area_um2: f64,
    /// Best (minimum) achieved clock period over the evaluated seeds.
    pub period_ps: f64,
    /// Best (maximum) simulated tokens/cycle over the evaluated seeds.
    pub throughput: f64,
}

impl Objectives {
    pub fn is_finite(&self) -> bool {
        self.area_um2.is_finite() && self.period_ps.is_finite() && self.throughput.is_finite()
    }

    /// The unroutable sentinel: dominated by every finite point,
    /// dominating nothing.
    pub fn unroutable() -> Objectives {
        Objectives { area_um2: f64::NAN, period_ps: f64::NAN, throughput: f64::NAN }
    }

    /// Fold one evaluated seed into the aggregate: area is
    /// seed-independent, period takes the min, throughput the max. A
    /// non-finite aggregate is replaced outright by a finite point (one
    /// routable seed makes the candidate routable); a non-finite point
    /// leaves a finite aggregate untouched.
    pub fn fold(&mut self, other: &Objectives) {
        if !other.is_finite() {
            return;
        }
        if !self.is_finite() {
            *self = *other;
            return;
        }
        self.period_ps = self.period_ps.min(other.period_ps);
        self.throughput = self.throughput.max(other.throughput);
    }
}

/// Strict Pareto dominance, NaN-safe by construction: `a` dominates `b`
/// iff `a` is finite and either `b` is not (routable beats unroutable)
/// or `a` is no worse on every objective and strictly better on at
/// least one. A non-finite `a` dominates nothing — NaN can never
/// silently "win" a comparison — and `dominates(x, x)` is always false,
/// so ties survive to the frontier. Comparisons go through `total_cmp`,
/// never `partial_cmp(..).unwrap()`.
pub fn dominates(a: &Objectives, b: &Objectives) -> bool {
    use std::cmp::Ordering::{Greater, Less};
    if !a.is_finite() {
        return false;
    }
    if !b.is_finite() {
        return true;
    }
    let le = |x: f64, y: f64| x.total_cmp(&y) != Greater;
    let ge = |x: f64, y: f64| x.total_cmp(&y) != Less;
    let no_worse = le(a.area_um2, b.area_um2)
        && le(a.period_ps, b.period_ps)
        && ge(a.throughput, b.throughput);
    let better = a.area_um2.total_cmp(&b.area_um2) == Less
        || a.period_ps.total_cmp(&b.period_ps) == Less
        || a.throughput.total_cmp(&b.throughput) == Greater;
    no_worse && better
}

/// Archive key: one entry per (full config descriptor, app registry
/// key). Dominance is only meaningful within one app.
#[derive(Clone, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub struct ArchiveKey {
    pub config: String,
    pub app: String,
}

/// One archived frontier point: a routed (config, app) aggregate.
#[derive(Clone, Debug, PartialEq)]
pub struct ParetoEntry {
    /// Full [`ConfigDescriptor`] string of the point.
    pub config: String,
    /// App registry key.
    pub app: String,
    /// [`FabricKind::label`] of the point's fabric.
    pub fabric: String,
    pub objectives: Objectives,
    /// Logical seeds folded into the aggregate, sorted ascending.
    pub seeds: Vec<u64>,
}

impl ParetoEntry {
    fn key(&self) -> ArchiveKey {
        ArchiveKey { config: self.config.clone(), app: self.app.clone() }
    }

    /// Merge a newer aggregate for the same key: period min, throughput
    /// max, seed union; area comes from the newer entry (the model is a
    /// pure function of the config, so they agree anyway).
    fn merge(&mut self, other: &ParetoEntry) {
        self.objectives.area_um2 = other.objectives.area_um2;
        self.objectives.fold(&other.objectives);
        for &s in &other.seeds {
            if let Err(at) = self.seeds.binary_search(&s) {
                self.seeds.insert(at, s);
            }
        }
    }
}

/// Per-app strict-dominance filter: the entries no other same-app entry
/// [`dominates`], in input order. Ties (equal objectives on distinct
/// configs) all survive — the exhaustive and tuned searches must agree
/// on exactly this set.
pub fn pareto_frontier(entries: &[ParetoEntry]) -> Vec<ParetoEntry> {
    entries
        .iter()
        .filter(|e| {
            !entries
                .iter()
                .any(|o| o.app == e.app && dominates(&o.objectives, &e.objectives))
        })
        .cloned()
        .collect()
}

/// Sibling path for the archive: `dse_cache.json` →
/// `dse_cache_pareto.json` (same convention as
/// [`super::artifacts::artifact_path_for`]).
pub fn archive_path_for(cache: &Path) -> PathBuf {
    let stem = cache.file_stem().and_then(|s| s.to_str()).unwrap_or("dse_cache");
    cache.with_file_name(format!("{stem}_pareto.json"))
}

/// Persisted Pareto archive, optionally backed by a JSON file.
/// BTree-ordered, so [`Self::to_json`] is byte-stable; writes go through
/// the shared atomic temp-file + rename path.
#[derive(Default)]
pub struct ParetoArchive {
    path: Option<PathBuf>,
    map: BTreeMap<ArchiveKey, ParetoEntry>,
}

impl ParetoArchive {
    /// Unbacked archive (lives for one search only).
    pub fn in_memory() -> ParetoArchive {
        ParetoArchive::default()
    }

    /// Archive backed by `path` — same contract as
    /// [`super::ResultCache::at`]: missing file = empty archive (created
    /// immediately, so an unwritable path fails before any PnR is
    /// spent), corrupt file = loud error.
    pub fn at(path: &Path) -> Result<ParetoArchive, String> {
        let mut archive =
            ParetoArchive { path: Some(path.to_path_buf()), map: BTreeMap::new() };
        match std::fs::read_to_string(path) {
            Ok(text) => {
                archive.load_json(&text).map_err(|e| format!("{}: {e}", path.display()))?
            }
            Err(e) if e.kind() == io::ErrorKind::NotFound => archive.save()?,
            Err(e) => return Err(format!("{}: {e}", path.display())),
        }
        Ok(archive)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    /// Entries in key order.
    pub fn entries(&self) -> impl Iterator<Item = &ParetoEntry> {
        self.map.values()
    }

    /// Merge one routed aggregate in (period min / throughput max / seed
    /// union on an existing key). Non-finite entries are rejected — the
    /// archive holds frontier candidates, never NaN.
    pub fn merge(&mut self, entry: ParetoEntry) {
        if !entry.objectives.is_finite() {
            return;
        }
        match self.map.get_mut(&entry.key()) {
            Some(have) => have.merge(&entry),
            None => {
                self.map.insert(entry.key(), entry);
            }
        }
    }

    /// Drop every entry another same-app entry strictly dominates,
    /// keeping the archive exactly its own Pareto frontier.
    pub fn prune_to_frontier(&mut self) {
        let all: Vec<ParetoEntry> = self.map.values().cloned().collect();
        let keep = pareto_frontier(&all);
        self.map = keep.into_iter().map(|e| (e.key(), e)).collect();
    }

    /// Merge entries from archive-file text.
    pub fn load_json(&mut self, text: &str) -> Result<(), String> {
        let doc = Json::parse(text)?;
        let version = doc.get("version").and_then(Json::as_u64).ok_or("missing version")?;
        if version != TUNE_VERSION {
            return Err(format!("unsupported archive version {version}"));
        }
        let entries = doc.get("entries").and_then(Json::as_arr).ok_or("missing entries")?;
        for (i, entry) in entries.iter().enumerate() {
            let e = entry_from_json(entry).map_err(|e| format!("entry {i}: {e}"))?;
            self.map.insert(e.key(), e);
        }
        Ok(())
    }

    /// Full archive as JSON text (entries in key order — stable, so a
    /// load → save cycle is byte-identical).
    pub fn to_json(&self) -> String {
        let entries: Vec<Json> = self.map.values().map(entry_json).collect();
        Json::Obj(vec![
            ("version".into(), Json::num_u64(TUNE_VERSION)),
            ("entries".into(), Json::Arr(entries)),
        ])
        .render()
    }

    /// Persist to the backing file (no-op for in-memory archives).
    pub fn save(&self) -> Result<(), String> {
        match &self.path {
            Some(path) => self.save_to(path),
            None => Ok(()),
        }
    }

    pub fn save_to(&self, path: &Path) -> Result<(), String> {
        super::cache::atomic_write(path, &self.to_json())
    }
}

fn entry_json(e: &ParetoEntry) -> Json {
    Json::Obj(vec![
        ("config".into(), Json::str(&e.config)),
        ("app".into(), Json::str(&e.app)),
        ("fabric".into(), Json::str(&e.fabric)),
        ("area_um2".into(), Json::num_f64(e.objectives.area_um2)),
        ("period_ps".into(), Json::num_f64(e.objectives.period_ps)),
        ("throughput".into(), Json::num_f64(e.objectives.throughput)),
        ("seeds".into(), Json::Arr(e.seeds.iter().map(|&s| Json::num_u64(s)).collect())),
    ])
}

fn entry_from_json(v: &Json) -> Result<ParetoEntry, String> {
    let str_field = |k: &str| -> Result<String, String> {
        v.get(k)
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| format!("missing `{k}`"))
    };
    // Unlike the result cache, the archive never holds NaN: a `null`
    // (non-finite) objective in the file is corruption, not data.
    let f64_field = |k: &str| -> Result<f64, String> {
        v.get(k)
            .and_then(Json::as_f64)
            .filter(|x| x.is_finite())
            .ok_or_else(|| format!("bad `{k}`"))
    };
    let seeds: Vec<u64> = v
        .get("seeds")
        .and_then(Json::as_arr)
        .ok_or("missing `seeds`")?
        .iter()
        .map(|s| s.as_u64().ok_or_else(|| "bad seed".to_string()))
        .collect::<Result<_, _>>()?;
    Ok(ParetoEntry {
        config: str_field("config")?,
        app: str_field("app")?,
        fabric: str_field("fabric")?,
        objectives: Objectives {
            area_um2: f64_field("area_um2")?,
            period_ps: f64_field("period_ps")?,
            throughput: f64_field("throughput")?,
        },
        seeds,
    })
}

/// A wire-delay lower bound (ps) on any achievable clock period of the
/// frozen graph: every routed net must leave some driving core port
/// through one of its fan-out hops, so the cheapest port-adjacent hop —
/// `min` over ported mux inputs of `node_delay(port) + wire_delay +
/// node_delay(driver)` — bounds the critical path from below. Exact
/// enough to separate delay-model variants, constant across track/
/// topology counts under one model (every candidate shares the same
/// cheapest hop), and free: one linear scan of the CSR arrays, no PnR.
pub fn period_lower_bound_ps(ic: &Interconnect, bit_width: u8) -> f64 {
    let g = ic.compiled(bit_width);
    let mut best: Option<u64> = None;
    for id in g.ids() {
        if !g.is_port(id) {
            continue;
        }
        let sources = g.fan_in(id);
        if sources.is_empty() {
            continue;
        }
        let own = g.node_delay_ps(id) as u64;
        for (i, &src) in sources.iter().enumerate() {
            let hop = own + g.in_wire_delays(id)[i] as u64 + g.node_delay_ps(src) as u64;
            best = Some(best.map_or(hop, |b| b.min(hop)));
        }
    }
    best.unwrap_or(0) as f64
}

/// One searchable design point: a unique (config, app) pair of the
/// spec's cross-product, carrying everything needed to re-issue it as a
/// one-candidate spec with the exact same [`ConfigDescriptor`].
#[derive(Clone, Debug)]
struct Candidate {
    desc: ConfigDescriptor,
    cfg: crate::dsl::InterconnectConfig,
    fabric: FabricKind,
    app_key: String,
    /// Cheap scores (pre-PnR): exact area, and the wire-delay period
    /// lower bound.
    est_area_um2: f64,
    est_period_lb_ps: f64,
    /// Real aggregate over the seeds evaluated so far.
    agg: Objectives,
    seeds_run: Vec<u64>,
}

impl Candidate {
    /// The one-candidate spec for one seed. Empty axes resolve to the
    /// base config's own values and `Sizing::Fixed` keeps the (already
    /// resolved — tight sizing included) dimensions, so
    /// `SweepSpec::jobs` reproduces `self.desc` exactly and the
    /// engine's cache keys line up with a full enumerating sweep's.
    fn spec_for_seed(&self, spec: &SweepSpec, seed: u64) -> SweepSpec {
        SweepSpec {
            name: spec.name.clone(),
            base: self.cfg.clone(),
            tracks: vec![],
            topologies: vec![],
            output_tracks: vec![],
            sb_sides: vec![],
            cb_sides: vec![],
            fabrics: vec![self.fabric],
            sizing: Sizing::Fixed,
            apps: vec![self.app_key.clone()],
            seeds: vec![seed],
            seed_mode: spec.seed_mode,
            flow: spec.flow.clone(),
            area: false,
        }
    }
}

/// Tuner knobs.
#[derive(Clone, Debug)]
pub struct TuneOptions {
    /// Cheap-model pre-pruning (on by default); `false` sends every
    /// candidate into round 0.
    pub prune: bool,
}

impl Default for TuneOptions {
    fn default() -> Self {
        TuneOptions { prune: true }
    }
}

/// What one tune run produced.
#[derive(Clone, Debug)]
pub struct TuneOutcome {
    pub name: String,
    /// The archive's frontier for this run's apps, in key order.
    pub frontier: Vec<ParetoEntry>,
    /// Single-point evaluations issued (cache hits included — strictly
    /// fewer than `cross_product` whenever search beat enumeration).
    pub evaluated: u64,
    /// Candidates discarded by cheap-model pre-pruning.
    pub pruned: u64,
    /// Candidates dropped by dominance checks between rounds.
    pub dropped: u64,
    /// Successive-halving rounds run (= seeds spent per finalist).
    pub rounds: u64,
    /// Jobs a full enumerating sweep of the spec would run.
    pub cross_product: u64,
    /// Engine counters absorbed over every evaluation
    /// (`stats.pnr_runs` / `stats.sims` are zero on a warm re-tune).
    pub stats: EngineStats,
}

/// Run the search. `placer_name` must match the evaluator's placement
/// backend (it keys the [`ConfigDescriptor`]s); `ics` serves frozen
/// interconnects for the cheap scores (the service plugs in its shared
/// LRU, the CLI builds fresh); `eval` runs one one-candidate spec
/// through the real engine — [`super::DseEngine::run`], or the
/// service's coalescing path. The archive is updated, pruned to its
/// frontier, and saved before returning.
pub fn run_tune(
    spec: &SweepSpec,
    placer_name: &str,
    ics: &dyn InterconnectSource,
    archive: &mut ParetoArchive,
    opts: &TuneOptions,
    eval: &mut dyn FnMut(&SweepSpec) -> Result<SweepOutcome, String>,
) -> Result<TuneOutcome, String> {
    if spec.apps.is_empty() {
        return Err(format!("tune `{}`: need at least one app", spec.name));
    }
    let jobs = spec.jobs(placer_name)?;
    let cross_product = jobs.len() as u64;
    let mut _tune_span = obs::span(spans::DSE_TUNE);
    _tune_span.args(cross_product, 0);

    // Unique (config, app) candidates in canonical job order, scored
    // with the cheap models. One frozen interconnect per unique config
    // serves both scores (and is shared across fabrics/apps).
    let area_model = AreaModel::default();
    let mut ic_cache: BTreeMap<String, std::sync::Arc<Interconnect>> = BTreeMap::new();
    let mut candidates: Vec<Candidate> = Vec::new();
    let mut seen: std::collections::BTreeSet<(ConfigDescriptor, String)> =
        std::collections::BTreeSet::new();
    for job in &jobs {
        if !seen.insert((job.key.config.clone(), job.key.app.clone())) {
            continue;
        }
        let ic = std::sync::Arc::clone(
            ic_cache
                .entry(job.cfg.descriptor())
                .or_insert_with(|| ics.interconnect(&job.cfg).0),
        );
        let tile = area_of(&ic, &area_model, job.fabric.area_mode()).interior_tile(&ic);
        candidates.push(Candidate {
            desc: job.key.config.clone(),
            cfg: job.cfg.clone(),
            fabric: job.fabric,
            app_key: job.key.app.clone(),
            est_area_um2: tile.total(),
            est_period_lb_ps: period_lower_bound_ps(&ic, job.flow.bit_width),
            agg: Objectives::unroutable(),
            seeds_run: Vec::new(),
        });
    }
    drop(ic_cache);

    // Phase 1: cheap-model pre-pruning. Discard a candidate only when a
    // same-app rival beats it strictly on BOTH the exact area and the
    // period lower bound — a lower bound cannot prove real dominance, so
    // the rule is deliberately strict-in-both (and a no-op wherever the
    // delay model is shared across the space).
    let candidates_in = candidates.len() as u64;
    let mut pruned = 0u64;
    if opts.prune {
        let scores: Vec<(String, f64, f64)> = candidates
            .iter()
            .map(|c| (c.app_key.clone(), c.est_area_um2, c.est_period_lb_ps))
            .collect();
        candidates.retain(|c| {
            let beaten = scores.iter().any(|(app, area, lb)| {
                *app == c.app_key
                    && area.total_cmp(&c.est_area_um2) == std::cmp::Ordering::Less
                    && lb.total_cmp(&c.est_period_lb_ps) == std::cmp::Ordering::Less
            });
            if beaten {
                pruned += 1;
            }
            !beaten
        });
    }
    obs::event(spans::TUNE_PRUNE, candidates_in, pruned);
    if obs::metrics_on() {
        obs::metrics::counter("tune.pruned").add(pruned);
    }

    // Phase 2: successive halving across seeds. Every candidate shares
    // the spec's seed list (they all come from one cross-product), so
    // round r spends seeds[r] on each survivor, then drops survivors
    // strictly dominated by another survivor's aggregate or an archive
    // incumbent of the same app.
    let mut stats = EngineStats::default();
    let mut evaluated = 0u64;
    let mut dropped = 0u64;
    let mut rounds = 0u64;
    for (r, &seed) in spec.seeds.iter().enumerate() {
        if candidates.is_empty() {
            break;
        }
        let mut _round = obs::span(spans::TUNE_ROUND);
        _round.args(r as u64, candidates.len() as u64);
        rounds += 1;
        for cand in candidates.iter_mut() {
            if cand.seeds_run.contains(&seed) {
                continue; // duplicate seed value in the axis
            }
            let out = eval(&cand.spec_for_seed(spec, seed))?;
            stats.absorb(&out.stats);
            evaluated += 1;
            let (_, point) = out
                .points
                .first()
                .ok_or_else(|| format!("tune `{}`: empty evaluation", spec.name))?;
            cand.agg.fold(&objectives_of(point, cand.est_area_um2));
            cand.seeds_run.push(seed);
        }
        if obs::metrics_on() {
            obs::metrics::counter("tune.evaluations").add(candidates.len() as u64);
        }
        // Halving: aggregates only improve with more seeds (period min,
        // throughput max, area constant), so a dominator stays a
        // dominator; the dropped candidate's unseen seeds are the one
        // heuristic leap, traded for the saved evaluations.
        let aggs: Vec<(String, Objectives, ConfigDescriptor)> = candidates
            .iter()
            .map(|c| (c.app_key.clone(), c.agg, c.desc.clone()))
            .collect();
        candidates.retain(|c| {
            let by_survivor = aggs.iter().any(|(app, agg, desc)| {
                *app == c.app_key && *desc != c.desc && dominates(agg, &c.agg)
            });
            let by_incumbent = archive.entries().any(|e| {
                e.app == c.app_key
                    && e.config != c.desc.0
                    && dominates(&e.objectives, &c.agg)
            });
            let out = by_survivor || by_incumbent;
            if out {
                dropped += 1;
            }
            !out
        });
    }

    // Phase 3: fold the finalists into the archive, prune it to its own
    // frontier, persist. Unroutable finalists never enter.
    for cand in &candidates {
        if !cand.agg.is_finite() {
            continue;
        }
        let mut seeds = cand.seeds_run.clone();
        seeds.sort_unstable();
        archive.merge(ParetoEntry {
            config: cand.desc.0.clone(),
            app: cand.app_key.clone(),
            fabric: cand.fabric.label(),
            objectives: cand.agg,
            seeds,
        });
    }
    archive.prune_to_frontier();
    archive.save()?;

    let frontier: Vec<ParetoEntry> =
        archive.entries().filter(|e| spec.apps.contains(&e.app)).cloned().collect();
    Ok(TuneOutcome {
        name: spec.name.clone(),
        frontier,
        evaluated,
        pruned,
        dropped,
        rounds,
        cross_product,
        stats,
    })
}

/// A point's objectives under a known exact area. Gated on
/// [`PointResult::has_finite_metrics`], so a NaN-metric "routed" point
/// classifies as unroutable instead of poisoning the dominance order.
pub fn objectives_of(r: &PointResult, area_um2: f64) -> Objectives {
    if !r.has_finite_metrics() {
        return Objectives::unroutable();
    }
    Objectives { area_um2, period_ps: r.period_ps, throughput: r.throughput() }
}

/// The frontier table `canal tune` and the service's `tune` responses
/// render.
pub fn frontier_table(out: &TuneOutcome) -> crate::util::table::Table {
    use crate::util::table::{fmt, Table};
    let mut t = Table::new(
        &format!("Pareto frontier — {}", out.name),
        &["config", "fabric", "app", "area_um2", "period_ps", "thpt", "seeds"],
    );
    for e in &out.frontier {
        let short = e
            .config
            .split(" delays=")
            .next()
            .unwrap_or(&e.config)
            .to_string();
        let seeds: Vec<String> = e.seeds.iter().map(u64::to_string).collect();
        t.row(vec![
            short,
            e.fabric.clone(),
            e.app.clone(),
            fmt(e.objectives.area_um2),
            fmt(e.objectives.period_ps),
            format!("{:.3}", e.objectives.throughput),
            seeds.join(","),
        ]);
    }
    t.note(&format!(
        "{} evaluations ({} cross-product): {} pruned, {} dropped, {} rounds; \
         {} PnR runs, {} sims, {} cache hits",
        out.evaluated,
        out.cross_product,
        out.pruned,
        out.dropped,
        out.rounds,
        out.stats.pnr_runs,
        out.stats.sims,
        out.stats.cache_hits
    ));
    t
}

/// Machine-readable record of one tune run (what the service's `tune`
/// result frames embed).
pub fn tune_json(out: &TuneOutcome) -> Json {
    let frontier: Vec<Json> = out
        .frontier
        .iter()
        .map(|e| {
            Json::Obj(vec![
                ("config".into(), Json::str(&e.config)),
                ("app".into(), Json::str(&e.app)),
                ("fabric".into(), Json::str(&e.fabric)),
                ("area_um2".into(), Json::num_f64(e.objectives.area_um2)),
                ("period_ps".into(), Json::num_f64(e.objectives.period_ps)),
                ("throughput".into(), Json::num_f64(e.objectives.throughput)),
                (
                    "seeds".into(),
                    Json::Arr(e.seeds.iter().map(|&s| Json::num_u64(s)).collect()),
                ),
            ])
        })
        .collect();
    Json::Obj(vec![
        ("name".into(), Json::str(&out.name)),
        ("evaluated".into(), Json::num_u64(out.evaluated)),
        ("pruned".into(), Json::num_u64(out.pruned)),
        ("dropped".into(), Json::num_u64(out.dropped)),
        ("rounds".into(), Json::num_u64(out.rounds)),
        ("cross_product".into(), Json::num_u64(out.cross_product)),
        ("stats".into(), super::report::stats_json(&out.stats)),
        ("frontier".into(), Json::Arr(frontier)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obj(area: f64, period: f64, thpt: f64) -> Objectives {
        Objectives { area_um2: area, period_ps: period, throughput: thpt }
    }

    fn entry(config: &str, app: &str, o: Objectives) -> ParetoEntry {
        ParetoEntry {
            config: config.into(),
            app: app.into(),
            fabric: "static".into(),
            objectives: o,
            seeds: vec![1],
        }
    }

    #[test]
    fn dominance_is_strict_and_irreflexive() {
        let a = obj(10.0, 100.0, 0.5);
        let better_area = obj(9.0, 100.0, 0.5);
        let better_all = obj(9.0, 90.0, 0.6);
        let tradeoff = obj(9.0, 110.0, 0.5);
        assert!(dominates(&better_area, &a));
        assert!(dominates(&better_all, &a));
        assert!(!dominates(&a, &better_area));
        // A trade-off dominates in neither direction.
        assert!(!dominates(&tradeoff, &a));
        assert!(!dominates(&a, &tradeoff));
        // Irreflexive: equal points never dominate each other, so ties
        // survive to the frontier.
        assert!(!dominates(&a, &a));
    }

    #[test]
    fn dominance_is_antisymmetric() {
        let pts = [
            obj(10.0, 100.0, 0.5),
            obj(9.0, 100.0, 0.5),
            obj(9.0, 90.0, 0.6),
            obj(11.0, 80.0, 0.9),
            Objectives::unroutable(),
        ];
        for x in &pts {
            for y in &pts {
                assert!(
                    !(dominates(x, y) && dominates(y, x)),
                    "both dominate: {x:?} vs {y:?}"
                );
            }
        }
    }

    #[test]
    fn nan_never_dominates_and_always_loses_to_finite() {
        let nan = Objectives::unroutable();
        let one_nan = obj(10.0, f64::NAN, 0.5);
        let fin = obj(1e12, 1e12, 0.0); // terrible but finite
        for bad in [&nan, &one_nan] {
            assert!(!dominates(bad, &fin), "NaN dominated a finite point");
            assert!(!dominates(bad, bad));
            assert!(dominates(&fin, bad), "finite must beat unroutable");
        }
    }

    #[test]
    fn fold_aggregates_min_period_max_throughput() {
        let mut a = Objectives::unroutable();
        a.fold(&obj(10.0, 100.0, 0.5));
        assert_eq!(a, obj(10.0, 100.0, 0.5));
        a.fold(&obj(10.0, 90.0, 0.4));
        assert_eq!(a, obj(10.0, 90.0, 0.5));
        // A NaN seed leaves a finite aggregate untouched.
        a.fold(&Objectives::unroutable());
        assert_eq!(a, obj(10.0, 90.0, 0.5));
    }

    #[test]
    fn frontier_keeps_nondominated_and_ties_per_app() {
        let entries = vec![
            entry("a", "app1", obj(10.0, 100.0, 0.5)),
            entry("b", "app1", obj(9.0, 100.0, 0.5)), // dominates a
            entry("c", "app1", obj(11.0, 80.0, 0.9)), // trade-off
            entry("d", "app1", obj(9.0, 100.0, 0.5)), // ties b
            entry("e", "app2", obj(1000.0, 1000.0, 0.1)), // other app
        ];
        let f = pareto_frontier(&entries);
        let names: Vec<&str> = f.iter().map(|e| e.config.as_str()).collect();
        assert_eq!(names, vec!["b", "c", "d", "e"]);
    }

    #[test]
    fn nan_point_result_classifies_as_unroutable() {
        // The regression at the heart of the NaN satellite: a routed
        // point whose runtime round-tripped through JSON null.
        let mut p = PointResult::unroutable();
        p.routed = true;
        p.critical_path_ps = 100.0;
        p.period_ps = 120.0;
        p.runtime_ns = f64::NAN;
        assert!(!p.has_finite_metrics());
        assert!(!objectives_of(&p, 10.0).is_finite());
        let fine = PointResult {
            routed: true,
            critical_path_ps: 100.0,
            period_ps: 120.0,
            latency_cycles: 4,
            runtime_ns: 480.0,
            iterations: 1,
            nodes_used: 8,
            alpha: 1.0,
            sim_cycles: 100,
            sim_tokens: 90,
            stall_cycles: 10,
        };
        assert!(fine.has_finite_metrics());
        let o = objectives_of(&fine, 10.0);
        assert_eq!(o, obj(10.0, 120.0, 0.9));
        assert!(dominates(&o, &objectives_of(&p, 1.0)));
    }

    #[test]
    fn archive_roundtrip_is_byte_identical_and_loud_on_corruption() {
        let mut a = ParetoArchive::in_memory();
        a.merge(entry("cfg-b", "app1", obj(9.0, 100.0 / 3.0, 0.5)));
        a.merge(entry("cfg-a", "app1", obj(10.0, 100.0, 0.5)));
        let text = a.to_json();
        let mut back = ParetoArchive::in_memory();
        back.load_json(&text).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back.to_json(), text, "re-emission must be byte-identical");
        // Corrupt / versioned / non-finite files are loud.
        assert!(ParetoArchive::in_memory().load_json("{not json").is_err());
        assert!(ParetoArchive::in_memory()
            .load_json(r#"{"version": 99, "entries": []}"#)
            .is_err());
        assert!(ParetoArchive::in_memory()
            .load_json(r#"{"version": 1, "entries": [{"config": "x"}]}"#)
            .is_err());
        // A non-finite objective (the `null` a NaN would serialize to)
        // is corruption here, not data — the archive never holds NaN.
        let nan = r#"{"version": 1, "entries": [
            {"config": "c", "app": "a", "fabric": "static",
             "area_um2": 1.0, "period_ps": null, "throughput": 0.5,
             "seeds": [1]}]}"#;
        assert!(ParetoArchive::in_memory().load_json(nan).is_err());
    }

    #[test]
    fn archive_merge_unions_seeds_and_improves_objectives() {
        let mut a = ParetoArchive::in_memory();
        let mut first = entry("cfg", "app", obj(10.0, 100.0, 0.5));
        first.seeds = vec![1, 3];
        a.merge(first);
        let mut second = entry("cfg", "app", obj(10.0, 90.0, 0.4));
        second.seeds = vec![2, 3];
        a.merge(second);
        let e = a.entries().next().unwrap();
        assert_eq!(e.objectives, obj(10.0, 90.0, 0.5));
        assert_eq!(e.seeds, vec![1, 2, 3]);
        // NaN entries never enter.
        a.merge(entry("cfg2", "app", Objectives::unroutable()));
        assert_eq!(a.len(), 1);
    }

    #[test]
    fn archive_prunes_to_its_own_frontier() {
        let mut a = ParetoArchive::in_memory();
        a.merge(entry("big", "app", obj(10.0, 100.0, 0.5)));
        a.merge(entry("small", "app", obj(9.0, 100.0, 0.5)));
        a.merge(entry("fast", "app", obj(11.0, 80.0, 0.9)));
        a.prune_to_frontier();
        let names: Vec<&str> = a.entries().map(|e| e.config.as_str()).collect();
        assert_eq!(names, vec!["fast", "small"]);
    }

    #[test]
    fn archive_path_sits_next_to_the_cache() {
        let p = archive_path_for(Path::new("/x/dse_cache.json"));
        assert_eq!(p, Path::new("/x/dse_cache_pareto.json"));
    }

    #[test]
    fn file_backed_archive_roundtrip() {
        let path = std::env::temp_dir()
            .join(format!("canal_tune_archive_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        {
            let mut a = ParetoArchive::at(&path).unwrap();
            assert!(a.is_empty());
            a.merge(entry("cfg", "app", obj(10.0, 100.0, 0.5)));
            a.save().unwrap();
        }
        let a = ParetoArchive::at(&path).unwrap();
        assert_eq!(a.len(), 1);
        assert_eq!(a.entries().next().unwrap().objectives, obj(10.0, 100.0, 0.5));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn period_lower_bound_is_positive_and_model_sensitive() {
        let base = crate::dsl::InterconnectConfig {
            width: 4,
            height: 4,
            mem_column_period: 3,
            ..Default::default()
        };
        let ic = crate::dsl::create_uniform_interconnect(&base);
        let lb = period_lower_bound_ps(&ic, base.track_widths[0]);
        assert!(lb > 0.0, "a real graph has at least one ported hop");
        // Same model, more tracks: the cheapest hop is unchanged — this
        // is exactly why the pre-prune is a no-op across track counts.
        let wide =
            crate::dsl::InterconnectConfig { num_tracks: base.num_tracks + 1, ..base.clone() };
        let wic = crate::dsl::create_uniform_interconnect(&wide);
        assert_eq!(lb, period_lower_bound_ps(&wic, wide.track_widths[0]));
        // A slower wire model raises the bound.
        let mut slow = base.clone();
        slow.delays.wire_ps += 100;
        let sic = crate::dsl::create_uniform_interconnect(&slow);
        assert!(period_lower_bound_ps(&sic, slow.track_widths[0]) > lb);
    }
}
