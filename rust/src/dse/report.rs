//! Results store: collects sweep outcomes, renders the paper-style
//! [`Table`]s, and emits a machine-readable JSON record for the bench
//! log (`canal dse --json FILE`).

use std::path::Path;

use crate::dsl::InterconnectConfig;
use crate::util::json::Json;
use crate::util::table::{fmt, Table};

use super::exec::{EngineStats, SweepOutcome};

/// Compact one-line config label for generic point tables.
pub fn short_config(cfg: &InterconnectConfig) -> String {
    format!(
        "{}x{} t={} {} sb{}/cb{} {}",
        cfg.width,
        cfg.height,
        cfg.num_tracks,
        cfg.sb_topology.name(),
        cfg.sb_core_sides.0,
        cfg.cb_core_sides.0,
        cfg.output_tracks.name(),
    )
}

/// Generic one-row-per-point table for ad-hoc `canal dse` sweeps.
pub fn points_table(outcome: &SweepOutcome) -> Table {
    let mut t = Table::new(
        &format!("DSE sweep — {}", outcome.name),
        &["config", "fabric", "app", "seed", "routed", "runtime_us", "critical_ps", "thpt", "iters"],
    );
    for (job, r) in &outcome.points {
        let dash = || "-".to_string();
        // Metric cells gate on `has_finite_metrics`, not `routed`: a
        // routed point loaded from a warm cache can carry NaN metrics
        // (JSON `null` round trip) and must render as data-less rather
        // than printing "NaN".
        let finite = r.has_finite_metrics();
        t.row(vec![
            short_config(&job.cfg),
            job.fabric.label(),
            job.app_name.clone(),
            job.key.seed.to_string(),
            if r.routed { "yes".into() } else { "no".into() },
            if finite { fmt(r.runtime_us()) } else { dash() },
            if finite { fmt(r.critical_path_ps) } else { dash() },
            if r.sim_cycles > 0 { format!("{:.3}", r.throughput()) } else { dash() },
            r.iterations.to_string(),
        ]);
    }
    let s = &outcome.stats;
    let mut note = format!(
        "{} jobs: {} cached, {} PnR runs, {} sims, {} configs built, {} batched solves, \
         {} steals",
        s.jobs, s.cache_hits, s.pnr_runs, s.sims, s.configs_built, s.batched_solves, s.steals
    );
    if s.warm_starts > 0 {
        note.push_str(&format!(
            ", {} warm starts ({} nets reused, {} rerouted)",
            s.warm_starts, s.nets_reused, s.nets_rerouted
        ));
    }
    t.note(&note);
    t
}

/// Per-(config, fabric) area table for area-enabled sweeps.
pub fn areas_table(outcome: &SweepOutcome) -> Table {
    let mut t = Table::new(
        &format!("DSE areas — {}", outcome.name),
        &["tracks", "fabric", "sb_sides", "cb_sides", "sb_area_um2", "cb_area_um2"],
    );
    for a in &outcome.areas {
        t.row(vec![
            a.tracks.to_string(),
            a.fabric.clone(),
            a.sb_sides.to_string(),
            a.cb_sides.to_string(),
            fmt(a.sb_um2),
            fmt(a.cb_um2),
        ]);
    }
    t
}

/// Machine-readable [`EngineStats`] (also what the service's `dse`
/// result frames embed — the loopback tests assert warm re-runs report
/// `pnr_runs == 0 && sims == 0` through this).
pub fn stats_json(s: &EngineStats) -> Json {
    Json::Obj(vec![
        ("jobs".into(), Json::num_u64(s.jobs)),
        ("cache_hits".into(), Json::num_u64(s.cache_hits)),
        ("coalesced".into(), Json::num_u64(s.coalesced)),
        ("pnr_runs".into(), Json::num_u64(s.pnr_runs)),
        ("sims".into(), Json::num_u64(s.sims)),
        ("configs_built".into(), Json::num_u64(s.configs_built)),
        ("steals".into(), Json::num_u64(s.steals)),
        ("batched_solves".into(), Json::num_u64(s.batched_solves)),
        ("warm_starts".into(), Json::num_u64(s.warm_starts)),
        ("nets_reused".into(), Json::num_u64(s.nets_reused)),
        ("nets_rerouted".into(), Json::num_u64(s.nets_rerouted)),
        ("route_expansions".into(), Json::num_u64(s.route_expansions)),
    ])
}

/// Mirror one run's [`EngineStats`] into the process-wide metrics
/// registry as monotonic `engine.*` counters, so the registry view
/// accumulates across runs while the struct stays the per-run report.
/// Called by the executor at the end of every sweep when metrics are
/// enabled; cheap enough to call unconditionally, but gated on
/// [`crate::obs::metrics_on`] upstream so the disabled path stays
/// zero-cost.
pub fn publish_engine_stats(s: &EngineStats) {
    use crate::obs::metrics::counter;
    counter("engine.jobs").add(s.jobs);
    counter("engine.cache_hits").add(s.cache_hits);
    counter("engine.coalesced").add(s.coalesced);
    counter("engine.pnr_runs").add(s.pnr_runs);
    counter("engine.sims").add(s.sims);
    counter("engine.configs_built").add(s.configs_built);
    counter("engine.steals").add(s.steals);
    counter("engine.batched_solves").add(s.batched_solves);
    counter("engine.warm_starts").add(s.warm_starts);
    counter("engine.nets_reused").add(s.nets_reused);
    counter("engine.nets_rerouted").add(s.nets_rerouted);
    counter("engine.route_expansions").add(s.route_expansions);
    counter("engine.sweeps").inc();
}

/// Machine-readable record of one sweep (points + areas + stats).
pub fn outcome_json(outcome: &SweepOutcome) -> Json {
    let points: Vec<Json> = outcome
        .points
        .iter()
        .map(|(job, r)| {
            Json::Obj(vec![
                ("config".into(), Json::str(&job.key.config.0)),
                ("fabric".into(), Json::str(&job.fabric.label())),
                ("app".into(), Json::str(&job.key.app)),
                ("app_name".into(), Json::str(&job.app_name)),
                ("seed".into(), Json::num_u64(job.key.seed)),
                ("tracks".into(), Json::num_u64(job.cfg.num_tracks as u64)),
                ("topology".into(), Json::str(job.cfg.sb_topology.name())),
                ("sb_sides".into(), Json::num_u64(job.cfg.sb_core_sides.0 as u64)),
                ("cb_sides".into(), Json::num_u64(job.cfg.cb_core_sides.0 as u64)),
                ("routed".into(), Json::Bool(r.routed)),
                ("runtime_ns".into(), Json::num_f64(r.runtime_ns)),
                ("critical_path_ps".into(), Json::num_f64(r.critical_path_ps)),
                ("period_ps".into(), Json::num_f64(r.period_ps)),
                ("latency_cycles".into(), Json::num_u64(r.latency_cycles)),
                ("iterations".into(), Json::num_u64(r.iterations)),
                ("nodes_used".into(), Json::num_u64(r.nodes_used)),
                ("alpha".into(), Json::num_f64(r.alpha)),
                ("sim_cycles".into(), Json::num_u64(r.sim_cycles)),
                ("sim_tokens".into(), Json::num_u64(r.sim_tokens)),
                ("stall_cycles".into(), Json::num_u64(r.stall_cycles)),
                ("throughput".into(), Json::num_f64(r.throughput())),
            ])
        })
        .collect();
    let areas: Vec<Json> = outcome
        .areas
        .iter()
        .map(|a| {
            Json::Obj(vec![
                ("config".into(), Json::str(&a.config)),
                ("fabric".into(), Json::str(&a.fabric)),
                ("tracks".into(), Json::num_u64(a.tracks as u64)),
                ("sb_sides".into(), Json::num_u64(a.sb_sides as u64)),
                ("cb_sides".into(), Json::num_u64(a.cb_sides as u64)),
                ("sb_um2".into(), Json::num_f64(a.sb_um2)),
                ("cb_um2".into(), Json::num_f64(a.cb_um2)),
            ])
        })
        .collect();
    Json::Obj(vec![
        ("name".into(), Json::str(&outcome.name)),
        ("stats".into(), stats_json(&outcome.stats)),
        ("points".into(), Json::Arr(points)),
        ("areas".into(), Json::Arr(areas)),
    ])
}

/// Accumulates sweeps: the rendered tables for humans, the raw records
/// for machines.
#[derive(Default)]
pub struct ResultsStore {
    tables: Vec<Table>,
    records: Vec<Json>,
}

impl ResultsStore {
    pub fn new() -> ResultsStore {
        ResultsStore::default()
    }

    /// Record one sweep with the table its figure built from it.
    pub fn add(&mut self, outcome: &SweepOutcome, table: Table) {
        self.records.push(outcome_json(outcome));
        self.tables.push(table);
    }

    pub fn tables(&self) -> &[Table] {
        &self.tables
    }

    pub fn render_all(&self) -> String {
        let mut s = String::new();
        for t in &self.tables {
            s.push_str(&t.render());
            s.push('\n');
        }
        s
    }

    /// The bench record: every sweep's raw points under one roof.
    pub fn to_json(&self) -> String {
        Json::Obj(vec![
            ("version".into(), Json::num_u64(1)),
            ("sweeps".into(), Json::Arr(self.records.clone())),
        ])
        .render()
    }

    pub fn write_json(&self, path: &Path) -> Result<(), String> {
        std::fs::write(path, self.to_json()).map_err(|e| format!("{}: {e}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::{DseEngine, SweepSpec};
    use crate::dsl::InterconnectConfig;
    use crate::pnr::{FlowParams, NativePlacer, SaParams};

    #[test]
    fn store_renders_tables_and_valid_json() {
        let spec = SweepSpec {
            name: "report-test".into(),
            base: InterconnectConfig { mem_column_period: 3, ..Default::default() },
            apps: vec!["pointwise".into()],
            seeds: vec![1],
            flow: FlowParams {
                sa: SaParams { moves_per_node: 4, ..Default::default() },
                ..Default::default()
            },
            area: true,
            ..Default::default()
        };
        let mut engine = DseEngine::in_memory();
        let out = engine.run(&spec, &NativePlacer::default()).unwrap();
        let mut store = ResultsStore::new();
        store.add(&out, points_table(&out));
        store.add(&out, areas_table(&out));
        assert_eq!(store.tables().len(), 2);
        let rendered = store.render_all();
        assert!(rendered.contains("DSE sweep — report-test"));
        assert!(rendered.contains("pointwise"));
        // The JSON record parses back and carries both sweeps.
        let doc = Json::parse(&store.to_json()).unwrap();
        let sweeps = doc.get("sweeps").and_then(Json::as_arr).unwrap();
        assert_eq!(sweeps.len(), 2);
        let first = &sweeps[0];
        assert_eq!(first.get("name").and_then(Json::as_str), Some("report-test"));
        assert_eq!(
            first.get("stats").and_then(|s| s.get("jobs")).and_then(Json::as_u64),
            Some(1)
        );
        assert_eq!(first.get("points").and_then(Json::as_arr).map(<[Json]>::len), Some(1));
        assert_eq!(first.get("areas").and_then(Json::as_arr).map(<[Json]>::len), Some(1));
    }
}
