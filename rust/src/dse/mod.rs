//! Design-space exploration engine (§4's "fast design space exploration"
//! claim, industrialized): the sharded, cached sweep infrastructure the
//! ROADMAP's parallel-DSE item called for.
//!
//! - [`SweepSpec`] (in [`spec`]) declaratively enumerates the
//!   cross-product of axes — tracks × SB topology × connected sides ×
//!   output-track mode × fabric (static vs ready-valid, §3.3) × apps ×
//!   seeds — into a deduplicated job list with stable
//!   [`ConfigDescriptor`] keys;
//! - [`DseEngine`] (in [`exec`]) runs the jobs on a fixed worker pool:
//!   per-worker deques of per-config *job groups* with work stealing,
//!   one batched global-placement solve per group
//!   ([`crate::pnr::GlobalPlacer::place_batch`]), per-worker reusable
//!   [`crate::pnr::RouterScratch`] buffers, and interconnects frozen once
//!   per configuration then shared across workers via `Arc` (the
//!   immutable CSR [`crate::ir::CompiledGraph`]s inside). Every routed
//!   point additionally runs the flattened elastic simulator
//!   ([`crate::sim::RvSim`]) on its own routing under the job's
//!   [`crate::sim::FabricKind`], recording throughput/stall metrics;
//! - [`ResultCache`] (in [`cache`]) keys results by
//!   `(config, app, seed)` and persists them to `dse_cache.json`, so
//!   re-runs and overlapping figures skip completed PnR — a warm re-run
//!   of the full figure suite performs zero PnR calls;
//! - [`ResultsStore`] (in [`report`]) emits both the paper-style
//!   [`crate::util::table::Table`]s and a machine-readable JSON record.
//!
//! The figure drivers in [`crate::coordinator::experiments`]
//! (fig07/08/09/10/11/14/15 — fig07/08 are the §3.3 static-vs-hybrid
//! comparison) are thin table-formatters over this engine, and the
//! `canal dse` CLI subcommand exposes it for ad-hoc sweeps
//! (`--fabric static,rv-full,rv-split` selects the fabric axis).
//!
//! Determinism contract: sharded results — any worker count, cache cold
//! or warm — are bit-identical to a sequential baseline run of the same
//! spec (asserted in `tests/dse_determinism.rs`).
//!
//! The executor is decomposed so the persistent daemon
//! ([`crate::service`]) can share warm state across concurrent
//! sessions: [`execute_jobs`] is the cache-free cold path over an
//! [`InterconnectSource`] (the service plugs in a process-wide LRU of
//! frozen interconnects), and [`run_sweep`] is the engine-handle form
//! that borrows a caller-owned [`ResultCache`] instead of owning one.

//!
//! Incremental PnR (`EngineOptions::warm_start`, off by default): a
//! [`PnrArtifactCache`] (in [`artifacts`]) keeps each point's legalized
//! placement and routed sink paths; neighboring points (small
//! [`AxisDelta`] reuse distance) are warm-started from the nearest
//! donor — seeded placement plus [`crate::pnr::route_with_seed`] tree
//! replay — and job groups are ordered along a nearest-neighbor chain
//! so each group runs right after its best donor. See
//! `docs/dse.md § Incremental PnR`.

//!
//! Search, not enumeration (`canal tune`): [`run_tune`] (in [`tune`])
//! finds the (area × period × throughput) Pareto frontier of a spec
//! without visiting the cross-product — cheap-model pre-pruning (exact
//! area + a wire-delay period lower bound, no PnR), successive halving
//! across seeds with NaN-safe strict-dominance checks, and a persisted
//! [`ParetoArchive`] whose incumbents re-anchor future searches. Every
//! real evaluation is a one-candidate spec through the machinery above,
//! so the cache keys line up and revisited points are free. See
//! `docs/tune.md`.

pub mod artifacts;
pub mod cache;
pub mod exec;
pub mod report;
pub mod spec;
pub mod tune;

pub use artifacts::{
    artifact_path_for, decode_node, encode_node, PnrArtifact, PnrArtifactCache, ARTIFACT_VERSION,
};
pub use cache::{ResultCache, CACHE_VERSION};
pub use exec::{
    area_points, execute_jobs, execute_jobs_obs, execute_jobs_with, resolve_workers, run_sweep,
    run_sweep_with, BuildFresh, ColdOutcome, DseEngine, EngineOptions, EngineStats,
    InterconnectSource, ProgressSnapshot, SweepOutcome, SweepProgress, SIM_TOKENS_CAP,
};
pub use report::{
    areas_table, outcome_json, points_table, publish_engine_stats, short_config, stats_json,
    ResultsStore,
};
pub use spec::{
    app_by_name, dense_suite_keys, registry_keys, suite_keys, AreaPoint, AxisDelta, AxisTokens,
    ConfigDescriptor, Job, JobKey, PointResult, SeedMode, Sizing, SweepSpec, MAX_DONOR_DISTANCE,
};
pub use tune::{
    archive_path_for, dominates, frontier_table, objectives_of, pareto_frontier,
    period_lower_bound_ps, run_tune, tune_json, ArchiveKey, Objectives, ParetoArchive,
    ParetoEntry, TuneOptions, TuneOutcome, TUNE_VERSION,
};
