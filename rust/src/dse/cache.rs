//! PnR result cache keyed by `(config descriptor, app, seed)` with JSON
//! persistence — re-runs and overlapping sweeps (fig09/10/11/14/15 share
//! many points) skip completed PnR entirely.
//!
//! ## File format (`dse_cache.json`, version 1)
//!
//! ```json
//! { "version": 1,
//!   "entries": [
//!     { "config": "<ConfigDescriptor string>", "app": "harris", "seed": 1,
//!       "routed": true, "critical_path_ps": 2209.0, "period_ps": 2269.0,
//!       "latency_cycles": 14, "runtime_ns": 9378.25, "iterations": 3,
//!       "nodes_used": 412, "alpha": 1.0,
//!       "sim_cycles": 532, "sim_tokens": 512, "stall_cycles": 20 } ] }
//! ```
//!
//! Floats are written in Rust's shortest-round-trip form and numbers are
//! re-emitted from their literal text (see [`crate::util::json`]), so a
//! load → save cycle is lossless and a warm-cache table render is
//! byte-identical to the cold one. Unroutable points are cached too
//! (`routed: false`, zero metrics) — negative results are as expensive to
//! recompute as positive ones.
//!
//! ## Versioning policy
//!
//! The version number only changes for *incompatible* layouts. The
//! elastic-simulation fields (`sim_cycles`, `sim_tokens`,
//! `stall_cycles`, added with the fabric sweep axis) are **optional on
//! read and always written**: a pre-fabric-axis cache file (entries
//! without them) still loads — the fields default to `0`, the
//! documented "never simulated" value — and an old reader simply
//! ignores the extra keys. Static-fabric descriptors deliberately carry
//! no `fabric=` token (see [`ConfigDescriptor::of`]), so such a file's
//! PnR results stay warm; delete the cache file to backfill the
//! simulation metrics.

use std::collections::BTreeMap;
use std::io;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

use super::spec::{ConfigDescriptor, JobKey, PointResult};

/// Cache file schema version.
pub const CACHE_VERSION: u64 = 1;

/// In-memory map of completed points, optionally backed by a JSON file.
#[derive(Default)]
pub struct ResultCache {
    path: Option<PathBuf>,
    map: BTreeMap<JobKey, PointResult>,
}

impl ResultCache {
    /// Unbacked cache (lives for the engine's lifetime only).
    pub fn in_memory() -> ResultCache {
        ResultCache::default()
    }

    /// Cache backed by `path`: loads what is there (a missing file is an
    /// empty cache; a corrupt one is an error — better loud than silently
    /// recomputing or clobbering). A missing file is created immediately,
    /// so an unwritable path fails here — before a sweep spends hours of
    /// PnR it could not have persisted.
    pub fn at(path: &Path) -> Result<ResultCache, String> {
        let mut cache =
            ResultCache { path: Some(path.to_path_buf()), map: BTreeMap::new() };
        match std::fs::read_to_string(path) {
            Ok(text) => cache.load_json(&text).map_err(|e| format!("{}: {e}", path.display()))?,
            Err(e) if e.kind() == io::ErrorKind::NotFound => cache.save()?,
            Err(e) => return Err(format!("{}: {e}", path.display())),
        }
        Ok(cache)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    pub fn get(&self, key: &JobKey) -> Option<&PointResult> {
        self.map.get(key)
    }

    pub fn contains(&self, key: &JobKey) -> bool {
        self.map.contains_key(key)
    }

    pub fn insert(&mut self, key: JobKey, result: PointResult) {
        self.map.insert(key, result);
    }

    /// Entries in key order (what [`Self::to_json`] serializes).
    pub fn iter(&self) -> impl Iterator<Item = (&JobKey, &PointResult)> {
        self.map.iter()
    }

    /// In-memory copy of the current entries, detached from any backing
    /// file — the service's figure path runs a throwaway engine over a
    /// snapshot, then merges new entries back into the shared cache.
    pub fn snapshot(&self) -> ResultCache {
        ResultCache { path: None, map: self.map.clone() }
    }

    /// Merge entries from cache-file text.
    pub fn load_json(&mut self, text: &str) -> Result<(), String> {
        let doc = Json::parse(text)?;
        let version = doc.get("version").and_then(Json::as_u64).ok_or("missing version")?;
        if version != CACHE_VERSION {
            return Err(format!("unsupported cache version {version}"));
        }
        let entries = doc.get("entries").and_then(Json::as_arr).ok_or("missing entries")?;
        for (i, entry) in entries.iter().enumerate() {
            let (key, result) =
                entry_from_json(entry).map_err(|e| format!("entry {i}: {e}"))?;
            self.map.insert(key, result);
        }
        Ok(())
    }

    /// Full cache as JSON text.
    pub fn to_json(&self) -> String {
        let entries: Vec<Json> =
            self.map.iter().map(|(k, r)| entry_json(k, r)).collect();
        Json::Obj(vec![
            ("version".into(), Json::num_u64(CACHE_VERSION)),
            ("entries".into(), Json::Arr(entries)),
        ])
        .render()
    }

    /// Persist to the backing file (no-op for in-memory caches).
    pub fn save(&self) -> Result<(), String> {
        match &self.path {
            Some(path) => self.save_to(path),
            None => Ok(()),
        }
    }

    /// Persist to an explicit path — how the service writes: it
    /// snapshots the shared cache under its request lock (a cheap map
    /// clone) and serializes + writes *outside* it, so concurrent
    /// sessions never block on disk I/O. Writes a sibling temp file and
    /// renames it over the target, so an interrupted save can never
    /// truncate an existing cache.
    pub fn save_to(&self, path: &Path) -> Result<(), String> {
        atomic_write(path, &self.to_json())
    }
}

/// Write `text` to `path` atomically: write a uniquely-named sibling
/// temp file, then rename it over the target. The temp name carries the
/// pid and a process-global sequence number so concurrent writers (two
/// engines saving next to the same cache file, or a service writing
/// while a CLI run saves) never scribble over each other's temp file —
/// last rename wins, but every rename installs a *complete* file. A
/// failed write or rename removes the temp file instead of leaking it.
pub(crate) fn atomic_write(path: &Path, text: &str) -> Result<(), String> {
    use std::sync::atomic::{AtomicU64, Ordering};
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(format!(".{}.{}.tmp", std::process::id(), seq));
    let tmp = path.with_file_name(name);
    std::fs::write(&tmp, text).map_err(|e| {
        let _ = std::fs::remove_file(&tmp);
        format!("{}: {e}", tmp.display())
    })?;
    std::fs::rename(&tmp, path).map_err(|e| {
        let _ = std::fs::remove_file(&tmp);
        format!("{}: {e}", path.display())
    })
}

pub(crate) fn entry_json(key: &JobKey, r: &PointResult) -> Json {
    Json::Obj(vec![
        ("config".into(), Json::str(&key.config.0)),
        ("app".into(), Json::str(&key.app)),
        ("seed".into(), Json::num_u64(key.seed)),
        ("routed".into(), Json::Bool(r.routed)),
        ("critical_path_ps".into(), Json::num_f64(r.critical_path_ps)),
        ("period_ps".into(), Json::num_f64(r.period_ps)),
        ("latency_cycles".into(), Json::num_u64(r.latency_cycles)),
        ("runtime_ns".into(), Json::num_f64(r.runtime_ns)),
        ("iterations".into(), Json::num_u64(r.iterations)),
        ("nodes_used".into(), Json::num_u64(r.nodes_used)),
        ("alpha".into(), Json::num_f64(r.alpha)),
        ("sim_cycles".into(), Json::num_u64(r.sim_cycles)),
        ("sim_tokens".into(), Json::num_u64(r.sim_tokens)),
        ("stall_cycles".into(), Json::num_u64(r.stall_cycles)),
    ])
}

pub(crate) fn entry_from_json(v: &Json) -> Result<(JobKey, PointResult), String> {
    let str_field = |k: &str| -> Result<String, String> {
        v.get(k)
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| format!("missing `{k}`"))
    };
    let u64_field = |k: &str| -> Result<u64, String> {
        v.get(k).and_then(Json::as_u64).ok_or_else(|| format!("missing `{k}`"))
    };
    // Fields added after version-1 files already existed in the wild:
    // absent means "never simulated" (0), present must parse. This keeps
    // pre-fabric-axis caches loadable without a version bump.
    let u64_opt = |k: &str| -> Result<u64, String> {
        match v.get(k) {
            None => Ok(0),
            Some(j) => j.as_u64().ok_or_else(|| format!("bad `{k}`")),
        }
    };
    // `num_f64` writes non-finite values as `null` (JSON has no NaN/inf);
    // accept them back as NaN rather than hard-failing the whole cache —
    // one odd metric must not brick every future run.
    let f64_field = |k: &str| -> Result<f64, String> {
        match v.get(k) {
            Some(Json::Null) => Ok(f64::NAN),
            Some(j) => j.as_f64().ok_or_else(|| format!("bad `{k}`")),
            None => Err(format!("missing `{k}`")),
        }
    };
    let key = JobKey {
        config: ConfigDescriptor(str_field("config")?),
        app: str_field("app")?,
        seed: u64_field("seed")?,
    };
    let result = PointResult {
        routed: v.get("routed").and_then(Json::as_bool).ok_or("missing `routed`")?,
        critical_path_ps: f64_field("critical_path_ps")?,
        period_ps: f64_field("period_ps")?,
        latency_cycles: u64_field("latency_cycles")?,
        runtime_ns: f64_field("runtime_ns")?,
        iterations: u64_field("iterations")?,
        nodes_used: u64_field("nodes_used")?,
        alpha: f64_field("alpha")?,
        sim_cycles: u64_opt("sim_cycles")?,
        sim_tokens: u64_opt("sim_tokens")?,
        stall_cycles: u64_opt("stall_cycles")?,
    };
    Ok((key, result))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(app: &str, seed: u64) -> JobKey {
        JobKey { config: ConfigDescriptor("cfg-A".into()), app: app.into(), seed }
    }

    fn point(runtime_ns: f64) -> PointResult {
        PointResult {
            routed: true,
            critical_path_ps: 2209.123456789,
            period_ps: 2269.0,
            latency_cycles: 14,
            runtime_ns,
            iterations: 3,
            nodes_used: 412,
            alpha: 1.0,
            sim_cycles: 532,
            sim_tokens: 512,
            stall_cycles: 20,
        }
    }

    #[test]
    fn json_roundtrip_is_bit_exact() {
        let mut c = ResultCache::in_memory();
        c.insert(key("harris", 1), point(9378.0 / 3.0));
        c.insert(key("gaussian", 2), PointResult::unroutable());
        let text = c.to_json();
        let mut back = ResultCache::in_memory();
        back.load_json(&text).unwrap();
        assert_eq!(back.len(), 2);
        let orig = c.get(&key("harris", 1)).unwrap();
        let got = back.get(&key("harris", 1)).unwrap();
        assert_eq!(orig, got);
        assert_eq!(orig.runtime_ns.to_bits(), got.runtime_ns.to_bits());
        assert!(!back.get(&key("gaussian", 2)).unwrap().routed);
        // Stable re-emission.
        assert_eq!(back.to_json(), text);
    }

    #[test]
    fn file_backing_roundtrip() {
        let path = std::env::temp_dir()
            .join(format!("canal_cache_test_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        {
            let mut c = ResultCache::at(&path).unwrap();
            assert!(c.is_empty());
            c.insert(key("harris", 7), point(123.456));
            c.save().unwrap();
        }
        let c = ResultCache::at(&path).unwrap();
        assert_eq!(c.len(), 1);
        assert_eq!(c.get(&key("harris", 7)), Some(&point(123.456)));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn non_finite_floats_roundtrip_as_nan_instead_of_bricking() {
        let mut c = ResultCache::in_memory();
        let mut p = point(1.0);
        p.runtime_ns = f64::INFINITY; // written as null
        c.insert(key("harris", 1), p);
        let text = c.to_json();
        let mut back = ResultCache::in_memory();
        back.load_json(&text).unwrap();
        assert!(back.get(&key("harris", 1)).unwrap().runtime_ns.is_nan());
    }

    #[test]
    fn corrupt_or_versioned_files_are_loud() {
        let mut c = ResultCache::in_memory();
        assert!(c.load_json("{not json").is_err());
        assert!(c.load_json(r#"{"version": 99, "entries": []}"#).is_err());
        assert!(c.load_json(r#"{"version": 1}"#).is_err());
        assert!(c
            .load_json(r#"{"version": 1, "entries": [{"config": "x"}]}"#)
            .is_err());
    }

    #[test]
    fn atomic_write_unique_tmp_and_error_cleanup() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("canal_atomic_write_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        atomic_write(&path, "one").unwrap();
        atomic_write(&path, "two").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "two");
        // Every temp file was renamed or removed — none leak beside the
        // target.
        let stem = path.file_name().unwrap().to_string_lossy().into_owned();
        let leftovers: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.starts_with(&stem) && n.ends_with(".tmp"))
            .collect();
        assert!(leftovers.is_empty(), "stray temp files: {leftovers:?}");
        std::fs::remove_file(&path).unwrap();
        // A target in a missing directory fails loudly (and has nothing
        // to leak: the temp file shares the missing parent).
        let bad =
            dir.join(format!("canal_missing_dir_{}", std::process::id())).join("x.json");
        assert!(atomic_write(&bad, "x").is_err());
    }

    #[test]
    fn concurrent_save_to_never_installs_a_torn_file() {
        // Two caches racing save_to on one path: whichever rename lands
        // last wins, but the installed file is always one writer's
        // complete JSON (the old single-name temp scheme could rename a
        // half-written file the other writer was still filling).
        let path = std::env::temp_dir()
            .join(format!("canal_cache_race_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let mut a = ResultCache::in_memory();
        a.insert(key("harris", 1), point(1.0));
        let mut b = ResultCache::in_memory();
        b.insert(key("gaussian", 2), point(2.0));
        std::thread::scope(|s| {
            for c in [&a, &b] {
                s.spawn(move || {
                    for _ in 0..32 {
                        c.save_to(&path).unwrap();
                    }
                });
            }
        });
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text == a.to_json() || text == b.to_json(), "torn file: {text}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn in_memory_save_is_noop() {
        let c = ResultCache::in_memory();
        c.save().unwrap();
        assert!(c.path().is_none());
    }

    #[test]
    fn sim_fields_roundtrip_byte_identically() {
        // The fabric-axis fields must survive save → load → save with
        // the same bytes as everything else.
        let mut c = ResultCache::in_memory();
        let mut p = point(9378.25);
        p.sim_cycles = 123_456_789;
        p.sim_tokens = 4096;
        p.stall_cycles = 123_452_693;
        c.insert(key("harris", 1), p.clone());
        let text = c.to_json();
        assert!(text.contains("\"sim_cycles\":123456789"), "{text}");
        let mut back = ResultCache::in_memory();
        back.load_json(&text).unwrap();
        let got = back.get(&key("harris", 1)).unwrap();
        assert_eq!(got, &p);
        assert_eq!(got.sim_cycles, 123_456_789);
        assert_eq!(back.to_json(), text, "re-emission must be byte-identical");
    }

    #[test]
    fn pre_fabric_axis_cache_loads_with_documented_defaults() {
        // A version-1 file written before the fabric axis existed: no
        // sim_cycles/sim_tokens/stall_cycles keys. It must load (not be
        // invalidated), with the fields defaulting to 0 = "never
        // simulated" and throughput() = 0.
        let old = r#"{
  "version": 1,
  "entries": [
    { "config": "cfg-A", "app": "harris", "seed": 1,
      "routed": true, "critical_path_ps": 2209.0, "period_ps": 2269.0,
      "latency_cycles": 14, "runtime_ns": 9378.25, "iterations": 3,
      "nodes_used": 412, "alpha": 1.0 }
  ]
}"#;
        let mut c = ResultCache::in_memory();
        c.load_json(old).unwrap();
        let p = c.get(&key("harris", 1)).unwrap();
        assert!(p.routed);
        assert_eq!(p.runtime_ns, 9378.25);
        assert_eq!((p.sim_cycles, p.sim_tokens, p.stall_cycles), (0, 0, 0));
        assert_eq!(p.throughput(), 0.0);
        // Saving upgrades the entry in place: the new keys appear.
        assert!(c.to_json().contains("\"sim_cycles\":0"));
        // A present-but-malformed sim field is still loud.
        let bad = old.replace("\"alpha\": 1.0", "\"alpha\": 1.0, \"sim_cycles\": \"x\"");
        assert!(ResultCache::in_memory().load_json(&bad).is_err());
    }
}
