//! Sweep specification: the declarative cross-product of design-space
//! axes (tracks × SB topology × connected sides × output-track mode ×
//! fabric × apps × seeds), compiled into a deduplicated,
//! deterministically-ordered job list with stable [`ConfigDescriptor`]
//! keys.

use crate::apps;
use crate::dsl::{ConnectedSides, InterconnectConfig, OutputTrackMode, SbTopology};
use crate::pnr::{AppGraph, FlowParams, FlowResult};
use crate::sim::FabricKind;
use crate::util::rng::derive_seed;

/// Canonical key for one sweep point's *configuration*: the resolved
/// interconnect parameters (including the delay model) plus every flow
/// knob that can change a PnR result, plus the placement backend. The
/// per-run seed is keyed separately — see [`JobKey`].
#[derive(Clone, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct ConfigDescriptor(pub String);

impl std::fmt::Display for ConfigDescriptor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl ConfigDescriptor {
    pub fn of(
        cfg: &InterconnectConfig,
        flow: &FlowParams,
        placer: &str,
        seed_mode: SeedMode,
        fabric: FabricKind,
    ) -> ConfigDescriptor {
        let d = &cfg.delays;
        let alphas = if flow.alpha_sweep.is_empty() {
            format!("[{}]", flow.sa.alpha)
        } else {
            let v: Vec<String> = flow.alpha_sweep.iter().map(f64::to_string).collect();
            format!("[{}]", v.join(","))
        };
        let r = &flow.router;
        // `seed_mode` changes how the logical seed maps to the RNG
        // stream, so raw and derived runs must never share cache entries.
        let seeds = match seed_mode {
            SeedMode::Raw => "raw",
            SeedMode::Derived => "derived",
        };
        // The fabric joins the key only when it is not the static
        // default: every pre-fabric-axis cache entry was (implicitly)
        // static, so omitting the token for `Static` keeps those
        // descriptor strings — and the cached PnR behind them — warm.
        let fabric = match fabric {
            FabricKind::Static => String::new(),
            other => format!(" fabric={}", other.label()),
        };
        // Router-variant tokens follow the same warm-cache rule as the
        // fabric token: only result-changing settings join the key.
        // Bucket/radix frontiers are pure execution strategies (bit-
        // identical output), so they — like every default — emit
        // nothing, keeping pre-variant descriptor strings intact.
        let mut rvar = String::new();
        if r.search_core.changes_results() {
            rvar.push_str(&format!(" rcore={}", r.search_core.name()));
        }
        if r.slack_order {
            rvar.push_str(" rorder=slack");
        }
        if !r.steiner {
            rvar.push_str(" rsinks=independent");
        }
        ConfigDescriptor(format!(
            "{} delays={}/{}/{}/{}/{} | placer={placer} seeds={seeds} \
             sa(moves={} gamma={} cooling={}) \
             alphas={alphas} router(iters={} pres={}x{} hist={} dw={} unused={}) items={} bw={}{fabric}{rvar}",
            cfg.descriptor(),
            d.sb_mux_ps,
            d.cb_mux_ps,
            d.wire_ps,
            d.reg_clk_q_ps,
            d.reg_mux_ps,
            flow.sa.moves_per_node,
            flow.sa.gamma,
            flow.sa.cooling,
            r.max_iterations,
            r.pres_fac_init,
            r.pres_fac_mult,
            r.hist_incr,
            r.delay_weight,
            r.unused_tile_penalty,
            flow.workload_items,
            flow.bit_width,
        ))
    }
}

/// Donor-eligibility radius for incremental PnR: a cached artifact whose
/// [`AxisDelta::distance`] from the target exceeds this is too different
/// to seed from, and the point falls back to the scratch flow.
pub const MAX_DONOR_DISTANCE: u32 = 12;

/// The sweep-axis tokens of a [`ConfigDescriptor`], parsed back out of
/// the descriptor string. `rest` is the descriptor with those axis
/// *values* removed — the delay model, placer, and every flow knob. Two
/// points are reuse-compatible only when their `rest` strings match
/// exactly; everything else is captured by [`AxisDelta`].
#[derive(Clone, PartialEq, Eq, Debug)]
pub struct AxisTokens {
    pub width: u16,
    pub height: u16,
    pub tracks: u16,
    pub topology: String,
    pub sb_sides: u8,
    pub cb_sides: u8,
    pub out_tracks: String,
    /// Fabric label; "static" when the descriptor carries no fabric
    /// token (the pre-fabric-axis default).
    pub fabric: String,
    pub rest: String,
}

/// Typed difference between two descriptors' axis tokens: how far apart
/// two sweep points sit for placement/routing reuse. The weights order
/// axes by how much of a routed solution each one invalidates — a track
/// added keeps every old node and most edges, a topology swap rewires
/// every switch box, a fabric change does not touch PnR at all.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct AxisDelta {
    pub d_width: u32,
    pub d_height: u32,
    pub d_tracks: u32,
    pub topology_changed: bool,
    pub d_sb_sides: u32,
    pub d_cb_sides: u32,
    pub out_tracks_changed: bool,
    pub fabric_changed: bool,
}

impl AxisDelta {
    /// Reuse distance: 0 means the two points run an identical PnR
    /// problem. Compared against [`MAX_DONOR_DISTANCE`].
    pub fn distance(&self) -> u32 {
        self.d_tracks
            + 2 * (self.d_sb_sides + self.d_cb_sides)
            + if self.topology_changed { 6 } else { 0 }
            + if self.out_tracks_changed { 4 } else { 0 }
            + if self.fabric_changed { 1 } else { 0 }
            + 4 * (self.d_width + self.d_height)
    }
}

/// Extract `marker`'s value (up to the next space) and the byte range
/// the value occupies, searching from the start of `s`.
fn axis_value<'s>(s: &'s str, marker: &str) -> Option<(std::ops::Range<usize>, &'s str)> {
    let at = s.find(marker)?;
    let start = at + marker.len();
    let end = s[start..].find(' ').map(|i| start + i).unwrap_or(s.len());
    Some((start..end, &s[start..end]))
}

impl ConfigDescriptor {
    /// Parse the axis tokens back out of the descriptor string. Returns
    /// `None` for descriptors this version cannot interpret (so unknown
    /// formats are simply never used as donors).
    pub fn axes(&self) -> Option<AxisTokens> {
        let s = self.0.as_str();
        let (r_dims, dims) = axis_value(s, "uniform ")?;
        let (w, h) = dims.split_once('x')?;
        let (r_topo, topo) = axis_value(s, " sb=")?;
        let (r_tracks, tracks) = axis_value(s, " tracks=")?;
        let (r_sb, sb_sides) = axis_value(s, " sb_sides=")?;
        let (r_cb, cb_sides) = axis_value(s, " cb_sides=")?;
        let (r_out, out_tracks) = axis_value(s, " out_tracks=")?;
        // The fabric token is optional and, unlike the others, its
        // *marker* is spliced out of `rest` too — otherwise a static
        // descriptor (no token at all) could never match a fabric one.
        let fabric = axis_value(s, " fabric=");
        let mut ranges = vec![r_dims, r_topo, r_tracks, r_sb, r_cb, r_out];
        let fabric_label = match &fabric {
            Some((r, label)) => {
                ranges.push(r.start - " fabric=".len()..r.end);
                label.to_string()
            }
            None => "static".to_string(),
        };
        ranges.sort_by_key(|r| r.start);
        let mut rest = String::with_capacity(s.len());
        let mut at = 0;
        for r in &ranges {
            rest.push_str(&s[at..r.start]);
            at = r.end;
        }
        rest.push_str(&s[at..]);
        Some(AxisTokens {
            width: w.parse().ok()?,
            height: h.parse().ok()?,
            tracks: tracks.parse().ok()?,
            topology: topo.to_string(),
            sb_sides: sb_sides.parse().ok()?,
            cb_sides: cb_sides.parse().ok()?,
            out_tracks: out_tracks.to_string(),
            fabric: fabric_label,
            rest,
        })
    }

    /// Axis-wise difference to `other`, or `None` when either descriptor
    /// is unparseable or the non-axis parts differ (different delay
    /// model, flow knobs, placer, … — never reuse across those).
    pub fn delta(&self, other: &ConfigDescriptor) -> Option<AxisDelta> {
        let a = self.axes()?;
        let b = other.axes()?;
        if a.rest != b.rest {
            return None;
        }
        Some(AxisDelta {
            d_width: a.width.abs_diff(b.width) as u32,
            d_height: a.height.abs_diff(b.height) as u32,
            d_tracks: a.tracks.abs_diff(b.tracks) as u32,
            topology_changed: a.topology != b.topology,
            d_sb_sides: a.sb_sides.abs_diff(b.sb_sides) as u32,
            d_cb_sides: a.cb_sides.abs_diff(b.cb_sides) as u32,
            out_tracks_changed: a.out_tracks != b.out_tracks,
            fabric_changed: a.fabric != b.fabric,
        })
    }

    /// [`AxisDelta::distance`] to `other`, or `None` when incompatible.
    pub fn reuse_distance(&self, other: &ConfigDescriptor) -> Option<u32> {
        self.delta(other).map(|d| d.distance())
    }
}

/// Cache key of one PnR job.
#[derive(Clone, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct JobKey {
    pub config: ConfigDescriptor,
    /// App *registry* key (see [`app_by_name`]) — unique even where two
    /// generators share a display name.
    pub app: String,
    /// Logical seed (the sweep-axis value, before any derivation).
    pub seed: u64,
}

/// One executable sweep point.
#[derive(Clone, Debug)]
pub struct Job {
    pub key: JobKey,
    /// Display name of the resolved application (what tables print).
    pub app_name: String,
    /// Fully-resolved interconnect configuration.
    pub cfg: InterconnectConfig,
    /// Flow parameters with the per-job seed already applied.
    pub flow: FlowParams,
    /// Which fabric the point's elastic simulation models (also encoded
    /// in `key.config` for every non-static kind).
    pub fabric: FabricKind,
}

/// How the array is sized for each job.
#[derive(Clone, Copy, Debug)]
pub enum Sizing {
    /// Use `base.width` × `base.height` as-is.
    Fixed,
    /// Capacity-match the array to each application with `slack` headroom
    /// (the Fig. 11 regime; see [`crate::coordinator::tight_array`]).
    TightArray { slack: f64 },
}

/// How a job's logical seed maps onto the flow RNG stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeedMode {
    /// `flow.seed = seed` — matches the pre-engine `figNN_*` loops.
    Raw,
    /// `flow.seed = derive_seed(seed, "<config>/<app>")`: every
    /// (config, app, seed) point gets an independent, reproducible
    /// stream regardless of worker count or scheduling order.
    Derived,
}

/// The summarized outcome of one (config, app, seed) job — what the
/// figures and the cache need, small enough to persist.
#[derive(Clone, Debug, PartialEq)]
pub struct PointResult {
    pub routed: bool,
    pub critical_path_ps: f64,
    pub period_ps: f64,
    pub latency_cycles: u64,
    pub runtime_ns: f64,
    pub iterations: u64,
    pub nodes_used: u64,
    /// α that won the flow's sweep.
    pub alpha: f64,
    /// Cycles the elastic (ready-valid) simulation ran to drain
    /// [`Self::sim_tokens`] through the routed fabric. Zero when the
    /// point was never simulated (unroutable points, and entries loaded
    /// from pre-fabric-axis cache files).
    pub sim_cycles: u64,
    /// Tokens drained by the slowest stream sink.
    pub sim_tokens: u64,
    /// Cycles the slowest sink spent *not* producing output
    /// (`sim_cycles - sim_tokens`): pipeline fill plus every bubble the
    /// fabric's channel capacities could not absorb.
    pub stall_cycles: u64,
}

impl PointResult {
    pub fn unroutable() -> PointResult {
        PointResult {
            routed: false,
            critical_path_ps: 0.0,
            period_ps: 0.0,
            latency_cycles: 0,
            runtime_ns: 0.0,
            iterations: 0,
            nodes_used: 0,
            alpha: 0.0,
            sim_cycles: 0,
            sim_tokens: 0,
            stall_cycles: 0,
        }
    }

    pub fn from_flow(r: &FlowResult) -> PointResult {
        PointResult {
            routed: true,
            critical_path_ps: r.timing.critical_path_ps,
            period_ps: r.timing.period_ps,
            latency_cycles: r.timing.latency_cycles as u64,
            runtime_ns: r.timing.runtime_ns,
            iterations: r.routing.iterations as u64,
            nodes_used: r.routing.nodes_used as u64,
            alpha: r.alpha,
            sim_cycles: 0,
            sim_tokens: 0,
            stall_cycles: 0,
        }
    }

    pub fn runtime_us(&self) -> f64 {
        self.runtime_ns / 1000.0
    }

    /// Did this point route *and* carry finite timing metrics?
    ///
    /// `Json::num_f64` writes non-finite floats as `null` and the cache
    /// and wire decoders read `null` back as NaN (see
    /// [`super::cache`], `service/proto.rs`), so a routed point loaded
    /// from a warm cache can legally carry NaN metrics. Every consumer
    /// that sorts, mins, or dominance-compares point metrics must gate
    /// on this instead of `routed` alone — NaN poisons `partial_cmp`
    /// orderings silently (it is unequal to everything, so a NaN point
    /// can "win" or "lose" a comparison depending on operand order).
    pub fn has_finite_metrics(&self) -> bool {
        self.routed
            && self.critical_path_ps.is_finite()
            && self.period_ps.is_finite()
            && self.runtime_ns.is_finite()
            && self.alpha.is_finite()
    }

    /// Sustained tokens/cycle of the elastic simulation (0 when the
    /// point carries no simulation data).
    pub fn throughput(&self) -> f64 {
        if self.sim_cycles == 0 {
            0.0
        } else {
            self.sim_tokens as f64 / self.sim_cycles as f64
        }
    }
}

/// Per-(config, fabric) area metrics (interior tile) for the
/// area-vs-axis figures (Fig. 8/10/13).
#[derive(Clone, Debug, PartialEq)]
pub struct AreaPoint {
    /// `InterconnectConfig::descriptor()` of the measured config.
    pub config: String,
    /// [`FabricKind::label`] of the measured fabric mode.
    pub fabric: String,
    pub tracks: u16,
    pub sb_sides: u8,
    pub cb_sides: u8,
    pub sb_um2: f64,
    pub cb_um2: f64,
}

/// Declarative sweep: empty axes fall back to the base config's value.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    pub name: String,
    pub base: InterconnectConfig,
    pub tracks: Vec<u16>,
    pub topologies: Vec<SbTopology>,
    pub output_tracks: Vec<OutputTrackMode>,
    pub sb_sides: Vec<u8>,
    pub cb_sides: Vec<u8>,
    /// Fabric axis (§3.3's static-vs-hybrid evaluation); empty ⇒
    /// [`FabricKind::Static`]. The fabric never changes the interconnect
    /// build or the PnR result — it selects the elastic-simulation
    /// capacity model (and, for area sweeps, the SB fabric mode) — but
    /// non-static kinds are keyed distinctly in the cache.
    pub fabrics: Vec<FabricKind>,
    pub sizing: Sizing,
    /// App registry keys (see [`app_by_name`]); empty ⇒ no PnR jobs
    /// (area-only sweeps).
    pub apps: Vec<String>,
    /// Logical seeds; one job per (config, app, seed).
    pub seeds: Vec<u64>,
    pub seed_mode: SeedMode,
    /// Flow knobs shared by every job (`flow.seed` is set per job).
    pub flow: FlowParams,
    /// Also record per-config [`AreaPoint`]s.
    pub area: bool,
}

impl Default for SweepSpec {
    fn default() -> Self {
        SweepSpec {
            name: "sweep".into(),
            base: InterconnectConfig::default(),
            tracks: vec![],
            topologies: vec![],
            output_tracks: vec![],
            sb_sides: vec![],
            cb_sides: vec![],
            fabrics: vec![],
            sizing: Sizing::Fixed,
            apps: vec![],
            seeds: vec![1],
            seed_mode: SeedMode::Raw,
            flow: FlowParams::default(),
            area: false,
        }
    }
}

fn axis<T: Clone>(axis: &[T], base: T) -> Vec<T> {
    if axis.is_empty() {
        vec![base]
    } else {
        axis.to_vec()
    }
}

impl SweepSpec {
    /// Resolve one point's interconnect config (the app matters only
    /// under tight sizing).
    fn resolve_cfg(
        &self,
        tracks: u16,
        topo: SbTopology,
        out_mode: OutputTrackMode,
        sb: u8,
        cb: u8,
        app: Option<&AppGraph>,
    ) -> Result<InterconnectConfig, String> {
        let mut cfg = self.base.clone();
        cfg.num_tracks = tracks;
        cfg.sb_topology = topo;
        cfg.output_tracks = out_mode;
        cfg.sb_core_sides = ConnectedSides(sb);
        cfg.cb_core_sides = ConnectedSides(cb);
        if let Sizing::TightArray { slack } = self.sizing {
            let app = app.ok_or("tight sizing needs an application")?;
            let (w, h) = crate::coordinator::tight_array(app, cfg.mem_column_period, slack);
            cfg.width = w;
            cfg.height = h;
        }
        cfg.validate().map_err(|e| format!("sweep `{}`: {e}", self.name))?;
        Ok(cfg)
    }

    /// Resolve every app key once, up front (registry generators are not
    /// free to construct; the job loop runs per axis combination).
    fn resolved_apps(&self) -> Result<Vec<(String, AppGraph)>, String> {
        self.apps
            .iter()
            .map(|k| {
                app_by_name(k)
                    .map(|a| (k.clone(), a))
                    .ok_or_else(|| format!("unknown app `{k}`"))
            })
            .collect()
    }

    /// The resolved fabric axis (`Static` when the axis is empty).
    pub fn fabric_axis(&self) -> Vec<FabricKind> {
        axis(&self.fabrics, FabricKind::Static)
    }

    /// The single axis-enumeration core: calls `f` for every
    /// (tracks, topology, output-mode, sb-sides, cb-sides, fabric)
    /// combination in canonical order. `jobs` and `configs` both build on
    /// this, so the PnR points and the area metrics can never enumerate
    /// different config sets.
    fn for_each_combo<F>(&self, mut f: F) -> Result<(), String>
    where
        F: FnMut(u16, SbTopology, OutputTrackMode, u8, u8, FabricKind) -> Result<(), String>,
    {
        for &tr in &axis(&self.tracks, self.base.num_tracks) {
            for &topo in &axis(&self.topologies, self.base.sb_topology) {
                for &om in &axis(&self.output_tracks, self.base.output_tracks) {
                    for &sb in &axis(&self.sb_sides, self.base.sb_core_sides.0) {
                        for &cb in &axis(&self.cb_sides, self.base.cb_core_sides.0) {
                            for &fb in &self.fabric_axis() {
                                f(tr, topo, om, sb, cb, fb)?;
                            }
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// The deduplicated job list in canonical enumeration order:
    /// tracks → topology → output-tracks → SB sides → CB sides →
    /// fabric → app → seed. `placer` is the placement backend's name
    /// (part of the cache key: different backends may legally produce
    /// different placements).
    pub fn jobs(&self, placer: &str) -> Result<Vec<Job>, String> {
        let apps = self.resolved_apps()?;
        let tight = matches!(self.sizing, Sizing::TightArray { .. });
        let mut seen = std::collections::BTreeSet::new();
        let mut out = Vec::new();
        self.for_each_combo(|tr, topo, om, sb, cb, fb| {
            // Under fixed sizing every app shares one config (and one
            // descriptor) per combination.
            let shared = if tight || apps.is_empty() {
                None
            } else {
                let cfg = self.resolve_cfg(tr, topo, om, sb, cb, None)?;
                let desc = ConfigDescriptor::of(&cfg, &self.flow, placer, self.seed_mode, fb);
                Some((cfg, desc))
            };
            for (app_key, app) in &apps {
                let (cfg, desc) = match &shared {
                    Some((cfg, desc)) => (cfg.clone(), desc.clone()),
                    None => {
                        let cfg = self.resolve_cfg(tr, topo, om, sb, cb, Some(app))?;
                        let desc =
                            ConfigDescriptor::of(&cfg, &self.flow, placer, self.seed_mode, fb);
                        (cfg, desc)
                    }
                };
                for &seed in &self.seeds {
                    let key =
                        JobKey { config: desc.clone(), app: app_key.clone(), seed };
                    if !seen.insert(key.clone()) {
                        continue;
                    }
                    let mut flow = self.flow.clone();
                    flow.seed = match self.seed_mode {
                        SeedMode::Raw => seed,
                        SeedMode::Derived => {
                            derive_seed(seed, &format!("{}/{}", desc.0, app_key))
                        }
                    };
                    out.push(Job {
                        key,
                        app_name: app.name.clone(),
                        cfg: cfg.clone(),
                        flow,
                        fabric: fb,
                    });
                }
            }
            Ok(())
        })?;
        Ok(out)
    }

    /// Every unique interconnect configuration of the cross-product, in
    /// enumeration order (used for the area metrics; under tight sizing
    /// configs vary per app).
    pub fn configs(&self) -> Result<Vec<InterconnectConfig>, String> {
        let app_axis: Vec<Option<AppGraph>> = if matches!(self.sizing, Sizing::TightArray { .. })
        {
            if self.apps.is_empty() {
                return Err(format!(
                    "sweep `{}`: tight sizing needs at least one app",
                    self.name
                ));
            }
            self.resolved_apps()?.into_iter().map(|(_, a)| Some(a)).collect()
        } else {
            vec![None]
        };
        let mut seen = std::collections::BTreeSet::new();
        let mut out = Vec::new();
        // The fabric does not change the interconnect build, so fabric
        // duplicates collapse here (area sweeps re-expand per fabric).
        self.for_each_combo(|tr, topo, om, sb, cb, _fb| {
            for app in &app_axis {
                let cfg = self.resolve_cfg(tr, topo, om, sb, cb, app.as_ref())?;
                if seen.insert(cfg.descriptor()) {
                    out.push(cfg);
                }
            }
            Ok(())
        })?;
        Ok(out)
    }
}

/// The named application registry: ONE table read by both
/// [`app_by_name`] and [`registry_keys`], so the resolvable keys and
/// the advertised keys (`canal info`, the service `info` response)
/// cannot drift apart. Keys are unique and stable even where two
/// generators share a display name (`matmul` = `matmul(2)` from the
/// runtime suite, `matmul3` = `matmul(3)` from the dense suite).
const APP_REGISTRY: &[(&str, fn() -> AppGraph)] = &[
    ("pointwise", || apps::pointwise(8)),
    ("pointwise4", || apps::pointwise(4)),
    ("gaussian", apps::gaussian),
    ("harris", apps::harris),
    ("camera", apps::camera),
    ("resnet", apps::resnet_block),
    ("matmul", || apps::matmul(2)),
    ("matmul3", || apps::matmul(3)),
    ("conv5x5", apps::conv5x5),
    ("unsharp", apps::unsharp),
    ("fft8", apps::fft8),
    ("stereo", || apps::stereo(4)),
    ("depthwise", apps::depthwise_separable),
    ("conv_stack3", || apps::conv_stack(3)),
];

/// Resolve one registry key to a fresh application graph.
pub fn app_by_name(key: &str) -> Option<AppGraph> {
    APP_REGISTRY.iter().find(|(k, _)| *k == key).map(|(_, ctor)| ctor())
}

/// Every key [`app_by_name`] resolves, in registry order — what
/// `canal info` and the service's `info` response enumerate.
pub fn registry_keys() -> Vec<&'static str> {
    APP_REGISTRY.iter().map(|(k, _)| *k).collect()
}

/// Registry keys matching [`apps::suite`] element-for-element.
pub fn suite_keys() -> Vec<String> {
    ["pointwise", "gaussian", "harris", "camera", "resnet", "matmul"]
        .iter()
        .map(|s| s.to_string())
        .collect()
}

/// Registry keys matching [`apps::dense_suite`] element-for-element.
pub fn dense_suite_keys() -> Vec<String> {
    ["harris", "conv5x5", "unsharp", "fft8", "stereo", "depthwise", "matmul3", "conv_stack3"]
        .iter()
        .map(|s| s.to_string())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_keys_all_resolve_and_are_complete() {
        // One table backs both functions, so resolvable ⇔ advertised by
        // construction; what is left to check is uniqueness and that
        // both suites stay inside the registry.
        let keys = registry_keys();
        let unique: std::collections::BTreeSet<_> = keys.iter().collect();
        assert_eq!(unique.len(), keys.len(), "registry keys must be unique");
        for k in &keys {
            assert!(app_by_name(k).is_some(), "registry key `{k}` does not resolve");
        }
        for k in suite_keys().iter().chain(dense_suite_keys().iter()) {
            assert!(keys.contains(&k.as_str()), "suite key `{k}` missing from registry");
        }
    }

    #[test]
    fn registry_covers_both_suites() {
        for (keys, suite) in
            [(suite_keys(), apps::suite()), (dense_suite_keys(), apps::dense_suite())]
        {
            assert_eq!(keys.len(), suite.len());
            for (k, a) in keys.iter().zip(&suite) {
                let resolved = app_by_name(k).unwrap_or_else(|| panic!("missing key {k}"));
                assert_eq!(resolved.name, a.name, "{k}");
                assert_eq!(resolved.len(), a.len(), "{k}");
            }
        }
        assert!(app_by_name("nope").is_none());
    }

    #[test]
    fn jobs_enumerate_cross_product_in_order() {
        let spec = SweepSpec {
            tracks: vec![3, 4],
            topologies: vec![SbTopology::Wilton, SbTopology::Disjoint],
            apps: vec!["gaussian".into(), "pointwise".into()],
            seeds: vec![1, 2],
            ..Default::default()
        };
        let jobs = spec.jobs("native-gd").unwrap();
        assert_eq!(jobs.len(), 2 * 2 * 2 * 2);
        // tracks is the outermost axis, seeds the innermost.
        assert_eq!(jobs[0].cfg.num_tracks, 3);
        assert_eq!(jobs[0].key.app, "gaussian");
        assert_eq!(jobs[0].key.seed, 1);
        assert_eq!(jobs[1].key.seed, 2);
        assert_eq!(jobs.last().unwrap().cfg.num_tracks, 4);
        assert_eq!(jobs.last().unwrap().cfg.sb_topology, SbTopology::Disjoint);
        // Raw mode passes the logical seed straight through.
        assert_eq!(jobs[0].flow.seed, 1);
        // Keys are unique.
        let keys: std::collections::BTreeSet<_> = jobs.iter().map(|j| j.key.clone()).collect();
        assert_eq!(keys.len(), jobs.len());
    }

    #[test]
    fn duplicate_axis_values_dedup() {
        let spec = SweepSpec {
            tracks: vec![4, 4],
            apps: vec!["gaussian".into()],
            seeds: vec![1, 1],
            ..Default::default()
        };
        assert_eq!(spec.jobs("native-gd").unwrap().len(), 1);
    }

    #[test]
    fn derived_seed_mode_splits_streams_per_point() {
        let spec = SweepSpec {
            tracks: vec![3, 4],
            apps: vec!["gaussian".into()],
            seeds: vec![7],
            seed_mode: SeedMode::Derived,
            ..Default::default()
        };
        let jobs = spec.jobs("native-gd").unwrap();
        assert_eq!(jobs.len(), 2);
        // Same logical seed, different configs ⇒ different streams; and
        // the mapping is reproducible.
        assert_ne!(jobs[0].flow.seed, jobs[1].flow.seed);
        assert_ne!(jobs[0].flow.seed, 7);
        let again = spec.jobs("native-gd").unwrap();
        assert_eq!(jobs[0].flow.seed, again[0].flow.seed);
    }

    #[test]
    fn descriptor_separates_flow_placer_and_seed_mode_variants() {
        let cfg = InterconnectConfig::default();
        let flow = FlowParams::default();
        let stat = FabricKind::Static;
        let a = ConfigDescriptor::of(&cfg, &flow, "native-gd", SeedMode::Raw, stat);
        let b = ConfigDescriptor::of(&cfg, &flow, "pjrt-jax-pallas", SeedMode::Raw, stat);
        assert_ne!(a, b);
        // Raw and Derived runs must never alias in the cache.
        let d = ConfigDescriptor::of(&cfg, &flow, "native-gd", SeedMode::Derived, stat);
        assert_ne!(a, d);
        let mut flow2 = flow.clone();
        flow2.sa.moves_per_node += 1;
        assert_ne!(a, ConfigDescriptor::of(&cfg, &flow2, "native-gd", SeedMode::Raw, stat));
        let mut flow3 = flow.clone();
        flow3.seed = 99; // seed is keyed separately, not in the descriptor
        assert_eq!(a, ConfigDescriptor::of(&cfg, &flow3, "native-gd", SeedMode::Raw, stat));
    }

    #[test]
    fn descriptor_keys_fabrics_distinctly_but_static_stays_bare() {
        let cfg = InterconnectConfig::default();
        let flow = FlowParams::default();
        let of = |f| ConfigDescriptor::of(&cfg, &flow, "native-gd", SeedMode::Raw, f);
        let stat = of(FabricKind::Static);
        let full = of(FabricKind::RvFullFifo { depth: 2 });
        let full4 = of(FabricKind::RvFullFifo { depth: 4 });
        let split = of(FabricKind::RvSplitFifo);
        // Static omits the token entirely — pre-fabric-axis cache
        // entries keep matching.
        assert!(!stat.0.contains("fabric="), "{stat}");
        assert!(full.0.contains("fabric=rv-full:2"), "{full}");
        assert!(split.0.contains("fabric=rv-split"), "{split}");
        let all = [&stat, &full, &full4, &split];
        for (i, x) in all.iter().enumerate() {
            for y in all.iter().skip(i + 1) {
                assert_ne!(x, y);
            }
        }
    }

    #[test]
    fn descriptor_keys_only_result_changing_router_variants() {
        use crate::pnr::SearchCore;
        let cfg = InterconnectConfig::default();
        let of = |f: &FlowParams| {
            ConfigDescriptor::of(&cfg, f, "native-gd", SeedMode::Raw, FabricKind::Static)
        };
        let base = of(&FlowParams::default());
        // Defaults carry no variant tokens: pre-PR cache entries stay warm.
        for tok in ["rcore=", "rorder=", "rsinks="] {
            assert!(!base.0.contains(tok), "{base}");
        }
        // Bucket/radix frontiers are bit-identical execution strategies —
        // they must alias the default descriptor.
        for core in [SearchCore::Bucket, SearchCore::Radix] {
            let mut f = FlowParams::default();
            f.router.search_core = core;
            assert_eq!(base, of(&f), "{} must not fork the cache key", core.name());
        }
        // A*/bidir can pick different equal-cost paths, slack ordering
        // reorders negotiation, and independent-sink mode changes trees:
        // all three fork the key.
        let mut astar = FlowParams::default();
        astar.router.search_core = SearchCore::AStar;
        let a = of(&astar);
        assert!(a.0.contains(" rcore=astar"), "{a}");
        let mut bidir = FlowParams::default();
        bidir.router.search_core = SearchCore::Bidir;
        assert!(of(&bidir).0.contains(" rcore=bidir"));
        let mut slack = FlowParams::default();
        slack.router.slack_order = true;
        assert!(of(&slack).0.contains(" rorder=slack"));
        let mut indep = FlowParams::default();
        indep.router.steiner = false;
        assert!(of(&indep).0.contains(" rsinks=independent"));
        let all = [&base, &a, &of(&bidir), &of(&slack), &of(&indep)];
        for (i, x) in all.iter().enumerate() {
            for y in all.iter().skip(i + 1) {
                assert_ne!(x, y);
            }
        }
        // Variant tokens land in `rest`, so axis parsing still works and
        // variant points never donate artifacts to default points.
        let t = a.axes().expect("parseable with variant tokens");
        assert!(t.rest.contains("rcore=astar"));
        assert_ne!(t.rest, base.axes().unwrap().rest);
    }

    #[test]
    fn fabric_axis_enumerates_between_sides_and_apps() {
        let spec = SweepSpec {
            tracks: vec![3, 4],
            fabrics: vec![
                FabricKind::Static,
                FabricKind::RvFullFifo { depth: 2 },
                FabricKind::RvSplitFifo,
            ],
            apps: vec!["gaussian".into(), "pointwise".into()],
            seeds: vec![1],
            ..Default::default()
        };
        let jobs = spec.jobs("native-gd").unwrap();
        assert_eq!(jobs.len(), 2 * 3 * 2);
        // fabric is inner to tracks, outer to apps.
        assert_eq!(jobs[0].fabric, FabricKind::Static);
        assert_eq!(jobs[0].key.app, "gaussian");
        assert_eq!(jobs[1].key.app, "pointwise");
        assert_eq!(jobs[2].fabric, FabricKind::RvFullFifo { depth: 2 });
        // Every key is unique (fabrics never alias).
        let keys: std::collections::BTreeSet<_> = jobs.iter().map(|j| j.key.clone()).collect();
        assert_eq!(keys.len(), jobs.len());
        // The default axis is implicit static.
        let plain = SweepSpec { fabrics: vec![], ..spec.clone() };
        assert!(plain.jobs("native-gd").unwrap().iter().all(|j| j.fabric == FabricKind::Static));
        assert_eq!(spec.fabric_axis().len(), 3);
        assert_eq!(plain.fabric_axis(), vec![FabricKind::Static]);
        // configs() collapses the fabric axis (same interconnect build).
        assert_eq!(spec.configs().unwrap().len(), 2);
    }

    #[test]
    fn axis_tokens_round_trip_and_delta_weights() {
        let flow = FlowParams::default();
        let of = |cfg: &InterconnectConfig, f| {
            ConfigDescriptor::of(cfg, &flow, "native-gd", SeedMode::Raw, f)
        };
        let base = InterconnectConfig::default();
        let a = of(&base, FabricKind::Static);
        let t = a.axes().expect("parseable");
        assert_eq!(t.width, base.width);
        assert_eq!(t.height, base.height);
        assert_eq!(t.tracks, base.num_tracks);
        assert_eq!(t.topology, base.sb_topology.name());
        assert_eq!(t.sb_sides, base.sb_core_sides.0);
        assert_eq!(t.cb_sides, base.cb_core_sides.0);
        assert_eq!(t.out_tracks, base.output_tracks.name());
        assert_eq!(t.fabric, "static");
        // Identity delta.
        let d = a.delta(&a).unwrap();
        assert_eq!(d.distance(), 0);
        // Tracks ±1 is the closest neighbor.
        let tr = InterconnectConfig { num_tracks: base.num_tracks + 1, ..base.clone() };
        assert_eq!(a.reuse_distance(&of(&tr, FabricKind::Static)), Some(1));
        // A fabric change leaves the PnR problem untouched: distance 1,
        // and the static descriptor (no fabric token) still parses
        // compatibly against a fabric-tagged one.
        let fb = of(&base, FabricKind::RvFullFifo { depth: 2 });
        assert_eq!(fb.axes().unwrap().fabric, "rv-full:2");
        assert_eq!(a.reuse_distance(&fb), Some(1));
        // Sides, output mode, topology carry their weights.
        let sb = InterconnectConfig { sb_core_sides: ConnectedSides(3), ..base.clone() };
        assert_eq!(a.reuse_distance(&of(&sb, FabricKind::Static)), Some(2));
        let ot =
            InterconnectConfig { output_tracks: OutputTrackMode::Pinned, ..base.clone() };
        assert_eq!(a.reuse_distance(&of(&ot, FabricKind::Static)), Some(4));
        let topo = InterconnectConfig { sb_topology: SbTopology::Disjoint, ..base.clone() };
        assert_eq!(a.reuse_distance(&of(&topo, FabricKind::Static)), Some(6));
        // A delay-model (non-axis) difference is never reuse-compatible.
        let mut slow = base.clone();
        slow.delays.wire_ps += 10;
        assert_eq!(a.reuse_distance(&of(&slow, FabricKind::Static)), None);
        // ... and neither is a different placer.
        let other = ConfigDescriptor::of(&base, &flow, "other", SeedMode::Raw, FabricKind::Static);
        assert_eq!(a.reuse_distance(&other), None);
    }

    #[test]
    fn tight_sizing_resolves_per_app() {
        let spec = SweepSpec {
            base: InterconnectConfig { mem_column_period: 3, ..Default::default() },
            sizing: Sizing::TightArray { slack: 1.25 },
            apps: vec!["gaussian".into(), "conv5x5".into()],
            ..Default::default()
        };
        let jobs = spec.jobs("native-gd").unwrap();
        assert_eq!(jobs.len(), 2);
        // conv5x5 needs a bigger array than gaussian.
        assert!(jobs[1].cfg.width > jobs[0].cfg.width);
        // configs() under tight sizing enumerates one per app.
        assert_eq!(spec.configs().unwrap().len(), 2);
    }
}
