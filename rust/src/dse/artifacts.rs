//! PnR artifact cache for incremental warm-starts: per
//! `(ConfigDescriptor, app, seed)` it keeps the *solution* — the
//! legalized placement and every routed sink path — not just the
//! metrics the [`super::cache::ResultCache`] stores. A neighboring sweep
//! point (small [`AxisDelta`] distance) replays the donor placement and
//! trees and repairs only what its axis change invalidated.
//!
//! ## File format (`*_artifacts.json`, version 1)
//!
//! ```json
//! { "version": 1,
//!   "entries": [
//!     { "config": "<ConfigDescriptor string>", "app": "harris", "seed": 1,
//!       "placement": [[0,1],[2,3]],
//!       "nets": [[["1,1,port,out,data_out_0","1,1,sb,east,out,0", "..."]]] } ] }
//! ```
//!
//! `placement` is tile coordinates in packed-vertex order. `nets` is one
//! entry per net (packed-app net order), each a list of sink paths, each
//! path a list of *logical node tokens*. `NodeId`s are per-graph arena
//! indices and mean nothing across configurations, so nodes are stored
//! by identity — `(x, y, kind)` — and re-resolved against the target
//! graph with [`crate::ir::RoutingGraph::find`]; a token with no
//! counterpart (e.g. a track removed by the axis change) voids that
//! net's seed. Every value is an integer or string, so a load → save
//! cycle is byte-identical (asserted by the warm smoke).

use std::collections::BTreeMap;
use std::io;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::ir::{NodeId, NodeKind, RoutingGraph, SbIo, Side};
use crate::util::json::Json;

use super::spec::{ConfigDescriptor, JobKey};

/// Artifact file schema version.
pub const ARTIFACT_VERSION: u64 = 1;

/// The reusable outcome of one PnR run.
#[derive(Clone, PartialEq, Eq, Debug)]
pub struct PnrArtifact {
    /// Final legalized tile coordinates, packed-vertex order.
    pub placement: Vec<(u16, u16)>,
    /// Per net (packed-app net order): per sink, the routed path as
    /// logical node tokens (see [`encode_node`]).
    pub nets: Vec<Vec<Vec<String>>>,
}

impl PnrArtifact {
    /// Re-resolve the stored sink paths against a target graph. Per net:
    /// `Some(paths)` when *every* node on every path exists in `rg`,
    /// `None` when the axis change removed any of them — that net is
    /// rerouted from scratch.
    pub fn resolve(&self, rg: &RoutingGraph) -> Vec<Option<Vec<Vec<NodeId>>>> {
        self.nets
            .iter()
            .map(|paths| {
                paths
                    .iter()
                    .map(|p| p.iter().map(|tok| decode_node(rg, tok)).collect::<Option<Vec<_>>>())
                    .collect::<Option<Vec<_>>>()
            })
            .collect()
    }
}

/// Encode a node by logical identity: `x,y,<kind...>`. Stable across
/// configurations — the uniform interconnect keeps `(x, y, kind)` node
/// identity under track growth and side changes.
pub fn encode_node(rg: &RoutingGraph, id: NodeId) -> String {
    let n = rg.node(id);
    match &n.kind {
        NodeKind::SwitchBox { side, io, track } => {
            format!("{},{},sb,{},{},{}", n.x, n.y, side.name(), io.name(), track)
        }
        NodeKind::Port { name, input } => {
            format!("{},{},port,{},{}", n.x, n.y, if *input { "in" } else { "out" }, name)
        }
        NodeKind::Register { side, track } => {
            format!("{},{},reg,{},{}", n.x, n.y, side.name(), track)
        }
        NodeKind::RegMux { side, track } => {
            format!("{},{},rmux,{},{}", n.x, n.y, side.name(), track)
        }
    }
}

fn parse_side(s: &str) -> Option<Side> {
    Side::ALL.into_iter().find(|side| side.name() == s)
}

/// Decode a [`encode_node`] token against `rg`; `None` when the node
/// does not exist there (or the token is malformed).
pub fn decode_node(rg: &RoutingGraph, token: &str) -> Option<NodeId> {
    let mut parts = token.splitn(4, ',');
    let x: u16 = parts.next()?.parse().ok()?;
    let y: u16 = parts.next()?.parse().ok()?;
    let tag = parts.next()?;
    let tail = parts.next()?;
    let kind = match tag {
        "sb" => {
            let (side, rest) = tail.split_once(',')?;
            let (io, track) = rest.split_once(',')?;
            let io = match io {
                "in" => SbIo::In,
                "out" => SbIo::Out,
                _ => return None,
            };
            NodeKind::SwitchBox { side: parse_side(side)?, io, track: track.parse().ok()? }
        }
        "port" => {
            let (dir, name) = tail.split_once(',')?;
            NodeKind::Port { name: name.to_string(), input: dir == "in" }
        }
        "reg" => {
            let (side, track) = tail.split_once(',')?;
            NodeKind::Register { side: parse_side(side)?, track: track.parse().ok()? }
        }
        "rmux" => {
            let (side, track) = tail.split_once(',')?;
            NodeKind::RegMux { side: parse_side(side)?, track: track.parse().ok()? }
        }
        _ => return None,
    };
    rg.find(x, y, &kind)
}

/// Sibling path for the artifact store: `dse_cache.json` →
/// `dse_cache_artifacts.json`.
pub fn artifact_path_for(cache: &Path) -> PathBuf {
    let stem = cache.file_stem().and_then(|s| s.to_str()).unwrap_or("dse_cache");
    cache.with_file_name(format!("{stem}_artifacts.json"))
}

/// Thread-safe artifact store, optionally backed by a JSON file.
/// Workers insert artifacts *during* a sweep (later groups seed from
/// earlier ones in the same run), so unlike [`super::ResultCache`] the
/// map sits behind a mutex and all methods take `&self`.
#[derive(Default)]
pub struct PnrArtifactCache {
    path: Option<PathBuf>,
    map: Mutex<BTreeMap<JobKey, Arc<PnrArtifact>>>,
}

impl PnrArtifactCache {
    /// Unbacked store (donors live only within this engine's lifetime).
    pub fn in_memory() -> PnrArtifactCache {
        PnrArtifactCache::default()
    }

    /// Store backed by `path` — same contract as `ResultCache::at`:
    /// missing file = empty store (created immediately, so an unwritable
    /// path fails before any PnR is spent), corrupt file = loud error.
    pub fn at(path: &Path) -> Result<PnrArtifactCache, String> {
        let cache = PnrArtifactCache {
            path: Some(path.to_path_buf()),
            map: Mutex::new(BTreeMap::new()),
        };
        match std::fs::read_to_string(path) {
            Ok(text) => cache.load_json(&text).map_err(|e| format!("{}: {e}", path.display()))?,
            Err(e) if e.kind() == io::ErrorKind::NotFound => cache.save()?,
            Err(e) => return Err(format!("{}: {e}", path.display())),
        }
        Ok(cache)
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    pub fn get(&self, key: &JobKey) -> Option<Arc<PnrArtifact>> {
        self.map.lock().unwrap().get(key).cloned()
    }

    pub fn insert(&self, key: JobKey, artifact: PnrArtifact) {
        self.map.lock().unwrap().insert(key, Arc::new(artifact));
    }

    /// Best donor for `key`: the compatible entry (same app, same seed,
    /// matching non-axis descriptor parts) with the smallest
    /// [`AxisDelta`](super::spec::AxisDelta) distance ≤ `max_distance`.
    /// Ties resolve to the first in `BTreeMap` key order, so donor
    /// choice is deterministic for a given store content.
    pub fn best_donor(
        &self,
        key: &JobKey,
        max_distance: u32,
    ) -> Option<(u32, ConfigDescriptor, Arc<PnrArtifact>)> {
        let map = self.map.lock().unwrap();
        let mut best: Option<(u32, &JobKey, &Arc<PnrArtifact>)> = None;
        for (k, art) in map.iter() {
            if k.app != key.app || k.seed != key.seed {
                continue;
            }
            let Some(d) = key.config.reuse_distance(&k.config) else { continue };
            if d > max_distance {
                continue;
            }
            if best.map(|(bd, _, _)| d < bd).unwrap_or(true) {
                best = Some((d, k, art));
            }
        }
        best.map(|(d, k, art)| (d, k.config.clone(), Arc::clone(art)))
    }

    /// Merge entries from artifact-file text.
    pub fn load_json(&self, text: &str) -> Result<(), String> {
        let doc = Json::parse(text)?;
        let version = doc.get("version").and_then(Json::as_u64).ok_or("missing version")?;
        if version != ARTIFACT_VERSION {
            return Err(format!("unsupported artifact version {version}"));
        }
        let entries = doc.get("entries").and_then(Json::as_arr).ok_or("missing entries")?;
        let mut map = self.map.lock().unwrap();
        for (i, entry) in entries.iter().enumerate() {
            let (key, art) = entry_from_json(entry).map_err(|e| format!("entry {i}: {e}"))?;
            map.insert(key, Arc::new(art));
        }
        Ok(())
    }

    /// Full store as JSON text (entries in key order — stable).
    pub fn to_json(&self) -> String {
        let map = self.map.lock().unwrap();
        let entries: Vec<Json> = map.iter().map(|(k, a)| entry_json(k, a)).collect();
        Json::Obj(vec![
            ("version".into(), Json::num_u64(ARTIFACT_VERSION)),
            ("entries".into(), Json::Arr(entries)),
        ])
        .render()
    }

    /// Persist to the backing file (no-op for in-memory stores). Same
    /// temp-file + rename discipline as the result cache.
    pub fn save(&self) -> Result<(), String> {
        match &self.path {
            Some(path) => self.save_to(path),
            None => Ok(()),
        }
    }

    pub fn save_to(&self, path: &Path) -> Result<(), String> {
        super::cache::atomic_write(path, &self.to_json())
    }
}

fn entry_json(key: &JobKey, a: &PnrArtifact) -> Json {
    let placement: Vec<Json> = a
        .placement
        .iter()
        .map(|&(x, y)| Json::Arr(vec![Json::num_u64(x as u64), Json::num_u64(y as u64)]))
        .collect();
    let nets: Vec<Json> = a
        .nets
        .iter()
        .map(|paths| {
            Json::Arr(
                paths
                    .iter()
                    .map(|p| Json::Arr(p.iter().map(|t| Json::str(t)).collect()))
                    .collect(),
            )
        })
        .collect();
    Json::Obj(vec![
        ("config".into(), Json::str(&key.config.0)),
        ("app".into(), Json::str(&key.app)),
        ("seed".into(), Json::num_u64(key.seed)),
        ("placement".into(), Json::Arr(placement)),
        ("nets".into(), Json::Arr(nets)),
    ])
}

fn entry_from_json(v: &Json) -> Result<(JobKey, PnrArtifact), String> {
    let str_field = |k: &str| -> Result<String, String> {
        v.get(k)
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| format!("missing `{k}`"))
    };
    let key = JobKey {
        config: ConfigDescriptor(str_field("config")?),
        app: str_field("app")?,
        seed: v.get("seed").and_then(Json::as_u64).ok_or("missing `seed`")?,
    };
    let placement = v
        .get("placement")
        .and_then(Json::as_arr)
        .ok_or("missing `placement`")?
        .iter()
        .map(|p| {
            let xy = p.as_arr().filter(|a| a.len() == 2).ok_or("bad placement entry")?;
            let coord = |j: &Json| -> Result<u16, String> {
                j.as_u64()
                    .and_then(|n| u16::try_from(n).ok())
                    .ok_or_else(|| "bad placement coordinate".to_string())
            };
            Ok((coord(&xy[0])?, coord(&xy[1])?))
        })
        .collect::<Result<Vec<_>, String>>()?;
    let nets = v
        .get("nets")
        .and_then(Json::as_arr)
        .ok_or("missing `nets`")?
        .iter()
        .map(|paths| {
            paths
                .as_arr()
                .ok_or("bad net entry")?
                .iter()
                .map(|p| {
                    p.as_arr()
                        .ok_or("bad path entry")?
                        .iter()
                        .map(|t| {
                            t.as_str().map(str::to_string).ok_or_else(|| "bad node token".into())
                        })
                        .collect::<Result<Vec<String>, String>>()
                })
                .collect::<Result<Vec<_>, String>>()
        })
        .collect::<Result<Vec<_>, String>>()?;
    Ok((key, PnrArtifact { placement, nets }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::{InterconnectConfig, SbTopology};

    fn key(config: &str, app: &str, seed: u64) -> JobKey {
        JobKey { config: ConfigDescriptor(config.into()), app: app.into(), seed }
    }

    fn art() -> PnrArtifact {
        PnrArtifact {
            placement: vec![(0, 1), (2, 3)],
            nets: vec![vec![vec![
                "1,1,port,out,data_out_0".into(),
                "1,1,sb,east,out,0".into(),
                "2,1,sb,west,in,0".into(),
                "2,1,port,in,data_in_0".into(),
            ]]],
        }
    }

    #[test]
    fn json_roundtrip_is_byte_identical() {
        let c = PnrArtifactCache::in_memory();
        c.insert(key("cfg-A", "harris", 1), art());
        c.insert(key("cfg-B", "harris", 1), PnrArtifact { placement: vec![], nets: vec![] });
        let text = c.to_json();
        let back = PnrArtifactCache::in_memory();
        back.load_json(&text).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(*back.get(&key("cfg-A", "harris", 1)).unwrap(), art());
        assert_eq!(back.to_json(), text, "re-emission must be byte-identical");
    }

    #[test]
    fn file_backing_roundtrip() {
        let path = std::env::temp_dir()
            .join(format!("canal_artifacts_test_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        {
            let c = PnrArtifactCache::at(&path).unwrap();
            assert!(c.is_empty());
            c.insert(key("cfg-A", "harris", 7), art());
            c.save().unwrap();
        }
        let c = PnrArtifactCache::at(&path).unwrap();
        assert_eq!(c.len(), 1);
        assert_eq!(*c.get(&key("cfg-A", "harris", 7)).unwrap(), art());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn corrupt_or_versioned_files_are_loud() {
        let c = PnrArtifactCache::in_memory();
        assert!(c.load_json("{not json").is_err());
        assert!(c.load_json(r#"{"version": 99, "entries": []}"#).is_err());
        assert!(c.load_json(r#"{"version": 1, "entries": [{"config": "x"}]}"#).is_err());
    }

    #[test]
    fn node_tokens_resolve_by_identity_across_track_growth() {
        let cfg3 = InterconnectConfig {
            width: 4,
            height: 4,
            num_tracks: 3,
            sb_topology: SbTopology::Wilton,
            mem_column_period: 3,
            ..Default::default()
        };
        let cfg4 = InterconnectConfig { num_tracks: 4, ..cfg3.clone() };
        let ic3 = crate::dsl::create_uniform_interconnect(&cfg3);
        let ic4 = crate::dsl::create_uniform_interconnect(&cfg4);
        let g3 = ic3.graph(16);
        let g4 = ic4.graph(16);
        // Every node of the 3-track graph encodes to a token that
        // resolves in the 4-track graph (node identity is preserved by
        // construction of the uniform interconnect)...
        for id in g3.ids() {
            let tok = encode_node(g3, id);
            let there = decode_node(g4, &tok).expect("identity preserved under track growth");
            assert_eq!(g3.node(id).kind, g4.node(there).kind);
        }
        // ...and a track-3 token does not resolve in the 3-track graph
        // but does in the 4-track one.
        let tok = "1,1,sb,north,in,3";
        assert_eq!(decode_node(g3, tok), None);
        assert!(decode_node(g4, tok).is_some());
    }

    #[test]
    fn best_donor_picks_nearest_compatible_entry() {
        use crate::pnr::FlowParams;
        use crate::sim::FabricKind;
        use crate::dse::SeedMode;
        let flow = FlowParams::default();
        let of = |tracks: u16| {
            let cfg = InterconnectConfig { num_tracks: tracks, ..Default::default() };
            ConfigDescriptor::of(&cfg, &flow, "native-gd", SeedMode::Raw, FabricKind::Static)
        };
        let c = PnrArtifactCache::in_memory();
        let mk = |cfg: ConfigDescriptor, seed| JobKey { config: cfg, app: "a".into(), seed };
        c.insert(mk(of(3), 1), art());
        c.insert(mk(of(6), 1), PnrArtifact { placement: vec![(9, 9)], nets: vec![] });
        c.insert(mk(of(5), 2), art()); // wrong seed — never a donor
        let (d, donor_cfg, donor) = c.best_donor(&mk(of(4), 1), 12).expect("donor");
        assert_eq!(d, 1);
        assert_eq!(donor_cfg, of(3));
        assert_eq!(*donor, art());
        // Nothing within range.
        assert!(c.best_donor(&mk(of(4), 1), 0).is_none());
        // Wrong app.
        let other = JobKey { config: of(4), app: "b".into(), seed: 1 };
        assert!(c.best_donor(&other, 12).is_none());
    }
}
