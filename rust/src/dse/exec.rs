//! The sharded sweep executor: a fixed worker pool over per-worker
//! deques of *job groups* with work stealing. Jobs sharing an
//! interconnect configuration form one group; a worker drains its group
//! through one batched global-placement solve
//! ([`GlobalPlacer::place_batch`] — N analytic problems, one solver
//! call) before finishing each point (legalize → SA → route → STA)
//! individually. Each worker owns reusable [`RouterScratch`] buffers
//! (PathFinder cost/visited/heap arrays allocated once, reset per
//! route); each interconnect configuration is built — and its routing
//! graphs frozen to immutable CSR [`crate::ir::CompiledGraph`]s —
//! exactly once, then shared across workers via `Arc`. Results are
//! keyed and cached through [`ResultCache`], so a warm re-run of the
//! same spec performs zero PnR calls (observable via
//! [`EngineStats::pnr_runs`]).
//!
//! Every *routed* cold point additionally runs the flattened elastic
//! (ready-valid) simulator on the point's own routing — channel
//! capacities derived from the registers each routed net crosses under
//! the job's [`crate::sim::FabricKind`] — and records throughput/stall
//! metrics in the cached [`PointResult`]. Warm points skip the
//! simulation along with PnR ([`EngineStats::sims`] is zero on a warm
//! re-run).
//!
//! Determinism: a job's result depends only on its resolved
//! `(config, app, seed)` content — never on the worker count, the
//! steal pattern, the batch grouping, or cache temperature — and the
//! outcome lists points in the spec's canonical enumeration order, so
//! sharded runs are bit-identical to a sequential (`workers: 1`)
//! baseline. Batching preserves this because `place_batch` backends are
//! contractually batch-size invariant: a problem's result bits depend
//! only on the problem, never on what else shares its solve. The
//! simulation is a deterministic function of the routed flow and the
//! fabric, both keyed content.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::area::{area_of, AreaModel};
use crate::dsl::create_uniform_interconnect;
use crate::ir::Interconnect;
use crate::pnr::{
    finish_flow_scratch, prepare_point, AppGraph, FlowResult, GlobalPlacer, PlacementInstance,
    RouterScratch,
};
use crate::sim::{routed_capacities, RvSim, StallPattern};

use super::cache::ResultCache;
use super::spec::{app_by_name, AreaPoint, Job, PointResult, SweepSpec};

/// Elastic-simulation workload per point: tokens every stream sink
/// drains. Capped below `FlowParams::workload_items` (the runtime
/// *model*'s stream length, 4096 by default) so a sweep point's
/// cycle-accurate simulation stays a few hundred µs; like the default
/// linebuffer delay, the cap is part of the simulation's semantics, not
/// of the cache key.
pub const SIM_TOKENS_CAP: usize = 512;

/// Fill `result`'s elastic-simulation fields for one routed point:
/// simulate the *un-packed* application over channel capacities derived
/// from the point's own routed nets under the job's fabric, free-running
/// (no external sink stalls) — `stall_cycles` then counts exactly the
/// bubbles the fabric's buffering could not absorb, plus pipeline fill.
fn simulate_point(
    app: &AppGraph,
    flow: &FlowResult,
    job: &Job,
    ic: &Interconnect,
    result: &mut PointResult,
) {
    let tokens = job.flow.workload_items.min(SIM_TOKENS_CAP);
    let caps =
        routed_capacities(app, &flow.packed, ic, job.flow.bit_width, &flow.routing, job.fabric);
    // Deterministic input stream (same family the rv tests and benches
    // use); a little slack beyond `tokens` covers linebuffer priming.
    let input: Vec<i64> = (0..(tokens as i64 + 64)).map(|i| (i * 7 + 3) % 251).collect();
    let mut sim = RvSim::new(app, &caps, input);
    let run = sim.run(tokens, tokens * 64 + 4096, StallPattern::None);
    result.sim_cycles = run.cycles as u64;
    result.sim_tokens = run.tokens as u64;
    result.stall_cycles = (run.cycles as u64).saturating_sub(run.tokens as u64);
}

/// Executor tuning.
#[derive(Clone, Debug, Default)]
pub struct EngineOptions {
    /// Worker threads; `0` ⇒ one per available core.
    pub workers: usize,
    /// JSON cache backing file (`dse_cache.json` by convention); `None`
    /// ⇒ in-memory cache only.
    pub cache_path: Option<std::path::PathBuf>,
}

/// Counters for one `run` (and, accumulated, for an engine's lifetime).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct EngineStats {
    /// Jobs in the (deduplicated) list.
    pub jobs: u64,
    /// Jobs answered from the cache.
    pub cache_hits: u64,
    /// Actual PnR flow executions (cold jobs). Zero on a warm re-run.
    pub pnr_runs: u64,
    /// Elastic simulations executed (routed cold jobs only — warm
    /// points reuse the cached metrics). Zero on a warm re-run.
    pub sims: u64,
    /// Interconnects built + frozen (≤ unique configs among cold jobs).
    pub configs_built: u64,
    /// Job groups a worker took from another worker's shard.
    pub steals: u64,
    /// Batched global-placement solves (one `place_batch` call per cold
    /// job group; each covers the whole group's analytic problems).
    pub batched_solves: u64,
}

impl EngineStats {
    fn absorb(&mut self, other: &EngineStats) {
        self.jobs += other.jobs;
        self.cache_hits += other.cache_hits;
        self.pnr_runs += other.pnr_runs;
        self.sims += other.sims;
        self.configs_built += other.configs_built;
        self.steals += other.steals;
        self.batched_solves += other.batched_solves;
    }
}

/// Everything one sweep produced.
#[derive(Clone, Debug)]
pub struct SweepOutcome {
    pub name: String,
    /// One entry per job, in the spec's canonical enumeration order.
    pub points: Vec<(Job, PointResult)>,
    /// Per-config area metrics (when `spec.area`), in config order.
    pub areas: Vec<AreaPoint>,
    pub stats: EngineStats,
}

/// The DSE engine: owns the options and the result cache, so successive
/// sweeps in one process (e.g. the five figure sweeps) share hits.
pub struct DseEngine {
    opts: EngineOptions,
    cache: ResultCache,
    lifetime: EngineStats,
}

impl DseEngine {
    pub fn new(opts: EngineOptions) -> Result<DseEngine, String> {
        let cache = match &opts.cache_path {
            Some(path) => ResultCache::at(path)?,
            None => ResultCache::in_memory(),
        };
        Ok(DseEngine { opts, cache, lifetime: EngineStats::default() })
    }

    /// Engine with default options and an unbacked cache.
    pub fn in_memory() -> DseEngine {
        DseEngine {
            opts: EngineOptions::default(),
            cache: ResultCache::in_memory(),
            lifetime: EngineStats::default(),
        }
    }

    pub fn cache(&self) -> &ResultCache {
        &self.cache
    }

    /// Counters accumulated over every `run` of this engine.
    pub fn lifetime_stats(&self) -> &EngineStats {
        &self.lifetime
    }

    fn worker_count(&self) -> usize {
        let configured = if self.opts.workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.opts.workers
        };
        configured.max(1)
    }

    /// Run one sweep. Cold points fan out over the worker pool; warm
    /// points come from the cache; the cache file (if any) is updated
    /// when new results were computed.
    pub fn run(
        &mut self,
        spec: &SweepSpec,
        placer: &(dyn GlobalPlacer + Sync),
    ) -> Result<SweepOutcome, String> {
        let jobs = spec.jobs(placer.name())?;
        let mut stats = EngineStats { jobs: jobs.len() as u64, ..Default::default() };

        // Partition into cache hits and cold misses.
        let mut hits: Vec<Option<PointResult>> = Vec::with_capacity(jobs.len());
        let mut misses: Vec<usize> = Vec::new();
        for (i, job) in jobs.iter().enumerate() {
            match self.cache.get(&job.key) {
                Some(r) => {
                    stats.cache_hits += 1;
                    hits.push(Some(r.clone()));
                }
                None => {
                    hits.push(None);
                    misses.push(i);
                }
            }
        }

        // Unique configurations among the cold jobs; each is built and
        // frozen lazily by the first worker that needs it and shared via
        // `Arc` from then on.
        let mut cfg_slot: BTreeMap<String, usize> = BTreeMap::new();
        let mut configs: Vec<crate::dsl::InterconnectConfig> = Vec::new();
        let mut cfg_of_job: Vec<usize> = vec![usize::MAX; jobs.len()];
        for &i in &misses {
            let slot = *cfg_slot.entry(jobs[i].key.config.0.clone()).or_insert_with(|| {
                configs.push(jobs[i].cfg.clone());
                configs.len() - 1
            });
            cfg_of_job[i] = slot;
        }
        let interconnects: Vec<OnceLock<Arc<Interconnect>>> =
            (0..configs.len()).map(|_| OnceLock::new()).collect();

        // Resolve each distinct app generator once per run; workers share
        // the graphs read-only (generator construction is not free).
        let mut app_graphs: BTreeMap<String, crate::pnr::AppGraph> = BTreeMap::new();
        for &i in &misses {
            let key = &jobs[i].key.app;
            if !app_graphs.contains_key(key) {
                let app = app_by_name(key).expect("app validated by SweepSpec::jobs");
                app_graphs.insert(key.clone(), app);
            }
        }

        // The cold jobs of one configuration form one *job group* — the
        // batching unit: the group's global-placement problems all live
        // on the same frozen fabric and solve in one `place_batch` call.
        // `misses` is in canonical job order and configs dedup by slot,
        // so grouping by slot preserves enumeration order within and
        // across groups.
        let mut group_of_slot: BTreeMap<usize, usize> = BTreeMap::new();
        let mut groups: Vec<Vec<usize>> = Vec::new();
        for &i in &misses {
            let g = *group_of_slot.entry(cfg_of_job[i]).or_insert_with(|| {
                groups.push(Vec::new());
                groups.len() - 1
            });
            groups[g].push(i);
        }

        // Shard the job groups round-robin; idle workers steal whole
        // groups from the back of the most-loaded victim.
        let workers = self.worker_count();
        let shards: Vec<Mutex<VecDeque<usize>>> =
            (0..workers).map(|_| Mutex::new(VecDeque::new())).collect();
        for k in 0..groups.len() {
            shards[k % workers].lock().expect("shard").push_back(k);
        }

        let computed: Vec<OnceLock<PointResult>> =
            (0..jobs.len()).map(|_| OnceLock::new()).collect();
        let pnr_runs = AtomicU64::new(0);
        let sims = AtomicU64::new(0);
        let configs_built = AtomicU64::new(0);
        let steals = AtomicU64::new(0);
        let batched_solves = AtomicU64::new(0);

        if !misses.is_empty() {
            std::thread::scope(|scope| {
                for me in 0..workers {
                    let jobs = &jobs;
                    let groups = &groups;
                    let shards = &shards;
                    let configs = &configs;
                    let interconnects = &interconnects;
                    let app_graphs = &app_graphs;
                    let cfg_of_job = &cfg_of_job;
                    let computed = &computed;
                    let pnr_runs = &pnr_runs;
                    let sims = &sims;
                    let configs_built = &configs_built;
                    let steals = &steals;
                    let batched_solves = &batched_solves;
                    scope.spawn(move || {
                        let mut scratch = RouterScratch::new();
                        while let Some(g) = next_group(shards, me, steals) {
                            let group = &groups[g];
                            let slot = cfg_of_job[group[0]];
                            let ic = interconnects[slot].get_or_init(|| {
                                configs_built.fetch_add(1, Ordering::Relaxed);
                                Arc::new(create_uniform_interconnect(&configs[slot]))
                            });
                            // Phase 1 for every job in the group: pack +
                            // problem construction.
                            let prepared: Vec<crate::pnr::PreparedPoint> = group
                                .iter()
                                .map(|&i| {
                                    let job = &jobs[i];
                                    let app = &app_graphs[job.key.app.as_str()];
                                    prepare_point(ic, app, &job.flow)
                                })
                                .collect();
                            // Phase 2: ONE batched global solve for the
                            // whole group.
                            let batch: Vec<PlacementInstance> = prepared
                                .iter()
                                .map(|pp| PlacementInstance {
                                    problem: &pp.problem,
                                    xs0: &pp.xs0,
                                    ys0: &pp.ys0,
                                })
                                .collect();
                            batched_solves.fetch_add(1, Ordering::Relaxed);
                            let solved = placer.place_batch(&batch);
                            assert_eq!(
                                solved.len(),
                                group.len(),
                                "placer `{}` returned {} results for a {}-job group",
                                placer.name(),
                                solved.len(),
                                group.len()
                            );
                            // Phase 3 per job: legalize → SA → route →
                            // STA, reusing the worker's router scratch;
                            // then the elastic simulation of the routed
                            // point under the job's fabric.
                            for ((&i, pp), (xs, ys)) in group.iter().zip(&prepared).zip(&solved) {
                                pnr_runs.fetch_add(1, Ordering::Relaxed);
                                let result = match finish_flow_scratch(
                                    ic,
                                    pp,
                                    xs,
                                    ys,
                                    &jobs[i].flow,
                                    &mut scratch,
                                ) {
                                    Ok(flow) => {
                                        let mut r = PointResult::from_flow(&flow);
                                        sims.fetch_add(1, Ordering::Relaxed);
                                        let app = &app_graphs[jobs[i].key.app.as_str()];
                                        simulate_point(app, &flow, &jobs[i], ic, &mut r);
                                        r
                                    }
                                    Err(_) => PointResult::unroutable(),
                                };
                                let _ = computed[i].set(result);
                            }
                        }
                    });
                }
            });
        }

        stats.pnr_runs = pnr_runs.into_inner();
        stats.sims = sims.into_inner();
        stats.configs_built = configs_built.into_inner();
        stats.steals = steals.into_inner();
        stats.batched_solves = batched_solves.into_inner();

        // Merge in canonical job order; feed new results to the cache.
        let mut points = Vec::with_capacity(jobs.len());
        for (i, job) in jobs.into_iter().enumerate() {
            let result = match hits[i].take() {
                Some(r) => r,
                None => {
                    let r = computed[i].get().expect("cold job executed").clone();
                    self.cache.insert(job.key.clone(), r.clone());
                    r
                }
            };
            points.push((job, result));
        }
        if stats.pnr_runs > 0 {
            self.cache.save()?;
        }

        // Area metrics per unique (config, fabric), config-major in
        // enumeration order. Cheap (no PnR), so not cached;
        // deterministic, so warm and cold runs render identical tables.
        // Interconnects the worker pool already froze are reused by
        // their config descriptor.
        let mut areas = Vec::new();
        if spec.area {
            let built: BTreeMap<String, Arc<Interconnect>> = configs
                .iter()
                .zip(&interconnects)
                .filter_map(|(cfg, cell)| {
                    cell.get().map(|ic| (cfg.descriptor(), Arc::clone(ic)))
                })
                .collect();
            let model = AreaModel::default();
            let fabrics = spec.fabric_axis();
            for cfg in spec.configs()? {
                let ic = match built.get(&cfg.descriptor()) {
                    Some(ic) => Arc::clone(ic),
                    None => Arc::new(create_uniform_interconnect(&cfg)),
                };
                for &fb in &fabrics {
                    let tile = area_of(&ic, &model, fb.area_mode()).interior_tile(&ic);
                    areas.push(AreaPoint {
                        config: cfg.descriptor(),
                        fabric: fb.label(),
                        tracks: cfg.num_tracks,
                        sb_sides: cfg.sb_core_sides.0,
                        cb_sides: cfg.cb_core_sides.0,
                        sb_um2: tile.sb_um2,
                        cb_um2: tile.cb_um2,
                    });
                }
            }
        }

        self.lifetime.absorb(&stats);
        Ok(SweepOutcome { name: spec.name.clone(), points, areas, stats })
    }
}

/// Pop the next job group: own shard front first, then steal from the
/// back of the most-loaded victim (re-scanning on races until every
/// shard is observed empty).
fn next_group(shards: &[Mutex<VecDeque<usize>>], me: usize, steals: &AtomicU64) -> Option<usize> {
    if let Some(i) = shards[me].lock().expect("shard").pop_front() {
        return Some(i);
    }
    loop {
        let mut victim = None;
        let mut victim_len = 0;
        for (v, shard) in shards.iter().enumerate() {
            if v == me {
                continue;
            }
            let len = shard.lock().expect("shard").len();
            if len > victim_len {
                victim_len = len;
                victim = Some(v);
            }
        }
        let v = victim?;
        if let Some(i) = shards[v].lock().expect("shard").pop_back() {
            steals.fetch_add(1, Ordering::Relaxed);
            return Some(i);
        }
        // Raced with the victim draining its shard; rescan.
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::InterconnectConfig;
    use crate::pnr::{FlowParams, NativePlacer, SaParams};

    fn quick_spec() -> SweepSpec {
        SweepSpec {
            name: "exec-test".into(),
            base: InterconnectConfig { mem_column_period: 3, ..Default::default() },
            tracks: vec![4, 5],
            apps: vec!["pointwise".into()],
            seeds: vec![1],
            flow: FlowParams {
                sa: SaParams { moves_per_node: 4, ..Default::default() },
                ..Default::default()
            },
            ..Default::default()
        }
    }

    #[test]
    fn cold_runs_count_pnr_and_warm_runs_do_not() {
        let mut engine = DseEngine::in_memory();
        let cold = engine.run(&quick_spec(), &NativePlacer::default()).unwrap();
        assert_eq!(cold.points.len(), 2);
        assert_eq!(cold.stats.pnr_runs, 2);
        assert_eq!(cold.stats.sims, 2, "every routed cold point simulates");
        assert_eq!(cold.stats.cache_hits, 0);
        assert!(cold.stats.configs_built <= 2);
        // Two distinct configs ⇒ two job groups ⇒ two batched solves.
        assert_eq!(cold.stats.batched_solves, 2);
        let warm = engine.run(&quick_spec(), &NativePlacer::default()).unwrap();
        assert_eq!(warm.stats.pnr_runs, 0);
        assert_eq!(warm.stats.sims, 0, "warm re-run must skip all simulations");
        assert_eq!(warm.stats.cache_hits, 2);
        assert_eq!(warm.stats.batched_solves, 0);
        for ((ja, ra), (jb, rb)) in cold.points.iter().zip(&warm.points) {
            assert_eq!(ja.key, jb.key);
            assert_eq!(ra, rb);
        }
        assert_eq!(engine.lifetime_stats().pnr_runs, 2);
        assert_eq!(engine.lifetime_stats().jobs, 4);
    }

    #[test]
    fn worker_count_does_not_change_results() {
        let spec = quick_spec();
        let run_with = |workers: usize| {
            let mut e = DseEngine::new(EngineOptions { workers, cache_path: None }).unwrap();
            e.run(&spec, &NativePlacer::default()).unwrap()
        };
        let sequential = run_with(1);
        let sharded = run_with(4);
        assert_eq!(sequential.points.len(), sharded.points.len());
        for ((ja, ra), (jb, rb)) in sequential.points.iter().zip(&sharded.points) {
            assert_eq!(ja.key, jb.key);
            assert_eq!(ra, rb);
        }
    }

    #[test]
    fn batched_placer_matches_scalar_loop_per_group() {
        use crate::pnr::BatchedNativePlacer;
        // NativePlacer takes the trait's default place_batch (a
        // sequential optimize loop); BatchedNativePlacer vectorizes it.
        // Same spec, both backends: every point must be bit-identical,
        // and the batched run must still do one solve per config group.
        let spec = SweepSpec {
            apps: vec!["pointwise".into(), "gaussian".into()],
            seeds: vec![1, 2],
            ..quick_spec()
        };
        let mut scalar_engine = DseEngine::in_memory();
        let scalar = scalar_engine.run(&spec, &NativePlacer::default()).unwrap();
        let mut batched_engine = DseEngine::in_memory();
        let batched = batched_engine.run(&spec, &BatchedNativePlacer::default()).unwrap();
        assert_eq!(scalar.points.len(), 8);
        // 2 configs ⇒ 2 groups of 4 problems each, regardless of backend.
        assert_eq!(scalar.stats.batched_solves, 2);
        assert_eq!(batched.stats.batched_solves, 2);
        for ((ja, ra), (jb, rb)) in scalar.points.iter().zip(&batched.points) {
            assert_eq!(ja.key, jb.key, "same placer name, same keys");
            assert_eq!(ra, rb);
            assert_eq!(ra.critical_path_ps.to_bits(), rb.critical_path_ps.to_bits());
        }
    }

    #[test]
    fn area_only_sweep_runs_no_pnr() {
        let spec = SweepSpec {
            name: "area-only".into(),
            base: InterconnectConfig {
                width: 6,
                height: 6,
                mem_column_period: 0,
                ..Default::default()
            },
            tracks: vec![2, 3, 4],
            area: true,
            ..Default::default()
        };
        let mut engine = DseEngine::in_memory();
        let out = engine.run(&spec, &NativePlacer::default()).unwrap();
        assert!(out.points.is_empty());
        assert_eq!(out.stats.pnr_runs, 0);
        assert_eq!(out.stats.sims, 0);
        assert_eq!(out.areas.len(), 3);
        assert_eq!(out.areas[0].tracks, 2);
        assert_eq!(out.areas[0].fabric, "static");
        // More tracks ⇒ more SB area (Fig. 10's monotonicity).
        assert!(out.areas[2].sb_um2 > out.areas[0].sb_um2);
    }

    #[test]
    fn fabric_axis_simulates_each_point_and_caches_distinctly() {
        use crate::sim::FabricKind;
        let spec = SweepSpec {
            fabrics: vec![FabricKind::Static, FabricKind::RvFullFifo { depth: 2 }],
            ..quick_spec()
        };
        let mut engine = DseEngine::in_memory();
        let cold = engine.run(&spec, &NativePlacer::default()).unwrap();
        // 2 tracks × 2 fabrics × 1 app × 1 seed.
        assert_eq!(cold.points.len(), 4);
        assert_eq!(cold.stats.pnr_runs, 4);
        assert_eq!(cold.stats.sims, 4);
        for (job, r) in &cold.points {
            assert!(r.routed, "{:?}", job.key);
            assert!(r.sim_cycles > 0 && r.sim_tokens > 0, "{:?}", job.key);
            assert_eq!(r.stall_cycles, r.sim_cycles - r.sim_tokens);
            assert!(r.throughput() > 0.0);
            // Fabric rows are keyed distinctly; static stays bare.
            assert_eq!(
                job.key.config.0.contains("fabric="),
                job.fabric != FabricKind::Static,
                "{}",
                job.key.config
            );
        }
        // Points come tracks-major, fabric-minor: per track, the
        // elastic fabric can only match or beat the static one (deeper
        // channels never reduce throughput).
        for pair in cold.points.chunks(2) {
            let (stat, rv) = (&pair[0].1, &pair[1].1);
            assert!(
                rv.sim_cycles <= stat.sim_cycles,
                "rv {} vs static {}",
                rv.sim_cycles,
                stat.sim_cycles
            );
        }
        // Warm re-run: zero PnR *and* zero simulations.
        let warm = engine.run(&spec, &NativePlacer::default()).unwrap();
        assert_eq!(warm.stats.pnr_runs, 0);
        assert_eq!(warm.stats.sims, 0);
        assert_eq!(warm.stats.cache_hits, 4);
        for ((ja, ra), (jb, rb)) in cold.points.iter().zip(&warm.points) {
            assert_eq!(ja.key, jb.key);
            assert_eq!(ra, rb);
        }
    }
}
