//! The sharded sweep executor: a fixed worker pool over per-worker
//! deques of *job groups* with work stealing. Jobs sharing an
//! interconnect configuration form one group; a worker drains its group
//! through one batched global-placement solve
//! ([`GlobalPlacer::place_batch`] — N analytic problems, one solver
//! call) before finishing each point (legalize → SA → route → STA)
//! individually. Each worker owns reusable [`RouterScratch`] buffers
//! (PathFinder cost/visited/heap arrays allocated once, reset per
//! route); each interconnect configuration is built — and its routing
//! graphs frozen to immutable CSR [`crate::ir::CompiledGraph`]s —
//! exactly once per run, then shared across workers via `Arc`. Results
//! are keyed and cached through [`ResultCache`], so a warm re-run of the
//! same spec performs zero PnR calls (observable via
//! [`EngineStats::pnr_runs`]).
//!
//! The executor is layered so the long-lived service
//! ([`crate::service`]) can share state across concurrent sessions:
//!
//! - [`execute_jobs`] is the pure cold path — run a job list, no cache
//!   involved — parameterized over an [`InterconnectSource`] so frozen
//!   interconnects can come from a process-wide LRU instead of being
//!   rebuilt per request;
//! - [`run_sweep`] is the engine *handle* form: partition against a
//!   caller-owned [`ResultCache`], execute the misses, merge, persist;
//! - [`DseEngine`] owns a cache and some options and delegates to
//!   [`run_sweep`] — the one-shot CLI shape.
//!
//! With [`EngineOptions::warm_start`] on, the executor additionally
//! threads a [`PnrArtifactCache`] through the run ([`execute_jobs_with`]
//! / [`run_sweep_with`]): job groups are reordered along a greedy
//! nearest-neighbor chain over [`super::spec::AxisDelta`] reuse
//! distance and sharded in contiguous blocks (so a group usually runs
//! right after its best donor finished), and every job first looks for
//! a donor artifact within [`MAX_DONOR_DISTANCE`] — found ⇒ the point
//! runs [`crate::pnr::run_flow_warm`] (seeded placement + routed-tree
//! replay) instead of the batched scratch pipeline, falling back to a
//! scratch solve when the seed cannot converge. Warm-started numbers
//! are *not* bit-identical to scratch (and the set of warm starts can
//! depend on the worker schedule through in-run donor visibility);
//! only the flag-off path carries the determinism contract below.
//!
//! Every *routed* cold point additionally runs the flattened elastic
//! (ready-valid) simulator on the point's own routing — channel
//! capacities derived from the registers each routed net crosses under
//! the job's [`crate::sim::FabricKind`] — and records throughput/stall
//! metrics in the cached [`PointResult`]. Warm points skip the
//! simulation along with PnR ([`EngineStats::sims`] is zero on a warm
//! re-run).
//!
//! Determinism: a job's result depends only on its resolved
//! `(config, app, seed)` content — never on the worker count, the
//! steal pattern, the batch grouping, or cache temperature — and the
//! outcome lists points in the spec's canonical enumeration order, so
//! sharded runs are bit-identical to a sequential (`workers: 1`)
//! baseline. Batching preserves this because `place_batch` backends are
//! contractually batch-size invariant: a problem's result bits depend
//! only on the problem, never on what else shares its solve. The
//! simulation is a deterministic function of the routed flow and the
//! fabric, both keyed content. Interconnect *reuse* preserves it too:
//! `create_uniform_interconnect` is a pure function of the config, so a
//! warm `Arc` from an [`InterconnectSource`] is indistinguishable from
//! a fresh build.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::area::{area_of, AreaModel};
use crate::dsl::{create_uniform_interconnect, InterconnectConfig};
use crate::ir::Interconnect;
use crate::obs;
use crate::obs::span::names as spans;
use crate::pnr::{
    finish_flow_scratch, prepare_point, run_flow_warm, AppGraph, FlowResult, GlobalPlacer,
    PlacementInstance, RouterScratch, WarmSeed,
};
use crate::sim::{routed_capacities, RvSim, StallPattern};

use super::artifacts::{artifact_path_for, encode_node, PnrArtifact, PnrArtifactCache};
use super::cache::ResultCache;
use super::spec::{app_by_name, AreaPoint, Job, PointResult, SweepSpec, MAX_DONOR_DISTANCE};

/// Elastic-simulation workload per point: tokens every stream sink
/// drains. Capped below `FlowParams::workload_items` (the runtime
/// *model*'s stream length, 4096 by default) so a sweep point's
/// cycle-accurate simulation stays a few hundred µs; like the default
/// linebuffer delay, the cap is part of the simulation's semantics, not
/// of the cache key.
pub const SIM_TOKENS_CAP: usize = 512;

/// Fill `result`'s elastic-simulation fields for one routed point:
/// simulate the *un-packed* application over channel capacities derived
/// from the point's own routed nets under the job's fabric, free-running
/// (no external sink stalls) — `stall_cycles` then counts exactly the
/// bubbles the fabric's buffering could not absorb, plus pipeline fill.
fn simulate_point(
    app: &AppGraph,
    flow: &FlowResult,
    job: &Job,
    ic: &Interconnect,
    result: &mut PointResult,
) {
    let tokens = job.flow.workload_items.min(SIM_TOKENS_CAP);
    let caps =
        routed_capacities(app, &flow.packed, ic, job.flow.bit_width, &flow.routing, job.fabric);
    // Deterministic input stream (same family the rv tests and benches
    // use); a little slack beyond `tokens` covers linebuffer priming.
    let input: Vec<i64> = (0..(tokens as i64 + 64)).map(|i| (i * 7 + 3) % 251).collect();
    let mut sim = RvSim::new(app, &caps, input);
    let run = sim.run(tokens, tokens * 64 + 4096, StallPattern::None);
    result.sim_cycles = run.cycles as u64;
    result.sim_tokens = run.tokens as u64;
    result.stall_cycles = (run.cycles as u64).saturating_sub(run.tokens as u64);
}

/// Executor tuning.
#[derive(Clone, Debug, Default)]
pub struct EngineOptions {
    /// Worker threads; `0` ⇒ one per available core.
    pub workers: usize,
    /// JSON cache backing file (`dse_cache.json` by convention); `None`
    /// ⇒ in-memory cache only.
    pub cache_path: Option<std::path::PathBuf>,
    /// Incremental PnR (off by default): keep a [`PnrArtifactCache`] of
    /// legalized placements and routed trees (persisted next to
    /// `cache_path` when file-backed, see [`artifact_path_for`]) and
    /// warm-start each point from its nearest axis-delta donor, with
    /// delta-aware job-group ordering. Flag-off runs are bit-identical
    /// to the executor without this feature; flag-on results stay legal
    /// but are not bit-identical to scratch.
    pub warm_start: bool,
}

/// Resolve a worker-count option: `0` ⇒ one per available core.
pub fn resolve_workers(workers: usize) -> usize {
    let configured = if workers == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        workers
    };
    configured.max(1)
}

/// Counters for one `run` (and, accumulated, for an engine's lifetime).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct EngineStats {
    /// Jobs in the (deduplicated) list.
    pub jobs: u64,
    /// Jobs answered from the cache.
    pub cache_hits: u64,
    /// Jobs answered by joining another in-flight request's computation
    /// instead of recomputing. Always zero for `DseEngine` runs — only
    /// the service's request coalescing ([`crate::service`]) produces
    /// joins.
    pub coalesced: u64,
    /// Actual PnR flow executions (cold jobs). Zero on a warm re-run.
    pub pnr_runs: u64,
    /// Elastic simulations executed (routed cold jobs only — warm
    /// points reuse the cached metrics). Zero on a warm re-run.
    pub sims: u64,
    /// Interconnects built + frozen (≤ unique configs among cold jobs;
    /// an [`InterconnectSource`] serving warm `Arc`s builds fewer).
    pub configs_built: u64,
    /// Job groups a worker took from another worker's shard.
    pub steals: u64,
    /// Batched global-placement solves (one `place_batch` call per cold
    /// job group; each covers the whole group's analytic problems).
    pub batched_solves: u64,
    /// Points warm-started from a donor artifact (seeded placement +
    /// routed-tree replay). Always zero unless
    /// [`EngineOptions::warm_start`] is on.
    pub warm_starts: u64,
    /// Donor sink-path trees replayed verbatim across all warm-started
    /// points (counted per net).
    pub nets_reused: u64,
    /// Nets PathFinder re-routed inside warm-started points: invalid or
    /// conflicting donor trees, plus every net of a point that fell
    /// back to scratch routing.
    pub nets_rerouted: u64,
    /// Search-frontier pops summed over every routed flow (cold and
    /// warm-started). The router-variant cost metric: bucket/radix/A*/
    /// bidir cores and Steiner sharing all move this number without
    /// touching `pnr_runs`.
    pub route_expansions: u64,
}

impl EngineStats {
    pub(crate) fn absorb(&mut self, other: &EngineStats) {
        self.jobs += other.jobs;
        self.cache_hits += other.cache_hits;
        self.coalesced += other.coalesced;
        self.pnr_runs += other.pnr_runs;
        self.sims += other.sims;
        self.configs_built += other.configs_built;
        self.steals += other.steals;
        self.batched_solves += other.batched_solves;
        self.warm_starts += other.warm_starts;
        self.nets_reused += other.nets_reused;
        self.nets_rerouted += other.nets_rerouted;
        self.route_expansions += other.route_expansions;
    }
}

/// Live counters for one in-flight sweep, shared between the executor's
/// workers and an observer (the daemon's heartbeat thread, which
/// renders [`SweepProgress::snapshot`] into each progress frame).
/// Totals are set once at partition time ([`SweepProgress::begin`]);
/// per-job counters tick as workers finish points. Purely
/// observational: nothing ever reads it back into the computation, so
/// threading it through changes no result bits.
#[derive(Debug, Default)]
pub struct SweepProgress {
    jobs_total: AtomicU64,
    /// Jobs answered on any path: cache hits + coalesced joins up
    /// front, then cold completions as they land.
    jobs_done: AtomicU64,
    cache_hits: AtomicU64,
    coalesced: AtomicU64,
    cold_total: AtomicU64,
    cold_done: AtomicU64,
    warm_starts: AtomicU64,
    start_ns: AtomicU64,
    /// Busy nanoseconds per executor worker (index = worker id).
    worker_busy_ns: Mutex<Vec<u64>>,
}

impl SweepProgress {
    pub fn new() -> SweepProgress {
        let p = SweepProgress::default();
        p.start_ns.store(obs::now_ns(), Ordering::Relaxed);
        p
    }

    /// Record the partition: `total` jobs, of which `hits` came from the
    /// cache and `coalesced` joined another request's computation (both
    /// count as done immediately — the coalesced jobs' own compute is
    /// tracked by the claiming request's progress).
    pub fn begin(&self, total: u64, hits: u64, coalesced: u64) {
        self.jobs_total.store(total, Ordering::Relaxed);
        self.cache_hits.store(hits, Ordering::Relaxed);
        self.coalesced.store(coalesced, Ordering::Relaxed);
        self.jobs_done.store(hits + coalesced, Ordering::Relaxed);
        self.cold_total.store(total.saturating_sub(hits + coalesced), Ordering::Relaxed);
    }

    fn ensure_workers(&self, n: usize) {
        let mut busy = self.worker_busy_ns.lock().unwrap_or_else(|p| p.into_inner());
        if busy.len() < n {
            busy.resize(n, 0);
        }
    }

    fn add_busy(&self, worker: usize, ns: u64) {
        let mut busy = self.worker_busy_ns.lock().unwrap_or_else(|p| p.into_inner());
        if worker >= busy.len() {
            busy.resize(worker + 1, 0);
        }
        busy[worker] += ns;
    }

    fn job_finished(&self, warm: bool) {
        self.jobs_done.fetch_add(1, Ordering::Relaxed);
        self.cold_done.fetch_add(1, Ordering::Relaxed);
        if warm {
            self.warm_starts.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn snapshot(&self) -> ProgressSnapshot {
        ProgressSnapshot {
            jobs_total: self.jobs_total.load(Ordering::Relaxed),
            jobs_done: self.jobs_done.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            coalesced: self.coalesced.load(Ordering::Relaxed),
            cold_total: self.cold_total.load(Ordering::Relaxed),
            cold_done: self.cold_done.load(Ordering::Relaxed),
            warm_starts: self.warm_starts.load(Ordering::Relaxed),
            elapsed_ns: obs::now_ns()
                .saturating_sub(self.start_ns.load(Ordering::Relaxed))
                .max(1),
            worker_busy_ns: self
                .worker_busy_ns
                .lock()
                .unwrap_or_else(|p| p.into_inner())
                .clone(),
        }
    }
}

/// One point-in-time view of a [`SweepProgress`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ProgressSnapshot {
    pub jobs_total: u64,
    pub jobs_done: u64,
    pub cache_hits: u64,
    pub coalesced: u64,
    pub cold_total: u64,
    pub cold_done: u64,
    pub warm_starts: u64,
    pub elapsed_ns: u64,
    pub worker_busy_ns: Vec<u64>,
}

impl ProgressSnapshot {
    /// The human-readable heartbeat line, e.g.
    /// `progress: 12/40 jobs (10 cached, 1 coalesced, 1/29 cold, 3
    /// warm-started), util w0=93% w1=88%`.
    pub fn message(&self) -> String {
        let mut s = format!(
            "progress: {}/{} jobs ({} cached, {} coalesced, {}/{} cold",
            self.jobs_done,
            self.jobs_total,
            self.cache_hits,
            self.coalesced,
            self.cold_done,
            self.cold_total,
        );
        if self.warm_starts > 0 {
            s.push_str(&format!(", {} warm-started", self.warm_starts));
        }
        s.push(')');
        if !self.worker_busy_ns.is_empty() {
            s.push_str(", util");
            for (w, &busy) in self.worker_busy_ns.iter().enumerate() {
                let pct = (busy as f64 / self.elapsed_ns as f64 * 100.0).min(100.0);
                s.push_str(&format!(" w{w}={pct:.0}%"));
            }
        }
        s
    }
}

/// Where the executor gets frozen interconnects. The build is a pure
/// function of the config, so any source is behaviorally identical to
/// [`BuildFresh`] — sharing only changes *when* the build cost is paid.
/// Implemented by the service's process-wide LRU
/// ([`crate::service::state`]) so concurrent sessions share warm
/// `CompiledGraph`s.
pub trait InterconnectSource: Sync {
    /// The frozen interconnect for `cfg`, plus whether this call built
    /// it (`true`) or served a warm copy (`false`).
    fn interconnect(&self, cfg: &InterconnectConfig) -> (Arc<Interconnect>, bool);
}

/// Default source: build and freeze on every call. The executor's
/// per-run `OnceLock` slots still guarantee at most one call per unique
/// configuration per run.
pub struct BuildFresh;

impl InterconnectSource for BuildFresh {
    fn interconnect(&self, cfg: &InterconnectConfig) -> (Arc<Interconnect>, bool) {
        (Arc::new(create_uniform_interconnect(cfg)), true)
    }
}

/// What [`execute_jobs`] produced: one result per input job (same
/// order), the cold-side counters, and the frozen interconnects the run
/// touched (by `InterconnectConfig::descriptor()`, for area reuse).
pub struct ColdOutcome {
    pub results: Vec<PointResult>,
    /// Only the cold counters are populated (`jobs`, `cache_hits`, and
    /// `coalesced` stay zero — the caller owns the partition).
    pub stats: EngineStats,
    pub interconnects: Vec<(String, Arc<Interconnect>)>,
}

/// The pure cold path: run every job in `jobs` (no cache involved) on a
/// worker pool of `workers` threads (`0` ⇒ one per core) and return the
/// results in input order. Jobs sharing a `key.config` descriptor form
/// one group, drained through one batched placement solve. The caller
/// guarantees the job list is what it wants executed — deduplication
/// and cache partitioning happen upstream ([`run_sweep`], or the
/// service's coalescer).
pub fn execute_jobs(
    jobs: &[&Job],
    workers: usize,
    placer: &(dyn GlobalPlacer + Sync),
    ics: &dyn InterconnectSource,
) -> ColdOutcome {
    execute_jobs_with(jobs, workers, placer, ics, None)
}

/// Snapshot one finished flow for the warm-start store: the legalized
/// placement plus every routed sink path encoded as graph-independent
/// node tokens (re-resolved per target fabric on reuse).
fn artifact_of(ic: &Interconnect, bit_width: u8, flow: &FlowResult) -> PnrArtifact {
    let rg = ic.graph(bit_width);
    PnrArtifact {
        placement: flow.placement.pos.clone(),
        nets: flow
            .routing
            .trees
            .iter()
            .map(|t| {
                t.sink_paths
                    .iter()
                    .map(|p| p.iter().map(|&n| encode_node(rg, n)).collect())
                    .collect()
            })
            .collect(),
    }
}

/// [`execute_jobs`], optionally threading a warm-start artifact store
/// through the run. `warm: None` is byte-for-byte the plain cold path
/// (same grouping, same round-robin sharding, same batched solves —
/// [`execute_jobs`] simply delegates here). `warm: Some(..)` enables
/// incremental PnR: groups are chained nearest-neighbor by axis delta,
/// sharded in contiguous blocks, and each job tries
/// [`PnrArtifactCache::best_donor`] before falling into the batched
/// scratch pipeline; every successfully routed point (warm or cold)
/// deposits its own artifact for later neighbors.
pub fn execute_jobs_with(
    jobs: &[&Job],
    workers: usize,
    placer: &(dyn GlobalPlacer + Sync),
    ics: &dyn InterconnectSource,
    warm: Option<&PnrArtifactCache>,
) -> ColdOutcome {
    execute_jobs_obs(jobs, workers, placer, ics, warm, None)
}

/// [`execute_jobs_with`], optionally ticking a live [`SweepProgress`]
/// as workers finish points (the daemon threads one through so its
/// heartbeat frames can report mid-sweep state). `progress` is written,
/// never read — all delegating forms pass `None` and compute the same
/// bits.
pub fn execute_jobs_obs(
    jobs: &[&Job],
    workers: usize,
    placer: &(dyn GlobalPlacer + Sync),
    ics: &dyn InterconnectSource,
    warm: Option<&PnrArtifactCache>,
    progress: Option<&SweepProgress>,
) -> ColdOutcome {
    // Unique configurations among the jobs, keyed by the full config
    // descriptor (the grouping identity: fabric and flow variants group
    // separately even when the interconnect build is shared). Each slot
    // is resolved through `ics` lazily by the first worker that needs
    // it and shared via `Arc` from then on.
    let mut cfg_slot: BTreeMap<&str, usize> = BTreeMap::new();
    let mut configs: Vec<&InterconnectConfig> = Vec::new();
    let mut cfg_of_job: Vec<usize> = Vec::with_capacity(jobs.len());
    for job in jobs {
        let slot = *cfg_slot.entry(job.key.config.0.as_str()).or_insert_with(|| {
            configs.push(&job.cfg);
            configs.len() - 1
        });
        cfg_of_job.push(slot);
    }
    let interconnects: Vec<OnceLock<Arc<Interconnect>>> =
        (0..configs.len()).map(|_| OnceLock::new()).collect();

    // Resolve each distinct app generator once per run; workers share
    // the graphs read-only (generator construction is not free).
    let mut app_graphs: BTreeMap<String, AppGraph> = BTreeMap::new();
    for job in jobs {
        if !app_graphs.contains_key(job.key.app.as_str()) {
            let app = app_by_name(&job.key.app).expect("app validated by SweepSpec::jobs");
            app_graphs.insert(job.key.app.clone(), app);
        }
    }

    // The jobs of one configuration form one *job group* — the batching
    // unit: the group's global-placement problems all live on the same
    // frozen fabric and solve in one `place_batch` call. The input is in
    // the caller's canonical order and configs dedup by slot, so
    // grouping by slot preserves that order within and across groups.
    let mut group_of_slot: BTreeMap<usize, usize> = BTreeMap::new();
    let mut groups: Vec<Vec<usize>> = Vec::new();
    for (i, &slot) in cfg_of_job.iter().enumerate() {
        let g = *group_of_slot.entry(slot).or_insert_with(|| {
            groups.push(Vec::new());
            groups.len() - 1
        });
        groups[g].push(i);
    }

    // Delta-aware sweep ordering (warm runs only): chain the job groups
    // greedily by nearest axis-delta reuse distance — start at the first
    // group, then always hop to the closest unvisited neighbor (ties to
    // the lowest index; incomparable descriptors sort last). Each group
    // then executes right after the group most likely to have deposited
    // its best donor artifacts.
    if warm.is_some() && groups.len() > 1 {
        let rep: Vec<_> = groups.iter().map(|g| &jobs[g[0]].key.config).collect();
        let mut order: Vec<usize> = Vec::with_capacity(groups.len());
        let mut taken = vec![false; groups.len()];
        let mut cur = 0usize;
        order.push(cur);
        taken[cur] = true;
        while order.len() < groups.len() {
            let mut best: Option<(u32, usize)> = None;
            for (cand, cand_taken) in taken.iter().enumerate() {
                if *cand_taken {
                    continue;
                }
                let d = rep[cur].reuse_distance(rep[cand]).unwrap_or(u32::MAX - 1);
                if best.map(|(bd, _)| d < bd).unwrap_or(true) {
                    best = Some((d, cand));
                }
            }
            let (_, next) = best.expect("unvisited group remains");
            order.push(next);
            taken[next] = true;
            cur = next;
        }
        groups = order.into_iter().map(|gi| std::mem::take(&mut groups[gi])).collect();
    }

    // Shard the job groups; idle workers steal whole groups from the
    // back of the most-loaded victim. Cold runs shard round-robin
    // (unchanged); warm runs shard the nearest-neighbor chain in
    // contiguous blocks so chain neighbors stay on the same worker.
    let workers = resolve_workers(workers);
    if let Some(p) = progress {
        p.ensure_workers(workers);
    }
    let shards: Vec<Mutex<VecDeque<usize>>> =
        (0..workers).map(|_| Mutex::new(VecDeque::new())).collect();
    if warm.is_some() {
        let per = (groups.len() + workers - 1) / workers;
        for k in 0..groups.len() {
            shards[(k / per.max(1)).min(workers - 1)].lock().expect("shard").push_back(k);
        }
    } else {
        for k in 0..groups.len() {
            shards[k % workers].lock().expect("shard").push_back(k);
        }
    }

    let computed: Vec<OnceLock<PointResult>> = (0..jobs.len()).map(|_| OnceLock::new()).collect();
    let pnr_runs = AtomicU64::new(0);
    let sims = AtomicU64::new(0);
    let configs_built = AtomicU64::new(0);
    let steals = AtomicU64::new(0);
    let batched_solves = AtomicU64::new(0);
    let warm_starts = AtomicU64::new(0);
    let nets_reused = AtomicU64::new(0);
    let nets_rerouted = AtomicU64::new(0);
    let route_expansions = AtomicU64::new(0);

    if !jobs.is_empty() {
        std::thread::scope(|scope| {
            for me in 0..workers {
                let jobs = &jobs;
                let groups = &groups;
                let shards = &shards;
                let configs = &configs;
                let interconnects = &interconnects;
                let app_graphs = &app_graphs;
                let cfg_of_job = &cfg_of_job;
                let computed = &computed;
                let pnr_runs = &pnr_runs;
                let sims = &sims;
                let configs_built = &configs_built;
                let steals = &steals;
                let batched_solves = &batched_solves;
                let warm_starts = &warm_starts;
                let nets_reused = &nets_reused;
                let nets_rerouted = &nets_rerouted;
                let route_expansions = &route_expansions;
                scope.spawn(move || {
                    if obs::trace_on() {
                        obs::span::label_thread(&format!("dse-worker-{me}"));
                    }
                    let mut scratch = RouterScratch::new();
                    while let Some(g) = next_group(shards, me, steals) {
                        let group_t0 = progress.map(|_| obs::now_ns());
                        let group = &groups[g];
                        let slot = cfg_of_job[group[0]];
                        let ic = interconnects[slot].get_or_init(|| {
                            let (ic, built) = ics.interconnect(configs[slot]);
                            if built {
                                configs_built.fetch_add(1, Ordering::Relaxed);
                            }
                            ic
                        });
                        // Warm runs only: look up each job's nearest
                        // donor artifact up front, so the cold
                        // remainder still shares one batched solve. On
                        // a cold run every slot is `None` and the group
                        // takes exactly the historical path.
                        let donors: Vec<Option<Arc<PnrArtifact>>> = group
                            .iter()
                            .map(|&i| {
                                warm.and_then(|w| {
                                    w.best_donor(&jobs[i].key, MAX_DONOR_DISTANCE).map(
                                        |(d, _, art)| {
                                            obs::event(spans::DONOR_PICK, d as u64, i as u64);
                                            art
                                        },
                                    )
                                })
                            })
                            .collect();
                        let cold_members: Vec<usize> = group
                            .iter()
                            .zip(&donors)
                            .filter(|(_, donor)| donor.is_none())
                            .map(|(&i, _)| i)
                            .collect();
                        obs::event(
                            spans::PLACE_BATCH,
                            group.len() as u64,
                            cold_members.len() as u64,
                        );
                        // Phase 1 for every cold job in the group: pack
                        // + problem construction.
                        let prepared: Vec<crate::pnr::PreparedPoint> = cold_members
                            .iter()
                            .map(|&i| {
                                let job = jobs[i];
                                let app = &app_graphs[job.key.app.as_str()];
                                prepare_point(ic, app, &job.flow)
                            })
                            .collect();
                        // Phase 2: ONE batched global solve for the
                        // group's cold remainder (skipped entirely when
                        // every member found a donor).
                        let solved = if prepared.is_empty() {
                            Vec::new()
                        } else {
                            let batch: Vec<PlacementInstance> = prepared
                                .iter()
                                .map(|pp| PlacementInstance {
                                    problem: &pp.problem,
                                    xs0: &pp.xs0,
                                    ys0: &pp.ys0,
                                })
                                .collect();
                            batched_solves.fetch_add(1, Ordering::Relaxed);
                            let mut _gp = obs::stage(spans::GLOBAL_PLACE);
                            _gp.args(batch.len() as u64, 0);
                            let solved = placer.place_batch(&batch);
                            assert_eq!(
                                solved.len(),
                                cold_members.len(),
                                "placer `{}` returned {} results for a {}-job batch",
                                placer.name(),
                                solved.len(),
                                cold_members.len()
                            );
                            solved
                        };
                        // Phase 3 per job, in group order. Cold jobs:
                        // legalize → SA → route → STA, reusing the
                        // worker's router scratch. Warm jobs: seeded
                        // placement + routed-tree replay
                        // (`run_flow_warm`), with a private scratch
                        // solve as fallback. Then the elastic
                        // simulation of the routed point under the
                        // job's fabric; routed points deposit their own
                        // artifact for later neighbors.
                        let mut cold_iter = prepared.iter().zip(&solved);
                        for (&i, donor) in group.iter().zip(&donors) {
                            let job = jobs[i];
                            let app = &app_graphs[job.key.app.as_str()];
                            let mut _job_span = obs::span(spans::JOB);
                            _job_span.args(i as u64, donor.is_some() as u64);
                            let mut warmed = false;
                            pnr_runs.fetch_add(1, Ordering::Relaxed);
                            let flow = match donor {
                                Some(art) => {
                                    let net_paths = {
                                        let _s = obs::span(spans::ARTIFACT_RESOLVE);
                                        art.resolve(ic.graph(job.flow.bit_width))
                                    };
                                    let seed =
                                        WarmSeed { placement: &art.placement, net_paths };
                                    match run_flow_warm(ic, app, &job.flow, &seed, &mut scratch)
                                    {
                                        Ok((flow, reuse)) => {
                                            warmed = true;
                                            warm_starts.fetch_add(1, Ordering::Relaxed);
                                            nets_reused.fetch_add(
                                                reuse.nets_reused as u64,
                                                Ordering::Relaxed,
                                            );
                                            nets_rerouted.fetch_add(
                                                reuse.nets_rerouted as u64,
                                                Ordering::Relaxed,
                                            );
                                            Ok(flow)
                                        }
                                        // Donor unusable (e.g. the
                                        // array shrank below the app,
                                        // or replay could not
                                        // converge): full scratch
                                        // solve, not counted as a warm
                                        // start.
                                        Err(_) => {
                                            let pp = prepare_point(ic, app, &job.flow);
                                            batched_solves.fetch_add(1, Ordering::Relaxed);
                                            let solo = {
                                                let mut _gp = obs::stage(spans::GLOBAL_PLACE);
                                                _gp.args(1, 1);
                                                placer.place_batch(&[PlacementInstance {
                                                    problem: &pp.problem,
                                                    xs0: &pp.xs0,
                                                    ys0: &pp.ys0,
                                                }])
                                            };
                                            finish_flow_scratch(
                                                ic,
                                                &pp,
                                                &solo[0].0,
                                                &solo[0].1,
                                                &job.flow,
                                                &mut scratch,
                                            )
                                        }
                                    }
                                }
                                None => {
                                    let (pp, (xs, ys)) =
                                        cold_iter.next().expect("one solve per cold member");
                                    finish_flow_scratch(ic, pp, xs, ys, &job.flow, &mut scratch)
                                }
                            };
                            let result = match flow {
                                Ok(flow) => {
                                    route_expansions.fetch_add(
                                        flow.routing.route_expansions,
                                        Ordering::Relaxed,
                                    );
                                    let mut r = PointResult::from_flow(&flow);
                                    sims.fetch_add(1, Ordering::Relaxed);
                                    {
                                        let _s = obs::stage(spans::SIM);
                                        simulate_point(app, &flow, job, ic, &mut r);
                                    }
                                    if let Some(w) = warm {
                                        w.insert(
                                            job.key.clone(),
                                            artifact_of(ic, job.flow.bit_width, &flow),
                                        );
                                    }
                                    r
                                }
                                Err(_) => PointResult::unroutable(),
                            };
                            let _ = computed[i].set(result);
                            if let Some(p) = progress {
                                p.job_finished(warmed);
                            }
                        }
                        if let (Some(p), Some(t0)) = (progress, group_t0) {
                            p.add_busy(me, obs::now_ns().saturating_sub(t0));
                        }
                    }
                });
            }
        });
    }

    let stats = EngineStats {
        pnr_runs: pnr_runs.into_inner(),
        sims: sims.into_inner(),
        configs_built: configs_built.into_inner(),
        steals: steals.into_inner(),
        batched_solves: batched_solves.into_inner(),
        warm_starts: warm_starts.into_inner(),
        nets_reused: nets_reused.into_inner(),
        nets_rerouted: nets_rerouted.into_inner(),
        route_expansions: route_expansions.into_inner(),
        ..Default::default()
    };
    let results = computed
        .into_iter()
        .map(|cell| cell.into_inner().expect("cold job executed"))
        .collect();
    let interconnects = configs
        .iter()
        .zip(interconnects)
        .filter_map(|(cfg, cell)| cell.into_inner().map(|ic| (cfg.descriptor(), ic)))
        .collect();
    ColdOutcome { results, stats, interconnects }
}

/// Per-(config, fabric) area metrics for a spec, config-major in
/// enumeration order. Cheap (no PnR), so never cached; deterministic,
/// so warm and cold runs render identical tables. `prebuilt` offers
/// interconnects a cold run already froze (by
/// `InterconnectConfig::descriptor()`); anything else comes from `ics`.
pub fn area_points(
    spec: &SweepSpec,
    prebuilt: &[(String, Arc<Interconnect>)],
    ics: &dyn InterconnectSource,
) -> Result<Vec<AreaPoint>, String> {
    let built: BTreeMap<&str, &Arc<Interconnect>> =
        prebuilt.iter().map(|(d, ic)| (d.as_str(), ic)).collect();
    let model = AreaModel::default();
    let fabrics = spec.fabric_axis();
    let mut areas = Vec::new();
    for cfg in spec.configs()? {
        let ic = match built.get(cfg.descriptor().as_str()) {
            Some(ic) => Arc::clone(ic),
            None => ics.interconnect(&cfg).0,
        };
        for &fb in &fabrics {
            let tile = area_of(&ic, &model, fb.area_mode()).interior_tile(&ic);
            areas.push(AreaPoint {
                config: cfg.descriptor(),
                fabric: fb.label(),
                tracks: cfg.num_tracks,
                sb_sides: cfg.sb_core_sides.0,
                cb_sides: cfg.cb_core_sides.0,
                sb_um2: tile.sb_um2,
                cb_um2: tile.cb_um2,
            });
        }
    }
    Ok(areas)
}

/// Everything one sweep produced.
#[derive(Clone, Debug)]
pub struct SweepOutcome {
    pub name: String,
    /// One entry per job, in the spec's canonical enumeration order.
    pub points: Vec<(Job, PointResult)>,
    /// Per-config area metrics (when `spec.area`), in config order.
    pub areas: Vec<AreaPoint>,
    pub stats: EngineStats,
}

/// Run one sweep against a caller-owned cache — the engine-*handle*
/// form: partition the job list into cache hits and misses, execute the
/// misses through [`execute_jobs`], merge in canonical order, and
/// persist the cache if anything new was computed. [`DseEngine::run`]
/// is exactly this over the engine's own cache and a [`BuildFresh`]
/// source; the service calls the pieces directly so it can interleave
/// its request coalescing between partition and execution.
pub fn run_sweep(
    spec: &SweepSpec,
    placer: &(dyn GlobalPlacer + Sync),
    workers: usize,
    cache: &mut ResultCache,
    ics: &dyn InterconnectSource,
) -> Result<SweepOutcome, String> {
    run_sweep_with(spec, placer, workers, cache, ics, None)
}

/// [`run_sweep`], optionally threading a warm-start artifact store
/// through the cold execution ([`execute_jobs_with`]). `warm: None` is
/// exactly [`run_sweep`]; `warm: Some(..)` warm-starts cold points from
/// their nearest donors and persists the (possibly grown) artifact
/// store alongside the result cache whenever new PnR ran.
pub fn run_sweep_with(
    spec: &SweepSpec,
    placer: &(dyn GlobalPlacer + Sync),
    workers: usize,
    cache: &mut ResultCache,
    ics: &dyn InterconnectSource,
    warm: Option<&PnrArtifactCache>,
) -> Result<SweepOutcome, String> {
    let jobs = spec.jobs(placer.name())?;
    let mut stats = EngineStats { jobs: jobs.len() as u64, ..Default::default() };

    // Partition into cache hits and cold misses.
    let mut hits: Vec<Option<PointResult>> = Vec::with_capacity(jobs.len());
    let mut cold_jobs: Vec<&Job> = Vec::new();
    for (idx, job) in jobs.iter().enumerate() {
        match cache.get(&job.key) {
            Some(r) => {
                stats.cache_hits += 1;
                obs::event(spans::CACHE_HIT, idx as u64, 0);
                hits.push(Some(r.clone()));
            }
            None => {
                obs::event(spans::CACHE_MISS, idx as u64, 0);
                hits.push(None);
                cold_jobs.push(job);
            }
        }
    }

    let cold = execute_jobs_with(&cold_jobs, workers, placer, ics, warm);
    stats.absorb(&cold.stats);

    // Merge in canonical job order; feed new results to the cache.
    // Misses appear in `cold_jobs` in job order, so results zip back by
    // sequential take.
    let mut cold_results = cold.results.into_iter();
    let mut points = Vec::with_capacity(jobs.len());
    for (i, job) in jobs.into_iter().enumerate() {
        let result = match hits[i].take() {
            Some(r) => r,
            None => {
                let r = cold_results.next().expect("one result per cold job");
                cache.insert(job.key.clone(), r.clone());
                r
            }
        };
        points.push((job, result));
    }
    if stats.pnr_runs > 0 {
        cache.save()?;
        if let Some(w) = warm {
            w.save()?;
        }
    }

    let areas =
        if spec.area { area_points(spec, &cold.interconnects, ics)? } else { Vec::new() };

    if obs::metrics_on() {
        super::report::publish_engine_stats(&stats);
    }
    Ok(SweepOutcome { name: spec.name.clone(), points, areas, stats })
}

/// The DSE engine: owns the options and the result cache, so successive
/// sweeps in one process (e.g. the five figure sweeps) share hits.
pub struct DseEngine {
    opts: EngineOptions,
    cache: ResultCache,
    /// Warm-start artifact store; `Some` iff `opts.warm_start`.
    artifacts: Option<PnrArtifactCache>,
    lifetime: EngineStats,
}

/// The engine's artifact store for its options: file-backed next to the
/// result cache when both `warm_start` and `cache_path` are set,
/// in-memory when only `warm_start` is, absent otherwise.
fn artifacts_for(opts: &EngineOptions) -> Result<Option<PnrArtifactCache>, String> {
    if !opts.warm_start {
        return Ok(None);
    }
    Ok(Some(match &opts.cache_path {
        Some(path) => PnrArtifactCache::at(&artifact_path_for(path))?,
        None => PnrArtifactCache::in_memory(),
    }))
}

impl DseEngine {
    pub fn new(opts: EngineOptions) -> Result<DseEngine, String> {
        let cache = match &opts.cache_path {
            Some(path) => ResultCache::at(path)?,
            None => ResultCache::in_memory(),
        };
        let artifacts = artifacts_for(&opts)?;
        Ok(DseEngine { opts, cache, artifacts, lifetime: EngineStats::default() })
    }

    /// Engine with default options and an unbacked cache.
    pub fn in_memory() -> DseEngine {
        DseEngine {
            opts: EngineOptions::default(),
            cache: ResultCache::in_memory(),
            artifacts: None,
            lifetime: EngineStats::default(),
        }
    }

    /// Engine over a caller-provided cache (e.g. a
    /// [`ResultCache::snapshot`] of the service's shared cache — the
    /// figure drivers take `&mut DseEngine`, so the service runs them on
    /// a snapshot-backed engine and merges new entries back). The
    /// artifact store (if `opts.warm_start`) stays in-memory here: a
    /// snapshot-backed engine must not race the owner's artifact file.
    pub fn with_cache(opts: EngineOptions, cache: ResultCache) -> DseEngine {
        let artifacts = opts.warm_start.then(PnrArtifactCache::in_memory);
        DseEngine { opts, cache, artifacts, lifetime: EngineStats::default() }
    }

    pub fn cache(&self) -> &ResultCache {
        &self.cache
    }

    /// The warm-start artifact store, when `opts.warm_start` is on.
    pub fn artifacts(&self) -> Option<&PnrArtifactCache> {
        self.artifacts.as_ref()
    }

    /// Counters accumulated over every `run` of this engine.
    pub fn lifetime_stats(&self) -> &EngineStats {
        &self.lifetime
    }

    /// Run one sweep. Cold points fan out over the worker pool; warm
    /// points come from the cache; the cache file (if any) is updated
    /// when new results were computed.
    pub fn run(
        &mut self,
        spec: &SweepSpec,
        placer: &(dyn GlobalPlacer + Sync),
    ) -> Result<SweepOutcome, String> {
        let out = run_sweep_with(
            spec,
            placer,
            self.opts.workers,
            &mut self.cache,
            &BuildFresh,
            self.artifacts.as_ref(),
        )?;
        self.lifetime.absorb(&out.stats);
        Ok(out)
    }
}

/// Pop the next job group: own shard front first, then steal from the
/// back of the most-loaded victim (re-scanning on races until every
/// shard is observed empty).
fn next_group(shards: &[Mutex<VecDeque<usize>>], me: usize, steals: &AtomicU64) -> Option<usize> {
    if let Some(i) = shards[me].lock().expect("shard").pop_front() {
        return Some(i);
    }
    loop {
        let mut victim = None;
        let mut victim_len = 0;
        for (v, shard) in shards.iter().enumerate() {
            if v == me {
                continue;
            }
            let len = shard.lock().expect("shard").len();
            if len > victim_len {
                victim_len = len;
                victim = Some(v);
            }
        }
        let v = victim?;
        if let Some(i) = shards[v].lock().expect("shard").pop_back() {
            steals.fetch_add(1, Ordering::Relaxed);
            return Some(i);
        }
        // Raced with the victim draining its shard; rescan.
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::InterconnectConfig;
    use crate::pnr::{FlowParams, NativePlacer, SaParams};

    fn quick_spec() -> SweepSpec {
        SweepSpec {
            name: "exec-test".into(),
            base: InterconnectConfig { mem_column_period: 3, ..Default::default() },
            tracks: vec![4, 5],
            apps: vec!["pointwise".into()],
            seeds: vec![1],
            flow: FlowParams {
                sa: SaParams { moves_per_node: 4, ..Default::default() },
                ..Default::default()
            },
            ..Default::default()
        }
    }

    #[test]
    fn cold_runs_count_pnr_and_warm_runs_do_not() {
        let mut engine = DseEngine::in_memory();
        let cold = engine.run(&quick_spec(), &NativePlacer::default()).unwrap();
        assert_eq!(cold.points.len(), 2);
        assert_eq!(cold.stats.pnr_runs, 2);
        assert_eq!(cold.stats.sims, 2, "every routed cold point simulates");
        assert_eq!(cold.stats.cache_hits, 0);
        assert!(cold.stats.configs_built <= 2);
        // Two distinct configs ⇒ two job groups ⇒ two batched solves.
        assert_eq!(cold.stats.batched_solves, 2);
        let warm = engine.run(&quick_spec(), &NativePlacer::default()).unwrap();
        assert_eq!(warm.stats.pnr_runs, 0);
        assert_eq!(warm.stats.sims, 0, "warm re-run must skip all simulations");
        assert_eq!(warm.stats.cache_hits, 2);
        assert_eq!(warm.stats.batched_solves, 0);
        for ((ja, ra), (jb, rb)) in cold.points.iter().zip(&warm.points) {
            assert_eq!(ja.key, jb.key);
            assert_eq!(ra, rb);
        }
        assert_eq!(engine.lifetime_stats().pnr_runs, 2);
        assert_eq!(engine.lifetime_stats().jobs, 4);
    }

    #[test]
    fn worker_count_does_not_change_results() {
        let spec = quick_spec();
        let run_with = |workers: usize| {
            let mut e = DseEngine::new(EngineOptions {
                workers,
                cache_path: None,
                warm_start: false,
            })
            .unwrap();
            e.run(&spec, &NativePlacer::default()).unwrap()
        };
        let sequential = run_with(1);
        let sharded = run_with(4);
        assert_eq!(sequential.points.len(), sharded.points.len());
        for ((ja, ra), (jb, rb)) in sequential.points.iter().zip(&sharded.points) {
            assert_eq!(ja.key, jb.key);
            assert_eq!(ra, rb);
        }
    }

    #[test]
    fn batched_placer_matches_scalar_loop_per_group() {
        use crate::pnr::BatchedNativePlacer;
        // NativePlacer takes the trait's default place_batch (a
        // sequential optimize loop); BatchedNativePlacer vectorizes it.
        // Same spec, both backends: every point must be bit-identical,
        // and the batched run must still do one solve per config group.
        let spec = SweepSpec {
            apps: vec!["pointwise".into(), "gaussian".into()],
            seeds: vec![1, 2],
            ..quick_spec()
        };
        let mut scalar_engine = DseEngine::in_memory();
        let scalar = scalar_engine.run(&spec, &NativePlacer::default()).unwrap();
        let mut batched_engine = DseEngine::in_memory();
        let batched = batched_engine.run(&spec, &BatchedNativePlacer::default()).unwrap();
        assert_eq!(scalar.points.len(), 8);
        // 2 configs ⇒ 2 groups of 4 problems each, regardless of backend.
        assert_eq!(scalar.stats.batched_solves, 2);
        assert_eq!(batched.stats.batched_solves, 2);
        for ((ja, ra), (jb, rb)) in scalar.points.iter().zip(&batched.points) {
            assert_eq!(ja.key, jb.key, "same placer name, same keys");
            assert_eq!(ra, rb);
            assert_eq!(ra.critical_path_ps.to_bits(), rb.critical_path_ps.to_bits());
        }
    }

    #[test]
    fn area_only_sweep_runs_no_pnr() {
        let spec = SweepSpec {
            name: "area-only".into(),
            base: InterconnectConfig {
                width: 6,
                height: 6,
                mem_column_period: 0,
                ..Default::default()
            },
            tracks: vec![2, 3, 4],
            area: true,
            ..Default::default()
        };
        let mut engine = DseEngine::in_memory();
        let out = engine.run(&spec, &NativePlacer::default()).unwrap();
        assert!(out.points.is_empty());
        assert_eq!(out.stats.pnr_runs, 0);
        assert_eq!(out.stats.sims, 0);
        assert_eq!(out.areas.len(), 3);
        assert_eq!(out.areas[0].tracks, 2);
        assert_eq!(out.areas[0].fabric, "static");
        // More tracks ⇒ more SB area (Fig. 10's monotonicity).
        assert!(out.areas[2].sb_um2 > out.areas[0].sb_um2);
    }

    #[test]
    fn fabric_axis_simulates_each_point_and_caches_distinctly() {
        use crate::sim::FabricKind;
        let spec = SweepSpec {
            fabrics: vec![FabricKind::Static, FabricKind::RvFullFifo { depth: 2 }],
            ..quick_spec()
        };
        let mut engine = DseEngine::in_memory();
        let cold = engine.run(&spec, &NativePlacer::default()).unwrap();
        // 2 tracks × 2 fabrics × 1 app × 1 seed.
        assert_eq!(cold.points.len(), 4);
        assert_eq!(cold.stats.pnr_runs, 4);
        assert_eq!(cold.stats.sims, 4);
        for (job, r) in &cold.points {
            assert!(r.routed, "{:?}", job.key);
            assert!(r.sim_cycles > 0 && r.sim_tokens > 0, "{:?}", job.key);
            assert_eq!(r.stall_cycles, r.sim_cycles - r.sim_tokens);
            assert!(r.throughput() > 0.0);
            // Fabric rows are keyed distinctly; static stays bare.
            assert_eq!(
                job.key.config.0.contains("fabric="),
                job.fabric != FabricKind::Static,
                "{}",
                job.key.config
            );
        }
        // Points come tracks-major, fabric-minor: per track, the
        // elastic fabric can only match or beat the static one (deeper
        // channels never reduce throughput).
        for pair in cold.points.chunks(2) {
            let (stat, rv) = (&pair[0].1, &pair[1].1);
            assert!(
                rv.sim_cycles <= stat.sim_cycles,
                "rv {} vs static {}",
                rv.sim_cycles,
                stat.sim_cycles
            );
        }
        // Warm re-run: zero PnR *and* zero simulations.
        let warm = engine.run(&spec, &NativePlacer::default()).unwrap();
        assert_eq!(warm.stats.pnr_runs, 0);
        assert_eq!(warm.stats.sims, 0);
        assert_eq!(warm.stats.cache_hits, 4);
        for ((ja, ra), (jb, rb)) in cold.points.iter().zip(&warm.points) {
            assert_eq!(ja.key, jb.key);
            assert_eq!(ra, rb);
        }
    }

    /// A counting source that serves every config from one pre-frozen
    /// `Arc` — execute_jobs must produce bit-identical results whether
    /// interconnects are fresh or warm, and must not count warm serves
    /// as builds.
    struct WarmSource {
        ic: Arc<Interconnect>,
        serves: AtomicU64,
    }

    impl InterconnectSource for WarmSource {
        fn interconnect(&self, cfg: &InterconnectConfig) -> (Arc<Interconnect>, bool) {
            assert_eq!(cfg.descriptor(), self.ic.descriptor);
            self.serves.fetch_add(1, Ordering::Relaxed);
            (Arc::clone(&self.ic), false)
        }
    }

    #[test]
    fn warm_interconnect_source_is_bit_identical_and_not_counted_as_build() {
        let spec = SweepSpec { tracks: vec![4], seeds: vec![1, 2], ..quick_spec() };
        let jobs = spec.jobs("native-gd").unwrap();
        let job_refs: Vec<&Job> = jobs.iter().collect();
        let fresh = execute_jobs(&job_refs, 1, &NativePlacer::default(), &BuildFresh);
        assert_eq!(fresh.stats.configs_built, 1);
        assert_eq!(fresh.interconnects.len(), 1);

        let warm_src = WarmSource {
            ic: Arc::clone(&fresh.interconnects[0].1),
            serves: AtomicU64::new(0),
        };
        let warm = execute_jobs(&job_refs, 2, &NativePlacer::default(), &warm_src);
        assert_eq!(warm.stats.configs_built, 0, "warm serves are not builds");
        assert_eq!(warm_src.serves.load(Ordering::Relaxed), 1, "one serve per unique config");
        assert_eq!(warm.stats.pnr_runs, 2);
        assert_eq!(fresh.results, warm.results);
    }

    #[test]
    fn warm_start_sweep_reuses_neighbor_artifacts_and_stays_close() {
        use crate::sim::FabricKind;
        // Tracks × fabric axes: the fabric neighbor is the *same* PnR
        // problem (distance 1), so the nearest-neighbor chain guarantees
        // at least one full-replay warm start; tracks neighbors reuse
        // partially (Wilton's track permutation shifts through-SB
        // paths).
        let spec = SweepSpec {
            fabrics: vec![FabricKind::Static, FabricKind::RvFullFifo { depth: 2 }],
            ..quick_spec()
        };
        let mut cold_engine = DseEngine::in_memory();
        let cold = cold_engine.run(&spec, &NativePlacer::default()).unwrap();
        let mut warm_engine = DseEngine::new(EngineOptions {
            workers: 1,
            cache_path: None,
            warm_start: true,
        })
        .unwrap();
        let warm = warm_engine.run(&spec, &NativePlacer::default()).unwrap();
        assert_eq!(warm.points.len(), cold.points.len());
        assert!(warm.stats.warm_starts > 0, "neighbors must warm-start: {:?}", warm.stats);
        assert!(warm.stats.nets_reused > 0, "fabric twin must replay trees: {:?}", warm.stats);
        assert_eq!(warm.stats.pnr_runs, 4, "warm starts still count as PnR runs");
        assert_eq!(warm_engine.artifacts().unwrap().len(), 4, "every routed point deposits");
        for ((ja, ra), (jb, rb)) in cold.points.iter().zip(&warm.points) {
            assert_eq!(ja.key, jb.key, "warm-start must not reorder the outcome");
            assert!(rb.routed, "{:?}", jb.key);
            // Acceptance bar: a warm-started point's critical path stays
            // within 5% of the scratch result for the same key.
            assert!(
                rb.critical_path_ps <= ra.critical_path_ps * 1.05,
                "{:?}: warm {} vs scratch {}",
                jb.key,
                rb.critical_path_ps,
                ra.critical_path_ps
            );
        }
    }

    #[test]
    fn sweep_progress_tracks_cold_completions() {
        let spec = quick_spec();
        let jobs = spec.jobs("native-gd").unwrap();
        let job_refs: Vec<&Job> = jobs.iter().collect();
        let progress = SweepProgress::new();
        progress.begin(jobs.len() as u64, 0, 0);
        let out = execute_jobs_obs(
            &job_refs,
            2,
            &NativePlacer::default(),
            &BuildFresh,
            None,
            Some(&progress),
        );
        assert_eq!(out.results.len(), jobs.len());
        let snap = progress.snapshot();
        assert_eq!(snap.jobs_total, 2);
        assert_eq!(snap.jobs_done, 2);
        assert_eq!(snap.cold_total, 2);
        assert_eq!(snap.cold_done, 2);
        assert_eq!(snap.cache_hits, 0);
        assert_eq!(snap.warm_starts, 0);
        assert_eq!(snap.worker_busy_ns.len(), 2);
        assert!(snap.worker_busy_ns.iter().sum::<u64>() > 0, "workers were busy");
        let msg = snap.message();
        assert!(msg.starts_with("progress: 2/2 jobs (0 cached, 0 coalesced, 2/2 cold)"), "{msg}");
        assert!(msg.contains("util w0="), "{msg}");
    }

    #[test]
    fn progress_message_counts_hits_and_warm_starts() {
        let p = SweepProgress::new();
        p.begin(10, 4, 1);
        p.job_finished(true);
        p.job_finished(false);
        let snap = p.snapshot();
        assert_eq!(snap.jobs_done, 7);
        assert_eq!(snap.cold_total, 5);
        assert_eq!(snap.cold_done, 2);
        let msg = snap.message();
        assert_eq!(msg, "progress: 7/10 jobs (4 cached, 1 coalesced, 2/5 cold, 1 warm-started)");
    }

    #[test]
    fn run_sweep_handle_matches_engine_over_shared_cache() {
        // The engine-handle form against a caller-owned cache is the
        // same computation as DseEngine::run — and a second call over
        // the *same* borrowed cache is fully warm.
        let spec = quick_spec();
        let mut cache = ResultCache::in_memory();
        let cold =
            run_sweep(&spec, &NativePlacer::default(), 2, &mut cache, &BuildFresh).unwrap();
        assert_eq!(cold.stats.pnr_runs, 2);
        assert_eq!(cache.len(), 2);
        let warm =
            run_sweep(&spec, &NativePlacer::default(), 2, &mut cache, &BuildFresh).unwrap();
        assert_eq!(warm.stats.pnr_runs, 0);
        assert_eq!(warm.stats.cache_hits, 2);
        let mut engine = DseEngine::in_memory();
        let reference = engine.run(&spec, &NativePlacer::default()).unwrap();
        for ((ja, ra), (jb, rb)) in reference.points.iter().zip(&warm.points) {
            assert_eq!(ja.key, jb.key);
            assert_eq!(ra, rb);
        }
    }
}
