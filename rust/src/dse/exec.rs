//! The sharded sweep executor: a fixed worker pool over per-worker job
//! deques with work stealing. Each worker owns reusable
//! [`RouterScratch`] buffers (PathFinder cost/visited/heap arrays
//! allocated once, reset per route); each interconnect configuration is
//! built — and its routing graphs frozen to immutable CSR
//! [`crate::ir::CompiledGraph`]s — exactly once, then shared across
//! workers via `Arc`. Results are keyed and cached through
//! [`ResultCache`], so a warm re-run of the same spec performs zero PnR
//! calls (observable via [`EngineStats::pnr_runs`]).
//!
//! Determinism: a job's result depends only on its resolved
//! `(config, app, seed)` content — never on the worker count, the
//! steal pattern, or cache temperature — and the outcome lists points in
//! the spec's canonical enumeration order, so sharded runs are
//! bit-identical to a sequential (`workers: 1`) baseline.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::area::{area_of, AreaModel, FabricMode};
use crate::dsl::create_uniform_interconnect;
use crate::ir::Interconnect;
use crate::pnr::{run_flow_scratch, GlobalPlacer, RouterScratch};

use super::cache::ResultCache;
use super::spec::{app_by_name, AreaPoint, Job, PointResult, SweepSpec};

/// Executor tuning.
#[derive(Clone, Debug, Default)]
pub struct EngineOptions {
    /// Worker threads; `0` ⇒ one per available core.
    pub workers: usize,
    /// JSON cache backing file (`dse_cache.json` by convention); `None`
    /// ⇒ in-memory cache only.
    pub cache_path: Option<std::path::PathBuf>,
}

/// Counters for one `run` (and, accumulated, for an engine's lifetime).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct EngineStats {
    /// Jobs in the (deduplicated) list.
    pub jobs: u64,
    /// Jobs answered from the cache.
    pub cache_hits: u64,
    /// Actual PnR flow executions (cold jobs). Zero on a warm re-run.
    pub pnr_runs: u64,
    /// Interconnects built + frozen (≤ unique configs among cold jobs).
    pub configs_built: u64,
    /// Jobs a worker took from another worker's shard.
    pub steals: u64,
}

impl EngineStats {
    fn absorb(&mut self, other: &EngineStats) {
        self.jobs += other.jobs;
        self.cache_hits += other.cache_hits;
        self.pnr_runs += other.pnr_runs;
        self.configs_built += other.configs_built;
        self.steals += other.steals;
    }
}

/// Everything one sweep produced.
#[derive(Clone, Debug)]
pub struct SweepOutcome {
    pub name: String,
    /// One entry per job, in the spec's canonical enumeration order.
    pub points: Vec<(Job, PointResult)>,
    /// Per-config area metrics (when `spec.area`), in config order.
    pub areas: Vec<AreaPoint>,
    pub stats: EngineStats,
}

/// The DSE engine: owns the options and the result cache, so successive
/// sweeps in one process (e.g. the five figure sweeps) share hits.
pub struct DseEngine {
    opts: EngineOptions,
    cache: ResultCache,
    lifetime: EngineStats,
}

impl DseEngine {
    pub fn new(opts: EngineOptions) -> Result<DseEngine, String> {
        let cache = match &opts.cache_path {
            Some(path) => ResultCache::at(path)?,
            None => ResultCache::in_memory(),
        };
        Ok(DseEngine { opts, cache, lifetime: EngineStats::default() })
    }

    /// Engine with default options and an unbacked cache.
    pub fn in_memory() -> DseEngine {
        DseEngine {
            opts: EngineOptions::default(),
            cache: ResultCache::in_memory(),
            lifetime: EngineStats::default(),
        }
    }

    pub fn cache(&self) -> &ResultCache {
        &self.cache
    }

    /// Counters accumulated over every `run` of this engine.
    pub fn lifetime_stats(&self) -> &EngineStats {
        &self.lifetime
    }

    fn worker_count(&self) -> usize {
        let configured = if self.opts.workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.opts.workers
        };
        configured.max(1)
    }

    /// Run one sweep. Cold points fan out over the worker pool; warm
    /// points come from the cache; the cache file (if any) is updated
    /// when new results were computed.
    pub fn run(
        &mut self,
        spec: &SweepSpec,
        placer: &(dyn GlobalPlacer + Sync),
    ) -> Result<SweepOutcome, String> {
        let jobs = spec.jobs(placer.name())?;
        let mut stats = EngineStats { jobs: jobs.len() as u64, ..Default::default() };

        // Partition into cache hits and cold misses.
        let mut hits: Vec<Option<PointResult>> = Vec::with_capacity(jobs.len());
        let mut misses: Vec<usize> = Vec::new();
        for (i, job) in jobs.iter().enumerate() {
            match self.cache.get(&job.key) {
                Some(r) => {
                    stats.cache_hits += 1;
                    hits.push(Some(r.clone()));
                }
                None => {
                    hits.push(None);
                    misses.push(i);
                }
            }
        }

        // Unique configurations among the cold jobs; each is built and
        // frozen lazily by the first worker that needs it and shared via
        // `Arc` from then on.
        let mut cfg_slot: BTreeMap<String, usize> = BTreeMap::new();
        let mut configs: Vec<crate::dsl::InterconnectConfig> = Vec::new();
        let mut cfg_of_job: Vec<usize> = vec![usize::MAX; jobs.len()];
        for &i in &misses {
            let slot = *cfg_slot.entry(jobs[i].key.config.0.clone()).or_insert_with(|| {
                configs.push(jobs[i].cfg.clone());
                configs.len() - 1
            });
            cfg_of_job[i] = slot;
        }
        let interconnects: Vec<OnceLock<Arc<Interconnect>>> =
            (0..configs.len()).map(|_| OnceLock::new()).collect();

        // Resolve each distinct app generator once per run; workers share
        // the graphs read-only (generator construction is not free).
        let mut app_graphs: BTreeMap<String, crate::pnr::AppGraph> = BTreeMap::new();
        for &i in &misses {
            let key = &jobs[i].key.app;
            if !app_graphs.contains_key(key) {
                let app = app_by_name(key).expect("app validated by SweepSpec::jobs");
                app_graphs.insert(key.clone(), app);
            }
        }

        // Shard the cold jobs round-robin; idle workers steal from the
        // back of the most-loaded victim.
        let workers = self.worker_count();
        let shards: Vec<Mutex<VecDeque<usize>>> =
            (0..workers).map(|_| Mutex::new(VecDeque::new())).collect();
        for (k, &i) in misses.iter().enumerate() {
            shards[k % workers].lock().expect("shard").push_back(i);
        }

        let computed: Vec<OnceLock<PointResult>> =
            (0..jobs.len()).map(|_| OnceLock::new()).collect();
        let pnr_runs = AtomicU64::new(0);
        let configs_built = AtomicU64::new(0);
        let steals = AtomicU64::new(0);

        if !misses.is_empty() {
            std::thread::scope(|scope| {
                for me in 0..workers {
                    let jobs = &jobs;
                    let shards = &shards;
                    let configs = &configs;
                    let interconnects = &interconnects;
                    let app_graphs = &app_graphs;
                    let cfg_of_job = &cfg_of_job;
                    let computed = &computed;
                    let pnr_runs = &pnr_runs;
                    let configs_built = &configs_built;
                    let steals = &steals;
                    scope.spawn(move || {
                        let mut scratch = RouterScratch::new();
                        while let Some(i) = next_job(shards, me, steals) {
                            let job = &jobs[i];
                            let slot = cfg_of_job[i];
                            let ic = interconnects[slot].get_or_init(|| {
                                configs_built.fetch_add(1, Ordering::Relaxed);
                                Arc::new(create_uniform_interconnect(&configs[slot]))
                            });
                            let app = &app_graphs[job.key.app.as_str()];
                            pnr_runs.fetch_add(1, Ordering::Relaxed);
                            let result =
                                match run_flow_scratch(ic, app, &job.flow, placer, &mut scratch)
                                {
                                    Ok(flow) => PointResult::from_flow(&flow),
                                    Err(_) => PointResult::unroutable(),
                                };
                            let _ = computed[i].set(result);
                        }
                    });
                }
            });
        }

        stats.pnr_runs = pnr_runs.into_inner();
        stats.configs_built = configs_built.into_inner();
        stats.steals = steals.into_inner();

        // Merge in canonical job order; feed new results to the cache.
        let mut points = Vec::with_capacity(jobs.len());
        for (i, job) in jobs.into_iter().enumerate() {
            let result = match hits[i].take() {
                Some(r) => r,
                None => {
                    let r = computed[i].get().expect("cold job executed").clone();
                    self.cache.insert(job.key.clone(), r.clone());
                    r
                }
            };
            points.push((job, result));
        }
        if stats.pnr_runs > 0 {
            self.cache.save()?;
        }

        // Area metrics per unique config, in enumeration order. Cheap
        // (no PnR), so not cached; deterministic, so warm and cold runs
        // render identical tables. Interconnects the worker pool already
        // froze are reused by their config descriptor.
        let mut areas = Vec::new();
        if spec.area {
            let built: BTreeMap<String, Arc<Interconnect>> = configs
                .iter()
                .zip(&interconnects)
                .filter_map(|(cfg, cell)| {
                    cell.get().map(|ic| (cfg.descriptor(), Arc::clone(ic)))
                })
                .collect();
            let model = AreaModel::default();
            for cfg in spec.configs()? {
                let ic = match built.get(&cfg.descriptor()) {
                    Some(ic) => Arc::clone(ic),
                    None => Arc::new(create_uniform_interconnect(&cfg)),
                };
                let tile = area_of(&ic, &model, FabricMode::Static).interior_tile(&ic);
                areas.push(AreaPoint {
                    config: cfg.descriptor(),
                    tracks: cfg.num_tracks,
                    sb_sides: cfg.sb_core_sides.0,
                    cb_sides: cfg.cb_core_sides.0,
                    sb_um2: tile.sb_um2,
                    cb_um2: tile.cb_um2,
                });
            }
        }

        self.lifetime.absorb(&stats);
        Ok(SweepOutcome { name: spec.name.clone(), points, areas, stats })
    }
}

/// Pop the next job: own shard front first, then steal from the back of
/// the most-loaded victim (re-scanning on races until every shard is
/// observed empty).
fn next_job(shards: &[Mutex<VecDeque<usize>>], me: usize, steals: &AtomicU64) -> Option<usize> {
    if let Some(i) = shards[me].lock().expect("shard").pop_front() {
        return Some(i);
    }
    loop {
        let mut victim = None;
        let mut victim_len = 0;
        for (v, shard) in shards.iter().enumerate() {
            if v == me {
                continue;
            }
            let len = shard.lock().expect("shard").len();
            if len > victim_len {
                victim_len = len;
                victim = Some(v);
            }
        }
        let v = victim?;
        if let Some(i) = shards[v].lock().expect("shard").pop_back() {
            steals.fetch_add(1, Ordering::Relaxed);
            return Some(i);
        }
        // Raced with the victim draining its shard; rescan.
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::InterconnectConfig;
    use crate::pnr::{FlowParams, NativePlacer, SaParams};

    fn quick_spec() -> SweepSpec {
        SweepSpec {
            name: "exec-test".into(),
            base: InterconnectConfig { mem_column_period: 3, ..Default::default() },
            tracks: vec![4, 5],
            apps: vec!["pointwise".into()],
            seeds: vec![1],
            flow: FlowParams {
                sa: SaParams { moves_per_node: 4, ..Default::default() },
                ..Default::default()
            },
            ..Default::default()
        }
    }

    #[test]
    fn cold_runs_count_pnr_and_warm_runs_do_not() {
        let mut engine = DseEngine::in_memory();
        let cold = engine.run(&quick_spec(), &NativePlacer::default()).unwrap();
        assert_eq!(cold.points.len(), 2);
        assert_eq!(cold.stats.pnr_runs, 2);
        assert_eq!(cold.stats.cache_hits, 0);
        assert!(cold.stats.configs_built <= 2);
        let warm = engine.run(&quick_spec(), &NativePlacer::default()).unwrap();
        assert_eq!(warm.stats.pnr_runs, 0);
        assert_eq!(warm.stats.cache_hits, 2);
        for ((ja, ra), (jb, rb)) in cold.points.iter().zip(&warm.points) {
            assert_eq!(ja.key, jb.key);
            assert_eq!(ra, rb);
        }
        assert_eq!(engine.lifetime_stats().pnr_runs, 2);
        assert_eq!(engine.lifetime_stats().jobs, 4);
    }

    #[test]
    fn worker_count_does_not_change_results() {
        let spec = quick_spec();
        let run_with = |workers: usize| {
            let mut e = DseEngine::new(EngineOptions { workers, cache_path: None }).unwrap();
            e.run(&spec, &NativePlacer::default()).unwrap()
        };
        let sequential = run_with(1);
        let sharded = run_with(4);
        assert_eq!(sequential.points.len(), sharded.points.len());
        for ((ja, ra), (jb, rb)) in sequential.points.iter().zip(&sharded.points) {
            assert_eq!(ja.key, jb.key);
            assert_eq!(ra, rb);
        }
    }

    #[test]
    fn area_only_sweep_runs_no_pnr() {
        let spec = SweepSpec {
            name: "area-only".into(),
            base: InterconnectConfig {
                width: 6,
                height: 6,
                mem_column_period: 0,
                ..Default::default()
            },
            tracks: vec![2, 3, 4],
            area: true,
            ..Default::default()
        };
        let mut engine = DseEngine::in_memory();
        let out = engine.run(&spec, &NativePlacer::default()).unwrap();
        assert!(out.points.is_empty());
        assert_eq!(out.stats.pnr_runs, 0);
        assert_eq!(out.areas.len(), 3);
        assert_eq!(out.areas[0].tracks, 2);
        // More tracks ⇒ more SB area (Fig. 10's monotonicity).
        assert!(out.areas[2].sb_um2 > out.areas[0].sb_um2);
    }
}
