//! Application benchmark suite.
//!
//! Dataflow-graph generators standing in for the Halide-compiled image
//! processing and ML applications the paper's CGRAs run (DESIGN.md §3).
//! Each generator produces an [`AppGraph`] whose structure (stencil
//! reuse, adder trees, streaming I/O through memory tiles, fan-out)
//! matches the corresponding real workload's communication pattern —
//! which is what the interconnect experiments measure.

use crate::pnr::app::{AppGraph, AppNodeId, AppOp};

/// Chain of `stages` pointwise ops on one stream: the simplest "does the
/// fabric route at all" workload.
pub fn pointwise(stages: usize) -> AppGraph {
    let mut g = AppGraph::new("pointwise");
    let input = g.mem("in", "stream_in");
    let mut prev = input;
    for i in 0..stages {
        let c = g.add(&format!("c{i}"), AppOp::Const(i as i64 + 1));
        let op = g.alu(&format!("op{i}"), if i % 2 == 0 { "mul" } else { "add" });
        g.wire(prev, op, 0);
        g.wire(c, op, 1);
        prev = op;
    }
    let output = g.mem("out", "stream_out");
    g.wire(prev, output, 0);
    g
}

/// Binary reduction tree over `inputs`, returning the root.
fn adder_tree(g: &mut AppGraph, prefix: &str, mut level: Vec<AppNodeId>) -> AppNodeId {
    let mut depth = 0;
    while level.len() > 1 {
        let mut next = Vec::new();
        for (i, pair) in level.chunks(2).enumerate() {
            if pair.len() == 1 {
                next.push(pair[0]);
                continue;
            }
            let add = g.alu(&format!("{prefix}_add_d{depth}_{i}"), "add");
            g.wire(pair[0], add, 0);
            g.wire(pair[1], add, 1);
            next.push(add);
        }
        level = next;
        depth += 1;
    }
    level[0]
}

/// NxN stencil skeleton: `n-1` line buffers (MEM) feed an NxN window of
/// shift registers; each window element is multiplied by a coefficient
/// and reduced through an adder tree. Models a Halide `convNxN` lowering.
/// Zero coefficients skip their multiplier (like a real compiler would).
fn stencil(name: &str, n: usize, coeffs: &[i64]) -> AppGraph {
    assert_eq!(coeffs.len(), n * n, "{name}: need {n}x{n} coefficients");
    let mut g = AppGraph::new(name);
    let input = g.mem("in", "stream_in");
    // n-1 line buffers give n row streams.
    let mut rows = vec![input];
    for i in 0..n - 1 {
        let lb = g.mem(&format!("lb{i}"), "linebuffer");
        g.wire(rows[i], lb, 0);
        rows.push(lb);
    }
    // Window: each row stream through n-1 registers -> n columns.
    let mut window = Vec::new();
    for (r, &row) in rows.iter().enumerate() {
        let mut prev = row;
        window.push(row);
        for c in 0..n - 1 {
            let reg = g.add(&format!("w{r}{c}"), AppOp::Reg);
            g.wire(prev, reg, 0);
            window.push(reg);
            prev = reg;
        }
    }
    // Multiply by coefficients and reduce.
    let mut products = Vec::new();
    for (i, (&w, &c)) in window.iter().zip(coeffs.iter()).enumerate() {
        if c == 0 {
            continue;
        }
        let k = g.add(&format!("k{i}"), AppOp::Const(c));
        let m = g.alu(&format!("mul{i}"), "mul");
        g.wire(w, m, 0);
        g.wire(k, m, 1);
        products.push(m);
    }
    let sum = adder_tree(&mut g, "t", products);
    let shift = g.alu("norm", "ashr");
    let sh = g.add("shamt", AppOp::Const(4));
    g.wire(sum, shift, 0);
    g.wire(sh, shift, 1);
    let output = g.mem("out", "stream_out");
    g.wire(shift, output, 0);
    g
}

/// 3x3 stencil (kept as the building block for gaussian/resnet).
fn stencil3x3(name: &str, coeffs: [i64; 9]) -> AppGraph {
    stencil(name, 3, &coeffs)
}

/// Gaussian 3x3 blur (binomial coefficients).
pub fn gaussian() -> AppGraph {
    stencil3x3("gaussian", [1, 2, 1, 2, 4, 2, 1, 2, 1])
}

/// Horizontal Sobel derivative (used inside Harris).
fn sobel_products(g: &mut AppGraph, prefix: &str, rows: [AppNodeId; 3], coeffs: [i64; 9]) -> AppNodeId {
    let mut window = Vec::new();
    for (r, &row) in rows.iter().enumerate() {
        let r0 = g.add(&format!("{prefix}_w{r}0"), AppOp::Reg);
        let r1 = g.add(&format!("{prefix}_w{r}1"), AppOp::Reg);
        g.wire(row, r0, 0);
        g.wire(r0, r1, 0);
        window.extend([row, r0, r1]);
    }
    let mut products = Vec::new();
    for (i, (&w, &c)) in window.iter().zip(coeffs.iter()).enumerate() {
        if c == 0 {
            continue;
        }
        let k = g.add(&format!("{prefix}_k{i}"), AppOp::Const(c));
        let m = g.alu(&format!("{prefix}_mul{i}"), "mul");
        g.wire(w, m, 0);
        g.wire(k, m, 1);
        products.push(m);
    }
    adder_tree(g, prefix, products)
}

/// Harris corner detector: Sobel dx/dy, structure-tensor products, and
/// the corner response `det - k*trace^2`. The heaviest stencil app in the
/// suite (matches the paper's Harris benchmark).
pub fn harris() -> AppGraph {
    let mut g = AppGraph::new("harris");
    let input = g.mem("in", "stream_in");
    let lb0 = g.mem("lb0", "linebuffer");
    let lb1 = g.mem("lb1", "linebuffer");
    g.wire(input, lb0, 0);
    g.wire(lb0, lb1, 0);
    let rows = [input, lb0, lb1];
    let gx = sobel_products(&mut g, "gx", rows, [-1, 0, 1, -2, 0, 2, -1, 0, 1]);
    let gy = sobel_products(&mut g, "gy", rows, [1, 2, 1, 0, 0, 0, -1, -2, -1]);
    // Structure tensor entries.
    let ixx = g.alu("ixx", "mul");
    g.wire(gx, ixx, 0);
    g.wire(gx, ixx, 1);
    let iyy = g.alu("iyy", "mul");
    g.wire(gy, iyy, 0);
    g.wire(gy, iyy, 1);
    let ixy = g.alu("ixy", "mul");
    g.wire(gx, ixy, 0);
    g.wire(gy, ixy, 1);
    // det = ixx*iyy - ixy^2 ; trace = ixx + iyy
    let m1 = g.alu("det_l", "mul");
    g.wire(ixx, m1, 0);
    g.wire(iyy, m1, 1);
    let m2 = g.alu("det_r", "mul");
    g.wire(ixy, m2, 0);
    g.wire(ixy, m2, 1);
    let det = g.alu("det", "sub");
    g.wire(m1, det, 0);
    g.wire(m2, det, 1);
    let tr = g.alu("trace", "add");
    g.wire(ixx, tr, 0);
    g.wire(iyy, tr, 1);
    let tr2 = g.alu("trace2", "mul");
    g.wire(tr, tr2, 0);
    g.wire(tr, tr2, 1);
    let k = g.add("k", AppOp::Const(3)); // ~0.05 in fixed point >>6
    let ktr2 = g.alu("ktrace2", "mul");
    g.wire(tr2, ktr2, 0);
    g.wire(k, ktr2, 1);
    let shr = g.add("shr6", AppOp::Const(6));
    let ktr2s = g.alu("ktrace2_s", "ashr");
    g.wire(ktr2, ktr2s, 0);
    g.wire(shr, ktr2s, 1);
    let resp = g.alu("response", "sub");
    g.wire(det, resp, 0);
    g.wire(ktr2s, resp, 1);
    let output = g.mem("out", "stream_out");
    g.wire(resp, output, 0);
    g
}

/// Simplified camera (ISP) pipeline: black-level subtract, demosaic
/// cross-channel mixes, white balance, gamma-ish shift — a wide app with
/// three parallel channel paths.
pub fn camera() -> AppGraph {
    let mut g = AppGraph::new("camera");
    let input = g.mem("in", "stream_in");
    let bl = g.add("black_level", AppOp::Const(16));
    let sub = g.alu("blc", "sub");
    g.wire(input, sub, 0);
    g.wire(bl, sub, 1);
    let lb = g.mem("lb", "linebuffer");
    g.wire(sub, lb, 0);
    let mut channels = Vec::new();
    for (c, chan) in ["r", "g", "b"].iter().enumerate() {
        let r0 = g.add(&format!("{chan}_d0"), AppOp::Reg);
        g.wire(if c % 2 == 0 { sub } else { lb }, r0, 0);
        let w = g.add(&format!("{chan}_gain"), AppOp::Const(20 + c as i64));
        let mul = g.alu(&format!("{chan}_wb"), "mul");
        g.wire(r0, mul, 0);
        g.wire(w, mul, 1);
        let sh = g.add(&format!("{chan}_shamt"), AppOp::Const(4));
        let gam = g.alu(&format!("{chan}_gamma"), "ashr");
        g.wire(mul, gam, 0);
        g.wire(sh, gam, 1);
        channels.push(gam);
    }
    // Luma combine: (r + 2g + b) >> 2
    let g2 = g.add("g2", AppOp::Const(2));
    let gm = g.alu("g_x2", "mul");
    g.wire(channels[1], gm, 0);
    g.wire(g2, gm, 1);
    let s1 = g.alu("rg", "add");
    g.wire(channels[0], s1, 0);
    g.wire(gm, s1, 1);
    let s2 = g.alu("rgb", "add");
    g.wire(s1, s2, 0);
    g.wire(channels[2], s2, 1);
    let sh = g.add("lshamt", AppOp::Const(2));
    let luma = g.alu("luma", "ashr");
    g.wire(s2, luma, 0);
    g.wire(sh, luma, 1);
    let out_rgb = g.mem("out_rgb", "stream_out");
    g.wire(s2, out_rgb, 1); // also stream the un-shifted sum
    let output = g.mem("out", "stream_out");
    g.wire(luma, output, 0);
    g
}

/// `n x n` output-stationary matmul tile: MAC grid with row/column
/// broadcast — the highest-fan-out app in the suite.
pub fn matmul(n: usize) -> AppGraph {
    let mut g = AppGraph::new("matmul");
    let a_rows: Vec<AppNodeId> =
        (0..n).map(|i| g.mem(&format!("a_row{i}"), "stream_in")).collect();
    let b_cols: Vec<AppNodeId> =
        (0..n).map(|j| g.mem(&format!("b_col{j}"), "stream_in")).collect();
    for i in 0..n {
        for j in 0..n {
            let mul = g.alu(&format!("mul_{i}_{j}"), "mul");
            g.wire(a_rows[i], mul, 0);
            g.wire(b_cols[j], mul, 1);
            let mac = g.alu(&format!("mac_{i}_{j}"), "mac");
            g.wire(mul, mac, 0);
            // Accumulator output streams to a result buffer per row.
        }
    }
    for i in 0..n {
        let sinks: Vec<AppNodeId> = (0..n)
            .map(|j| g.ids().find(|&id| g.node(id).name == format!("mac_{i}_{j}")).unwrap())
            .collect();
        let sum = adder_tree(&mut g, &format!("r{i}"), sinks);
        let out = g.mem(&format!("c_row{i}"), "stream_out");
        g.wire(sum, out, 0);
    }
    g
}

/// Residual conv block: 3x3 conv + ReLU + skip connection add. Models a
/// quantized ResNet layer's inner loop.
pub fn resnet_block() -> AppGraph {
    let mut g = stencil3x3("resnet", [1, 1, 1, 1, 2, 1, 1, 1, 1]);
    // Append relu + skip add after the stencil's `norm` node.
    let norm = g.ids().find(|&id| g.node(id).name == "norm").unwrap();
    let zero = g.add("zero", AppOp::Const(0));
    let relu = g.alu("relu", "max");
    g.wire(norm, relu, 0);
    g.wire(zero, relu, 1);
    let skip = g.mem("skip_in", "stream_in");
    let add = g.alu("skip_add", "add");
    g.wire(relu, add, 0);
    g.wire(skip, add, 1);
    let out2 = g.mem("out2", "stream_out");
    g.wire(add, out2, 0);
    g
}

/// 5x5 convolution (binomial kernel): the big stencil. Roughly 2.8x the
/// PE count of gaussian 3x3; the channel-pressure workload for the
/// topology/track experiments.
pub fn conv5x5() -> AppGraph {
    // Binomial 5x5 = outer([1,4,6,4,1]).
    let b = [1i64, 4, 6, 4, 1];
    let mut coeffs = [0i64; 25];
    for r in 0..5 {
        for c in 0..5 {
            coeffs[r * 5 + c] = b[r] * b[c];
        }
    }
    stencil("conv5x5", 5, &coeffs)
}

/// Unsharp masking: gaussian blur + amount-weighted difference from the
/// original. Two stencil paths sharing the input stream — high fan-out on
/// the input net.
pub fn unsharp() -> AppGraph {
    let mut g = stencil("unsharp", 3, &[1, 2, 1, 2, 4, 2, 1, 2, 1]);
    let input = g.ids().find(|&id| g.node(id).name == "in").unwrap();
    let blurred = g.ids().find(|&id| g.node(id).name == "norm").unwrap();
    // sharp = in + amount * (in - blurred)
    let delay = g.add("in_align", AppOp::Reg);
    g.wire(input, delay, 1); // second consumer port of the input stream
    let diff = g.alu("hipass", "sub");
    g.wire(delay, diff, 0);
    g.wire(blurred, diff, 1);
    let amt = g.add("amount", AppOp::Const(3));
    let scaled = g.alu("amount_mul", "mul");
    g.wire(diff, scaled, 0);
    g.wire(amt, scaled, 1);
    let sh = g.add("ash", AppOp::Const(1));
    let scaled_s = g.alu("amount_shift", "ashr");
    g.wire(scaled, scaled_s, 0);
    g.wire(sh, scaled_s, 1);
    let add = g.alu("sharp", "add");
    g.wire(delay, add, 0);
    g.wire(scaled_s, add, 1);
    let out = g.mem("out_sharp", "stream_out");
    g.wire(add, out, 0);
    g
}

/// Radix-2 FFT over 8 real-valued lanes (fixed-point, twiddle factors as
/// constant multipliers): 3 butterfly stages with the classic strided
/// cross-lane exchange — the worst-case *non-local* communication pattern
/// in the suite.
pub fn fft8() -> AppGraph {
    let mut g = AppGraph::new("fft8");
    let mut lanes: Vec<AppNodeId> =
        (0..8).map(|i| g.mem(&format!("x{i}"), "stream_in")).collect();
    for stage in 0..3usize {
        let half = 4 >> stage; // butterfly stride: 4, 2, 1
        let mut next = lanes.clone();
        for group in 0..(8 / (2 * half)) {
            for k in 0..half {
                let i = group * 2 * half + k;
                let j = i + half;
                // Twiddle on the lower input.
                let tw = g.add(&format!("tw_s{stage}_{i}"), AppOp::Const(181 >> stage));
                let twm = g.alu(&format!("twmul_s{stage}_{i}"), "mul");
                g.wire(lanes[j], twm, 0);
                g.wire(tw, twm, 1);
                let sh = g.add(&format!("twsh_s{stage}_{i}"), AppOp::Const(7));
                let tws = g.alu(&format!("twshift_s{stage}_{i}"), "ashr");
                g.wire(twm, tws, 0);
                g.wire(sh, tws, 1);
                let a = g.alu(&format!("bfly_add_s{stage}_{i}"), "add");
                g.wire(lanes[i], a, 0);
                g.wire(tws, a, 1);
                let s = g.alu(&format!("bfly_sub_s{stage}_{i}"), "sub");
                g.connect(lanes[i], 0, s, 0);
                g.connect(tws, 0, s, 1);
                next[i] = a;
                next[j] = s;
            }
        }
        lanes = next;
    }
    for (i, &lane) in lanes.iter().enumerate() {
        let out = g.mem(&format!("y{i}"), "stream_out");
        g.wire(lane, out, 0);
    }
    g
}

/// Stereo block matching: per-disparity absolute differences over a
/// 3-wide window, SAD adder trees, and a min-reduction across `disps`
/// disparities. Wide parallel structure with a deep reduction.
pub fn stereo(disps: usize) -> AppGraph {
    let mut g = AppGraph::new("stereo");
    let left = g.mem("left", "stream_in");
    let right = g.mem("right", "stream_in");
    // Window taps on the left stream.
    let mut lw = vec![left];
    for c in 0..2 {
        let r = g.add(&format!("lw{c}"), AppOp::Reg);
        g.wire(*lw.last().unwrap(), r, 0);
        lw.push(r);
    }
    // Right stream delayed per disparity.
    let mut rtap = right;
    let mut sads = Vec::new();
    for d in 0..disps {
        // 3-tap window on this disparity's right stream.
        let mut rw = vec![rtap];
        for c in 0..2 {
            let r = g.add(&format!("rw{d}_{c}"), AppOp::Reg);
            g.wire(*rw.last().unwrap(), r, 0);
            rw.push(r);
        }
        let mut diffs = Vec::new();
        for c in 0..3 {
            let sub = g.alu(&format!("diff{d}_{c}"), "sub");
            g.connect(lw[c], 0, sub, 0);
            g.connect(rw[c], 0, sub, 1);
            let abs = g.alu(&format!("abs{d}_{c}"), "abs");
            g.wire(sub, abs, 0);
            diffs.push(abs);
        }
        let sad = adder_tree(&mut g, &format!("sad{d}"), diffs);
        sads.push(sad);
        // Next disparity: delay the right stream one more pixel.
        let r = g.add(&format!("rd{d}"), AppOp::Reg);
        g.wire(rtap, r, 1);
        rtap = r;
    }
    // Min-reduce the SADs.
    let mut level = sads;
    let mut depth = 0;
    while level.len() > 1 {
        let mut nextl = Vec::new();
        for (i, pair) in level.chunks(2).enumerate() {
            if pair.len() == 1 {
                nextl.push(pair[0]);
                continue;
            }
            let m = g.alu(&format!("min_d{depth}_{i}"), "min");
            g.connect(pair[0], 0, m, 0);
            g.connect(pair[1], 0, m, 1);
            nextl.push(m);
        }
        level = nextl;
        depth += 1;
    }
    let out = g.mem("disparity", "stream_out");
    g.wire(level[0], out, 0);
    g
}

/// Depthwise-separable conv block: two per-channel 3x3 depthwise stencils
/// followed by a 1x1 pointwise combine + ReLU. Models a MobileNet-style
/// layer; two independent stencil subgraphs that converge late.
pub fn depthwise_separable() -> AppGraph {
    let mut g = AppGraph::new("depthwise");
    let mut channel_outs = Vec::new();
    for ch in 0..2 {
        let input = g.mem(&format!("ch{ch}_in"), "stream_in");
        let lb0 = g.mem(&format!("ch{ch}_lb0"), "linebuffer");
        let lb1 = g.mem(&format!("ch{ch}_lb1"), "linebuffer");
        g.wire(input, lb0, 0);
        g.wire(lb0, lb1, 0);
        let rows = [input, lb0, lb1];
        let mut window = Vec::new();
        for (r, &row) in rows.iter().enumerate() {
            let r0 = g.add(&format!("ch{ch}_w{r}0"), AppOp::Reg);
            let r1 = g.add(&format!("ch{ch}_w{r}1"), AppOp::Reg);
            g.wire(row, r0, 0);
            g.wire(r0, r1, 0);
            window.extend([row, r0, r1]);
        }
        let coeffs = [1i64, 2, 1, 2, 4, 2, 1, 2, 1];
        let mut products = Vec::new();
        for (i, (&w, &c)) in window.iter().zip(coeffs.iter()).enumerate() {
            let k = g.add(&format!("ch{ch}_k{i}"), AppOp::Const(c));
            let m = g.alu(&format!("ch{ch}_mul{i}"), "mul");
            g.wire(w, m, 0);
            g.wire(k, m, 1);
            products.push(m);
        }
        let sum = adder_tree(&mut g, &format!("ch{ch}_t"), products);
        channel_outs.push(sum);
    }
    // Pointwise 1x1: weighted channel mix + ReLU.
    let mut mixed = Vec::new();
    for (ch, &c_out) in channel_outs.iter().enumerate() {
        let w = g.add(&format!("pw_w{ch}"), AppOp::Const(5 + ch as i64));
        let m = g.alu(&format!("pw_mul{ch}"), "mul");
        g.wire(c_out, m, 0);
        g.wire(w, m, 1);
        mixed.push(m);
    }
    let sum = g.alu("pw_sum", "add");
    g.connect(mixed[0], 0, sum, 0);
    g.connect(mixed[1], 0, sum, 1);
    let zero = g.add("zero", AppOp::Const(0));
    let relu = g.alu("relu", "max");
    g.wire(sum, relu, 0);
    g.wire(zero, relu, 1);
    let out = g.mem("out", "stream_out");
    g.wire(relu, out, 0);
    g
}

/// A stack of `n` chained 3x3 convolutions (conv -> relu -> conv ...):
/// the fused multi-stage pipeline shape Halide emits for deep stencil
/// programs. The biggest app in the dense suite: ~n x the PE count of a
/// single stencil, with long producer→consumer routes between stages.
pub fn conv_stack(n: usize) -> AppGraph {
    let mut g = AppGraph::new("conv_stack");
    let coeffs = [1i64, 2, 1, 2, 4, 2, 1, 2, 1];
    let input = g.mem("in", "stream_in");
    let mut stream = input;
    for stage in 0..n {
        let lb0 = g.mem(&format!("s{stage}_lb0"), "linebuffer");
        let lb1 = g.mem(&format!("s{stage}_lb1"), "linebuffer");
        g.wire(stream, lb0, 0);
        g.wire(lb0, lb1, 0);
        let rows = [stream, lb0, lb1];
        let mut window = Vec::new();
        for (r, &row) in rows.iter().enumerate() {
            let r0 = g.add(&format!("s{stage}_w{r}0"), AppOp::Reg);
            let r1 = g.add(&format!("s{stage}_w{r}1"), AppOp::Reg);
            g.wire(row, r0, 0);
            g.wire(r0, r1, 0);
            window.extend([row, r0, r1]);
        }
        let mut products = Vec::new();
        for (i, (&w, &c)) in window.iter().zip(coeffs.iter()).enumerate() {
            let k = g.add(&format!("s{stage}_k{i}"), AppOp::Const(c));
            let m = g.alu(&format!("s{stage}_mul{i}"), "mul");
            g.wire(w, m, 0);
            g.wire(k, m, 1);
            products.push(m);
        }
        let sum = adder_tree(&mut g, &format!("s{stage}_t"), products);
        let sh = g.add(&format!("s{stage}_sh"), AppOp::Const(4));
        let norm = g.alu(&format!("s{stage}_norm"), "ashr");
        g.wire(sum, norm, 0);
        g.wire(sh, norm, 1);
        let zero = g.add(&format!("s{stage}_zero"), AppOp::Const(0));
        let relu = g.alu(&format!("s{stage}_relu"), "max");
        g.wire(norm, relu, 0);
        g.wire(zero, relu, 1);
        stream = relu;
    }
    let out = g.mem("out", "stream_out");
    g.wire(stream, out, 0);
    g
}

/// The full suite used by the paper-style runtime experiments
/// (Figs. 11/14/15 sweep "applications" on each interconnect variant).
pub fn suite() -> Vec<AppGraph> {
    vec![pointwise(8), gaussian(), harris(), camera(), resnet_block(), matmul(2)]
}

/// The dense suite: larger applications whose PE demand approaches the
/// array capacity. Used by the topology-routability (Fig. 9) and
/// track-count (Fig. 11) experiments, where the paper's effects only
/// appear under channel pressure.
pub fn dense_suite() -> Vec<AppGraph> {
    vec![
        harris(),
        conv5x5(),
        unsharp(),
        fft8(),
        stereo(4),
        depthwise_separable(),
        matmul(3),
        conv_stack(3),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_suite_apps_are_well_formed() {
        for app in suite() {
            app.check().unwrap_or_else(|e| panic!("{}: {e}", app.name));
            assert!(app.len() >= 10, "{} too small ({})", app.name, app.len());
        }
    }

    #[test]
    fn harris_is_largest_stencil() {
        assert!(harris().len() > gaussian().len());
    }

    #[test]
    fn suite_spans_fanout_range() {
        // At least one app must have a high-fanout net (stresses the
        // ready-valid join logic) and one must be a pure chain.
        let max_fanout = |g: &AppGraph| g.nets().iter().map(|n| n.sinks.len()).max().unwrap();
        let fans: Vec<usize> = suite().iter().map(max_fanout).collect();
        assert!(fans.iter().any(|&f| f >= 3), "{fans:?}");
        assert!(fans.contains(&1) || fans.contains(&2));
    }

    #[test]
    fn matmul_scales_quadratically() {
        assert!(matmul(3).len() > matmul(2).len());
        let g = matmul(2);
        // 2 rows + 2 cols in, 4 mul + 4 mac, adders, 2 out
        assert!(g.histogram()["mem"] == 6);
    }

    #[test]
    fn pointwise_node_count_linear() {
        // in + out + (const, op) per stage
        assert_eq!(pointwise(4).len(), 2 + 2 * 4);
        assert_eq!(pointwise(6).len(), 2 + 2 * 6);
    }
}
