//! PJRT runtime: execute the AOT-compiled JAX/Pallas placement artifacts
//! from the Rust hot path.
//!
//! `make artifacts` lowers `python/compile/model.py` (which calls the L1
//! Pallas kernel) to HLO text; this module loads the text with
//! `HloModuleProto::from_text_file`, compiles it once on the PJRT CPU
//! client, and drives the optimizer loop from
//! [`crate::pnr::place::GlobalPlacer`]'s interface. Python never runs at
//! request time.
//!
//! The PJRT executor itself needs the `xla` crate, which is not part of
//! the offline dependency set, so it is gated behind the off-by-default
//! `pjrt` cargo feature. Without the feature, [`PjrtPlacer::load`]
//! reports that support is compiled out and every flow falls back to
//! [`crate::pnr::place::NativePlacer`] (same objective, same step rule);
//! artifact metadata and golden-vector parsing stay available either way.

use std::fmt;
use std::path::{Path, PathBuf};

use crate::pnr::place::{GlobalPlacer, GlobalProblem, PlacementInstance};

/// Self-contained runtime error (the offline build carries no
/// error-handling dependencies).
#[derive(Debug)]
pub struct RuntimeError(String);

impl RuntimeError {
    pub fn new(msg: impl Into<String>) -> RuntimeError {
        RuntimeError(msg.into())
    }
}

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

pub type Result<T> = std::result::Result<T, RuntimeError>;

/// Shape contract of the exported artifact (must match
/// `python/compile/model.py` and `artifacts/placer_meta.txt`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ArtifactMeta {
    pub pad_n: usize,
    pub pad_m: usize,
    pub pad_k: usize,
    pub inner_steps: usize,
    /// Batch lanes of the vmapped `placer_batch_step` artifact. `1` when
    /// the meta file predates the batched export (scalar-only artifacts).
    pub pad_b: usize,
}

impl ArtifactMeta {
    /// Parse `placer_meta.txt` (flat `key = value` lines). `pad_b` is
    /// optional and defaults to 1 for pre-batching artifact sets.
    pub fn from_file(path: &Path) -> Result<ArtifactMeta> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| RuntimeError::new(format!("reading {}: {e}", path.display())))?;
        let mut meta = ArtifactMeta { pad_n: 0, pad_m: 0, pad_k: 0, inner_steps: 0, pad_b: 1 };
        for line in text.lines() {
            let Some((k, v)) = line.split_once('=') else { continue };
            let v: usize = v
                .trim()
                .parse()
                .map_err(|e| RuntimeError::new(format!("bad meta line `{line}`: {e}")))?;
            match k.trim() {
                "pad_n" => meta.pad_n = v,
                "pad_m" => meta.pad_m = v,
                "pad_k" => meta.pad_k = v,
                "inner_steps" => meta.inner_steps = v,
                "pad_b" => meta.pad_b = v,
                _ => {}
            }
        }
        if meta.pad_n == 0
            || meta.pad_m == 0
            || meta.pad_k == 0
            || meta.inner_steps == 0
            || meta.pad_b == 0
        {
            return Err(RuntimeError::new(format!(
                "incomplete artifact meta in {}",
                path.display()
            )));
        }
        Ok(meta)
    }
}

/// Default artifacts directory, overridable with `CANAL_ARTIFACTS`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("CANAL_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use super::*;

    /// The PJRT-backed global placer (drop-in for `NativePlacer`).
    pub struct PjrtPlacer {
        client: xla::PjRtClient,
        step_exe: xla::PjRtLoadedExecutable,
        /// The vmapped `placer_batch_step` executable (`meta.pad_b` lanes
        /// per dispatch), when the artifact set includes it.
        batch_exe: Option<xla::PjRtLoadedExecutable>,
        meta: ArtifactMeta,
        /// Total optimizer iterations per `optimize` call (rounded up to a
        /// multiple of `meta.inner_steps`).
        pub iters: usize,
        /// Hyperparameters fed to the artifact: (lr, momentum, lambda_mem).
        pub hyper: (f32, f32, f32),
    }

    fn err(what: &str) -> impl Fn(xla::Error) -> RuntimeError + '_ {
        move |e| RuntimeError::new(format!("{what}: {e}"))
    }

    impl PjrtPlacer {
        fn compile_hlo(
            client: &xla::PjRtClient,
            path: &Path,
        ) -> Result<xla::PjRtLoadedExecutable> {
            let s = path.to_str().ok_or_else(|| RuntimeError::new("non-utf8 artifact path"))?;
            let proto = xla::HloModuleProto::from_text_file(s)
                .map_err(|e| RuntimeError::new(format!("parsing {}: {e}", path.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .map_err(|e| RuntimeError::new(format!("compiling {}: {e}", path.display())))
        }

        /// Load and compile the step artifact from a directory. The
        /// batched artifact (`placer_batch_step.hlo.txt`) is optional —
        /// without it, `place_batch` falls back to the scalar loop.
        pub fn load(dir: &Path) -> Result<PjrtPlacer> {
            let meta = ArtifactMeta::from_file(&dir.join("placer_meta.txt"))?;
            let client = xla::PjRtClient::cpu().map_err(err("creating PJRT CPU client"))?;
            let step_exe = Self::compile_hlo(&client, &dir.join("placer_step.hlo.txt"))?;
            let batch_path = dir.join("placer_batch_step.hlo.txt");
            let batch_exe = if meta.pad_b > 1 && batch_path.exists() {
                Some(Self::compile_hlo(&client, &batch_path)?)
            } else {
                None
            };
            Ok(PjrtPlacer {
                client,
                step_exe,
                batch_exe,
                meta,
                iters: 150,
                hyper: (0.12, 0.9, 0.4),
            })
        }

        /// Load from the default artifacts directory.
        pub fn load_default() -> Result<PjrtPlacer> {
            Self::load(&artifacts_dir())
        }

        pub fn meta(&self) -> ArtifactMeta {
            self.meta
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Pad a problem into artifact shapes.
        fn pad_problem(&self, p: &GlobalProblem) -> Result<(Vec<i32>, Vec<f32>, Vec<f32>)> {
            let m = self.meta;
            if p.n_nodes > m.pad_n {
                return Err(RuntimeError::new(format!(
                    "problem has {} nodes > artifact pad {}",
                    p.n_nodes, m.pad_n
                )));
            }
            if p.pins.len() > m.pad_m {
                return Err(RuntimeError::new(format!(
                    "problem has {} nets > artifact pad {}",
                    p.pins.len(),
                    m.pad_m
                )));
            }
            let mut pins = vec![-1i32; m.pad_m * m.pad_k];
            for (i, net) in p.pins.iter().enumerate() {
                if net.len() > m.pad_k {
                    return Err(RuntimeError::new(format!(
                        "net {i} has {} pins > artifact pad {}",
                        net.len(),
                        m.pad_k
                    )));
                }
                for (j, &v) in net.iter().enumerate() {
                    pins[i * m.pad_k + j] = v;
                }
            }
            let mut col = vec![0f32; m.pad_n];
            let mut colm = vec![0f32; m.pad_n];
            for (i, c) in p.column_pull.iter().enumerate() {
                if let Some(c) = c {
                    col[i] = *c;
                    colm[i] = 1.0;
                }
            }
            Ok((pins, col, colm))
        }

        /// One artifact invocation: `inner_steps` optimizer steps.
        #[allow(clippy::too_many_arguments)]
        pub fn call_step(
            &self,
            xs: &[f32],
            ys: &[f32],
            vx: &[f32],
            vy: &[f32],
            pins: &[i32],
            col: &[f32],
            colm: &[f32],
            bounds: [f32; 2],
            hyper: [f32; 3],
        ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> {
            let m = self.meta;
            let args = [
                xla::Literal::vec1(xs),
                xla::Literal::vec1(ys),
                xla::Literal::vec1(vx),
                xla::Literal::vec1(vy),
                xla::Literal::vec1(pins)
                    .reshape(&[m.pad_m as i64, m.pad_k as i64])
                    .map_err(err("reshaping pins"))?,
                xla::Literal::vec1(col),
                xla::Literal::vec1(colm),
                xla::Literal::vec1(&bounds),
                xla::Literal::vec1(&hyper),
            ];
            let result = self
                .step_exe
                .execute::<xla::Literal>(&args)
                .map_err(err("executing placer_step"))?[0][0]
                .to_literal_sync()
                .map_err(err("syncing result"))?;
            let (oxs, oys, ovx, ovy) = result.to_tuple4().map_err(err("untupling result"))?;
            Ok((
                oxs.to_vec().map_err(err("reading xs"))?,
                oys.to_vec().map_err(err("reading ys"))?,
                ovx.to_vec().map_err(err("reading vx"))?,
                ovy.to_vec().map_err(err("reading vy"))?,
            ))
        }

        /// One batched artifact invocation: `inner_steps` optimizer steps
        /// on `pad_b` lanes at once. All slices are row-major flattened
        /// batch-of-lane arrays (`xs`: `[pad_b * pad_n]`, `pins`:
        /// `[pad_b * pad_m * pad_k]`, `bounds`: `[pad_b * 2]`, `hyper`:
        /// `[pad_b * 3]`).
        #[allow(clippy::too_many_arguments)]
        pub fn call_step_batch(
            &self,
            xs: &[f32],
            ys: &[f32],
            vx: &[f32],
            vy: &[f32],
            pins: &[i32],
            col: &[f32],
            colm: &[f32],
            bounds: &[f32],
            hyper: &[f32],
        ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> {
            let m = self.meta;
            let exe = self
                .batch_exe
                .as_ref()
                .ok_or_else(|| RuntimeError::new("no placer_batch_step artifact loaded"))?;
            let (b, n) = (m.pad_b as i64, m.pad_n as i64);
            let lane = |v: &[f32], w: i64| {
                xla::Literal::vec1(v).reshape(&[b, w]).map_err(err("reshaping batch input"))
            };
            let args = [
                lane(xs, n)?,
                lane(ys, n)?,
                lane(vx, n)?,
                lane(vy, n)?,
                xla::Literal::vec1(pins)
                    .reshape(&[b, m.pad_m as i64, m.pad_k as i64])
                    .map_err(err("reshaping batch pins"))?,
                lane(col, n)?,
                lane(colm, n)?,
                lane(bounds, 2)?,
                lane(hyper, 3)?,
            ];
            let result = exe
                .execute::<xla::Literal>(&args)
                .map_err(err("executing placer_batch_step"))?[0][0]
                .to_literal_sync()
                .map_err(err("syncing batch result"))?;
            let (oxs, oys, ovx, ovy) = result.to_tuple4().map_err(err("untupling batch"))?;
            Ok((
                oxs.to_vec().map_err(err("reading batch xs"))?,
                oys.to_vec().map_err(err("reading batch ys"))?,
                ovx.to_vec().map_err(err("reading batch vx"))?,
                ovy.to_vec().map_err(err("reading batch vy"))?,
            ))
        }

        /// Whether this placer loaded the batched executable.
        pub fn has_batch_artifact(&self) -> bool {
            self.batch_exe.is_some()
        }

        /// Does the problem fit the padded lane shapes of the batched
        /// artifact?
        fn fits_batch(&self, p: &GlobalProblem) -> bool {
            let m = self.meta;
            p.n_nodes <= m.pad_n
                && p.pins.len() <= m.pad_m
                && p.pins.iter().all(|net| net.len() <= m.pad_k)
        }
    }

    impl GlobalPlacer for PjrtPlacer {
        fn optimize(&self, p: &GlobalProblem, xs0: &[f32], ys0: &[f32]) -> (Vec<f32>, Vec<f32>) {
            // With the batched executable loaded, a fitting problem
            // ALWAYS solves through it — singleton or grouped — so the
            // bits a (config, app, seed) point produces never depend on
            // how the solve was batched (group composition varies with
            // cache temperature).
            if self.batch_exe.is_some() && self.fits_batch(p) {
                return self
                    .place_batch(&[PlacementInstance { problem: p, xs0, ys0 }])
                    .pop()
                    .expect("one result for one instance");
            }
            let m = self.meta;
            let (pins, col, colm) =
                self.pad_problem(p).expect("problem exceeds artifact padding");
            let mut xs = vec![0f32; m.pad_n];
            let mut ys = vec![0f32; m.pad_n];
            xs[..p.n_nodes].copy_from_slice(xs0);
            ys[..p.n_nodes].copy_from_slice(ys0);
            let mut vx = vec![0f32; m.pad_n];
            let mut vy = vec![0f32; m.pad_n];
            let bounds = [p.width - 1.0, p.height - 1.0];
            let hyper = [self.hyper.0, self.hyper.1, self.hyper.2];

            let calls = self.iters.div_ceil(m.inner_steps);
            for _ in 0..calls {
                let (nxs, nys, nvx, nvy) = self
                    .call_step(&xs, &ys, &vx, &vy, &pins, &col, &colm, bounds, hyper)
                    .expect("artifact execution failed");
                xs = nxs;
                ys = nys;
                vx = nvx;
                vy = nvy;
            }
            xs.truncate(p.n_nodes);
            ys.truncate(p.n_nodes);
            (xs, ys)
        }

        /// Batched solve: lower up to `pad_b` problems per HLO dispatch
        /// through the vmapped `placer_batch_step` executable. Each lane
        /// runs the per-problem computation of the scalar artifact (vmap
        /// adds a leading axis without reassociating per-lane
        /// arithmetic); XLA may still compile the lanes to different
        /// instruction schedules than the scalar executable, so the
        /// batch-capable placer carries its own `name()` and never
        /// shares cache entries with the scalar path. The feature-gated
        /// `pjrt_batch_size_is_bit_invariant` test asserts that batch
        /// composition cannot change a problem's bits.
        ///
        /// The path a fitting problem takes depends only on its own
        /// shape, never on what else happens to share its batch (which
        /// varies with cache temperature), so re-runs reproduce
        /// identical bits. A problem exceeding the padded shapes cannot
        /// run on *either* executable (scalar and batched artifacts
        /// share `pad_n`/`pad_m`/`pad_k`) and panics with the scalar
        /// path's "problem exceeds artifact padding", exactly as
        /// `optimize` always has.
        fn place_batch(&self, batch: &[PlacementInstance<'_>]) -> Vec<(Vec<f32>, Vec<f32>)> {
            let m = self.meta;
            if self.batch_exe.is_none() {
                return batch.iter().map(|b| self.optimize(b.problem, b.xs0, b.ys0)).collect();
            }
            let mut out: Vec<Option<(Vec<f32>, Vec<f32>)>> = vec![None; batch.len()];
            let lanes: Vec<usize> =
                (0..batch.len()).filter(|&i| self.fits_batch(batch[i].problem)).collect();
            for chunk_idx in lanes.chunks(m.pad_b) {
                let chunk: Vec<&PlacementInstance> =
                    chunk_idx.iter().map(|&i| &batch[i]).collect();
                // Pad each problem into its lane of the [pad_b, ...]
                // batch arrays; unused lanes stay zero (their clamp box
                // is degenerate but harmless — they are never read back).
                let mut xs = vec![0f32; m.pad_b * m.pad_n];
                let mut ys = vec![0f32; m.pad_b * m.pad_n];
                let mut vx = vec![0f32; m.pad_b * m.pad_n];
                let mut vy = vec![0f32; m.pad_b * m.pad_n];
                let mut pins = vec![-1i32; m.pad_b * m.pad_m * m.pad_k];
                let mut col = vec![0f32; m.pad_b * m.pad_n];
                let mut colm = vec![0f32; m.pad_b * m.pad_n];
                let mut bounds = vec![0f32; m.pad_b * 2];
                let mut hyper = vec![0f32; m.pad_b * 3];
                for (l, inst) in chunk.iter().enumerate() {
                    let p = inst.problem;
                    let (lpins, lcol, lcolm) =
                        self.pad_problem(p).expect("problem checked against padding");
                    xs[l * m.pad_n..l * m.pad_n + p.n_nodes].copy_from_slice(inst.xs0);
                    ys[l * m.pad_n..l * m.pad_n + p.n_nodes].copy_from_slice(inst.ys0);
                    pins[l * m.pad_m * m.pad_k..(l + 1) * m.pad_m * m.pad_k]
                        .copy_from_slice(&lpins);
                    col[l * m.pad_n..(l + 1) * m.pad_n].copy_from_slice(&lcol);
                    colm[l * m.pad_n..(l + 1) * m.pad_n].copy_from_slice(&lcolm);
                    bounds[l * 2] = p.width - 1.0;
                    bounds[l * 2 + 1] = p.height - 1.0;
                    hyper[l * 3] = self.hyper.0;
                    hyper[l * 3 + 1] = self.hyper.1;
                    hyper[l * 3 + 2] = self.hyper.2;
                }
                let calls = self.iters.div_ceil(m.inner_steps);
                for _ in 0..calls {
                    let (nxs, nys, nvx, nvy) = self
                        .call_step_batch(&xs, &ys, &vx, &vy, &pins, &col, &colm, &bounds, &hyper)
                        .expect("batched artifact execution failed");
                    xs = nxs;
                    ys = nys;
                    vx = nvx;
                    vy = nvy;
                }
                for (l, inst) in chunk.iter().enumerate() {
                    let n = inst.problem.n_nodes;
                    out[chunk_idx[l]] = Some((
                        xs[l * m.pad_n..l * m.pad_n + n].to_vec(),
                        ys[l * m.pad_n..l * m.pad_n + n].to_vec(),
                    ));
                }
            }
            // Oversized problems: route through `optimize`, which
            // panics with the canonical "problem exceeds artifact
            // padding" message (no artifact can run them).
            for (i, slot) in out.iter_mut().enumerate() {
                if slot.is_none() {
                    let b = &batch[i];
                    *slot = Some(self.optimize(b.problem, b.xs0, b.ys0));
                }
            }
            out.into_iter().map(|s| s.expect("every lane solved")).collect()
        }

        /// The cache identity. A placer that loaded the batched
        /// executable solves through a *different compiled program* than
        /// the scalar artifact (numerically equivalent, not bit-
        /// identical), so it carries a distinct name — scalar-path and
        /// batch-path results must never alias under one
        /// `ConfigDescriptor`.
        fn name(&self) -> &'static str {
            if self.batch_exe.is_some() {
                "pjrt-jax-pallas-batch"
            } else {
                "pjrt-jax-pallas"
            }
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::PjrtPlacer;

/// Stub placer used when the crate is built without the `pjrt` feature:
/// [`PjrtPlacer::load`] always fails, so callers take their native
/// fallback path. The type still exists (and implements `GlobalPlacer`)
/// so call sites compile identically with and without the feature.
#[cfg(not(feature = "pjrt"))]
pub struct PjrtPlacer {
    meta: ArtifactMeta,
    pub iters: usize,
    pub hyper: (f32, f32, f32),
}

#[cfg(not(feature = "pjrt"))]
impl PjrtPlacer {
    pub fn load(_dir: &Path) -> Result<PjrtPlacer> {
        Err(RuntimeError::new(
            "PJRT support not compiled in: vendor the `xla` crate, declare it \
             in rust/Cargo.toml, and build with `--features pjrt`",
        ))
    }

    pub fn load_default() -> Result<PjrtPlacer> {
        Self::load(&artifacts_dir())
    }

    pub fn meta(&self) -> ArtifactMeta {
        self.meta
    }

    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }
}

#[cfg(not(feature = "pjrt"))]
impl GlobalPlacer for PjrtPlacer {
    fn optimize(&self, _p: &GlobalProblem, _xs0: &[f32], _ys0: &[f32]) -> (Vec<f32>, Vec<f32>) {
        unreachable!("stub PjrtPlacer cannot be constructed")
    }

    fn place_batch(&self, _batch: &[PlacementInstance<'_>]) -> Vec<(Vec<f32>, Vec<f32>)> {
        unreachable!("stub PjrtPlacer cannot be constructed")
    }

    fn name(&self) -> &'static str {
        "pjrt-unavailable"
    }
}

/// Parsed golden test vector dumped by `aot.py`.
pub struct TestVec {
    pub fields: std::collections::HashMap<String, Vec<f32>>,
}

impl TestVec {
    pub fn from_file(path: &Path) -> Result<TestVec> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| RuntimeError::new(format!("reading {}: {e}", path.display())))?;
        let mut fields = std::collections::HashMap::new();
        for line in text.lines() {
            let mut it = line.split_whitespace();
            let Some(name) = it.next() else { continue };
            let vals: Vec<f32> = it.map(|t| t.parse().unwrap_or(f32::NAN)).collect();
            fields.insert(name.to_string(), vals);
        }
        Ok(TestVec { fields })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        artifacts_dir().join("placer_step.hlo.txt").exists()
    }

    #[test]
    fn meta_parses() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = ArtifactMeta::from_file(&artifacts_dir().join("placer_meta.txt")).unwrap();
        assert!(m.pad_n >= 64 && m.pad_m >= 128 && m.inner_steps >= 1);
    }

    #[test]
    fn meta_rejects_incomplete_files() {
        let dir = std::env::temp_dir().join("canal-runtime-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("placer_meta.txt");
        std::fs::write(&path, "pad_n = 64\npad_m = 128\n").unwrap();
        assert!(ArtifactMeta::from_file(&path).is_err());
        // A pre-batching meta file (no pad_b line) defaults to pad_b = 1.
        std::fs::write(&path, "pad_n = 64\npad_m = 128\npad_k = 8\ninner_steps = 10\n").unwrap();
        let m = ArtifactMeta::from_file(&path).unwrap();
        assert_eq!(
            m,
            ArtifactMeta { pad_n: 64, pad_m: 128, pad_k: 8, inner_steps: 10, pad_b: 1 }
        );
        std::fs::write(
            &path,
            "pad_n = 64\npad_m = 128\npad_k = 8\ninner_steps = 10\npad_b = 8\n",
        )
        .unwrap();
        assert_eq!(ArtifactMeta::from_file(&path).unwrap().pad_b, 8);
        // An explicit zero is invalid, not "absent".
        std::fs::write(
            &path,
            "pad_n = 64\npad_m = 128\npad_k = 8\ninner_steps = 10\npad_b = 0\n",
        )
        .unwrap();
        assert!(ArtifactMeta::from_file(&path).is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_placer_reports_missing_feature() {
        let e = PjrtPlacer::load_default().unwrap_err();
        assert!(e.to_string().contains("pjrt"), "{e}");
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn artifact_matches_python_golden_vector() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let placer = PjrtPlacer::load_default().unwrap();
        let m = placer.meta();
        let tv = TestVec::from_file(&artifacts_dir().join("placer_testvec.txt")).unwrap();
        let f = |k: &str| tv.fields[k].clone();
        let pins: Vec<i32> = f("in_pins").iter().map(|&v| v as i32).collect();
        let bounds = [f("in_bounds")[0], f("in_bounds")[1]];
        let hyper = [f("in_hyper")[0], f("in_hyper")[1], f("in_hyper")[2]];
        let (xs, ys, vx, vy) = placer
            .call_step(
                &f("in_xs"),
                &f("in_ys"),
                &f("in_vx"),
                &f("in_vy"),
                &pins,
                &f("in_col"),
                &f("in_colm"),
                bounds,
                hyper,
            )
            .unwrap();
        let check = |got: &[f32], want: &[f32], what: &str| {
            assert_eq!(got.len(), want.len(), "{what} length");
            for (i, (g, w)) in got.iter().zip(want).enumerate() {
                assert!(
                    (g - w).abs() <= 1e-4 * w.abs().max(1.0),
                    "{what}[{i}]: rust={g} python={w}"
                );
            }
        };
        check(&xs, &f("out_xs"), "xs");
        check(&ys, &f("out_ys"), "ys");
        check(&vx, &f("out_vx"), "vx");
        check(&vy, &f("out_vy"), "vy");
        assert_eq!(m.pad_n, xs.len());
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn pjrt_placer_agrees_with_native_on_final_cost() {
        use crate::pnr::pack::pack;
        use crate::pnr::place::{build_global_problem, initial_positions, NativePlacer};
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        use crate::dsl::{create_uniform_interconnect, InterconnectConfig};
        let ic = create_uniform_interconnect(&InterconnectConfig {
            width: 8,
            height: 8,
            num_tracks: 3,
            mem_column_period: 3,
            reg_density: 0,
            ..Default::default()
        });
        let packed = pack(&crate::apps::harris()).app;
        let problem = build_global_problem(&packed, &ic);
        let (xs0, ys0) = initial_positions(&packed, &ic, 11);

        let native = NativePlacer::default();
        let (nx, ny) = native.optimize(&problem, &xs0, &ys0);
        let (nc, _, _) = crate::pnr::place::global_cost_grad(&problem, &nx, &ny, 0.4);

        let pjrt = PjrtPlacer::load_default().unwrap();
        let (px, py) = pjrt.optimize(&problem, &xs0, &ys0);
        let (pc, _, _) = crate::pnr::place::global_cost_grad(&problem, &px, &py, 0.4);

        // Same objective, same step rule, same budget: final costs must
        // land close (fp accumulation differences only).
        assert!((nc - pc).abs() <= 0.05 * nc.abs().max(1.0), "native {nc} vs pjrt {pc}");
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn pjrt_batch_size_is_bit_invariant() {
        // The property the DSE engine's determinism rests on: how a
        // problem is batched (full group, pairs, singleton — which is
        // what `optimize` dispatches) cannot change a single bit of its
        // result, because every fitting problem runs the same lanewise
        // program and lanes are independent.
        use crate::dsl::{create_uniform_interconnect, InterconnectConfig};
        use crate::pnr::pack::pack;
        use crate::pnr::place::{build_global_problem, initial_positions};
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let placer = PjrtPlacer::load_default().unwrap();
        if !placer.has_batch_artifact() {
            eprintln!("skipping: no placer_batch_step artifact");
            return;
        }
        let ic = create_uniform_interconnect(&InterconnectConfig {
            width: 8,
            height: 8,
            num_tracks: 3,
            mem_column_period: 3,
            reg_density: 0,
            ..Default::default()
        });
        let apps = [crate::apps::harris(), crate::apps::gaussian(), crate::apps::camera()];
        let packed: Vec<_> = apps.iter().map(|a| pack(a).app).collect();
        let problems: Vec<_> = packed.iter().map(|a| build_global_problem(a, &ic)).collect();
        let inits: Vec<_> =
            packed.iter().enumerate().map(|(i, a)| initial_positions(a, &ic, i as u64)).collect();
        let batch: Vec<PlacementInstance> = problems
            .iter()
            .zip(&inits)
            .map(|(p, (xs0, ys0))| PlacementInstance { problem: p, xs0, ys0 })
            .collect();
        let grouped = placer.place_batch(&batch);
        for (inst, (gxs, gys)) in batch.iter().zip(&grouped) {
            let (sxs, sys) = placer.optimize(inst.problem, inst.xs0, inst.ys0);
            let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(gxs), bits(&sxs), "xs bits differ across batch sizes");
            assert_eq!(bits(gys), bits(&sys), "ys bits differ across batch sizes");
        }
    }
}
