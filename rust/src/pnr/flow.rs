//! The end-to-end PnR flow (Fig. 2 right-hand path): pack → global place
//! → detailed place → route → STA, with the α-sweep the paper describes
//! ("sweeping α from 1 to 20 and choosing the best result post-routing").

use crate::ir::Interconnect;

use super::app::AppGraph;
use super::pack::{pack, PackedApp};
use super::place::{
    build_global_problem, detailed_place, initial_positions, legalize, GlobalPlacer,
    GlobalProblem, NativePlacer, Placement, SaParams,
};
use super::route::{route_with_scratch, RouterParams, RouterScratch, RoutingFailed, RoutingResult};
use super::timing::{analyze, TimingReport};

/// Flow-level options.
#[derive(Clone, Debug)]
pub struct FlowParams {
    pub seed: u64,
    pub sa: SaParams,
    pub router: RouterParams,
    /// α values to sweep (best post-route critical path wins). Empty ⇒
    /// single run with `sa.alpha`.
    pub alpha_sweep: Vec<f64>,
    /// Streamed elements for the run-time model (64x64 image default).
    pub workload_items: usize,
    /// Routing layer.
    pub bit_width: u8,
}

impl Default for FlowParams {
    fn default() -> Self {
        FlowParams {
            seed: 1,
            sa: SaParams::default(),
            router: RouterParams::default(),
            alpha_sweep: vec![],
            workload_items: 4096,
            bit_width: 16,
        }
    }
}

/// Everything the flow produces for one application.
#[derive(Clone, Debug)]
pub struct FlowResult {
    pub packed: PackedApp,
    pub placement: Placement,
    pub routing: RoutingResult,
    pub timing: TimingReport,
    /// α that won the sweep (or the single configured α).
    pub alpha: f64,
    pub placement_cost: f64,
}

/// Run the full flow with the native global placer.
pub fn run_flow(
    ic: &Interconnect,
    app: &AppGraph,
    params: &FlowParams,
) -> Result<FlowResult, RoutingFailed> {
    run_flow_with(ic, app, params, &NativePlacer::default())
}

/// Run the full flow with an explicit global-placement backend (native or
/// the PJRT artifact executor).
pub fn run_flow_with(
    ic: &Interconnect,
    app: &AppGraph,
    params: &FlowParams,
    placer: &dyn GlobalPlacer,
) -> Result<FlowResult, RoutingFailed> {
    run_flow_scratch(ic, app, params, placer, &mut RouterScratch::new())
}

/// [`run_flow_with`], reusing caller-owned PathFinder buffers across the
/// α sweep's routes — and, for the DSE engine's workers, across every
/// sweep point the worker processes. Bit-identical to a fresh-scratch
/// call.
pub fn run_flow_scratch(
    ic: &Interconnect,
    app: &AppGraph,
    params: &FlowParams,
    placer: &dyn GlobalPlacer,
    scratch: &mut RouterScratch,
) -> Result<FlowResult, RoutingFailed> {
    let prepared = prepare_point(ic, app, params);
    let (xs, ys) = placer.optimize(&prepared.problem, &prepared.xs0, &prepared.ys0);
    finish_flow_scratch(ic, &prepared, &xs, &ys, params, scratch)
}

/// Phase 1 of the flow — everything *before* the global solve: packing,
/// the dense analytic problem, and the seeded initial spread. Split out
/// so the DSE executor can prepare a whole per-config job group, solve it
/// with one [`GlobalPlacer::place_batch`] call, and then
/// [`finish_flow_scratch`] each point. `prepare` + `optimize` + `finish`
/// is exactly [`run_flow_scratch`].
pub struct PreparedPoint {
    /// Packed application (Const/Reg vertices absorbed into host PEs).
    pub packed: PackedApp,
    /// The dense Eq. 1 problem for the packed app on this fabric.
    pub problem: GlobalProblem,
    /// Seeded initial x positions.
    pub xs0: Vec<f32>,
    /// Seeded initial y positions.
    pub ys0: Vec<f32>,
}

/// Pack `app` and build its global-placement problem (flow stages 1-2a).
pub fn prepare_point(ic: &Interconnect, app: &AppGraph, params: &FlowParams) -> PreparedPoint {
    // 1. Packing.
    let packed = pack(app);
    // 2a. Global-placement problem construction (analytic; Eq. 1).
    let (xs0, ys0) = initial_positions(&packed.app, ic, params.seed);
    let problem = build_global_problem(&packed.app, ic);
    PreparedPoint { packed, problem, xs0, ys0 }
}

/// Flow stages 2b-5: legalize the globally-placed continuous positions,
/// then detailed placement + routing over the α sweep, then STA.
/// Bit-identical to the tail of [`run_flow_scratch`] by construction —
/// it *is* that tail.
pub fn finish_flow_scratch(
    ic: &Interconnect,
    prepared: &PreparedPoint,
    xs: &[f32],
    ys: &[f32],
    params: &FlowParams,
    scratch: &mut RouterScratch,
) -> Result<FlowResult, RoutingFailed> {
    let packed = &prepared.packed;
    // 2b. Legalization of the analytic solution.
    let seed_placement = legalize(&packed.app, ic, xs, ys).map_err(|e| RoutingFailed {
        iterations: 0,
        overused_nodes: 0,
        detail: format!("legalization failed: {e}"),
    })?;

    // 3+4. Detailed placement (Eq. 2) + routing, over the α sweep.
    let alphas: Vec<f64> =
        if params.alpha_sweep.is_empty() { vec![params.sa.alpha] } else { params.alpha_sweep.clone() };
    let nets = packed.app.nets();

    let mut best: Option<FlowResult> = None;
    let mut last_err: Option<RoutingFailed> = None;
    for &alpha in &alphas {
        let sa = SaParams { alpha, seed: params.seed ^ alpha.to_bits(), ..params.sa };
        let (placement, placement_cost) =
            detailed_place(&packed.app, ic, &nets, seed_placement.clone(), &sa);
        let routed = route_with_scratch(
            ic,
            &packed.app,
            &placement,
            params.bit_width,
            &params.router,
            scratch,
        );
        match routed {
            Ok(routing) => {
                let timing =
                    analyze(ic, packed, &routing, params.bit_width, params.workload_items);
                let better = best
                    .as_ref()
                    .map_or(true, |b| timing.critical_path_ps < b.timing.critical_path_ps);
                if better {
                    best = Some(FlowResult {
                        packed: packed.clone(),
                        placement,
                        routing,
                        timing,
                        alpha,
                        placement_cost,
                    });
                }
            }
            Err(e) => last_err = Some(e),
        }
    }

    best.ok_or_else(|| {
        last_err.unwrap_or(RoutingFailed {
            iterations: 0,
            overused_nodes: 0,
            detail: "no alpha produced a routable placement".into(),
        })
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::dsl::{create_uniform_interconnect, InterconnectConfig};

    fn ic() -> Interconnect {
        create_uniform_interconnect(&InterconnectConfig {
            width: 8,
            height: 8,
            num_tracks: 5,
            mem_column_period: 3,
            ..Default::default()
        })
    }

    #[test]
    fn flow_runs_entire_suite() {
        let ic = ic();
        let params = FlowParams {
            sa: SaParams { moves_per_node: 10, ..Default::default() },
            ..Default::default()
        };
        for app in apps::suite() {
            let r = run_flow(&ic, &app, &params)
                .unwrap_or_else(|e| panic!("{} failed: {e}", app.name));
            assert!(r.timing.critical_path_ps > 0.0, "{}", app.name);
            assert_eq!(r.routing.trees.len(), r.packed.app.nets().len());
        }
    }

    #[test]
    fn alpha_sweep_never_worse_than_single_alpha() {
        let ic = ic();
        let app = apps::gaussian();
        let base = FlowParams {
            sa: SaParams { moves_per_node: 10, ..Default::default() },
            ..Default::default()
        };
        let single = run_flow(&ic, &app, &base).unwrap();
        let swept = run_flow(
            &ic,
            &app,
            &FlowParams { alpha_sweep: vec![1.0, 2.0, 4.0], ..base },
        )
        .unwrap();
        assert!(swept.timing.critical_path_ps <= single.timing.critical_path_ps + 1e-9);
    }

    #[test]
    fn flow_is_deterministic() {
        let ic = ic();
        let app = apps::camera();
        let params = FlowParams {
            sa: SaParams { moves_per_node: 8, ..Default::default() },
            ..Default::default()
        };
        let a = run_flow(&ic, &app, &params).unwrap();
        let b = run_flow(&ic, &app, &params).unwrap();
        assert_eq!(a.placement.pos, b.placement.pos);
        assert_eq!(a.timing.critical_path_ps, b.timing.critical_path_ps);
    }
}
