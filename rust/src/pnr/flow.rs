//! The end-to-end PnR flow (Fig. 2 right-hand path): pack → global place
//! → detailed place → route → STA, with the α-sweep the paper describes
//! ("sweeping α from 1 to 20 and choosing the best result post-routing").
//!
//! The router knobs ride along in [`FlowParams::router`]: Steiner-tree
//! multi-sink routing, the pluggable search core, and slack-driven net
//! ordering (see [`RouterParams`]) all apply to every route of the α
//! sweep and to the warm-started replay path alike.

use crate::ir::{Interconnect, NodeId};
use crate::obs;
use crate::obs::span::names as spans;

use super::app::AppGraph;
use super::pack::{pack, PackedApp};
use super::place::{
    build_global_problem, detailed_place, initial_positions, legalize, refine_place,
    seed_placement, GlobalPlacer, GlobalProblem, NativePlacer, Placement, SaParams,
};
use super::route::{
    route_with_scratch, route_with_seed, RouteReuse, RouterParams, RouterScratch, RoutingFailed,
    RoutingResult,
};
use super::timing::{analyze, TimingReport};

/// Flow-level options.
#[derive(Clone, Debug)]
pub struct FlowParams {
    pub seed: u64,
    pub sa: SaParams,
    pub router: RouterParams,
    /// α values to sweep (best post-route critical path wins). Empty ⇒
    /// single run with `sa.alpha`.
    pub alpha_sweep: Vec<f64>,
    /// Streamed elements for the run-time model (64x64 image default).
    pub workload_items: usize,
    /// Routing layer.
    pub bit_width: u8,
}

impl Default for FlowParams {
    fn default() -> Self {
        FlowParams {
            seed: 1,
            sa: SaParams::default(),
            router: RouterParams::default(),
            alpha_sweep: vec![],
            workload_items: 4096,
            bit_width: 16,
        }
    }
}

/// Everything the flow produces for one application.
#[derive(Clone, Debug)]
pub struct FlowResult {
    pub packed: PackedApp,
    pub placement: Placement,
    pub routing: RoutingResult,
    pub timing: TimingReport,
    /// α that won the sweep (or the single configured α).
    pub alpha: f64,
    pub placement_cost: f64,
}

/// Run the full flow with the native global placer.
pub fn run_flow(
    ic: &Interconnect,
    app: &AppGraph,
    params: &FlowParams,
) -> Result<FlowResult, RoutingFailed> {
    run_flow_with(ic, app, params, &NativePlacer::default())
}

/// Run the full flow with an explicit global-placement backend (native or
/// the PJRT artifact executor).
pub fn run_flow_with(
    ic: &Interconnect,
    app: &AppGraph,
    params: &FlowParams,
    placer: &dyn GlobalPlacer,
) -> Result<FlowResult, RoutingFailed> {
    run_flow_scratch(ic, app, params, placer, &mut RouterScratch::new())
}

/// [`run_flow_with`], reusing caller-owned PathFinder buffers across the
/// α sweep's routes — and, for the DSE engine's workers, across every
/// sweep point the worker processes. Bit-identical to a fresh-scratch
/// call.
pub fn run_flow_scratch(
    ic: &Interconnect,
    app: &AppGraph,
    params: &FlowParams,
    placer: &dyn GlobalPlacer,
    scratch: &mut RouterScratch,
) -> Result<FlowResult, RoutingFailed> {
    let prepared = prepare_point(ic, app, params);
    let (xs, ys) = {
        let mut g = obs::stage(spans::GLOBAL_PLACE);
        g.args(1, 0); // scalar path: batch of one
        placer.optimize(&prepared.problem, &prepared.xs0, &prepared.ys0)
    };
    finish_flow_scratch(ic, &prepared, &xs, &ys, params, scratch)
}

/// Phase 1 of the flow — everything *before* the global solve: packing,
/// the dense analytic problem, and the seeded initial spread. Split out
/// so the DSE executor can prepare a whole per-config job group, solve it
/// with one [`GlobalPlacer::place_batch`] call, and then
/// [`finish_flow_scratch`] each point. `prepare` + `optimize` + `finish`
/// is exactly [`run_flow_scratch`].
pub struct PreparedPoint {
    /// Packed application (Const/Reg vertices absorbed into host PEs).
    pub packed: PackedApp,
    /// The dense Eq. 1 problem for the packed app on this fabric.
    pub problem: GlobalProblem,
    /// Seeded initial x positions.
    pub xs0: Vec<f32>,
    /// Seeded initial y positions.
    pub ys0: Vec<f32>,
}

/// Pack `app` and build its global-placement problem (flow stages 1-2a).
pub fn prepare_point(ic: &Interconnect, app: &AppGraph, params: &FlowParams) -> PreparedPoint {
    // 1. Packing.
    let packed = {
        let _s = obs::stage(spans::PACK);
        pack(app)
    };
    // 2a. Global-placement problem construction (analytic; Eq. 1).
    let (xs0, ys0) = initial_positions(&packed.app, ic, params.seed);
    let problem = build_global_problem(&packed.app, ic);
    PreparedPoint { packed, problem, xs0, ys0 }
}

/// Flow stages 2b-5: legalize the globally-placed continuous positions,
/// then detailed placement + routing over the α sweep, then STA.
/// Bit-identical to the tail of [`run_flow_scratch`] by construction —
/// it *is* that tail.
pub fn finish_flow_scratch(
    ic: &Interconnect,
    prepared: &PreparedPoint,
    xs: &[f32],
    ys: &[f32],
    params: &FlowParams,
    scratch: &mut RouterScratch,
) -> Result<FlowResult, RoutingFailed> {
    let packed = &prepared.packed;
    // 2b. Legalization of the analytic solution (the `pnr.legalize`
    // span is recorded inside `legalize` itself).
    let seed_placement = legalize(&packed.app, ic, xs, ys).map_err(|e| RoutingFailed {
        iterations: 0,
        overused_nodes: 0,
        detail: format!("legalization failed: {e}"),
    })?;

    // 3+4. Detailed placement (Eq. 2) + routing, over the α sweep.
    let alphas: Vec<f64> =
        if params.alpha_sweep.is_empty() { vec![params.sa.alpha] } else { params.alpha_sweep.clone() };
    let nets = packed.app.nets();

    let mut best: Option<FlowResult> = None;
    let mut last_err: Option<RoutingFailed> = None;
    for &alpha in &alphas {
        let sa = SaParams { alpha, seed: params.seed ^ alpha.to_bits(), ..params.sa };
        let (placement, placement_cost) =
            detailed_place(&packed.app, ic, &nets, seed_placement.clone(), &sa);
        let routed = route_with_scratch(
            ic,
            &packed.app,
            &placement,
            params.bit_width,
            &params.router,
            scratch,
        );
        match routed {
            Ok(routing) => {
                let timing = {
                    let _s = obs::stage(spans::STA);
                    analyze(ic, packed, &routing, params.bit_width, params.workload_items)
                };
                let better = best
                    .as_ref()
                    .map_or(true, |b| timing.critical_path_ps < b.timing.critical_path_ps);
                if better {
                    best = Some(FlowResult {
                        packed: packed.clone(),
                        placement,
                        routing,
                        timing,
                        alpha,
                        placement_cost,
                    });
                }
            }
            Err(e) => last_err = Some(e),
        }
    }

    best.ok_or_else(|| {
        last_err.unwrap_or(RoutingFailed {
            iterations: 0,
            overused_nodes: 0,
            detail: "no alpha produced a routable placement".into(),
        })
    })
}

/// Refinement temperature for warm-started detailed placement (see
/// [`refine_place`]): low enough that the donor placement — already the
/// output of a full anneal on a neighboring configuration — survives
/// mostly intact, so its routed trees keep their terminals.
pub const REFINE_TEMP0: f64 = 0.05;

/// A donor's solution, resolved onto the target fabric: the final
/// placement (packed-vertex order) and, per net (packed-app net order),
/// the routed sink paths re-resolved to this graph's node ids — `None`
/// where the axis change removed any node
/// (see [`crate::dse::PnrArtifact::resolve`]).
pub struct WarmSeed<'a> {
    pub placement: &'a [(u16, u16)],
    pub net_paths: Vec<Option<Vec<Vec<NodeId>>>>,
}

/// The warm-started flow: pack, map the donor placement onto this
/// fabric ([`seed_placement`]), polish it with a low-temperature anneal
/// ([`refine_place`] — the donor fulfills the global stage's role, so
/// GD is skipped), then replay the donor's routed trees and repair the
/// rest ([`route_with_seed`]). When tree replay cannot converge, the
/// routing falls back to scratch PathFinder on the refined placement
/// (all nets counted as rerouted); a donor that cannot even seed the
/// placement (e.g. the target array shrank below the app) is an error —
/// callers fall back to the full scratch flow.
pub fn run_flow_warm(
    ic: &Interconnect,
    app: &AppGraph,
    params: &FlowParams,
    seed: &WarmSeed,
    scratch: &mut RouterScratch,
) -> Result<(FlowResult, RouteReuse), RoutingFailed> {
    let packed = {
        let _s = obs::stage(spans::PACK);
        pack(app)
    };
    let start = seed_placement(&packed.app, ic, seed.placement).map_err(|e| RoutingFailed {
        iterations: 0,
        overused_nodes: 0,
        detail: format!("warm-start legalization failed: {e}"),
    })?;
    let nets = packed.app.nets();
    if seed.net_paths.len() != nets.len() {
        return Err(RoutingFailed {
            iterations: 0,
            overused_nodes: 0,
            detail: format!(
                "donor has {} nets, app has {}",
                seed.net_paths.len(),
                nets.len()
            ),
        });
    }

    let alphas: Vec<f64> =
        if params.alpha_sweep.is_empty() { vec![params.sa.alpha] } else { params.alpha_sweep.clone() };

    let mut best: Option<(FlowResult, RouteReuse)> = None;
    let mut last_err: Option<RoutingFailed> = None;
    for &alpha in &alphas {
        let sa = SaParams { alpha, seed: params.seed ^ alpha.to_bits(), ..params.sa };
        let (placement, placement_cost) =
            refine_place(&packed.app, ic, &nets, start.clone(), &sa, REFINE_TEMP0);
        let routed = route_with_seed(
            ic,
            &packed.app,
            &placement,
            params.bit_width,
            &params.router,
            scratch,
            &seed.net_paths,
        );
        let (routing, reuse) = match routed {
            Ok(x) => x,
            // Seed replay could not converge — negotiate everything from
            // scratch on the refined placement before giving up.
            Err(_) => match route_with_scratch(
                ic,
                &packed.app,
                &placement,
                params.bit_width,
                &params.router,
                scratch,
            ) {
                Ok(r) => {
                    let n = r.trees.len();
                    (r, RouteReuse { nets_reused: 0, nets_rerouted: n })
                }
                Err(e) => {
                    last_err = Some(e);
                    continue;
                }
            },
        };
        let timing = {
            let _s = obs::stage(spans::STA);
            analyze(ic, &packed, &routing, params.bit_width, params.workload_items)
        };
        let better = best
            .as_ref()
            .map_or(true, |(b, _)| timing.critical_path_ps < b.timing.critical_path_ps);
        if better {
            best = Some((
                FlowResult {
                    packed: packed.clone(),
                    placement,
                    routing,
                    timing,
                    alpha,
                    placement_cost,
                },
                reuse,
            ));
        }
    }

    best.ok_or_else(|| {
        last_err.unwrap_or(RoutingFailed {
            iterations: 0,
            overused_nodes: 0,
            detail: "no alpha produced a routable warm-started placement".into(),
        })
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::dsl::{create_uniform_interconnect, InterconnectConfig};

    fn ic() -> Interconnect {
        create_uniform_interconnect(&InterconnectConfig {
            width: 8,
            height: 8,
            num_tracks: 5,
            mem_column_period: 3,
            ..Default::default()
        })
    }

    #[test]
    fn flow_runs_entire_suite() {
        let ic = ic();
        let params = FlowParams {
            sa: SaParams { moves_per_node: 10, ..Default::default() },
            ..Default::default()
        };
        for app in apps::suite() {
            let r = run_flow(&ic, &app, &params)
                .unwrap_or_else(|e| panic!("{} failed: {e}", app.name));
            assert!(r.timing.critical_path_ps > 0.0, "{}", app.name);
            assert_eq!(r.routing.trees.len(), r.packed.app.nets().len());
        }
    }

    #[test]
    fn alpha_sweep_never_worse_than_single_alpha() {
        let ic = ic();
        let app = apps::gaussian();
        let base = FlowParams {
            sa: SaParams { moves_per_node: 10, ..Default::default() },
            ..Default::default()
        };
        let single = run_flow(&ic, &app, &base).unwrap();
        let swept = run_flow(
            &ic,
            &app,
            &FlowParams { alpha_sweep: vec![1.0, 2.0, 4.0], ..base },
        )
        .unwrap();
        assert!(swept.timing.critical_path_ps <= single.timing.critical_path_ps + 1e-9);
    }

    #[test]
    fn warm_flow_reuses_own_solution_and_stays_legal() {
        let ic = ic();
        let app = apps::gaussian();
        let params = FlowParams {
            sa: SaParams { moves_per_node: 8, ..Default::default() },
            ..Default::default()
        };
        let donor = run_flow(&ic, &app, &params).unwrap();
        let seed = WarmSeed {
            placement: &donor.placement.pos,
            net_paths: donor.routing.trees.iter().map(|t| Some(t.sink_paths.clone())).collect(),
        };
        let mut scratch = RouterScratch::new();
        let (warm, reuse) = run_flow_warm(&ic, &app, &params, &seed, &mut scratch).unwrap();
        warm.placement.check(&warm.packed.app, &ic).unwrap();
        assert_eq!(reuse.nets_reused + reuse.nets_rerouted, warm.routing.trees.len());
        assert!(reuse.nets_reused > 0, "self-seed must reuse trees");
        assert!(warm.timing.critical_path_ps > 0.0);
        // A donor whose vertex count cannot match the app is a loud
        // error (callers fall back to the scratch flow).
        let bad = WarmSeed { placement: &donor.placement.pos[1..], net_paths: vec![] };
        assert!(run_flow_warm(&ic, &app, &params, &bad, &mut scratch).is_err());
    }

    #[test]
    fn flow_runs_under_every_search_core() {
        // End-to-end coverage for the result-changing cores: the whole
        // flow (place + route + STA) must succeed and stay self-
        // consistent whatever frontier drives PathFinder. (Bit-identity
        // of the execution-strategy cores is golden-tested in route.rs
        // and tests/router_variants.rs.)
        use crate::pnr::route::SearchCore;
        let ic = ic();
        let app = apps::gaussian();
        for core in SearchCore::ALL {
            let params = FlowParams {
                sa: SaParams { moves_per_node: 8, ..Default::default() },
                router: RouterParams { search_core: core, ..Default::default() },
                ..Default::default()
            };
            let r = run_flow(&ic, &app, &params)
                .unwrap_or_else(|e| panic!("{} failed: {e}", core.name()));
            assert!(r.timing.critical_path_ps > 0.0, "{}", core.name());
            assert_eq!(r.routing.trees.len(), r.packed.app.nets().len());
            assert!(r.routing.route_expansions > 0, "{}", core.name());
        }
    }

    #[test]
    fn flow_is_deterministic() {
        let ic = ic();
        let app = apps::camera();
        let params = FlowParams {
            sa: SaParams { moves_per_node: 8, ..Default::default() },
            ..Default::default()
        };
        let a = run_flow(&ic, &app, &params).unwrap();
        let b = run_flow(&ic, &app, &params).unwrap();
        assert_eq!(a.placement.pos, b.placement.pos);
        assert_eq!(a.timing.critical_path_ps, b.timing.critical_path_ps);
    }
}
