//! Placement (§3.4, stages 2-3 of PnR).
//!
//! **Global placement** is analytic: gradient-based minimization of a
//! differentiable star-model wirelength (the L2 approximation of HPWL the
//! paper uses "to speed up the algorithm") plus a quadratic legalization
//! term pulling MEM vertices toward MEM columns (Eq. 1). The objective is
//! implemented twice with identical semantics: natively here (fallback +
//! baseline) and as an AOT-compiled JAX/Pallas artifact executed through
//! PJRT (`crate::runtime`) — the repo's L2/L1 layers.
//!
//! **Detailed placement** is simulated annealing on Eq. 2:
//! `cost_net = (HPWL_net − γ·|Area_net ∩ Area_existing|)^α`, where γ
//! discourages powering on pass-through tiles and α penalizes long nets;
//! the paper sweeps α in 1..20 and keeps the best post-route result.

use std::collections::HashMap;

use crate::ir::{CoreKind, Interconnect};
use crate::util::rng::Rng;

use super::app::{AppGraph, AppNodeId, Net};

/// A full placement: tile coordinates per application vertex.
#[derive(Clone, Debug, PartialEq)]
pub struct Placement {
    pub pos: Vec<(u16, u16)>,
}

impl Placement {
    pub fn of(&self, id: AppNodeId) -> (u16, u16) {
        self.pos[id.index()]
    }

    /// Check legality: in-bounds, one vertex per tile, core kinds match.
    pub fn check(&self, app: &AppGraph, ic: &Interconnect) -> Result<(), String> {
        if self.pos.len() != app.len() {
            return Err("placement size mismatch".into());
        }
        let mut used: HashMap<(u16, u16), AppNodeId> = HashMap::new();
        for (id, n) in app.iter() {
            let (x, y) = self.of(id);
            if x >= ic.width || y >= ic.height {
                return Err(format!("`{}` out of bounds at ({x},{y})", n.name));
            }
            if let Some(prev) = used.insert((x, y), id) {
                return Err(format!(
                    "`{}` and `{}` share tile ({x},{y})",
                    app.node(prev).name,
                    n.name
                ));
            }
            let need = n.op.core_kind();
            let have = ic.tile(x, y).core.kind;
            if need != have {
                return Err(format!(
                    "`{}` needs {} but tile ({x},{y}) is {}",
                    n.name,
                    need.name(),
                    have.name()
                ));
            }
        }
        Ok(())
    }

    /// Half-perimeter wirelength of one net under this placement.
    pub fn hpwl(&self, net: &Net) -> f64 {
        let mut min_x = u16::MAX;
        let mut max_x = 0;
        let mut min_y = u16::MAX;
        let mut max_y = 0;
        let mut visit = |(x, y): (u16, u16)| {
            min_x = min_x.min(x);
            max_x = max_x.max(x);
            min_y = min_y.min(y);
            max_y = max_y.max(y);
        };
        visit(self.of(net.src));
        for &(s, _) in &net.sinks {
            visit(self.of(s));
        }
        (max_x - min_x) as f64 + (max_y - min_y) as f64
    }

    /// Total HPWL over all nets.
    pub fn total_hpwl(&self, nets: &[Net]) -> f64 {
        nets.iter().map(|n| self.hpwl(n)).sum()
    }
}

// ---------------------------------------------------------------------------
// Global placement objective (shared semantics with the JAX artifact)
// ---------------------------------------------------------------------------

/// The analytic global-placement problem in the padded dense form consumed
/// by both the native optimizer and the AOT JAX artifact: `memberships`
/// holds, for each net, the vertex indices of its pins (-1 padding).
#[derive(Clone, Debug)]
pub struct GlobalProblem {
    pub n_nodes: usize,
    /// `pins[net][k]` = vertex index or -1.
    pub pins: Vec<Vec<i32>>,
    /// Per-vertex target-column legalization: `Some(col)` pulls x toward
    /// `col` (MEM vertices toward their nearest MEM column).
    pub column_pull: Vec<Option<f32>>,
    /// Array bounds for clamping.
    pub width: f32,
    pub height: f32,
}

/// Quadratic star-model wirelength + legalization (Eq. 1), and its
/// gradient. This exact function is what `python/compile/model.py`
/// lowers to HLO; keep the two in lockstep (pytest cross-checks via the
/// dumped test vectors, rust cross-checks via `runtime` tests).
pub fn global_cost_grad(
    p: &GlobalProblem,
    xs: &[f32],
    ys: &[f32],
    lambda_mem: f32,
) -> (f32, Vec<f32>, Vec<f32>) {
    let mut gx = vec![0.0f32; p.n_nodes];
    let mut gy = vec![0.0f32; p.n_nodes];
    let cost = global_cost_grad_into(p, xs, ys, lambda_mem, &mut gx, &mut gy);
    (cost, gx, gy)
}

/// [`global_cost_grad`] writing the gradient into caller-owned buffers
/// (zeroed here), so the optimizer loops — scalar and batched — run
/// allocation-free. Identical arithmetic, in identical order.
pub fn global_cost_grad_into(
    p: &GlobalProblem,
    xs: &[f32],
    ys: &[f32],
    lambda_mem: f32,
    gx: &mut [f32],
    gy: &mut [f32],
) -> f32 {
    let mut cost = 0.0f32;
    gx[..p.n_nodes].fill(0.0);
    gy[..p.n_nodes].fill(0.0);
    for net in &p.pins {
        let pins = net.iter().filter(|&&i| i >= 0).map(|&i| i as usize);
        let k = pins.clone().count();
        if k < 2 {
            continue;
        }
        let kf = k as f32;
        let cx = pins.clone().map(|i| xs[i]).sum::<f32>() / kf;
        let cy = pins.clone().map(|i| ys[i]).sum::<f32>() / kf;
        for i in pins {
            let dx = xs[i] - cx;
            let dy = ys[i] - cy;
            cost += dx * dx + dy * dy;
            // d/dxi of sum_j (xj - cx)^2 = 2(xi - cx) (the centroid terms
            // cancel: sum_j 2(xj-cx)·(-1/k) = 0).
            gx[i] += 2.0 * dx;
            gy[i] += 2.0 * dy;
        }
    }
    for i in 0..p.n_nodes {
        if let Some(col) = p.column_pull[i] {
            let dx = xs[i] - col;
            cost += lambda_mem * dx * dx;
            gx[i] += lambda_mem * 2.0 * dx;
        }
    }
    cost
}

/// Build the dense problem from a packed app + interconnect.
pub fn build_global_problem(app: &AppGraph, ic: &Interconnect) -> GlobalProblem {
    let mem_cols: Vec<u16> = (0..ic.width)
        .filter(|&x| ic.tile(x, 0).core.kind == CoreKind::Mem)
        .collect();
    let column_pull = app
        .iter()
        .map(|(_, n)| {
            if n.op.core_kind() == CoreKind::Mem && !mem_cols.is_empty() {
                // Pull toward the array-centre-most MEM column; the
                // optimizer refines via the quadratic well, legalization
                // snaps to the actual nearest column.
                let mid = ic.width as f32 / 2.0;
                // `total_cmp`, not `partial_cmp(..).unwrap()`: the
                // distances here cannot be NaN today, but a panic-free
                // total order costs nothing and the float-ordering lint
                // in CI bans the fallible form outright.
                let col = mem_cols
                    .iter()
                    .copied()
                    .min_by(|a, b| {
                        (*a as f32 - mid).abs().total_cmp(&(*b as f32 - mid).abs())
                    })
                    .unwrap();
                Some(col as f32)
            } else {
                None
            }
        })
        .collect();
    GlobalProblem {
        n_nodes: app.len(),
        pins: app
            .nets()
            .iter()
            .map(|n| {
                let mut v: Vec<i32> = vec![n.src.0 as i32];
                v.extend(n.sinks.iter().map(|&(s, _)| s.0 as i32));
                v
            })
            .collect(),
        column_pull,
        width: ic.width as f32,
        height: ic.height as f32,
    }
}

/// One problem of a batched solve: the dense problem plus its initial
/// continuous positions. Borrowed, so the DSE executor can batch a whole
/// job group without copying problem data.
#[derive(Clone, Copy, Debug)]
pub struct PlacementInstance<'a> {
    /// The dense analytic problem.
    pub problem: &'a GlobalProblem,
    /// Initial x positions (`problem.n_nodes` long).
    pub xs0: &'a [f32],
    /// Initial y positions (`problem.n_nodes` long).
    pub ys0: &'a [f32],
}

/// Backend executing the global-placement optimization loop. The native
/// implementation lives here; `crate::runtime::PjrtPlacer` implements the
/// same trait on top of the AOT JAX/Pallas artifact.
pub trait GlobalPlacer {
    /// Return optimized continuous positions (xs, ys).
    fn optimize(&self, p: &GlobalProblem, xs0: &[f32], ys0: &[f32]) -> (Vec<f32>, Vec<f32>);

    /// Solve N independent problems in one call, returning one
    /// `(xs, ys)` pair per instance, in order.
    ///
    /// The default implementation loops [`GlobalPlacer::optimize`], so
    /// every backend is batchable. The contract an override must honor,
    /// because the DSE cache and the engine's determinism both depend
    /// on it: a problem's result bits may depend only on the problem
    /// itself — never on batch composition or size. The struct-of-arrays
    /// [`BatchedNativePlacer`] satisfies it in the strongest form
    /// (bit-identical to the sequential `optimize` loop, hence its
    /// shared `"native-gd"` name); a backend whose batched program is
    /// numerically different from its scalar one (the batched-HLO
    /// `PjrtPlacer` path) must instead route `optimize` and
    /// `place_batch` through the same program *and* carry a distinct
    /// [`GlobalPlacer::name`] so its results never alias the scalar
    /// backend's cache entries.
    fn place_batch(&self, batch: &[PlacementInstance<'_>]) -> Vec<(Vec<f32>, Vec<f32>)> {
        batch.iter().map(|b| self.optimize(b.problem, b.xs0, b.ys0)).collect()
    }

    fn name(&self) -> &'static str;
}

/// Native gradient-descent-with-momentum placer (the conjugate-gradient
/// stand-in; same objective, same fixed iteration budget as the artifact).
pub struct NativePlacer {
    pub iters: usize,
    pub lr: f32,
    pub momentum: f32,
    pub lambda_mem: f32,
}

impl Default for NativePlacer {
    fn default() -> Self {
        NativePlacer { iters: 150, lr: 0.12, momentum: 0.9, lambda_mem: 0.4 }
    }
}

impl GlobalPlacer for NativePlacer {
    fn optimize(&self, p: &GlobalProblem, xs0: &[f32], ys0: &[f32]) -> (Vec<f32>, Vec<f32>) {
        let mut xs = xs0.to_vec();
        let mut ys = ys0.to_vec();
        let mut vx = vec![0.0f32; p.n_nodes];
        let mut vy = vec![0.0f32; p.n_nodes];
        let mut gx = vec![0.0f32; p.n_nodes];
        let mut gy = vec![0.0f32; p.n_nodes];
        for _ in 0..self.iters {
            global_cost_grad_into(p, &xs, &ys, self.lambda_mem, &mut gx, &mut gy);
            for i in 0..p.n_nodes {
                vx[i] = self.momentum * vx[i] - self.lr * gx[i];
                vy[i] = self.momentum * vy[i] - self.lr * gy[i];
                xs[i] = (xs[i] + vx[i]).clamp(0.0, p.width - 1.0);
                ys[i] = (ys[i] + vy[i]).clamp(0.0, p.height - 1.0);
            }
        }
        (xs, ys)
    }

    fn name(&self) -> &'static str {
        "native-gd"
    }
}

/// Struct-of-arrays batched variant of [`NativePlacer`]: runs the
/// momentum-GD loop over N problems in one pass. Positions, velocities
/// and gradients for the whole batch live in flat concatenated arrays
/// (per-problem spans), the step rule is shared, and a per-problem
/// convergence mask retires problems whose state has reached an exact
/// fixed point (gradient and velocity all zero — every further scalar
/// iteration would be a no-op, so masking cannot change the result).
///
/// Per problem, the arithmetic — order included — is exactly the scalar
/// [`NativePlacer`] loop's, so `place_batch` is bit-identical to
/// the sequential loop for any batch size. `name()` is therefore also
/// `"native-gd"`: the DSE cache keys results by the *math* of the
/// backend, not its execution strategy, and batched/scalar runs must
/// share cache entries. The wrapper embeds the scalar solver — one set
/// of hyperparameters, so the two can never drift apart.
#[derive(Default)]
pub struct BatchedNativePlacer(pub NativePlacer);

impl GlobalPlacer for BatchedNativePlacer {
    fn optimize(&self, p: &GlobalProblem, xs0: &[f32], ys0: &[f32]) -> (Vec<f32>, Vec<f32>) {
        self.0.optimize(p, xs0, ys0)
    }

    fn place_batch(&self, batch: &[PlacementInstance<'_>]) -> Vec<(Vec<f32>, Vec<f32>)> {
        // Per-problem spans into the concatenated state arrays.
        let mut offsets = Vec::with_capacity(batch.len() + 1);
        let mut total = 0usize;
        offsets.push(0);
        for b in batch {
            total += b.problem.n_nodes;
            offsets.push(total);
        }
        let mut xs = vec![0.0f32; total];
        let mut ys = vec![0.0f32; total];
        for (b, inst) in batch.iter().enumerate() {
            xs[offsets[b]..offsets[b + 1]].copy_from_slice(inst.xs0);
            ys[offsets[b]..offsets[b + 1]].copy_from_slice(inst.ys0);
        }
        let mut vx = vec![0.0f32; total];
        let mut vy = vec![0.0f32; total];
        let mut gx = vec![0.0f32; total];
        let mut gy = vec![0.0f32; total];
        let mut active = vec![true; batch.len()];
        let mut live = batch.len();

        for _ in 0..self.0.iters {
            if live == 0 {
                break;
            }
            // Gradient pass: every live problem's Eq. 1 gradient, each
            // written into its own span.
            for (b, inst) in batch.iter().enumerate() {
                if !active[b] {
                    continue;
                }
                let s = offsets[b]..offsets[b + 1];
                global_cost_grad_into(
                    inst.problem,
                    &xs[s.clone()],
                    &ys[s.clone()],
                    self.0.lambda_mem,
                    &mut gx[s.clone()],
                    &mut gy[s],
                );
            }
            // Update pass: one shared momentum-GD step rule over the
            // concatenated arrays, clamped per problem's bounds.
            for (b, inst) in batch.iter().enumerate() {
                if !active[b] {
                    continue;
                }
                let (w, h) = (inst.problem.width - 1.0, inst.problem.height - 1.0);
                let mut settled = true;
                for i in offsets[b]..offsets[b + 1] {
                    vx[i] = self.0.momentum * vx[i] - self.0.lr * gx[i];
                    vy[i] = self.0.momentum * vy[i] - self.0.lr * gy[i];
                    xs[i] = (xs[i] + vx[i]).clamp(0.0, w);
                    ys[i] = (ys[i] + vy[i]).clamp(0.0, h);
                    settled &= vx[i] == 0.0 && vy[i] == 0.0 && gx[i] == 0.0 && gy[i] == 0.0;
                }
                // Exact fixed point: positions are clamped copies of the
                // previous iterate and every future step repeats this one
                // verbatim, so retiring the problem is bit-exact.
                if settled {
                    active[b] = false;
                    live -= 1;
                }
            }
        }

        (0..batch.len())
            .map(|b| {
                (xs[offsets[b]..offsets[b + 1]].to_vec(), ys[offsets[b]..offsets[b + 1]].to_vec())
            })
            .collect()
    }

    fn name(&self) -> &'static str {
        "native-gd"
    }
}

/// Deterministic initial spread: vertices on a jittered grid around the
/// array centre.
pub fn initial_positions(app: &AppGraph, ic: &Interconnect, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let cx = ic.width as f32 / 2.0;
    let cy = ic.height as f32 / 2.0;
    let spread = (ic.width.min(ic.height) as f32 / 4.0).max(1.0);
    let mut xs = Vec::with_capacity(app.len());
    let mut ys = Vec::with_capacity(app.len());
    for _ in 0..app.len() {
        xs.push(cx + (rng.f64() as f32 - 0.5) * spread);
        ys.push(cy + (rng.f64() as f32 - 0.5) * spread);
    }
    (xs, ys)
}

// ---------------------------------------------------------------------------
// Legalization: snap continuous positions to distinct compatible tiles
// ---------------------------------------------------------------------------

/// Snap continuous positions onto legal tiles: nearest free tile of the
/// right core kind, searched in expanding rings.
pub fn legalize(
    app: &AppGraph,
    ic: &Interconnect,
    xs: &[f32],
    ys: &[f32],
) -> Result<Placement, String> {
    let _span = crate::obs::stage(crate::obs::span::names::LEGALIZE);
    let mut used = vec![false; ic.width as usize * ic.height as usize];
    let mut pos = vec![(0u16, 0u16); app.len()];
    // Place in order of "constrainedness": MEM first (fewer sites).
    let mut order: Vec<AppNodeId> = app.ids().collect();
    order.sort_by_key(|&id| match app.node(id).op.core_kind() {
        CoreKind::Mem => 0,
        CoreKind::Io => 1,
        CoreKind::Pe => 2,
    });
    for id in order {
        let kind = app.node(id).op.core_kind();
        let (fx, fy) = (xs[id.index()], ys[id.index()]);
        let mut best: Option<(f32, u16, u16)> = None;
        // Scan only compatible sites (frozen per-kind lists, row-major —
        // the same order as a full-grid scan, so tie-breaks are
        // unchanged) instead of testing every tile's core kind.
        for &(x, y) in ic.sites_of(kind) {
            if used[y as usize * ic.width as usize + x as usize] {
                continue;
            }
            let d = (x as f32 - fx).powi(2) + (y as f32 - fy).powi(2);
            if best.map_or(true, |(bd, _, _)| d < bd) {
                best = Some((d, x, y));
            }
        }
        let (_, x, y) = best.ok_or_else(|| {
            format!("no free {} tile for `{}`", kind.name(), app.node(id).name)
        })?;
        used[y as usize * ic.width as usize + x as usize] = true;
        pos[id.index()] = (x, y);
    }
    let placement = Placement { pos };
    placement.check(app, ic)?;
    Ok(placement)
}

// ---------------------------------------------------------------------------
// Detailed placement: simulated annealing on Eq. 2
// ---------------------------------------------------------------------------

/// SA hyperparameters (γ and α of Eq. 2 plus schedule knobs).
#[derive(Clone, Copy, Debug)]
pub struct SaParams {
    /// Pass-through-tile reuse bonus weight (γ).
    pub gamma: f64,
    /// Route-length penalty exponent (α); the paper sweeps 1..20.
    pub alpha: f64,
    /// Moves per temperature step, scaled by vertex count.
    pub moves_per_node: usize,
    /// Geometric cooling factor.
    pub cooling: f64,
    pub seed: u64,
}

impl Default for SaParams {
    fn default() -> Self {
        SaParams { gamma: 0.3, alpha: 1.0, moves_per_node: 40, cooling: 0.92, seed: 0xCA7A1 }
    }
}

struct SaState<'a> {
    app: &'a AppGraph,
    ic: &'a Interconnect,
    nets: &'a [Net],
    place: Placement,
    /// Occupancy grid: vertex id per tile.
    grid: Vec<Option<AppNodeId>>,
    /// Net indices touching each vertex (incremental cost evaluation).
    nets_of: Vec<Vec<u32>>,
    /// Cached Eq. 2 cost per net (valid between accepted moves).
    net_cost_cache: Vec<f64>,
    /// Cached bounding box per net: (min_x, max_x, min_y, max_y).
    net_bbox: Vec<(u16, u16, u16, u16)>,
    /// Scratch: per-net "already queued" epoch marker.
    mark: Vec<u32>,
    epoch: u32,
    /// Reusable buffers for the per-move affected-net set.
    affected_scratch: Vec<u32>,
    newcost_scratch: Vec<(f64, (u16, u16, u16, u16))>,
}

impl<'a> SaState<'a> {
    fn new(
        app: &'a AppGraph,
        ic: &'a Interconnect,
        nets: &'a [Net],
        place: Placement,
        grid: Vec<Option<AppNodeId>>,
    ) -> SaState<'a> {
        let mut nets_of: Vec<Vec<u32>> = vec![Vec::new(); app.len()];
        for (ni, net) in nets.iter().enumerate() {
            nets_of[net.src.index()].push(ni as u32);
            for &(sv, _) in &net.sinks {
                if !nets_of[sv.index()].contains(&(ni as u32)) {
                    nets_of[sv.index()].push(ni as u32);
                }
            }
        }
        SaState {
            app,
            ic,
            nets,
            place,
            grid,
            nets_of,
            net_cost_cache: Vec::new(),
            net_bbox: Vec::new(),
            mark: vec![0; nets.len()],
            epoch: 0,
            affected_scratch: Vec::with_capacity(64),
            newcost_scratch: Vec::new(),
        }
    }

    fn tile_index(&self, x: u16, y: u16) -> usize {
        y as usize * self.ic.width as usize + x as usize
    }

    /// Bounding box of a net under the current placement.
    fn bbox_of(&self, net: &Net) -> (u16, u16, u16, u16) {
        let mut min_x = u16::MAX;
        let mut max_x = 0;
        let mut min_y = u16::MAX;
        let mut max_y = 0;
        let mut visit = |(x, y): (u16, u16)| {
            min_x = min_x.min(x);
            max_x = max_x.max(x);
            min_y = min_y.min(y);
            max_y = max_y.max(y);
        };
        visit(self.place.of(net.src));
        for &(s, _) in &net.sinks {
            visit(self.place.of(s));
        }
        (min_x, max_x, min_y, max_y)
    }

    /// Eq. 2 for one net: (HPWL − γ·overlap)^α where overlap counts
    /// *occupied* tiles inside the net's bounding box — routing through
    /// already-powered tiles is free-ish, pass-through tiles cost.
    fn net_cost_at(&self, net: &Net, bbox: (u16, u16, u16, u16), gamma: f64, alpha: f64) -> f64 {
        let (min_x, max_x, min_y, max_y) = bbox;
        let hpwl = (max_x - min_x) as f64 + (max_y - min_y) as f64;
        let mut overlap = 0usize;
        for y in min_y..=max_y {
            for x in min_x..=max_x {
                if self.grid[y as usize * self.ic.width as usize + x as usize].is_some() {
                    overlap += 1;
                }
            }
        }
        // Terminals themselves are always occupied; exclude them so an
        // isolated 2-pin net has zero bonus.
        let terminals = 1 + net.sinks.len();
        let bonus = gamma * overlap.saturating_sub(terminals.min(overlap)) as f64;
        (hpwl - bonus).max(0.0).powf(alpha)
    }

    fn net_cost(&self, net: &Net, gamma: f64, alpha: f64) -> f64 {
        self.net_cost_at(net, self.bbox_of(net), gamma, alpha)
    }

    fn total_cost(&self, gamma: f64, alpha: f64) -> f64 {
        self.nets.iter().map(|n| self.net_cost(n, gamma, alpha)).sum()
    }

    /// Refresh every cache entry (called once at the start of annealing).
    fn rebuild_caches(&mut self, gamma: f64, alpha: f64) {
        self.net_bbox = self.nets.iter().map(|n| self.bbox_of(n)).collect();
        self.net_cost_cache = self
            .nets
            .iter()
            .zip(&self.net_bbox)
            .map(|(n, &b)| self.net_cost_at(n, b, gamma, alpha))
            .collect();
    }

    /// Net indices affected by occupancy/terminal changes at the given
    /// tiles and vertices: member nets of the moved vertices plus any net
    /// whose cached bbox covers a changed tile. Deduplicated via epoch
    /// marks. O(nets) with O(1) per-net tests — the expensive bbox scans
    /// only run for the returned subset.
    fn affected_nets(
        &mut self,
        verts: impl Iterator<Item = AppNodeId>,
        tiles: &[(u16, u16)],
    ) -> Vec<u32> {
        self.epoch += 1;
        let mut out = std::mem::take(&mut self.affected_scratch);
        out.clear();
        for v in verts {
            for &ni in &self.nets_of[v.index()] {
                if self.mark[ni as usize] != self.epoch {
                    self.mark[ni as usize] = self.epoch;
                    out.push(ni);
                }
            }
        }
        for (ni, &(min_x, max_x, min_y, max_y)) in self.net_bbox.iter().enumerate() {
            if self.mark[ni] == self.epoch {
                continue;
            }
            if tiles
                .iter()
                .any(|&(x, y)| x >= min_x && x <= max_x && y >= min_y && y <= max_y)
            {
                self.mark[ni] = self.epoch;
                out.push(ni as u32);
            }
        }
        if self.newcost_scratch.len() < out.len() {
            self.newcost_scratch.resize(out.len(), (0.0, (0, 0, 0, 0)));
        }
        out
    }

    /// Hand the affected-net buffer back for reuse by the next move.
    fn return_scratch(&mut self, buf: Vec<u32>) {
        self.affected_scratch = buf;
    }
}

/// Detailed placement: anneal `initial` under Eq. 2. Returns the improved
/// placement and its final cost.
pub fn detailed_place(
    app: &AppGraph,
    ic: &Interconnect,
    nets: &[Net],
    initial: Placement,
    params: &SaParams,
) -> (Placement, f64) {
    anneal(app, ic, nets, initial, params, None)
}

/// Low-temperature refinement for warm-started points: the same Eq. 2
/// annealer, but started at `temp0` instead of the cost-derived initial
/// temperature. A donor placement is already the *output* of a full
/// anneal on a neighboring configuration, so re-heating it would walk
/// away from the very solution the routed-tree reuse depends on; a cold
/// start only polishes it with (near-)downhill moves, keeping most net
/// terminals where the donor's routed trees expect them.
pub fn refine_place(
    app: &AppGraph,
    ic: &Interconnect,
    nets: &[Net],
    initial: Placement,
    params: &SaParams,
    temp0: f64,
) -> (Placement, f64) {
    anneal(app, ic, nets, initial, params, Some(temp0))
}

/// Map a donor placement onto `ic`: clamp tile coordinates into bounds,
/// then snap every vertex to the nearest free compatible site via
/// [`legalize`]. When the donor comes from a same-sized neighbor (the
/// common case — track/side axes do not move tiles), each vertex's own
/// tile is free and compatible at distance 0, so legalization returns
/// the donor placement exactly.
pub fn seed_placement(
    app: &AppGraph,
    ic: &Interconnect,
    donor: &[(u16, u16)],
) -> Result<Placement, String> {
    if donor.len() != app.len() {
        return Err(format!(
            "donor placement has {} vertices, app has {}",
            donor.len(),
            app.len()
        ));
    }
    let xs: Vec<f32> = donor.iter().map(|&(x, _)| x.min(ic.width - 1) as f32).collect();
    let ys: Vec<f32> = donor.iter().map(|&(_, y)| y.min(ic.height - 1) as f32).collect();
    legalize(app, ic, &xs, &ys)
}

fn anneal(
    app: &AppGraph,
    ic: &Interconnect,
    nets: &[Net],
    initial: Placement,
    params: &SaParams,
    temp0: Option<f64>,
) -> (Placement, f64) {
    let mut _span = crate::obs::stage(crate::obs::span::names::SA);
    _span.args(params.moves_per_node as u64, temp0.is_some() as u64);
    initial.check(app, ic).expect("detailed placement needs a legal start");
    let mut grid = vec![None; ic.width as usize * ic.height as usize];
    for (id, _) in app.iter() {
        let (x, y) = initial.of(id);
        grid[y as usize * ic.width as usize + x as usize] = Some(id);
    }
    let mut st = SaState::new(app, ic, nets, initial, grid);
    let mut rng = Rng::new(params.seed);

    let n = app.len().max(1);
    st.rebuild_caches(params.gamma, params.alpha);
    let mut cost: f64 = st.net_cost_cache.iter().sum();
    // Initial temperature: accept ~85% of average uphill moves early on
    // (or the caller's explicit refinement temperature).
    let mut temp = temp0.unwrap_or_else(|| (cost / nets.len().max(1) as f64).max(1.0));
    let moves = params.moves_per_node * n;

    while temp > 1e-3 {
        for _ in 0..moves {
            // Pick a vertex and a candidate tile of the same core kind.
            let id = AppNodeId(rng.below(n) as u32);
            let kind = st.app.node(id).op.core_kind();
            let (ox, oy) = st.place.of(id);
            let tx = rng.below(ic.width as usize) as u16;
            let ty = rng.below(ic.height as usize) as u16;
            if (tx, ty) == (ox, oy) || ic.core_kind_at(tx, ty) != kind {
                continue;
            }
            let other = st.grid[st.tile_index(tx, ty)];
            if let Some(o) = other {
                if st.app.node(o).op.core_kind() != kind {
                    continue; // cannot swap across kinds
                }
            }

            // Apply move (swap or relocate).
            let apply = |st: &mut SaState, to_empty: bool| {
                let gi_old = st.tile_index(ox, oy);
                let gi_new = st.tile_index(tx, ty);
                st.place.pos[id.index()] = (tx, ty);
                if to_empty {
                    st.grid[gi_old] = None;
                    st.grid[gi_new] = Some(id);
                } else {
                    let o = other.unwrap();
                    st.place.pos[o.index()] = (ox, oy);
                    st.grid[gi_old] = Some(o);
                    st.grid[gi_new] = Some(id);
                }
            };
            let revert = |st: &mut SaState| {
                let gi_old = st.tile_index(ox, oy);
                let gi_new = st.tile_index(tx, ty);
                st.place.pos[id.index()] = (ox, oy);
                st.grid[gi_old] = Some(id);
                match other {
                    Some(o) => {
                        st.place.pos[o.index()] = (tx, ty);
                        st.grid[gi_new] = Some(o);
                    }
                    None => st.grid[gi_new] = None,
                }
            };

            // Incremental Eq. 2 evaluation. Only two net families can
            // change cost: member nets of the moved vertices (their bbox
            // moves), and nets whose *unchanged* bbox covers one of the
            // two occupancy-flipped tiles. One pre-move scan finds both —
            // non-member bboxes are identical before and after the move.
            let verts = [Some(id), other];
            let tiles = [(ox, oy), (tx, ty)];
            let affected =
                st.affected_nets(verts.iter().flatten().copied(), &tiles);
            apply(&mut st, other.is_none());
            let mut delta = 0.0;
            let mut k = 0;
            while k < affected.len() {
                let ni = affected[k];
                let net = &st.nets[ni as usize];
                let bbox = st.bbox_of(net);
                let c = st.net_cost_at(net, bbox, params.gamma, params.alpha);
                delta += c - st.net_cost_cache[ni as usize];
                st.newcost_scratch[k] = (c, bbox);
                k += 1;
            }
            if delta <= 0.0 || rng.f64() < (-delta / temp).exp() {
                cost += delta;
                for (k, &ni) in affected.iter().enumerate() {
                    let (c, bbox) = st.newcost_scratch[k];
                    st.net_cost_cache[ni as usize] = c;
                    st.net_bbox[ni as usize] = bbox;
                }
                st.return_scratch(affected);
            } else {
                st.return_scratch(affected);
                revert(&mut st);
            }
        }
        temp *= params.cooling;
    }

    st.place.check(app, ic).expect("SA must preserve legality");
    (st.place, cost)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::dsl::{create_uniform_interconnect, InterconnectConfig};
    use crate::pnr::pack::pack;

    fn ic() -> Interconnect {
        create_uniform_interconnect(&InterconnectConfig {
            width: 8,
            height: 8,
            num_tracks: 3,
            mem_column_period: 3,
            reg_density: 0,
            ..Default::default()
        })
    }

    fn place_app(name: &str) -> (AppGraph, Interconnect, Placement) {
        let ic = ic();
        let app = apps::suite().into_iter().find(|a| a.name == name).unwrap();
        let packed = pack(&app).app;
        let (xs, ys) = initial_positions(&packed, &ic, 1);
        let p = build_global_problem(&packed, &ic);
        let (xs, ys) = NativePlacer::default().optimize(&p, &xs, &ys);
        let placement = legalize(&packed, &ic, &xs, &ys).unwrap();
        (packed, ic, placement)
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let ic = ic();
        let packed = pack(&apps::gaussian()).app;
        let p = build_global_problem(&packed, &ic);
        let (xs, ys) = initial_positions(&packed, &ic, 7);
        let (c0, gx, gy) = global_cost_grad(&p, &xs, &ys, 0.4);
        let eps = 1e-2f32;
        for i in [0usize, 3, 7] {
            let mut xs2 = xs.clone();
            xs2[i] += eps;
            let (c1, _, _) = global_cost_grad(&p, &xs2, &ys, 0.4);
            let fd = (c1 - c0) / eps;
            assert!((fd - gx[i]).abs() < 0.05 * gx[i].abs().max(1.0), "gx[{i}] {fd} vs {}", gx[i]);
            let mut ys2 = ys.clone();
            ys2[i] += eps;
            let (c2, _, _) = global_cost_grad(&p, &xs, &ys2, 0.4);
            let fd = (c2 - c0) / eps;
            assert!((fd - gy[i]).abs() < 0.05 * gy[i].abs().max(1.0), "gy[{i}]");
        }
    }

    #[test]
    fn global_placement_reduces_cost() {
        let ic = ic();
        let packed = pack(&apps::harris()).app;
        let p = build_global_problem(&packed, &ic);
        let (xs0, ys0) = initial_positions(&packed, &ic, 3);
        let (c0, _, _) = global_cost_grad(&p, &xs0, &ys0, 0.4);
        let (xs, ys) = NativePlacer::default().optimize(&p, &xs0, &ys0);
        let (c1, _, _) = global_cost_grad(&p, &xs, &ys, 0.4);
        assert!(c1 < c0, "optimizer must reduce cost: {c0} -> {c1}");
    }

    #[test]
    fn legalization_produces_legal_placements_for_suite() {
        let ic = ic();
        for app in apps::suite() {
            let packed = pack(&app).app;
            let (xs, ys) = initial_positions(&packed, &ic, 5);
            let p = build_global_problem(&packed, &ic);
            let (xs, ys) = NativePlacer::default().optimize(&p, &xs, &ys);
            let placement = legalize(&packed, &ic, &xs, &ys)
                .unwrap_or_else(|e| panic!("{}: {e}", app.name));
            placement.check(&packed, &ic).unwrap();
        }
    }

    #[test]
    fn sa_improves_or_maintains_hpwl() {
        let (packed, ic, placement) = place_app("gaussian");
        let nets = packed.nets();
        let before = placement.total_hpwl(&nets);
        let params = SaParams { moves_per_node: 20, ..Default::default() };
        let (after_p, _) = detailed_place(&packed, &ic, &nets, placement, &params);
        let after = after_p.total_hpwl(&nets);
        assert!(after <= before * 1.05, "SA regressed HPWL {before} -> {after}");
        after_p.check(&packed, &ic).unwrap();
    }

    #[test]
    fn sa_is_deterministic_per_seed() {
        let (packed, ic, placement) = place_app("pointwise");
        let nets = packed.nets();
        let params = SaParams { moves_per_node: 10, ..Default::default() };
        let (p1, c1) = detailed_place(&packed, &ic, &nets, placement.clone(), &params);
        let (p2, c2) = detailed_place(&packed, &ic, &nets, placement, &params);
        assert_eq!(p1.pos, p2.pos);
        assert_eq!(c1, c2);
    }

    #[test]
    fn alpha_changes_cost_landscape() {
        let (packed, ic, placement) = place_app("camera");
        let nets = packed.nets();
        let mut grid = vec![None; 64];
        for (id, _) in packed.iter() {
            let (x, y) = placement.of(id);
            grid[y as usize * 8 + x as usize] = Some(id);
        }
        let st = SaState::new(&packed, &ic, &nets, placement, grid);
        let c1 = st.total_cost(0.3, 1.0);
        let c2 = st.total_cost(0.3, 2.0);
        assert!(c1 > 0.0 && c2 > 0.0 && (c1 - c2).abs() > 1e-9);
    }

    #[test]
    fn place_batch_is_bit_identical_to_sequential() {
        let ic = ic();
        // One problem per suite app, each with its own seed — a realistic
        // per-config DSE job group.
        let packed: Vec<AppGraph> = apps::suite().iter().map(|a| pack(a).app).collect();
        let problems: Vec<GlobalProblem> =
            packed.iter().map(|a| build_global_problem(a, &ic)).collect();
        let inits: Vec<(Vec<f32>, Vec<f32>)> = packed
            .iter()
            .enumerate()
            .map(|(i, a)| initial_positions(a, &ic, 1 + i as u64))
            .collect();
        let batch: Vec<PlacementInstance> = problems
            .iter()
            .zip(&inits)
            .map(|(p, (xs0, ys0))| PlacementInstance { problem: p, xs0, ys0 })
            .collect();
        let scalar = NativePlacer::default();
        let batched = BatchedNativePlacer::default();
        assert_eq!(scalar.name(), batched.name(), "shared cache identity");
        let got = batched.place_batch(&batch);
        assert_eq!(got.len(), batch.len());
        for (inst, (bxs, bys)) in batch.iter().zip(&got) {
            let (sxs, sys) = scalar.optimize(inst.problem, inst.xs0, inst.ys0);
            // Exact f32 equality: batching must not change a single bit.
            assert_eq!(&sxs, bxs);
            assert_eq!(&sys, bys);
        }
        // The default trait impl (sequential loop) agrees too.
        let default_path = scalar.place_batch(&batch);
        assert_eq!(default_path, got);
    }

    #[test]
    fn place_batch_handles_empty_and_degenerate_batches() {
        let batched = BatchedNativePlacer::default();
        assert!(batched.place_batch(&[]).is_empty());
        // A zero-node problem retires via the convergence mask on the
        // first iteration and yields empty position vectors.
        let empty = GlobalProblem {
            n_nodes: 0,
            pins: vec![],
            column_pull: vec![],
            width: 4.0,
            height: 4.0,
        };
        let out = batched.place_batch(&[PlacementInstance {
            problem: &empty,
            xs0: &[],
            ys0: &[],
        }]);
        assert_eq!(out, vec![(vec![], vec![])]);
    }

    #[test]
    fn check_rejects_malformed_placements() {
        let ic = ic();
        let packed = pack(&apps::pointwise(4)).app;
        // Wrong length.
        let short = Placement { pos: vec![] };
        assert!(short.check(&packed, &ic).unwrap_err().contains("size mismatch"));
        // Start from a legal placement, then break it in each way.
        let (xs, ys) = initial_positions(&packed, &ic, 5);
        let p = build_global_problem(&packed, &ic);
        let (xs, ys) = NativePlacer::default().optimize(&p, &xs, &ys);
        let legal = legalize(&packed, &ic, &xs, &ys).unwrap();
        let mut oob = legal.clone();
        oob.pos[0] = (ic.width, 0);
        assert!(oob.check(&packed, &ic).unwrap_err().contains("out of bounds"));
        let mut dup = legal.clone();
        let pe_pair: Vec<usize> = packed
            .iter()
            .filter(|(_, n)| n.op.core_kind() == CoreKind::Pe)
            .map(|(id, _)| id.index())
            .take(2)
            .collect();
        dup.pos[pe_pair[1]] = dup.pos[pe_pair[0]];
        assert!(dup.check(&packed, &ic).unwrap_err().contains("share tile"));
        // A PE vertex forced onto a MEM column tile.
        let mem_col = (0..ic.width).find(|&x| ic.tile(x, 0).core.kind == CoreKind::Mem).unwrap();
        let mut wrong_kind = legal.clone();
        wrong_kind.pos[pe_pair[0]] = (mem_col, 0);
        // Either the MEM tile is occupied (share) or the kind mismatches.
        assert!(wrong_kind.check(&packed, &ic).is_err());
    }

    #[test]
    fn zero_node_app_flows_through_placement() {
        let ic = ic();
        let empty = pack(&AppGraph::new("empty")).app;
        assert_eq!(empty.len(), 0);
        let (xs0, ys0) = initial_positions(&empty, &ic, 1);
        assert!(xs0.is_empty());
        let p = build_global_problem(&empty, &ic);
        let (xs, ys) = NativePlacer::default().optimize(&p, &xs0, &ys0);
        let placement = legalize(&empty, &ic, &xs, &ys).unwrap();
        assert!(placement.pos.is_empty());
        placement.check(&empty, &ic).unwrap();
        assert_eq!(placement.total_hpwl(&empty.nets()), 0.0);
    }

    #[test]
    fn single_tile_fabric_places_one_node_and_rejects_two() {
        let tiny = create_uniform_interconnect(&InterconnectConfig {
            width: 1,
            height: 1,
            num_tracks: 1,
            mem_column_period: 0,
            reg_density: 0,
            ..Default::default()
        });
        let mut one = AppGraph::new("one");
        let c = one.add("c", crate::pnr::AppOp::Const(1));
        let a = one.alu("a", "add");
        one.wire(c, a, 0);
        // The constant packs into its host PE, leaving a one-vertex app.
        let one = pack(&one).app;
        assert_eq!(one.len(), 1);
        let placement = legalize(&one, &tiny, &[0.0], &[0.0]).unwrap();
        assert_eq!(placement.pos, vec![(0, 0)]);
        placement.check(&one, &tiny).unwrap();

        let mut two = AppGraph::new("two");
        let c = two.add("c", crate::pnr::AppOp::Const(1));
        let a = two.alu("a", "add");
        let b = two.alu("b", "mul");
        two.wire(c, a, 0);
        two.wire(a, b, 0);
        let two = pack(&two).app;
        assert_eq!(two.len(), 2);
        let err = legalize(&two, &tiny, &[0.0, 0.0], &[0.0, 0.0]).unwrap_err();
        assert!(err.contains("no free"), "{err}");
    }

    #[test]
    fn mem_nodes_land_on_mem_columns() {
        let (packed, ic, placement) = place_app("gaussian");
        for (id, n) in packed.iter() {
            if n.op.core_kind() == CoreKind::Mem {
                let (x, _) = placement.of(id);
                assert_eq!(ic.tile(x, 0).core.kind, CoreKind::Mem);
            }
        }
    }

    #[test]
    fn seed_placement_returns_legal_donor_exactly() {
        let (packed, ic, placement) = place_app("gaussian");
        // A legal donor on the same fabric maps back to itself: every
        // vertex's own tile is free and compatible at distance 0.
        let seeded = seed_placement(&packed, &ic, &placement.pos).unwrap();
        assert_eq!(seeded.pos, placement.pos);
        // Out-of-bounds donor coordinates are clamped, then legalized.
        let far: Vec<(u16, u16)> = placement.pos.iter().map(|&(x, y)| (x + 100, y)).collect();
        let clamped = seed_placement(&packed, &ic, &far).unwrap();
        clamped.check(&packed, &ic).unwrap();
        // Wrong vertex count is a loud error, not a misaligned seed.
        assert!(seed_placement(&packed, &ic, &placement.pos[1..]).is_err());
    }

    #[test]
    fn refine_place_stays_legal_and_close_to_start() {
        let (packed, ic, placement) = place_app("gaussian");
        let nets = packed.nets();
        let params = SaParams { moves_per_node: 4, ..Default::default() };
        let (full, full_cost) = detailed_place(&packed, &ic, &nets, placement.clone(), &params);
        full.check(&packed, &ic).unwrap();
        // temp0 below the annealer's cutoff: zero moves, placement and
        // cost come back untouched — the donor survives verbatim.
        let (same, same_cost) = refine_place(&packed, &ic, &nets, full.clone(), &params, 1e-4);
        assert_eq!(same.pos, full.pos);
        assert_eq!(same_cost, full_cost);
        // A real refinement temperature keeps legality and only improves
        // an already-annealed start (all accepted moves are ~downhill).
        let (refined, refined_cost) =
            refine_place(&packed, &ic, &nets, full.clone(), &params, 0.05);
        refined.check(&packed, &ic).unwrap();
        // Low-temperature acceptance can take small uphill steps, but it
        // must stay in the donor's neighborhood, never re-heat.
        assert!(refined_cost <= full_cost + 2.0, "{refined_cost} vs {full_cost}");
    }
}
