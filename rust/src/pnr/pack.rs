//! Packing (§3.4, stage 1 of PnR).
//!
//! "Constants and registers in the application are analyzed to identify
//! any packing opportunities. For example, a pipeline register that feeds
//! directly into a PE can be packed within that PE, eliminating the need
//! to place that register on the configurable interconnect."
//!
//! Rules implemented:
//! - every `Const` is packed into each of its consumers (constants are
//!   free to replicate into PE immediate registers) and disappears;
//! - a `Reg` whose *only* consumer is an ALU/MEM vertex is packed into
//!   that consumer's input register and disappears;
//! - remaining `Reg` vertices (fan-out > 1, or feeding another register)
//!   stay placeable and occupy a PE in register/passthrough mode.

use std::collections::HashMap;

use super::app::{AppGraph, AppNodeId, AppOp};

/// Result of packing: a rewritten graph plus records of what was folded
/// where (consumed later by the bitstream generator to configure PE
/// immediates and input registers).
#[derive(Clone, Debug)]
pub struct PackedApp {
    /// Rewritten application (no `Const` vertices; packed `Reg`s removed).
    pub app: AppGraph,
    /// `(consumer, port, value)` — constant packed as a PE immediate.
    pub packed_consts: Vec<(AppNodeId, u8, i64)>,
    /// `(consumer, port)` — input port with a packed pipeline register.
    pub packed_regs: Vec<(AppNodeId, u8)>,
    /// Mapping from original vertex ids to packed ids (packed-away
    /// vertices are absent).
    pub mapping: HashMap<AppNodeId, AppNodeId>,
}

/// Pack an application graph.
pub fn pack(original: &AppGraph) -> PackedApp {
    original.check().unwrap_or_else(|e| panic!("unpackable app {}: {e}", original.name));

    // Decide which Reg vertices get packed: single consumer, and that
    // consumer is an ALU or MEM vertex.
    let mut packed_reg_of: HashMap<AppNodeId, (AppNodeId, u8)> = HashMap::new();
    for (id, n) in original.iter() {
        if !matches!(n.op, AppOp::Reg) {
            continue;
        }
        let outs = original.outputs_of(id);
        if outs.len() != 1 {
            continue;
        }
        let consumer = outs[0].dst;
        if matches!(original.node(consumer).op, AppOp::Alu(_) | AppOp::Mem(_)) {
            packed_reg_of.insert(id, (consumer, outs[0].dst_port));
        }
    }

    // Build the rewritten graph.
    let mut app = AppGraph::new(&original.name);
    let mut mapping: HashMap<AppNodeId, AppNodeId> = HashMap::new();
    for (id, n) in original.iter() {
        let keep = match n.op {
            AppOp::Const(_) => false,
            AppOp::Reg => !packed_reg_of.contains_key(&id),
            _ => true,
        };
        if keep {
            mapping.insert(id, app.add(&n.name, n.op.clone()));
        }
    }

    let mut packed_consts = Vec::new();
    let mut packed_regs = Vec::new();

    for e in original.edges() {
        let src_node = original.node(e.src);
        match (&src_node.op, packed_reg_of.get(&e.src)) {
            // Constant -> consumer: becomes an immediate (if the consumer
            // is itself a packed register, the immediate lands on the
            // register's host port).
            (AppOp::Const(v), _) => {
                let (dst, port) = match packed_reg_of.get(&e.dst) {
                    Some(&(consumer, port)) => (mapping[&consumer], port),
                    None => (mapping[&e.dst], e.dst_port),
                };
                packed_consts.push((dst, port, *v));
            }
            // Packed register -> consumer: the register's own input edge
            // is rerouted below; here we just record the registered port.
            (AppOp::Reg, Some(_)) => {
                let dst = mapping[&e.dst];
                packed_regs.push((dst, e.dst_port));
            }
            _ => {
                // Edge into a packed register is rerouted to the
                // register's consumer; everything else copies through.
                if let Some(&(consumer, port)) = packed_reg_of.get(&e.dst) {
                    // original: e.src -> reg -> consumer.port
                    let s = mapping[&e.src];
                    let d = mapping[&consumer];
                    app.connect(s, e.src_port, d, port);
                } else {
                    app.connect(mapping[&e.src], e.src_port, mapping[&e.dst], e.dst_port);
                }
            }
        }
    }

    packed_consts.sort_by_key(|&(n, p, _)| (n, p));
    packed_regs.sort();
    packed_regs.dedup();
    PackedApp { app, packed_consts, packed_regs, mapping }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::pnr::app::AppGraph;

    #[test]
    fn constants_always_packed() {
        let packed = pack(&apps::pointwise(4));
        assert!(packed.app.iter().all(|(_, n)| !matches!(n.op, AppOp::Const(_))));
        assert_eq!(packed.packed_consts.len(), 4);
    }

    #[test]
    fn single_consumer_reg_packed() {
        let mut g = AppGraph::new("t");
        let i = g.mem("in", "stream_in");
        let r = g.add("r", AppOp::Reg);
        let a = g.alu("a", "add");
        let o = g.mem("out", "stream_out");
        g.wire(i, r, 0);
        g.wire(r, a, 0);
        g.wire(i, a, 1);
        g.wire(a, o, 0);
        let p = pack(&g);
        // r disappears; in drives a.0 directly; a.0 is a registered port.
        assert_eq!(p.app.len(), 3);
        assert_eq!(p.packed_regs.len(), 1);
        let a_new = p.app.ids().find(|&id| p.app.node(id).name == "a").unwrap();
        assert_eq!(p.packed_regs[0].0, a_new);
        assert_eq!(p.app.inputs_of(a_new).len(), 2);
    }

    #[test]
    fn fanout_reg_stays_placeable() {
        let mut g = AppGraph::new("t");
        let i = g.mem("in", "stream_in");
        let r = g.add("r", AppOp::Reg);
        let a = g.alu("a", "add");
        let b = g.alu("b", "add");
        let o = g.mem("out", "stream_out");
        g.wire(i, r, 0);
        g.wire(r, a, 0);
        g.wire(i, a, 1);
        g.wire(r, b, 0);
        g.wire(i, b, 1);
        g.wire(a, o, 0);
        g.wire(b, o, 1);
        let p = pack(&g);
        assert!(p.app.iter().any(|(_, n)| matches!(n.op, AppOp::Reg)));
    }

    #[test]
    fn reg_feeding_reg_not_packed_into_it() {
        // reg chains stay chains: the first reg's consumer is a Reg, so it
        // cannot be packed (only ALU/MEM hosts have input registers).
        let mut g = AppGraph::new("t");
        let i = g.mem("in", "stream_in");
        let r0 = g.add("r0", AppOp::Reg);
        let r1 = g.add("r1", AppOp::Reg);
        let o = g.mem("out", "stream_out");
        g.wire(i, r0, 0);
        g.wire(r0, r1, 0);
        g.wire(r1, o, 0);
        let p = pack(&g);
        // r1 packs into the MEM; r0 stays (its consumer was a Reg).
        let regs: Vec<_> =
            p.app.iter().filter(|(_, n)| matches!(n.op, AppOp::Reg)).collect();
        assert_eq!(regs.len(), 1);
        assert_eq!(p.app.node(regs[0].0).name, "r0");
    }

    #[test]
    fn suite_packs_and_stays_well_formed() {
        for app in apps::suite() {
            let p = pack(&app);
            p.app.check().unwrap_or_else(|e| panic!("{}: {e}", app.name));
            assert!(p.app.len() <= app.len(), "{} must not grow", app.name);
            if app.iter().any(|(_, n)| matches!(n.op, AppOp::Const(_))) {
                assert!(p.app.len() < app.len(), "{} should shrink", app.name);
            }
        }
    }

    #[test]
    fn packed_graph_preserves_net_semantics() {
        // Every non-const edge of the original must correspond to a path
        // of length 1 in the packed graph (possibly through a removed
        // register).
        let g = apps::gaussian();
        let p = pack(&g);
        for e in g.edges() {
            let src = g.node(e.src);
            if matches!(src.op, AppOp::Const(_)) {
                continue;
            }
            if !p.mapping.contains_key(&e.src) {
                continue; // packed reg: its input edge was rerouted
            }
            let s = p.mapping[&e.src];
            if let Some(&d) = p.mapping.get(&e.dst) {
                assert!(
                    p.app.edges().iter().any(|pe| pe.src == s && pe.dst == d),
                    "edge {} -> {} lost",
                    src.name,
                    g.node(e.dst).name
                );
            } else {
                // destination was packed away: s must now reach the
                // destination's consumer directly.
                assert!(
                    p.app.edges().iter().any(|pe| pe.src == s),
                    "rerouted edge from {} lost",
                    src.name
                );
            }
        }
    }
}
