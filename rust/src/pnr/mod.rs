//! Place and route over the Canal IR (§3.4).
//!
//! The PnR backend runs in three stages — packing, placement (analytic
//! global + simulated-annealing detailed), and iteration-based negotiated
//! A* routing — operating *directly on the interconnect graph*, which is
//! the point of Canal's IR design (Fig. 7: PnR runs on the same digraph
//! the hardware is generated from, with delays as edge weights).

pub mod app;
pub mod flow;
pub mod pack;
pub mod place;
pub mod route;
pub mod timing;

pub use app::{AppEdge, AppGraph, AppNode, AppNodeId, AppOp, Net};
pub use flow::{
    finish_flow_scratch, prepare_point, run_flow, run_flow_scratch, run_flow_warm, run_flow_with,
    FlowParams, FlowResult, PreparedPoint, WarmSeed, REFINE_TEMP0,
};
pub use pack::{pack, PackedApp};
pub use place::{
    build_global_problem, detailed_place, global_cost_grad, global_cost_grad_into,
    initial_positions, legalize, refine_place, seed_placement, BatchedNativePlacer, GlobalPlacer,
    GlobalProblem, NativePlacer, Placement, PlacementInstance, SaParams,
};
pub use route::{
    route, route_with_scratch, route_with_seed, RouteReuse, RouterParams, RouterScratch,
    RouteTree, RoutingFailed, RoutingResult, SearchCore,
};
pub use timing::{analyze, TimingReport};
