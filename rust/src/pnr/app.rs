//! Application dataflow graphs (§3.4).
//!
//! Applications are "represented as a dataflow graph" whose vertices are
//! PE operations, memory accesses, constants and pipeline registers, and
//! whose edges are data dependencies. PnR maps vertices onto tiles and
//! edges onto routed nets.

use std::collections::{BTreeMap, HashMap};

use crate::ir::CoreKind;

/// Index of a vertex in an [`AppGraph`].
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct AppNodeId(pub u32);

impl AppNodeId {
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// What an application vertex computes.
#[derive(Clone, PartialEq, Debug)]
pub enum AppOp {
    /// A PE ALU operation (`add`, `mul`, `sub`, `shift`, `gte`, ...).
    Alu(String),
    /// A memory operation: line buffer, ROM, stream in/out buffer.
    Mem(String),
    /// A compile-time constant (packable into the consuming PE).
    Const(i64),
    /// An explicit pipeline register (packable into a consuming PE's
    /// input register — the paper's packing example).
    Reg,
}

impl AppOp {
    /// Which core kind this op needs once placed (packed Const/Reg need
    /// none — they disappear into their host PE).
    pub fn core_kind(&self) -> CoreKind {
        match self {
            AppOp::Alu(_) => CoreKind::Pe,
            AppOp::Mem(_) => CoreKind::Mem,
            AppOp::Const(_) | AppOp::Reg => CoreKind::Pe,
        }
    }
}

/// An application vertex.
#[derive(Clone, Debug)]
pub struct AppNode {
    pub name: String,
    pub op: AppOp,
}

/// A directed dependency: output port `src_port` of `src` feeds input
/// port `dst_port` of `dst`. Port indices select among a core's data
/// ports at routing time.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct AppEdge {
    pub src: AppNodeId,
    pub src_port: u8,
    pub dst: AppNodeId,
    pub dst_port: u8,
}

/// A multi-terminal net: one driver, many sinks (the fan-out case §3.3
/// calls out for ready-valid generation).
#[derive(Clone, Debug)]
pub struct Net {
    pub src: AppNodeId,
    pub src_port: u8,
    pub sinks: Vec<(AppNodeId, u8)>,
}

/// Application dataflow graph.
#[derive(Clone, Debug, Default)]
pub struct AppGraph {
    pub name: String,
    nodes: Vec<AppNode>,
    edges: Vec<AppEdge>,
}

impl AppGraph {
    pub fn new(name: &str) -> Self {
        AppGraph { name: name.to_string(), ..Default::default() }
    }

    pub fn add(&mut self, name: &str, op: AppOp) -> AppNodeId {
        let id = AppNodeId(self.nodes.len() as u32);
        self.nodes.push(AppNode { name: name.to_string(), op });
        id
    }

    /// Shorthand for an ALU vertex.
    pub fn alu(&mut self, name: &str, op: &str) -> AppNodeId {
        self.add(name, AppOp::Alu(op.to_string()))
    }

    /// Shorthand for a memory vertex.
    pub fn mem(&mut self, name: &str, role: &str) -> AppNodeId {
        self.add(name, AppOp::Mem(role.to_string()))
    }

    pub fn connect(&mut self, src: AppNodeId, src_port: u8, dst: AppNodeId, dst_port: u8) {
        assert!(src.index() < self.nodes.len() && dst.index() < self.nodes.len());
        assert!(
            !self.edges.iter().any(|e| e.dst == dst && e.dst_port == dst_port),
            "input port {}#{} already driven",
            self.nodes[dst.index()].name,
            dst_port
        );
        self.edges.push(AppEdge { src, src_port, dst, dst_port });
    }

    /// Simple 0->0 connection.
    pub fn wire(&mut self, src: AppNodeId, dst: AppNodeId, dst_port: u8) {
        self.connect(src, 0, dst, dst_port);
    }

    pub fn node(&self, id: AppNodeId) -> &AppNode {
        &self.nodes[id.index()]
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn ids(&self) -> impl Iterator<Item = AppNodeId> {
        (0..self.nodes.len() as u32).map(AppNodeId)
    }

    pub fn iter(&self) -> impl Iterator<Item = (AppNodeId, &AppNode)> {
        self.nodes.iter().enumerate().map(|(i, n)| (AppNodeId(i as u32), n))
    }

    pub fn edges(&self) -> &[AppEdge] {
        &self.edges
    }

    /// Incoming edges of a vertex, sorted by destination port.
    pub fn inputs_of(&self, id: AppNodeId) -> Vec<AppEdge> {
        let mut v: Vec<AppEdge> = self.edges.iter().filter(|e| e.dst == id).copied().collect();
        v.sort_by_key(|e| e.dst_port);
        v
    }

    /// Outgoing edges of a vertex.
    pub fn outputs_of(&self, id: AppNodeId) -> Vec<AppEdge> {
        self.edges.iter().filter(|e| e.src == id).copied().collect()
    }

    /// Group edges into multi-terminal nets by (src, src_port).
    pub fn nets(&self) -> Vec<Net> {
        let mut by_src: BTreeMap<(AppNodeId, u8), Vec<(AppNodeId, u8)>> = BTreeMap::new();
        for e in &self.edges {
            by_src.entry((e.src, e.src_port)).or_default().push((e.dst, e.dst_port));
        }
        by_src
            .into_iter()
            .map(|((src, src_port), sinks)| Net { src, src_port, sinks })
            .collect()
    }

    /// Count of vertices per op family (used in reports).
    pub fn histogram(&self) -> HashMap<&'static str, usize> {
        let mut h = HashMap::new();
        for n in &self.nodes {
            let k = match n.op {
                AppOp::Alu(_) => "alu",
                AppOp::Mem(_) => "mem",
                AppOp::Const(_) => "const",
                AppOp::Reg => "reg",
            };
            *h.entry(k).or_insert(0) += 1;
        }
        h
    }

    /// Validate basic well-formedness: every non-source vertex has at
    /// least one input, names are unique, no duplicate edges.
    pub fn check(&self) -> Result<(), String> {
        let mut names = std::collections::HashSet::new();
        for n in &self.nodes {
            if !names.insert(&n.name) {
                return Err(format!("duplicate vertex name `{}`", n.name));
            }
        }
        for (id, n) in self.iter() {
            let has_in = self.edges.iter().any(|e| e.dst == id);
            let has_out = self.edges.iter().any(|e| e.src == id);
            match n.op {
                AppOp::Const(_) => {
                    if has_in {
                        return Err(format!("constant `{}` has inputs", n.name));
                    }
                }
                AppOp::Alu(_) | AppOp::Reg => {
                    if !has_in {
                        return Err(format!("compute vertex `{}` has no inputs", n.name));
                    }
                }
                AppOp::Mem(_) => {}
            }
            if !has_in && !has_out {
                return Err(format!("vertex `{}` is disconnected", n.name));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> AppGraph {
        let mut g = AppGraph::new("tiny");
        let src = g.mem("in", "stream_in");
        let a = g.alu("a", "mul");
        let b = g.alu("b", "add");
        let dst = g.mem("out", "stream_out");
        g.wire(src, a, 0);
        let c = g.add("c2", AppOp::Const(2));
        g.wire(c, a, 1);
        g.wire(a, b, 0);
        g.wire(a, b, 1); // fan-out of `a`
        g.wire(b, dst, 0);
        g
    }

    #[test]
    fn nets_group_fanout() {
        let g = tiny();
        g.check().unwrap();
        let nets = g.nets();
        // in->a, c2->a, a->{b0,b1}, b->out
        assert_eq!(nets.len(), 4);
        let fan = nets.iter().find(|n| n.sinks.len() == 2).expect("fanout net");
        assert_eq!(g.node(fan.src).name, "a");
    }

    #[test]
    fn double_driven_port_rejected() {
        let mut g = AppGraph::new("bad");
        let a = g.mem("i", "stream_in");
        let b = g.mem("j", "stream_in");
        let c = g.alu("c", "add");
        g.wire(a, c, 0);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| g.wire(b, c, 0)));
        assert!(r.is_err());
    }

    #[test]
    fn check_rejects_malformed() {
        let mut g = AppGraph::new("g");
        let a = g.alu("a", "add");
        assert!(g.check().is_err()); // no inputs
        let i = g.mem("in", "stream_in");
        g.wire(i, a, 0);
        g.check().unwrap();

        let mut g2 = AppGraph::new("g2");
        g2.add("k", AppOp::Const(1));
        assert!(g2.check().is_err()); // disconnected const
    }

    #[test]
    fn inputs_sorted_by_port() {
        let g = tiny();
        let b = g.ids().find(|&i| g.node(i).name == "b").unwrap();
        let ins = g.inputs_of(b);
        assert_eq!(ins.len(), 2);
        assert!(ins[0].dst_port < ins[1].dst_port);
    }

    #[test]
    fn histogram_counts_families() {
        let h = tiny().histogram();
        assert_eq!(h["alu"], 2);
        assert_eq!(h["mem"], 2);
        assert_eq!(h["const"], 1);
    }
}
