//! Iteration-based negotiated-congestion routing (§3.4, stage 4).
//!
//! PathFinder-style: each iteration routes every net with a graph search
//! over the routing graph; node costs combine base (delay) cost, present
//! congestion, and accumulated history. Timing criticality re-weights
//! nets between iterations ("we compute the slack on a net and determine
//! how critical it is given global timing information"). Routing finishes
//! when a legal (overuse-free) result is produced, or fails after
//! `max_iterations` — which is how the Disjoint topology's unroutability
//! manifests in Fig. 9's experiment.
//!
//! Multi-fanout nets route as **shared-subtree Steiner trees**: sinks are
//! visited in geometric-distance order and every search starts from the
//! whole tree built so far (zero-cost re-entry at any tree node), so a
//! branch to a new sink pays only for the nodes it adds. The search core
//! behind that is pluggable ([`RouterParams::search_core`]): the default
//! binary heap, two execution-strategy frontiers that pop in the exact
//! same order (bucket and radix queues), a full-strength admissible A*,
//! and a bidirectional Dijkstra. [`RouterParams::slack_order`] feeds an
//! STA pass between PathFinder iterations back into the net order so
//! critical nets route first. Every knob's default reproduces the
//! pre-variant router bit-for-bit (locked down by
//! `tests/router_variants.rs`).

use std::collections::HashMap;

use crate::ir::{CompiledGraph, CoreKind, Interconnect, NodeId, RoutingGraph};

use super::app::{AppGraph, AppNodeId, Net};
use super::place::Placement;

/// The pluggable PathFinder search core (ROADMAP's "smarter PathFinder
/// search over the CSR graph").
///
/// `Bucket` and `Radix` are pure execution strategies: they pop the
/// frontier in the binary heap's exact total order (golden-tested), so
/// results are bit-identical and they are deliberately **not** part of
/// the [`crate::dse::ConfigDescriptor`] cache key. `AStar` and `Bidir`
/// legitimately change which (equally legal) paths are found, so they
/// *are* descriptor-visible (`rcore=` token) — see
/// [`SearchCore::changes_results`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SearchCore {
    /// `BinaryHeap<(Reverse(cost), NodeId)>` — the original frontier.
    #[default]
    BinaryHeap,
    /// Fixed-width bucketed frontier (PR 6's `bucket_queue`, graduated).
    Bucket,
    /// Radix frontier: buckets indexed by the IEEE-754 bit pattern of
    /// the f-cost (monotone for non-negative doubles), 32 buckets per
    /// octave. Same pop order as the heap.
    Radix,
    /// A* with the full-strength admissible geometric lower bound
    /// (manhattan distance × 1.0 — every hop moves at most one tile and
    /// every node's base cost is ≥ 1.0).
    AStar,
    /// Bidirectional Dijkstra: forward from the net's tree, backward
    /// from the sink over the fan-in CSR, meeting in the middle.
    Bidir,
}

impl SearchCore {
    /// Every core, in flag order.
    pub const ALL: [SearchCore; 5] = [
        SearchCore::BinaryHeap,
        SearchCore::Bucket,
        SearchCore::Radix,
        SearchCore::AStar,
        SearchCore::Bidir,
    ];

    /// Parse a CLI spelling (`--search-core <name>`).
    pub fn parse(s: &str) -> Option<SearchCore> {
        match s.trim() {
            "binary-heap" | "heap" => Some(SearchCore::BinaryHeap),
            "bucket" => Some(SearchCore::Bucket),
            "radix" => Some(SearchCore::Radix),
            "astar" | "a-star" => Some(SearchCore::AStar),
            "bidir" | "bidirectional" => Some(SearchCore::Bidir),
            _ => None,
        }
    }

    /// Canonical CLI / report name.
    pub fn name(self) -> &'static str {
        match self {
            SearchCore::BinaryHeap => "binary-heap",
            SearchCore::Bucket => "bucket",
            SearchCore::Radix => "radix",
            SearchCore::AStar => "astar",
            SearchCore::Bidir => "bidir",
        }
    }

    /// The `pnr.route.<core>` span recorded around every routing call.
    pub fn span_name(self) -> &'static str {
        match self {
            SearchCore::BinaryHeap => crate::obs::span::names::ROUTE_BINARY_HEAP,
            SearchCore::Bucket => crate::obs::span::names::ROUTE_BUCKET,
            SearchCore::Radix => crate::obs::span::names::ROUTE_RADIX,
            SearchCore::AStar => crate::obs::span::names::ROUTE_ASTAR,
            SearchCore::Bidir => crate::obs::span::names::ROUTE_BIDIR,
        }
    }

    /// Does this core change routing results (vs. the binary heap)?
    /// Execution strategies (`Bucket`, `Radix`) cannot; result-changing
    /// cores get a `rcore=` token in the config descriptor.
    pub fn changes_results(self) -> bool {
        matches!(self, SearchCore::AStar | SearchCore::Bidir)
    }
}

/// Router tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct RouterParams {
    pub max_iterations: usize,
    /// Present-congestion factor growth per iteration.
    pub pres_fac_init: f64,
    pub pres_fac_mult: f64,
    /// History increment per overused node per iteration.
    pub hist_incr: f64,
    /// Weight of delay in the base cost (timing-driven share).
    pub delay_weight: f64,
    /// Extra cost discouraging routes through tiles no app vertex uses
    /// (the §3.4 "discourage the use of unused tiles" wire-cost shaping).
    pub unused_tile_penalty: f64,
    /// Which frontier/search drives PathFinder (see [`SearchCore`]).
    /// The default is bit-identical to the pre-variant router.
    pub search_core: SearchCore,
    /// Re-order nets between PathFinder iterations by STA slack
    /// (most-critical first) instead of keeping the static big-fanout-
    /// first order. Off by default (bit-identical); descriptor-visible
    /// (`rorder=slack`) because it changes results.
    pub slack_order: bool,
    /// Route multi-fanout nets as shared-subtree Steiner trees (every
    /// sink search may re-enter the already-built tree at zero cost).
    /// `false` routes each sink independently from the source — the
    /// measurable baseline the Steiner sharing is benched against.
    /// Descriptor-visible when disabled (`rsinks=independent`).
    pub steiner: bool,
}

impl Default for RouterParams {
    fn default() -> Self {
        RouterParams {
            max_iterations: 40,
            pres_fac_init: 0.6,
            pres_fac_mult: 1.4,
            hist_incr: 0.35,
            delay_weight: 1.0,
            unused_tile_penalty: 0.15,
            search_core: SearchCore::BinaryHeap,
            slack_order: false,
            steiner: true,
        }
    }
}

/// A routed net: the tree edges in routing-graph node space, plus the
/// concrete path to each sink (for STA and bitstream generation).
#[derive(Clone, Debug)]
pub struct RouteTree {
    pub net: Net,
    /// Path per sink, source port node first, sink port node last.
    pub sink_paths: Vec<Vec<NodeId>>,
}

impl RouteTree {
    /// Every routing-graph node used by this net (deduplicated).
    pub fn nodes(&self) -> Vec<NodeId> {
        let mut v: Vec<NodeId> = self.sink_paths.iter().flatten().copied().collect();
        v.sort();
        v.dedup();
        v
    }

    /// Every directed edge used by this net (deduplicated).
    pub fn edges(&self) -> Vec<(NodeId, NodeId)> {
        let mut v = Vec::new();
        for path in &self.sink_paths {
            for w in path.windows(2) {
                v.push((w[0], w[1]));
            }
        }
        v.sort();
        v.dedup();
        v
    }
}

/// Successful routing result.
#[derive(Clone, Debug)]
pub struct RoutingResult {
    pub trees: Vec<RouteTree>,
    pub iterations: usize,
    /// Total routing-graph nodes used (wirelength proxy).
    pub nodes_used: usize,
    /// Frontier pops across every search of every iteration — the
    /// router's unit of work, comparable across search cores.
    pub route_expansions: u64,
}

impl RoutingResult {
    /// Total distinct directed edges across all trees (the routed
    /// wirelength the Steiner sharing is benched on).
    pub fn wirelength(&self) -> usize {
        self.trees.iter().map(|t| t.edges().len()).sum()
    }
}

/// Routing failure: congestion never resolved.
#[derive(Clone, Debug)]
pub struct RoutingFailed {
    pub iterations: usize,
    pub overused_nodes: usize,
    pub detail: String,
}

impl std::fmt::Display for RoutingFailed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "routing failed after {} iterations ({} overused nodes): {}",
            self.iterations, self.overused_nodes, self.detail
        )
    }
}

/// Map an application vertex's output port index to the IR port-node name.
pub fn out_port_name(kind: CoreKind, port: u8) -> String {
    match kind {
        CoreKind::Pe => format!("data_out_{port}"),
        CoreKind::Mem => format!("rdata_{port}"),
        CoreKind::Io => "io_out".to_string(),
    }
}

/// Map an application vertex's input port index to the IR port-node name.
pub fn in_port_name(kind: CoreKind, port: u8) -> String {
    match kind {
        CoreKind::Pe => format!("data_in_{port}"),
        CoreKind::Mem => format!("wdata_{port}"),
        CoreKind::Io => "io_in".to_string(),
    }
}

/// Resolve a net terminal to its routing-graph port node.
fn terminal_node(
    g: &RoutingGraph,
    app: &AppGraph,
    placement: &Placement,
    vertex: AppNodeId,
    port: u8,
    input: bool,
) -> Result<NodeId, String> {
    let (x, y) = placement.of(vertex);
    let kind = app.node(vertex).op.core_kind();
    let name =
        if input { in_port_name(kind, port) } else { out_port_name(kind, port) };
    g.find_port(x, y, &name, input).ok_or_else(|| {
        format!("no port node `{name}` at ({x},{y}) for vertex `{}`", app.node(vertex).name)
    })
}

/// f64 ordered for the binary heap (min-heap via Reverse).
#[derive(PartialEq)]
struct Cost(f64);
impl Eq for Cost {}
impl PartialOrd for Cost {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Cost {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// f-cost quantum of the bucketed frontier. Node base costs are ≥ 1.0,
/// so a quarter-hop bucket keeps buckets small without many of them.
const BUCKET_WIDTH: f64 = 0.25;
/// Entries above this f-cost share one overflow bucket (still correct —
/// the bucket is min-scanned — just slower; reachable path costs in our
/// graphs never get near it).
const BUCKET_OVERFLOW: usize = 4095;

/// Min-scan a bucket and remove the entry the binary heap would pop:
/// globally minimal f under `total_cmp`, ties broken toward the larger
/// [`NodeId`] (what the max-heap over `(Reverse(Cost), NodeId)` yields).
fn min_scan_pop(b: &mut Vec<(f64, NodeId)>) -> (f64, NodeId) {
    let mut best = 0;
    for i in 1..b.len() {
        let (f, n) = b[i];
        let (bf, bn) = b[best];
        match f.total_cmp(&bf) {
            std::cmp::Ordering::Less => best = i,
            std::cmp::Ordering::Equal if n > bn => best = i,
            _ => {}
        }
    }
    b.swap_remove(best)
}

/// Monotone bucketed priority queue over f-costs — the ROADMAP's "bucket
/// queue" router variant. Pop order is *exactly* the binary heap's: the
/// lowest non-empty bucket must contain the global minimum (bucket index
/// is monotone in f), and [`min_scan_pop`] inside it reproduces the
/// heap's tie-break.
#[derive(Default)]
struct BucketQueue {
    buckets: Vec<Vec<(f64, NodeId)>>,
    /// Lowest possibly-non-empty bucket (entries pushed below it move
    /// the cursor back — the heuristic is not strictly consistent).
    cursor: usize,
    len: usize,
}

impl BucketQueue {
    fn clear(&mut self) {
        for b in &mut self.buckets {
            b.clear();
        }
        self.cursor = 0;
        self.len = 0;
    }

    fn push(&mut self, f: f64, n: NodeId) {
        let idx = ((f / BUCKET_WIDTH) as usize).min(BUCKET_OVERFLOW);
        if idx >= self.buckets.len() {
            self.buckets.resize_with(idx + 1, Vec::new);
        }
        self.buckets[idx].push((f, n));
        if idx < self.cursor {
            self.cursor = idx;
        }
        self.len += 1;
    }

    fn pop(&mut self) -> Option<(f64, NodeId)> {
        if self.len == 0 {
            return None;
        }
        while self.buckets[self.cursor].is_empty() {
            self.cursor += 1;
        }
        self.len -= 1;
        Some(min_scan_pop(&mut self.buckets[self.cursor]))
    }
}

/// Bucket index of the radix frontier: the top 17 bits of the f-cost's
/// IEEE-754 pattern (sign + exponent + 5 mantissa bits), rebased so
/// everything below 0.5 shares bucket 0. For non-negative finite
/// doubles the bit pattern is monotone in value, so the index is
/// monotone in f and the lowest non-empty bucket holds the global
/// minimum — 32 buckets per octave, resolution scaling with magnitude.
const RADIX_BASE: usize = 0x7FC0; // 0.5f64.to_bits() >> 47
const RADIX_OVERFLOW: usize = 1023;

fn radix_index(f: f64) -> usize {
    ((f.max(0.0).to_bits() >> 47) as usize).saturating_sub(RADIX_BASE).min(RADIX_OVERFLOW)
}

/// Radix priority queue: like [`BucketQueue`] but with exponent-scaled
/// buckets ([`radix_index`]), so no tuning constant and no giant linear
/// overflow bucket for large f. Pop order is the heap's exactly (same
/// [`min_scan_pop`] tie-break), golden-tested.
#[derive(Default)]
struct RadixQueue {
    buckets: Vec<Vec<(f64, NodeId)>>,
    cursor: usize,
    len: usize,
}

impl RadixQueue {
    fn clear(&mut self) {
        for b in &mut self.buckets {
            b.clear();
        }
        self.cursor = 0;
        self.len = 0;
    }

    fn push(&mut self, f: f64, n: NodeId) {
        let idx = radix_index(f);
        if idx >= self.buckets.len() {
            self.buckets.resize_with(idx + 1, Vec::new);
        }
        self.buckets[idx].push((f, n));
        if idx < self.cursor {
            self.cursor = idx;
        }
        self.len += 1;
    }

    fn pop(&mut self) -> Option<(f64, NodeId)> {
        if self.len == 0 {
            return None;
        }
        while self.buckets[self.cursor].is_empty() {
            self.cursor += 1;
        }
        self.len -= 1;
        Some(min_scan_pop(&mut self.buckets[self.cursor]))
    }
}

/// The search frontier: implemented by the binary heap and both bucketed
/// queues. All three pop in the same total order, so the search is
/// bit-identical whichever backs it (golden-tested below).
trait Frontier {
    fn fclear(&mut self);
    fn fpush(&mut self, f: f64, n: NodeId);
    fn fpop(&mut self) -> Option<(f64, NodeId)>;
}

impl Frontier for std::collections::BinaryHeap<(std::cmp::Reverse<Cost>, NodeId)> {
    fn fclear(&mut self) {
        self.clear();
    }
    fn fpush(&mut self, f: f64, n: NodeId) {
        self.push((std::cmp::Reverse(Cost(f)), n));
    }
    fn fpop(&mut self) -> Option<(f64, NodeId)> {
        self.pop().map(|(std::cmp::Reverse(Cost(f)), n)| (f, n))
    }
}

impl Frontier for BucketQueue {
    fn fclear(&mut self) {
        self.clear();
    }
    fn fpush(&mut self, f: f64, n: NodeId) {
        self.push(f, n);
    }
    fn fpop(&mut self) -> Option<(f64, NodeId)> {
        self.pop()
    }
}

impl Frontier for RadixQueue {
    fn fclear(&mut self) {
        self.clear();
    }
    fn fpush(&mut self, f: f64, n: NodeId) {
        self.push(f, n);
    }
    fn fpop(&mut self) -> Option<(f64, NodeId)> {
        self.pop()
    }
}

/// Reusable PathFinder buffers: every per-route allocation — occupancy,
/// history, base costs, the flat coordinate lookups, the search arenas
/// (forward and backward) and the frontiers — lives here so repeat
/// callers stop paying malloc/free per route. The α sweep inside one
/// flow reuses one, and the DSE engine gives each worker its own,
/// carried across thousands of sweep points. Reuse never changes
/// results: [`route_with_scratch`] resets every array to exactly the
/// state a fresh allocation would have.
#[derive(Default)]
pub struct RouterScratch {
    /// Present occupancy per node (net count).
    occ: Vec<u16>,
    /// Historical congestion per node.
    hist: Vec<f64>,
    /// Base cost per node: 1 + delay share.
    base: Vec<f64>,
    // --- Flat per-node lookups (cache-friendly; avoid deref of fat
    // `Node` structs in the inner loop) ---------------------------------
    /// Tile coordinates per node.
    nx: Vec<f32>,
    ny: Vec<f32>,
    /// Flattened tile index per node.
    tile_of: Vec<u32>,
    /// Tiles occupied by app vertices (for the unused-tile penalty).
    used_tiles: Vec<bool>,
    // --- search scratch arenas (allocated once, reset via `touched`) ---
    /// Tentative cost per node (`f64::INFINITY` = unvisited).
    dist: Vec<f64>,
    /// Predecessor per node (u32::MAX = none / search root).
    prev: Vec<u32>,
    /// Backward-search tentative cost (`bidir` core only).
    bdist: Vec<f64>,
    /// Backward-search successor pointer (toward the sink).
    bprev: Vec<u32>,
    /// Is this node part of the current net's tree?
    in_tree: Vec<bool>,
    /// Nodes whose forward scratch entries need resetting after a search.
    touched: Vec<u32>,
    /// Nodes whose backward scratch entries need resetting.
    btouched: Vec<u32>,
    /// Per-node "already counted" bitmap for tree-occupancy marking
    /// (dedup without the per-net sort+dedup allocation).
    seen: Vec<bool>,
    /// Frontier pops this routing call (all searches, all iterations).
    expansions: u64,
    /// Reusable forward frontier (cleared per search, capacity persists).
    pq: std::collections::BinaryHeap<(std::cmp::Reverse<Cost>, NodeId)>,
    /// Backward frontier for the `bidir` core.
    bpq: std::collections::BinaryHeap<(std::cmp::Reverse<Cost>, NodeId)>,
    /// Alternative bucketed frontier (see [`SearchCore::Bucket`]).
    bq: BucketQueue,
    /// Alternative radix frontier (see [`SearchCore::Radix`]).
    rq: RadixQueue,
}

impl RouterScratch {
    pub fn new() -> RouterScratch {
        RouterScratch::default()
    }

    /// Reset every buffer to the fresh-allocation state for a graph of
    /// `g.len()` nodes on a `tiles`-tile array (capacity persists).
    fn prepare(&mut self, g: &CompiledGraph, tiles: usize, ic_width: u32, params: &RouterParams) {
        let n = g.len();
        self.occ.clear();
        self.occ.resize(n, 0);
        self.hist.clear();
        self.hist.resize(n, 0.0);
        self.base.clear();
        self.base.extend(g.ids().map(|id| {
            let wire_out = g.max_out_wire_delay(id);
            1.0 + params.delay_weight * (g.node_delay_ps(id) + wire_out) as f64 / 1000.0
        }));
        self.nx.clear();
        self.nx.extend(g.ids().map(|id| g.x(id) as f32));
        self.ny.clear();
        self.ny.extend(g.ids().map(|id| g.y(id) as f32));
        self.tile_of.clear();
        self.tile_of.extend(g.ids().map(|id| g.y(id) as u32 * ic_width + g.x(id) as u32));
        self.used_tiles.clear();
        self.used_tiles.resize(tiles, false);
        self.dist.clear();
        self.dist.resize(n, f64::INFINITY);
        self.prev.clear();
        self.prev.resize(n, u32::MAX);
        self.bdist.clear();
        self.bdist.resize(n, f64::INFINITY);
        self.bprev.clear();
        self.bprev.resize(n, u32::MAX);
        self.in_tree.clear();
        self.in_tree.resize(n, false);
        self.touched.clear();
        self.btouched.clear();
        self.seen.clear();
        self.seen.resize(n, false);
        self.expansions = 0;
        self.pq.clear();
        self.bpq.clear();
        self.bq.clear();
        self.rq.clear();
    }

    /// Count each distinct node of `paths` into `occ` exactly once,
    /// using the `seen` bitmap instead of a sort+dedup allocation.
    /// Cleared on exit; equivalent to iterating the deduplicated node
    /// set (addition is order-independent).
    fn mark_tree_occupancy(&mut self, paths: &[Vec<NodeId>]) {
        for p in paths {
            for &n in p {
                let i = n.index();
                if !self.seen[i] {
                    self.seen[i] = true;
                    self.occ[i] += 1;
                }
            }
        }
        for p in paths {
            for &n in p {
                self.seen[n.index()] = false;
            }
        }
    }
}

struct RouterState<'a> {
    /// Frozen CSR graph — every inner-loop access (fan-out slices, wire
    /// delays) is a flat-array read; no hashing, no `Vec<Vec<_>>` chase.
    g: &'a CompiledGraph,
    /// Builder graph, kept only for cold paths (names in error reports).
    names: &'a RoutingGraph,
    params: RouterParams,
    pres_fac: f64,
    /// Reusable buffers (see [`RouterScratch`]).
    s: &'a mut RouterScratch,
}

impl<'a> RouterState<'a> {
    fn node_cost(&self, n: NodeId, crit: f64) -> f64 {
        let i = n.index();
        let over = self.s.occ[i] as f64; // occupancy *before* adding us
        let pres = 1.0 + self.pres_fac * over;
        let unused = if self.s.used_tiles[self.s.tile_of[i] as usize] {
            0.0
        } else {
            self.params.unused_tile_penalty
        };
        // Timing-criticality blend: critical nets weight delay, relaxed
        // nets weight congestion (negotiation share).
        let cong_share = (self.s.base[i] + unused) * pres + self.s.hist[i];
        let delay_share = self.s.base[i];
        crit * delay_share + (1.0 - crit) * cong_share
    }
}

/// Re-sort a net order most-critical-first from per-net STA slack.
/// Ties fall back to the static big-fanout-first order (then index), so
/// the sort is total and deterministic.
fn slack_sort(order: &mut [usize], nets: &[Net], slack: &[f64]) {
    order.sort_by(|&a, &b| {
        slack[a]
            .total_cmp(&slack[b])
            .then_with(|| nets[b].sinks.len().cmp(&nets[a].sinks.len()))
            .then_with(|| a.cmp(&b))
    });
}

/// Route all nets of a placed application on the `bit_width` layer.
pub fn route(
    ic: &Interconnect,
    app: &AppGraph,
    placement: &Placement,
    bit_width: u8,
    params: &RouterParams,
) -> Result<RoutingResult, RoutingFailed> {
    route_with_scratch(ic, app, placement, bit_width, params, &mut RouterScratch::new())
}

/// [`route`], reusing caller-owned PathFinder buffers. Bit-identical to a
/// fresh-scratch call; strictly an allocation saving.
pub fn route_with_scratch(
    ic: &Interconnect,
    app: &AppGraph,
    placement: &Placement,
    bit_width: u8,
    params: &RouterParams,
    scratch: &mut RouterScratch,
) -> Result<RoutingResult, RoutingFailed> {
    // The frozen CSR graph drives the search; the builder graph only
    // resolves terminal names (cold) and labels errors.
    let g = ic.compiled(bit_width);
    let rg = ic.graph(bit_width);
    let nets = app.nets();
    let mut _span = crate::obs::stage(crate::obs::span::names::ROUTE);
    _span.args(nets.len() as u64, 0);

    // Pre-resolve terminals.
    let mut terminals: Vec<(NodeId, Vec<NodeId>)> = Vec::with_capacity(nets.len());
    for net in &nets {
        let src = terminal_node(rg, app, placement, net.src, net.src_port, false)
            .map_err(|e| RoutingFailed { iterations: 0, overused_nodes: 0, detail: e })?;
        let sinks = net
            .sinks
            .iter()
            .map(|&(s, p)| terminal_node(rg, app, placement, s, p, true))
            .collect::<Result<Vec<_>, _>>()
            .map_err(|e| RoutingFailed { iterations: 0, overused_nodes: 0, detail: e })?;
        terminals.push((src, sinks));
    }

    scratch.prepare(g, ic.width as usize * ic.height as usize, ic.width as u32, params);
    for (id, _) in app.iter() {
        let (x, y) = placement.of(id);
        scratch.used_tiles[y as usize * ic.width as usize + x as usize] = true;
    }

    let mut st = RouterState {
        g,
        names: rg,
        params: *params,
        pres_fac: params.pres_fac_init,
        s: scratch,
    };
    let mut core_span = crate::obs::span::span(params.search_core.span_name());

    // Route-order: big nets first (more sinks, larger bbox). With
    // `slack_order` the STA pass below re-sorts this between iterations.
    let mut order: Vec<usize> = (0..nets.len()).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(nets[i].sinks.len()));

    let mut trees: Vec<Option<RouteTree>> = vec![None; nets.len()];
    let mut crit = vec![0.0f64; nets.len()];

    for iter in 0..params.max_iterations {
        // Rip up everything (occupancies reset; history persists).
        for o in st.s.occ.iter_mut() {
            *o = 0;
        }

        for &ni in &order {
            let (src, sinks) = &terminals[ni];
            let tree = match route_net(&mut st, *src, sinks, crit[ni]) {
                Ok(t) => t,
                Err(detail) => {
                    core_span.arg0(st.s.expansions);
                    return Err(RoutingFailed { iterations: iter, overused_nodes: 0, detail });
                }
            };
            // Mark occupancy for this net's nodes (once per net).
            st.s.mark_tree_occupancy(&tree);
            trees[ni] = Some(RouteTree { net: nets[ni].clone(), sink_paths: tree });
        }

        // Count overuse (port nodes are per-net by construction; all
        // nodes have capacity 1).
        let overused: Vec<usize> =
            (0..g.len()).filter(|&i| st.s.occ[i] > 1).collect();
        if overused.is_empty() {
            let trees: Vec<RouteTree> = trees.into_iter().map(Option::unwrap).collect();
            let nodes_used = trees.iter().map(|t| t.nodes().len()).sum();
            core_span.arg0(st.s.expansions);
            return Ok(RoutingResult {
                trees,
                iterations: iter + 1,
                nodes_used,
                route_expansions: st.s.expansions,
            });
        }

        // Negotiate: bump history on overused nodes, raise pressure.
        for &i in &overused {
            st.s.hist[i] += params.hist_incr * (st.s.occ[i] as f64 - 1.0);
        }
        st.pres_fac *= params.pres_fac_mult;

        // Update criticalities from current route delays.
        let delays: Vec<f64> = trees
            .iter()
            .map(|t| {
                t.as_ref()
                    .map(|t| {
                        t.sink_paths
                            .iter()
                            .map(|p| path_delay(g, p))
                            .fold(0.0f64, f64::max)
                    })
                    .unwrap_or(0.0)
            })
            .collect();
        let dmax = delays.iter().copied().fold(1e-9, f64::max);
        for i in 0..nets.len() {
            crit[i] = (delays[i] / dmax).clamp(0.0, 0.95);
        }

        // Slack-driven ordering: an STA pass over the app DAG with the
        // just-measured route delays; tightest-slack nets route first
        // next iteration so critical nets get first pick of resources.
        if params.slack_order {
            let slack = super::timing::net_slacks(app, &nets, &delays);
            slack_sort(&mut order, &nets, &slack);
        }
    }

    let overused = st.s.occ.iter().filter(|&&o| o > 1).count();
    core_span.arg0(st.s.expansions);
    Err(RoutingFailed {
        iterations: params.max_iterations,
        overused_nodes: overused,
        detail: "congestion did not resolve".into(),
    })
}

/// How much of a seeded routing was replayed vs repaired.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RouteReuse {
    /// Donor trees accepted verbatim (every node exists, endpoints match
    /// the current placement, no conflicts).
    pub nets_reused: usize,
    /// Nets routed by PathFinder (invalid or absent seeds).
    pub nets_rerouted: usize,
}

/// Incremental routing: replay donor sink-path trees, keep every tree
/// that is still valid on this graph and placement, and run negotiated
/// PathFinder only over the rest. `seed_paths` is one entry per net
/// (same order as `app.nets()`): `Some(paths)` with one path per sink,
/// or `None` for "no seed, route from scratch".
///
/// A donor tree is accepted only when every path starts at the net's
/// current source terminal and ends at its sink terminal, every
/// consecutive pair is an edge of this graph, and no node is already
/// claimed by another accepted tree — so accepted trees are legal by
/// construction and hold through the final overuse check (their
/// occupancy is frozen into every PathFinder iteration's baseline).
/// Trees are considered in the same big-nets-first order PathFinder
/// starts in, making acceptance (and therefore the result)
/// deterministic for given seeds — seed validation is order-stable even
/// under `slack_order`, which only reorders the repair iterations.
pub fn route_with_seed(
    ic: &Interconnect,
    app: &AppGraph,
    placement: &Placement,
    bit_width: u8,
    params: &RouterParams,
    scratch: &mut RouterScratch,
    seed_paths: &[Option<Vec<Vec<NodeId>>>],
) -> Result<(RoutingResult, RouteReuse), RoutingFailed> {
    let g = ic.compiled(bit_width);
    let rg = ic.graph(bit_width);
    let nets = app.nets();
    let mut _span = crate::obs::stage(crate::obs::span::names::ROUTE);
    _span.args(nets.len() as u64, 1); // arg1 = seeded (warm) route
    if seed_paths.len() != nets.len() {
        return Err(RoutingFailed {
            iterations: 0,
            overused_nodes: 0,
            detail: format!(
                "seed has {} nets, app has {}",
                seed_paths.len(),
                nets.len()
            ),
        });
    }

    let mut terminals: Vec<(NodeId, Vec<NodeId>)> = Vec::with_capacity(nets.len());
    for net in &nets {
        let src = terminal_node(rg, app, placement, net.src, net.src_port, false)
            .map_err(|e| RoutingFailed { iterations: 0, overused_nodes: 0, detail: e })?;
        let sinks = net
            .sinks
            .iter()
            .map(|&(s, p)| terminal_node(rg, app, placement, s, p, true))
            .collect::<Result<Vec<_>, _>>()
            .map_err(|e| RoutingFailed { iterations: 0, overused_nodes: 0, detail: e })?;
        terminals.push((src, sinks));
    }

    scratch.prepare(g, ic.width as usize * ic.height as usize, ic.width as u32, params);
    for (id, _) in app.iter() {
        let (x, y) = placement.of(id);
        scratch.used_tiles[y as usize * ic.width as usize + x as usize] = true;
    }

    let mut order: Vec<usize> = (0..nets.len()).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(nets[i].sinks.len()));

    // Accept valid donor trees, claiming occupancy as we go so a later
    // seed conflicting with an earlier one is rejected, not overlaid.
    let mut trees: Vec<Option<RouteTree>> = vec![None; nets.len()];
    let mut reused = 0usize;
    for &ni in &order {
        let Some(paths) = &seed_paths[ni] else { continue };
        let (src, sinks) = &terminals[ni];
        if paths.len() != sinks.len() {
            continue;
        }
        let endpoints_ok = paths.iter().zip(sinks).all(|(p, &sk)| {
            p.first() == Some(src)
                && p.last() == Some(&sk)
                && p.windows(2).all(|w| g.fan_out(w[0]).contains(&w[1]))
        });
        if !endpoints_ok {
            continue;
        }
        let conflict =
            paths.iter().flatten().any(|&n| scratch.occ[n.index()] > 0);
        if conflict {
            continue;
        }
        scratch.mark_tree_occupancy(paths);
        trees[ni] = Some(RouteTree { net: nets[ni].clone(), sink_paths: paths.clone() });
        reused += 1;
    }

    let mut pending: Vec<usize> =
        order.iter().copied().filter(|&ni| trees[ni].is_none()).collect();
    let reuse = RouteReuse { nets_reused: reused, nets_rerouted: pending.len() };

    let finish = |trees: Vec<Option<RouteTree>>, iterations: usize, expansions: u64| {
        let trees: Vec<RouteTree> = trees.into_iter().map(Option::unwrap).collect();
        let nodes_used = trees.iter().map(|t| t.nodes().len()).sum();
        RoutingResult { trees, iterations, nodes_used, route_expansions: expansions }
    };
    if pending.is_empty() {
        // Everything replayed: no PathFinder iterations at all.
        return Ok((finish(trees, 0, 0), reuse));
    }

    // Accepted trees are frozen: their occupancy is the rip-up baseline
    // of every iteration, so PathFinder negotiates the pending nets
    // around them (a seeded node costs like any occupied node).
    let seeded_occ = scratch.occ.clone();
    let mut st = RouterState {
        g,
        names: rg,
        params: *params,
        pres_fac: params.pres_fac_init,
        s: scratch,
    };
    let mut core_span = crate::obs::span::span(params.search_core.span_name());
    let mut crit = vec![0.0f64; nets.len()];

    for iter in 0..params.max_iterations {
        st.s.occ.copy_from_slice(&seeded_occ);

        for &ni in &pending {
            let (src, sinks) = &terminals[ni];
            let tree = match route_net(&mut st, *src, sinks, crit[ni]) {
                Ok(t) => t,
                Err(detail) => {
                    core_span.arg0(st.s.expansions);
                    return Err(RoutingFailed { iterations: iter, overused_nodes: 0, detail });
                }
            };
            st.s.mark_tree_occupancy(&tree);
            trees[ni] = Some(RouteTree { net: nets[ni].clone(), sink_paths: tree });
        }

        let overused: Vec<usize> = (0..g.len()).filter(|&i| st.s.occ[i] > 1).collect();
        if overused.is_empty() {
            let expansions = st.s.expansions;
            core_span.arg0(expansions);
            return Ok((finish(trees, iter + 1, expansions), reuse));
        }

        for &i in &overused {
            st.s.hist[i] += params.hist_incr * (st.s.occ[i] as f64 - 1.0);
        }
        st.pres_fac *= params.pres_fac_mult;

        let delays: Vec<f64> = trees
            .iter()
            .map(|t| {
                t.as_ref()
                    .map(|t| {
                        t.sink_paths
                            .iter()
                            .map(|p| path_delay(g, p))
                            .fold(0.0f64, f64::max)
                    })
                    .unwrap_or(0.0)
            })
            .collect();
        let dmax = delays.iter().copied().fold(1e-9, f64::max);
        for i in 0..nets.len() {
            crit[i] = (delays[i] / dmax).clamp(0.0, 0.95);
        }

        // Only the repaired (pending) nets reorder — accepted seeds stay
        // frozen whatever their slack.
        if params.slack_order {
            let slack = super::timing::net_slacks(app, &nets, &delays);
            slack_sort(&mut pending, &nets, &slack);
        }
    }

    let overused = st.s.occ.iter().filter(|&&o| o > 1).count();
    core_span.arg0(st.s.expansions);
    Err(RoutingFailed {
        iterations: params.max_iterations,
        overused_nodes: overused,
        detail: "congestion did not resolve around seeded trees".into(),
    })
}

/// Delay along one path (node delays + wire delays), on the frozen graph.
pub fn path_delay(g: &CompiledGraph, path: &[NodeId]) -> f64 {
    g.path_delay(path)
}

/// Route one net. In Steiner mode (the default) the net grows a shared
/// subtree: each sink searches from the *whole* tree built so far
/// (every tree node seeds at cost 0 — zero-cost re-entry), nearest sink
/// first, so a branch pays only for the nodes it adds. With
/// `steiner: false` every sink searches from the source alone — the
/// independent-paths baseline. Uses the arena scratch in
/// [`RouterState`] — no per-net allocation beyond the result paths.
fn route_net(
    st: &mut RouterState,
    src: NodeId,
    sinks: &[NodeId],
    crit: f64,
) -> Result<Vec<Vec<NodeId>>, String> {
    let g = st.g;
    // Order sinks by manhattan distance from source.
    let (sx, sy) = (g.x(src) as i32, g.y(src) as i32);
    let mut order: Vec<usize> = (0..sinks.len()).collect();
    order.sort_by_key(|&i| {
        let s = sinks[i];
        (g.x(s) as i32 - sx).abs() + (g.y(s) as i32 - sy).abs()
    });

    let steiner = st.params.steiner;
    let mut tree: Vec<NodeId> = vec![src];
    st.s.in_tree[src.index()] = true;
    let mut paths: Vec<Vec<NodeId>> = vec![Vec::new(); sinks.len()];

    let mut result = Ok(());
    for &si in &order {
        let sink = sinks[si];
        match search(st, &tree, sink, crit) {
            Some(path) => {
                if steiner {
                    for &n in &path {
                        if !st.s.in_tree[n.index()] {
                            st.s.in_tree[n.index()] = true;
                            tree.push(n);
                        }
                    }
                }
                paths[si] = path;
            }
            None => {
                result =
                    Err(format!("no path to sink {}", st.names.node(sink).qualified_name()));
                break;
            }
        }
    }
    // Reset tree membership for the next net.
    for &n in &tree {
        st.s.in_tree[n.index()] = false;
    }
    result?;

    if steiner {
        // Rebuild each sink path so it starts at the net source (the
        // search from the tree may start mid-tree; graft with recorded
        // prefixes).
        Ok(stitch_paths(src, sinks, paths))
    } else {
        // Independent paths can overlap each other arbitrarily; merge
        // them onto one driver per node so the net still encodes as a
        // proper tree (one mux select per node — the PR 1 invariant).
        Ok(merge_independent_paths(src, &order, paths))
    }
}

/// Dispatch one tree→sink search to the configured core.
fn search(st: &mut RouterState, tree: &[NodeId], sink: NodeId, crit: f64) -> Option<Vec<NodeId>> {
    match st.params.search_core {
        SearchCore::BinaryHeap => {
            let mut q = std::mem::take(&mut st.s.pq);
            let path = astar_with(st, tree, sink, crit, &mut q, 0.9);
            st.s.pq = q;
            path
        }
        SearchCore::Bucket => {
            let mut q = std::mem::take(&mut st.s.bq);
            let path = astar_with(st, tree, sink, crit, &mut q, 0.9);
            st.s.bq = q;
            path
        }
        SearchCore::Radix => {
            let mut q = std::mem::take(&mut st.s.rq);
            let path = astar_with(st, tree, sink, crit, &mut q, 0.9);
            st.s.rq = q;
            path
        }
        SearchCore::AStar => {
            let mut q = std::mem::take(&mut st.s.pq);
            let path = astar_with(st, tree, sink, crit, &mut q, 1.0);
            st.s.pq = q;
            path
        }
        SearchCore::Bidir => bidir_search(st, tree, sink, crit),
    }
}

/// A* from any node of `tree` (cost 0) to `sink`, using (and resetting)
/// the arena scratch in `st`. `hfac` scales the manhattan lower bound:
/// 0.9 is the historical default (kept bit-identical), 1.0 is the
/// full-strength admissible bound of the `astar` core — every hop moves
/// at most one tile and every node's base cost is ≥ 1.0, so remaining
/// cost ≥ remaining manhattan distance.
fn astar_with<F: Frontier>(
    st: &mut RouterState,
    tree: &[NodeId],
    sink: NodeId,
    crit: f64,
    pq: &mut F,
    hfac: f64,
) -> Option<Vec<NodeId>> {
    let g = st.g;
    let (tx, ty) = (st.s.nx[sink.index()], st.s.ny[sink.index()]);
    fn h(s: &RouterScratch, n: NodeId, tx: f32, ty: f32, hfac: f64) -> f64 {
        ((s.nx[n.index()] - tx).abs() + (s.ny[n.index()] - ty).abs()) as f64 * hfac
    }

    pq.fclear();
    for &t in tree {
        st.s.dist[t.index()] = 0.0;
        st.s.prev[t.index()] = u32::MAX;
        st.s.touched.push(t.0);
        pq.fpush(h(st.s, t, tx, ty, hfac), t);
    }

    let mut found = false;
    while let Some((f, n)) = pq.fpop() {
        let d = st.s.dist[n.index()];
        if f > d + h(st.s, n, tx, ty, hfac) + 1e-9 {
            continue; // stale entry
        }
        st.s.expansions += 1;
        if n == sink {
            found = true;
            break;
        }
        for &succ in g.fan_out(n) {
            // Sinks of other nets (ports) are not usable as intermediates:
            // only the target sink's port node may terminate the search.
            if g.is_port(succ) && succ != sink {
                continue;
            }
            let nd = d + st.node_cost(succ, crit);
            let si = succ.index();
            if nd < st.s.dist[si] - 1e-12 {
                if st.s.dist[si].is_infinite() {
                    st.s.touched.push(succ.0);
                }
                st.s.dist[si] = nd;
                st.s.prev[si] = n.0;
                pq.fpush(nd + h(st.s, succ, tx, ty, hfac), succ);
            }
        }
    }

    let path = if found {
        // Walk back to a tree node (prev == MAX).
        let mut path = vec![sink];
        let mut cur = sink;
        while st.s.prev[cur.index()] != u32::MAX {
            cur = NodeId(st.s.prev[cur.index()]);
            path.push(cur);
        }
        path.reverse();
        Some(path)
    } else {
        None
    };

    // Reset scratch for the next search.
    for &t in &st.s.touched {
        st.s.dist[t as usize] = f64::INFINITY;
        st.s.prev[t as usize] = u32::MAX;
    }
    st.s.touched.clear();
    path
}

/// Minimum key in a binary-heap frontier (∞ when empty).
fn heap_top(q: &std::collections::BinaryHeap<(std::cmp::Reverse<Cost>, NodeId)>) -> f64 {
    q.peek().map(|&(std::cmp::Reverse(Cost(f)), _)| f).unwrap_or(f64::INFINITY)
}

/// Bidirectional Dijkstra from the net tree to `sink`.
///
/// Node costs map onto edge lengths — entering node `v` over any edge
/// costs `node_cost(v)` — so the backward half is plain Dijkstra on the
/// reversed CSR (`fan_in`) with the same length function: `bdist[v]` =
/// cost of `v → … → sink` *excluding* `v` itself, seeded with
/// `bdist[sink] = 0`. A meeting node `m` then yields a complete path of
/// cost `dist[m] + bdist[m]` (forward labels include `m` unless it is a
/// free tree seed — exactly the forward metric's semantics). The search
/// expands whichever frontier has the smaller top and stops at the
/// classic bound `ftop + btop ≥ best`.
///
/// Port discipline mirrors the forward search: backward never steps
/// onto a port that is not the sink or already in the tree, and never
/// expands *through* a tree node (meeting there is the goal). The two
/// half-paths come from independent searches and may overlap, so any
/// revisited node cuts the loop between its two occurrences before the
/// path is returned.
fn bidir_search(
    st: &mut RouterState,
    tree: &[NodeId],
    sink: NodeId,
    crit: f64,
) -> Option<Vec<NodeId>> {
    let g = st.g;
    let mut fq = std::mem::take(&mut st.s.pq);
    let mut bq = std::mem::take(&mut st.s.bpq);
    fq.clear();
    bq.clear();

    for &t in tree {
        st.s.dist[t.index()] = 0.0;
        st.s.prev[t.index()] = u32::MAX;
        st.s.touched.push(t.0);
        Frontier::fpush(&mut fq, 0.0, t);
    }
    st.s.bdist[sink.index()] = 0.0;
    st.s.bprev[sink.index()] = u32::MAX;
    st.s.btouched.push(sink.0);
    Frontier::fpush(&mut bq, 0.0, sink);

    let mut best = f64::INFINITY;
    let mut meet: Option<NodeId> = None;

    loop {
        let ftop = heap_top(&fq);
        let btop = heap_top(&bq);
        if ftop + btop >= best - 1e-12 {
            break;
        }
        if ftop <= btop {
            let Some((f, n)) = Frontier::fpop(&mut fq) else { break };
            let d = st.s.dist[n.index()];
            if f > d + 1e-9 {
                continue; // stale
            }
            st.s.expansions += 1;
            if n == sink {
                // Direct arrival; candidate already recorded at relax
                // time (bdist[sink] = 0), and sinks are never expanded.
                continue;
            }
            for &succ in g.fan_out(n) {
                if g.is_port(succ) && succ != sink {
                    continue;
                }
                let nd = d + st.node_cost(succ, crit);
                let si = succ.index();
                if nd < st.s.dist[si] - 1e-12 {
                    if st.s.dist[si].is_infinite() {
                        st.s.touched.push(succ.0);
                    }
                    st.s.dist[si] = nd;
                    st.s.prev[si] = n.0;
                    Frontier::fpush(&mut fq, nd, succ);
                    if st.s.bdist[si].is_finite() {
                        let total = nd + st.s.bdist[si];
                        if total < best - 1e-12 {
                            best = total;
                            meet = Some(succ);
                        }
                    }
                }
            }
        } else {
            let Some((f, v)) = Frontier::fpop(&mut bq) else { break };
            let vi = v.index();
            let bd = st.s.bdist[vi];
            if f > bd + 1e-9 {
                continue; // stale
            }
            st.s.expansions += 1;
            if st.s.in_tree[vi] {
                continue; // met the tree; candidate recorded at relax
            }
            let vc = st.node_cost(v, crit);
            for &p in g.fan_in(v) {
                // `p` becomes an interior node of the final path: ports
                // are only allowed if they are the net's own tree (the
                // source port, or an already-routed branch).
                if g.is_port(p) && !st.s.in_tree[p.index()] {
                    continue;
                }
                let nb = bd + vc;
                let pi = p.index();
                if nb < st.s.bdist[pi] - 1e-12 {
                    if st.s.bdist[pi].is_infinite() {
                        st.s.btouched.push(p.0);
                    }
                    st.s.bdist[pi] = nb;
                    st.s.bprev[pi] = v.0;
                    Frontier::fpush(&mut bq, nb, p);
                    if st.s.dist[pi].is_finite() {
                        let total = st.s.dist[pi] + nb;
                        if total < best - 1e-12 {
                            best = total;
                            meet = Some(p);
                        }
                    }
                }
            }
        }
    }

    let path = meet.map(|m| {
        // Forward half: seed … m.
        let mut path = vec![m];
        let mut cur = m;
        while st.s.prev[cur.index()] != u32::MAX {
            cur = NodeId(st.s.prev[cur.index()]);
            path.push(cur);
        }
        path.reverse();
        // Backward half: m … sink (bprev points toward the sink).
        let mut cur = m;
        while st.s.bprev[cur.index()] != u32::MAX {
            cur = NodeId(st.s.bprev[cur.index()]);
            path.push(cur);
        }
        // The halves may overlap; cut any loop between a node's two
        // occurrences (junction pairs were consecutive in the original
        // sequence, so every remaining pair is still a graph edge).
        let mut pos: HashMap<NodeId, usize> = HashMap::new();
        let mut clean: Vec<NodeId> = Vec::new();
        for &n in &path {
            if let Some(&i) = pos.get(&n) {
                for d in clean.drain(i + 1..) {
                    pos.remove(&d);
                }
            } else {
                pos.insert(n, clean.len());
                clean.push(n);
            }
        }
        clean
    });

    for &t in &st.s.touched {
        st.s.dist[t as usize] = f64::INFINITY;
        st.s.prev[t as usize] = u32::MAX;
    }
    st.s.touched.clear();
    for &t in &st.s.btouched {
        st.s.bdist[t as usize] = f64::INFINITY;
        st.s.bprev[t as usize] = u32::MAX;
    }
    st.s.btouched.clear();

    st.s.pq = fq;
    st.s.bpq = bq;
    path
}

/// Make every sink path start at the true source by grafting tree
/// prefixes together.
fn stitch_paths(src: NodeId, sinks: &[NodeId], paths: Vec<Vec<NodeId>>) -> Vec<Vec<NodeId>> {
    // Build child->parent map over the union of all paths.
    let mut parent: HashMap<NodeId, NodeId> = HashMap::new();
    for p in &paths {
        for w in p.windows(2) {
            parent.entry(w[1]).or_insert(w[0]);
        }
    }
    sinks
        .iter()
        .map(|&sink| {
            let mut path = vec![sink];
            let mut cur = sink;
            let mut guard = 0;
            while cur != src {
                let p = *parent.get(&cur).expect("path must reach source");
                path.push(p);
                cur = p;
                guard += 1;
                assert!(guard < 1_000_000, "cycle in stitched path");
            }
            path.reverse();
            path
        })
        .collect()
}

/// Merge independently-searched sink paths (all starting at `src`) onto
/// one driver per node. Processed in routing order: when a later path
/// touches a node an earlier path already claimed, it adopts the
/// existing chain `src → node` and keeps only its own suffix past the
/// *last* such node — so every node has exactly one in-net predecessor
/// and the net encodes as a proper tree (one mux select per node),
/// while the search effort measured stays fully independent.
fn merge_independent_paths(
    src: NodeId,
    order: &[usize],
    paths: Vec<Vec<NodeId>>,
) -> Vec<Vec<NodeId>> {
    let mut parent: HashMap<NodeId, NodeId> = HashMap::new();
    let mut known: std::collections::HashSet<NodeId> = std::collections::HashSet::new();
    known.insert(src);
    let mut out = vec![Vec::new(); paths.len()];
    for &si in order {
        let p = &paths[si];
        // Last node already claimed by this net (index 0 = src always).
        let mut j = 0;
        for (i, n) in p.iter().enumerate() {
            if known.contains(n) {
                j = i;
            }
        }
        // Existing chain src → p[j] …
        let mut pref = vec![p[j]];
        let mut cur = p[j];
        while cur != src {
            cur = parent[&cur];
            pref.push(cur);
        }
        pref.reverse();
        // … then claim the fresh suffix.
        for w in p[j..].windows(2) {
            parent.insert(w[1], w[0]);
            known.insert(w[1]);
        }
        pref.extend_from_slice(&p[j + 1..]);
        out[si] = pref;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::dsl::{create_uniform_interconnect, InterconnectConfig, SbTopology};
    use crate::pnr::pack::pack;
    use crate::pnr::place::{
        build_global_problem, initial_positions, legalize, GlobalPlacer, NativePlacer,
    };

    fn ic_with(topo: SbTopology, tracks: u16) -> Interconnect {
        create_uniform_interconnect(&InterconnectConfig {
            width: 8,
            height: 8,
            num_tracks: tracks,
            mem_column_period: 3,
            sb_topology: topo,
            reg_density: 0,
            ..Default::default()
        })
    }

    fn place(app_name: &str, ic: &Interconnect) -> (AppGraph, Placement) {
        let app = apps::suite().into_iter().find(|a| a.name == app_name).unwrap();
        let packed = pack(&app).app;
        let (xs, ys) = initial_positions(&packed, ic, 1);
        let p = build_global_problem(&packed, ic);
        let (xs, ys) = NativePlacer::default().optimize(&p, &xs, &ys);
        let placement = legalize(&packed, ic, &xs, &ys).unwrap();
        (packed, placement)
    }

    fn assert_legal(ic: &Interconnect, r: &RoutingResult) {
        let g = ic.graph(16);
        let mut seen: HashMap<NodeId, usize> = HashMap::new();
        for (i, t) in r.trees.iter().enumerate() {
            for n in t.nodes() {
                if let Some(&j) = seen.get(&n) {
                    panic!("node {n} shared by nets {i} and {j}");
                }
                seen.insert(n, i);
            }
            for p in &t.sink_paths {
                assert!(g.node(*p.first().unwrap()).kind.is_port());
                assert!(g.node(*p.last().unwrap()).kind.is_port());
                for w in p.windows(2) {
                    assert!(g.fan_out(w[0]).contains(&w[1]), "non-edge in path");
                }
            }
        }
    }

    #[test]
    fn routes_pointwise_on_wilton() {
        let ic = ic_with(SbTopology::Wilton, 3);
        let (app, placement) = place("pointwise", &ic);
        let r = route(&ic, &app, &placement, 16, &RouterParams::default()).unwrap();
        assert_eq!(r.trees.len(), app.nets().len());
        assert!(r.route_expansions > 0, "expansion accounting engaged");
        // Every sink path starts at a source port and ends at a sink port.
        let g = ic.graph(16);
        for t in &r.trees {
            for p in &t.sink_paths {
                assert!(g.node(*p.first().unwrap()).kind.is_port());
                assert!(g.node(*p.last().unwrap()).kind.is_port());
                // consecutive nodes are graph edges
                for w in p.windows(2) {
                    assert!(g.fan_out(w[0]).contains(&w[1]), "non-edge in path");
                }
            }
        }
    }

    #[test]
    fn routed_nets_are_node_disjoint() {
        let ic = ic_with(SbTopology::Wilton, 5);
        let (app, placement) = place("gaussian", &ic);
        let r = route(&ic, &app, &placement, 16, &RouterParams::default()).unwrap();
        let mut seen: HashMap<NodeId, usize> = HashMap::new();
        for (i, t) in r.trees.iter().enumerate() {
            for n in t.nodes() {
                if let Some(&j) = seen.get(&n) {
                    panic!("node {n} shared by nets {i} and {j}");
                }
                seen.insert(n, i);
            }
        }
    }

    #[test]
    fn wilton_routes_suite_where_disjoint_fails() {
        // The Fig. 9 result in miniature, on the pinned-output fabric
        // where each net's starting track is fixed by its driver (the
        // regime §4.2.1 describes): Wilton escapes the plane at every
        // turn and routes apps that Disjoint cannot.
        use crate::dsl::OutputTrackMode;
        use crate::pnr::flow::{run_flow, FlowParams};
        use crate::pnr::place::SaParams;
        let apps: Vec<AppGraph> =
            vec![crate::apps::matmul(3), crate::apps::harris(), crate::apps::conv5x5()];
        let params = FlowParams {
            sa: SaParams { moves_per_node: 15, ..Default::default() },
            ..Default::default()
        };
        let count = |topo| {
            let ic = create_uniform_interconnect(&InterconnectConfig {
                width: 10,
                height: 10,
                num_tracks: 4,
                mem_column_period: 3,
                sb_topology: topo,
                output_tracks: OutputTrackMode::Pinned,
                ..Default::default()
            });
            apps.iter().filter(|a| run_flow(&ic, a, &params).is_ok()).count()
        };
        let wilton_ok = count(SbTopology::Wilton);
        let disjoint_ok = count(SbTopology::Disjoint);
        assert!(wilton_ok > disjoint_ok, "wilton {wilton_ok} vs disjoint {disjoint_ok}");
    }

    #[test]
    fn more_tracks_never_hurt_routability() {
        let ic3 = ic_with(SbTopology::Wilton, 3);
        let ic6 = ic_with(SbTopology::Wilton, 6);
        let (app3, p3) = place("harris", &ic3);
        let (app6, p6) = place("harris", &ic6);
        let r3 = route(&ic3, &app3, &p3, 16, &RouterParams::default());
        let r6 = route(&ic6, &app6, &p6, 16, &RouterParams::default());
        assert!(r6.is_ok());
        if let (Ok(r3), Ok(r6)) = (r3, r6) {
            assert!(r6.iterations <= r3.iterations + 2);
        }
    }

    #[test]
    fn scratch_reuse_is_bit_identical() {
        // One scratch carried across differently-sized graphs (the DSE
        // worker regime) must give exactly the fresh-allocation result.
        let ic3 = ic_with(SbTopology::Wilton, 3);
        let ic5 = ic_with(SbTopology::Wilton, 5);
        let (a3, p3) = place("pointwise", &ic3);
        let (a5, p5) = place("gaussian", &ic5);
        let params = RouterParams::default();
        let mut scratch = RouterScratch::new();
        let r1 = route_with_scratch(&ic5, &a5, &p5, 16, &params, &mut scratch).unwrap();
        let _ = route_with_scratch(&ic3, &a3, &p3, 16, &params, &mut scratch).unwrap();
        let r2 = route_with_scratch(&ic5, &a5, &p5, 16, &params, &mut scratch).unwrap();
        let fresh = route(&ic5, &a5, &p5, 16, &params).unwrap();
        let paths = |r: &RoutingResult| -> Vec<Vec<Vec<NodeId>>> {
            r.trees.iter().map(|t| t.sink_paths.clone()).collect()
        };
        assert_eq!(paths(&r1), paths(&fresh));
        assert_eq!(paths(&r2), paths(&fresh));
        assert_eq!(r1.iterations, fresh.iterations);
        assert_eq!(r2.nodes_used, fresh.nodes_used);
        assert_eq!(r1.route_expansions, fresh.route_expansions);
        assert_eq!(r2.route_expansions, fresh.route_expansions);
    }

    #[test]
    fn bucket_and_radix_frontiers_are_golden_bit_identical_to_heap() {
        // The bucketed and radix frontiers must reproduce the
        // BinaryHeap's pop order exactly — same paths, same iteration
        // count, same expansion count — across topologies and
        // congestion levels (few tracks = many negotiation iterations).
        let heap = RouterParams::default();
        let paths = |r: &RoutingResult| -> Vec<Vec<Vec<NodeId>>> {
            r.trees.iter().map(|t| t.sink_paths.clone()).collect()
        };
        for (topo, tracks, app_name) in [
            (SbTopology::Wilton, 3, "pointwise"),
            (SbTopology::Wilton, 4, "gaussian"),
            (SbTopology::Imran, 4, "harris"),
        ] {
            let ic = ic_with(topo, tracks);
            let (app, placement) = place(app_name, &ic);
            let a = route(&ic, &app, &placement, 16, &heap).unwrap();
            for core in [SearchCore::Bucket, SearchCore::Radix] {
                let b = route(
                    &ic,
                    &app,
                    &placement,
                    16,
                    &RouterParams { search_core: core, ..heap },
                )
                .unwrap();
                let tag = core.name();
                assert_eq!(paths(&a), paths(&b), "{app_name}/{tag} paths diverge");
                assert_eq!(a.iterations, b.iterations, "{app_name}/{tag} iterations diverge");
                assert_eq!(a.nodes_used, b.nodes_used);
                assert_eq!(
                    a.route_expansions, b.route_expansions,
                    "{app_name}/{tag} expansions diverge"
                );
            }
        }
    }

    #[test]
    fn radix_index_is_monotone_in_f() {
        let samples = [
            0.0, 1e-9, 0.1, 0.25, 0.49, 0.5, 0.51, 0.9, 1.0, 1.5, 2.0, 3.7, 8.0, 100.0,
            1234.5, 1e6, 1e9, 1e12, f64::INFINITY,
        ];
        for w in samples.windows(2) {
            assert!(
                radix_index(w[0]) <= radix_index(w[1]),
                "radix_index not monotone at {} vs {}",
                w[0],
                w[1]
            );
        }
        assert_eq!(radix_index(0.0), 0);
        assert_eq!(radix_index(0.49), 0, "everything below 0.5 shares bucket 0");
        assert!(radix_index(f64::INFINITY) == RADIX_OVERFLOW);
    }

    #[test]
    fn astar_and_bidir_cores_route_legally() {
        // Result-changing cores: no bit-identity promise, but every
        // routing they produce must be as legal as the default's.
        for (topo, tracks, app_name) in [
            (SbTopology::Wilton, 4, "gaussian"),
            (SbTopology::Wilton, 5, "harris"),
            (SbTopology::Imran, 4, "gaussian"),
        ] {
            let ic = ic_with(topo, tracks);
            let (app, placement) = place(app_name, &ic);
            for core in [SearchCore::AStar, SearchCore::Bidir] {
                let params = RouterParams { search_core: core, ..Default::default() };
                let r = route(&ic, &app, &placement, 16, &params)
                    .unwrap_or_else(|e| panic!("{}/{app_name}: {e}", core.name()));
                assert_eq!(r.trees.len(), app.nets().len());
                assert!(r.route_expansions > 0);
                assert_legal(&ic, &r);
            }
        }
    }

    #[test]
    fn independent_sinks_route_legally_and_merge_to_one_driver() {
        // The Steiner-off baseline still yields a proper tree per net:
        // node-disjoint across nets, one driver per node within a net.
        let ic = ic_with(SbTopology::Wilton, 5);
        let (app, placement) = place("harris", &ic);
        let params = RouterParams { steiner: false, ..Default::default() };
        let r = route(&ic, &app, &placement, 16, &params).unwrap();
        assert_legal(&ic, &r);
        for t in &r.trees {
            let mut driver: HashMap<NodeId, NodeId> = HashMap::new();
            for p in &t.sink_paths {
                for w in p.windows(2) {
                    if let Some(&d) = driver.get(&w[1]) {
                        assert_eq!(d, w[0], "two drivers for one node in a net");
                    }
                    driver.insert(w[1], w[0]);
                }
            }
        }
    }

    #[test]
    fn slack_order_routes_legally() {
        // Congested fabric (few tracks → several negotiation rounds):
        // the reordered router must still produce a legal result and
        // cannot be catastrophically slower than the static order.
        let ic = ic_with(SbTopology::Wilton, 3);
        let (app, placement) = place("harris", &ic);
        let base = route(&ic, &app, &placement, 16, &RouterParams::default()).unwrap();
        let params = RouterParams { slack_order: true, ..Default::default() };
        let r = route(&ic, &app, &placement, 16, &params).unwrap();
        assert_legal(&ic, &r);
        assert!(
            r.iterations <= base.iterations + 3,
            "slack order {} vs static {}",
            r.iterations,
            base.iterations
        );
    }

    #[test]
    fn seeded_route_replays_own_solution_verbatim() {
        // Seeding a routing back onto the identical problem reuses every
        // net and runs zero PathFinder iterations.
        let ic = ic_with(SbTopology::Wilton, 4);
        let (app, placement) = place("gaussian", &ic);
        let params = RouterParams::default();
        let donor = route(&ic, &app, &placement, 16, &params).unwrap();
        let seeds: Vec<Option<Vec<Vec<NodeId>>>> =
            donor.trees.iter().map(|t| Some(t.sink_paths.clone())).collect();
        let mut scratch = RouterScratch::new();
        let (r, reuse) =
            route_with_seed(&ic, &app, &placement, 16, &params, &mut scratch, &seeds).unwrap();
        assert_eq!(reuse.nets_reused, donor.trees.len());
        assert_eq!(reuse.nets_rerouted, 0);
        assert_eq!(r.iterations, 0);
        assert_eq!(r.route_expansions, 0, "full replay searches nothing");
        let paths = |r: &RoutingResult| -> Vec<Vec<Vec<NodeId>>> {
            r.trees.iter().map(|t| t.sink_paths.clone()).collect()
        };
        assert_eq!(paths(&r), paths(&donor));
    }

    #[test]
    fn seeded_route_repairs_invalid_seeds_and_stays_disjoint() {
        let ic = ic_with(SbTopology::Wilton, 4);
        let (app, placement) = place("gaussian", &ic);
        let params = RouterParams::default();
        let donor = route(&ic, &app, &placement, 16, &params).unwrap();
        // Break half the seeds: drop one (None) and corrupt another's
        // endpoint so validation rejects it.
        let mut seeds: Vec<Option<Vec<Vec<NodeId>>>> =
            donor.trees.iter().map(|t| Some(t.sink_paths.clone())).collect();
        let n = seeds.len();
        assert!(n >= 2, "gaussian has multiple nets");
        seeds[0] = None;
        if let Some(paths) = &mut seeds[1] {
            paths[0].truncate(paths[0].len().saturating_sub(1));
        }
        let mut scratch = RouterScratch::new();
        let (r, reuse) =
            route_with_seed(&ic, &app, &placement, 16, &params, &mut scratch, &seeds).unwrap();
        assert_eq!(reuse.nets_reused + reuse.nets_rerouted, n);
        assert!(reuse.nets_rerouted >= 2, "both broken seeds rerouted");
        assert!(reuse.nets_reused > 0, "intact seeds replayed");
        // The repaired result is legal: node-disjoint, endpoints right.
        assert_legal(&ic, &r);
    }

    #[test]
    fn path_delay_accumulates_node_and_wire() {
        let ic = ic_with(SbTopology::Wilton, 3);
        let g = ic.graph(16);
        let (app, placement) = place("pointwise", &ic);
        let r = route(&ic, &app, &placement, 16, &RouterParams::default()).unwrap();
        let p = &r.trees[0].sink_paths[0];
        // Computed on the frozen graph; checked against the builder graph.
        let d = path_delay(ic.compiled(16), p);
        assert!(d > 0.0);
        let manual: f64 = p.iter().map(|&n| g.node(n).delay_ps as f64).sum::<f64>()
            + p.windows(2).map(|w| g.wire_delay(w[0], w[1]) as f64).sum::<f64>();
        assert_eq!(d, manual);
    }

    #[test]
    fn search_core_parses_all_names() {
        for core in SearchCore::ALL {
            assert_eq!(SearchCore::parse(core.name()), Some(core));
        }
        assert_eq!(SearchCore::parse("heap"), Some(SearchCore::BinaryHeap));
        assert_eq!(SearchCore::parse("bidirectional"), Some(SearchCore::Bidir));
        assert_eq!(SearchCore::parse("bogus"), None);
        assert!(!SearchCore::Bucket.changes_results());
        assert!(!SearchCore::Radix.changes_results());
        assert!(SearchCore::AStar.changes_results());
        assert!(SearchCore::Bidir.changes_results());
    }
}
