//! Static timing analysis and the application run-time model.
//!
//! The paper's runtime experiments (Figs. 11/14/15) rest on the chain:
//! routability → shorter routes → shorter critical path → higher clock →
//! lower application run time. This module computes the post-route
//! critical path over the combined application + routed-interconnect
//! timing graph, and converts it into a run-time figure for a fixed
//! streaming workload.

use std::collections::HashMap;

use crate::ir::Interconnect;

use super::app::{AppGraph, AppNodeId, AppOp, Net};
use super::pack::PackedApp;
use super::route::{path_delay, RoutingResult};

/// Timing report for one placed-and-routed application.
#[derive(Clone, Debug)]
pub struct TimingReport {
    /// Longest register-to-register combinational path, ps.
    pub critical_path_ps: f64,
    /// Achievable clock period (critical path + margin), ps.
    pub period_ps: f64,
    /// Pipeline latency in cycles (longest sequential chain).
    pub latency_cycles: usize,
    /// Modeled run time for `workload_items` streamed elements, ns.
    pub runtime_ns: f64,
    pub workload_items: usize,
}

/// Clock margin (setup + clock uncertainty), ps.
const CLOCK_MARGIN_PS: f64 = 60.0;

/// Is this vertex a sequential element (breaks combinational paths)?
fn is_sequential(op: &AppOp) -> bool {
    matches!(op, AppOp::Mem(_) | AppOp::Reg)
}

/// Compute STA over the packed app + routing result.
///
/// Arrival semantics: sequential vertices launch at `clk_q`; combinational
/// vertices add their core delay; each routed edge adds its sink path's
/// interconnect delay; packed input registers (from packing) also break
/// paths at the consumer's input pin.
pub fn analyze(
    ic: &Interconnect,
    packed: &PackedApp,
    routing: &RoutingResult,
    bit_width: u8,
    workload_items: usize,
) -> TimingReport {
    let app = &packed.app;
    // Route delays are summed over the frozen CSR graph (hash-free).
    let g = ic.compiled(bit_width);

    // Route delay per (src, src_port, dst, dst_port).
    let mut route_delay: HashMap<(AppNodeId, u8, AppNodeId, u8), f64> = HashMap::new();
    for tree in &routing.trees {
        for (k, &(dst, dst_port)) in tree.net.sinks.iter().enumerate() {
            let d = path_delay(g, &tree.sink_paths[k]);
            route_delay.insert((tree.net.src, tree.net.src_port, dst, dst_port), d);
        }
    }

    let registered_inputs: std::collections::HashSet<(AppNodeId, u8)> =
        packed.packed_regs.iter().copied().collect();

    // Topological order (apps are DAGs; on a cycle we fall back to
    // iteration-bounded relaxation).
    let order = topo_order(app);

    let clk_q = 80.0; // register/core launch delay, ps
    let mut arrival: Vec<f64> = vec![0.0; app.len()];
    let mut critical = 0.0f64;

    for &v in &order {
        let node = app.node(v);
        let mut in_arrival = 0.0f64;
        for e in app.inputs_of(v) {
            let src_arr = arrival[e.src.index()];
            let rd = route_delay
                .get(&(e.src, e.src_port, e.dst, e.dst_port))
                .copied()
                .unwrap_or(0.0);
            let at_pin = src_arr + rd;
            // A packed input register terminates the path at the pin.
            if registered_inputs.contains(&(v, e.dst_port)) {
                critical = critical.max(at_pin);
            } else {
                in_arrival = in_arrival.max(at_pin);
            }
        }
        if is_sequential(&node.op) {
            // Path ends at the sequential element's D pin.
            critical = critical.max(in_arrival);
            arrival[v.index()] = clk_q;
        } else {
            let delay = core_delay(ic, node);
            arrival[v.index()] = if app.inputs_of(v).is_empty() {
                clk_q + delay
            } else {
                in_arrival + delay
            };
            critical = critical.max(arrival[v.index()]);
        }
    }

    // Latency: longest chain of sequential elements (cycles of pipeline
    // fill before the first output).
    let mut depth: Vec<usize> = vec![0; app.len()];
    for &v in &order {
        let node = app.node(v);
        let in_depth = app
            .inputs_of(v)
            .iter()
            .map(|e| depth[e.src.index()] + registered_inputs.contains(&(v, e.dst_port)) as usize)
            .max()
            .unwrap_or(0);
        depth[v.index()] = in_depth + is_sequential(&node.op) as usize;
    }
    let latency_cycles = depth.iter().copied().max().unwrap_or(0).max(1);

    let period_ps = critical + CLOCK_MARGIN_PS;
    let cycles = workload_items + latency_cycles;
    TimingReport {
        critical_path_ps: critical,
        period_ps,
        latency_cycles,
        runtime_ns: period_ps * cycles as f64 / 1000.0,
        workload_items,
    }
}

/// Per-net slack from a lightweight STA pass over the app DAG, using the
/// router's per-net routed delays (max over a net's sink paths — exactly
/// what PathFinder measures between iterations).
///
/// This is the feed for [`crate::pnr::route::RouterParams::slack_order`]:
/// it deliberately models only interconnect delay (no core delays, no
/// packed-register pin breaks — those need the full [`analyze`] inputs),
/// because all the ordering needs is a *relative* criticality that is
/// cheap and allocation-light inside the negotiation loop. Sequential
/// vertices break paths (launch fresh at 0); `Tmax` anchors at the worst
/// endpoint arrival, so slacks are non-negative and the critical path's
/// nets come back with slack exactly 0.
pub fn net_slacks(app: &AppGraph, nets: &[Net], net_delays: &[f64]) -> Vec<f64> {
    assert_eq!(nets.len(), net_delays.len());
    let order = topo_order(app);

    // Net fan-in/fan-out per vertex.
    let mut out_nets: Vec<Vec<usize>> = vec![Vec::new(); app.len()];
    let mut in_nets: Vec<Vec<usize>> = vec![Vec::new(); app.len()];
    for (i, net) in nets.iter().enumerate() {
        out_nets[net.src.index()].push(i);
        for &(dst, _) in &net.sinks {
            in_nets[dst.index()].push(i);
        }
    }

    // Forward: worst-case arrival at each vertex's output.
    let mut arrival = vec![0.0f64; app.len()];
    for &v in &order {
        if is_sequential(&app.node(v).op) {
            continue; // launches fresh; arrival stays 0
        }
        let mut a = 0.0f64;
        for &i in &in_nets[v.index()] {
            a = a.max(arrival[nets[i].src.index()] + net_delays[i]);
        }
        arrival[v.index()] = a;
    }

    // Tmax: worst endpoint arrival — combinational arrivals dominate
    // transitively, sequential D-pin arrivals are checked explicitly
    // (the vertex's own arrival resets to 0).
    let mut tmax = arrival.iter().copied().fold(0.0f64, f64::max);
    for (i, net) in nets.iter().enumerate() {
        for &(dst, _) in &net.sinks {
            if is_sequential(&app.node(dst).op) {
                tmax = tmax.max(arrival[net.src.index()] + net_delays[i]);
            }
        }
    }

    // Backward: latest time each vertex's output may launch.
    let mut required = vec![tmax; app.len()];
    for &v in order.iter().rev() {
        let mut req = tmax;
        for &i in &out_nets[v.index()] {
            for &(dst, _) in &nets[i].sinks {
                let end_req = if is_sequential(&app.node(dst).op) {
                    tmax
                } else {
                    required[dst.index()]
                };
                req = req.min(end_req - net_delays[i]);
            }
        }
        required[v.index()] = req;
    }

    nets.iter()
        .enumerate()
        .map(|(i, net)| {
            let mut end = tmax;
            for &(dst, _) in &net.sinks {
                let end_req = if is_sequential(&app.node(dst).op) {
                    tmax
                } else {
                    required[dst.index()]
                };
                end = end.min(end_req);
            }
            end - net_delays[i] - arrival[net.src.index()]
        })
        .collect()
}

fn core_delay(ic: &Interconnect, node: &super::app::AppNode) -> f64 {
    // Core delays are tile attributes; use the spec of the core kind (all
    // tiles of a kind share a spec in uniform interconnects).
    match node.op {
        AppOp::Alu(_) => {
            ic.tiles
                .iter()
                .find(|t| t.core.kind == crate::ir::CoreKind::Pe)
                .map(|t| t.core.delay_ps as f64)
                .unwrap_or(640.0)
        }
        _ => 0.0,
    }
}

/// Kahn topological sort; on a cyclic graph returns vertices in input
/// order for the cyclic remainder (bounded relaxation semantics).
fn topo_order(app: &AppGraph) -> Vec<AppNodeId> {
    let mut in_deg: Vec<usize> = vec![0; app.len()];
    for e in app.edges() {
        in_deg[e.dst.index()] += 1;
    }
    let mut queue: Vec<AppNodeId> = app.ids().filter(|v| in_deg[v.index()] == 0).collect();
    let mut order = Vec::with_capacity(app.len());
    let mut qi = 0;
    while qi < queue.len() {
        let v = queue[qi];
        qi += 1;
        order.push(v);
        for e in app.outputs_of(v) {
            in_deg[e.dst.index()] -= 1;
            if in_deg[e.dst.index()] == 0 {
                queue.push(e.dst);
            }
        }
    }
    if order.len() < app.len() {
        for v in app.ids() {
            if !order.contains(&v) {
                order.push(v);
            }
        }
    }
    order
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::dsl::{create_uniform_interconnect, InterconnectConfig};
    use crate::pnr::pack::pack;
    use crate::pnr::place::{
        build_global_problem, initial_positions, legalize, GlobalPlacer, NativePlacer,
    };
    use crate::pnr::route::{route, RouterParams};

    fn pnr(name: &str) -> (Interconnect, PackedApp, RoutingResult) {
        let ic = create_uniform_interconnect(&InterconnectConfig {
            width: 8,
            height: 8,
            num_tracks: 5,
            mem_column_period: 3,
            reg_density: 0,
            ..Default::default()
        });
        let app = apps::suite().into_iter().find(|a| a.name == name).unwrap();
        let packed = pack(&app);
        let (xs, ys) = initial_positions(&packed.app, &ic, 1);
        let p = build_global_problem(&packed.app, &ic);
        let (xs, ys) = NativePlacer::default().optimize(&p, &xs, &ys);
        let placement = legalize(&packed.app, &ic, &xs, &ys).unwrap();
        let routing = route(&ic, &packed.app, &placement, 16, &RouterParams::default()).unwrap();
        (ic, packed, routing)
    }

    #[test]
    fn critical_path_positive_and_bounded() {
        let (ic, packed, routing) = pnr("gaussian");
        let t = analyze(&ic, &packed, &routing, 16, 4096);
        assert!(t.critical_path_ps > 0.0);
        // Sanity: no combinational path should exceed a few ns on an 8x8.
        assert!(t.critical_path_ps < 20_000.0, "{}", t.critical_path_ps);
        assert_eq!(t.period_ps, t.critical_path_ps + CLOCK_MARGIN_PS);
    }

    #[test]
    fn runtime_scales_with_workload() {
        let (ic, packed, routing) = pnr("pointwise");
        let t1 = analyze(&ic, &packed, &routing, 16, 1024);
        let t2 = analyze(&ic, &packed, &routing, 16, 4096);
        assert!(t2.runtime_ns > t1.runtime_ns * 3.0);
        assert_eq!(t1.period_ps, t2.period_ps);
    }

    #[test]
    fn latency_reflects_pipeline_depth() {
        let (ic, packed, routing) = pnr("gaussian");
        let t = analyze(&ic, &packed, &routing, 16, 64);
        // gaussian has linebuffer chains and register windows: at least
        // a few sequential stages.
        assert!(t.latency_cycles >= 2, "{}", t.latency_cycles);
    }

    #[test]
    fn net_slacks_are_nonnegative_with_zero_on_critical_path() {
        // Tmax anchors at the worst endpoint arrival computed from the
        // same delays, so every slack is ≥ 0 and the critical path's
        // nets sit at exactly 0.
        let (_, packed, _) = pnr("gaussian");
        let app = &packed.app;
        let nets = app.nets();
        for scale in [1.0, 37.5] {
            let delays: Vec<f64> =
                (0..nets.len()).map(|i| scale * (1.0 + (i % 5) as f64)).collect();
            let slack = net_slacks(app, &nets, &delays);
            assert_eq!(slack.len(), nets.len());
            let min = slack.iter().copied().fold(f64::INFINITY, f64::min);
            assert!(min >= -1e-9, "negative slack {min}");
            assert!(min.abs() < 1e-6, "critical path slack should be 0, got {min}");
        }
    }

    #[test]
    fn raising_a_net_delay_never_raises_its_slack() {
        let (_, packed, _) = pnr("gaussian");
        let app = &packed.app;
        let nets = app.nets();
        let delays: Vec<f64> = (0..nets.len()).map(|i| 50.0 + (i % 3) as f64 * 20.0).collect();
        let base = net_slacks(app, &nets, &delays);
        for bump_i in 0..nets.len().min(6) {
            let mut d = delays.clone();
            d[bump_i] += 500.0;
            let bumped = net_slacks(app, &nets, &d);
            assert!(
                bumped[bump_i] <= base[bump_i] + 1e-9,
                "net {bump_i}: slack rose from {} to {}",
                base[bump_i],
                bumped[bump_i]
            );
        }
    }

    #[test]
    fn packed_registers_cut_paths() {
        let (ic, packed, routing) = pnr("gaussian");
        let with_regs = analyze(&ic, &packed, &routing, 16, 64);
        // Strip the packed-register records: paths lengthen.
        let mut no_regs = packed.clone();
        no_regs.packed_regs.clear();
        let without = analyze(&ic, &no_regs, &routing, 16, 64);
        assert!(without.critical_path_ps >= with_regs.critical_path_ps);
    }
}
