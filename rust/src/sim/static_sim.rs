//! Functional simulation of a configured static fabric.
//!
//! Values injected at source nodes propagate through the muxes exactly as
//! the configuration dictates (registers are transparent here — this is
//! the connectivity-level model used by the configuration sweep suite and
//! the bitstream checks; cycle behaviour lives in [`super::rv_sim`]).

use std::collections::HashMap;

use crate::bitstream::Configuration;
use crate::ir::{CompiledGraph, Interconnect, NodeId};

/// One configured simulation instance over a single bit-width layer.
/// Propagation walks the frozen CSR graph's fan-in slices.
pub struct StaticSim<'a> {
    g: &'a CompiledGraph,
    bit_width: u8,
    cfg: &'a Configuration,
    injected: HashMap<NodeId, u64>,
}

impl<'a> StaticSim<'a> {
    pub fn new(ic: &'a Interconnect, bit_width: u8, cfg: &'a Configuration) -> Self {
        StaticSim { g: ic.compiled(bit_width), bit_width, cfg, injected: HashMap::new() }
    }

    /// Drive a node with a value (typically a core output port).
    pub fn inject(&mut self, node: NodeId, value: u64) {
        self.injected.insert(node, value);
    }

    /// Value observed at `node`, or `None` if its path is undriven or the
    /// configuration selects an undriven input. Cycles (possible in a
    /// misconfigured fabric) resolve to `None`.
    pub fn value(&self, node: NodeId) -> Option<u64> {
        let mut visiting = std::collections::HashSet::new();
        self.eval(node, &mut visiting)
    }

    fn eval(&self, node: NodeId, visiting: &mut std::collections::HashSet<NodeId>) -> Option<u64> {
        if let Some(&v) = self.injected.get(&node) {
            return Some(v);
        }
        if !visiting.insert(node) {
            return None; // combinational loop through misconfiguration
        }
        let fan_in = self.g.fan_in(node);
        let result = match fan_in.len() {
            0 => None,
            1 => self.eval(fan_in[0], visiting),
            n => {
                let sel = self
                    .cfg
                    .selects
                    .get(&(self.bit_width, node))
                    .copied()
                    .unwrap_or(0) as usize;
                if sel < n {
                    self.eval(fan_in[sel], visiting)
                } else {
                    None
                }
            }
        };
        visiting.remove(&node);
        result
    }
}

/// Check a routed configuration end to end: inject a distinct value at
/// every net source port and verify each sink port observes it.
pub fn check_routing(
    ic: &Interconnect,
    bit_width: u8,
    cfg: &Configuration,
    routing: &crate::pnr::RoutingResult,
) -> Result<(), String> {
    let mut sim = StaticSim::new(ic, bit_width, cfg);
    for (i, tree) in routing.trees.iter().enumerate() {
        let src = tree.sink_paths[0][0];
        sim.inject(src, 0xBEEF_0000 + i as u64);
    }
    let g = ic.graph(bit_width);
    for (i, tree) in routing.trees.iter().enumerate() {
        for path in &tree.sink_paths {
            let sink = *path.last().unwrap();
            let got = sim.value(sink);
            if got != Some(0xBEEF_0000 + i as u64) {
                return Err(format!(
                    "net {i}: sink {} observed {:?}",
                    g.node(sink).qualified_name(),
                    got
                ));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::bitstream::Configuration;
    use crate::dsl::{create_uniform_interconnect, InterconnectConfig};
    use crate::pnr::{run_flow, FlowParams, SaParams};

    fn ic() -> Interconnect {
        create_uniform_interconnect(&InterconnectConfig {
            width: 8,
            height: 8,
            num_tracks: 4,
            mem_column_period: 3,
            ..Default::default()
        })
    }

    #[test]
    fn routed_gaussian_delivers_all_net_values() {
        let ic = ic();
        let params = FlowParams {
            sa: SaParams { moves_per_node: 8, ..Default::default() },
            ..Default::default()
        };
        let r = run_flow(&ic, &apps::gaussian(), &params).unwrap();
        let cfg = Configuration::from_routing(&ic, 16, &r.routing).unwrap();
        check_routing(&ic, 16, &cfg, &r.routing).unwrap();
    }

    #[test]
    fn wrong_select_breaks_delivery() {
        let ic = ic();
        let params = FlowParams {
            sa: SaParams { moves_per_node: 8, ..Default::default() },
            ..Default::default()
        };
        let r = run_flow(&ic, &apps::pointwise(6), &params).unwrap();
        let cfg = Configuration::from_routing(&ic, 16, &r.routing).unwrap();
        let g = ic.graph(16);
        // Corrupting a select must break delivery for at least one mux
        // (some corruptions are benign when the alternate input carries
        // the same net's value — e.g. another branch of the route tree).
        let mut keys: Vec<_> = cfg.selects.keys().copied().collect();
        keys.sort_by_key(|k| k.1);
        let broke = keys.iter().any(|&key| {
            let mut bad = cfg.clone();
            let sel = cfg.selects[&key];
            let fan = g.fan_in(key.1).len() as u32;
            bad.selects.insert(key, (sel + 1) % fan);
            check_routing(&ic, 16, &bad, &r.routing).is_err()
        });
        assert!(broke, "no single-select corruption was detected");
    }

    #[test]
    fn undriven_paths_read_none() {
        let ic = ic();
        let cfg = Configuration::default();
        let sim = StaticSim::new(&ic, 16, &cfg);
        // Any CB output with an all-undriven fabric reads None.
        let g = ic.graph(16);
        let port = g.find_port(4, 4, "data_in_0", true).unwrap();
        assert_eq!(sim.value(port), None);
    }
}
