//! Cycle-level simulation of the dynamic NoC backend.
//!
//! Store-and-forward, credit-based, single-flit packets. Each router has
//! one input FIFO per port (plus a local injection queue); every cycle a
//! router forwards at most one packet per output port, using the
//! table-driven route from [`crate::hw::dynamic`]. The X-first tables are
//! deadlock-free on a mesh, so bounded buffers suffice.
//!
//! The simulator answers the comparison the paper's §3.3 NoC discussion
//! implies: what does *dynamic* routing cost in latency/throughput versus
//! the statically-configured fabric for the same application traffic?

use std::collections::{HashMap, VecDeque};

use crate::hw::dynamic::DynNoc;
use crate::pnr::app::AppGraph;
use crate::pnr::place::Placement;

/// One packet: a single data flit routed by destination tile.
#[derive(Clone, Copy, Debug)]
struct Packet {
    dest: usize,
    /// (sink vertex, sink port) so delivery can be matched to app edges.
    sink: (u32, u8),
    /// Carried data word (kept for debugging dumps).
    #[allow(dead_code)]
    payload: i64,
    injected_at: u64,
}

/// Result of a NoC simulation run.
#[derive(Clone, Debug)]
pub struct NocRun {
    /// Cycles until `tokens_target` tokens were delivered at every sink.
    pub cycles: u64,
    /// Packets delivered in total.
    pub delivered: usize,
    /// Mean in-flight latency (cycles) over all delivered packets.
    pub mean_latency: f64,
    /// Worst observed packet latency.
    pub max_latency: u64,
    /// Sum over cycles of packets occupying buffers (congestion proxy).
    pub buffer_occupancy: u64,
}

/// Per-tile router state.
struct RouterState {
    /// One FIFO per side + one local-injection FIFO (index 4).
    in_q: [VecDeque<Packet>; 5],
}

const LOCAL: usize = 4;

/// Simulate `app` traffic over the NoC: every source vertex emits one
/// packet per sink per token (fan-out = replicated unicast, the standard
/// NoC treatment of multicast), paced by `injection_interval` cycles.
pub struct NocSim<'a> {
    noc: &'a DynNoc,
    app: &'a AppGraph,
    placement: &'a Placement,
}

impl<'a> NocSim<'a> {
    pub fn new(noc: &'a DynNoc, app: &'a AppGraph, placement: &'a Placement) -> Self {
        NocSim { noc, app, placement }
    }

    /// Run until every sink edge has received `tokens` packets (or
    /// `max_cycles` elapses). `injection_interval` = cycles between
    /// successive tokens at each source.
    pub fn run(&self, tokens: usize, injection_interval: u64, max_cycles: u64) -> NocRun {
        let w = self.noc.width as usize;
        let nets = self.app.nets();

        // Source schedule: (src tile, dest tile, sink id) per net sink.
        struct Flow {
            src_tile: usize,
            dest_tile: usize,
            sink: (u32, u8),
            sent: usize,
        }
        let mut flows: Vec<Flow> = Vec::new();
        for net in &nets {
            let (sx, sy) = self.placement.of(net.src);
            let src_tile = sy as usize * w + sx as usize;
            for &(dst, port) in &net.sinks {
                let (dx, dy) = self.placement.of(dst);
                flows.push(Flow {
                    src_tile,
                    dest_tile: dy as usize * w + dx as usize,
                    sink: (dst.0, port),
                    sent: 0,
                });
            }
        }

        let n_tiles = self.noc.routers.len();
        let mut routers: Vec<RouterState> = (0..n_tiles)
            .map(|_| RouterState { in_q: Default::default() })
            .collect();

        let mut delivered_per_sink: HashMap<(u32, u8), usize> = HashMap::new();
        for f in &flows {
            delivered_per_sink.entry(f.sink).or_insert(0);
        }

        let mut cycle: u64 = 0;
        let mut delivered = 0usize;
        let mut lat_sum: u64 = 0;
        let mut lat_max: u64 = 0;
        let mut occupancy: u64 = 0;
        let buf = self.noc.opts.buf_depth;

        loop {
            // Injection phase: each flow emits on its interval if the
            // local queue has room.
            for f in flows.iter_mut() {
                if f.sent < tokens && cycle % injection_interval == 0 {
                    let q = &mut routers[f.src_tile].in_q[LOCAL];
                    if q.len() < buf * 4 {
                        q.push_back(Packet {
                            dest: f.dest_tile,
                            sink: f.sink,
                            payload: f.sent as i64,
                            injected_at: cycle,
                        });
                        f.sent += 1;
                    }
                }
            }

            // Switch phase: every router arbitrates each output side
            // round-robin over input queues; compute moves on a snapshot
            // of queue heads so a packet moves at most one hop per cycle.
            let mut moves: Vec<(usize, usize, usize)> = Vec::new(); // (tile, in_q, out)
            let mut deliveries: Vec<(usize, usize)> = Vec::new(); // (tile, in_q)
            for (t, r) in self.noc.routers.iter().enumerate() {
                let mut out_used = [false; 4];
                for qi in 0..5 {
                    let head = match routers[t].in_q[qi].front() {
                        Some(p) => *p,
                        None => continue,
                    };
                    if head.dest == t {
                        deliveries.push((t, qi));
                        continue;
                    }
                    let side = match r.table[head.dest] {
                        Some(s) => s,
                        None => continue, // unreachable; parked forever
                    };
                    let si = side.index();
                    if out_used[si] {
                        continue;
                    }
                    // Credit check: the downstream FIFO on the opposite
                    // side must have room.
                    let (ox, oy) = side.offset();
                    let nt = (r.y as i32 + oy) as usize * w + (r.x as i32 + ox) as usize;
                    let din = side.opposite().index();
                    if routers[nt].in_q[din].len() >= buf {
                        continue;
                    }
                    out_used[si] = true;
                    moves.push((t, qi, nt * 8 + din));
                }
            }

            for (t, qi) in deliveries {
                let p = routers[t].in_q[qi].pop_front().unwrap();
                delivered += 1;
                *delivered_per_sink.get_mut(&p.sink).unwrap() += 1;
                let lat = cycle - p.injected_at;
                lat_sum += lat;
                lat_max = lat_max.max(lat);
            }
            for (t, qi, enc) in moves {
                let p = routers[t].in_q[qi].pop_front().unwrap();
                routers[enc / 8].in_q[enc % 8].push_back(p);
            }

            occupancy +=
                routers.iter().map(|r| r.in_q.iter().map(VecDeque::len).sum::<usize>() as u64).sum::<u64>();

            cycle += 1;
            let done = delivered_per_sink.values().all(|&v| v >= tokens.min(usize::MAX));
            let all_sent = flows.iter().all(|f| f.sent >= tokens);
            if (done && all_sent) || cycle >= max_cycles {
                break;
            }
        }

        NocRun {
            cycles: cycle,
            delivered,
            mean_latency: if delivered > 0 { lat_sum as f64 / delivered as f64 } else { 0.0 },
            max_latency: lat_max,
            buffer_occupancy: occupancy,
        }
    }
}

/// Convenience: simulate an app with a legal placement on a fresh NoC.
pub fn simulate_app(
    noc: &DynNoc,
    app: &AppGraph,
    placement: &Placement,
    tokens: usize,
) -> NocRun {
    NocSim::new(noc, app, placement).run(tokens, 1, 4_000_000)
}

/// Sanity helper for tests: all-to-one hotspot traffic pattern.
pub fn hotspot_pattern(noc: &DynNoc, tokens: usize) -> NocRun {
    // Build a synthetic app: every tile's "source" sends to tile (0,0).
    let mut app = AppGraph::new("hotspot");
    let mut pos = Vec::new();
    let sink = app.alu("sink", "add");
    pos.push((0u16, 0u16));
    let mut port = 0u8;
    for y in 0..noc.height {
        for x in 0..noc.width {
            if (x, y) == (0, 0) || port >= 4 {
                continue;
            }
            let s = app.alu(&format!("s{x}_{y}"), "add");
            app.connect(s, 0, sink, port);
            pos.push((x, y));
            port += 1;
        }
    }
    let placement = Placement { pos };
    NocSim::new(noc, &app, &placement).run(tokens, 1, 1_000_000)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::dsl::{create_uniform_interconnect, InterconnectConfig};
    use crate::hw::dynamic::{lower_dynamic, DynOptions};
    use crate::pnr::{pack, run_flow, FlowParams, SaParams};

    fn fabric(w: u16, h: u16) -> (crate::ir::Interconnect, DynNoc) {
        let ic = create_uniform_interconnect(&InterconnectConfig {
            width: w,
            height: h,
            num_tracks: 5,
            mem_column_period: 3,
            ..Default::default()
        });
        let noc = lower_dynamic(&ic, 16, &DynOptions::default());
        (ic, noc)
    }

    fn placed(app: &AppGraph, ic: &crate::ir::Interconnect) -> (AppGraph, Placement) {
        let params = FlowParams {
            sa: SaParams { moves_per_node: 6, ..Default::default() },
            ..Default::default()
        };
        let r = run_flow(ic, app, &params).expect("flow");
        (pack(app).app, r.placement)
    }

    #[test]
    fn delivers_all_tokens_for_gaussian() {
        let (ic, noc) = fabric(8, 8);
        let app = apps::gaussian();
        let (packed, placement) = placed(&app, &ic);
        let run = simulate_app(&noc, &packed, &placement, 32);
        let sink_edges = packed.nets().iter().map(|n| n.sinks.len()).sum::<usize>();
        assert_eq!(run.delivered, 32 * sink_edges);
        assert!(run.cycles < 4_000_000);
    }

    #[test]
    fn latency_at_least_hop_count() {
        let (_, noc) = fabric(6, 6);
        let run = hotspot_pattern(&noc, 8);
        assert!(run.delivered > 0);
        // The farthest senders are several hops away; mean latency must
        // exceed 1 cycle and be finite.
        assert!(run.mean_latency >= 1.0, "{}", run.mean_latency);
        assert!(run.max_latency >= run.mean_latency as u64);
    }

    #[test]
    fn hotspot_congests_more_than_neighbour_traffic() {
        let (_, noc) = fabric(6, 6);
        let hot = hotspot_pattern(&noc, 32);
        // Neighbour traffic: one source next to the sink.
        let mut app = AppGraph::new("pair");
        let a = app.alu("a", "add");
        let b = app.alu("b", "add");
        app.connect(a, 0, b, 0);
        let placement = Placement { pos: vec![(1, 0), (0, 0)] };
        let pair = NocSim::new(&noc, &app, &placement).run(32, 1, 100_000);
        assert!(hot.mean_latency > pair.mean_latency);
    }

    #[test]
    fn bounded_buffers_do_not_deadlock() {
        // Tight buffers + hotspot traffic: X-first tables keep the mesh
        // deadlock-free; the run must complete.
        let ic = create_uniform_interconnect(&InterconnectConfig {
            width: 5,
            height: 5,
            num_tracks: 3,
            mem_column_period: 0,
            ..Default::default()
        });
        let noc = lower_dynamic(&ic, 16, &DynOptions { buf_depth: 1, hop_latency: 1 });
        let run = hotspot_pattern(&noc, 16);
        assert!(run.cycles < 1_000_000, "deadlocked at {} cycles", run.cycles);
        assert!(run.delivered > 0);
    }

    #[test]
    fn throughput_tracks_injection_interval() {
        let (ic, noc) = fabric(8, 8);
        let app = apps::pointwise(6);
        let (packed, placement) = placed(&app, &ic);
        let fast = NocSim::new(&noc, &packed, &placement).run(64, 1, 1_000_000);
        let slow = NocSim::new(&noc, &packed, &placement).run(64, 4, 1_000_000);
        assert!(slow.cycles > fast.cycles);
        // Slower injection -> less buffer pressure.
        assert!(slow.buffer_occupancy <= fast.buffer_occupancy);
    }
}
