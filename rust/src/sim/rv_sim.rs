//! Cycle-accurate ready-valid (elastic) simulation.
//!
//! Models the statically-configured NoC backend (§3.3): every routed edge
//! is an elastic channel whose buffering comes from the interconnect
//! registers the route passes through — none for a static fabric, depth-2
//! FIFOs in full-FIFO mode, shared split FIFOs in split mode (Fig. 6).
//! Vertices fire when all inputs are valid and all outputs ready, exactly
//! the join semantics the ready/valid layers implement in hardware.
//!
//! Two invariants matter:
//! - **elasticity preserves values**: any stall pattern produces the same
//!   output *sequence* as an unconstrained run (FIFOs only retime);
//! - **buffering recovers throughput**: unbalanced reconvergent paths and
//!   bursty sinks run faster with deeper channels — the reason the RV
//!   backend needs FIFOs at all (Fig. 8's motivation).

use std::collections::{HashMap, VecDeque};

use crate::pnr::app::{AppGraph, AppNodeId, AppOp};
use crate::pnr::RoutingResult;
use crate::util::rng::Rng;

/// Which fabric the channels model.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum FabricKind {
    /// Static interconnect: no elastic buffering (capacity-1 wires).
    Static,
    /// Ready-valid with a depth-`d` FIFO at every route register.
    RvFullFifo { depth: u8 },
    /// Ready-valid with split FIFOs: each register contributes one entry;
    /// adjacent pairs chain into depth-2 (Fig. 6).
    RvSplitFifo,
}

impl FabricKind {
    /// Channel capacity for a route that crosses `regs` register nodes.
    pub fn capacity(self, regs: usize) -> usize {
        match self {
            FabricKind::Static => 1,
            FabricKind::RvFullFifo { depth } => 1 + regs * depth as usize,
            FabricKind::RvSplitFifo => 1 + regs,
        }
    }

    /// Extra combinational delay (ps) from chained split-FIFO control:
    /// "these control signals cannot be registered at the tile boundary;
    /// the longer the FIFO is chained, the longer the combinational delay"
    /// (§3.3). `chain` = longest register chain on any route.
    pub fn period_penalty_ps(self, chain: usize) -> f64 {
        match self {
            FabricKind::RvSplitFifo => 35.0 * chain.saturating_sub(1) as f64,
            _ => 0.0,
        }
    }
}

/// Stall model applied to stream sinks (downstream backpressure).
#[derive(Clone, Copy, Debug)]
pub enum StallPattern {
    None,
    /// Sink accepts `accept` cycles then stalls `stall` cycles.
    Bursty { accept: u32, stall: u32 },
    /// Random stalls with probability `p` (deterministic seed).
    Random { p: f64, seed: u64 },
}

/// Result of a simulation run.
#[derive(Clone, Debug)]
pub struct SimRun {
    /// Output token sequence per stream-out vertex (sorted by name).
    pub outputs: HashMap<String, Vec<i64>>,
    pub cycles: usize,
    pub tokens: usize,
}

/// Per-edge channel capacities, derived from a routing result (registers
/// crossed per sink path) or uniform for un-routed simulations.
pub fn channel_capacities(
    app: &AppGraph,
    routing: Option<(&crate::ir::Interconnect, u8, &RoutingResult)>,
    fabric: FabricKind,
) -> HashMap<(AppNodeId, u8, AppNodeId, u8), usize> {
    let mut caps = HashMap::new();
    match routing {
        Some((ic, bw, routing)) => {
            let g = ic.compiled(bw);
            for tree in &routing.trees {
                for (k, &(dst, dport)) in tree.net.sinks.iter().enumerate() {
                    let regs = tree.sink_paths[k]
                        .iter()
                        .filter(|&&n| g.is_register(n))
                        .count();
                    caps.insert(
                        (tree.net.src, tree.net.src_port, dst, dport),
                        fabric.capacity(regs),
                    );
                }
            }
        }
        None => {
            for e in app.edges() {
                caps.insert((e.src, e.src_port, e.dst, e.dst_port), fabric.capacity(1));
            }
        }
    }
    caps
}

struct Channel {
    cap: usize,
    q: VecDeque<i64>,
}

/// The elastic dataflow simulator.
pub struct RvSim<'a> {
    app: &'a AppGraph,
    /// channel index: (src, sport, dst, dport) -> Channel
    channels: HashMap<(AppNodeId, u8, AppNodeId, u8), Channel>,
    /// MAC accumulators and linebuffer delay lines.
    state: HashMap<AppNodeId, VecDeque<i64>>,
    input_stream: Vec<i64>,
    /// Next input index per stream-in vertex.
    in_pos: HashMap<AppNodeId, usize>,
    /// Tokens produced this cycle, visible next cycle (1-cycle stages).
    pending: Vec<((AppNodeId, u8, AppNodeId, u8), i64)>,
    /// Staged push counts per channel (for capacity checks within the
    /// current cycle).
    staged: HashMap<(AppNodeId, u8, AppNodeId, u8), usize>,
    /// Linebuffer depth: the row stride of the streamed image.
    pub linebuffer_delay: usize,
}

/// Default linebuffer delay in tokens (a "row" of the modeled image).
pub const DEFAULT_LINEBUFFER_DELAY: usize = 8;

impl<'a> RvSim<'a> {
    pub fn new(
        app: &'a AppGraph,
        caps: &HashMap<(AppNodeId, u8, AppNodeId, u8), usize>,
        input_stream: Vec<i64>,
    ) -> Self {
        let mut channels = HashMap::new();
        for e in app.edges() {
            let key = (e.src, e.src_port, e.dst, e.dst_port);
            let cap = caps.get(&key).copied().unwrap_or(1);
            channels.insert(key, Channel { cap, q: VecDeque::new() });
        }
        RvSim {
            app,
            channels,
            state: HashMap::new(),
            input_stream,
            in_pos: HashMap::new(),
            pending: Vec::new(),
            staged: HashMap::new(),
            linebuffer_delay: DEFAULT_LINEBUFFER_DELAY,
        }
    }

    fn stage(&mut self, key: (AppNodeId, u8, AppNodeId, u8), tok: i64) {
        self.pending.push((key, tok));
        *self.staged.entry(key).or_insert(0) += 1;
    }

    fn channel_ready(&self, key: &(AppNodeId, u8, AppNodeId, u8)) -> bool {
        let ch = &self.channels[key];
        ch.q.len() + self.staged.get(key).copied().unwrap_or(0) < ch.cap
    }

    fn out_keys(&self, v: AppNodeId) -> Vec<(AppNodeId, u8, AppNodeId, u8)> {
        self.app
            .outputs_of(v)
            .iter()
            .map(|e| (e.src, e.src_port, e.dst, e.dst_port))
            .collect()
    }

    fn in_keys(&self, v: AppNodeId) -> Vec<(AppNodeId, u8, AppNodeId, u8)> {
        self.app
            .inputs_of(v)
            .iter()
            .map(|e| (e.src, e.src_port, e.dst, e.dst_port))
            .collect()
    }

    /// Run until every stream-out vertex has collected `n_tokens` or
    /// `max_cycles` elapse.
    pub fn run(&mut self, n_tokens: usize, max_cycles: usize, stall: StallPattern) -> SimRun {
        let sinks: Vec<AppNodeId> = self
            .app
            .iter()
            .filter(|(_, n)| matches!(&n.op, AppOp::Mem(r) if r == "stream_out"))
            .map(|(id, _)| id)
            .collect();
        let mut outputs: HashMap<String, Vec<i64>> =
            sinks.iter().map(|&s| (self.app.node(s).name.clone(), Vec::new())).collect();
        let mut rng = Rng::new(match stall {
            StallPattern::Random { seed, .. } => seed,
            _ => 0,
        });

        let order: Vec<AppNodeId> = self.app.ids().collect();
        let mut cycles = 0usize;
        while cycles < max_cycles
            && outputs.values().any(|v| v.len() < n_tokens)
        {
            // Sink acceptance this cycle.
            let sink_ready = match stall {
                StallPattern::None => true,
                StallPattern::Bursty { accept, stall } => {
                    (cycles as u32) % (accept + stall) < accept
                }
                StallPattern::Random { p, .. } => rng.f64() >= p,
            };

            // Two-phase update: decide fires on the pre-cycle state.
            // (Vertices read channel occupancy as of cycle start; pushes
            // land visible next cycle — modeled by draining *then*
            // firing producers in reverse topological order.)
            for &v in order.iter() {
                let node = self.app.node(v);
                match &node.op {
                    AppOp::Mem(role) if role == "stream_out" => {
                        if !sink_ready {
                            continue;
                        }
                        let keys = self.in_keys(v);
                        if keys.is_empty() {
                            continue;
                        }
                        // Accept one token per input channel per cycle.
                        if keys.iter().all(|k| !self.channels[k].q.is_empty()) {
                            let tok = self.channels.get_mut(&keys[0]).unwrap().q.pop_front().unwrap();
                            for k in &keys[1..] {
                                self.channels.get_mut(k).unwrap().q.pop_front();
                            }
                            outputs.get_mut(&node.name).unwrap().push(tok);
                        }
                    }
                    _ => {}
                }
            }

            for &v in order.iter() {
                let node = self.app.node(v);
                let outs = self.out_keys(v);
                if outs.is_empty() {
                    continue; // sinks handled above
                }
                let outs_ready = outs.iter().all(|k| self.channel_ready(k));
                if !outs_ready {
                    continue;
                }
                match &node.op {
                    AppOp::Mem(role) if role == "stream_in" => {
                        let pos = self.in_pos.entry(v).or_insert(0);
                        if *pos < self.input_stream.len() {
                            let tok = self.input_stream[*pos];
                            *pos += 1;
                            for k in &outs {
                                self.stage(*k, tok);
                            }
                        }
                    }
                    AppOp::Mem(role) if role == "linebuffer" => {
                        let ins = self.in_keys(v);
                        if ins.iter().all(|k| !self.channels[k].q.is_empty()) {
                            let tok =
                                self.channels.get_mut(&ins[0]).unwrap().q.pop_front().unwrap();
                            let delay = self.linebuffer_delay;
                            let line = self.state.entry(v).or_default();
                            line.push_back(tok);
                            let out_tok = if line.len() > delay {
                                line.pop_front().unwrap()
                            } else {
                                0 // priming zeros
                            };
                            for k in &outs {
                                self.stage(*k, out_tok);
                            }
                        }
                    }
                    AppOp::Alu(op) => {
                        let ins = self.in_keys(v);
                        if !ins.is_empty()
                            && ins.iter().all(|k| !self.channels[k].q.is_empty())
                        {
                            let args: Vec<i64> = ins
                                .iter()
                                .map(|k| self.channels.get_mut(k).unwrap().q.pop_front().unwrap())
                                .collect();
                            let val = self.eval_alu(v, op, &args);
                            for k in &outs {
                                self.stage(*k, val);
                            }
                        }
                    }
                    AppOp::Reg => {
                        // A register is a 1-token delay line: out[i] =
                        // in[i-1], with a zero priming token — this is
                        // what makes stencil window registers select the
                        // previous pixel column.
                        let ins = self.in_keys(v);
                        if ins.iter().all(|k| !self.channels[k].q.is_empty()) {
                            let tok =
                                self.channels.get_mut(&ins[0]).unwrap().q.pop_front().unwrap();
                            let st = self.state.entry(v).or_default();
                            let prev = if st.is_empty() { 0 } else { st.pop_front().unwrap() };
                            st.push_back(tok);
                            for k in &outs {
                                self.stage(*k, prev);
                            }
                        }
                    }
                    AppOp::Const(c) => {
                        let c = *c;
                        for k in &outs {
                            self.stage(*k, c);
                        }
                    }
                    AppOp::Mem(_) => {
                        // other memory roles behave as pass-throughs
                        let ins = self.in_keys(v);
                        if !ins.is_empty()
                            && ins.iter().all(|k| !self.channels[k].q.is_empty())
                        {
                            let tok =
                                self.channels.get_mut(&ins[0]).unwrap().q.pop_front().unwrap();
                            for k in ins.iter().skip(1) {
                                self.channels.get_mut(k).unwrap().q.pop_front();
                            }
                            for k in &outs {
                                self.stage(*k, tok);
                            }
                        }
                    }
                }
            }

            // Commit this cycle's productions: visible next cycle.
            for (key, tok) in self.pending.drain(..) {
                self.channels.get_mut(&key).unwrap().q.push_back(tok);
            }
            self.staged.clear();

            cycles += 1;
        }

        let tokens = outputs.values().map(Vec::len).min().unwrap_or(0);
        SimRun { outputs, cycles, tokens }
    }

    fn eval_alu(&mut self, v: AppNodeId, op: &str, args: &[i64]) -> i64 {
        let a = args.first().copied().unwrap_or(0);
        let b = args.get(1).copied().unwrap_or(0);
        match op {
            "add" => a.wrapping_add(b),
            "sub" => a.wrapping_sub(b),
            "mul" => a.wrapping_mul(b),
            "ashr" => a >> (b & 63),
            "max" => a.max(b),
            "min" => a.min(b),
            "abs" => a.wrapping_abs(),
            "mac" => {
                let acc = self.state.entry(v).or_default();
                if acc.is_empty() {
                    acc.push_back(0);
                }
                let sum = acc[0].wrapping_add(a.wrapping_mul(if args.len() > 1 { b } else { 1 }));
                acc[0] = sum;
                sum
            }
            other => panic!("unknown ALU op `{other}`"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;

    fn uniform_caps(app: &AppGraph, cap: usize) -> HashMap<(AppNodeId, u8, AppNodeId, u8), usize> {
        app.edges().iter().map(|e| ((e.src, e.src_port, e.dst, e.dst_port), cap)).collect()
    }

    fn stream(n: usize) -> Vec<i64> {
        (0..n as i64).map(|i| (i * 7 + 3) % 251).collect()
    }

    #[test]
    fn pointwise_computes_correct_values() {
        let app = apps::pointwise(3);
        // in -> *1 -> +2 -> *3 -> out
        let caps = uniform_caps(&app, 2);
        let mut sim = RvSim::new(&app, &caps, stream(16));
        let run = sim.run(8, 10_000, StallPattern::None);
        let out = &run.outputs["out"];
        assert_eq!(out.len(), 8);
        for (i, &v) in out.iter().enumerate() {
            let x = stream(16)[i];
            assert_eq!(v, (x * 1 + 2) * 3, "token {i}");
        }
    }

    #[test]
    fn elasticity_preserves_output_sequence() {
        // The core RV invariant: stalls retime but never reorder/corrupt.
        for app in [apps::gaussian(), apps::camera(), apps::pointwise(5)] {
            let caps = uniform_caps(&app, 2);
            let free = RvSim::new(&app, &caps, stream(64)).run(24, 100_000, StallPattern::None);
            let bursty = RvSim::new(&app, &caps, stream(64)).run(
                24,
                100_000,
                StallPattern::Bursty { accept: 2, stall: 3 },
            );
            let random = RvSim::new(&app, &caps, stream(64)).run(
                24,
                100_000,
                StallPattern::Random { p: 0.3, seed: 9 },
            );
            for (name, seq) in &free.outputs {
                assert_eq!(&bursty.outputs[name][..], &seq[..], "{}: bursty diverged", app.name);
                assert_eq!(&random.outputs[name][..], &seq[..], "{}: random diverged", app.name);
            }
        }
    }

    #[test]
    fn buffering_improves_unbalanced_reconvergence() {
        // a -> e directly and a -> b -> c -> e: the short path needs >= 3
        // slack slots to keep `a` producing at full rate.
        let mut g = AppGraph::new("reconverge");
        let i = g.mem("in", "stream_in");
        let b = g.alu("b", "add");
        let c = g.alu("c", "add");
        let d = g.alu("d", "add");
        let e = g.alu("e", "add");
        let o = g.mem("out", "stream_out");
        let k = g.add("k", AppOp::Const(1));
        g.wire(i, b, 0);
        g.wire(k, b, 1);
        g.wire(b, c, 0);
        g.wire(c, d, 0);
        g.wire(i, e, 0); // short path
        g.wire(d, e, 1); // long path
        g.wire(e, o, 0);
        g.check().unwrap();

        let n = 32;
        let run1 = RvSim::new(&g, &uniform_caps(&g, 1), stream(64)).run(n, 100_000, StallPattern::None);
        let run4 = RvSim::new(&g, &uniform_caps(&g, 4), stream(64)).run(n, 100_000, StallPattern::None);
        assert_eq!(run1.outputs["out"], run4.outputs["out"]);
        assert!(
            run4.cycles < run1.cycles,
            "deep channels must be faster: {} vs {}",
            run4.cycles,
            run1.cycles
        );
    }

    #[test]
    fn fabric_capacity_model() {
        assert_eq!(FabricKind::Static.capacity(3), 1);
        assert_eq!(FabricKind::RvFullFifo { depth: 2 }.capacity(3), 7);
        assert_eq!(FabricKind::RvSplitFifo.capacity(3), 4);
        assert_eq!(FabricKind::RvSplitFifo.period_penalty_ps(1), 0.0);
        assert!(FabricKind::RvSplitFifo.period_penalty_ps(3) > 0.0);
        assert_eq!(FabricKind::Static.period_penalty_ps(5), 0.0);
    }

    #[test]
    fn mac_accumulates() {
        let mut g = AppGraph::new("acc");
        let i = g.mem("in", "stream_in");
        let m = g.alu("m", "mac");
        let o = g.mem("out", "stream_out");
        g.wire(i, m, 0);
        g.wire(m, o, 0);
        let caps = uniform_caps(&g, 2);
        let run = RvSim::new(&g, &caps, vec![1, 2, 3, 4]).run(4, 1000, StallPattern::None);
        assert_eq!(run.outputs["out"], vec![1, 3, 6, 10]);
    }
}
