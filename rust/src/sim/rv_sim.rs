//! Cycle-accurate ready-valid (elastic) simulation.
//!
//! Models the statically-configured NoC backend (§3.3): every routed edge
//! is an elastic channel whose buffering comes from the interconnect
//! registers the route passes through — none for a static fabric, depth-2
//! FIFOs in full-FIFO mode, shared split FIFOs in split mode (Fig. 6).
//! Vertices fire when all inputs are valid and all outputs ready, exactly
//! the join semantics the ready/valid layers implement in hardware.
//!
//! Two invariants matter (tested end-to-end in `tests/rv_elasticity.rs`):
//! - **elasticity preserves values**: any stall pattern produces the same
//!   output *sequence* as an unconstrained run (FIFOs only retime);
//! - **buffering recovers throughput**: unbalanced reconvergent paths and
//!   bursty sinks run faster with deeper channels — the reason the RV
//!   backend needs FIFOs at all (Fig. 8's motivation).
//!
//! ## Storage layout
//!
//! The simulator is the DSE engine's per-point hot loop (every fabric
//! sweep point simulates), so it runs entirely on dense arena storage
//! built once at construction:
//!
//! - **channels** are a flat array indexed by *edge index* (channel `i`
//!   is `app.edges()[i]`), with per-channel `cap/base/head/len` arrays;
//! - **queues** are ring-buffer windows into ONE backing `buf: Vec<i64>`
//!   (channel `c` owns `buf[base[c] .. base[c] + cap[c]]`);
//! - **per-vertex fan-in/fan-out** are CSR index lists (`in_start` /
//!   `in_chan`, `out_start` / `out_chan`) mirroring `inputs_of` (sorted
//!   by destination port) and `outputs_of` (edge order) exactly;
//! - **ops** are pre-classified into a dense [`VertexKind`] array, so the
//!   cycle loop never hashes a key or matches a role string.
//!
//! The cycle-level semantics are bit-identical to the original
//! `HashMap`-of-`VecDeque` implementation, which is preserved under
//! `#[cfg(test)]` as the `reference` oracle module and asserted
//! equivalent cycle-for-cycle by the golden tests below.

use std::collections::{HashMap, VecDeque};

use crate::pnr::app::{AppGraph, AppNodeId, AppOp};
use crate::pnr::{PackedApp, RoutingResult};
use crate::util::rng::Rng;

/// Which fabric the channels model.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum FabricKind {
    /// Static interconnect: no elastic buffering (capacity-1 wires).
    Static,
    /// Ready-valid with a depth-`d` FIFO at every route register.
    RvFullFifo { depth: u8 },
    /// Ready-valid with split FIFOs: each register contributes one entry;
    /// adjacent pairs chain into depth-2 (Fig. 6).
    RvSplitFifo,
}

impl FabricKind {
    /// Channel capacity for a route that crosses `regs` register nodes.
    pub fn capacity(self, regs: usize) -> usize {
        match self {
            FabricKind::Static => 1,
            FabricKind::RvFullFifo { depth } => 1 + regs * depth as usize,
            FabricKind::RvSplitFifo => 1 + regs,
        }
    }

    /// Extra combinational delay (ps) from chained split-FIFO control:
    /// "these control signals cannot be registered at the tile boundary;
    /// the longer the FIFO is chained, the longer the combinational delay"
    /// (§3.3). `chain` = longest register chain on any route.
    pub fn period_penalty_ps(self, chain: usize) -> f64 {
        match self {
            FabricKind::RvSplitFifo => 35.0 * chain.saturating_sub(1) as f64,
            _ => 0.0,
        }
    }

    /// Stable label, used by the DSE `ConfigDescriptor`, cache rows, and
    /// the `canal dse --fabric` flag. Inverse of [`FabricKind::parse`].
    pub fn label(self) -> String {
        match self {
            FabricKind::Static => "static".into(),
            FabricKind::RvFullFifo { depth } => format!("rv-full:{depth}"),
            FabricKind::RvSplitFifo => "rv-split".into(),
        }
    }

    /// Parse a label: `static`, `rv-full` (depth 2), `rv-full:D`,
    /// `rv-split`.
    pub fn parse(s: &str) -> Option<FabricKind> {
        match s {
            "static" => Some(FabricKind::Static),
            "rv-full" => Some(FabricKind::RvFullFifo { depth: 2 }),
            "rv-split" => Some(FabricKind::RvSplitFifo),
            other => other
                .strip_prefix("rv-full:")
                .and_then(|d| d.parse().ok())
                .map(|depth| FabricKind::RvFullFifo { depth }),
        }
    }

    /// The area model's matching fabric mode (Fig. 8's three bars).
    pub fn area_mode(self) -> crate::area::FabricMode {
        match self {
            FabricKind::Static => crate::area::FabricMode::Static,
            FabricKind::RvFullFifo { depth } => {
                crate::area::FabricMode::ReadyValidFullFifo { fifo_depth: depth as usize }
            }
            FabricKind::RvSplitFifo => crate::area::FabricMode::ReadyValidSplitFifo,
        }
    }
}

/// Stall model applied to stream sinks (downstream backpressure).
#[derive(Clone, Copy, Debug)]
pub enum StallPattern {
    None,
    /// Sink accepts `accept` cycles then stalls `stall` cycles.
    Bursty { accept: u32, stall: u32 },
    /// Random stalls with probability `p` (deterministic seed).
    Random { p: f64, seed: u64 },
}

/// Result of a simulation run.
#[derive(Clone, Debug)]
pub struct SimRun {
    /// Output token sequence per stream-out vertex (sorted by name).
    pub outputs: HashMap<String, Vec<i64>>,
    pub cycles: usize,
    pub tokens: usize,
}

/// Per-edge channel capacities, derived from a routing result (registers
/// crossed per sink path) or uniform for un-routed simulations.
///
/// When a routing is given, `app` must be the graph the nets were routed
/// for (the *packed* application). For capacities on the un-packed graph
/// use [`routed_capacities`], which maps folded constants and registers
/// back through the packing.
pub fn channel_capacities(
    app: &AppGraph,
    routing: Option<(&crate::ir::Interconnect, u8, &RoutingResult)>,
    fabric: FabricKind,
) -> HashMap<(AppNodeId, u8, AppNodeId, u8), usize> {
    let mut caps = HashMap::new();
    match routing {
        Some((ic, bw, routing)) => {
            let g = ic.compiled(bw);
            for tree in &routing.trees {
                for (k, &(dst, dport)) in tree.net.sinks.iter().enumerate() {
                    let regs = tree.sink_paths[k]
                        .iter()
                        .filter(|&&n| g.is_register(n))
                        .count();
                    caps.insert(
                        (tree.net.src, tree.net.src_port, dst, dport),
                        fabric.capacity(regs),
                    );
                }
            }
        }
        None => {
            for e in app.edges() {
                caps.insert((e.src, e.src_port, e.dst, e.dst_port), fabric.capacity(1));
            }
        }
    }
    caps
}

/// Per-edge channel capacities for the **un-packed** application, derived
/// from the routed nets of its packed form: each surviving edge gets the
/// elastic capacity of the interconnect registers its route crosses;
/// edges folded into a PE by packing (constant immediates, packed input
/// registers) never cross the fabric and get `fabric.capacity(0)`. An
/// edge *into* a packed-away register maps to the routed net that lands
/// on the register's host port.
pub fn routed_capacities(
    app: &AppGraph,
    packed: &PackedApp,
    ic: &crate::ir::Interconnect,
    bit_width: u8,
    routing: &RoutingResult,
    fabric: FabricKind,
) -> HashMap<(AppNodeId, u8, AppNodeId, u8), usize> {
    let g = ic.compiled(bit_width);
    // Interconnect registers crossed per routed (src, sport, dst, dport).
    let mut regs: HashMap<(AppNodeId, u8, AppNodeId, u8), usize> = HashMap::new();
    for tree in &routing.trees {
        for (k, &(dst, dport)) in tree.net.sinks.iter().enumerate() {
            let n = tree.sink_paths[k].iter().filter(|&&n| g.is_register(n)).count();
            regs.insert((tree.net.src, tree.net.src_port, dst, dport), n);
        }
    }
    let mut caps = HashMap::new();
    for e in app.edges() {
        let crossed = match packed.mapping.get(&e.src) {
            // Constant immediates and packed registers live inside their
            // host PE: this edge never crosses the fabric.
            None => 0,
            Some(&s) => {
                let sink = match packed.mapping.get(&e.dst) {
                    Some(&d) => Some((d, e.dst_port)),
                    // `e.dst` is a packed-away Reg: the routed net lands
                    // on its single consumer's (registered) port.
                    None => app.outputs_of(e.dst).first().and_then(|oe| {
                        packed.mapping.get(&oe.dst).map(|&d| (d, oe.dst_port))
                    }),
                };
                sink.and_then(|(d, dport)| regs.get(&(s, e.src_port, d, dport)).copied())
                    .unwrap_or(0)
            }
        };
        caps.insert((e.src, e.src_port, e.dst, e.dst_port), fabric.capacity(crossed));
    }
    caps
}

/// Default linebuffer delay in tokens (a "row" of the modeled image).
pub const DEFAULT_LINEBUFFER_DELAY: usize = 8;

/// Dense per-vertex op classification, resolved once at construction so
/// the cycle loop never matches on role/op strings.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum VertexKind {
    StreamIn,
    StreamOut,
    Linebuffer,
    /// Any other memory role: pass-through.
    MemPass,
    Alu(AluOp),
    Reg,
    Const(i64),
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum AluOp {
    Add,
    Sub,
    Mul,
    Ashr,
    Max,
    Min,
    Abs,
    Mac,
    /// Unrecognized op string: panics if it ever fires (matching the
    /// original implementation's fire-time error).
    Other,
}

fn classify(op: &AppOp) -> VertexKind {
    match op {
        AppOp::Mem(role) if role == "stream_in" => VertexKind::StreamIn,
        AppOp::Mem(role) if role == "stream_out" => VertexKind::StreamOut,
        AppOp::Mem(role) if role == "linebuffer" => VertexKind::Linebuffer,
        AppOp::Mem(_) => VertexKind::MemPass,
        AppOp::Reg => VertexKind::Reg,
        AppOp::Const(c) => VertexKind::Const(*c),
        AppOp::Alu(op) => VertexKind::Alu(match op.as_str() {
            "add" => AluOp::Add,
            "sub" => AluOp::Sub,
            "mul" => AluOp::Mul,
            "ashr" => AluOp::Ashr,
            "max" => AluOp::Max,
            "min" => AluOp::Min,
            "abs" => AluOp::Abs,
            "mac" => AluOp::Mac,
            _ => AluOp::Other,
        }),
    }
}

/// The elastic dataflow simulator (flat arena storage; see module docs).
pub struct RvSim<'a> {
    app: &'a AppGraph,
    /// Pre-classified op per vertex.
    kinds: Vec<VertexKind>,
    /// CSR fan-in: vertex `v`'s input channels are
    /// `in_chan[in_start[v]..in_start[v+1]]`, sorted by destination port
    /// (the argument order `inputs_of` defines).
    in_start: Vec<u32>,
    in_chan: Vec<u32>,
    /// CSR fan-out: `out_chan[out_start[v]..out_start[v+1]]`, edge order.
    out_start: Vec<u32>,
    out_chan: Vec<u32>,
    /// Per-channel ring windows into `buf`.
    cap: Vec<u32>,
    base: Vec<u32>,
    head: Vec<u32>,
    len: Vec<u32>,
    /// Staged push counts per channel (capacity checks within a cycle).
    staged: Vec<u32>,
    /// Single backing buffer for every channel queue.
    buf: Vec<i64>,
    /// Tokens produced this cycle, visible next cycle (1-cycle stages).
    pending: Vec<(u32, i64)>,
    /// MAC accumulators and linebuffer delay lines, per vertex.
    state: Vec<VecDeque<i64>>,
    input_stream: Vec<i64>,
    /// Next input index per stream-in vertex.
    in_pos: Vec<usize>,
    /// Reusable ALU argument scratch.
    args: Vec<i64>,
    /// Linebuffer depth: the row stride of the streamed image.
    pub linebuffer_delay: usize,
}

impl<'a> RvSim<'a> {
    pub fn new(
        app: &'a AppGraph,
        caps: &HashMap<(AppNodeId, u8, AppNodeId, u8), usize>,
        input_stream: Vec<i64>,
    ) -> Self {
        let nv = app.len();
        let edges = app.edges();
        let ne = edges.len();
        let kinds: Vec<VertexKind> = app.iter().map(|(_, n)| classify(&n.op)).collect();

        // Channel capacities and ring windows (channel i == edge i).
        let mut cap = Vec::with_capacity(ne);
        let mut base = Vec::with_capacity(ne);
        let mut total = 0u32;
        for e in edges {
            let c = caps.get(&(e.src, e.src_port, e.dst, e.dst_port)).copied().unwrap_or(1);
            base.push(total);
            cap.push(c as u32);
            total += c as u32;
        }

        // CSR fan-in/fan-out, built in one counting pass + one fill pass.
        let mut in_start = vec![0u32; nv + 1];
        let mut out_start = vec![0u32; nv + 1];
        for e in edges {
            in_start[e.dst.index() + 1] += 1;
            out_start[e.src.index() + 1] += 1;
        }
        for v in 0..nv {
            in_start[v + 1] += in_start[v];
            out_start[v + 1] += out_start[v];
        }
        let mut in_chan = vec![0u32; ne];
        let mut out_chan = vec![0u32; ne];
        let mut in_fill: Vec<u32> = in_start.clone();
        let mut out_fill: Vec<u32> = out_start.clone();
        for (ci, e) in edges.iter().enumerate() {
            in_chan[in_fill[e.dst.index()] as usize] = ci as u32;
            in_fill[e.dst.index()] += 1;
            out_chan[out_fill[e.src.index()] as usize] = ci as u32;
            out_fill[e.src.index()] += 1;
        }
        // Inputs sorted by destination port (stable on edge order —
        // exactly `inputs_of`); outputs stay in edge order.
        for v in 0..nv {
            in_chan[in_start[v] as usize..in_start[v + 1] as usize]
                .sort_by_key(|&c| edges[c as usize].dst_port);
        }

        RvSim {
            app,
            kinds,
            in_start,
            in_chan,
            out_start,
            out_chan,
            buf: vec![0; total as usize],
            head: vec![0; ne],
            len: vec![0; ne],
            staged: vec![0; ne],
            cap,
            base,
            pending: Vec::new(),
            state: vec![VecDeque::new(); nv],
            input_stream,
            in_pos: vec![0; nv],
            args: Vec::new(),
            linebuffer_delay: DEFAULT_LINEBUFFER_DELAY,
        }
    }

    #[inline]
    fn ins(&self, v: usize) -> std::ops::Range<usize> {
        self.in_start[v] as usize..self.in_start[v + 1] as usize
    }

    #[inline]
    fn outs(&self, v: usize) -> std::ops::Range<usize> {
        self.out_start[v] as usize..self.out_start[v + 1] as usize
    }

    /// All of `v`'s input channels hold at least one token.
    #[inline]
    fn inputs_valid(&self, v: usize) -> bool {
        self.ins(v).all(|i| self.len[self.in_chan[i] as usize] > 0)
    }

    /// `c` can absorb one more push this cycle (occupancy + already
    /// staged pushes below capacity).
    #[inline]
    fn channel_ready(&self, c: usize) -> bool {
        self.len[c] + self.staged[c] < self.cap[c]
    }

    #[inline]
    fn pop(&mut self, c: usize) -> i64 {
        debug_assert!(self.len[c] > 0);
        let tok = self.buf[(self.base[c] + self.head[c]) as usize];
        self.head[c] = (self.head[c] + 1) % self.cap[c];
        self.len[c] -= 1;
        tok
    }

    #[inline]
    fn stage(&mut self, c: u32, tok: i64) {
        self.pending.push((c, tok));
        self.staged[c as usize] += 1;
    }

    /// Stage `tok` on every output channel of `v`.
    #[inline]
    #[allow(clippy::needless_range_loop)] // body needs &mut self
    fn stage_outputs(&mut self, v: usize, tok: i64) {
        for i in self.outs(v) {
            let c = self.out_chan[i];
            self.stage(c, tok);
        }
    }

    /// Commit this cycle's productions: visible next cycle.
    fn commit_pending(&mut self) {
        let mut pending = std::mem::take(&mut self.pending);
        for &(c, tok) in &pending {
            let c = c as usize;
            debug_assert!(self.len[c] < self.cap[c]);
            let slot = (self.base[c] + (self.head[c] + self.len[c]) % self.cap[c]) as usize;
            self.buf[slot] = tok;
            self.len[c] += 1;
            self.staged[c] = 0;
        }
        pending.clear();
        self.pending = pending; // keep the allocation across cycles
    }

    /// Run until every stream-out vertex has collected `n_tokens` or
    /// `max_cycles` elapse.
    // Index loops over the CSR channel lists are deliberate: the loop
    // bodies call `&mut self` methods (`pop`/`stage`), so iterator
    // borrows of `in_chan`/`out_chan` cannot be held across them.
    #[allow(clippy::needless_range_loop)]
    pub fn run(&mut self, n_tokens: usize, max_cycles: usize, stall: StallPattern) -> SimRun {
        let nv = self.kinds.len();
        let sinks: Vec<usize> =
            (0..nv).filter(|&v| self.kinds[v] == VertexKind::StreamOut).collect();
        let mut collected: Vec<Vec<i64>> = vec![Vec::new(); sinks.len()];
        let mut rng = Rng::new(match stall {
            StallPattern::Random { seed, .. } => seed,
            _ => 0,
        });

        let mut cycles = 0usize;
        while cycles < max_cycles && collected.iter().any(|v| v.len() < n_tokens) {
            // Sink acceptance this cycle.
            let sink_ready = match stall {
                StallPattern::None => true,
                StallPattern::Bursty { accept, stall } => {
                    (cycles as u32) % (accept + stall) < accept
                }
                StallPattern::Random { p, .. } => rng.f64() >= p,
            };

            // Two-phase update: decide fires on the pre-cycle state.
            // (Vertices read channel occupancy as of cycle start; pushes
            // land visible next cycle — modeled by draining *then*
            // firing producers in reverse topological order.)
            if sink_ready {
                for (si, &v) in sinks.iter().enumerate() {
                    let ins = self.ins(v);
                    if ins.is_empty() {
                        continue;
                    }
                    // Accept one token per input channel per cycle.
                    if self.inputs_valid(v) {
                        let first = self.in_chan[ins.start] as usize;
                        let tok = self.pop(first);
                        for i in ins.start + 1..ins.end {
                            let c = self.in_chan[i] as usize;
                            self.pop(c);
                        }
                        collected[si].push(tok);
                    }
                }
            }

            for v in 0..nv {
                let outs = self.outs(v);
                if outs.is_empty() {
                    continue; // sinks handled above
                }
                let outs_ready = outs.clone().all(|i| self.channel_ready(self.out_chan[i] as usize));
                if !outs_ready {
                    continue;
                }
                match self.kinds[v] {
                    VertexKind::StreamIn => {
                        let pos = self.in_pos[v];
                        if pos < self.input_stream.len() {
                            let tok = self.input_stream[pos];
                            self.in_pos[v] = pos + 1;
                            self.stage_outputs(v, tok);
                        }
                    }
                    VertexKind::Linebuffer => {
                        if self.inputs_valid(v) {
                            let ins = self.ins(v);
                            let first = self.in_chan[ins.clone()][0] as usize;
                            let tok = self.pop(first);
                            let delay = self.linebuffer_delay;
                            let line = &mut self.state[v];
                            line.push_back(tok);
                            let out_tok = if line.len() > delay {
                                line.pop_front().unwrap()
                            } else {
                                0 // priming zeros
                            };
                            self.stage_outputs(v, out_tok);
                        }
                    }
                    VertexKind::Alu(op) => {
                        let ins = self.ins(v);
                        if !ins.is_empty() && self.inputs_valid(v) {
                            self.args.clear();
                            for i in ins {
                                let c = self.in_chan[i] as usize;
                                let tok = self.pop(c);
                                self.args.push(tok);
                            }
                            let val = self.eval_alu(v, op);
                            self.stage_outputs(v, val);
                        }
                    }
                    VertexKind::Reg => {
                        // A register is a 1-token delay line: out[i] =
                        // in[i-1], with a zero priming token — this is
                        // what makes stencil window registers select the
                        // previous pixel column.
                        if self.inputs_valid(v) {
                            let ins = self.ins(v);
                            let first = self.in_chan[ins.clone()][0] as usize;
                            let tok = self.pop(first);
                            let st = &mut self.state[v];
                            let prev = if st.is_empty() { 0 } else { st.pop_front().unwrap() };
                            st.push_back(tok);
                            self.stage_outputs(v, prev);
                        }
                    }
                    VertexKind::Const(c) => {
                        self.stage_outputs(v, c);
                    }
                    // Other memory roles pass through; a stream-out
                    // with outputs (never reached for normal terminal
                    // sinks, which bail at `outs.is_empty()` above)
                    // behaves the same way, exactly as the reference.
                    VertexKind::MemPass | VertexKind::StreamOut => {
                        let ins = self.ins(v);
                        if !ins.is_empty() && self.inputs_valid(v) {
                            let first = self.in_chan[ins.start] as usize;
                            let tok = self.pop(first);
                            for i in ins.start + 1..ins.end {
                                let c = self.in_chan[i] as usize;
                                self.pop(c);
                            }
                            self.stage_outputs(v, tok);
                        }
                    }
                }
            }

            self.commit_pending();
            cycles += 1;
        }

        let outputs: HashMap<String, Vec<i64>> = sinks
            .iter()
            .zip(collected)
            .map(|(&v, seq)| (self.app.node(AppNodeId(v as u32)).name.clone(), seq))
            .collect();
        let tokens = outputs.values().map(Vec::len).min().unwrap_or(0);
        SimRun { outputs, cycles, tokens }
    }

    fn eval_alu(&mut self, v: usize, op: AluOp) -> i64 {
        let a = self.args.first().copied().unwrap_or(0);
        let b = self.args.get(1).copied().unwrap_or(0);
        match op {
            AluOp::Add => a.wrapping_add(b),
            AluOp::Sub => a.wrapping_sub(b),
            AluOp::Mul => a.wrapping_mul(b),
            AluOp::Ashr => a >> (b & 63),
            AluOp::Max => a.max(b),
            AluOp::Min => a.min(b),
            AluOp::Abs => a.wrapping_abs(),
            AluOp::Mac => {
                let factor = if self.args.len() > 1 { b } else { 1 };
                let acc = &mut self.state[v];
                if acc.is_empty() {
                    acc.push_back(0);
                }
                let sum = acc[0].wrapping_add(a.wrapping_mul(factor));
                acc[0] = sum;
                sum
            }
            AluOp::Other => match &self.app.node(AppNodeId(v as u32)).op {
                AppOp::Alu(name) => panic!("unknown ALU op `{name}`"),
                _ => unreachable!("non-ALU vertex classified as ALU"),
            },
        }
    }
}

/// The original `HashMap`-of-`VecDeque` simulator, kept verbatim as the
/// golden oracle: the flattened [`RvSim`] must match it cycle-for-cycle
/// on every app, fabric, and stall pattern (asserted in the tests below).
#[cfg(test)]
pub(crate) mod reference {
    use super::*;

    struct Channel {
        cap: usize,
        q: VecDeque<i64>,
    }

    pub struct ReferenceRvSim<'a> {
        app: &'a AppGraph,
        channels: HashMap<(AppNodeId, u8, AppNodeId, u8), Channel>,
        state: HashMap<AppNodeId, VecDeque<i64>>,
        input_stream: Vec<i64>,
        in_pos: HashMap<AppNodeId, usize>,
        pending: Vec<((AppNodeId, u8, AppNodeId, u8), i64)>,
        staged: HashMap<(AppNodeId, u8, AppNodeId, u8), usize>,
        pub linebuffer_delay: usize,
    }

    impl<'a> ReferenceRvSim<'a> {
        pub fn new(
            app: &'a AppGraph,
            caps: &HashMap<(AppNodeId, u8, AppNodeId, u8), usize>,
            input_stream: Vec<i64>,
        ) -> Self {
            let mut channels = HashMap::new();
            for e in app.edges() {
                let key = (e.src, e.src_port, e.dst, e.dst_port);
                let cap = caps.get(&key).copied().unwrap_or(1);
                channels.insert(key, Channel { cap, q: VecDeque::new() });
            }
            ReferenceRvSim {
                app,
                channels,
                state: HashMap::new(),
                input_stream,
                in_pos: HashMap::new(),
                pending: Vec::new(),
                staged: HashMap::new(),
                linebuffer_delay: DEFAULT_LINEBUFFER_DELAY,
            }
        }

        fn stage(&mut self, key: (AppNodeId, u8, AppNodeId, u8), tok: i64) {
            self.pending.push((key, tok));
            *self.staged.entry(key).or_insert(0) += 1;
        }

        fn channel_ready(&self, key: &(AppNodeId, u8, AppNodeId, u8)) -> bool {
            let ch = &self.channels[key];
            ch.q.len() + self.staged.get(key).copied().unwrap_or(0) < ch.cap
        }

        fn out_keys(&self, v: AppNodeId) -> Vec<(AppNodeId, u8, AppNodeId, u8)> {
            self.app
                .outputs_of(v)
                .iter()
                .map(|e| (e.src, e.src_port, e.dst, e.dst_port))
                .collect()
        }

        fn in_keys(&self, v: AppNodeId) -> Vec<(AppNodeId, u8, AppNodeId, u8)> {
            self.app
                .inputs_of(v)
                .iter()
                .map(|e| (e.src, e.src_port, e.dst, e.dst_port))
                .collect()
        }

        pub fn run(
            &mut self,
            n_tokens: usize,
            max_cycles: usize,
            stall: StallPattern,
        ) -> SimRun {
            let sinks: Vec<AppNodeId> = self
                .app
                .iter()
                .filter(|(_, n)| matches!(&n.op, AppOp::Mem(r) if r == "stream_out"))
                .map(|(id, _)| id)
                .collect();
            let mut outputs: HashMap<String, Vec<i64>> = sinks
                .iter()
                .map(|&s| (self.app.node(s).name.clone(), Vec::new()))
                .collect();
            let mut rng = Rng::new(match stall {
                StallPattern::Random { seed, .. } => seed,
                _ => 0,
            });

            let order: Vec<AppNodeId> = self.app.ids().collect();
            let mut cycles = 0usize;
            while cycles < max_cycles && outputs.values().any(|v| v.len() < n_tokens) {
                let sink_ready = match stall {
                    StallPattern::None => true,
                    StallPattern::Bursty { accept, stall } => {
                        (cycles as u32) % (accept + stall) < accept
                    }
                    StallPattern::Random { p, .. } => rng.f64() >= p,
                };

                for &v in order.iter() {
                    let node = self.app.node(v);
                    match &node.op {
                        AppOp::Mem(role) if role == "stream_out" => {
                            if !sink_ready {
                                continue;
                            }
                            let keys = self.in_keys(v);
                            if keys.is_empty() {
                                continue;
                            }
                            if keys.iter().all(|k| !self.channels[k].q.is_empty()) {
                                let tok = self
                                    .channels
                                    .get_mut(&keys[0])
                                    .unwrap()
                                    .q
                                    .pop_front()
                                    .unwrap();
                                for k in &keys[1..] {
                                    self.channels.get_mut(k).unwrap().q.pop_front();
                                }
                                outputs.get_mut(&node.name).unwrap().push(tok);
                            }
                        }
                        _ => {}
                    }
                }

                for &v in order.iter() {
                    let node = self.app.node(v);
                    let outs = self.out_keys(v);
                    if outs.is_empty() {
                        continue;
                    }
                    let outs_ready = outs.iter().all(|k| self.channel_ready(k));
                    if !outs_ready {
                        continue;
                    }
                    match &node.op {
                        AppOp::Mem(role) if role == "stream_in" => {
                            let pos = self.in_pos.entry(v).or_insert(0);
                            if *pos < self.input_stream.len() {
                                let tok = self.input_stream[*pos];
                                *pos += 1;
                                for k in &outs {
                                    self.stage(*k, tok);
                                }
                            }
                        }
                        AppOp::Mem(role) if role == "linebuffer" => {
                            let ins = self.in_keys(v);
                            if ins.iter().all(|k| !self.channels[k].q.is_empty()) {
                                let tok = self
                                    .channels
                                    .get_mut(&ins[0])
                                    .unwrap()
                                    .q
                                    .pop_front()
                                    .unwrap();
                                let delay = self.linebuffer_delay;
                                let line = self.state.entry(v).or_default();
                                line.push_back(tok);
                                let out_tok = if line.len() > delay {
                                    line.pop_front().unwrap()
                                } else {
                                    0
                                };
                                for k in &outs {
                                    self.stage(*k, out_tok);
                                }
                            }
                        }
                        AppOp::Alu(op) => {
                            let ins = self.in_keys(v);
                            if !ins.is_empty()
                                && ins.iter().all(|k| !self.channels[k].q.is_empty())
                            {
                                let args: Vec<i64> = ins
                                    .iter()
                                    .map(|k| {
                                        self.channels
                                            .get_mut(k)
                                            .unwrap()
                                            .q
                                            .pop_front()
                                            .unwrap()
                                    })
                                    .collect();
                                let op = op.clone();
                                let val = self.eval_alu(v, &op, &args);
                                for k in &outs {
                                    self.stage(*k, val);
                                }
                            }
                        }
                        AppOp::Reg => {
                            let ins = self.in_keys(v);
                            if ins.iter().all(|k| !self.channels[k].q.is_empty()) {
                                let tok = self
                                    .channels
                                    .get_mut(&ins[0])
                                    .unwrap()
                                    .q
                                    .pop_front()
                                    .unwrap();
                                let st = self.state.entry(v).or_default();
                                let prev =
                                    if st.is_empty() { 0 } else { st.pop_front().unwrap() };
                                st.push_back(tok);
                                for k in &outs {
                                    self.stage(*k, prev);
                                }
                            }
                        }
                        AppOp::Const(c) => {
                            let c = *c;
                            for k in &outs {
                                self.stage(*k, c);
                            }
                        }
                        AppOp::Mem(_) => {
                            let ins = self.in_keys(v);
                            if !ins.is_empty()
                                && ins.iter().all(|k| !self.channels[k].q.is_empty())
                            {
                                let tok = self
                                    .channels
                                    .get_mut(&ins[0])
                                    .unwrap()
                                    .q
                                    .pop_front()
                                    .unwrap();
                                for k in ins.iter().skip(1) {
                                    self.channels.get_mut(k).unwrap().q.pop_front();
                                }
                                for k in &outs {
                                    self.stage(*k, tok);
                                }
                            }
                        }
                    }
                }

                for (key, tok) in self.pending.drain(..) {
                    self.channels.get_mut(&key).unwrap().q.push_back(tok);
                }
                self.staged.clear();

                cycles += 1;
            }

            let tokens = outputs.values().map(Vec::len).min().unwrap_or(0);
            SimRun { outputs, cycles, tokens }
        }

        fn eval_alu(&mut self, v: AppNodeId, op: &str, args: &[i64]) -> i64 {
            let a = args.first().copied().unwrap_or(0);
            let b = args.get(1).copied().unwrap_or(0);
            match op {
                "add" => a.wrapping_add(b),
                "sub" => a.wrapping_sub(b),
                "mul" => a.wrapping_mul(b),
                "ashr" => a >> (b & 63),
                "max" => a.max(b),
                "min" => a.min(b),
                "abs" => a.wrapping_abs(),
                "mac" => {
                    let acc = self.state.entry(v).or_default();
                    if acc.is_empty() {
                        acc.push_back(0);
                    }
                    let sum = acc[0]
                        .wrapping_add(a.wrapping_mul(if args.len() > 1 { b } else { 1 }));
                    acc[0] = sum;
                    sum
                }
                other => panic!("unknown ALU op `{other}`"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;

    fn uniform_caps(app: &AppGraph, cap: usize) -> HashMap<(AppNodeId, u8, AppNodeId, u8), usize> {
        app.edges().iter().map(|e| ((e.src, e.src_port, e.dst, e.dst_port), cap)).collect()
    }

    fn stream(n: usize) -> Vec<i64> {
        (0..n as i64).map(|i| (i * 7 + 3) % 251).collect()
    }

    #[test]
    fn pointwise_computes_correct_values() {
        let app = apps::pointwise(3);
        // in -> *1 -> +2 -> *3 -> out
        let caps = uniform_caps(&app, 2);
        let mut sim = RvSim::new(&app, &caps, stream(16));
        let run = sim.run(8, 10_000, StallPattern::None);
        let out = &run.outputs["out"];
        assert_eq!(out.len(), 8);
        for (i, &v) in out.iter().enumerate() {
            let x = stream(16)[i];
            assert_eq!(v, (x * 1 + 2) * 3, "token {i}");
        }
    }

    #[test]
    fn elasticity_preserves_output_sequence() {
        // The core RV invariant: stalls retime but never reorder/corrupt.
        for app in [apps::gaussian(), apps::camera(), apps::pointwise(5)] {
            let caps = uniform_caps(&app, 2);
            let free = RvSim::new(&app, &caps, stream(64)).run(24, 100_000, StallPattern::None);
            let bursty = RvSim::new(&app, &caps, stream(64)).run(
                24,
                100_000,
                StallPattern::Bursty { accept: 2, stall: 3 },
            );
            let random = RvSim::new(&app, &caps, stream(64)).run(
                24,
                100_000,
                StallPattern::Random { p: 0.3, seed: 9 },
            );
            for (name, seq) in &free.outputs {
                assert_eq!(&bursty.outputs[name][..], &seq[..], "{}: bursty diverged", app.name);
                assert_eq!(&random.outputs[name][..], &seq[..], "{}: random diverged", app.name);
            }
        }
    }

    #[test]
    fn buffering_improves_unbalanced_reconvergence() {
        // a -> e directly and a -> b -> c -> e: the short path needs >= 3
        // slack slots to keep `a` producing at full rate.
        let mut g = AppGraph::new("reconverge");
        let i = g.mem("in", "stream_in");
        let b = g.alu("b", "add");
        let c = g.alu("c", "add");
        let d = g.alu("d", "add");
        let e = g.alu("e", "add");
        let o = g.mem("out", "stream_out");
        let k = g.add("k", AppOp::Const(1));
        g.wire(i, b, 0);
        g.wire(k, b, 1);
        g.wire(b, c, 0);
        g.wire(c, d, 0);
        g.wire(i, e, 0); // short path
        g.wire(d, e, 1); // long path
        g.wire(e, o, 0);
        g.check().unwrap();

        let n = 32;
        let run1 = RvSim::new(&g, &uniform_caps(&g, 1), stream(64)).run(n, 100_000, StallPattern::None);
        let run4 = RvSim::new(&g, &uniform_caps(&g, 4), stream(64)).run(n, 100_000, StallPattern::None);
        assert_eq!(run1.outputs["out"], run4.outputs["out"]);
        assert!(
            run4.cycles < run1.cycles,
            "deep channels must be faster: {} vs {}",
            run4.cycles,
            run1.cycles
        );
    }

    #[test]
    fn fabric_capacity_model() {
        assert_eq!(FabricKind::Static.capacity(3), 1);
        assert_eq!(FabricKind::RvFullFifo { depth: 2 }.capacity(3), 7);
        assert_eq!(FabricKind::RvSplitFifo.capacity(3), 4);
        assert_eq!(FabricKind::RvSplitFifo.period_penalty_ps(1), 0.0);
        assert!(FabricKind::RvSplitFifo.period_penalty_ps(3) > 0.0);
        assert_eq!(FabricKind::Static.period_penalty_ps(5), 0.0);
    }

    #[test]
    fn fabric_labels_roundtrip() {
        for fabric in [
            FabricKind::Static,
            FabricKind::RvFullFifo { depth: 2 },
            FabricKind::RvFullFifo { depth: 4 },
            FabricKind::RvSplitFifo,
        ] {
            assert_eq!(FabricKind::parse(&fabric.label()), Some(fabric));
        }
        // The bare CLI spelling defaults to the paper's depth-2 FIFO.
        assert_eq!(FabricKind::parse("rv-full"), Some(FabricKind::RvFullFifo { depth: 2 }));
        assert_eq!(FabricKind::parse("nope"), None);
        assert_eq!(FabricKind::parse("rv-full:x"), None);
    }

    #[test]
    fn mac_accumulates() {
        let mut g = AppGraph::new("acc");
        let i = g.mem("in", "stream_in");
        let m = g.alu("m", "mac");
        let o = g.mem("out", "stream_out");
        g.wire(i, m, 0);
        g.wire(m, o, 0);
        let caps = uniform_caps(&g, 2);
        let run = RvSim::new(&g, &caps, vec![1, 2, 3, 4]).run(4, 1000, StallPattern::None);
        assert_eq!(run.outputs["out"], vec![1, 3, 6, 10]);
    }

    /// One golden comparison: flat vs reference, full `SimRun` equality.
    fn assert_matches_reference(
        app: &AppGraph,
        caps: &HashMap<(AppNodeId, u8, AppNodeId, u8), usize>,
        input: &[i64],
        n_tokens: usize,
        max_cycles: usize,
        stall: StallPattern,
    ) {
        let flat = RvSim::new(app, caps, input.to_vec()).run(n_tokens, max_cycles, stall);
        let oracle = reference::ReferenceRvSim::new(app, caps, input.to_vec()).run(
            n_tokens, max_cycles, stall,
        );
        assert_eq!(flat.outputs, oracle.outputs, "{}: outputs diverged ({stall:?})", app.name);
        assert_eq!(flat.cycles, oracle.cycles, "{}: cycle count diverged ({stall:?})", app.name);
        assert_eq!(flat.tokens, oracle.tokens, "{}: token count diverged ({stall:?})", app.name);
    }

    #[test]
    fn golden_flat_matches_reference_on_harris_and_random_fabrics() {
        // The tentpole contract: the arena simulator is sequence- AND
        // cycle-identical to the original HashMap implementation, on the
        // paper's Harris pipeline and on randomized per-edge capacities
        // ("random fabrics": capacity = 1 + registers-crossed varies per
        // route), under every stall family.
        let suite = [apps::harris(), apps::gaussian(), apps::camera(), apps::pointwise(6)];
        for app in &suite {
            let mut rng = Rng::new(0xFAB0 ^ app.name.len() as u64);
            for trial in 0..4u64 {
                let caps: HashMap<_, _> = app
                    .edges()
                    .iter()
                    .map(|e| {
                        ((e.src, e.src_port, e.dst, e.dst_port), 1 + rng.below(5))
                    })
                    .collect();
                for stall in [
                    StallPattern::None,
                    StallPattern::Bursty { accept: 2, stall: 3 },
                    StallPattern::Random { p: 0.25, seed: 11 + trial },
                ] {
                    assert_matches_reference(app, &caps, &stream(96), 24, 100_000, stall);
                }
            }
        }
    }

    #[test]
    fn golden_flat_matches_reference_cycle_for_cycle() {
        // Truncated runs pin per-cycle equivalence, not just the final
        // fixpoint: whatever the oracle has produced after exactly K
        // cycles, the flat simulator has produced too.
        let app = apps::harris();
        let caps = uniform_caps(&app, 2);
        for max_cycles in [1, 3, 7, 20, 55, 160] {
            assert_matches_reference(
                &app,
                &caps,
                &stream(96),
                1_000_000, // never the binding limit
                max_cycles,
                StallPattern::Bursty { accept: 3, stall: 2 },
            );
        }
    }

    #[test]
    fn golden_flat_matches_reference_per_fabric_kind() {
        // The three fabric capacity models of the DSE axis.
        let app = apps::gaussian();
        for fabric in
            [FabricKind::Static, FabricKind::RvFullFifo { depth: 2 }, FabricKind::RvSplitFifo]
        {
            let caps = channel_capacities(&app, None, fabric);
            assert_matches_reference(&app, &caps, &stream(96), 32, 100_000, StallPattern::None);
        }
    }

    #[test]
    fn routed_capacities_cover_every_unpacked_edge() {
        use crate::dsl::{create_uniform_interconnect, InterconnectConfig};
        use crate::pnr::{run_flow, FlowParams, SaParams};
        let ic = create_uniform_interconnect(&InterconnectConfig {
            width: 8,
            height: 8,
            num_tracks: 5,
            mem_column_period: 3,
            ..Default::default()
        });
        let app = apps::harris();
        let params = FlowParams {
            sa: SaParams { moves_per_node: 4, ..Default::default() },
            ..Default::default()
        };
        let flow = run_flow(&ic, &app, &params).expect("harris routes");
        for fabric in
            [FabricKind::Static, FabricKind::RvFullFifo { depth: 2 }, FabricKind::RvSplitFifo]
        {
            let caps = routed_capacities(&app, &flow.packed, &ic, 16, &flow.routing, fabric);
            assert_eq!(caps.len(), app.edges().len(), "one capacity per edge");
            assert!(caps.values().all(|&c| c >= 1));
            if fabric == FabricKind::Static {
                assert!(caps.values().all(|&c| c == 1), "static fabric has no buffering");
            }
            // The simulation still computes the right values.
            let run = RvSim::new(&app, &caps, stream(128)).run(16, 1_000_000, StallPattern::None);
            let free = RvSim::new(&app, &channel_capacities(&app, None, fabric), stream(128))
                .run(16, 1_000_000, StallPattern::None);
            for (name, seq) in &free.outputs {
                assert_eq!(&run.outputs[name][..], &seq[..], "{name} diverged on routed caps");
            }
        }
    }
}
