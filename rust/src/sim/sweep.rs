//! Exhaustive configuration sweep (§3.3):
//!
//! "Canal also has a built in configuration sweep test suite that
//! exhaustively tests every possible connection in IR on the CGRA."
//!
//! For every edge `(driver → node)` in every layer, configure the node's
//! mux to select that driver, inject a unique value at the driver, and
//! check the node observes it. This validates (a) the IR-to-hardware mux
//! encoding, (b) the config address map, and (c) the bitstream
//! encode/decode path when run in `through_bitstream` mode.

use crate::bitstream::{encode, Configuration};
use crate::hw::config::ConfigSpace;
use crate::ir::Interconnect;

use super::static_sim::StaticSim;

/// Sweep report.
#[derive(Clone, Debug, Default)]
pub struct SweepReport {
    pub connections_tested: usize,
    pub failures: Vec<String>,
}

impl SweepReport {
    pub fn ok(&self) -> bool {
        self.failures.is_empty()
    }
}

/// Test every IR connection. When `cs` is given, each configuration is
/// additionally round-tripped through a packed bitstream before
/// simulation, covering the encode/decode path.
pub fn sweep_connections(ic: &Interconnect, cs: Option<&ConfigSpace>) -> SweepReport {
    let mut report = SweepReport::default();
    for bw in ic.bit_widths() {
        // Enumerate connections off the frozen CSR view; the builder
        // graph is only consulted to name nodes in failure reports.
        let g = ic.compiled(bw);
        let names = ic.graph(bw);
        for node in g.ids() {
            let fan_in = g.fan_in(node).to_vec();
            if fan_in.is_empty() {
                continue;
            }
            for (sel, &driver) in fan_in.iter().enumerate() {
                let mut cfg = Configuration::default();
                if fan_in.len() > 1 {
                    cfg.selects.insert((bw, node), sel as u32);
                }
                // Optionally pack + unpack through the bitstream. The
                // read-back is targeted at the one configured field: a
                // whole-config-space `decode` per connection would make
                // the sweep O(edges x fields) for no extra coverage (the
                // full decode path has its own roundtrip tests).
                let cfg = match cs {
                    Some(cs) => {
                        let bits = encode(&cfg, cs);
                        let mut back = Configuration::default();
                        if fan_in.len() > 1 {
                            let f = cs.mux_field(bw, node).expect("field allocated");
                            let word =
                                bits.words.get(&(f.x, f.y, f.word)).copied().unwrap_or(0);
                            back.selects
                                .insert((bw, node), (word & f.mask()) >> f.offset);
                        }
                        back
                    }
                    None => cfg,
                };
                let mut sim = StaticSim::new(ic, bw, &cfg);
                let magic = 0xA5A5_0000 | (report.connections_tested as u64 & 0xFFFF);
                sim.inject(driver, magic);
                report.connections_tested += 1;
                if sim.value(node) != Some(magic) {
                    report.failures.push(format!(
                        "width {bw}: {} -> {} (select {sel}) did not deliver",
                        names.node(driver).qualified_name(),
                        names.node(node).qualified_name(),
                    ));
                }
            }
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::{create_uniform_interconnect, InterconnectConfig, SbTopology};
    use crate::hw::config::allocate;

    fn ic(topo: SbTopology) -> Interconnect {
        create_uniform_interconnect(&InterconnectConfig {
            width: 4,
            height: 4,
            num_tracks: 3,
            mem_column_period: 2,
            sb_topology: topo,
            track_widths: vec![1, 16],
            ..Default::default()
        })
    }

    #[test]
    fn every_connection_works_wilton() {
        let ic = ic(SbTopology::Wilton);
        let r = sweep_connections(&ic, None);
        assert!(r.ok(), "{:?}", &r.failures[..r.failures.len().min(5)]);
        assert_eq!(r.connections_tested, ic.edge_count());
    }

    #[test]
    fn every_connection_works_through_bitstream() {
        let ic = ic(SbTopology::Disjoint);
        let cs = allocate(&ic);
        let r = sweep_connections(&ic, Some(&cs));
        assert!(r.ok(), "{:?}", &r.failures[..r.failures.len().min(5)]);
    }

    #[test]
    fn sweep_counts_both_layers() {
        let ic = ic(SbTopology::Wilton);
        let edges_16 = ic.graph(16).edge_count();
        let edges_1 = ic.graph(1).edge_count();
        let r = sweep_connections(&ic, None);
        assert_eq!(r.connections_tested, edges_16 + edges_1);
    }
}
