//! Simulation of configured fabrics.
//!
//! - [`static_sim`] — functional propagation through a configured static
//!   fabric (used by bitstream checks);
//! - [`sweep`] — the exhaustive configuration sweep suite of §3.3;
//! - [`rv_sim`] — cycle-accurate elastic (ready-valid) simulation with
//!   FIFO backpressure, modeling the NoC backend and the split-FIFO
//!   optimization.

pub mod noc_sim;
pub mod rv_sim;
pub mod static_sim;
pub mod sweep;

pub use noc_sim::{hotspot_pattern, simulate_app, NocRun, NocSim};
pub use rv_sim::{channel_capacities, routed_capacities, FabricKind, RvSim, SimRun, StallPattern};
pub use static_sim::{check_routing, StaticSim};
pub use sweep::{sweep_connections, SweepReport};
