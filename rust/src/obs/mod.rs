//! Observability: span tracing, a metrics registry, and trace export.
//!
//! The layer is std-only (JSON via [`crate::util::json`], no serde/HDR
//! deps) and split in three:
//!
//! - [`span`] — lightweight span tracing into per-thread lock-free ring
//!   buffers (fixed capacity, drop-oldest, merged at collection time);
//! - [`metrics`] — a process-wide registry of counters, gauges, and
//!   log-bucketed histograms (p50/p90/p99 without external deps);
//! - [`export`] — Chrome trace-event JSON (one track per worker, loads
//!   directly in Perfetto / `chrome://tracing`) and an NDJSON metrics
//!   snapshot;
//! - [`history`] — a fixed-capacity ring of timestamped registry
//!   samples (counters as deltas, gauges/quantiles as points) recorded
//!   by a background sampler thread — the data source of `canal dash`.
//!
//! # The gate
//!
//! Everything is off by default and **zero-cost when disabled**: every
//! recording path starts with a single relaxed atomic load of a
//! process-wide gate byte ([`metrics_on`] / [`trace_on`]) and returns
//! immediately when the corresponding bit is clear. Recording never
//! feeds back into any algorithm — results are bit-identical with the
//! gate on or off (`tests/dse_determinism.rs` proves it).
//!
//! [`ObsOptions`] is the configuration surface: `canal dse --trace F`
//! enables both bits for the run and writes the Chrome trace to `F`;
//! `canal serve` enables metrics so the daemon's `metrics` request has
//! live data; everything else leaves the gate at zero.
//!
//! Span taxonomy, metric names, and file formats are documented in
//! `docs/observability.md`.

pub mod export;
pub mod history;
pub mod metrics;
pub mod span;

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

pub use export::{chrome_trace, metrics_json, metrics_ndjson, write_chrome_trace};
pub use history::{HistorySample, HistorySampler, MetricsHistory, ProgressSample};
pub use metrics::{Counter, Gauge, Histogram, MetricValue};
pub use span::{event, span, stage, SpanEvent, SpanGuard, SpanKind, StageGuard};

const METRICS_BIT: u8 = 1;
const TRACE_BIT: u8 = 2;

static GATE: AtomicU8 = AtomicU8::new(0);

/// Runtime configuration of the observability layer.
///
/// Plain data — call [`ObsOptions::apply`] to install it process-wide.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ObsOptions {
    /// Record stage counters/histograms into the global registry.
    pub metrics: bool,
    /// Record spans into the per-thread ring buffers.
    pub trace: bool,
}

impl ObsOptions {
    /// Everything off (the default; recording paths cost one atomic load).
    pub fn disabled() -> ObsOptions {
        ObsOptions { metrics: false, trace: false }
    }

    /// Metrics + spans (what `canal dse --trace` uses).
    pub fn full() -> ObsOptions {
        ObsOptions { metrics: true, trace: true }
    }

    /// Counters/histograms only — what the daemon runs with so the
    /// `metrics` request has data without paying for span recording.
    pub fn metrics_only() -> ObsOptions {
        ObsOptions { metrics: true, trace: false }
    }

    /// Install process-wide (a single atomic store).
    pub fn apply(self) {
        let mut bits = 0;
        if self.metrics {
            bits |= METRICS_BIT;
        }
        if self.trace {
            bits |= TRACE_BIT;
        }
        GATE.store(bits, Ordering::Relaxed);
    }

    /// The currently-installed options.
    pub fn current() -> ObsOptions {
        let bits = GATE.load(Ordering::Relaxed);
        ObsOptions { metrics: bits & METRICS_BIT != 0, trace: bits & TRACE_BIT != 0 }
    }
}

/// Fast-path check: is metric recording enabled?
#[inline]
pub fn metrics_on() -> bool {
    GATE.load(Ordering::Relaxed) & METRICS_BIT != 0
}

/// Fast-path check: is span recording enabled?
#[inline]
pub fn trace_on() -> bool {
    GATE.load(Ordering::Relaxed) & TRACE_BIT != 0
}

/// Is anything enabled at all? (One load; the common disabled path.)
#[inline]
pub fn enabled() -> bool {
    GATE.load(Ordering::Relaxed) != 0
}

/// Nanoseconds since the process-wide observability epoch (first call).
///
/// All spans from all threads share this epoch, which is what makes the
/// merged trace's timestamps comparable across tracks.
#[inline]
pub fn now_ns() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// Wall-clock milliseconds since the unix epoch (0 if the system clock
/// sits before it). Paired with [`now_ns`] on every timestamped frame
/// and history sample: `ts_ms` anchors the series to human time,
/// `mono_ns` makes intervals trustworthy under clock steps.
pub fn now_ms() -> u64 {
    use std::time::{SystemTime, UNIX_EPOCH};
    SystemTime::now().duration_since(UNIX_EPOCH).map(|d| d.as_millis() as u64).unwrap_or(0)
}

/// Serializes unit tests that flip the process-global gate, so one
/// test's `disabled` window can't race another's `trace` window.
#[cfg(test)]
pub(crate) fn test_gate_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gate_bits_round_trip() {
        let _gate = test_gate_lock();
        let prev = ObsOptions::current();
        ObsOptions::disabled().apply();
        assert!(!metrics_on() && !trace_on() && !enabled());
        ObsOptions::metrics_only().apply();
        assert!(metrics_on() && !trace_on() && enabled());
        ObsOptions::full().apply();
        assert!(metrics_on() && trace_on() && enabled());
        assert_eq!(ObsOptions::current(), ObsOptions::full());
        prev.apply();
    }

    #[test]
    fn now_ns_is_monotonic() {
        let a = now_ns();
        let b = now_ns();
        assert!(b >= a);
    }
}
