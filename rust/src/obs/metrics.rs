//! A process-wide metrics registry: counters, gauges, and log-bucketed
//! histograms.
//!
//! Handles are `Arc`s to lock-free atomics — the registry mutex is only
//! taken on first registration and at snapshot time, never on the
//! record path. Histograms bucket by `ceil(log2(v))` (64 buckets cover
//! the full `u64` range), which gives p50/p90/p99 estimates with ≤ 2×
//! relative error and no HDR dependency — plenty for "where do the
//! nanoseconds go" questions.
//!
//! Naming convention (full table in `docs/observability.md`):
//! dot-separated lowercase, `<stage>.count` / `<stage>.ns` for flow
//! stages (e.g. `pnr.route.count`), `engine.<field>` for the
//! [`crate::dse::EngineStats`] mirror, `service.*` for the daemon.
//!
//! [`crate::dse::EngineStats`] remains the per-run value returned by
//! the engine; `crate::dse::report::publish_engine_stats` mirrors every
//! run's fields into this registry, so the registry is the cumulative
//! process view and `stats_json` stays byte-compatible.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// A monotonically increasing counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A point-in-time signed value (queue depths, utilization, ...).
#[derive(Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

const BUCKETS: usize = 64;

/// A log₂-bucketed histogram of `u64` samples.
pub struct Histogram {
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
    /// `buckets[k]` counts samples with `ceil(log2(v)) == k` (v = 0 and
    /// v = 1 land in bucket 0).
    buckets: [AtomicU64; BUCKETS],
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
            buckets: [(); BUCKETS].map(|_| AtomicU64::new(0)),
        }
    }
}

fn bucket_of(v: u64) -> usize {
    if v <= 1 {
        0
    } else {
        // Values ≥ 2^63 collapse into the top bucket.
        ((64 - (v - 1).leading_zeros()) as usize).min(BUCKETS - 1)
    }
}

/// Upper bound of bucket `k` (inclusive): the largest value it can hold.
fn bucket_hi(k: usize) -> u64 {
    if k >= 64 {
        u64::MAX
    } else {
        1u64 << k
    }
}

impl Histogram {
    pub fn record(&self, v: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Estimate the `q`-quantile (`0.0 ..= 1.0`) from the buckets:
    /// linear interpolation inside the bucket that crosses the target
    /// rank, so the estimate is within the bucket's 2× span.
    pub fn quantile(&self, q: f64) -> f64 {
        let counts: Vec<u64> =
            self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let target = q.clamp(0.0, 1.0) * total as f64;
        let mut seen = 0u64;
        for (k, &c) in counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let next = seen + c;
            if next as f64 >= target {
                let lo = if k == 0 { 0 } else { bucket_hi(k - 1) } as f64;
                let hi = bucket_hi(k) as f64;
                let frac = if c == 0 { 0.0 } else { (target - seen as f64) / c as f64 };
                let est = lo + (hi - lo) * frac.clamp(0.0, 1.0);
                // Clamp to the observed range so tiny histograms don't
                // report an upper bound no sample ever reached.
                let min = self.min.load(Ordering::Relaxed) as f64;
                let max = self.max.load(Ordering::Relaxed) as f64;
                return est.clamp(min, max);
            }
            seen = next;
        }
        self.max.load(Ordering::Relaxed) as f64
    }

    pub fn snapshot(&self) -> HistSnapshot {
        let count = self.count();
        HistSnapshot {
            count,
            sum: self.sum.load(Ordering::Relaxed),
            min: if count == 0 { 0 } else { self.min.load(Ordering::Relaxed) },
            max: self.max.load(Ordering::Relaxed),
            p50: self.quantile(0.50),
            p90: self.quantile(0.90),
            p99: self.quantile(0.99),
        }
    }
}

/// A point-in-time histogram summary.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HistSnapshot {
    pub count: u64,
    pub sum: u64,
    pub min: u64,
    pub max: u64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

/// One metric's snapshotted value.
#[derive(Clone, Debug, PartialEq)]
pub enum MetricValue {
    Counter(u64),
    Gauge(i64),
    Histogram(HistSnapshot),
}

enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

fn registry() -> &'static Mutex<BTreeMap<String, Metric>> {
    static REGISTRY: OnceLock<Mutex<BTreeMap<String, Metric>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Get-or-register the named counter. If the name is already taken by a
/// different metric kind (a programming error), a detached handle is
/// returned so the caller still works — the registered metric wins in
/// snapshots.
pub fn counter(name: &str) -> Arc<Counter> {
    let mut map = registry().lock().unwrap_or_else(|p| p.into_inner());
    match map.get(name) {
        Some(Metric::Counter(c)) => Arc::clone(c),
        Some(_) => Arc::new(Counter::default()),
        None => {
            let c = Arc::new(Counter::default());
            map.insert(name.to_string(), Metric::Counter(Arc::clone(&c)));
            c
        }
    }
}

/// Get-or-register the named gauge (same kind-mismatch policy as
/// [`counter`]).
pub fn gauge(name: &str) -> Arc<Gauge> {
    let mut map = registry().lock().unwrap_or_else(|p| p.into_inner());
    match map.get(name) {
        Some(Metric::Gauge(g)) => Arc::clone(g),
        Some(_) => Arc::new(Gauge::default()),
        None => {
            let g = Arc::new(Gauge::default());
            map.insert(name.to_string(), Metric::Gauge(Arc::clone(&g)));
            g
        }
    }
}

/// Get-or-register the named histogram (same kind-mismatch policy as
/// [`counter`]).
pub fn histogram(name: &str) -> Arc<Histogram> {
    let mut map = registry().lock().unwrap_or_else(|p| p.into_inner());
    match map.get(name) {
        Some(Metric::Histogram(h)) => Arc::clone(h),
        Some(_) => Arc::new(Histogram::default()),
        None => {
            let h = Arc::new(Histogram::default());
            map.insert(name.to_string(), Metric::Histogram(Arc::clone(&h)));
            h
        }
    }
}

/// Snapshot one metric by name.
pub fn get(name: &str) -> Option<MetricValue> {
    let map = registry().lock().unwrap_or_else(|p| p.into_inner());
    map.get(name).map(|m| match m {
        Metric::Counter(c) => MetricValue::Counter(c.get()),
        Metric::Gauge(g) => MetricValue::Gauge(g.get()),
        Metric::Histogram(h) => MetricValue::Histogram(h.snapshot()),
    })
}

/// Snapshot every registered metric, sorted by name.
pub fn snapshot() -> Vec<(String, MetricValue)> {
    let map = registry().lock().unwrap_or_else(|p| p.into_inner());
    map.iter()
        .map(|(name, m)| {
            let v = match m {
                Metric::Counter(c) => MetricValue::Counter(c.get()),
                Metric::Gauge(g) => MetricValue::Gauge(g.get()),
                Metric::Histogram(h) => MetricValue::Histogram(h.snapshot()),
            };
            (name.clone(), v)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_round_trip() {
        let c = counter("test.metrics.counter");
        let before = c.get();
        c.inc();
        c.add(4);
        assert_eq!(counter("test.metrics.counter").get(), before + 5);

        let g = gauge("test.metrics.gauge");
        g.set(7);
        g.add(-3);
        assert_eq!(gauge("test.metrics.gauge").get(), 4);

        match get("test.metrics.gauge") {
            Some(MetricValue::Gauge(4)) => {}
            other => panic!("unexpected snapshot: {other:?}"),
        }
    }

    #[test]
    fn kind_mismatch_returns_detached_handle() {
        counter("test.metrics.kind").inc();
        // Asking for the same name as a gauge must not panic or clobber.
        let g = gauge("test.metrics.kind");
        g.set(99);
        match get("test.metrics.kind") {
            Some(MetricValue::Counter(n)) => assert!(n >= 1),
            other => panic!("registered kind must win: {other:?}"),
        }
    }

    #[test]
    fn histogram_buckets_and_quantiles() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 0);
        assert_eq!(bucket_of(2), 1);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 2);
        assert_eq!(bucket_of(5), 3);
        assert_eq!(bucket_of(1024), 10);
        assert_eq!(bucket_of(1025), 11);
        assert_eq!(bucket_of(u64::MAX), BUCKETS - 1, "huge values collapse into the top bucket");

        let h = Histogram::default();
        assert_eq!(h.quantile(0.5), 0.0, "empty histogram quantile is 0");
        for v in 1..=1000u64 {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 1000);
        assert_eq!(s.sum, 500_500);
        assert_eq!((s.min, s.max), (1, 1000));
        // Log buckets give ≤ 2× relative error: p50 of 1..=1000 is 500,
        // so the estimate must land within its bucket's (256, 1000] span.
        assert!(s.p50 > 250.0 && s.p50 <= 1000.0, "p50 estimate {} out of range", s.p50);
        assert!(s.p90 >= s.p50 && s.p99 >= s.p90, "quantiles must be monotone");
        assert!(s.p99 <= 1000.0, "clamped to the observed max");
    }

    #[test]
    fn histogram_single_sample_is_exact() {
        let h = Histogram::default();
        h.record(777);
        let s = h.snapshot();
        assert_eq!((s.count, s.min, s.max), (1, 777, 777));
        assert_eq!(s.p50, 777.0, "clamping makes single-sample quantiles exact");
        assert_eq!(s.p99, 777.0);
    }

    #[test]
    fn snapshot_is_sorted_by_name() {
        counter("test.metrics.zzz").inc();
        counter("test.metrics.aaa").inc();
        let names: Vec<String> = snapshot().into_iter().map(|(n, _)| n).collect();
        let mut sorted = names.clone();
        sorted.sort();
        assert_eq!(names, sorted);
    }
}
