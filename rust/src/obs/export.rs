//! Trace and metrics export: Chrome trace-event JSON and NDJSON
//! metrics snapshots.
//!
//! The trace format is the Chrome trace-event "JSON object format"
//! (`{"traceEvents": [...]}`): load the file in <https://ui.perfetto.dev>
//! or `chrome://tracing` and every instrumented thread appears as its
//! own named track. Timestamps are microseconds (fractional — the ring
//! records nanoseconds) on one shared epoch, complete spans are `ph:"X"`
//! events, instants are `ph:"i"`.
//!
//! The metrics snapshot is NDJSON: one [`crate::util::json`] object per
//! line, one line per registered metric, sorted by name, plus two
//! `obs.span.*` lines accounting for the ring buffers themselves.

use std::path::Path;

use super::{metrics, span};
use crate::util::json::Json;

/// Build the Chrome trace document for a set of collected events.
///
/// One `pid` (the process), one `tid` per worker ring, a `thread_name`
/// metadata record per track, and events sorted by timestamp so the
/// file streams into Perfetto without a sort pass.
pub fn chrome_trace(events: &[span::SpanEvent], labels: &[(u32, String)]) -> Json {
    // Workers can record events without ever labelling their track
    // (e.g. a thread that only hits instrumented library code), so the
    // label table is not authoritative: synthesize a `worker-<n>` row
    // for any worker present in the events but absent from `labels`,
    // instead of leaving its track unnamed.
    let mut tracks: Vec<(u32, &str)> =
        labels.iter().map(|(w, l)| (*w, l.as_str())).collect();
    let mut extra: Vec<u32> = events
        .iter()
        .map(|ev| ev.worker)
        .filter(|w| !labels.iter().any(|(lw, _)| lw == w))
        .collect();
    extra.sort_unstable();
    extra.dedup();
    let synthesized: Vec<(u32, String)> =
        extra.into_iter().map(|w| (w, format!("worker-{w}"))).collect();
    tracks.extend(synthesized.iter().map(|(w, l)| (*w, l.as_str())));
    tracks.sort_by_key(|(w, _)| *w);

    let mut out: Vec<Json> = Vec::with_capacity(events.len() + tracks.len());
    for (worker, label) in &tracks {
        out.push(Json::Obj(vec![
            ("name".into(), Json::str("thread_name")),
            ("ph".into(), Json::str("M")),
            ("pid".into(), Json::num_u64(1)),
            ("tid".into(), Json::num_u64(*worker as u64)),
            ("args".into(), Json::Obj(vec![("name".into(), Json::str(label))])),
        ]));
    }
    let mut sorted: Vec<&span::SpanEvent> = events.iter().collect();
    sorted.sort_by_key(|ev| (ev.start_ns, ev.worker));
    for ev in sorted {
        let ts_us = ev.start_ns as f64 / 1000.0;
        let mut obj = vec![
            ("name".into(), Json::str(ev.name)),
            ("cat".into(), Json::str("canal")),
            (
                "ph".into(),
                Json::str(match ev.kind {
                    span::SpanKind::Span => "X",
                    span::SpanKind::Instant => "i",
                }),
            ),
            ("pid".into(), Json::num_u64(1)),
            ("tid".into(), Json::num_u64(ev.worker as u64)),
            ("ts".into(), Json::num_f64(ts_us)),
        ];
        match ev.kind {
            span::SpanKind::Span => {
                obj.push(("dur".into(), Json::num_f64(ev.dur_ns as f64 / 1000.0)));
            }
            span::SpanKind::Instant => {
                // Thread-scoped instant marker.
                obj.push(("s".into(), Json::str("t")));
            }
        }
        if ev.arg0 != 0 || ev.arg1 != 0 {
            obj.push((
                "args".into(),
                Json::Obj(vec![
                    ("arg0".into(), Json::num_u64(ev.arg0)),
                    ("arg1".into(), Json::num_u64(ev.arg1)),
                ]),
            ));
        }
        out.push(Json::Obj(obj));
    }
    Json::Obj(vec![
        ("traceEvents".into(), Json::Arr(out)),
        ("displayTimeUnit".into(), Json::str("ms")),
    ])
}

/// Collect every ring and write the Chrome trace to `path`.
pub fn write_chrome_trace(path: &Path) -> Result<(), String> {
    let doc = chrome_trace(&span::collect(), &span::track_labels());
    std::fs::write(path, doc.render()).map_err(|e| format!("{}: {e}", path.display()))
}

fn metric_obj(name: &str, value: &metrics::MetricValue) -> Json {
    match value {
        metrics::MetricValue::Counter(v) => Json::Obj(vec![
            ("metric".into(), Json::str(name)),
            ("type".into(), Json::str("counter")),
            ("value".into(), Json::num_u64(*v)),
        ]),
        metrics::MetricValue::Gauge(v) => Json::Obj(vec![
            ("metric".into(), Json::str(name)),
            ("type".into(), Json::str("gauge")),
            ("value".into(), Json::Num(v.to_string())),
        ]),
        metrics::MetricValue::Histogram(s) => Json::Obj(vec![
            ("metric".into(), Json::str(name)),
            ("type".into(), Json::str("histogram")),
            ("count".into(), Json::num_u64(s.count)),
            ("sum".into(), Json::num_u64(s.sum)),
            ("min".into(), Json::num_u64(s.min)),
            ("max".into(), Json::num_u64(s.max)),
            ("p50".into(), Json::num_f64(s.p50)),
            ("p90".into(), Json::num_f64(s.p90)),
            ("p99".into(), Json::num_f64(s.p99)),
        ]),
    }
}

/// Every registered metric plus the span-layer's own accounting, as a
/// list of one-object-per-metric JSON values (sorted by name; the
/// `obs.span.*` lines come last).
pub fn metric_objects() -> Vec<Json> {
    let mut out: Vec<Json> =
        metrics::snapshot().iter().map(|(n, v)| metric_obj(n, v)).collect();
    let (pushed, dropped) = span::totals();
    out.push(metric_obj("obs.span.dropped_events", &metrics::MetricValue::Counter(dropped)));
    out.push(metric_obj("obs.span.recorded", &metrics::MetricValue::Counter(pushed)));
    out
}

/// The metrics snapshot as NDJSON (one line per metric, `\n`-terminated).
pub fn metrics_ndjson() -> String {
    let mut out = String::new();
    for obj in metric_objects() {
        out.push_str(&obj.render_line());
        out.push('\n');
    }
    out
}

/// The metrics snapshot as one JSON document (what the daemon's
/// `metrics` request and `GET /metrics.json` return):
/// `{"ts_ms": ..., "mono_ns": ..., "metrics": [...]}` — the timestamp
/// pair says *when* the snapshot was taken, so two snapshots can be
/// turned into rates.
pub fn metrics_json() -> Json {
    Json::Obj(vec![
        ("ts_ms".into(), Json::num_u64(super::now_ms())),
        ("mono_ns".into(), Json::num_u64(super::now_ns())),
        ("metrics".into(), Json::Arr(metric_objects())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::span::{SpanEvent, SpanKind};

    fn sample_events() -> Vec<SpanEvent> {
        vec![
            SpanEvent {
                name: "pnr.route",
                kind: SpanKind::Span,
                worker: 1,
                start_ns: 2500,
                dur_ns: 1500,
                arg0: 3,
                arg1: 0,
            },
            SpanEvent {
                name: "dse.cache.hit",
                kind: SpanKind::Instant,
                worker: 0,
                start_ns: 1000,
                dur_ns: 0,
                arg0: 0,
                arg1: 0,
            },
        ]
    }

    #[test]
    fn chrome_trace_shape_and_order() {
        let labels = vec![(0u32, "worker-0".to_string()), (1u32, "dse-worker-1".to_string())];
        let doc = chrome_trace(&sample_events(), &labels);
        let evs = doc.get("traceEvents").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(evs.len(), 4, "2 metadata + 2 events");
        // Metadata first, then events sorted by ts regardless of input order.
        assert_eq!(evs[0].get("ph").and_then(|v| v.as_str()), Some("M"));
        assert_eq!(evs[1].get("ph").and_then(|v| v.as_str()), Some("M"));
        assert_eq!(evs[2].get("name").and_then(|v| v.as_str()), Some("dse.cache.hit"));
        assert_eq!(evs[2].get("ph").and_then(|v| v.as_str()), Some("i"));
        assert_eq!(evs[2].get("s").and_then(|v| v.as_str()), Some("t"));
        let x = &evs[3];
        assert_eq!(x.get("ph").and_then(|v| v.as_str()), Some("X"));
        assert_eq!(x.get("ts").and_then(|v| v.as_f64()), Some(2.5));
        assert_eq!(x.get("dur").and_then(|v| v.as_f64()), Some(1.5));
        assert_eq!(x.get("tid").and_then(|v| v.as_u64()), Some(1));
        let args = x.get("args").unwrap();
        assert_eq!(args.get("arg0").and_then(|v| v.as_u64()), Some(3));
        // The rendered document parses back (structural validity).
        let parsed = Json::parse(&doc.render()).unwrap();
        assert_eq!(parsed, doc);
    }

    #[test]
    fn chrome_trace_synthesizes_missing_worker_labels() {
        // Only worker 1 is labelled; worker 0's track must still get a
        // thread_name row instead of being dropped.
        let labels = vec![(1u32, "dse-worker-1".to_string())];
        let doc = chrome_trace(&sample_events(), &labels);
        let evs = doc.get("traceEvents").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(evs.len(), 4, "2 metadata (one synthesized) + 2 events");
        let meta_names: Vec<(u64, &str)> = evs
            .iter()
            .filter(|e| e.get("ph").and_then(|v| v.as_str()) == Some("M"))
            .map(|e| {
                (
                    e.get("tid").and_then(|v| v.as_u64()).unwrap(),
                    e.get("args").and_then(|a| a.get("name")).and_then(|v| v.as_str()).unwrap(),
                )
            })
            .collect();
        assert_eq!(meta_names, vec![(0, "worker-0"), (1, "dse-worker-1")]);
    }

    #[test]
    fn metrics_json_is_timestamped() {
        metrics::counter("test.export.ts").inc();
        let doc = metrics_json();
        assert!(doc.get("ts_ms").and_then(|v| v.as_u64()).unwrap_or(0) > 0);
        assert!(doc.get("mono_ns").and_then(|v| v.as_u64()).is_some());
        assert!(doc.get("metrics").and_then(|v| v.as_arr()).is_some());
    }

    #[test]
    fn metrics_ndjson_lines_parse() {
        metrics::counter("test.export.lines").add(2);
        let nd = metrics_ndjson();
        let mut saw = false;
        for line in nd.lines() {
            let j = Json::parse(line).expect("every NDJSON line parses");
            assert!(j.get("metric").is_some() && j.get("type").is_some());
            if j.get("metric").and_then(|v| v.as_str()) == Some("test.export.lines") {
                assert!(j.get("value").and_then(|v| v.as_u64()).unwrap_or(0) >= 2);
                saw = true;
            }
        }
        assert!(saw);
        assert!(nd.contains("obs.span.dropped_events"));
    }
}
