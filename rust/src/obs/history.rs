//! Metrics history: a bounded time series over the metrics registry.
//!
//! [`MetricsHistory`] is the daemon-side substrate of `canal dash`: a
//! fixed-capacity ring of timestamped [`HistorySample`]s, each one
//! snapshot of the process-wide registry ([`super::metrics`]) plus —
//! when a sweep is running — a rendered-down live-progress sample.
//! A background [`HistorySampler`] thread records one sample per
//! period; the ring drops its oldest sample once full, so memory is
//! bounded no matter how long the daemon lives.
//!
//! Storage convention (mirrored in the JSON forms):
//!
//! - **counters** are stored as per-interval *deltas* (zero deltas are
//!   omitted — an absent counter means "nothing happened this tick"),
//!   so rates fall out of `delta / interval` without client-side
//!   bookkeeping;
//! - **gauges** and **histogram quantiles** are stored as *points*
//!   (their value at sample time); a histogram additionally carries its
//!   count delta so "how many observations landed in this tick" stays
//!   answerable;
//! - every sample carries a `ts_ms` wall-clock / `mono_ns` monotonic
//!   timestamp pair and a strictly increasing `seq` number that
//!   survives ring eviction, which is what lets a `watch` client
//!   request "everything since sample N".
//!
//! The history is purely observational: it only ever *reads* the
//! registry and never feeds anything back, preserving the module-wide
//! guarantee that observability cannot change results.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::metrics::{self, MetricValue};
use crate::util::json::Json;

/// Samples kept before the ring drops its oldest (~8.5 minutes at the
/// default period).
pub const DEFAULT_HISTORY_CAPACITY: usize = 512;

/// Default sampling period of the daemon's background sampler.
pub const DEFAULT_HISTORY_PERIOD: Duration = Duration::from_millis(1000);

/// Live sweep state folded into one history sample.
///
/// This is a rendered-down `crate::dse::SweepProgress` snapshot; the
/// indirection keeps `obs` free of `dse` types (the dependency runs the
/// other way). The service layer does the conversion.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ProgressSample {
    pub jobs_total: u64,
    pub jobs_done: u64,
    pub cache_hits: u64,
    pub coalesced: u64,
    pub cold_total: u64,
    pub cold_done: u64,
    pub warm_starts: u64,
    /// Per-worker busy percentage over the sweep so far (`0..=100`).
    pub worker_util_pct: Vec<u8>,
}

impl ProgressSample {
    fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("jobs_total".into(), Json::num_u64(self.jobs_total)),
            ("jobs_done".into(), Json::num_u64(self.jobs_done)),
            ("cache_hits".into(), Json::num_u64(self.cache_hits)),
            ("coalesced".into(), Json::num_u64(self.coalesced)),
            ("cold_total".into(), Json::num_u64(self.cold_total)),
            ("cold_done".into(), Json::num_u64(self.cold_done)),
            ("warm_starts".into(), Json::num_u64(self.warm_starts)),
            (
                "util".into(),
                Json::Arr(
                    self.worker_util_pct.iter().map(|&p| Json::num_u64(u64::from(p))).collect(),
                ),
            ),
        ])
    }
}

/// A histogram's point-in-time quantiles plus its count delta.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantilePoint {
    /// Observations recorded since the previous sample.
    pub count_delta: u64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

/// One timestamped observation of the registry (+ live sweep, if any).
#[derive(Clone, Debug, PartialEq)]
pub struct HistorySample {
    /// Strictly increasing sample number; survives ring eviction.
    pub seq: u64,
    /// Wall-clock milliseconds since the unix epoch at sample time.
    pub ts_ms: u64,
    /// Monotonic nanoseconds ([`super::now_ns`]) at sample time.
    pub mono_ns: u64,
    /// Counter deltas since the previous sample (zero deltas omitted),
    /// sorted by name.
    pub counters: Vec<(String, u64)>,
    /// Gauge values at sample time, sorted by name.
    pub gauges: Vec<(String, i64)>,
    /// Histogram quantile points at sample time, sorted by name.
    pub quantiles: Vec<(String, QuantilePoint)>,
    /// Live sweep progress, when one was running at sample time.
    pub progress: Option<ProgressSample>,
}

impl HistorySample {
    /// The sample as one JSON object (the wire/history-file form).
    pub fn to_json(&self) -> Json {
        let counters =
            self.counters.iter().map(|(n, d)| (n.clone(), Json::num_u64(*d))).collect();
        let gauges = self
            .gauges
            .iter()
            .map(|(n, v)| (n.clone(), Json::Num(v.to_string())))
            .collect();
        let quantiles = self
            .quantiles
            .iter()
            .map(|(n, q)| {
                (
                    n.clone(),
                    Json::Obj(vec![
                        ("count".into(), Json::num_u64(q.count_delta)),
                        ("p50".into(), Json::num_f64(q.p50)),
                        ("p90".into(), Json::num_f64(q.p90)),
                        ("p99".into(), Json::num_f64(q.p99)),
                    ]),
                )
            })
            .collect();
        let mut members = vec![
            ("seq".into(), Json::num_u64(self.seq)),
            ("ts_ms".into(), Json::num_u64(self.ts_ms)),
            ("mono_ns".into(), Json::num_u64(self.mono_ns)),
            ("counters".into(), Json::Obj(counters)),
            ("gauges".into(), Json::Obj(gauges)),
            ("quantiles".into(), Json::Obj(quantiles)),
        ];
        if let Some(p) = &self.progress {
            members.push(("progress".into(), p.to_json()));
        }
        Json::Obj(members)
    }
}

struct Inner {
    samples: VecDeque<HistorySample>,
    /// Last-seen cumulative counts (counters and histogram counts; the
    /// registry guarantees one kind per name so one map serves both).
    last_counts: HashMap<String, u64>,
    next_seq: u64,
}

/// The ring of [`HistorySample`]s plus the delta state between samples.
///
/// Thread-safe; the daemon shares one instance between the sampler
/// thread, `watch`/`history` request handlers, and the HTTP dashboard.
pub struct MetricsHistory {
    capacity: usize,
    period: Duration,
    inner: Mutex<Inner>,
}

impl MetricsHistory {
    pub fn new(capacity: usize, period: Duration) -> MetricsHistory {
        MetricsHistory {
            capacity: capacity.max(1),
            period,
            inner: Mutex::new(Inner {
                samples: VecDeque::new(),
                last_counts: HashMap::new(),
                next_seq: 0,
            }),
        }
    }

    /// The daemon's defaults: [`DEFAULT_HISTORY_CAPACITY`] samples at
    /// [`DEFAULT_HISTORY_PERIOD`].
    pub fn with_defaults() -> MetricsHistory {
        MetricsHistory::new(DEFAULT_HISTORY_CAPACITY, DEFAULT_HISTORY_PERIOD)
    }

    pub fn period(&self) -> Duration {
        self.period
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.lock().samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn lock(&self) -> MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Take one sample now: snapshot the registry, diff counters
    /// against the previous sample, and push (dropping the oldest
    /// sample if the ring is full).
    pub fn record(&self, progress: Option<ProgressSample>) {
        let ts_ms = super::now_ms();
        let mono_ns = super::now_ns();
        let snap = metrics::snapshot();
        let mut inner = self.lock();
        let mut counters = Vec::new();
        let mut gauges = Vec::new();
        let mut quantiles = Vec::new();
        for (name, value) in snap {
            match value {
                MetricValue::Counter(c) => {
                    let prev = inner.last_counts.insert(name.clone(), c).unwrap_or(0);
                    let delta = c.saturating_sub(prev);
                    if delta > 0 {
                        counters.push((name, delta));
                    }
                }
                MetricValue::Gauge(g) => gauges.push((name, g)),
                MetricValue::Histogram(h) => {
                    let prev = inner.last_counts.insert(name.clone(), h.count).unwrap_or(0);
                    quantiles.push((
                        name,
                        QuantilePoint {
                            count_delta: h.count.saturating_sub(prev),
                            p50: h.p50,
                            p90: h.p90,
                            p99: h.p99,
                        },
                    ));
                }
            }
        }
        let seq = inner.next_seq;
        inner.next_seq += 1;
        inner.samples.push_back(HistorySample {
            seq,
            ts_ms,
            mono_ns,
            counters,
            gauges,
            quantiles,
            progress,
        });
        while inner.samples.len() > self.capacity {
            inner.samples.pop_front();
        }
    }

    /// Every sample currently in the ring, oldest first.
    pub fn samples(&self) -> Vec<HistorySample> {
        self.lock().samples.iter().cloned().collect()
    }

    /// Samples with `seq >= from`, oldest first, plus the cursor to
    /// pass as `from` next time (`next_seq`). `since(0)` returns the
    /// whole ring.
    pub fn since(&self, from: u64) -> (u64, Vec<HistorySample>) {
        let inner = self.lock();
        let out = inner.samples.iter().filter(|s| s.seq >= from).cloned().collect();
        (inner.next_seq, out)
    }

    /// The whole history as one JSON document:
    /// `{"period_ms", "capacity", "next_seq", "samples": [...]}`.
    pub fn to_json(&self) -> Json {
        let inner = self.lock();
        let samples = inner.samples.iter().map(HistorySample::to_json).collect();
        Json::Obj(vec![
            ("period_ms".into(), Json::num_u64(self.period.as_millis() as u64)),
            ("capacity".into(), Json::num_u64(self.capacity as u64)),
            ("next_seq".into(), Json::num_u64(inner.next_seq)),
            ("samples".into(), Json::Arr(samples)),
        ])
    }
}

/// A background thread recording one [`MetricsHistory`] sample per
/// period. Stops (and joins) on drop, so owning it scopes the sampling.
pub struct HistorySampler {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl HistorySampler {
    /// Spawn the sampler. `progress` is polled once per sample and
    /// should return the live sweep state when one is running (the
    /// daemon wires it to the request currently holding the progress
    /// slot; `|| None` is fine for history without sweep context).
    pub fn spawn<F>(history: Arc<MetricsHistory>, progress: F) -> HistorySampler
    where
        F: Fn() -> Option<ProgressSample> + Send + 'static,
    {
        let stop = Arc::new(AtomicBool::new(false));
        let stop_thread = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("canal-history".into())
            .spawn(move || {
                while !stop_thread.load(Ordering::Relaxed) {
                    history.record(progress());
                    sleep_unless_stopped(history.period(), &stop_thread);
                }
            })
            .expect("spawn history sampler thread");
        HistorySampler { stop, handle: Some(handle) }
    }
}

impl Drop for HistorySampler {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Sleep up to `total`, waking early (within one 25 ms chunk) when
/// `stop` flips — keeps sampler shutdown prompt at any period.
fn sleep_unless_stopped(total: Duration, stop: &AtomicBool) {
    const CHUNK: Duration = Duration::from_millis(25);
    let deadline = Instant::now() + total;
    while !stop.load(Ordering::Relaxed) {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        std::thread::sleep((deadline - now).min(CHUNK));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_become_deltas_between_samples() {
        let h = MetricsHistory::new(8, Duration::from_millis(10));
        let c = metrics::counter("test.history.delta");
        c.add(5);
        h.record(None);
        c.add(2);
        h.record(None);
        h.record(None);
        let samples = h.samples();
        assert_eq!(samples.len(), 3);
        let delta_of = |s: &HistorySample| {
            s.counters.iter().find(|(n, _)| n == "test.history.delta").map(|(_, d)| *d)
        };
        assert_eq!(delta_of(&samples[0]), Some(5), "first sample baselines at zero");
        assert_eq!(delta_of(&samples[1]), Some(2));
        assert_eq!(delta_of(&samples[2]), None, "zero deltas are omitted");
    }

    #[test]
    fn ring_drops_oldest_and_seq_survives_eviction() {
        let h = MetricsHistory::new(3, Duration::from_millis(10));
        for _ in 0..5 {
            h.record(None);
        }
        let samples = h.samples();
        assert_eq!(samples.len(), 3);
        assert_eq!(samples[0].seq, 2, "oldest two were evicted");
        assert_eq!(samples[2].seq, 4);
        let (next, since) = h.since(3);
        assert_eq!(next, 5);
        assert_eq!(since.iter().map(|s| s.seq).collect::<Vec<_>>(), vec![3, 4]);
    }

    #[test]
    fn samples_carry_monotone_timestamps() {
        let h = MetricsHistory::new(4, Duration::from_millis(10));
        h.record(None);
        h.record(None);
        let s = h.samples();
        assert!(s[0].ts_ms > 0, "wall clock must be stamped");
        assert!(s[1].mono_ns > s[0].mono_ns, "monotonic clock must advance");
    }

    #[test]
    fn quantiles_and_progress_serialize() {
        let h = MetricsHistory::new(4, Duration::from_millis(10));
        metrics::histogram("test.history.hist").record(100);
        metrics::gauge("test.history.gauge").set(-3);
        h.record(Some(ProgressSample {
            jobs_total: 4,
            jobs_done: 2,
            cold_total: 3,
            cold_done: 1,
            worker_util_pct: vec![93, 88],
            ..Default::default()
        }));
        let doc = h.to_json();
        let line = doc.render_line();
        let parsed = Json::parse(&line).expect("history JSON must parse");
        let samples = parsed.get("samples").and_then(Json::as_arr).unwrap();
        let s = samples.last().unwrap();
        let q = s.get("quantiles").and_then(|q| q.get("test.history.hist")).unwrap();
        assert_eq!(q.get("count").and_then(Json::as_u64), Some(1));
        assert_eq!(q.get("p50").and_then(Json::as_f64), Some(100.0));
        let g = s.get("gauges").and_then(|g| g.get("test.history.gauge"));
        assert_eq!(g.and_then(Json::as_f64), Some(-3.0));
        let p = s.get("progress").unwrap();
        assert_eq!(p.get("jobs_done").and_then(Json::as_u64), Some(2));
        let util = p.get("util").and_then(Json::as_arr).unwrap();
        assert_eq!(util.len(), 2);
        assert_eq!(parsed.get("period_ms").and_then(Json::as_u64), Some(10));
    }

    #[test]
    fn sampler_thread_records_and_stops_on_drop() {
        let h = Arc::new(MetricsHistory::new(16, Duration::from_millis(5)));
        let sampler = HistorySampler::spawn(Arc::clone(&h), || None);
        let t0 = Instant::now();
        while h.is_empty() && t0.elapsed() < Duration::from_secs(5) {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(!h.is_empty(), "sampler never recorded a sample");
        drop(sampler);
        let n = h.len();
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(h.len(), n, "sampler must stop recording once dropped");
    }
}
